//! Fig 2 — "Data distribution in the heat equation simulation".
//!
//! (a) the full-run octave histogram is *globally wide* yet *locally
//! clustered*; (b)/(c) per-quarter stages show the *dynamic range shift*
//! (paper: first quarter reaches ±500, last quarter within ±0.25).

use r2f2::analysis::heat_distribution;
use r2f2::bench_util::parse_bench_args;
use r2f2::pde::heat1d::HeatParams;
use r2f2::report::ascii_plot::histogram;
use r2f2::report::{sig, CsvWriter, Table};

fn main() {
    let args = parse_bench_args();
    // Long decay so the range shift spans the paper's three decades:
    // amplitude 500 → ~0.2 needs t ≈ ln(2500)/(α·k²).
    let n = 257;
    let mut p = HeatParams::default();
    p.n = n;
    p.dt = 0.25 / ((n - 1) as f64 * (n - 1) as f64);
    p.steps = 70_000;
    println!(
        "heat run for distribution study: n={n}, steps={}, {} muls",
        p.steps,
        p.expected_muls()
    );

    let rep = heat_distribution(&p, 4);

    println!("\nFig 2(a): all multiplication operands/results ({} samples)", rep.samples);
    println!("{}", histogram("", &rep.overall.bars(), 44));
    let (lo, hi) = rep.overall.nonzero_range().unwrap();
    println!(
        "globally wide: {:.2e} .. {:.2e} ({} octaves occupied)\n\
         locally clustered: 90% of samples within {} contiguous octaves",
        lo,
        hi,
        rep.overall.occupied_octaves(),
        rep.overall.bulk_octaves(0.9)
    );

    let mut t = Table::new(vec!["stage", "min |v|", "max |v|", "90% within", "samples"]);
    let mut csv = CsvWriter::new();
    csv.row(vec!["stage", "min_abs", "max_abs", "bulk_octaves", "count"]);
    for s in &rep.stages {
        t.row(vec![
            format!("{}/4", s.index + 1),
            sig(s.min_abs, 3),
            sig(s.max_abs, 3),
            format!("{} octaves", s.histogram.bulk_octaves(0.9)),
            s.count.to_string(),
        ]);
        csv.row(vec![
            format!("{}", s.index + 1),
            format!("{}", s.min_abs),
            format!("{}", s.max_abs),
            format!("{}", s.histogram.bulk_octaves(0.9)),
            format!("{}", s.count),
        ]);
    }
    println!("\nFig 2(b)/(c): per-stage dynamic range shift");
    println!("{}", t.render());
    println!(
        "paper's trajectory: stage max goes ~500 → … → ~0.25; ours: {} → {}",
        sig(rep.stages[0].max_abs, 3),
        sig(rep.stages.last().unwrap().max_abs, 3)
    );

    let out = args.out.unwrap_or_else(|| "target/reports/fig2_distribution.csv".to_string());
    let path = std::path::Path::new(&out);
    csv.write(path).expect("write csv");
    println!("wrote {}", path.display());
}
