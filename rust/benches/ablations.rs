//! Ablations over the R2F2 design choices DESIGN.md §3 fixes:
//!
//! * redundancy window width — the paper's §4.2 discussion: "using one bit
//!   is too sensitive ... three bits is too conservative";
//! * narrowing streak threshold — our hysteresis interpretation (the
//!   literal streak=1 reading oscillates);
//! * widen-on-operand-underflow — the paper's literal trigger vs our
//!   silent-flush refinement;
//! * the flexible partial-product truncation — accuracy cost of the
//!   hardware approximation.

use r2f2::bench_util::parse_bench_args_no_artifact;
use r2f2::pde::heat1d::{run, HeatParams};
use r2f2::pde::{rel_l2, Arith, F32Arith, QuantMode};
use r2f2::r2f2core::{mul_packed, R2f2Config, R2f2Multiplier, Stats};
use r2f2::report::Table;
use r2f2::rng::SplitMix64;
use r2f2::softfloat::{decode, encode, mul, Rounder};

/// Heat run with a custom-built multiplier unit.
struct CustomUnit(R2f2Multiplier);

impl Arith for CustomUnit {
    fn name(&self) -> String {
        "custom".into()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.0.mul(a, b)
    }
    fn r2f2_stats(&self) -> Option<Stats> {
        Some(self.0.stats())
    }
}

fn heat_with(unit: R2f2Multiplier) -> (f64, Stats) {
    let mut p = HeatParams::default();
    p.n = 257;
    p.dt = 0.25 / (256.0f64 * 256.0);
    p.steps = 2000;
    let reference = run(&p, &mut F32Arith, QuantMode::MulOnly);
    let mut be = CustomUnit(unit);
    let res = run(&p, &mut be, QuantMode::MulOnly);
    (rel_l2(&res.u, &reference.u), res.r2f2_stats.unwrap())
}

fn main() {
    // No artifact here — the tables are the output; strict parsing still
    // rejects typos and a meaningless --out with exit 2.
    let _args = parse_bench_args_no_artifact();
    let cfg = R2f2Config::C16_393;

    // ---- redundancy window width (§4.2) --------------------------------
    println!("== ablation: redundancy window width (paper: 2 is the sweet spot) ==");
    let mut t = Table::new(vec!["window", "rel-err vs f32", "widen", "narrow", "note"]);
    for w in 1..=3u32 {
        let (err, st) = heat_with(R2f2Multiplier::new(cfg).with_window(w));
        t.row(vec![
            w.to_string(),
            format!("{err:.2e}"),
            st.overflow_adjustments.to_string(),
            st.redundancy_adjustments.to_string(),
            match w {
                1 => "aggressive narrowing → more widen-retries",
                2 => "paper's choice",
                _ => "conservative → rarely narrows",
            }
            .to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- narrowing streak threshold -------------------------------------
    println!("== ablation: narrowing streak threshold (hysteresis) ==");
    let mut t = Table::new(vec!["threshold", "rel-err vs f32", "widen", "narrow"]);
    for thr in [1u32, 8, 32, 128] {
        let (err, st) = heat_with(R2f2Multiplier::new(cfg).with_streak_threshold(thr));
        t.row(vec![
            thr.to_string(),
            format!("{err:.2e}"),
            st.overflow_adjustments.to_string(),
            st.redundancy_adjustments.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("threshold 1 (the literal Fig-5 reading) thrashes: every narrow is paid\nback by a widen-retry a few multiplications later.\n");

    // ---- widen on operand underflow --------------------------------------
    println!("== ablation: operand-underflow widening ==");
    let mut t = Table::new(vec!["policy", "rel-err vs f32", "widen", "unresolved"]);
    for (name, on) in [("silent flush (ours)", false), ("widen on flush (literal)", true)] {
        let (err, st) = heat_with(R2f2Multiplier::new(cfg).widen_on_operand_underflow(on));
        t.row(vec![
            name.to_string(),
            format!("{err:.2e}"),
            st.overflow_adjustments.to_string(),
            st.unresolved_range_events.to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- stochastic rounding (Paxton et al., cited §2) --------------------
    println!("== extension: stochastic rounding in a fully-half simulation ==");
    {
        use r2f2::pde::{F64Arith, FixedArith, StochasticArith};
        use r2f2::softfloat::FpFormat;
        let p = HeatParams::default();
        let reference = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let mut rne = FixedArith::new(FpFormat::E5M10);
        let err_rne = rel_l2(&run(&p, &mut rne, QuantMode::Full).u, &reference.u);
        let mut sr = StochasticArith::new(FpFormat::E5M10, 7);
        let err_sr = rel_l2(&run(&p, &mut sr, QuantMode::Full).u, &reference.u);
        let mut t = Table::new(vec!["rounding", "rel-err vs f64 (full-half heat)"]);
        t.row(vec!["nearest-even".to_string(), format!("{err_rne:.2e}")]);
        t.row(vec!["stochastic".to_string(), format!("{err_sr:.2e}")]);
        println!("{}", t.render());
        println!("Paxton et al.'s claim reproduced: stochastic rounding recovers much of\nthe deterministic-rounding failure — but R2F2 at the same width does\nbetter still without randomness (see fig1_fig7 bench).\n");
    }

    // ---- truncation approximation accuracy cost --------------------------
    println!("== ablation: flexible partial-product truncation (§4.1 approximation) ==");
    let mut rng = SplitMix64::new(5);
    let mut diffs = 0u64;
    let mut max_rel: f64 = 0.0;
    let n = 500_000u64;
    let k = 0; // worst case: t = FX bits dropped
    let fmt = cfg.format(k);
    for _ in 0..n {
        let a = encode(rng.log_uniform(0.25, 4.0), fmt, &mut Rounder::nearest_even()).0;
        let b = encode(rng.log_uniform(0.25, 4.0), fmt, &mut Rounder::nearest_even()).0;
        let (apx, _) = mul_packed(a, b, cfg, k, &mut Rounder::nearest_even());
        let (ex, _) = mul(a, b, fmt, &mut Rounder::nearest_even());
        if apx != ex {
            diffs += 1;
            let rel = ((decode(apx, fmt) - decode(ex, fmt)) / decode(ex, fmt)).abs();
            max_rel = max_rel.max(rel);
        }
    }
    println!(
        "k=0 (max truncation): {} of {} products differ from exact ({:.4}%),\n\
         max relative deviation {:.2e}\n\
         paper: \"errors smaller than 0.1% in less than 0.04% of the time\"",
        diffs,
        n,
        100.0 * diffs as f64 / n as f64,
        max_rel
    );
}
