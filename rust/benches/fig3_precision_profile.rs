//! Fig 3 — "Average computation error using different configurations" plus
//! the §3.2 Eq.(1) reliability check.
//!
//! For each operand range the paper discusses, profiles the full 16-bit
//! `E{e}M{15−e}` family (1000 pairs per cell, identical operands across
//! configurations) and compares the profiled optimum with the intuition
//! formula — reproducing the paper's finding that they disagree.

use r2f2::bench_util::parse_bench_args;
use r2f2::report::{sig, CsvWriter, Table};
use r2f2::sweep::config_profile::{
    best_of, eq1_exponent_bits, profile_range, sixteen_bit_family, PAPER_RANGES,
};

fn main() {
    let args = parse_bench_args();
    let configs = sixteen_bit_family();
    let mut csv = CsvWriter::new();
    let mut header = vec!["range".to_string()];
    header.extend(configs.iter().map(|c| c.to_string()));
    csv.row(header);

    println!("=============== FIG 3: per-range configuration profile ===============");
    let mut t = Table::new(vec!["range", "best (profiled)", "avg err", "Eq.(1)", "agree?", "paper says"]);
    // The paper's commentary per range (§3.2 / Fig 3).
    let paper_notes = [
        "5-bit exp, 10/11-bit mantissa",
        "3-bit exp (their lib allows emax=2^e−1; ours reserves the top code → E4)",
        "profiling 5 (Eq.1 wrongly says 6)",
        "profiling 6 (Eq.1 wrongly says 8)",
    ];
    for (idx, (lo, hi)) in PAPER_RANGES.into_iter().enumerate() {
        let pts = profile_range(lo, hi, &configs, 1000, 42 + idx as u64);
        let mut row = vec![format!("({lo},{hi})")];
        row.extend(pts.iter().map(|p| format!("{}", p.avg_err)));
        csv.row(row);

        println!("\nrange ({lo}, {hi}):");
        for p in &pts {
            let bar = (p.avg_err.min(1.0) * 40.0) as usize;
            println!("  {:<6} {:>10} |{}", p.fmt.to_string(), sig(p.avg_err, 3), "#".repeat(bar));
        }
        let best = best_of(&pts);
        let eq1 = eq1_exponent_bits(hi);
        t.row(vec![
            format!("({lo}, {hi})"),
            best.fmt.to_string(),
            sig(best.avg_err, 3),
            format!("E{eq1}"),
            if best.fmt.e_w == eq1 { "yes".into() } else { "NO".to_string() },
            paper_notes[idx].to_string(),
        ]);
    }
    println!("\n=============== §3.2: intuition vs profiling ===============");
    println!("{}", t.render());
    println!("Conclusion reproduced: Eq.(1) disagrees with the profiled optimum on\nmost ranges — \"dynamically determining the optimal data precision\nconfiguration in practice is non-trivial\".");

    let out = args.out.unwrap_or_else(|| "target/reports/fig3_profile.csv".to_string());
    let path = std::path::Path::new(&out);
    csv.write(path).expect("write csv");
    println!("wrote {}", path.display());
}
