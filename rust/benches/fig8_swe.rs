//! Fig 8 — "SWE simulation results using different precisions".
//!
//! The paper substitutes one sub-equation
//! (`Ux_mx = q1_mx²/q3_mx + 0.5g·q3_mx²`) out of 24 and shows snapshots at
//! three times: double is truth, 16-bit R2F2 matches it, E5M10 shows
//! visible artifacts. ~30 K substituted multiplications; R2F2 adjusted 7
//! (overflow) + 15 (redundancy) times.

use r2f2::bench_util::parse_bench_args;
use r2f2::pde::swe2d::{run, QuantScope, SweParams};
use r2f2::pde::{rel_l2, F64Arith, FixedArith, R2f2Arith};
use r2f2::r2f2core::R2f2Config;
use r2f2::report::ascii_plot::surface;
use r2f2::report::{CsvWriter, Table};
use r2f2::softfloat::FpFormat;
use std::time::Instant;

fn main() {
    let args = parse_bench_args();
    // Three snapshot times like the paper's 2/6/12-hour panels.
    let mut params = SweParams::default();
    params.steps = 60;
    params.snapshot_every = 20;
    println!(
        "SWE: {0}×{0} cells of {1} m, depth {2} m, {3} steps, {4} substituted muls",
        params.n,
        params.dx,
        params.init.base_depth,
        params.steps,
        6 * params.n * params.n * params.steps
    );
    println!(
        "substituted flux 0.5·g·h² ≈ {:.2e} > 65504 → E5M10 saturates (the Fig 8c artifact)\n",
        0.5 * params.g * params.init.base_depth * params.init.base_depth
    );

    let t0 = Instant::now();
    let truth = run(&params, &mut F64Arith, QuantScope::UxFluxOnly);
    let wall_f64 = t0.elapsed();

    let t0 = Instant::now();
    let mut half = FixedArith::new(FpFormat::E5M10);
    let halfr = run(&params, &mut half, QuantScope::UxFluxOnly);
    let wall_half = t0.elapsed();
    let he = halfr.range_events.unwrap();

    let t0 = Instant::now();
    let mut unit = R2f2Arith::new(R2f2Config::C16_384);
    let r2f2r = run(&params, &mut unit, QuantScope::UxFluxOnly);
    let wall_r2f2 = t0.elapsed();
    let st = r2f2r.r2f2_stats.unwrap();

    let mut t = Table::new(vec!["backend", "rel-err vs f64", "mass drift", "events", "wall"]);
    t.row(vec![
        "f64 (Fig 8a)".to_string(),
        "0".into(),
        format!("{:.1e}", truth.mass_drift),
        "-".into(),
        format!("{wall_f64:.0?}"),
    ]);
    t.row(vec![
        "R2F2 <3,8,4> (Fig 8b)".to_string(),
        format!("{:.2e}", rel_l2(&r2f2r.h, &truth.h)),
        format!("{:.1e}", r2f2r.mass_drift),
        format!(
            "{} widen / {} narrow in {} muls (paper: 7 / 15 in 30K)",
            st.overflow_adjustments, st.redundancy_adjustments, st.muls
        ),
        format!("{wall_r2f2:.0?}"),
    ]);
    t.row(vec![
        "E5M10 (Fig 8c)".to_string(),
        format!("{:.2e}", rel_l2(&halfr.h, &truth.h)),
        format!("{:.1e}", halfr.mass_drift),
        format!("{} overflows — flux saturated", he.overflows),
        format!("{wall_half:.0?}"),
    ]);
    println!("{}", t.render());

    // Snapshot panels (wave-height deviation) at the three times.
    let base = params.init.base_depth;
    let dev = |h: &[f64]| h.iter().map(|&x| x - base).collect::<Vec<f64>>();
    for (idx, (step, h)) in truth.snapshots.iter().enumerate() {
        println!("{}", surface(&format!("f64, t={step} steps (Fig 8a panel {})", idx + 1), &dev(h), params.n));
    }
    println!("{}", surface("R2F2 final (Fig 8b) — same wave pattern as f64", &dev(&r2f2r.h), params.n));
    println!("{}", surface("E5M10 final (Fig 8c) — corrupted pattern", &dev(&halfr.h), params.n));

    let mut csv = CsvWriter::new();
    csv.row(vec!["backend", "rel_err", "mass_drift", "widen", "narrow", "overflows"]);
    csv.row(vec!["f64".into(), "0".to_string(), format!("{}", truth.mass_drift), "0".into(), "0".into(), "0".into()]);
    csv.row(vec![
        "r2f2<3,8,4>".to_string(),
        format!("{}", rel_l2(&r2f2r.h, &truth.h)),
        format!("{}", r2f2r.mass_drift),
        format!("{}", st.overflow_adjustments),
        format!("{}", st.redundancy_adjustments),
        "0".into(),
    ]);
    csv.row(vec![
        "E5M10".to_string(),
        format!("{}", rel_l2(&halfr.h, &truth.h)),
        format!("{}", halfr.mass_drift),
        "0".into(),
        "0".into(),
        format!("{}", he.overflows),
    ]);
    let out = args.out.unwrap_or_else(|| "target/reports/fig8_swe.csv".to_string());
    let path = std::path::Path::new(&out);
    csv.write(path).expect("write csv");
    println!("wrote {}", path.display());
}
