//! Figs 1 & 7 — heat-equation simulations across precisions.
//!
//! Fig 1: f32 vs fully-half (E5M10 state + arithmetic) for sin and exp
//! initializations — half is visibly wrong.
//! Fig 7: 16-bit <3,9,3> and 15-bit <3,8,3> R2F2 multiplications achieve
//! the f32 result, with single-digit/tens adjustment counts over ~1.5 M
//! multiplications (paper: 5 overflow + 23 redundancy).

use r2f2::bench_util::parse_bench_args;
use r2f2::pde::heat1d::{run, HeatParams};
use r2f2::pde::init::HeatInit;
use r2f2::pde::{rel_l2, F32Arith, F64Arith, FixedArith, QuantMode, R2f2Arith};
use r2f2::r2f2core::R2f2Config;
use r2f2::report::ascii_plot::line_plot;
use r2f2::report::{CsvWriter, Table};
use r2f2::softfloat::FpFormat;
use std::time::Instant;

fn sample(u: &[f64]) -> Vec<f64> {
    u.iter().step_by(u.len().div_ceil(64)).copied().collect()
}

fn main() {
    let args = parse_bench_args();
    let mut csv = CsvWriter::new();
    csv.row(vec!["figure", "init", "backend", "mode", "rel_err_vs_f64", "widen", "narrow", "wall_ms"]);

    for (fig, init) in
        [("fig1(a,b)", HeatInit::sin_default()), ("fig1(c,d)", HeatInit::exp_default())]
    {
        let params = HeatParams { init, ..HeatParams::default() };
        let truth = run(&params, &mut F64Arith, QuantMode::MulOnly);
        println!(
            "\n================ {fig}: heat, init={}, {} muls ================",
            params.init.name(),
            params.expected_muls()
        );

        let mut t = Table::new(vec!["backend", "mode", "rel-err vs f64", "events", "wall"]);
        let mut series: Vec<(String, Vec<f64>)> = vec![("f64".into(), sample(&truth.u))];

        // f32 (the paper's "correct" panel).
        let t0 = Instant::now();
        let f32r = run(&params, &mut F32Arith, QuantMode::MulOnly);
        t.row(vec![
            "f32".to_string(),
            "mul-only".into(),
            format!("{:.2e}", rel_l2(&f32r.u, &truth.u)),
            "-".into(),
            format!("{:.0?}", t0.elapsed()),
        ]);
        csv.row(vec![
            fig.to_string(),
            params.init.name().into(),
            "f32".into(),
            "mul-only".into(),
            format!("{}", rel_l2(&f32r.u, &truth.u)),
            "0".into(),
            "0".into(),
            format!("{}", t0.elapsed().as_millis()),
        ]);

        // Fully-half (the paper's wrong panel).
        let t0 = Instant::now();
        let mut half = FixedArith::new(FpFormat::E5M10);
        let halfr = run(&params, &mut half, QuantMode::Full);
        let ev = halfr.range_events.unwrap();
        t.row(vec![
            "E5M10".to_string(),
            "full".into(),
            format!("{:.2e}", rel_l2(&halfr.u, &truth.u)),
            format!("{} oflow / {} uflow", ev.overflows, ev.underflows),
            format!("{:.0?}", t0.elapsed()),
        ]);
        csv.row(vec![
            fig.to_string(),
            params.init.name().into(),
            "E5M10".into(),
            "full".into(),
            format!("{}", rel_l2(&halfr.u, &truth.u)),
            format!("{}", ev.overflows),
            format!("{}", ev.underflows),
            format!("{}", t0.elapsed().as_millis()),
        ]);
        series.push(("E5M10-full".into(), sample(&halfr.u)));

        // Fig 7: R2F2 16/15-bit (sin panel is the one the paper shows).
        for cfg in [R2f2Config::C16_393, R2f2Config::C15_383] {
            let t0 = Instant::now();
            let mut be = R2f2Arith::new(cfg);
            let res = run(&params, &mut be, QuantMode::MulOnly);
            let st = res.r2f2_stats.unwrap();
            t.row(vec![
                format!("R2F2 {cfg}"),
                "mul-only".into(),
                format!("{:.2e}", rel_l2(&res.u, &truth.u)),
                format!(
                    "{} widen / {} narrow (paper: 5 / 23)",
                    st.overflow_adjustments, st.redundancy_adjustments
                ),
                format!("{:.0?}", t0.elapsed()),
            ]);
            csv.row(vec![
                "fig7".to_string(),
                params.init.name().into(),
                format!("r2f2{cfg}"),
                "mul-only".into(),
                format!("{}", rel_l2(&res.u, &truth.u)),
                format!("{}", st.overflow_adjustments),
                format!("{}", st.redundancy_adjustments),
                format!("{}", t0.elapsed().as_millis()),
            ]);
            if cfg == R2f2Config::C16_393 {
                series.push((format!("R2F2{cfg}"), sample(&res.u)));
            }
        }
        println!("{}", t.render());
        let refs: Vec<(&str, &[f64])> =
            series.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect();
        println!("{}", line_plot("final profiles", &refs, 64, 14));
    }

    let out = args.out.unwrap_or_else(|| "target/reports/fig1_fig7_heat.csv".to_string());
    let path = std::path::Path::new(&out);
    csv.write(path).expect("write csv");
    println!("wrote {}", path.display());
}
