//! §Perf — hot-path microbenchmarks across all three layers.
//!
//! L3 native: scalar multiplier throughput (the sweep/solver inner loop),
//! scalar-dispatch vs batched-engine heat steps (the DESIGN.md §8 rows —
//! the batched fixed-format and R2F2 paths must come out ≥ 2× faster),
//! parallel sweep scaling.
//! L1/L2 via PJRT: compiled heat/SWE step latency and steps/s (skipped when
//! artifacts are absent).

use r2f2::bench_util::{bench, bench_with, black_box, fmt_ns, print_results, BenchResult};
use r2f2::coordinator::parallel_map;
use r2f2::metrics::Registry;
use r2f2::pde::heat1d::{run, run_scalar, HeatParams, HeatResult};
use r2f2::pde::{Arith, F32Arith, F64Arith, FixedArith, QuantMode, R2f2Arith};
use r2f2::r2f2core::{R2f2Config, R2f2Multiplier};
use r2f2::rng::SplitMix64;
use r2f2::runtime::{HeatRunner, Runtime};
use r2f2::softfloat::{add_f, mul_batch_f, mul_f, quantize, Flags, FpFormat};
use r2f2::sweep::error_sweep::{error_sweep, SweepParams};
use std::time::Duration;

fn main() {
    let mut rng = SplitMix64::new(2);
    let ops: Vec<(f64, f64)> =
        (0..4096).map(|_| (rng.log_uniform(1e-4, 1e4), rng.log_uniform(1e-4, 1e4))).collect();

    // ---- L3 scalar units ------------------------------------------------
    let mut results: Vec<BenchResult> = Vec::new();
    let mut i = 0usize;
    results.push(bench("quantize E5M10", || {
        let (a, _) = ops[i & 4095];
        i += 1;
        black_box(quantize(a, FpFormat::E5M10));
    }));
    let mut i = 0usize;
    results.push(bench("softfloat mul_f E5M10", || {
        let (a, b) = ops[i & 4095];
        i += 1;
        black_box(mul_f(a, b, FpFormat::E5M10));
    }));
    let mut i = 0usize;
    results.push(bench("softfloat add_f E5M10", || {
        let (a, b) = ops[i & 4095];
        i += 1;
        black_box(add_f(a, b, FpFormat::E5M10));
    }));
    let mut unit = R2f2Multiplier::new(R2f2Config::C16_393);
    let mut i = 0usize;
    results.push(bench("R2f2Multiplier::mul (adaptive)", || {
        let (a, b) = ops[i & 4095];
        i += 1;
        black_box(unit.mul(a, b));
    }));
    // Batched counterparts of the scalar units above: one constant operand,
    // hoisted format/rounder state (DESIGN.md §8).
    let xs: Vec<f64> = ops.iter().map(|&(_, b)| b).collect();
    let mut out = vec![0.0f64; xs.len()];
    let mut flags = vec![Flags::NONE; xs.len()];
    results.push(bench_with(
        "softfloat mul_batch_f E5M10 ×256 els",
        30,
        Duration::from_millis(2),
        &mut || {
            mul_batch_f(0.25, &xs[..256], FpFormat::E5M10, &mut out[..256], &mut flags[..256]);
            black_box(&out);
        },
    ));
    let mut unit = R2f2Arith::new(R2f2Config::C16_393);
    results.push(bench_with(
        "R2f2Arith::mul_batch ×256 els",
        30,
        Duration::from_millis(2),
        &mut || {
            unit.mul_batch(&mut out[..256], 0.25, &xs[..256]);
            black_box(&out);
        },
    ));
    print_results("L3 scalar vs batched units", &results);

    // ---- L3 solver steps: scalar dispatch vs batched engine -------------
    let mut p = HeatParams::default();
    p.n = 257;
    p.dt = 0.25 / (256.0f64 * 256.0);
    p.steps = 50;

    fn heat_case(p: &HeatParams, which: usize, batched: bool) {
        type Run = fn(&HeatParams, &mut dyn Arith, QuantMode) -> HeatResult;
        let go: Run = if batched { run } else { run_scalar };
        match which {
            0 => {
                black_box(go(p, &mut F64Arith, QuantMode::MulOnly));
            }
            1 => {
                black_box(go(p, &mut F32Arith, QuantMode::MulOnly));
            }
            2 => {
                let mut be = FixedArith::new(FpFormat::E5M10);
                black_box(go(p, &mut be, QuantMode::MulOnly));
            }
            _ => {
                let mut be = R2f2Arith::new(R2f2Config::C16_393);
                black_box(go(p, &mut be, QuantMode::MulOnly));
            }
        }
    }

    let mut results = Vec::new();
    let mut medians = [[0.0f64; 2]; 4];
    for (which, name) in [
        (0usize, "heat 257×50 f64"),
        (1, "heat 257×50 f32"),
        (2, "heat 257×50 fixed E5M10"),
        (3, "heat 257×50 r2f2 <3,9,3>"),
    ] {
        for (bi, label) in [(0usize, "scalar dispatch"), (1, "batched engine")] {
            let pp = p.clone();
            let r = bench_with(
                &format!("{name} [{label}]"),
                10,
                Duration::from_millis(5),
                &mut || heat_case(&pp, which, bi == 1),
            );
            medians[which][bi] = r.median_ns;
            results.push(r);
        }
    }
    print_results("L3 solver (50 steps per iteration)", &results);
    println!("\nbatched-engine speedup vs scalar dispatch (median):");
    for (which, name) in
        [(0usize, "f64"), (1, "f32"), (2, "fixed E5M10"), (3, "r2f2 <3,9,3>")]
    {
        println!("  {name:<14} ×{:.2}", medians[which][0] / medians[which][1]);
    }

    // ---- Coordinator fan-out scaling ------------------------------------
    let sweep_job = |workers: usize| {
        let t0 = std::time::Instant::now();
        let chunks: Vec<u64> = (0..8).collect();
        let _ = parallel_map(chunks, workers, |seed| {
            error_sweep(
                R2f2Config::C16_393,
                FpFormat::E5M10,
                &SweepParams { intervals: 64, pairs: 100, seed, ..Default::default() },
            )
            .avg_reduction
        });
        t0.elapsed()
    };
    let t1 = sweep_job(1);
    let tn = sweep_job(r2f2::coordinator::default_workers());
    println!(
        "\ncoordinator fan-out: 8 sweep shards  1 worker: {}  {} workers: {}  speedup ×{:.1}",
        fmt_ns(t1.as_nanos() as f64),
        r2f2::coordinator::default_workers(),
        fmt_ns(tn.as_nanos() as f64),
        t1.as_secs_f64() / tn.as_secs_f64()
    );

    // ---- PJRT compiled path ---------------------------------------------
    match Runtime::from_default_dir() {
        Err(e) => println!("\nPJRT benches skipped: {e}"),
        Ok(mut rt) => {
            let m = Registry::new();
            let n = rt.manifest.heat_n;
            let u0: Vec<f32> = (0..n)
                .map(|i| 500.0 * (2.0 * std::f32::consts::PI * i as f32 / (n - 1) as f32).sin())
                .collect();
            println!("\nPJRT compiled step throughput (n={n}):");
            for variant in ["heat_step_f32", "heat_step_e5m10", "heat_step_r2f2"] {
                let runner = HeatRunner::new(&mut rt, variant, m.clone()).unwrap();
                let out = runner.run(&u0, 0.25, 200, 2).unwrap();
                println!(
                    "  {variant:<18} {:>8.0} steps/s  ({} per step)",
                    200.0 / out.elapsed.as_secs_f64(),
                    fmt_ns(out.elapsed.as_nanos() as f64 / 200.0)
                );
            }
            // Executable load+compile cost (cache miss vs hit).
            let t0 = std::time::Instant::now();
            let _ = rt.load("quantize_e5m10").unwrap();
            let miss = t0.elapsed();
            let t0 = std::time::Instant::now();
            let _ = rt.load("quantize_e5m10").unwrap();
            let hit = t0.elapsed();
            println!(
                "  artifact compile: cache miss {}  hit {}",
                fmt_ns(miss.as_nanos() as f64),
                fmt_ns(hit.as_nanos() as f64)
            );
        }
    }
}
