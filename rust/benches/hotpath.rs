//! §Perf — hot-path microbenchmarks across all three layers.
//!
//! L3 native: scalar multiplier throughput (the sweep/solver inner loop),
//! then the perf trajectory of the solver engines — **scalar dispatch**
//! (per-mul virtual calls) → **carrier engine** (PR-1 batching, f64-carrier
//! round-trips) → **packed engine** (DESIGN.md §9, state in bits) →
//! **SWAR engine** (§14, two lanes per u64) → **tiled** (§14, cache-tiled
//! sweeps over the worker pool) — on the heat and shallow-water workloads,
//! plus sweep sharding scaling. Tiers a workload can't run (tiling only
//! applies to `Full`-mode multi-step; R2F2 has no lane kernels) are `null`
//! in the JSON, so every speedup row stays one comparable family.
//! L1/L2 via PJRT: compiled heat/SWE step latency (skipped when artifacts
//! are absent).
//!
//! Flags (after `--` on the cargo command line):
//!   --smoke         cut workload sizes and sample counts (CI mode)
//!   --out <path>    also emit machine-readable results
//!                   (schema `r2f2-bench-hotpath/5`, see EXPERIMENTS.md §E11;
//!                   the `BENCH_smoke.json` snapshot path:
//!                   `cargo bench --bench hotpath -- --smoke --out BENCH_smoke.json`)
//!   --json <path>   alias for --out (kept for older invocations)
//!
//! Any other flag is an error (exit 2) — a typo must not silently bench
//! the wrong configuration.

use r2f2::bench_util::{
    bench_with, black_box, fmt_ns, parse_bench_args, print_results, BenchArgs, BenchResult,
};
use r2f2::coordinator::parallel_map;
use r2f2::metrics::Registry;
use r2f2::pde::adaptive::{
    fixed_cost_lut, run_heat as heat_run_adaptive, run_heat_scalar as heat_run_adaptive_scalar,
};
use r2f2::pde::decomp::run_heat as decomp_run_heat;
use r2f2::pde::heat1d::{run as heat_run, run_scalar as heat_run_scalar, HeatParams};
use r2f2::pde::scenario::{ScenarioSize, SCENARIOS};
use r2f2::pde::swe2d::{run as swe_run, run_scalar as swe_run_scalar, QuantScope, SweParams};
use r2f2::pde::{
    AdaptiveArith, AdaptivePolicy, Arith, BatchEngine, F32Arith, F64Arith, FixedArith, QuantMode,
    R2f2Arith,
};
use r2f2::r2f2core::{R2f2Config, R2f2Multiplier};
use r2f2::rng::SplitMix64;
use r2f2::runtime::{HeatRunner, Runtime};
use r2f2::softfloat::packed;
use r2f2::softfloat::{add_f, mul_batch_f, mul_f, quantize, Flags, FpFormat, Rounder};
use r2f2::sweep::error_sweep::{error_sweep, SweepParams};
use std::time::Duration;

// Argv handling lives in `bench_util::parse_bench_args` (shared with the
// figure benches): `--smoke`, canonical `--out` with `--json` as alias,
// unknown flags exit 2.

/// One engine tier of the perf trajectory. Each tier adds exactly one
/// optimisation on top of the previous one, so the row family reads as a
/// cumulative ablation: `Swar` is the packed engine with two lanes per u64
/// (DESIGN.md §14) pinned to a single tile; `Tiled` is the SWAR engine with
/// the default cache-tile geometry, so Full-mode sweeps fan out over the
/// worker pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Tier {
    Scalar,
    Carrier,
    Packed,
    Swar,
    Tiled,
}

impl Tier {
    fn label(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar dispatch",
            Tier::Carrier => "carrier engine",
            Tier::Packed => "packed engine",
            Tier::Swar => "swar engine",
            Tier::Tiled => "tiled swar engine",
        }
    }
}

/// Per-workload median timings of the tiers, for the speedup table.
/// Tiers a workload can't run stay `NaN` and are emitted as JSON `null`
/// (tiling only applies to Full-mode multi-step sweeps; the R2F2 truncated
/// datapath has no lane kernels, so Swar degrades to Packed there and we
/// don't report a duplicate number).
struct Trajectory {
    workload: &'static str,
    backend: &'static str,
    ns: [f64; 5], // indexed by Tier as declared; NaN = tier not applicable
}

/// One adaptive-scheduler workload row (DESIGN.md §10): timings of the
/// scalar vs packed adaptive runs plus the schedule/cost metadata.
struct AdaptiveRow {
    workload: String,
    scalar_ns: f64,
    packed_ns: f64,
    widen: u64,
    narrow: u64,
    final_format: String,
    modeled_cost_lut: f64,
    e5m10_cost_lut: f64,
}

/// One scenario-registry row: every registry workload through the shared
/// generic drivers, scalar dispatch vs the packed batched engine.
struct ScenarioRow {
    scenario: &'static str,
    scalar_ns: f64,
    packed_ns: f64,
    muls: u64,
}

/// One domain-decomposition scaling row (pde::decomp, DESIGN.md §13): the
/// heat workload sharded across the worker pool. Results are bit-identical
/// at every shard count (tests/decomp_identity.rs), so the only thing that
/// may move is the wall clock.
struct DecompRow {
    shards: usize,
    median_ns: f64,
    muls: u64,
}

// One escape routine crate-wide (PR-5 satellite): the same dual of
// `config::json_mini`'s parser that `metrics::to_json` and the server use,
// so bench-case names with quotes/backslashes/control chars stay valid.
use r2f2::config::json_escape;

fn emit_json(
    path: &str,
    smoke: bool,
    rows: &[BenchResult],
    trajs: &[Trajectory],
    adaptive: &[AdaptiveRow],
    scenarios: &[ScenarioRow],
    decomp: &[DecompRow],
) {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"r2f2-bench-hotpath/5\",\n");
    out.push_str(
        "  \"generator\": \"cargo bench --bench hotpath -- --smoke --out BENCH_smoke.json\",\n",
    );
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"median_ns\": {:.3}, \"mean_ns\": {:.3}, \
             \"p95_ns\": {:.3}, \"ops_per_s\": {:.3}}}{}\n",
            json_escape(&r.name),
            r.median_ns,
            r.mean_ns,
            r.p95_ns,
            r.throughput(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"speedups\": [\n");
    // NaN tiers (not applicable to the workload) become JSON `null` so every
    // row keeps the same field set — one comparable family under schema /5.
    let opt = |v: f64| if v.is_finite() { format!("{v:.3}") } else { "null".to_string() };
    for (i, t) in trajs.iter().enumerate() {
        let [s, c, p, sw, ti] = t.ns;
        out.push_str(&format!(
            "    {{\"workload\": \"{}\", \"backend\": \"{}\", \"scalar_ns\": {}, \
             \"carrier_ns\": {}, \"packed_ns\": {}, \"swar_ns\": {}, \"tiled_ns\": {}, \
             \"packed_vs_carrier\": {}, \"packed_vs_scalar\": {}, \
             \"swar_vs_packed\": {}, \"tiled_vs_packed\": {}}}{}\n",
            json_escape(t.workload),
            json_escape(t.backend),
            opt(s),
            opt(c),
            opt(p),
            opt(sw),
            opt(ti),
            opt(c / p),
            opt(s / p),
            opt(p / sw),
            opt(p / ti),
            if i + 1 < trajs.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"adaptive\": [\n");
    for (i, a) in adaptive.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"workload\": \"{}\", \"scalar_ns\": {:.3}, \"packed_ns\": {:.3}, \
             \"widen_events\": {}, \"narrow_events\": {}, \"final_format\": \"{}\", \
             \"modeled_cost_lut\": {:.3}, \"all_e5m10_cost_lut\": {:.3}}}{}\n",
            json_escape(&a.workload),
            a.scalar_ns,
            a.packed_ns,
            a.widen,
            a.narrow,
            json_escape(&a.final_format),
            a.modeled_cost_lut,
            a.e5m10_cost_lut,
            if i + 1 < adaptive.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"scenarios\": [\n");
    for (i, s) in scenarios.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"scalar_ns\": {:.3}, \"packed_ns\": {:.3}, \
             \"scalar_vs_packed\": {:.3}, \"muls\": {}}}{}\n",
            json_escape(s.scenario),
            s.scalar_ns,
            s.packed_ns,
            s.scalar_ns / s.packed_ns,
            s.muls,
            if i + 1 < scenarios.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"decomp\": [\n");
    let base_ns = decomp.first().map_or(1.0, |d| d.median_ns);
    for (i, d) in decomp.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"shards\": {}, \"median_ns\": {:.3}, \"muls\": {}, \
             \"speedup_vs_unsharded\": {:.3}}}{}\n",
            d.shards,
            d.median_ns,
            d.muls,
            base_ns / d.median_ns,
            if i + 1 < decomp.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    if let Err(e) = std::fs::write(path, out) {
        eprintln!("failed to write {path}: {e}");
        std::process::exit(1);
    }
    println!("\nwrote {path}");
}

fn main() {
    let opts: BenchArgs = parse_bench_args();
    let (samples, batch_ms) = if opts.smoke { (5, 1) } else { (10, 5) };
    let unit_samples = if opts.smoke { 8 } else { 30 };
    let mut all_rows: Vec<BenchResult> = Vec::new();
    let mut trajs: Vec<Trajectory> = Vec::new();

    let mut rng = SplitMix64::new(2);
    let ops: Vec<(f64, f64)> =
        (0..4096).map(|_| (rng.log_uniform(1e-4, 1e4), rng.log_uniform(1e-4, 1e4))).collect();

    // ---- L3 scalar vs batched vs packed units ---------------------------
    let mut results: Vec<BenchResult> = Vec::new();
    let mut i = 0usize;
    results.push(bench_with("quantize E5M10", unit_samples, Duration::from_millis(2), &mut || {
        let (a, _) = ops[i & 4095];
        i += 1;
        black_box(quantize(a, FpFormat::E5M10));
    }));
    let mut i = 0usize;
    results.push(bench_with(
        "softfloat mul_f E5M10",
        unit_samples,
        Duration::from_millis(2),
        &mut || {
            let (a, b) = ops[i & 4095];
            i += 1;
            black_box(mul_f(a, b, FpFormat::E5M10));
        },
    ));
    let mut i = 0usize;
    results.push(bench_with(
        "softfloat add_f E5M10",
        unit_samples,
        Duration::from_millis(2),
        &mut || {
            let (a, b) = ops[i & 4095];
            i += 1;
            black_box(add_f(a, b, FpFormat::E5M10));
        },
    ));
    // The packed word kernel alone (encode → mul → decode, no Fp structs).
    let pf = FpFormat::E5M10.packed();
    let mut rnd = Rounder::nearest_even();
    let mut i = 0usize;
    results.push(bench_with(
        "packed encode+mul+decode E5M10",
        unit_samples,
        Duration::from_millis(2),
        &mut || {
            let (a, b) = ops[i & 4095];
            i += 1;
            let (wa, fla) = packed::encode_bits(a.to_bits(), &pf, &mut rnd);
            let (wb, flb) = packed::encode_bits(b.to_bits(), &pf, &mut rnd);
            let (wc, flc) = packed::mul_packed(wa, wb, &pf, &mut rnd);
            black_box((packed::decode_word(wc, &pf), fla | flb | flc));
        },
    ));
    let mut unit = R2f2Multiplier::new(R2f2Config::C16_393);
    let mut i = 0usize;
    results.push(bench_with(
        "R2f2Multiplier::mul (adaptive)",
        unit_samples,
        Duration::from_millis(2),
        &mut || {
            let (a, b) = ops[i & 4095];
            i += 1;
            black_box(unit.mul(a, b));
        },
    ));
    let mut unit = R2f2Multiplier::new(R2f2Config::C16_393);
    let mut i = 0usize;
    results.push(bench_with(
        "R2f2Multiplier::mul_packed_pair",
        unit_samples,
        Duration::from_millis(2),
        &mut || {
            let (a, b) = ops[i & 4095];
            i += 1;
            black_box(unit.mul_packed_pair(a, b));
        },
    ));
    // Batched slice kernels: one constant operand, hoisted state.
    let xs: Vec<f64> = ops.iter().map(|&(_, b)| b).collect();
    let mut out = vec![0.0f64; xs.len()];
    let mut flags = vec![Flags::NONE; xs.len()];
    results.push(bench_with(
        "softfloat mul_batch_f E5M10 ×256 els",
        unit_samples,
        Duration::from_millis(2),
        &mut || {
            mul_batch_f(0.25, &xs[..256], FpFormat::E5M10, &mut out[..256], &mut flags[..256]);
            black_box(&out);
        },
    ));
    let mut be = R2f2Arith::new(R2f2Config::C16_393);
    results.push(bench_with(
        "R2f2Arith::mul_batch ×256 els",
        unit_samples,
        Duration::from_millis(2),
        &mut || {
            be.mul_batch(&mut out[..256], 0.25, &xs[..256]);
            black_box(&out);
        },
    ));
    print_results("L3 scalar vs batched vs packed units", &results);
    all_rows.extend(results);

    // ---- L3 heat solver: the three-tier perf trajectory -----------------
    let mut p = HeatParams::default();
    if opts.smoke {
        p.n = 129;
        p.dt = 0.25 / (128.0f64 * 128.0);
        p.steps = 10;
    } else {
        p.n = 257;
        p.dt = 0.25 / (256.0f64 * 256.0);
        p.steps = 50;
    }

    fn heat_case(p: &HeatParams, which: usize, tier: Tier, mode: QuantMode) {
        // Packed/Swar tiers pin the sweep to a single tile so the row
        // isolates the kernel change; only the Tiled tier uses the default
        // cache-tile geometry (and thus the worker pool on large grids).
        let one_tile = usize::MAX / 2;
        let mut be: Box<dyn Arith> = match (which, tier) {
            (0, _) => Box::new(F64Arith),
            (1, _) => Box::new(F32Arith),
            (2, Tier::Carrier) => {
                Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier))
            }
            (2, Tier::Swar) => Box::new(
                FixedArith::new(FpFormat::E5M10)
                    .with_engine(BatchEngine::Swar)
                    .with_tiling(1, one_tile),
            ),
            (2, Tier::Tiled) => {
                Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Swar))
            }
            (2, _) => Box::new(FixedArith::new(FpFormat::E5M10).with_tiling(1, one_tile)),
            (_, Tier::Carrier) => {
                Box::new(R2f2Arith::new(R2f2Config::C16_393).with_engine(BatchEngine::Carrier))
            }
            (_, _) => Box::new(R2f2Arith::new(R2f2Config::C16_393)),
        };
        if tier == Tier::Scalar {
            black_box(heat_run_scalar(p, be.as_mut(), mode));
        } else {
            black_box(heat_run(p, be.as_mut(), mode));
        }
    }

    let heat_label = if opts.smoke { "heat 129×10" } else { "heat 257×50" };
    let mut results = Vec::new();
    for (which, name, is_fixed_or_r2f2) in [
        (0usize, "f64", false),
        (1, "f32", false),
        (2, "fixed E5M10", true),
        (3, "r2f2 <3,9,3>", true),
    ] {
        // MulOnly batches pair up under the SWAR engine (fixed formats ≤ 16
        // bits only — R2F2's truncated datapath treats Swar as Packed, so a
        // Swar row there would just duplicate the packed number). Tiling is
        // a Full-mode property and doesn't apply here.
        let tiers: &[Tier] = match which {
            2 => &[Tier::Scalar, Tier::Carrier, Tier::Packed, Tier::Swar],
            _ if is_fixed_or_r2f2 => &[Tier::Scalar, Tier::Carrier, Tier::Packed],
            _ => &[Tier::Scalar, Tier::Packed],
        };
        let mut ns = [f64::NAN; 5];
        for &tier in tiers {
            let pp = p.clone();
            let r = bench_with(
                &format!("{heat_label} {name} [{}]", tier.label()),
                samples,
                Duration::from_millis(batch_ms),
                &mut || heat_case(&pp, which, tier, QuantMode::MulOnly),
            );
            ns[tier as usize] = r.median_ns;
            results.push(r);
        }
        if is_fixed_or_r2f2 {
            trajs.push(Trajectory { workload: "heat-mulonly", backend: name, ns });
        }
    }
    // Full mode: the packed engine keeps the whole state in bits across
    // timesteps, the SWAR engine runs two lanes per u64, and the tiled tier
    // fans cache-tile row blocks out over the worker pool — the full
    // trajectory. On this grid the default geometry collapses to a single
    // tile (interior < MIN_TILE), so the tiled row documents parity, not a
    // speedup; the large grid below is where tiling engages.
    {
        let mut ns = [f64::NAN; 5];
        for tier in [Tier::Scalar, Tier::Carrier, Tier::Packed, Tier::Swar, Tier::Tiled] {
            let pp = p.clone();
            let r = bench_with(
                &format!("{heat_label} fixed E5M10 full [{}]", tier.label()),
                samples,
                Duration::from_millis(batch_ms),
                &mut || heat_case(&pp, 2, tier, QuantMode::Full),
            );
            ns[tier as usize] = r.median_ns;
            results.push(r);
        }
        trajs.push(Trajectory { workload: "heat-full", backend: "fixed E5M10", ns });
    }
    // Full mode on a cache-straining grid: interior spans several MIN_TILE
    // widths, so the Tiled tier genuinely splits the sweep across workers
    // (deterministic tile order keeps it bit-identical — tests/swar_vs_packed.rs).
    {
        let mut big = HeatParams::default();
        if opts.smoke {
            big.n = 4097;
            big.dt = 0.25 / (4096.0f64 * 4096.0);
            big.steps = 5;
        } else {
            big.n = 16385;
            big.dt = 0.25 / (16384.0f64 * 16384.0);
            big.steps = 10;
        }
        let big_label = if opts.smoke { "heat 4097×5" } else { "heat 16385×10" };
        let mut ns = [f64::NAN; 5];
        for tier in [Tier::Scalar, Tier::Carrier, Tier::Packed, Tier::Swar, Tier::Tiled] {
            let pp = big.clone();
            let r = bench_with(
                &format!("{big_label} fixed E5M10 full [{}]", tier.label()),
                samples,
                Duration::from_millis(batch_ms),
                &mut || heat_case(&pp, 2, tier, QuantMode::Full),
            );
            ns[tier as usize] = r.median_ns;
            results.push(r);
        }
        trajs.push(Trajectory { workload: "heat-full-large", backend: "fixed E5M10", ns });
    }
    print_results("L3 heat solver (one run per iteration)", &results);
    all_rows.extend(results);

    // ---- L3 shallow water: same trajectory on the flux engine -----------
    let swe_p = SweParams { steps: if opts.smoke { 5 } else { 20 }, ..SweParams::default() };
    fn swe_case(p: &SweParams, fixed: bool, tier: Tier) {
        let mut be: Box<dyn Arith> = match (fixed, tier) {
            (true, Tier::Carrier) => {
                Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier))
            }
            (true, _) => Box::new(FixedArith::new(FpFormat::E5M10)),
            (false, Tier::Carrier) => {
                Box::new(R2f2Arith::new(R2f2Config::C16_384).with_engine(BatchEngine::Carrier))
            }
            (false, _) => Box::new(R2f2Arith::new(R2f2Config::C16_384)),
        };
        // AllFluxMuls so the quantized share of the work dominates.
        if tier == Tier::Scalar {
            black_box(swe_run_scalar(p, be.as_mut(), QuantScope::AllFluxMuls));
        } else {
            black_box(swe_run(p, be.as_mut(), QuantScope::AllFluxMuls));
        }
    }
    let swe_label = if opts.smoke { "swe 16×16×5" } else { "swe 16×16×20" };
    let mut results = Vec::new();
    // The SWE hot path is flux_batch, which stays on the scalar-word packed
    // kernels under every engine (DESIGN.md §14) — swar/tiled rows would
    // duplicate the packed number, so they stay null here.
    for (fixed, name) in [(true, "fixed E5M10"), (false, "r2f2 <3,8,4>")] {
        let mut ns = [f64::NAN; 5];
        for tier in [Tier::Scalar, Tier::Carrier, Tier::Packed] {
            let pp = swe_p.clone();
            let r = bench_with(
                &format!("{swe_label} {name} [{}]", tier.label()),
                samples,
                Duration::from_millis(batch_ms),
                &mut || swe_case(&pp, fixed, tier),
            );
            ns[tier as usize] = r.median_ns;
            results.push(r);
        }
        trajs.push(Trajectory { workload: "swe-allflux", backend: name, ns });
    }
    print_results("L3 shallow water (one run per iteration)", &results);
    all_rows.extend(results);

    // ---- L3 adaptive precision scheduler (DESIGN.md §10) ----------------
    // Scalar vs packed adaptive heat runs under the default E4M3→E5M10
    // ladder. The bench-sized runs widen out of FP8 immediately (amplitude
    // 500 > 480) and are too short to narrow — the schedule metadata rows
    // record what the scheduler actually did alongside the timings.
    let adapt_policy = || {
        let mut pol = AdaptivePolicy::heat_default();
        pol.epoch_len = if opts.smoke { 8 } else { 16 };
        pol
    };
    let mut results = Vec::new();
    let mut adaptive_rows: Vec<AdaptiveRow> = Vec::new();
    for (mode, mode_label) in [(QuantMode::MulOnly, "mulonly"), (QuantMode::Full, "full")] {
        let mut ns = [0.0f64; 2];
        for (idx, tier_label) in [(0usize, "scalar dispatch"), (1, "packed engine")] {
            let pp = p.clone();
            let r = bench_with(
                &format!("{heat_label} adaptive E4M3→E5M10 {mode_label} [{tier_label}]"),
                samples,
                Duration::from_millis(batch_ms),
                &mut || {
                    let mut sched = AdaptiveArith::new(adapt_policy());
                    if idx == 0 {
                        black_box(heat_run_adaptive_scalar(&pp, &mut sched, mode));
                    } else {
                        black_box(heat_run_adaptive(&pp, &mut sched, mode));
                    }
                },
            );
            ns[idx] = r.median_ns;
            results.push(r);
        }
        // One instrumented run for the schedule/cost metadata.
        let mut sched = AdaptiveArith::new(adapt_policy());
        let _ = heat_run_adaptive(&p, &mut sched, mode);
        let rep = sched.report();
        adaptive_rows.push(AdaptiveRow {
            workload: format!("heat-{mode_label}"),
            scalar_ns: ns[0],
            packed_ns: ns[1],
            widen: rep.widen_events,
            narrow: rep.narrow_events,
            final_format: rep.final_format.to_string(),
            modeled_cost_lut: rep.modeled_cost_lut,
            e5m10_cost_lut: fixed_cost_lut(FpFormat::E5M10, p.expected_muls()),
        });
    }
    print_results("L3 adaptive scheduler (one run per iteration)", &results);
    all_rows.extend(results);
    println!("\nadaptive schedule metadata:");
    for a in &adaptive_rows {
        println!(
            "  {:<14} widen {}  narrow {}  final {}  modeled cost {:.3e} LUT·ops \
             (all-E5M10 {:.3e})",
            a.workload, a.widen, a.narrow, a.final_format, a.modeled_cost_lut, a.e5m10_cost_lut
        );
    }

    // ---- L3 scenario registry (DESIGN.md §11) ---------------------------
    // Every registry workload through the shared generic drivers, under
    // the E5M10 fixed backend: scalar dispatch vs the packed batched
    // engine. The registry is the row source, so a scenario added there
    // automatically lands here (and in the CI schema check).
    let mut results = Vec::new();
    let mut scenario_rows: Vec<ScenarioRow> = Vec::new();
    for spec in SCENARIOS {
        let mut ns = [0.0f64; 2];
        for (idx, tier_label) in [(0usize, "scalar dispatch"), (1, "packed engine")] {
            let r = bench_with(
                &format!("scenario {} E5M10 mulonly [{tier_label}]", spec.name),
                samples,
                Duration::from_millis(batch_ms),
                &mut || {
                    let mut be = FixedArith::new(FpFormat::E5M10);
                    black_box((spec.run)(
                        ScenarioSize::Quick,
                        &mut be,
                        QuantMode::MulOnly,
                        idx == 1,
                    ));
                },
            );
            ns[idx] = r.median_ns;
            results.push(r);
        }
        let mut be = FixedArith::new(FpFormat::E5M10);
        let probe = (spec.run)(ScenarioSize::Quick, &mut be, QuantMode::MulOnly, true);
        scenario_rows.push(ScenarioRow {
            scenario: spec.name,
            scalar_ns: ns[0],
            packed_ns: ns[1],
            muls: probe.muls,
        });
    }
    print_results("L3 scenario registry (one run per iteration)", &results);
    all_rows.extend(results);
    println!("\nscenario registry rows:");
    for s in &scenario_rows {
        println!(
            "  {:<12} scalar {}  packed {}  ({:.2}x, {} muls)",
            s.scenario,
            fmt_ns(s.scalar_ns),
            fmt_ns(s.packed_ns),
            s.scalar_ns / s.packed_ns,
            s.muls
        );
    }

    // ---- L3 domain decomposition (DESIGN.md §13) -------------------------
    // The heat workload sharded across the worker pool via pde::decomp.
    // Bit-identity is the conformance suite's job; here we record the
    // wall-clock scaling and double-check the mul count never moves.
    let mut results = Vec::new();
    let mut decomp_rows: Vec<DecompRow> = Vec::new();
    let mut decomp_muls = 0u64;
    for shards in [1usize, 2, 4, 8] {
        let pp = p.clone();
        let r = bench_with(
            &format!("{heat_label} fixed E5M10 decomp ×{shards} shards"),
            samples,
            Duration::from_millis(batch_ms),
            &mut || {
                let mut be = FixedArith::new(FpFormat::E5M10);
                black_box(decomp_run_heat(&pp, &mut be, QuantMode::MulOnly, shards));
            },
        );
        let mut be = FixedArith::new(FpFormat::E5M10);
        let probe = decomp_run_heat(&p, &mut be, QuantMode::MulOnly, shards);
        if shards == 1 {
            decomp_muls = probe.muls;
        }
        assert_eq!(probe.muls, decomp_muls, "sharding must not change the op count");
        decomp_rows.push(DecompRow { shards, median_ns: r.median_ns, muls: probe.muls });
        results.push(r);
    }
    print_results("L3 domain decomposition (one run per iteration)", &results);
    all_rows.extend(results);
    println!("\nsharded-scaling rows ({} workers available):", r2f2::coordinator::default_workers());
    for d in &decomp_rows {
        println!(
            "  shards {:<2} median {}  ×{:.2} vs unsharded  ({} muls)",
            d.shards,
            fmt_ns(d.median_ns),
            decomp_rows[0].median_ns / d.median_ns,
            d.muls
        );
    }

    // ---- Speedup summary -------------------------------------------------
    println!("\nengine-tier speedups (median; '-' = tier not applicable):");
    println!(
        "{:<16} {:<14} {:>10} {:>10} {:>10} {:>10} {:>10} {:>9} {:>9} {:>9}",
        "workload", "backend", "scalar", "carrier", "packed", "swar", "tiled", "pk/scal",
        "sw/pk", "ti/pk"
    );
    let cell = |v: f64| if v.is_finite() { fmt_ns(v) } else { "-".to_string() };
    let ratio = |num: f64, den: f64| {
        let r = num / den;
        if r.is_finite() { format!("{r:.2}x") } else { "-".to_string() }
    };
    for t in &trajs {
        let [s, c, p, sw, ti] = t.ns;
        println!(
            "{:<16} {:<14} {:>10} {:>10} {:>10} {:>10} {:>10} {:>9} {:>9} {:>9}",
            t.workload,
            t.backend,
            cell(s),
            cell(c),
            cell(p),
            cell(sw),
            cell(ti),
            ratio(s, p),
            ratio(p, sw),
            ratio(p, ti)
        );
    }

    // ---- Sweep sharding + coordinator fan-out ---------------------------
    let sweep_intervals = if opts.smoke { 32 } else { 64 };
    let shard_job = |workers: usize| {
        let t0 = std::time::Instant::now();
        let _ = error_sweep(
            R2f2Config::C16_393,
            FpFormat::E5M10,
            &SweepParams {
                intervals: sweep_intervals * 8,
                pairs: 100,
                workers,
                ..Default::default()
            },
        );
        t0.elapsed()
    };
    let t1 = shard_job(1);
    let tn = shard_job(r2f2::coordinator::default_workers());
    println!(
        "\nsweep sharding: {} intervals  1 worker: {}  {} workers: {}  speedup ×{:.1}",
        sweep_intervals * 8,
        fmt_ns(t1.as_nanos() as f64),
        r2f2::coordinator::default_workers(),
        fmt_ns(tn.as_nanos() as f64),
        t1.as_secs_f64() / tn.as_secs_f64()
    );
    let fan_job = |workers: usize| {
        let t0 = std::time::Instant::now();
        let chunks: Vec<u64> = (0..8).collect();
        let _ = parallel_map(chunks, workers, |seed| {
            error_sweep(
                R2f2Config::C16_393,
                FpFormat::E5M10,
                &SweepParams {
                    intervals: sweep_intervals,
                    pairs: 100,
                    seed,
                    workers: 1,
                    ..Default::default()
                },
            )
            .avg_reduction
        });
        t0.elapsed()
    };
    let t1 = fan_job(1);
    let tn = fan_job(r2f2::coordinator::default_workers());
    println!(
        "coordinator fan-out: 8 sweep shards  1 worker: {}  {} workers: {}  speedup ×{:.1}",
        fmt_ns(t1.as_nanos() as f64),
        r2f2::coordinator::default_workers(),
        fmt_ns(tn.as_nanos() as f64),
        t1.as_secs_f64() / tn.as_secs_f64()
    );

    // ---- PJRT compiled path ---------------------------------------------
    match Runtime::from_default_dir() {
        Err(e) => println!("\nPJRT benches skipped: {e}"),
        Ok(mut rt) => {
            let m = Registry::new();
            let n = rt.manifest.heat_n;
            let u0: Vec<f32> = (0..n)
                .map(|i| 500.0 * (2.0 * std::f32::consts::PI * i as f32 / (n - 1) as f32).sin())
                .collect();
            println!("\nPJRT compiled step throughput (n={n}):");
            for variant in ["heat_step_f32", "heat_step_e5m10", "heat_step_r2f2"] {
                let runner = HeatRunner::new(&mut rt, variant, m.clone()).unwrap();
                let out = runner.run(&u0, 0.25, 200, 2).unwrap();
                println!(
                    "  {variant:<18} {:>8.0} steps/s  ({} per step)",
                    200.0 / out.elapsed.as_secs_f64(),
                    fmt_ns(out.elapsed.as_nanos() as f64 / 200.0)
                );
            }
            // Executable load+compile cost (cache miss vs hit).
            let t0 = std::time::Instant::now();
            let _ = rt.load("quantize_e5m10").unwrap();
            let miss = t0.elapsed();
            let t0 = std::time::Instant::now();
            let _ = rt.load("quantize_e5m10").unwrap();
            let hit = t0.elapsed();
            println!(
                "  artifact compile: cache miss {}  hit {}",
                fmt_ns(miss.as_nanos() as f64),
                fmt_ns(hit.as_nanos() as f64)
            );
        }
    }

    if let Some(path) = &opts.out {
        emit_json(
            path,
            opts.smoke,
            &all_rows,
            &trajs,
            &adaptive_rows,
            &scenario_rows,
            &decomp_rows,
        );
    }
}
