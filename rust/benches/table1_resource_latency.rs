//! Table 1 — "Resource and latency overhead of R2F2".
//!
//! Regenerates every row: FF/LUT from the calibrated structural cost model
//! (`r2f2core::resource`), latency/II from the cycle-accurate datapath
//! schedule (`r2f2core::datapath`), printed against the paper's published
//! numbers with per-cell deviation. The Vitis HLS *library* rows are opaque
//! vendor IP and are reported verbatim for context.

use r2f2::bench_util::{bench, black_box, parse_bench_args_no_artifact, print_results};
use r2f2::r2f2core::{datapath, mul_packed, resource, R2f2Config};
use r2f2::report::Table;
use r2f2::rng::SplitMix64;
use r2f2::softfloat::{encode, mul, FpFormat, Rounder};

fn main() {
    // Tables only, no artifact; strict parsing rejects typos with exit 2.
    let _args = parse_bench_args_no_artifact();
    println!("==================== TABLE 1 ====================");

    // Library rows (from the paper; not modelled — see DESIGN.md §6).
    let mut t = Table::new(vec!["unit", "FF", "FF(paper)", "Δ%", "LUT", "LUT(paper)", "Δ%", "Lat", "II"]);
    for (name, ff, lut, lat, ii) in resource::LIB_ROWS {
        t.row(vec![
            name.to_string(),
            "-".into(),
            ff.to_string(),
            "-".into(),
            "-".into(),
            lut.to_string(),
            "-".into(),
            lat.to_string(),
            ii.to_string(),
        ]);
    }

    let dev = |model: f64, paper: u32| format!("{:+.1}", 100.0 * (model - paper as f64) / paper as f64);

    // Impl. fixed-format rows (model anchored on these three).
    for (fmt, row) in [
        (FpFormat::E11M52, &resource::PAPER_ROWS[0]),
        (FpFormat::E8M23, &resource::PAPER_ROWS[1]),
        (FpFormat::E5M10, &resource::PAPER_ROWS[2]),
    ] {
        let r = resource::fixed_multiplier(fmt);
        let s = datapath::fixed_schedule(fmt.total_bits());
        t.row(vec![
            row.name.to_string(),
            format!("{:.0}", r.ff),
            row.ff.to_string(),
            dev(r.ff, row.ff),
            format!("{:.0}", r.lut),
            row.lut.to_string(),
            dev(r.lut, row.lut),
            s.latency.to_string(),
            s.ii.to_string(),
        ]);
    }

    // R2F2 rows.
    for (i, cfg) in R2f2Config::TABLE1.iter().enumerate() {
        let r = resource::r2f2_multiplier(*cfg);
        let s = datapath::r2f2_schedule(*cfg);
        let row = &resource::PAPER_ROWS[3 + i];
        t.row(vec![
            row.name.to_string(),
            format!("{:.0}", r.ff),
            row.ff.to_string(),
            dev(r.ff, row.ff),
            format!("{:.0}", r.lut),
            row.lut.to_string(),
            dev(r.lut, row.lut),
            s.latency.to_string(),
            s.ii.to_string(),
        ]);
    }
    println!("{}", t.render());

    // Headline ratios the abstract claims.
    let half = resource::fixed_multiplier(FpFormat::E5M10);
    let single = resource::fixed_multiplier(FpFormat::E8M23);
    let mut lo = (f64::MAX, f64::MAX);
    let mut hi: (f64, f64) = (0.0, 0.0);
    for cfg in R2f2Config::TABLE1 {
        let (ff, lut) = resource::r2f2_multiplier(cfg).overhead(&half);
        lo = (lo.0.min(ff), lo.1.min(lut));
        hi = (hi.0.max(ff), hi.1.max(lut));
    }
    println!(
        "vs half:   FF {:+.1}%..{:+.1}% (paper −5%..+2%), LUT {:+.1}%..{:+.1}% (paper +3%..+7%)",
        100.0 * (lo.0 - 1.0),
        100.0 * (hi.0 - 1.0),
        100.0 * (lo.1 - 1.0),
        100.0 * (hi.1 - 1.0)
    );
    let (ffs, luts) = resource::r2f2_multiplier(R2f2Config::C16_393).overhead(&single);
    println!(
        "vs single: LUT −{:.1}% (paper −37.9%), FF −{:.1}% (paper −33.2%)",
        100.0 * (1.0 - luts),
        100.0 * (1.0 - ffs)
    );

    // Pipeline schedule trace (the 12-cycle / II=4 claim, from structure).
    println!("\ndatapath trace for <3,9,3>:");
    for (cycle, stage) in datapath::trace(R2f2Config::C16_393) {
        println!("  cycle {cycle:>2}: {stage}");
    }
    let s = datapath::r2f2_schedule(R2f2Config::C16_393);
    println!("pipelined: 1000 muls in {} cycles (II={})", s.latency + 999 * s.ii, s.ii);

    // Software-emulation throughput of the same units (context for §Perf).
    let fmt = FpFormat::E5M10;
    let cfg = R2f2Config::C16_393;
    let mut rng = SplitMix64::new(1);
    let ops: Vec<(f64, f64)> =
        (0..1024).map(|_| (rng.log_uniform(1e-3, 1e3), rng.log_uniform(1e-3, 1e3))).collect();
    let mut r1 = Rounder::nearest_even();
    let mut i = 0;
    let results = vec![
        bench("softfloat fixed E5M10 mul (encode+mul+decode)", || {
            let (a, b) = ops[i & 1023];
            i += 1;
            let (fa, _) = encode(a, fmt, &mut r1);
            let (fb, _) = encode(b, fmt, &mut r1);
            black_box(mul(fa, fb, fmt, &mut r1));
        }),
        {
            let mut j = 0;
            let mut r2 = Rounder::nearest_even();
            bench("r2f2 truncated mul_packed k=0", || {
                let (a, b) = ops[j & 1023];
                j += 1;
                let (fa, _) = encode(a, cfg.format(0), &mut r2);
                let (fb, _) = encode(b, cfg.format(0), &mut r2);
                black_box(mul_packed(fa, fb, cfg, 0, &mut r2));
            })
        },
    ];
    print_results("software emulation throughput", &results);
}
