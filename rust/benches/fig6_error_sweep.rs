//! Fig 6 — "Error reduction of R2F2 compared with fixed types".
//!
//! The paper's protocol (§5.1): operands swept over (1e-4, 1e4) in 10 K
//! intervals × 1000 random pairs, error measured against the
//! single-precision product with range failures cast to 100%. Reports both
//! error-reduction aggregations (see DESIGN.md E5): the per-interval mean
//! (conservative) and the pooled error-mass reduction (generous); the
//! paper's 70.2%/70.6%/70.7% falls between them.
//!
//! Full paper scale: `R2F2_BENCH_FULL=1 cargo bench --bench fig6_error_sweep`
//! (≈10 M multiplications per unit per pairing); default is a 2000×200
//! subsample with statistically identical structure.

use r2f2::bench_util::parse_bench_args;
use r2f2::report::ascii_plot::line_plot;
use r2f2::report::{pct, CsvWriter, Table};
use r2f2::sweep::error_sweep::{error_sweep, paper_pairings, SweepParams};
use std::time::Instant;

fn main() {
    let args = parse_bench_args();
    let full = std::env::var("R2F2_BENCH_FULL").is_ok();
    let params = if full {
        SweepParams::default() // 10 000 × 1000 — the paper's exact protocol
    } else {
        SweepParams { intervals: 2000, pairs: 200, ..SweepParams::default() }
    };
    println!(
        "sweep: {} intervals × {} pairs over ({:.0e}, {:.0e}){}",
        params.intervals,
        params.pairs,
        params.lo,
        params.hi,
        if full { " [FULL]" } else { " [set R2F2_BENCH_FULL=1 for the full 10K×1000]" }
    );

    let mut t = Table::new(vec![
        "pairing",
        "avg reduction",
        "pooled reduction",
        "max",
        "min",
        "paper avg",
        "wall",
    ]);
    let paper_avg = ["70.2%", "70.6%", "70.7%"];
    let mut csv = CsvWriter::new();
    csv.row(vec!["pairing", "interval_lo", "interval_hi", "err_fixed", "err_r2f2", "reduction"]);

    for (idx, (cfg, fixed)) in paper_pairings().into_iter().enumerate() {
        let t0 = Instant::now();
        let r = error_sweep(cfg, fixed, &params);
        t.row(vec![
            format!("{cfg} vs {fixed}"),
            pct(r.avg_reduction),
            pct(r.global_reduction),
            pct(r.max_reduction),
            pct(r.min_reduction),
            paper_avg[idx].to_string(),
            format!("{:.1?}", t0.elapsed()),
        ]);
        for iv in &r.intervals {
            csv.row(vec![
                format!("{cfg}"),
                format!("{}", iv.lo),
                format!("{}", iv.hi),
                format!("{}", iv.err_fixed),
                format!("{}", iv.err_r2f2),
                format!("{}", iv.reduction()),
            ]);
        }

        if idx == 0 {
            // Fig 6(a)-style curves: per-interval error vs operand range
            // (log-spaced), fixed saturating at 100% outside its range.
            let stride = (r.intervals.len() / 120).max(1);
            let fixed_curve: Vec<f64> =
                r.intervals.iter().step_by(stride).map(|iv| iv.err_fixed).collect();
            let r2f2_curve: Vec<f64> =
                r.intervals.iter().step_by(stride).map(|iv| iv.err_r2f2).collect();
            println!(
                "{}",
                line_plot(
                    "Fig 6(a): mean error per interval, operands 1e-4 → 1e4 (log axis)",
                    &[("E5M10", &fixed_curve), ("R2F2<3,9,3>", &r2f2_curve)],
                    120,
                    16,
                )
            );
            // Zoom: the in-range region (0.01, 200) of Fig 6(b)-(d).
            let zoom: Vec<&r2f2::sweep::error_sweep::IntervalResult> =
                r.intervals.iter().filter(|iv| iv.lo >= 0.01 && iv.hi <= 200.0).collect();
            let zf: Vec<f64> = zoom.iter().map(|iv| iv.err_fixed).collect();
            let zr: Vec<f64> = zoom.iter().map(|iv| iv.err_r2f2).collect();
            println!(
                "{}",
                line_plot(
                    "Fig 6(b-d) zoom (0.01, 200): absolute error, R2F2 below fixed where it narrows",
                    &[("E5M10", &zf), ("R2F2", &zr)],
                    120,
                    12,
                )
            );
        }
    }

    println!("================ FIG 6(g): error reduction summary ================");
    println!("{}", t.render());
    println!(
        "Our per-interval mean is conservative (~50%) and the pooled error-mass\n\
         reduction is generous (>99%); the paper's 70.2% aggregation lies between\n\
         (see EXPERIMENTS.md E5). Max ≈ 99.9% and small negative dips (truncation\n\
         approximation) match the paper's description."
    );

    let out = args.out.unwrap_or_else(|| "target/reports/fig6_error_sweep.csv".to_string());
    let path = std::path::Path::new(&out);
    csv.write(path).expect("write csv");
    println!("wrote {}", path.display());
}
