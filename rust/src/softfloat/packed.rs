//! The packed-domain kernels (DESIGN.md §9).
//!
//! The PR-1 batched engine still pays the **carrier tax** on the hot path:
//! every multiplication decodes packed operands to the `f64` carrier,
//! re-encodes them, multiplies in the integer domain and decodes the product
//! back. These kernels keep values **in** the packed representation — one
//! `u32` word per element in the §3.1 wire layout `[sign | exp | frac]` —
//! and do all arithmetic with 64-bit integer intermediates (`m_w ≤ 29` is
//! guaranteed by [`PackedFormat`], so nothing needs `u128`).
//!
//! **Contract.** Every kernel is **bit-identical** to its carrier twin:
//!
//! * [`encode_bits`] ≡ [`encode`]`(f64::from_bits(bits), fmt, r)` packed to
//!   a word — same value, same [`Flags`], same stochastic RNG draws;
//! * [`mul_packed`] ≡ [`crate::softfloat::mul`] on the unpacked operands;
//! * [`add_packed`] ≡ [`crate::softfloat::add`];
//! * [`decode_word`] ≡ [`crate::softfloat::decode`].
//!
//! `rust/tests/packed_vs_carrier.rs` enforces this exhaustively for small
//! formats and property-based over log-uniform regimes (including the
//! saturate/flush boundaries) for the larger ones.

use super::encode::encode;
use super::format::{Flags, FpFormat, PackedFormat};
use super::round::Rounder;

const F64_FRAC_BITS: u32 = 52;
const F64_EXP_MASK: u64 = 0x7FF;

/// Guard + round + sticky bits carried through addition alignment (must
/// match `softfloat::add`).
const G: u32 = 3;

/// Encode raw `f64` bits into a packed word with one correctly-rounded
/// step — the branch-light twin of [`encode`] using the precomputed
/// [`PackedFormat`] constants. Same values, same flags, same RNG draws.
#[inline]
pub fn encode_bits(bits: u64, pf: &PackedFormat, r: &mut Rounder) -> (u32, Flags) {
    let sign = ((bits >> 63) as u32) & 1;
    let e_f64 = ((bits >> F64_FRAC_BITS) & F64_EXP_MASK) as i64;
    let frac52 = bits & ((1u64 << F64_FRAC_BITS) - 1);

    if e_f64 == 0 {
        // Zero or f64 subnormal: flush.
        let fl = if frac52 != 0 { Flags::UNDERFLOW } else { Flags::NONE };
        return (pf.zero_word(sign), fl);
    }
    if e_f64 == F64_EXP_MASK as i64 {
        if frac52 != 0 {
            return (0, Flags::NAN_INPUT);
        }
        return (pf.max_word_signed(sign), Flags::OVERFLOW);
    }

    let mut flags = Flags::NONE;
    // m_w ≤ 29 ⇒ frac_shift ≥ 23: the shifted rounding always runs.
    let (f, inexact) = r.round_shift64(frac52, pf.frac_shift);
    if inexact {
        flags |= Flags::INEXACT;
    }
    let (frac, exp_carry) = if f >> pf.m_w != 0 {
        (0u32, 1i64) // fraction rounded up to 2.0: renormalize
    } else {
        (f as u32, 0i64)
    };

    let e = e_f64 - 1023 + exp_carry + pf.bias;
    if e <= 0 {
        return (pf.zero_word(sign), flags | Flags::UNDERFLOW);
    }
    if e > pf.max_biased_exp {
        return (pf.max_word_signed(sign), flags | Flags::OVERFLOW);
    }
    ((sign << pf.sign_shift) | ((e as u32) << pf.m_w) | frac, flags)
}

/// Encode a whole `f64` slice into packed words, appending per-element
/// words and flags (both vectors are cleared first). One shared rounding
/// context, constants hoisted — element-for-element bit-identical to
/// calling [`encode`] in a loop.
pub fn encode_slice_bits(
    xs: &[f64], // r2f2-audit: allow(native-float-quarantine) — encode boundary: carrier input is bits-only via to_bits, no float arithmetic
    pf: &PackedFormat,
    r: &mut Rounder,
    words: &mut Vec<u32>,
    flags: &mut Vec<Flags>,
) {
    words.clear();
    flags.clear();
    words.reserve(xs.len());
    flags.reserve(xs.len());
    for &x in xs {
        let (w, fl) = encode_bits(x.to_bits(), pf, r);
        words.push(w);
        flags.push(fl);
    }
}

/// Decode a packed word back to `f64` by direct bit construction — the
/// word's fraction slides into the top of the f64 fraction field and the
/// exponent is rebased. No float arithmetic; exact.
#[inline]
pub fn decode_word(w: u32, pf: &PackedFormat) -> f64 { // r2f2-audit: allow(native-float-quarantine) — decode boundary: exact bit construction
    let sign = ((w >> pf.sign_shift) & 1) as u64;
    let exp = (w >> pf.m_w) & pf.exp_mask;
    if exp == 0 {
        return f64::from_bits(sign << 63); // r2f2-audit: allow(native-float-quarantine) — signed-zero carrier, pure bit pattern
    }
    let e_f64 = (exp as i64 - pf.bias + 1023) as u64;
    let frac = (w & pf.frac_mask) as u64;
    f64::from_bits((sign << 63) | (e_f64 << 52) | (frac << pf.frac_shift)) // r2f2-audit: allow(native-float-quarantine) — from_bits is exact, no rounding
}

/// Shared tail of [`mul_packed`]: normalize the raw mantissa product,
/// round, rebase the exponent, saturate/flush. Delegates to the one
/// 64-bit implementation (`softfloat::mul::normalize_round_pack64`) and
/// packs the result to a word — the repack is a few shifts, and keeping a
/// single copy of the rounding algorithm keeps the bit-identity contract
/// un-forkable.
#[inline]
pub(crate) fn normalize_round_pack_word(
    p: u64,
    sign: u32,
    exp_sum: i64,
    pf: &PackedFormat,
    r: &mut Rounder,
) -> (u32, Flags) {
    let (fp, flags) = super::mul::normalize_round_pack64(p, sign as u8, exp_sum, pf.fmt, r);
    (pf.from_fp(fp), flags)
}

/// Multiply two packed words with one rounding step — the word-domain twin
/// of [`crate::softfloat::mul`], operating on `[sign|exp|frac]` words
/// directly with no decode.
#[inline]
pub fn mul_packed(wa: u32, wb: u32, pf: &PackedFormat, r: &mut Rounder) -> (u32, Flags) {
    let sign = ((wa ^ wb) >> pf.sign_shift) & 1;
    let ea = (wa >> pf.m_w) & pf.exp_mask;
    let eb = (wb >> pf.m_w) & pf.exp_mask;
    if ea == 0 || eb == 0 {
        return (pf.zero_word(sign), Flags::NONE);
    }

    let lead = 1u64 << pf.m_w;
    let ia = lead | (wa & pf.frac_mask) as u64;
    let ib = lead | (wb & pf.frac_mask) as u64;
    let p = ia * ib; // ≤ 2·m_w + 2 ≤ 60 bits: fits u64

    normalize_round_pack_word(p, sign, ea as i64 + eb as i64, pf, r)
}

/// Add two packed words with one rounding step — the word-domain twin of
/// [`crate::softfloat::add`] (align–add–normalize–round with
/// guard/round/sticky bits), including its signed-zero conventions.
#[inline]
pub fn add_packed(wa: u32, wb: u32, pf: &PackedFormat, r: &mut Rounder) -> (u32, Flags) {
    let sa = (wa >> pf.sign_shift) & 1;
    let sb = (wb >> pf.sign_shift) & 1;
    let mag_a = wa & pf.mag_mask;
    let mag_b = wb & pf.mag_mask;
    if mag_a >> pf.m_w == 0 && mag_b >> pf.m_w == 0 {
        return (pf.zero_word(sa & sb), Flags::NONE);
    }
    if mag_a >> pf.m_w == 0 {
        return (wb, Flags::NONE);
    }
    if mag_b >> pf.m_w == 0 {
        return (wa, Flags::NONE);
    }

    // Order by magnitude so `hi` dominates the result sign; the word's
    // magnitude bits ARE the (exp, frac) lexicographic key.
    let (hs, hmag, lmag) = if mag_a >= mag_b { (sa, mag_a, mag_b) } else { (sb, mag_b, mag_a) };
    let m_w = pf.m_w;
    let lead = 1u64 << m_w;
    let mhi = (lead | (hmag & pf.frac_mask) as u64) << G;
    let mlo_full = lead | (lmag & pf.frac_mask) as u64;
    let hexp = (hmag >> m_w) as i64;
    let d = (hmag >> m_w) - (lmag >> m_w);

    // Align the smaller operand, collapsing shifted-out bits into sticky.
    let mlo = if d == 0 {
        mlo_full << G
    } else if d >= m_w + G + 2 {
        1 // pure sticky: lo is non-zero but far below the guard bits
    } else {
        let full = mlo_full << G;
        (full >> d) | u64::from(full & ((1u64 << d) - 1) != 0)
    };

    let mut flags = Flags::NONE;
    if sa == sb {
        // Effective addition: sum ∈ [2^(m_w+G+1), 2^(m_w+G+2)).
        let sum = mhi + mlo;
        let (shift, exp_inc) =
            if sum >> (m_w + G + 1) != 0 { (G + 1, 1i64) } else { (G, 0i64) };
        let (val, inexact) = r.round_shift64(sum, shift);
        if inexact {
            flags |= Flags::INEXACT;
        }
        pack_word(val, hs, hexp + exp_inc, pf, flags)
    } else {
        // Effective subtraction; exact cancellation gives +0.
        let diff = mhi - mlo;
        if diff == 0 {
            return (0, flags);
        }
        let msb = 63 - diff.leading_zeros();
        let target = m_w + G;
        debug_assert!(msb <= target);
        let lshift = target - msb;
        let e = hexp - lshift as i64;
        if e <= 0 {
            return (pf.zero_word(hs), flags | Flags::UNDERFLOW);
        }
        let (val, inexact) = r.round_shift64(diff << lshift, G);
        if inexact {
            flags |= Flags::INEXACT;
        }
        pack_word(val, hs, e, pf, flags)
    }
}

/// Common tail of [`add_packed`]: post-rounding renormalize carry, range
/// check, pack — the word twin of `softfloat::add`'s `pack`.
#[inline]
fn pack_word(mut val: u64, sign: u32, mut e: i64, pf: &PackedFormat, flags: Flags) -> (u32, Flags) {
    if val >> (pf.m_w + 1) != 0 {
        val >>= 1; // 10.00…0 — exact
        e += 1;
    }
    debug_assert!(val >> pf.m_w == 1, "normalized significand expected");
    if e <= 0 {
        return (pf.zero_word(sign), flags | Flags::UNDERFLOW);
    }
    if e > pf.max_biased_exp {
        return (pf.max_word_signed(sign), flags | Flags::OVERFLOW);
    }
    ((sign << pf.sign_shift) | ((e as u32) << pf.m_w) | (val as u32 & pf.frac_mask), flags)
}

/// Transcode one packed word from `from` to `to` — the **repack hook** the
/// adaptive precision scheduler uses at a format switch (`pde::adaptive`):
/// the whole state vector is re-encoded in one pass over the words instead
/// of being bounced through an `f64` slice per element.
///
/// Contract: bit-identical (value *and* flags) to quantizing the decoded
/// value into `to` — `encode(decode_word(w, from), to, r)`:
///
/// * widening (`to` has ≥ mantissa bits and ≥ exponent bits): pure shifts
///   and a rebias, exact and flag-free — exactly what the carrier encode
///   reports for an already-representable value;
/// * same format: identity, flag-free;
/// * narrowing (or mixed trade-offs): one correctly-rounded encode from
///   the exact f64 bit image (`decode_word` is a bit construction, so no
///   float arithmetic happens even on this path).
#[inline]
pub fn repack_word(
    w: u32,
    from: &PackedFormat,
    to: &PackedFormat,
    r: &mut Rounder,
) -> (u32, Flags) {
    if from.fmt == to.fmt {
        return (w, Flags::NONE);
    }
    if to.m_w >= from.m_w && to.e_w >= from.e_w {
        let sign = (w >> from.sign_shift) & 1;
        let exp = (w >> from.m_w) & from.exp_mask;
        if exp == 0 {
            return (to.zero_word(sign), Flags::NONE);
        }
        // Rebias: to.bias ≥ from.bias keeps e ≥ 1, and the max biased
        // exponents differ by at least the bias difference, so e always
        // fits — the widened format covers the whole source range.
        let e = (exp as i64 - from.bias + to.bias) as u32;
        let frac = (w & from.frac_mask) << (to.m_w - from.m_w);
        return ((sign << to.sign_shift) | (e << to.m_w) | frac, Flags::NONE);
    }
    encode_bits(decode_word(w, from).to_bits(), to, r)
}

/// A state vector living in the packed domain: one `u32` word per element
/// in the §3.1 wire layout, plus the constant table of the format it is
/// packed in. This is what the packed solver paths keep across
/// `QuantMode::Full` timesteps instead of bouncing every node through the
/// `f64` carrier.
///
/// ```
/// use r2f2::softfloat::{FpFormat, PackedVec, Rounder};
///
/// let mut r = Rounder::nearest_even();
/// let (v, flags) = PackedVec::encode(&[1.0, -2.5, 0.0], FpFormat::E5M10, &mut r);
/// assert!(flags.iter().all(|f| f.is_empty()));
/// let mut out = [0.0f64; 3];
/// v.decode_into(&mut out);
/// assert_eq!(out, [1.0, -2.5, 0.0]);
/// ```
#[derive(Debug, Clone)]
pub struct PackedVec {
    pf: PackedFormat,
    words: Vec<u32>,
}

impl PackedVec {
    /// An empty vector in `fmt` (panics unless [`FpFormat::fits_word`]).
    pub fn new(fmt: FpFormat) -> PackedVec {
        PackedVec { pf: PackedFormat::new(fmt), words: Vec::new() }
    }

    /// Encode an `f64` slice, returning the packed vector and the
    /// per-element encode flags.
    pub fn encode(xs: &[f64], fmt: FpFormat, r: &mut Rounder) -> (PackedVec, Vec<Flags>) { // r2f2-audit: allow(native-float-quarantine) — encode boundary into the packed domain
        let mut v = PackedVec::new(fmt);
        let mut flags = Vec::new();
        encode_slice_bits(xs, &v.pf, r, &mut v.words, &mut flags);
        (v, flags)
    }

    /// Re-encode in place from an `f64` slice (flags appended to `flags`).
    pub fn encode_from(&mut self, xs: &[f64], r: &mut Rounder, flags: &mut Vec<Flags>) { // r2f2-audit: allow(native-float-quarantine) — encode boundary into the packed domain
        let pf = self.pf;
        encode_slice_bits(xs, &pf, r, &mut self.words, flags);
    }

    /// Decode every element into `out` (must match in length). Exact.
    pub fn decode_into(&self, out: &mut [f64]) { // r2f2-audit: allow(native-float-quarantine) — decode boundary out of the packed domain (exact)
        assert_eq!(out.len(), self.words.len());
        for (o, &w) in out.iter_mut().zip(self.words.iter()) {
            *o = decode_word(w, &self.pf);
        }
    }

    /// The constant table of the format this vector is packed in.
    pub fn packed_format(&self) -> &PackedFormat {
        &self.pf
    }

    /// The format this vector is packed in.
    pub fn format(&self) -> FpFormat {
        self.pf.fmt
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// The raw words (wire layout, low bits = fraction).
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// Mutable access for in-place kernels.
    pub fn words_mut(&mut self) -> &mut Vec<u32> {
        &mut self.words
    }

    /// Re-encode the whole vector into `to` **in place** with one pass of
    /// [`repack_word`] — the adaptive scheduler's format-switch primitive.
    /// `on_flags` sees each element's repack flags (index, flags), exactly
    /// the flags a per-element `quant` through the carrier would raise.
    pub fn repack(
        &mut self,
        to: FpFormat,
        r: &mut Rounder,
        mut on_flags: impl FnMut(usize, Flags),
    ) {
        let to_pf = PackedFormat::new(to);
        let from = self.pf;
        for (i, w) in self.words.iter_mut().enumerate() {
            let (nw, fl) = repack_word(*w, &from, &to_pf, r);
            *w = nw;
            on_flags(i, fl);
        }
        self.pf = to_pf;
    }
}

/// Convenience for tests and interop: encode one `f64` through the carrier
/// [`encode`] and pack the result to a word — the value [`encode_bits`]
/// must reproduce.
pub fn encode_via_carrier(x: f64, pf: &PackedFormat, r: &mut Rounder) -> (u32, Flags) { // r2f2-audit: allow(native-float-quarantine) — carrier-path oracle the packed encoder is tested against
    let (fp, fl) = encode(x, pf.fmt, r);
    (pf.from_fp(fp), fl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;
    use crate::softfloat::{add as carrier_add, decode, mul as carrier_mul};

    fn formats() -> Vec<FpFormat> {
        vec![
            FpFormat::E5M10,
            FpFormat::new(4, 3),
            FpFormat::new(6, 9),
            FpFormat::E8M7,
            FpFormat::E8M23,
        ]
    }

    #[test]
    fn encode_bits_matches_carrier_on_nasty_values() {
        let specials = [
            0.0,
            -0.0,
            1.0,
            -1.0,
            65504.0,
            65520.0,
            6.103515625e-5,
            1e-30,
            1e30,
            2047.9999,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            f64::MIN_POSITIVE / 4.0, // f64 subnormal
            f64::MAX,
        ];
        for fmt in formats() {
            let pf = fmt.packed();
            let mut ra = Rounder::nearest_even();
            let mut rb = Rounder::nearest_even();
            for &x in &specials {
                let (got_w, got_fl) = encode_bits(x.to_bits(), &pf, &mut ra);
                let (want_w, want_fl) = encode_via_carrier(x, &pf, &mut rb);
                assert_eq!((got_w, got_fl), (want_w, want_fl), "{fmt}: x={x}");
            }
        }
    }

    #[test]
    fn encode_bits_matches_carrier_random_all_modes() {
        let mut rng = SplitMix64::new(0x915);
        for fmt in formats() {
            let pf = fmt.packed();
            for seed in [1u64, 2, 3] {
                let mut ra = Rounder::stochastic(seed);
                let mut rb = Rounder::stochastic(seed);
                for _ in 0..5_000 {
                    let x = f64::from_bits(rng.next_u64());
                    let (gw, gf) = encode_bits(x.to_bits(), &pf, &mut ra);
                    let (ww, wf) = encode_via_carrier(x, &pf, &mut rb);
                    assert_eq!((gw, gf), (ww, wf), "{fmt}: x={x:e}");
                }
            }
        }
    }

    #[test]
    fn decode_word_matches_carrier_exhaustive_e5m10() {
        let fmt = FpFormat::E5M10;
        let pf = fmt.packed();
        for w in 0..(1u32 << fmt.total_bits()) {
            let fp = pf.to_fp(w);
            if fp.exp as i64 > fmt.max_biased_exp() {
                continue; // reserved all-ones exponent never occurs
            }
            let got = decode_word(w, &pf);
            let want = decode(fp, fmt);
            assert_eq!(got.to_bits(), want.to_bits(), "w={w:#x}");
        }
    }

    #[test]
    fn mul_packed_matches_carrier_exhaustive_e4m3() {
        // Every ordered pair of E4M3 codepoints (256 × 256).
        let fmt = FpFormat::new(4, 3);
        let pf = fmt.packed();
        let mut ra = Rounder::nearest_even();
        let mut rb = Rounder::nearest_even();
        for wa in 0..(1u32 << fmt.total_bits()) {
            let fa = pf.to_fp(wa);
            if fa.exp as i64 > fmt.max_biased_exp() {
                continue;
            }
            for wb in 0..(1u32 << fmt.total_bits()) {
                let fb = pf.to_fp(wb);
                if fb.exp as i64 > fmt.max_biased_exp() {
                    continue;
                }
                let (gw, gf) = mul_packed(wa, wb, &pf, &mut ra);
                let (wfp, wf) = carrier_mul(fa, fb, fmt, &mut rb);
                assert_eq!((pf.to_fp(gw), gf), (wfp, wf), "{wa:#x} × {wb:#x}");
            }
        }
    }

    #[test]
    fn add_packed_matches_carrier_exhaustive_e4m3() {
        let fmt = FpFormat::new(4, 3);
        let pf = fmt.packed();
        let mut ra = Rounder::nearest_even();
        let mut rb = Rounder::nearest_even();
        for wa in 0..(1u32 << fmt.total_bits()) {
            let fa = pf.to_fp(wa);
            if fa.exp as i64 > fmt.max_biased_exp() {
                continue;
            }
            for wb in 0..(1u32 << fmt.total_bits()) {
                let fb = pf.to_fp(wb);
                if fb.exp as i64 > fmt.max_biased_exp() {
                    continue;
                }
                let (gw, gf) = add_packed(wa, wb, &pf, &mut ra);
                let (wfp, wf) = carrier_add(fa, fb, fmt, &mut rb);
                assert_eq!((pf.to_fp(gw), gf), (wfp, wf), "{wa:#x} + {wb:#x}");
            }
        }
    }

    #[test]
    fn packed_vec_roundtrip_preserves_representable_values() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let xs: Vec<f64> = vec![1.0, -2.5, 0.0, -0.0, 65504.0, 6.103515625e-5];
        let (v, flags) = PackedVec::encode(&xs, fmt, &mut r);
        assert_eq!(v.len(), xs.len());
        assert!(flags.iter().all(|f| f.is_empty()));
        let mut out = vec![0.0; xs.len()];
        v.decode_into(&mut out);
        for (a, b) in xs.iter().zip(out.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn packed_vec_flags_report_range_events() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (_, flags) = PackedVec::encode(&[1e6, 1e-6, 1.5], fmt, &mut r);
        assert!(flags[0].overflow());
        assert!(flags[1].underflow());
        assert!(flags[2].is_empty());
    }

    #[test]
    fn repack_word_matches_carrier_quantize_exhaustive() {
        // Every E5M10 codepoint through every interesting transition:
        // widen (E5M10→E8M23, E5M10→E6M9-by-both?), identity, narrow
        // (E5M10→E4M3), and the mixed trade (E5M10→E4M11: fewer exponent,
        // more mantissa bits). The reference is quantize-through-carrier.
        let from_fmt = FpFormat::E5M10;
        let from = from_fmt.packed();
        for to_fmt in
            [FpFormat::E8M23, FpFormat::new(6, 11), from_fmt, FpFormat::E4M3, FpFormat::new(4, 11)]
        {
            let to = to_fmt.packed();
            let mut ra = Rounder::nearest_even();
            let mut rb = Rounder::nearest_even();
            for w in 0..(1u32 << from_fmt.total_bits()) {
                let fp = from.to_fp(w);
                if fp.exp as i64 > from_fmt.max_biased_exp() {
                    continue; // reserved all-ones exponent never occurs
                }
                let v = decode_word(w, &from);
                let (got_w, got_fl) = repack_word(w, &from, &to, &mut ra);
                let (want_w, want_fl) = encode_bits(v.to_bits(), &to, &mut rb);
                assert_eq!(
                    (got_w, got_fl),
                    (want_w, want_fl),
                    "{from_fmt}→{to_fmt}: w={w:#x} v={v:e}"
                );
                if to_fmt == from_fmt {
                    assert_eq!(got_w, w, "identity repack must not rewrite");
                }
            }
        }
    }

    #[test]
    fn packed_vec_repack_roundtrips_and_reports_flags() {
        let mut r = Rounder::nearest_even();
        let xs = [1.0, -2.5, 0.0, 480.0, 65504.0, 1e-3];
        let (mut v, _) = PackedVec::encode(&xs, FpFormat::E5M10, &mut r);
        // Widen: exact, flag-free, format updated.
        v.repack(FpFormat::E8M23, &mut r, |i, fl| assert!(fl.is_empty(), "widen flag at {i}"));
        assert_eq!(v.format(), FpFormat::E8M23);
        let mut out = [0.0f64; 6];
        v.decode_into(&mut out);
        for (a, b) in xs.iter().zip(out.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Narrow to E4M3: 65504 saturates, 1e-3 flushes — the flags the
        // scheduler's event accounting relies on.
        let mut saw_over = false;
        let mut saw_under = false;
        v.repack(FpFormat::E4M3, &mut r, |_, fl| {
            saw_over |= fl.overflow();
            saw_under |= fl.underflow();
        });
        assert!(saw_over && saw_under);
        v.decode_into(&mut out);
        assert_eq!(out[3], 480.0); // E4M3 max finite
        assert_eq!(out[4], 480.0);
        assert_eq!(out[5], 0.0);
    }

    #[test]
    fn neg_word_is_exact_negation() {
        let fmt = FpFormat::E5M10;
        let pf = fmt.packed();
        let mut r = Rounder::nearest_even();
        for &x in &[1.5, -3.25, 0.0, -0.0, 65504.0] {
            let (w, _) = encode_bits(x.to_bits(), &pf, &mut r);
            assert_eq!(decode_word(pf.neg_word(w), &pf).to_bits(), (-x).to_bits(), "x={x}");
        }
    }
}
