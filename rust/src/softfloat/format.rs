//! Floating-point format descriptors, packed values and exception flags.

use std::fmt;

/// An arbitrary floating-point format: `e_w` exponent bits, `m_w` stored
/// fraction bits (the leading 1 is implicit). Written `E{e_w}M{m_w}` in the
/// paper's notation — `E5M10` is IEEE half without subnormals/inf/NaN.
///
/// ```
/// use r2f2::softfloat::{quantize, FpFormat};
///
/// let half = FpFormat::E5M10;                  // standard half precision
/// assert_eq!(half.max_value(), 65504.0);       // §4.1: 2¹⁵·(1+1023/1024)
/// assert_eq!(half.total_bits(), 16);
///
/// // One more exponent bit buys range at the cost of resolution.
/// let e6m9 = FpFormat::new(6, 9);
/// assert!(e6m9.max_value() > half.max_value());
/// assert!(e6m9.ulp_at_one() > half.ulp_at_one());
///
/// // Round-trip an f64 through the format.
/// assert_eq!(quantize(3.14159265, half), 3.140625);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FpFormat {
    /// Exponent field width in bits (2..=11).
    pub e_w: u32,
    /// Fraction field width in bits (1..=52).
    pub m_w: u32,
}

impl FpFormat {
    /// Standard half precision (5-bit exponent, 10-bit fraction).
    pub const E5M10: FpFormat = FpFormat { e_w: 5, m_w: 10 };
    /// FP8 (4-bit exponent, 3-bit fraction) — the narrow end of the
    /// adaptive precision scheduler's default ladder (`pde::adaptive`).
    pub const E4M3: FpFormat = FpFormat { e_w: 4, m_w: 3 };
    /// 15-bit fixed baseline used in the paper's Fig. 6(e).
    pub const E5M9: FpFormat = FpFormat { e_w: 5, m_w: 9 };
    /// 14-bit fixed baseline used in the paper's Fig. 6(f).
    pub const E5M8: FpFormat = FpFormat { e_w: 5, m_w: 8 };
    /// bfloat16.
    pub const E8M7: FpFormat = FpFormat { e_w: 8, m_w: 7 };
    /// Single precision (normals only).
    pub const E8M23: FpFormat = FpFormat { e_w: 8, m_w: 23 };
    /// Double precision (normals only).
    pub const E11M52: FpFormat = FpFormat { e_w: 11, m_w: 52 };

    /// Construct a format, validating the supported widths.
    pub const fn new(e_w: u32, m_w: u32) -> FpFormat {
        assert!(e_w >= 2 && e_w <= 11, "exponent width must be in 2..=11");
        assert!(m_w >= 1 && m_w <= 52, "fraction width must be in 1..=52");
        FpFormat { e_w, m_w }
    }

    /// Exponent bias: `2^(e_w−1) − 1`.
    pub const fn bias(&self) -> i64 {
        (1i64 << (self.e_w - 1)) - 1
    }

    /// Largest biased exponent of a finite value (`2^e_w − 2`; the all-ones
    /// code is reserved, matching IEEE and the paper's max-value arithmetic).
    pub const fn max_biased_exp(&self) -> i64 {
        (1i64 << self.e_w) - 2
    }

    /// Total storage bits including the sign.
    pub const fn total_bits(&self) -> u32 {
        1 + self.e_w + self.m_w
    }

    /// Largest representable finite value.
    pub fn max_value(&self) -> f64 {
        let e = self.max_biased_exp() - self.bias();
        let frac = ((1u64 << self.m_w) - 1) as f64 / (1u64 << self.m_w) as f64;
        (1.0 + frac) * pow2(e)
    }

    /// Smallest positive normal value (`2^(1 − bias)`).
    pub fn min_normal(&self) -> f64 {
        pow2(1 - self.bias())
    }

    /// Unit in the last place at 1.0 (`2^−m_w`) — the format's resolution.
    pub fn ulp_at_one(&self) -> f64 {
        pow2(-(self.m_w as i64))
    }

    /// Largest finite value of this format as a packed [`Fp`].
    pub fn max_finite(&self, sign: u8) -> Fp {
        Fp { sign, exp: self.max_biased_exp() as u32, frac: (1u64 << self.m_w) - 1 }
    }

    /// Does the format fit one [`PackedFormat`] word (`total_bits ≤ 32`)?
    /// Every format the packed-domain engine accelerates must; `E11M52`
    /// (the f64 mirror) is the notable exception and falls back to the
    /// carrier path.
    pub const fn fits_word(&self) -> bool {
        self.total_bits() <= 32
    }

    /// Precompute the packed-domain constant table for this format
    /// (DESIGN.md §9). Panics unless [`FpFormat::fits_word`].
    pub fn packed(&self) -> PackedFormat {
        PackedFormat::new(*self)
    }

    /// Does the format fit one 16-bit SWAR lane (`total_bits ≤ 16`,
    /// DESIGN.md §14)? Then two elements ride per `u64` with full headroom:
    /// `m_w ≤ 13` keeps mantissa products (`2·m_w+2 ≤ 28` bits) and aligned
    /// adder sums (`m_w+5 ≤ 18` bits) inside a 32-bit lane slot. E5M10,
    /// E4M3 and every rung of the adaptive ladder qualify; `E8M23` falls
    /// back to the scalar-word packed engine.
    pub const fn fits_lane(&self) -> bool {
        self.total_bits() <= 16
    }

    /// Precompute the lane-replicated SWAR constant table
    /// (DESIGN.md §14). Panics unless [`FpFormat::fits_lane`].
    pub fn swar(&self) -> super::swar::SwarFormat {
        super::swar::SwarFormat::new(*self)
    }
}

/// Per-format constants precomputed once per batch/sweep so the
/// packed-domain kernels (`softfloat::packed`) never re-derive shifts,
/// masks or biases per element (DESIGN.md §9).
///
/// Values are stored as one `u32` word in the §3.1 wire layout
/// `[sign | biased exponent | fraction]` (sign at bit `e_w + m_w`). Only
/// formats with `total_bits ≤ 32` are supported — which also guarantees
/// `m_w ≤ 29`, so every kernel intermediate (mantissa products of
/// `2·m_w + 2` bits, aligned adder sums of `m_w + 5` bits) fits `u64`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackedFormat {
    /// The format these constants were derived from.
    pub fmt: FpFormat,
    /// Stored fraction bits (`fmt.m_w`).
    pub m_w: u32,
    /// Exponent bits (`fmt.e_w`).
    pub e_w: u32,
    /// Exponent bias (`fmt.bias()`).
    pub bias: i64,
    /// Largest biased exponent of a finite value (`fmt.max_biased_exp()`).
    pub max_biased_exp: i64,
    /// `52 − m_w`: the right-shift aligning an f64 fraction to `m_w` bits
    /// on encode (and the left-shift restoring it on decode).
    pub frac_shift: u32,
    /// `m_w`-bit fraction mask.
    pub frac_mask: u32,
    /// `e_w`-bit exponent-field mask.
    pub exp_mask: u32,
    /// Bit position of the sign in the word (`e_w + m_w`).
    pub sign_shift: u32,
    /// Mask of the magnitude bits (exponent + fraction, sign cleared).
    pub mag_mask: u32,
    /// Positive max-finite word (`[0 | 2^e_w − 2 | all-ones]`).
    pub max_word: u32,
}

impl PackedFormat {
    /// Derive the table. Panics when the format does not fit a `u32` word.
    pub fn new(fmt: FpFormat) -> PackedFormat {
        assert!(
            fmt.fits_word(),
            "packed-domain words require total_bits ≤ 32, got {} for {fmt}",
            fmt.total_bits()
        );
        let sign_shift = fmt.e_w + fmt.m_w;
        let frac_mask = (1u32 << fmt.m_w) - 1;
        PackedFormat {
            fmt,
            m_w: fmt.m_w,
            e_w: fmt.e_w,
            bias: fmt.bias(),
            max_biased_exp: fmt.max_biased_exp(),
            frac_shift: 52 - fmt.m_w,
            frac_mask,
            exp_mask: (1u32 << fmt.e_w) - 1,
            sign_shift,
            mag_mask: (1u32 << sign_shift) - 1,
            max_word: ((fmt.max_biased_exp() as u32) << fmt.m_w) | frac_mask,
        }
    }

    /// The (signed) zero word.
    #[inline]
    pub fn zero_word(&self, sign: u32) -> u32 {
        sign << self.sign_shift
    }

    /// The signed max-finite word (saturation target).
    #[inline]
    pub fn max_word_signed(&self, sign: u32) -> u32 {
        (sign << self.sign_shift) | self.max_word
    }

    /// Flip a word's sign bit (exact negation — zero words flip too,
    /// matching `-0.0`).
    #[inline]
    pub fn neg_word(&self, w: u32) -> u32 {
        w ^ (1u32 << self.sign_shift)
    }

    /// Word → [`Fp`] (for interop with the carrier-path structs).
    #[inline]
    pub fn to_fp(&self, w: u32) -> Fp {
        Fp {
            sign: ((w >> self.sign_shift) & 1) as u8,
            exp: (w >> self.m_w) & self.exp_mask,
            frac: (w & self.frac_mask) as u64,
        }
    }

    /// [`Fp`] → word.
    #[inline]
    pub fn from_fp(&self, fp: Fp) -> u32 {
        ((fp.sign as u32) << self.sign_shift) | (fp.exp << self.m_w) | (fp.frac as u32)
    }
}

impl fmt::Display for FpFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "E{}M{}", self.e_w, self.m_w)
    }
}

/// Exact power of two as `f64` (|e| ≤ 1023 — always true for our formats).
pub(crate) fn pow2(e: i64) -> f64 {
    debug_assert!((-1022..=1023).contains(&e));
    f64::from_bits(((e + 1023) as u64) << 52)
}

/// A value packed in some [`FpFormat`]: sign, biased exponent, fraction.
///
/// `exp == 0` encodes zero (there are no subnormals). Fields are kept
/// unpacked for clarity; [`Fp::to_bits`]/[`Fp::from_bits`] give the wire
/// layout used by the Pallas kernels (sign at the top, then exponent,
/// then fraction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fp {
    /// 0 = positive, 1 = negative.
    pub sign: u8,
    /// Biased exponent; 0 means the value is zero.
    pub exp: u32,
    /// Fraction bits (without the implicit leading 1).
    pub frac: u64,
}

impl Fp {
    /// Zero with the given sign.
    pub const fn zero(sign: u8) -> Fp {
        Fp { sign, exp: 0, frac: 0 }
    }

    /// Is this the (signed) zero?
    pub const fn is_zero(&self) -> bool {
        self.exp == 0
    }

    /// Pack to the wire layout `[sign | exp | frac]` (low bits = fraction).
    pub fn to_bits(&self, fmt: FpFormat) -> u64 {
        debug_assert!(self.frac < (1u64 << fmt.m_w));
        debug_assert!((self.exp as u64) < (1u64 << fmt.e_w));
        ((self.sign as u64) << (fmt.e_w + fmt.m_w)) | ((self.exp as u64) << fmt.m_w) | self.frac
    }

    /// Unpack from the wire layout.
    pub fn from_bits(bits: u64, fmt: FpFormat) -> Fp {
        Fp {
            sign: ((bits >> (fmt.e_w + fmt.m_w)) & 1) as u8,
            exp: ((bits >> fmt.m_w) & ((1u64 << fmt.e_w) - 1)) as u32,
            frac: bits & ((1u64 << fmt.m_w) - 1),
        }
    }
}

/// Exception flags accumulated by encode/mul/add, modeled on IEEE-754 status
/// bits. The R2F2 precision-adjustment unit (§4.2) keys off
/// [`Flags::OVERFLOW`] and [`Flags::UNDERFLOW`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Flags(pub u8);

impl Flags {
    pub const NONE: Flags = Flags(0);
    /// Result magnitude exceeded the format's max finite value (saturated).
    pub const OVERFLOW: Flags = Flags(1);
    /// Non-zero result flushed to zero (below the min normal).
    pub const UNDERFLOW: Flags = Flags(2);
    /// Rounding discarded non-zero bits.
    pub const INEXACT: Flags = Flags(4);
    /// A NaN reached encode (mapped to zero; the format has no NaN).
    pub const NAN_INPUT: Flags = Flags(8);

    pub const fn overflow(&self) -> bool {
        self.0 & Self::OVERFLOW.0 != 0
    }
    pub const fn underflow(&self) -> bool {
        self.0 & Self::UNDERFLOW.0 != 0
    }
    pub const fn inexact(&self) -> bool {
        self.0 & Self::INEXACT.0 != 0
    }
    pub const fn nan_input(&self) -> bool {
        self.0 & Self::NAN_INPUT.0 != 0
    }
    /// Overflow or underflow — the adjustment unit's "range trouble" signal.
    pub const fn range_event(&self) -> bool {
        self.overflow() || self.underflow()
    }
    pub const fn is_empty(&self) -> bool {
        self.0 == 0
    }
}

impl std::ops::BitOr for Flags {
    type Output = Flags;
    fn bitor(self, rhs: Flags) -> Flags {
        Flags(self.0 | rhs.0)
    }
}

impl std::ops::BitOrAssign for Flags {
    fn bitor_assign(&mut self, rhs: Flags) {
        self.0 |= rhs.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_and_limits_match_ieee_half() {
        let h = FpFormat::E5M10;
        assert_eq!(h.bias(), 15);
        assert_eq!(h.max_biased_exp(), 30);
        assert_eq!(h.max_value(), 65504.0);
        assert_eq!(h.min_normal(), 6.103515625e-5);
        assert_eq!(h.total_bits(), 16);
    }

    #[test]
    fn bias_and_limits_match_ieee_single() {
        let s = FpFormat::E8M23;
        assert_eq!(s.bias(), 127);
        assert_eq!(s.max_value(), f32::MAX as f64);
        assert_eq!(s.min_normal(), f32::MIN_POSITIVE as f64);
    }

    #[test]
    fn paper_r2f2_widest_exponent_range() {
        // §4.1: <3,8,4> with all flexible bits on the exponent gives E7M8,
        // largest value 2^63 · (1+255/256) ≈ 1.8410715e19.
        let f = FpFormat::new(7, 8);
        let expected = (1.0 + 255.0 / 256.0) * (2f64).powi(63);
        assert_eq!(f.max_value(), expected);
        assert!((f.max_value() - 1.8410715e19).abs() / 1.8410715e19 < 1e-7);
    }

    #[test]
    fn bits_roundtrip() {
        let fmt = FpFormat::new(6, 9);
        let v = Fp { sign: 1, exp: 37, frac: 0x1AB };
        assert_eq!(Fp::from_bits(v.to_bits(fmt), fmt), v);
    }

    #[test]
    fn pow2_is_exact() {
        assert_eq!(pow2(0), 1.0);
        assert_eq!(pow2(10), 1024.0);
        assert_eq!(pow2(-14), 6.103515625e-5);
    }

    #[test]
    fn flags_compose() {
        let f = Flags::OVERFLOW | Flags::INEXACT;
        assert!(f.overflow() && f.inexact() && !f.underflow());
        assert!(f.range_event());
    }

    #[test]
    #[should_panic]
    fn invalid_width_rejected() {
        let _ = FpFormat::new(1, 10);
    }

    #[test]
    fn display_notation() {
        assert_eq!(FpFormat::E5M10.to_string(), "E5M10");
    }

    #[test]
    fn packed_constants_match_format_derivation() {
        for fmt in [FpFormat::E5M10, FpFormat::E8M7, FpFormat::E8M23, FpFormat::new(4, 3)] {
            let pf = fmt.packed();
            assert_eq!(pf.bias, fmt.bias());
            assert_eq!(pf.max_biased_exp, fmt.max_biased_exp());
            assert_eq!(pf.frac_shift, 52 - fmt.m_w);
            assert_eq!(pf.sign_shift, fmt.e_w + fmt.m_w);
            assert_eq!(pf.to_fp(pf.max_word), fmt.max_finite(0));
            assert_eq!(pf.to_fp(pf.max_word_signed(1)), fmt.max_finite(1));
            assert_eq!(pf.to_fp(pf.zero_word(1)), Fp::zero(1));
        }
    }

    #[test]
    fn packed_word_roundtrips_through_fp_and_wire_bits() {
        let fmt = FpFormat::new(6, 9);
        let pf = fmt.packed();
        let v = Fp { sign: 1, exp: 37, frac: 0x1AB };
        let w = pf.from_fp(v);
        assert_eq!(pf.to_fp(w), v);
        // The word IS the §3.1 wire layout.
        assert_eq!(w as u64, v.to_bits(fmt));
        assert_eq!(pf.neg_word(pf.neg_word(w)), w);
        assert_eq!(pf.to_fp(pf.neg_word(w)).sign, 0);
    }

    #[test]
    #[should_panic(expected = "total_bits")]
    fn packed_rejects_oversized_formats() {
        let _ = FpFormat::E11M52.packed();
    }
}
