//! Conversion between `f64` and arbitrary formats.
//!
//! The paper's datapath reads operands "converting from single precision to
//! R2F2 format and converting back" (§5.2); `encode`/`decode` are that
//! conversion for any [`FpFormat`]. `f64` is the carrier type so the same
//! code also services the double-precision reference runs.

use super::format::{Flags, Fp, FpFormat};
use super::round::Rounder;

const F64_FRAC_BITS: u32 = 52;
const F64_EXP_MASK: u64 = 0x7FF;

/// Encode an `f64` into `fmt` with one correctly-rounded step.
///
/// * f64 subnormals flush to zero (they are far below any supported format's
///   range anyway).
/// * ±inf saturates to the max finite value with [`Flags::OVERFLOW`].
/// * NaN maps to +0 with [`Flags::NAN_INPUT`].
/// * Results below the min normal flush to zero with [`Flags::UNDERFLOW`];
///   above the max finite they saturate with [`Flags::OVERFLOW`].
#[inline]
pub fn encode(x: f64, fmt: FpFormat, r: &mut Rounder) -> (Fp, Flags) {
    let bits = x.to_bits();
    let sign = (bits >> 63) as u8;
    let e_f64 = ((bits >> F64_FRAC_BITS) & F64_EXP_MASK) as i64;
    let frac52 = bits & ((1u64 << F64_FRAC_BITS) - 1);

    if e_f64 == 0 {
        // Zero or f64 subnormal: flush.
        let fl = if frac52 != 0 { Flags::UNDERFLOW } else { Flags::NONE };
        return (Fp::zero(sign), fl);
    }
    if e_f64 == F64_EXP_MASK as i64 {
        if frac52 != 0 {
            return (Fp::zero(0), Flags::NAN_INPUT);
        }
        return (fmt.max_finite(sign), Flags::OVERFLOW);
    }

    let unbiased = e_f64 - 1023;
    let mut flags = Flags::NONE;

    // Round the 52-bit fraction to m_w bits.
    let frac;
    let mut exp_carry = 0i64;
    if fmt.m_w >= F64_FRAC_BITS {
        frac = frac52 << (fmt.m_w - F64_FRAC_BITS);
    } else {
        let shift = F64_FRAC_BITS - fmt.m_w;
        let (f, inexact) = r.round_shift(frac52 as u128, shift);
        if inexact {
            flags |= Flags::INEXACT;
        }
        if f >> fmt.m_w != 0 {
            // Fraction rounded up to 2.0: renormalize.
            frac = 0;
            exp_carry = 1;
        } else {
            frac = f;
        }
    }

    let e = unbiased + exp_carry + fmt.bias();
    if e <= 0 {
        return (Fp::zero(sign), flags | Flags::UNDERFLOW);
    }
    if e > fmt.max_biased_exp() {
        return (fmt.max_finite(sign), flags | Flags::OVERFLOW);
    }
    (Fp { sign, exp: e as u32, frac }, flags)
}

/// Decode a packed value back to `f64`. Exact: every representable value of
/// every supported format is exactly representable in `f64`.
///
/// Implemented as **direct bit construction** — the format's fraction slides
/// into the top of the f64 fraction field and the exponent is rebased — with
/// no floating-point arithmetic on the path. The arithmetic construction
/// `±(1 + frac/2^m_w)·2^e` it replaces cost an integer→float conversion, a
/// division and a multiplication per value; both agree bit-for-bit on every
/// codepoint of every supported format (`decode_bit_construction_matches_*`
/// below verify this exhaustively), because every supported exponent lands
/// in f64's normal range: `e − bias + 1023 ∈ [1, 2046]` for `e_w ≤ 11`.
#[inline]
pub fn decode(fp: Fp, fmt: FpFormat) -> f64 {
    if fp.is_zero() {
        return if fp.sign == 1 { -0.0 } else { 0.0 };
    }
    let e_f64 = fp.exp as i64 - fmt.bias() + 1023;
    debug_assert!((1..=2046).contains(&e_f64));
    f64::from_bits(
        ((fp.sign as u64) << 63) | ((e_f64 as u64) << 52) | (fp.frac << (52 - fmt.m_w)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    #[test]
    fn roundtrip_exact_values() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        for &x in &[1.0, -1.0, 0.5, 1.5, 2.0, 65504.0, 6.103515625e-5, -1024.0] {
            let (fp, fl) = encode(x, fmt, &mut r);
            assert!(fl.is_empty(), "x={x} flags={fl:?}");
            assert_eq!(decode(fp, fmt), x);
        }
    }

    #[test]
    fn zero_signs_preserved() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (fp, _) = encode(-0.0, fmt, &mut r);
        assert!(fp.is_zero() && fp.sign == 1);
        assert_eq!(decode(fp, fmt).to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn e8m23_encode_matches_f32_cast() {
        // Rounding f64 -> E8M23 must match the hardware f64->f32 conversion
        // on values that stay normal.
        let fmt = FpFormat::E8M23;
        let mut r = Rounder::nearest_even();
        let mut rng = SplitMix64::new(7);
        for _ in 0..50_000 {
            let x = rng.log_uniform(1e-30, 1e30)
                * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
            let want = x as f32;
            if !want.is_normal() {
                continue;
            }
            let (fp, _) = encode(x, fmt, &mut r);
            assert_eq!(decode(fp, fmt) as f32, want, "x={x}");
        }
    }

    #[test]
    fn e11m52_is_lossless_for_f64_normals() {
        let fmt = FpFormat::E11M52;
        let mut r = Rounder::nearest_even();
        let mut rng = SplitMix64::new(11);
        for _ in 0..10_000 {
            let x = f64::from_bits(rng.next_u64());
            if !x.is_normal() {
                continue;
            }
            let (fp, fl) = encode(x, fmt, &mut r);
            assert!(fl.is_empty());
            assert_eq!(decode(fp, fmt), x);
        }
    }

    #[test]
    fn overflow_saturates_and_flags() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (fp, fl) = encode(1e6, fmt, &mut r);
        assert!(fl.overflow());
        assert_eq!(decode(fp, fmt), 65504.0);
        let (fp, fl) = encode(-1e6, fmt, &mut r);
        assert!(fl.overflow());
        assert_eq!(decode(fp, fmt), -65504.0);
    }

    #[test]
    fn underflow_flushes_and_flags() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (fp, fl) = encode(1e-6, fmt, &mut r);
        assert!(fl.underflow());
        assert!(fp.is_zero());
    }

    #[test]
    fn inf_nan_handled() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (fp, fl) = encode(f64::INFINITY, fmt, &mut r);
        assert!(fl.overflow());
        assert_eq!(decode(fp, fmt), 65504.0);
        let (fp, fl) = encode(f64::NAN, fmt, &mut r);
        assert!(fl.nan_input());
        assert!(fp.is_zero());
    }

    #[test]
    fn rounding_carry_into_exponent() {
        // 2047.9999 rounds up to 2048 in E5M10 (all-ones fraction carries).
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let x = 2047.9999;
        let (fp, fl) = encode(x, fmt, &mut r);
        assert!(fl.inexact());
        assert_eq!(decode(fp, fmt), 2048.0);
    }

    #[test]
    fn boundary_just_above_max_rounds_to_overflow() {
        // Values that round to 2^16 overflow E5M10 even though 65504 doesn't.
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (fp, fl) = encode(65520.0, fmt, &mut r); // rounds to 65536
        assert!(fl.overflow());
        assert_eq!(decode(fp, fmt), 65504.0);
    }

    /// The arithmetic decode the bit construction replaced — kept as the
    /// test oracle for the exhaustive equivalence sweeps.
    fn decode_arith(fp: Fp, fmt: FpFormat) -> f64 {
        use crate::softfloat::format::pow2;
        if fp.is_zero() {
            return if fp.sign == 1 { -0.0 } else { 0.0 };
        }
        let e = fp.exp as i64 - fmt.bias();
        let m = 1.0 + fp.frac as f64 / (1u64 << fmt.m_w) as f64;
        let v = m * pow2(e);
        if fp.sign == 1 {
            -v
        } else {
            v
        }
    }

    fn assert_decode_equivalent_exhaustive(fmt: FpFormat) {
        for sign in 0..=1u8 {
            for exp in 0..=fmt.max_biased_exp() as u32 {
                for frac in 0..(1u64 << fmt.m_w) {
                    let fp = Fp { sign, exp, frac };
                    let got = decode(fp, fmt);
                    let want = decode_arith(fp, fmt);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "{fmt} sign={sign} exp={exp} frac={frac}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn decode_bit_construction_matches_arithmetic_e5m10_exhaustive() {
        // Every codepoint of E5M10 (2 × 31 × 1024 values incl. signed zero).
        assert_decode_equivalent_exhaustive(FpFormat::E5M10);
    }

    #[test]
    fn decode_bit_construction_matches_arithmetic_e4m3_exhaustive() {
        assert_decode_equivalent_exhaustive(FpFormat::new(4, 3));
    }

    #[test]
    fn decode_bit_construction_matches_arithmetic_extreme_widths() {
        // Spot the corners the exhaustive formats cannot reach: the widest
        // exponent (E11M52 — lossless f64 mirror) and a 1-bit fraction.
        for fmt in [FpFormat::E11M52, FpFormat::new(2, 1), FpFormat::new(11, 1)] {
            for sign in 0..=1u8 {
                for exp in [1u32, 2, fmt.max_biased_exp() as u32] {
                    for frac in [0u64, 1, (1u64 << fmt.m_w) - 1] {
                        let fp = Fp { sign, exp, frac };
                        assert_eq!(
                            decode(fp, fmt).to_bits(),
                            decode_arith(fp, fmt).to_bits(),
                            "{fmt} {fp:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn toward_zero_never_overflows_from_rounding() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::toward_zero();
        let (fp, fl) = encode(65535.9, fmt, &mut r);
        assert!(!fl.overflow());
        assert_eq!(decode(fp, fmt), 65504.0);
        assert!(fl.inexact());
    }
}
