//! Arbitrary-precision floating-point multiplication.
//!
//! This is the *exact* (single-rounding) multiplier used as the fixed-format
//! baseline ("Impl. 16-bit FP" etc. in Table 1) and as the reference the
//! R2F2 truncation approximation is validated against. The R2F2 multiplier
//! itself lives in [`crate::r2f2core::mul`] and differs only by the
//! flexible-partial-product truncation.

use super::format::{Flags, Fp, FpFormat};
use super::round::Rounder;

/// Multiply two packed values of the same format with one rounding step.
///
/// Algorithm (the paper's §4.1 datapath, without the flexible-bit
/// truncation):
/// 1. sign = XOR of signs;
/// 2. integer mantissa product `P = (2^m_w + fa)·(2^m_w + fb)`;
/// 3. normalize P (product of two values in `[1,2)` lies in `[1,4)`);
/// 4. round to `m_w` fraction bits (carry may renormalize);
/// 5. exponent = `ea + eb − bias (+ carries)`, computed the way the paper's
///    hardware does (`− 2^(e_w−1) + 1`);
/// 6. saturate on overflow, flush on underflow.
#[inline]
pub fn mul(a: Fp, b: Fp, fmt: FpFormat, r: &mut Rounder) -> (Fp, Flags) {
    let sign = a.sign ^ b.sign;
    if a.is_zero() || b.is_zero() {
        return (Fp::zero(sign), Flags::NONE);
    }

    let m_w = fmt.m_w;
    let ia = (1u64 << m_w) | a.frac;
    let ib = (1u64 << m_w) | b.frac;
    let p = ia as u128 * ib as u128; // 2·m_w+2 bits, fits u128 (m_w ≤ 52)

    normalize_round_pack(p, sign, a.exp as i64 + b.exp as i64, fmt, r)
}

/// Shared tail of the exact and R2F2 multipliers: normalize the raw product
/// `p` (in `[2^(2m_w), 2^(2m_w+2))`), round to `m_w` fraction bits, add the
/// exponents with the paper's bias trick, and handle range events.
///
/// `exp_sum` is the sum of the two biased exponents.
#[inline]
pub(crate) fn normalize_round_pack(
    p: u128,
    sign: u8,
    exp_sum: i64,
    fmt: FpFormat,
    r: &mut Rounder,
) -> (Fp, Flags) {
    let m_w = fmt.m_w;
    let mut flags = Flags::NONE;

    // Product of [1,2)×[1,2) is [1,4): one possible normalize shift.
    let (shift, mut exp_inc) = if p >> (2 * m_w + 1) != 0 { (m_w + 1, 1i64) } else { (m_w, 0i64) };
    let (mut frac_with_lead, inexact) = r.round_shift(p, shift);
    if inexact {
        flags |= Flags::INEXACT;
    }
    // frac_with_lead holds 1.m_w bits; rounding may carry to 2^(m_w+1).
    if frac_with_lead >> (m_w + 1) != 0 {
        frac_with_lead >>= 1; // 10.00..0 -> 1.000..0, exact
        exp_inc += 1;
    }
    let frac = frac_with_lead & ((1u64 << m_w) - 1);

    // Paper's bias subtraction: e1 + e2 − BIAS = e1 + e2 − 2^(e_w−1) + 1.
    let e = exp_sum - (1i64 << (fmt.e_w - 1)) + 1 + exp_inc;

    if e <= 0 {
        return (Fp::zero(sign), flags | Flags::UNDERFLOW);
    }
    if e > fmt.max_biased_exp() {
        return (fmt.max_finite(sign), flags | Flags::OVERFLOW);
    }
    (Fp { sign, exp: e as u32, frac }, flags)
}

/// [`normalize_round_pack`] with 64-bit intermediates — the packed-domain
/// fast path (DESIGN.md §9). Valid for `m_w ≤ 30` (raw product ≤ 62 bits);
/// bit-identical to the u128 version, including the stochastic rounding
/// draw sequence (see [`Rounder::round_shift64`]).
#[inline]
pub(crate) fn normalize_round_pack64(
    p: u64,
    sign: u8,
    exp_sum: i64,
    fmt: FpFormat,
    r: &mut Rounder,
) -> (Fp, Flags) {
    let m_w = fmt.m_w;
    debug_assert!(m_w <= 30);
    let mut flags = Flags::NONE;

    let (shift, mut exp_inc) = if p >> (2 * m_w + 1) != 0 { (m_w + 1, 1i64) } else { (m_w, 0i64) };
    let (mut frac_with_lead, inexact) = r.round_shift64(p, shift);
    if inexact {
        flags |= Flags::INEXACT;
    }
    if frac_with_lead >> (m_w + 1) != 0 {
        frac_with_lead >>= 1; // 10.00..0 -> 1.000..0, exact
        exp_inc += 1;
    }
    let frac = frac_with_lead & ((1u64 << m_w) - 1);

    let e = exp_sum - (1i64 << (fmt.e_w - 1)) + 1 + exp_inc;

    if e <= 0 {
        return (Fp::zero(sign), flags | Flags::UNDERFLOW);
    }
    if e > fmt.max_biased_exp() {
        return (fmt.max_finite(sign), flags | Flags::OVERFLOW);
    }
    (Fp { sign, exp: e as u32, frac }, flags)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;
    use crate::softfloat::{decode, encode};

    fn enc(x: f64, fmt: FpFormat) -> Fp {
        encode(x, fmt, &mut Rounder::nearest_even()).0
    }

    #[test]
    fn simple_products_exact() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        for &(a, b, want) in
            &[(1.0, 1.0, 1.0), (2.0, 3.0, 6.0), (-2.5, 4.0, -10.0), (0.5, 0.5, 0.25)]
        {
            let (p, fl) = mul(enc(a, fmt), enc(b, fmt), fmt, &mut r);
            assert_eq!(decode(p, fmt), want);
            assert!(fl.is_empty());
        }
    }

    #[test]
    fn zero_operand_gives_signed_zero() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (p, _) = mul(enc(0.0, fmt), enc(-3.0, fmt), fmt, &mut r);
        assert!(p.is_zero());
        assert_eq!(p.sign, 1);
    }

    #[test]
    fn matches_f64_single_rounding_random() {
        // For random in-range operands, our mul must equal: exact product in
        // f64 (m_w ≤ 26 ⇒ product fits 53 bits) re-encoded to the format.
        let fmt = FpFormat::new(6, 9);
        let mut r = Rounder::nearest_even();
        let mut rng = SplitMix64::new(99);
        for _ in 0..50_000 {
            let a = decode(enc(rng.log_uniform(1e-3, 1e3), fmt), fmt);
            let b = decode(enc(rng.log_uniform(1e-3, 1e3), fmt), fmt);
            let (p, _) = mul(enc(a, fmt), enc(b, fmt), fmt, &mut r);
            let exact = a * b; // exact in f64: 10 bits × 10 bits
            let want = encode(exact, fmt, &mut Rounder::nearest_even()).0;
            assert_eq!(p, want, "a={a} b={b}");
        }
    }

    #[test]
    fn overflow_saturates() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (p, fl) = mul(enc(300.0, fmt), enc(300.0, fmt), fmt, &mut r);
        assert!(fl.overflow());
        assert_eq!(decode(p, fmt), 65504.0);
    }

    #[test]
    fn underflow_flushes() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (p, fl) = mul(enc(1e-3, fmt), enc(1e-3, fmt), fmt, &mut r);
        assert!(fl.underflow());
        assert!(p.is_zero());
        assert_eq!(p.sign, 0);
    }

    #[test]
    fn commutative() {
        let fmt = FpFormat::new(4, 7);
        let mut rng = SplitMix64::new(5);
        let mut r = Rounder::nearest_even();
        for _ in 0..10_000 {
            let a = enc(rng.log_uniform(1e-2, 1e2), fmt);
            let b = enc(rng.log_uniform(1e-2, 1e2), fmt);
            assert_eq!(mul(a, b, fmt, &mut r), mul(b, a, fmt, &mut r));
        }
    }

    #[test]
    fn rounding_carry_renormalizes() {
        // Choose operands whose product fraction is all ones + eps so RNE
        // carries: 1.9990234375 (max E5M10 mantissa) squared = 3.99609...
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let x = decode(fmt.max_finite(0), fmt) / 32768.0; // 1.9990234375
        let (p, _) = mul(enc(x, fmt), enc(x, fmt), fmt, &mut r);
        let exact = x * x;
        let want = encode(exact, fmt, &mut Rounder::nearest_even()).0;
        assert_eq!(p, want);
    }
}
