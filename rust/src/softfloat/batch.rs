//! Batched fixed-format kernels with hoisted per-operation state.
//!
//! The scalar helpers ([`crate::softfloat::mul_f`] and friends) construct a
//! fresh [`Rounder`] and re-encode both operands on every call — fine for
//! one multiplication, wasteful for the PDE hot loops that issue millions
//! (DESIGN.md §8). The batch kernels hoist everything that is loop-invariant
//! out of the inner loop:
//!
//! * one rounding context per batch (round-to-nearest-even is stateless, so
//!   sharing it is bit-identical to constructing one per call);
//! * the encoding of a constant operand (the stencil coefficient `r`, the
//!   flux constant `g/2`) is computed once per batch;
//! * format-derived constants (bias, widths) stay in registers instead of
//!   being re-derived per element.
//!
//! Every kernel returns per-element [`Flags`] with exactly the same union
//! semantics as its scalar counterpart, so callers that count range events
//! (e.g. `pde::FixedArith`) observe identical counters.

use super::encode::{decode, encode};
use super::format::{Flags, FpFormat, PackedFormat};
use super::mul::mul;
use super::packed;
use super::round::Rounder;

/// Packed-domain core of [`mul_batch_f`]: constant ⊗ slice through the
/// word kernels (DESIGN.md §9), streaming each element's flag union to
/// `on_flags(index, flags)`. Shared with `pde::FixedArith`'s batched
/// engine so the encode → mul → decode → flag-union sequence exists once.
pub fn mul_batch_packed(
    a: f64,
    xs: &[f64],
    pf: &PackedFormat,
    r: &mut Rounder,
    out: &mut [f64],
    mut on_flags: impl FnMut(usize, Flags),
) {
    assert_eq!(out.len(), xs.len());
    let (wa, fla) = packed::encode_bits(a.to_bits(), pf, r);
    for (i, (o, &x)) in out.iter_mut().zip(xs.iter()).enumerate() {
        let (wb, flb) = packed::encode_bits(x.to_bits(), pf, r);
        let (wc, flc) = packed::mul_packed(wa, wb, pf, r);
        *o = packed::decode_word(wc, pf);
        on_flags(i, fla | flb | flc);
    }
}

/// Packed-domain core of [`mul_pairs_f`] — see [`mul_batch_packed`].
pub fn mul_pairs_packed(
    pairs: &[(f64, f64)],
    pf: &PackedFormat,
    r: &mut Rounder,
    out: &mut [f64],
    mut on_flags: impl FnMut(usize, Flags),
) {
    assert_eq!(out.len(), pairs.len());
    for (i, (o, &(a, b))) in out.iter_mut().zip(pairs.iter()).enumerate() {
        let (wa, fla) = packed::encode_bits(a.to_bits(), pf, r);
        let (wb, flb) = packed::encode_bits(b.to_bits(), pf, r);
        let (wc, flc) = packed::mul_packed(wa, wb, pf, r);
        *o = packed::decode_word(wc, pf);
        on_flags(i, fla | flb | flc);
    }
}

/// `out[i] = a ⊗ xs[i]` in `fmt`, with `flags[i] = fla | flb_i | flc_i` —
/// element-for-element bit-identical to calling
/// [`crate::softfloat::mul_f`]`(a, xs[i], fmt)` in a loop, but the constant
/// operand `a` is encoded once.
///
/// Panics if `out` or `flags` differ in length from `xs`.
pub fn mul_batch_f(a: f64, xs: &[f64], fmt: FpFormat, out: &mut [f64], flags: &mut [Flags]) {
    assert_eq!(out.len(), xs.len());
    assert_eq!(flags.len(), xs.len());
    let mut r = Rounder::nearest_even();
    if fmt.fits_word() {
        // Packed-domain fast path (DESIGN.md §9): same transcode semantics,
        // word kernels with 64-bit intermediates — bit-identical.
        let pf = fmt.packed();
        mul_batch_packed(a, xs, &pf, &mut r, out, |i, fl| flags[i] = fl);
        return;
    }
    let (fa, fla) = encode(a, fmt, &mut r);
    for i in 0..xs.len() {
        let (fb, flb) = encode(xs[i], fmt, &mut r);
        let (fc, flc) = mul(fa, fb, fmt, &mut r);
        out[i] = decode(fc, fmt);
        flags[i] = fla | flb | flc;
    }
}

/// `out[i] = pairs[i].0 ⊗ pairs[i].1` in `fmt` — bit-identical to the
/// scalar loop, with one shared rounding context and the format constants
/// hoisted.
///
/// Panics if `out` or `flags` differ in length from `pairs`.
pub fn mul_pairs_f(pairs: &[(f64, f64)], fmt: FpFormat, out: &mut [f64], flags: &mut [Flags]) {
    assert_eq!(out.len(), pairs.len());
    assert_eq!(flags.len(), pairs.len());
    let mut r = Rounder::nearest_even();
    if fmt.fits_word() {
        // Packed-domain fast path — see `mul_batch_f`.
        let pf = fmt.packed();
        mul_pairs_packed(pairs, &pf, &mut r, out, |i, fl| flags[i] = fl);
        return;
    }
    for i in 0..pairs.len() {
        let (a, b) = pairs[i];
        let (fa, fla) = encode(a, fmt, &mut r);
        let (fb, flb) = encode(b, fmt, &mut r);
        let (fc, flc) = mul(fa, fb, fmt, &mut r);
        out[i] = decode(fc, fmt);
        flags[i] = fla | flb | flc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;
    use crate::softfloat::mul_f;

    #[test]
    fn mul_batch_matches_scalar_bit_for_bit() {
        let fmt = FpFormat::E5M10;
        let mut rng = SplitMix64::new(0x51);
        // Include range-event operands so flags differ across elements.
        let mut xs: Vec<f64> = (0..512).map(|_| rng.log_uniform(1e-8, 1e8)).collect();
        xs.push(0.0);
        xs.push(-0.0);
        for &a in &[0.25, 0.5, 1e-3, 4000.0] {
            let mut out = vec![0.0; xs.len()];
            let mut flags = vec![Flags::NONE; xs.len()];
            mul_batch_f(a, &xs, fmt, &mut out, &mut flags);
            for i in 0..xs.len() {
                let (want, want_fl) = mul_f(a, xs[i], fmt);
                assert_eq!(out[i].to_bits(), want.to_bits(), "a={a} x={}", xs[i]);
                assert_eq!(flags[i], want_fl, "a={a} x={}", xs[i]);
            }
        }
    }

    #[test]
    fn mul_pairs_matches_scalar_bit_for_bit() {
        let fmt = FpFormat::new(6, 9);
        let mut rng = SplitMix64::new(0x52);
        let pairs: Vec<(f64, f64)> = (0..512)
            .map(|_| {
                let s = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                (s * rng.log_uniform(1e-8, 1e8), rng.log_uniform(1e-8, 1e8))
            })
            .collect();
        let mut out = vec![0.0; pairs.len()];
        let mut flags = vec![Flags::NONE; pairs.len()];
        mul_pairs_f(&pairs, fmt, &mut out, &mut flags);
        for (i, &(a, b)) in pairs.iter().enumerate() {
            let (want, want_fl) = mul_f(a, b, fmt);
            assert_eq!(out[i].to_bits(), want.to_bits(), "{a} × {b}");
            assert_eq!(flags[i], want_fl, "{a} × {b}");
        }
    }

    #[test]
    #[should_panic]
    fn length_mismatch_rejected() {
        let mut out = vec![0.0; 2];
        let mut flags = vec![Flags::NONE; 3];
        mul_batch_f(1.0, &[1.0, 2.0, 3.0], FpFormat::E5M10, &mut out, &mut flags);
    }
}
