//! SWAR (SIMD-within-a-register) packed-domain kernels (DESIGN.md §14).
//!
//! The §9 packed engine still pushes **one** `u32` word per
//! [`mul_packed`]/[`add_packed`] call. For formats that fit a 16-bit lane
//! (`total_bits ≤ 16` — E5M10, E4M3 and every rung of the adaptive ladder),
//! two elements travel together in one `u64`:
//!
//! ```text
//!        bit 63                32 31                 0
//!        ┌──────────────────────┬──────────────────────┐
//!   u64  │       lane 1         │       lane 0         │
//!        │ 0…0 [sign|exp|frac]  │ 0…0 [sign|exp|frac]  │
//!        └──────────────────────┴──────────────────────┘
//! ```
//!
//! Each lane is a 32-bit slot holding one §3.1 wire-layout word in its low
//! `total_bits` bits. A 16-bit ceiling (`m_w ≤ 13`) guarantees every
//! intermediate stays inside its slot: mantissa products are `2·m_w+2 ≤ 28`
//! bits and aligned adder sums are `m_w+G+2 ≤ 18` bits, so nothing a lane
//! computes can touch its neighbour.
//!
//! **What is shared, what is unrolled.** Field extraction and
//! classification run on the packed register with lane-replicated masks
//! (one AND/shift serves both lanes — [`SwarFormat`] precomputes the
//! doubled masks). The normalize/round tail is an **unrolled, branch-free
//! lane core**: rounding needs data-dependent shift amounts (alignment
//! distance, cancellation renormalize), and a per-lane variable shift on
//! the packed register would smear bits across the lane boundary. The lane
//! core therefore runs straight-line on one slot — every select is a mask
//! (`wrapping_neg` of a bool), so the common path executes **no per-lane
//! branches** and the two unrolled cores schedule as independent ILP
//! streams.
//!
//! **Contract.** Every kernel is bit-identical **lane-for-lane** to the
//! scalar word kernels of [`super::packed`]:
//!
//! * [`mul_packed_lanes`]`(va, vb)` lane `k` ≡ [`mul_packed`]`(a_k, b_k)`,
//!   value and [`Flags`] both (per-lane flags, not a union — callers union
//!   them exactly where the scalar loop would);
//! * [`add_packed_lanes`] ≡ [`add_packed`] per lane, including the
//!   signed-zero, exact-cancellation and pre-rounding-underflow early
//!   paths (the mask cascade reproduces their priority order);
//! * [`encode_lanes`]/[`decode_lanes`] ≡ [`encode_bits`]/`decode_word`
//!   per lane.
//!
//! **Draw-order contract (stochastic rounding).** The deterministic modes
//! (nearest-even, toward-zero) never consume RNG draws, so the branch-free
//! cores are trivially draw-exact. Stochastic rounding draws **once per
//! inexact rounding, in lane order: lane 0 consumes all of its draws
//! before lane 1 draws.** That is exactly the sequence a scalar loop over
//! the flat element array produces when element `2i+k` rides in lane `k`
//! of packed word `i`, so a SWAR sweep and the scalar sweep leave a shared
//! [`Rounder`] in the same state. The stochastic path delegates to the
//! scalar kernels per lane (a data-dependent draw *is* a per-lane branch;
//! there is no branch-free formulation that preserves the draw count), and
//! `rust/tests/swar_vs_packed.rs` pins the sequence.
//!
//! [`mul_packed`]: super::packed::mul_packed
//! [`add_packed`]: super::packed::add_packed
//! [`encode_bits`]: super::packed::encode_bits

use super::format::{Flags, FpFormat, PackedFormat};
use super::packed;
use super::round::{Rounder, RoundingMode};

/// Lanes per SWAR word. Two 32-bit slots per `u64`; each slot holds one
/// `total_bits ≤ 16` wire word with headroom for every intermediate.
pub const LANES: usize = 2;

/// Bits per lane slot.
pub const LANE_BITS: u32 = 32;

/// Guard + round + sticky bits carried through addition alignment (must
/// match `softfloat::add` and `packed::add_packed`).
const G: u32 = 3;

/// Pack two scalar words into one SWAR word (lane 0 = low slot).
#[inline]
pub fn pack2(lane0: u32, lane1: u32) -> u64 {
    ((lane1 as u64) << LANE_BITS) | lane0 as u64
}

/// Unpack a SWAR word into its `(lane 0, lane 1)` scalar words.
#[inline]
pub fn unpack2(v: u64) -> (u32, u32) {
    (v as u32, (v >> LANE_BITS) as u32)
}

/// Lane-replicated constant table for the SWAR kernels: the scalar
/// [`PackedFormat`] plus each mask doubled into both 32-bit slots, so one
/// AND/shift classifies or extracts both lanes (DESIGN.md §14). Only
/// formats with [`FpFormat::fits_lane`] are supported.
#[derive(Debug, Clone, Copy)]
pub struct SwarFormat {
    /// The scalar constant table (shared by both lane cores).
    pub pf: PackedFormat,
    /// Fraction mask in both lanes.
    pub frac2: u64,
    /// Exponent-field mask (shifted down to bit 0) in both lanes.
    pub exp2: u64,
    /// Magnitude mask (exponent + fraction) in both lanes.
    pub mag2: u64,
    /// Implicit leading-one bit (`1 << m_w`) in both lanes.
    pub lead2: u64,
    /// Bit 0 of each lane (`0x0000_0001_0000_0001`).
    pub lane_lsb: u64,
}

impl SwarFormat {
    /// Derive the table. Panics unless the format fits a 16-bit lane.
    pub fn new(fmt: FpFormat) -> SwarFormat {
        assert!(
            fmt.fits_lane(),
            "SWAR lanes require total_bits ≤ 16, got {} for {fmt}",
            fmt.total_bits()
        );
        let pf = PackedFormat::new(fmt);
        let rep = |m: u32| ((m as u64) << LANE_BITS) | m as u64;
        SwarFormat {
            pf,
            frac2: rep(pf.frac_mask),
            exp2: rep(pf.exp_mask),
            mag2: rep(pf.mag_mask),
            lead2: rep(1u32 << pf.m_w),
            lane_lsb: rep(1),
        }
    }
}

/// Branch-free select: `if c { t } else { f }` as mask arithmetic.
#[inline]
fn sel32(c: bool, t: u32, f: u32) -> u32 {
    let m = (c as u32).wrapping_neg();
    (t & m) | (f & !m)
}

#[inline]
fn sel64(c: bool, t: u64, f: u64) -> u64 {
    let m = (c as u64).wrapping_neg();
    (t & m) | (f & !m)
}

#[inline]
fn sel8(c: bool, t: u8, f: u8) -> u8 {
    let m = (c as u8).wrapping_neg();
    (t & m) | (f & !m)
}

/// Branch-free `round_shift64` for the deterministic modes. `shift ≥ 1`
/// at every call site (so `half` is well-formed); when `lost == 0` the
/// up-bit is provably false in both modes, matching the scalar early
/// return. Returns `(rounded, inexact)`.
#[inline]
fn round_lane(v: u64, shift: u32, rne: bool) -> (u64, bool) {
    debug_assert!(shift >= 1);
    let kept = v >> shift;
    let lost = v & ((1u64 << shift) - 1);
    let half = 1u64 << (shift - 1);
    let up = rne & ((lost > half) | ((lost == half) & (kept & 1 == 1)));
    (kept + up as u64, lost != 0)
}

/// One lane of the branch-free multiply tail: raw significand product →
/// normalize → round → rebase → saturate/flush, mirroring
/// `mul::normalize_round_pack64` select-for-branch. `zero_in` marks a
/// zero operand (result is the signed zero with no flags, the scalar
/// early return).
#[inline]
#[allow(clippy::too_many_arguments)]
fn mul_lane_tail(
    sig_a: u64,
    sig_b: u64,
    sign: u32,
    exp_sum: i64,
    zero_in: bool,
    pf: &PackedFormat,
    rne: bool,
) -> (u32, Flags) {
    let m_w = pf.m_w;
    let p = sig_a * sig_b; // ≤ 2·m_w+2 ≤ 28 bits
    let hi = ((p >> (2 * m_w + 1)) & 1) as u32;
    let (f, inexact) = round_lane(p, m_w + hi, rne);
    let carry = (f >> (m_w + 1)) & 1;
    let f = f >> carry;
    let e = exp_sum - (1i64 << (pf.e_w - 1)) + 1 + hi as i64 + carry as i64;

    let under = e <= 0;
    let over = e > pf.max_biased_exp;
    let normal =
        (sign << pf.sign_shift) | (((e as u32) & pf.exp_mask) << m_w) | (f as u32 & pf.frac_mask);
    let w = sel32(
        zero_in,
        pf.zero_word(sign),
        sel32(under, pf.zero_word(sign), sel32(over, pf.max_word_signed(sign), normal)),
    );
    let bits = (over as u8) | ((under as u8) << 1) | ((inexact as u8) << 2);
    (w, Flags(sel8(zero_in, 0, bits)))
}

/// Multiply both lanes: lane `k` of the result ≡
/// [`packed::mul_packed`]`(lane_k(va), lane_k(vb))`, value and flags.
/// Deterministic modes run the branch-free SWAR core; stochastic rounding
/// delegates to the scalar kernel per lane **in lane order** (the
/// draw-order contract in the module docs).
#[inline]
pub fn mul_packed_lanes(
    va: u64,
    vb: u64,
    sf: &SwarFormat,
    r: &mut Rounder,
) -> (u64, [Flags; 2]) {
    let pf = &sf.pf;
    if r.mode == RoundingMode::Stochastic {
        let (a0, a1) = unpack2(va);
        let (b0, b1) = unpack2(vb);
        let (w0, f0) = packed::mul_packed(a0, b0, pf, r);
        let (w1, f1) = packed::mul_packed(a1, b1, pf, r);
        return (pack2(w0, w1), [f0, f1]);
    }
    let rne = r.mode == RoundingMode::NearestEven;

    // Shared-mask stage: both lanes' signs, exponents and significands in
    // one register op each.
    let sign2 = ((va ^ vb) >> pf.sign_shift) & sf.lane_lsb;
    let ea2 = (va >> pf.m_w) & sf.exp2;
    let eb2 = (vb >> pf.m_w) & sf.exp2;
    let sig_a2 = (va & sf.frac2) | sf.lead2;
    let sig_b2 = (vb & sf.frac2) | sf.lead2;

    // Unrolled branch-free lane cores (variable rounding shifts cannot run
    // on the packed register — see module docs).
    let (ea0, ea1) = unpack2(ea2);
    let (eb0, eb1) = unpack2(eb2);
    let (w0, f0) = mul_lane_tail(
        sig_a2 as u32 as u64,
        sig_b2 as u32 as u64,
        sign2 as u32,
        ea0 as i64 + eb0 as i64,
        ea0 == 0 || eb0 == 0,
        pf,
        rne,
    );
    let (w1, f1) = mul_lane_tail(
        sig_a2 >> LANE_BITS,
        sig_b2 >> LANE_BITS,
        (sign2 >> LANE_BITS) as u32,
        ea1 as i64 + eb1 as i64,
        ea1 == 0 || eb1 == 0,
        pf,
        rne,
    );
    (pack2(w0, w1), [f0, f1])
}

/// One lane of the branch-free addition core, mirroring
/// [`packed::add_packed`]'s control flow as a select cascade with the same
/// priority order (zeros, magnitude order, alignment with sticky
/// collapse, add/sub split, exact cancellation, pre-rounding underflow,
/// post-rounding renormalize + range checks).
#[inline]
fn add_lane(wa: u32, wb: u32, pf: &PackedFormat, rne: bool) -> (u32, Flags) {
    let m_w = pf.m_w;
    let sa = (wa >> pf.sign_shift) & 1;
    let sb = (wb >> pf.sign_shift) & 1;
    let mag_a = wa & pf.mag_mask;
    let mag_b = wb & pf.mag_mask;
    let a_zero = mag_a >> m_w == 0;
    let b_zero = mag_b >> m_w == 0;

    // Magnitude order: the word's magnitude bits ARE the (exp, frac)
    // lexicographic key, so `hi` dominates the result sign.
    let swap = mag_a < mag_b;
    let hs = sel32(swap, sb, sa);
    let hmag = sel32(swap, mag_b, mag_a);
    let lmag = sel32(swap, mag_a, mag_b);

    let lead = 1u64 << m_w;
    let mhi = (lead | (hmag & pf.frac_mask) as u64) << G;
    let mlo_full = lead | (lmag & pf.frac_mask) as u64;
    let hexp = (hmag >> m_w) as i64;

    // Clamped alignment: for d ≥ m_w+G+2 the clamped shift empties the
    // kept bits and the sticky OR alone reproduces the scalar pure-sticky
    // arm — one formula covers d == 0, the in-range shift and the far
    // case, with the shift bounded ≤ m_w+G+2 ≤ 18 (no u64 shift hazard
    // even though raw d can reach the full exponent range).
    let d = ((hmag >> m_w) - (lmag >> m_w)).min(m_w + G + 2);
    let full = mlo_full << G;
    let mlo = (full >> d) | u64::from(full & ((1u64 << d) - 1) != 0);

    // Effective addition (same sign): sum ∈ [2^(m_w+G+1), 2^(m_w+G+2)).
    let sum = mhi + mlo;
    let hi_bit = ((sum >> (m_w + G + 1)) & 1) as u32;
    let (val_add, inex_add) = round_lane(sum, G + hi_bit, rne);
    let e_add = hexp + hi_bit as i64;

    // Effective subtraction: mhi ≥ mlo by the magnitude order, so the
    // difference never wraps. `| cancel` keeps leading_zeros off 64 on
    // exact cancellation; that lane's result is overridden below.
    let diff = mhi - mlo;
    let cancel = diff == 0;
    let msb = 63 - (diff | u64::from(cancel)).leading_zeros();
    let lshift = (m_w + G) - msb;
    let e_sub = hexp - lshift as i64;
    // Scalar add_packed returns zero + UNDERFLOW *before* rounding here,
    // so INEXACT is suppressed and (in stochastic mode) no draw happens —
    // the select cascade must keep that flag shape.
    let sub_under = e_sub <= 0;
    let (val_sub, inex_sub) = round_lane(diff << lshift, G, rne);

    let same = sa == sb;
    let val = sel64(same, val_add, val_sub);
    let e = sel64(same, e_add as u64, e_sub as u64) as i64;
    let inexact = (same & inex_add) | (!same & inex_sub);

    // pack_word: post-rounding renormalize carry, then range checks.
    let carry = (val >> (m_w + 1)) & 1;
    let val = val >> carry;
    let e = e + carry as i64;
    let under = e <= 0;
    let over = e > pf.max_biased_exp;
    let normal =
        (hs << pf.sign_shift) | (((e as u32) & pf.exp_mask) << m_w) | (val as u32 & pf.frac_mask);
    let w_main = sel32(under, pf.zero_word(hs), sel32(over, pf.max_word_signed(hs), normal));
    let fl_main = (over as u8) | ((under as u8) << 1) | ((inexact as u8) << 2);

    // Subtraction early exits (exact cancellation → +0 with no flags;
    // pre-rounding underflow → signed zero + UNDERFLOW only).
    let sub_cancel = !same & cancel;
    let sub_uf = !same & !cancel & sub_under;
    let w_main = sel32(sub_cancel, 0, sel32(sub_uf, pf.zero_word(hs), w_main));
    let fl_main = sel8(sub_cancel, 0, sel8(sub_uf, Flags::UNDERFLOW.0, fl_main));

    // Zero-operand early exits (both → zero of ANDed sign; one → the
    // other word verbatim; all flag-free).
    let any_zero = a_zero | b_zero;
    let w = sel32(
        a_zero & b_zero,
        pf.zero_word(sa & sb),
        sel32(a_zero, wb, sel32(b_zero, wa, w_main)),
    );
    (w, Flags(sel8(any_zero, 0, fl_main)))
}

/// Add both lanes: lane `k` of the result ≡
/// [`packed::add_packed`]`(lane_k(va), lane_k(vb))`, value and flags.
/// Deterministic modes run the branch-free cores; stochastic rounding
/// delegates per lane in lane order (draw-order contract).
#[inline]
pub fn add_packed_lanes(
    va: u64,
    vb: u64,
    sf: &SwarFormat,
    r: &mut Rounder,
) -> (u64, [Flags; 2]) {
    let pf = &sf.pf;
    if r.mode == RoundingMode::Stochastic {
        let (a0, a1) = unpack2(va);
        let (b0, b1) = unpack2(vb);
        let (w0, f0) = packed::add_packed(a0, b0, pf, r);
        let (w1, f1) = packed::add_packed(a1, b1, pf, r);
        return (pack2(w0, w1), [f0, f1]);
    }
    let rne = r.mode == RoundingMode::NearestEven;
    let (a0, a1) = unpack2(va);
    let (b0, b1) = unpack2(vb);
    let (w0, f0) = add_lane(a0, b0, pf, rne);
    let (w1, f1) = add_lane(a1, b1, pf, rne);
    (pack2(w0, w1), [f0, f1])
}

/// One lane of the branch-free encode core — the select-cascade twin of
/// [`packed::encode_bits`]. The f64 classification (zero/subnormal flush,
/// NaN, infinity) and the range checks become mask selects with the
/// scalar priority order; the single rounding uses the shared
/// `frac_shift` constant (≥ 39 for lane formats, so the shift always
/// runs).
#[inline]
fn encode_lane(bits: u64, pf: &PackedFormat, rne: bool) -> (u32, Flags) {
    let sign = ((bits >> 63) as u32) & 1;
    let e_f64 = ((bits >> 52) & 0x7FF) as i64;
    let frac52 = bits & ((1u64 << 52) - 1);

    let (f, inexact) = round_lane(frac52, pf.frac_shift, rne);
    // f ≤ 2^m_w after a possible round-up carry; the frac mask then zeroes
    // the fraction exactly as the scalar renormalize branch does.
    let carry = (f >> pf.m_w) & 1;
    let e = e_f64 - 1023 + carry as i64 + pf.bias;

    let is_flush = e_f64 == 0;
    let is_special = e_f64 == 0x7FF;
    let is_nan = is_special && frac52 != 0;
    let is_inf = is_special && frac52 == 0;
    let under = e <= 0;
    let over = e > pf.max_biased_exp;
    let normal =
        (sign << pf.sign_shift) | (((e as u32) & pf.exp_mask) << pf.m_w) | (f as u32 & pf.frac_mask);
    let w = sel32(
        is_nan,
        0,
        sel32(
            is_inf,
            pf.max_word_signed(sign),
            sel32(
                is_flush,
                pf.zero_word(sign),
                sel32(under, pf.zero_word(sign), sel32(over, pf.max_word_signed(sign), normal)),
            ),
        ),
    );
    let normal_bits = (over as u8) | ((under as u8) << 1) | ((inexact as u8) << 2);
    let fl = sel8(
        is_nan,
        Flags::NAN_INPUT.0,
        sel8(
            is_inf,
            Flags::OVERFLOW.0,
            sel8(is_flush, ((frac52 != 0) as u8) << 1, normal_bits),
        ),
    );
    (w, Flags(fl))
}

/// Encode two `f64`s into one SWAR word (`a` → lane 0, `b` → lane 1),
/// lane-for-lane ≡ [`packed::encode_bits`]. The inputs are two full
/// 64-bit carriers, so there is no register-packing win on this side —
/// the SWAR payoff is that the *output* is already lane-packed for
/// [`mul_packed_lanes`]/[`add_packed_lanes`]. Stochastic rounding
/// delegates per lane in lane order.
#[inline]
pub fn encode_lanes(a: f64, b: f64, sf: &SwarFormat, r: &mut Rounder) -> (u64, [Flags; 2]) { // r2f2-audit: allow(native-float-quarantine) — encode boundary: carriers enter via to_bits only
    let pf = &sf.pf;
    if r.mode == RoundingMode::Stochastic {
        let (w0, f0) = packed::encode_bits(a.to_bits(), pf, r);
        let (w1, f1) = packed::encode_bits(b.to_bits(), pf, r);
        return (pack2(w0, w1), [f0, f1]);
    }
    let rne = r.mode == RoundingMode::NearestEven;
    let (w0, f0) = encode_lane(a.to_bits(), pf, rne);
    let (w1, f1) = encode_lane(b.to_bits(), pf, rne);
    (pack2(w0, w1), [f0, f1])
}

/// Decode both lanes back to `f64` — branch-free, exact, lane-for-lane ≡
/// `packed::decode_word` (the zero-exponent case is a mask select).
#[inline]
pub fn decode_lanes(v: u64, sf: &SwarFormat) -> (f64, f64) { // r2f2-audit: allow(native-float-quarantine) — decode boundary out of the lane domain (exact)
    let pf = &sf.pf;
    let decode_lane = |w: u32| -> f64 { // r2f2-audit: allow(native-float-quarantine) — per-lane bit construction, no float arithmetic
        let sign = ((w >> pf.sign_shift) & 1) as u64;
        let exp = (w >> pf.m_w) & pf.exp_mask;
        let e_f64 = (exp as i64 - pf.bias + 1023) as u64;
        let frac = (w & pf.frac_mask) as u64;
        let body = sel64(exp != 0, (e_f64 << 52) | (frac << pf.frac_shift), 0);
        f64::from_bits((sign << 63) | body) // r2f2-audit: allow(native-float-quarantine) — from_bits is exact
    };
    let (w0, w1) = unpack2(v);
    (decode_lane(w0), decode_lane(w1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    #[test]
    fn pack_unpack_roundtrip() {
        assert_eq!(unpack2(pack2(0xDEAD, 0xBEEF)), (0xDEAD, 0xBEEF));
        assert_eq!(pack2(0xFFFF_FFFF, 0), 0xFFFF_FFFF);
        assert_eq!(pack2(0, 1), 1u64 << 32);
    }

    #[test]
    fn swar_format_masks_are_lane_replicated() {
        let sf = SwarFormat::new(FpFormat::E5M10);
        let pf = &sf.pf;
        assert_eq!(sf.frac2, pack2(pf.frac_mask, pf.frac_mask));
        assert_eq!(sf.exp2, pack2(pf.exp_mask, pf.exp_mask));
        assert_eq!(sf.mag2, pack2(pf.mag_mask, pf.mag_mask));
        assert_eq!(sf.lead2, pack2(1 << pf.m_w, 1 << pf.m_w));
        assert_eq!(sf.lane_lsb, pack2(1, 1));
    }

    #[test]
    #[should_panic(expected = "total_bits ≤ 16")]
    fn oversized_format_rejected() {
        let _ = SwarFormat::new(FpFormat::E8M23); // 32 bits: word-packable, not lane-packable
    }

    #[test]
    fn e8m7_is_the_widest_lane_format() {
        // bfloat16 is exactly 16 bits — the widest admissible lane format.
        let _ = SwarFormat::new(FpFormat::E8M7);
        assert!(FpFormat::E8M7.fits_lane());
        assert!(!FpFormat::new(6, 10).fits_lane()); // 17 bits
    }

    #[test]
    fn encode_decode_lanes_match_scalar_on_nasty_values() {
        let specials = [
            0.0,
            -0.0,
            1.0,
            -1.0,
            65504.0,
            65520.0,
            6.103515625e-5,
            1e-30,
            1e30,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            f64::MIN_POSITIVE / 4.0,
            f64::MAX,
        ];
        for fmt in [FpFormat::E5M10, FpFormat::E4M3, FpFormat::E5M8] {
            let sf = SwarFormat::new(fmt);
            let mut ra = Rounder::nearest_even();
            let mut rb = Rounder::nearest_even();
            for &a in &specials {
                for &b in &[1.0, -2.5, 1e-9] {
                    let (v, fl) = encode_lanes(a, b, &sf, &mut ra);
                    let (w0, g0) = packed::encode_bits(a.to_bits(), &sf.pf, &mut rb);
                    let (w1, g1) = packed::encode_bits(b.to_bits(), &sf.pf, &mut rb);
                    assert_eq!((unpack2(v), fl), ((w0, w1), [g0, g1]), "{fmt}: {a} {b}");
                    let (d0, d1) = decode_lanes(v, &sf);
                    assert_eq!(d0.to_bits(), packed::decode_word(w0, &sf.pf).to_bits());
                    assert_eq!(d1.to_bits(), packed::decode_word(w1, &sf.pf).to_bits());
                }
            }
        }
    }

    #[test]
    fn toward_zero_mode_matches_scalar() {
        let sf = SwarFormat::new(FpFormat::E5M10);
        let mut rng = SplitMix64::new(0x7A);
        let mut ra = Rounder::toward_zero();
        let mut rb = Rounder::toward_zero();
        for _ in 0..5_000 {
            let a = f64::from_bits(rng.next_u64());
            let b = f64::from_bits(rng.next_u64());
            let (va, fa) = encode_lanes(a, b, &sf, &mut ra);
            let (w0, g0) = packed::encode_bits(a.to_bits(), &sf.pf, &mut rb);
            let (w1, g1) = packed::encode_bits(b.to_bits(), &sf.pf, &mut rb);
            assert_eq!((unpack2(va), fa), ((w0, w1), [g0, g1]), "encode {a:e} {b:e}");
            let (vm, fm) = mul_packed_lanes(va, va, &sf, &mut ra);
            let (m0, h0) = packed::mul_packed(w0, w0, &sf.pf, &mut rb);
            let (m1, h1) = packed::mul_packed(w1, w1, &sf.pf, &mut rb);
            assert_eq!((unpack2(vm), fm), ((m0, m1), [h0, h1]), "mul {a:e} {b:e}");
            let (vs, fs) = add_packed_lanes(va, vm, &sf, &mut ra);
            let (s0, k0) = packed::add_packed(w0, m0, &sf.pf, &mut rb);
            let (s1, k1) = packed::add_packed(w1, m1, &sf.pf, &mut rb);
            assert_eq!((unpack2(vs), fs), ((s0, s1), [k0, k1]), "add {a:e} {b:e}");
        }
    }

    #[test]
    fn stochastic_delegation_preserves_draw_sequence() {
        // The SWAR stochastic path and a scalar loop in flat-element order
        // must consume identical RNG draws: interleave kernels and check
        // the rounders stay in lockstep (same results ⇒ same draw counts).
        let sf = SwarFormat::new(FpFormat::E4M3);
        let mut rng = SplitMix64::new(0x7B);
        let mut ra = Rounder::stochastic(99);
        let mut rb = Rounder::stochastic(99);
        for _ in 0..5_000 {
            let a = rng.log_uniform(1e-3, 1e3);
            let b = -rng.log_uniform(1e-3, 1e3);
            let (va, fa) = encode_lanes(a, b, &sf, &mut ra);
            let (w0, g0) = packed::encode_bits(a.to_bits(), &sf.pf, &mut rb);
            let (w1, g1) = packed::encode_bits(b.to_bits(), &sf.pf, &mut rb);
            assert_eq!((unpack2(va), fa), ((w0, w1), [g0, g1]), "encode {a:e} {b:e}");
            let (vm, fm) = mul_packed_lanes(va, va, &sf, &mut ra);
            let (m0, h0) = packed::mul_packed(w0, w0, &sf.pf, &mut rb);
            let (m1, h1) = packed::mul_packed(w1, w1, &sf.pf, &mut rb);
            assert_eq!((unpack2(vm), fm), ((m0, m1), [h0, h1]), "mul {a:e} {b:e}");
            let (vs, fs) = add_packed_lanes(va, vm, &sf, &mut ra);
            let (s0, k0) = packed::add_packed(w0, m0, &sf.pf, &mut rb);
            let (s1, k1) = packed::add_packed(w1, m1, &sf.pf, &mut rb);
            assert_eq!((unpack2(vs), fs), ((s0, s1), [k0, k1]), "add {a:e} {b:e}");
        }
    }
}
