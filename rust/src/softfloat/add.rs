//! Arbitrary-precision floating-point addition/subtraction.
//!
//! A textbook align–add–normalize–round datapath with guard/round/sticky
//! bits, correctly rounded in a single step for every supported format.
//! The PDE solvers use it for the "fully quantized" mode (the Fig. 1
//! half-precision baseline, where the whole state lives in the format), and
//! it stands in for the approximate-adder substrate the paper cites
//! (Omidi et al., Liu et al.).

use super::format::{Flags, Fp, FpFormat};
use super::round::Rounder;

/// Guard + round + sticky bits carried through alignment.
const G: u32 = 3;

/// Add two packed values of the same format with one rounding step.
///
/// Signed-zero behaviour follows IEEE round-to-nearest: `(+0) + (−0) = +0`,
/// exact cancellation of finite values gives `+0`.
pub fn add(a: Fp, b: Fp, fmt: FpFormat, r: &mut Rounder) -> (Fp, Flags) {
    if a.is_zero() && b.is_zero() {
        return (Fp::zero(a.sign & b.sign), Flags::NONE);
    }
    if a.is_zero() {
        return (b, Flags::NONE);
    }
    if b.is_zero() {
        return (a, Flags::NONE);
    }

    // Order by magnitude so `hi` dominates the result sign.
    let (hi, lo) =
        if (a.exp, a.frac) >= (b.exp, b.frac) { (a, b) } else { (b, a) };
    let m_w = fmt.m_w;
    let mhi = (((1u64 << m_w) | hi.frac) as u128) << G;
    let mlo_full = ((1u64 << m_w) | lo.frac) as u128;
    let d = hi.exp - lo.exp;

    // Align the smaller operand, collapsing shifted-out bits into sticky.
    let mlo = if d == 0 {
        mlo_full << G
    } else if d >= m_w + G + 2 {
        1 // pure sticky: lo is non-zero but far below the guard bits
    } else {
        let full = mlo_full << G;
        let kept = full >> d;
        let lost = full & ((1u128 << d) - 1);
        kept | (lost != 0) as u128
    };

    let mut flags = Flags::NONE;
    if a.sign == b.sign {
        // Effective addition: sum ∈ [2^(m_w+G+1), 2^(m_w+G+2)).
        let sum = mhi + mlo;
        let (shift, exp_inc) =
            if sum >> (m_w + G + 1) != 0 { (G + 1, 1i64) } else { (G, 0i64) };
        let (val, inexact) = r.round_shift(sum, shift);
        if inexact {
            flags |= Flags::INEXACT;
        }
        pack(val, hi.sign, hi.exp as i64 + exp_inc, fmt, flags)
    } else {
        // Effective subtraction. Note: if the result needs a left shift
        // (cancellation), then d ≤ 1 and alignment lost no bits, so the
        // sticky bit is exact and shifting it left is sound.
        let diff = mhi - mlo;
        if diff == 0 {
            return (Fp::zero(0), flags);
        }
        let msb = 127 - diff.leading_zeros(); // index of leading 1
        let target = m_w + G;
        debug_assert!(msb <= target);
        let lshift = target - msb;
        let e = hi.exp as i64 - lshift as i64;
        if e <= 0 {
            return (Fp::zero(hi.sign), flags | Flags::UNDERFLOW);
        }
        let (val, inexact) = r.round_shift(diff << lshift, G);
        if inexact {
            flags |= Flags::INEXACT;
        }
        pack(val, hi.sign, e, fmt, flags)
    }
}

/// Common tail: handle the post-rounding renormalize carry, then range-check
/// the exponent and pack.
fn pack(mut val: u64, sign: u8, mut e: i64, fmt: FpFormat, flags: Flags) -> (Fp, Flags) {
    let m_w = fmt.m_w;
    if val >> (m_w + 1) != 0 {
        val >>= 1; // 10.00…0 — exact
        e += 1;
    }
    debug_assert!(val >> m_w == 1, "normalized significand expected");
    if e <= 0 {
        return (Fp::zero(sign), flags | Flags::UNDERFLOW);
    }
    if e > fmt.max_biased_exp() {
        return (fmt.max_finite(sign), flags | Flags::OVERFLOW);
    }
    (Fp { sign, exp: e as u32, frac: val & ((1u64 << m_w) - 1) }, flags)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;
    use crate::softfloat::{decode, encode};

    fn enc(x: f64, fmt: FpFormat) -> Fp {
        encode(x, fmt, &mut Rounder::nearest_even()).0
    }

    #[test]
    fn simple_sums_exact() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        for &(a, b, want) in &[
            (1.0, 1.0, 2.0),
            (1.5, 0.25, 1.75),
            (-3.0, 1.0, -2.0),
            (100.0, -100.0, 0.0),
            (0.0, 5.0, 5.0),
        ] {
            let (s, _) = add(enc(a, fmt), enc(b, fmt), fmt, &mut r);
            assert_eq!(decode(s, fmt), want, "{a}+{b}");
        }
    }

    #[test]
    fn matches_single_rounding_reference_random() {
        // m_w ≤ 24: exact sum fits f64, so f64-add + one encode is the
        // correctly-rounded reference.
        let fmt = FpFormat::new(6, 11);
        let mut r = Rounder::nearest_even();
        let mut rng = SplitMix64::new(2024);
        for _ in 0..50_000 {
            let a = decode(enc(rng.log_uniform(1e-4, 1e4), fmt), fmt)
                * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
            let b = decode(enc(rng.log_uniform(1e-4, 1e4), fmt), fmt)
                * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
            let (s, _) = add(enc(a, fmt), enc(b, fmt), fmt, &mut r);
            let want = encode(a + b, fmt, &mut Rounder::nearest_even()).0;
            assert_eq!(s, want, "a={a} b={b}");
        }
    }

    #[test]
    fn catastrophic_cancellation_is_exact() {
        // Sterbenz: if a/2 ≤ b ≤ 2a the difference is exact.
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let a = 1.0 + 512.0 * fmt.ulp_at_one();
        let b = -1.0;
        let (s, fl) = add(enc(a, fmt), enc(b, fmt), fmt, &mut r);
        assert_eq!(decode(s, fmt), a - 1.0);
        assert!(!fl.inexact());
    }

    #[test]
    fn exact_cancel_gives_plus_zero() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (s, _) = add(enc(7.5, fmt), enc(-7.5, fmt), fmt, &mut r);
        assert!(s.is_zero());
        assert_eq!(s.sign, 0);
    }

    #[test]
    fn tiny_plus_huge_keeps_huge() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (s, fl) = add(enc(65504.0, fmt), enc(1e-4, fmt), fmt, &mut r);
        assert_eq!(decode(s, fmt), 65504.0);
        assert!(fl.inexact());
    }

    #[test]
    fn overflow_saturates() {
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let (s, fl) = add(enc(65504.0, fmt), enc(65504.0, fmt), fmt, &mut r);
        assert!(fl.overflow());
        assert_eq!(decode(s, fmt), 65504.0);
    }

    #[test]
    fn subtraction_underflow_flushes() {
        // Two adjacent tiny normals differ by less than the min normal.
        let fmt = FpFormat::E5M10;
        let mut r = Rounder::nearest_even();
        let tiny = fmt.min_normal();
        let tiny2 = tiny * (1.0 + fmt.ulp_at_one());
        let (s, fl) = add(enc(tiny2, fmt), enc(-tiny, fmt), fmt, &mut r);
        assert!(fl.underflow());
        assert!(s.is_zero());
    }

    #[test]
    fn commutative() {
        let fmt = FpFormat::new(5, 7);
        let mut rng = SplitMix64::new(31);
        let mut r = Rounder::nearest_even();
        for _ in 0..10_000 {
            let a = enc(
                rng.log_uniform(1e-3, 1e3) * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 },
                fmt,
            );
            let b = enc(
                rng.log_uniform(1e-3, 1e3) * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 },
                fmt,
            );
            assert_eq!(add(a, b, fmt, &mut r), add(b, a, fmt, &mut r));
        }
    }
}
