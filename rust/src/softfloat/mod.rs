//! Arbitrary-precision software floating point.
//!
//! This is the paper's exploration substrate (§3): an "open-source library
//! for floating point multiplications using arbitrary data precision". Any
//! format `ExMy` with `2 ≤ x ≤ 11` exponent bits and `1 ≤ y ≤ 52` mantissa
//! (fraction) bits is supported, with round-to-nearest-even, toward-zero and
//! stochastic rounding.
//!
//! ## Semantics (shared with the Pallas kernels — see DESIGN.md §3)
//!
//! * **Normals only.** Subnormal inputs and underflowing results flush to
//!   zero (the paper's HLS datapath has no subnormal path).
//! * **No inf/NaN.** The all-ones exponent is *reserved* (matching the
//!   paper's "largest half = 2^15·(1+1023/1024)" arithmetic), so the maximum
//!   biased exponent of a finite value is `2^e_w − 2`. Overflow **saturates**
//!   to the largest finite value and raises [`Flags::OVERFLOW`] — the signal
//!   consumed by the R2F2 precision-adjustment unit.
//! * Results carry [`Flags`] so callers (and the adjustment unit) can see
//!   overflow/underflow/inexact events.
//!
//! The `ExMy` notation follows the paper: `E5M10` is standard half.
//!
//! Three kernel families implement these semantics, bit-identically: the
//! **carrier** path ([`encode`]/[`mul`]/[`add`]/[`decode`] on [`Fp`]
//! structs — the specification), the **packed-domain** path
//! ([`packed`]: `u32`-word kernels with precomputed [`PackedFormat`]
//! constants and 64-bit intermediates — the hot-path engine, DESIGN.md §9),
//! and the **SWAR multi-lane** path ([`swar`]: two ≤16-bit lanes per `u64`
//! with lane-replicated [`SwarFormat`] masks and branch-free lane cores,
//! DESIGN.md §14).

pub mod add;
pub mod batch;
pub mod encode;
pub mod format;
pub mod mul;
pub mod packed;
pub mod round;
pub mod swar;

pub use add::add;
pub use batch::{mul_batch_f, mul_pairs_f};
pub use encode::{decode, encode};
pub use format::{Flags, Fp, FpFormat, PackedFormat};
pub use mul::mul;
pub use packed::PackedVec;
pub use round::{Rounder, RoundingMode};
pub use swar::SwarFormat;

/// Quantize an `f64` to the nearest representable value of `fmt`
/// (round-to-nearest-even), returning the value back as `f64`.
///
/// This is the "convert from single precision and back" step the paper's
/// datapath performs around every multiplication (§5.2).
pub fn quantize(x: f64, fmt: FpFormat) -> f64 {
    let mut r = Rounder::nearest_even();
    let (fp, _) = encode(x, fmt, &mut r);
    decode(fp, fmt)
}

/// Quantize, also reporting the encode flags (overflow/underflow/inexact).
pub fn quantize_flagged(x: f64, fmt: FpFormat) -> (f64, Flags) {
    let mut r = Rounder::nearest_even();
    let (fp, f) = encode(x, fmt, &mut r);
    (decode(fp, fmt), f)
}

/// `a × b` computed entirely in `fmt`: encode both operands, multiply with a
/// single rounding, decode the result. Returns the result and the union of
/// all flags raised along the way.
pub fn mul_f(a: f64, b: f64, fmt: FpFormat) -> (f64, Flags) {
    let mut r = Rounder::nearest_even();
    let (fa, fla) = encode(a, fmt, &mut r);
    let (fb, flb) = encode(b, fmt, &mut r);
    let (fc, flc) = mul(fa, fb, fmt, &mut r);
    (decode(fc, fmt), fla | flb | flc)
}

/// `a + b` computed entirely in `fmt` (encode, add with one rounding, decode).
pub fn add_f(a: f64, b: f64, fmt: FpFormat) -> (f64, Flags) {
    let mut r = Rounder::nearest_even();
    let (fa, fla) = encode(a, fmt, &mut r);
    let (fb, flb) = encode(b, fmt, &mut r);
    let (fc, flc) = add(fa, fb, fmt, &mut r);
    (decode(fc, fmt), fla | flb | flc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_is_idempotent() {
        let fmt = FpFormat::E5M10;
        for &x in &[1.0, 0.1, 3.14159, 1234.5, -0.0625, 6.1e-5] {
            let q = quantize(x, fmt);
            assert_eq!(q, quantize(q, fmt), "x={x}");
        }
    }

    #[test]
    fn mul_f_matches_f32_hardware_for_e8m23() {
        // E8M23 *is* single precision (minus inf/NaN/subnormals); on normal
        // in-range data the software pipeline must agree with the FPU
        // bit-for-bit.
        let fmt = FpFormat::E8M23;
        let mut rng = crate::rng::SplitMix64::new(0xBEEF);
        for _ in 0..20_000 {
            let a = rng.log_uniform(1e-18, 1e18) * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
            let b = rng.log_uniform(1e-18, 1e18);
            let (got, _) = mul_f(a, b, fmt);
            let want = (a as f32) * (b as f32);
            if want.is_normal() {
                assert_eq!(got as f32, want, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn add_f_matches_f32_hardware_for_e8m23() {
        let fmt = FpFormat::E8M23;
        let mut rng = crate::rng::SplitMix64::new(0xCAFE);
        for _ in 0..20_000 {
            let a = rng.log_uniform(1e-12, 1e12) * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
            let b = rng.log_uniform(1e-12, 1e12) * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
            let (got, _) = add_f(a, b, fmt);
            let want = (a as f32) + (b as f32);
            if want.is_normal() || want == 0.0 {
                assert_eq!(got as f32, want, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn half_largest_value_matches_paper() {
        // §4.1: "The standard half precision ... can represent largest
        // number 65504 (2^15 · (1+1023/1024))".
        assert_eq!(FpFormat::E5M10.max_value(), 65504.0);
    }

    #[test]
    fn flags_reported_on_overflow_and_underflow() {
        let fmt = FpFormat::E5M10;
        let (v, f) = mul_f(1000.0, 1000.0, fmt); // 1e6 > 65504
        assert!(f.overflow());
        assert_eq!(v, 65504.0); // saturates
        let (v, f) = mul_f(1e-4, 1e-4, fmt); // 1e-8 < 2^-14
        assert!(f.underflow());
        assert_eq!(v, 0.0); // flushes
    }
}
