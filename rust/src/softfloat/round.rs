//! Rounding of fixed-point intermediates.
//!
//! Everything in the library rounds through [`Rounder::round_shift`], so the
//! three supported modes (nearest-even, toward-zero, stochastic) behave
//! identically in encode, multiply and add. Stochastic rounding is the
//! extension the paper cites from Paxton et al. (climate modeling in low
//! precision); it is exposed so the PDE harness can ablate it.

use crate::rng::SplitMix64;

/// IEEE-style rounding mode selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundingMode {
    /// Round to nearest, ties to even — the paper's datapath behaviour.
    NearestEven,
    /// Truncate (round toward zero).
    TowardZero,
    /// Stochastic rounding: round up with probability = discarded / ulp.
    Stochastic,
}

/// A rounding context: the mode plus the RNG used by stochastic rounding.
#[derive(Debug, Clone)]
pub struct Rounder {
    pub mode: RoundingMode,
    rng: SplitMix64,
}

impl Rounder {
    pub fn new(mode: RoundingMode, seed: u64) -> Rounder {
        Rounder { mode, rng: SplitMix64::new(seed) }
    }

    /// Round-to-nearest-even context (deterministic; RNG unused).
    pub fn nearest_even() -> Rounder {
        Rounder::new(RoundingMode::NearestEven, 0)
    }

    /// Toward-zero context (deterministic; RNG unused).
    pub fn toward_zero() -> Rounder {
        Rounder::new(RoundingMode::TowardZero, 0)
    }

    /// Stochastic-rounding context with the given seed.
    pub fn stochastic(seed: u64) -> Rounder {
        Rounder::new(RoundingMode::Stochastic, seed)
    }

    /// Compute `round(value / 2^shift)` per the mode.
    ///
    /// Returns `(rounded, inexact)`. `shift` may be 0 (identity) or up to
    /// 127. The caller is responsible for detecting carry-out (the rounded
    /// value reaching `2^width`).
    ///
    /// When callers pre-collapse low bits into a sticky bit (the adder does
    /// this), nearest-even and toward-zero decisions are unaffected as long
    /// as at least guard+round+sticky bits are kept; stochastic rounding
    /// then sees a coarsened probability, which we accept and document.
    #[inline]
    pub fn round_shift(&mut self, value: u128, shift: u32) -> (u64, bool) {
        if shift == 0 {
            return (value as u64, false);
        }
        let kept = (value >> shift) as u64;
        let lost = value & ((1u128 << shift) - 1);
        if lost == 0 {
            return (kept, false);
        }
        let up = match self.mode {
            RoundingMode::TowardZero => false,
            RoundingMode::NearestEven => {
                let half = 1u128 << (shift - 1);
                lost > half || (lost == half && kept & 1 == 1)
            }
            RoundingMode::Stochastic => {
                // Draw r uniform in [0, 2^shift); round up iff r < lost.
                let r = if shift >= 64 {
                    ((self.rng.next_u64() as u128) << 64 | self.rng.next_u64() as u128)
                        & ((1u128 << shift) - 1)
                } else {
                    (self.rng.next_u64() & ((1u64 << shift) - 1)) as u128
                };
                r < lost
            }
        };
        (kept + up as u64, true)
    }

    /// [`Rounder::round_shift`] restricted to 64-bit intermediates
    /// (`shift < 64`) — the packed-domain kernels' fast path (DESIGN.md §9).
    ///
    /// Bit-identical to the u128 version for every mode, **including the
    /// stochastic RNG draw sequence**: both draw exactly one `next_u64` per
    /// inexact rounding when `shift < 64`, masked the same way, so a packed
    /// kernel and its carrier twin sharing a `Rounder` stay in lockstep.
    #[inline]
    pub fn round_shift64(&mut self, value: u64, shift: u32) -> (u64, bool) {
        debug_assert!(shift < 64);
        if shift == 0 {
            return (value, false);
        }
        let kept = value >> shift;
        let lost = value & ((1u64 << shift) - 1);
        if lost == 0 {
            return (kept, false);
        }
        let up = match self.mode {
            RoundingMode::TowardZero => false,
            RoundingMode::NearestEven => {
                let half = 1u64 << (shift - 1);
                lost > half || (lost == half && kept & 1 == 1)
            }
            RoundingMode::Stochastic => {
                let r = self.rng.next_u64() & ((1u64 << shift) - 1);
                r < lost
            }
        };
        (kept + up as u64, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_when_no_bits_lost() {
        let mut r = Rounder::nearest_even();
        assert_eq!(r.round_shift(0b1000, 3), (1, false));
        assert_eq!(r.round_shift(42, 0), (42, false));
    }

    #[test]
    fn nearest_even_basic() {
        let mut r = Rounder::nearest_even();
        // 0b101.1 -> 6 (round half up to even)
        assert_eq!(r.round_shift(0b1011, 1), (0b110, true));
        // 0b100.1 -> 4 (round half down to even)
        assert_eq!(r.round_shift(0b1001, 1), (0b100, true));
        // 0b100.11 -> 5 (above half)
        assert_eq!(r.round_shift(0b10011, 2), (0b101, true));
        // 0b101.01 -> 5 (below half)
        assert_eq!(r.round_shift(0b10101, 2), (0b101, true));
    }

    #[test]
    fn toward_zero_truncates() {
        let mut r = Rounder::toward_zero();
        assert_eq!(r.round_shift(0b1011, 1), (0b101, true));
        assert_eq!(r.round_shift(0b1111, 2), (0b11, true));
    }

    #[test]
    fn stochastic_is_unbiased() {
        // E[round(x / 2^s)] == x / 2^s: rounding 0b1.01 (1.25) by 2 bits
        // should go up ~25% of the time.
        let mut r = Rounder::stochastic(123);
        let mut ups = 0u32;
        let n = 100_000;
        for _ in 0..n {
            let (v, inexact) = r.round_shift(0b101, 2);
            assert!(inexact);
            if v == 2 {
                ups += 1;
            } else {
                assert_eq!(v, 1);
            }
        }
        let p = ups as f64 / n as f64;
        assert!((p - 0.25).abs() < 0.01, "p={p}");
    }

    #[test]
    fn large_shift_ok() {
        let mut r = Rounder::nearest_even();
        let v = (1u128 << 100) + (1u128 << 99); // 1.5 * 2^100
        assert_eq!(r.round_shift(v, 100), (2, true)); // ties to even -> 2
    }

    #[test]
    fn round_shift64_matches_round_shift_all_modes() {
        // The packed kernels' 64-bit rounding must agree with the u128
        // reference bit-for-bit, including the stochastic draw sequence.
        let mut mk = crate::rng::SplitMix64::new(0x64);
        for (mut a, mut b) in [
            (Rounder::nearest_even(), Rounder::nearest_even()),
            (Rounder::toward_zero(), Rounder::toward_zero()),
            (Rounder::stochastic(77), Rounder::stochastic(77)),
        ] {
            for _ in 0..20_000 {
                let v = mk.next_u64() >> (mk.below(40) as u32);
                let s = mk.below(40) as u32;
                assert_eq!(a.round_shift(v as u128, s), b.round_shift64(v, s), "v={v} s={s}");
            }
        }
    }
}
