//! Data-distribution exploration (§3.1, Fig. 2): instruments a simulation
//! and reports how the multiplication operand/result values distribute —
//! globally wide, locally clustered, dynamically shifting.

pub mod histogram;
pub mod stages;

pub use histogram::Log2Histogram;
pub use stages::{StageStats, StageTracker};

use crate::coordinator::parallel_map;
use crate::pde::heat1d::{self, HeatParams};
use crate::pde::scenario::{self, ScenarioSize};
use crate::pde::{F64Arith, QuantMode, RecordingArith};

/// Full distribution report for one simulation run.
#[derive(Debug, Clone)]
pub struct DistributionReport {
    /// All multiplication operands+results over the entire run (Fig. 2a).
    pub overall: Log2Histogram,
    /// Per-quarter statistics (Fig. 2b/2c's "different stages").
    pub stages: Vec<StageStats>,
    /// Total values recorded.
    pub samples: u64,
}

/// Run the heat equation in f64 and record every multiplication's operands
/// and result — the §3.1 study ("we analyze data distribution using the 1D
/// heat equation during its entire simulation process").
pub fn heat_distribution(params: &HeatParams, num_stages: usize) -> DistributionReport {
    let mut overall = Log2Histogram::new();
    // The tap records 3 values per multiplication (a, b, result), so the
    // tracker's expected record count is 3× the multiplication count.
    let mut tracker =
        StageTracker::new(num_stages, 3 * params.steps as u64 * muls_per_step(params));
    let mut samples = 0u64;
    {
        let mut tap = |a: f64, b: f64, r: f64| {
            for v in [a, b, r] {
                overall.record(v);
                tracker.record(v);
            }
            samples += 3;
        };
        let mut be = RecordingArith { inner: F64Arith, tap: &mut tap };
        let _ = heat1d::run(params, &mut be, QuantMode::MulOnly);
    }
    DistributionReport { overall, stages: tracker.finish(), samples }
}

fn muls_per_step(params: &HeatParams) -> u64 {
    3 * (params.n as u64 - 2)
}

/// Octave histogram of a field, built by sharding it across `workers`
/// threads (one [`Log2Histogram`] per worker chunk, folded with
/// [`Log2Histogram::merge`]). Results are identical for any worker count —
/// the merge combines every counter, including `nonfinite`, and keeps the
/// `min_abs` sentinel honest.
pub fn field_histogram(field: &[f64], workers: usize) -> Log2Histogram {
    let workers = workers.max(1);
    // Below the fan-out threshold, thread setup dominates: record serially
    // (the merged result is identical either way).
    if workers == 1 || field.len() < 4096 {
        let mut h = Log2Histogram::new();
        for &v in field {
            h.record(v);
        }
        return h;
    }
    let per = field.len().div_ceil(workers);
    let chunks: Vec<&[f64]> = field.chunks(per).collect();
    let parts = parallel_map(chunks, workers, |c| {
        let mut h = Log2Histogram::new();
        for &v in c {
            h.record(v);
        }
        h
    });
    let mut out = Log2Histogram::new();
    for p in &parts {
        out.merge(p);
    }
    out
}

/// [`field_histogram`] of a registry scenario's final f64 field at
/// [`ScenarioSize::Accuracy`]. Callers that already hold the reference
/// field (e.g. `sweep::error_sweep::scenario_precision_profile`) should
/// histogram it directly instead of re-running the simulation here.
pub fn scenario_field_histogram(name: &str, workers: usize) -> Result<Log2Histogram, String> {
    let spec = scenario::find(name).ok_or_else(|| format!("unknown scenario `{name}`"))?;
    let run = (spec.run)(ScenarioSize::Accuracy, &mut F64Arith, QuantMode::MulOnly, true);
    Ok(field_histogram(&run.field, workers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::init::HeatInit;

    fn small() -> HeatParams {
        HeatParams {
            n: 65,
            dt: 0.25 / (64.0f64 * 64.0),
            steps: 512,
            init: HeatInit::sin_default(),
            ..HeatParams::default()
        }
    }

    #[test]
    fn report_covers_all_muls() {
        let p = small();
        let rep = heat_distribution(&p, 4);
        assert_eq!(rep.samples, p.expected_muls() * 3);
        assert_eq!(rep.stages.len(), 4);
        // The quarters are genuine quarters: equal record counts per stage.
        let per = rep.samples / 4;
        assert!(rep.stages.iter().all(|s| s.count == per), "{:?}", rep.stages);
    }

    #[test]
    fn range_is_globally_wide() {
        // Fig. 2a: "the data range is globally wide" — many octaves between
        // the largest and smallest non-zero magnitudes seen by the
        // multiplier.
        let rep = heat_distribution(&small(), 4);
        let (lo, hi) = rep.overall.nonzero_range().unwrap();
        assert!(hi / lo > 1e4, "range [{lo},{hi}] not wide");
    }

    #[test]
    fn range_shrinks_across_stages() {
        // Fig. 2b: the sine solution decays, so later stages see smaller
        // maxima — the "dynamic range shift" motivating runtime adjustment.
        let rep = heat_distribution(&small(), 4);
        let maxes: Vec<f64> = rep.stages.iter().map(|s| s.max_abs).collect();
        assert!(
            maxes[3] < maxes[0],
            "stage maxima should shrink: {maxes:?}"
        );
        // Decay is monotone for the pure sine mode.
        assert!(maxes.windows(2).all(|w| w[1] <= w[0] * 1.01), "{maxes:?}");
    }

    #[test]
    fn field_histogram_is_worker_count_invariant() {
        // A field large enough to cross the fan-out threshold, with every
        // counter class populated (zeros, signs, non-finites, wide range):
        // the per-worker histograms must merge to the serial recording no
        // matter how the chunks land on threads.
        let mut field: Vec<f64> = (0..10_000)
            .map(|i| {
                let s = if i % 3 == 0 { -1.0 } else { 1.0 };
                s * (i as f64 - 5000.0) * 1e-3
            })
            .collect();
        field[17] = 0.0;
        field[4096] = f64::INFINITY;
        field[9000] = f64::NAN;
        let mut one = Log2Histogram::new();
        for &v in &field {
            one.record(v);
        }
        for workers in [1usize, 2, 5, 8] {
            let many = field_histogram(&field, workers);
            assert_eq!(many.total, one.total);
            assert_eq!(many.zeros, one.zeros);
            assert_eq!(many.negatives, one.negatives);
            assert_eq!(many.nonfinite, one.nonfinite, "workers = {workers}");
            assert_eq!(many.nonzero_range(), one.nonzero_range());
            let a: Vec<(i32, u64)> = many.iter().collect();
            let b: Vec<(i32, u64)> = one.iter().collect();
            assert_eq!(a, b, "workers = {workers}");
        }
        // The by-name wrapper resolves registry scenarios (and rejects
        // unknown names).
        assert!(scenario_field_histogram("heat1d", 2).unwrap().total > 0);
        assert!(scenario_field_histogram("no-such-scenario", 2).is_err());
    }

    #[test]
    fn values_cluster_locally() {
        // Fig. 2a also shows local clusters: within one stage, the bulk of
        // values occupy far fewer octaves than the global range.
        let rep = heat_distribution(&small(), 4);
        let s = &rep.stages[0];
        let bulk = s.histogram.bulk_octaves(0.9);
        let global = rep.overall.occupied_octaves();
        assert!(
            (bulk as f64) < 0.7 * global as f64,
            "bulk {bulk} octaves vs global {global}"
        );
    }
}
