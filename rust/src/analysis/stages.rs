//! Per-stage range tracking: splits a value stream into equal phases of the
//! simulation (the paper uses quarters: "in the first 25% simulation
//! iterations ... in the last 25%", Fig. 2b/2c) and summarizes each.

use super::histogram::Log2Histogram;

/// Summary of one simulation stage.
#[derive(Debug, Clone)]
pub struct StageStats {
    /// Stage index (0-based).
    pub index: usize,
    /// Non-zero magnitude extremes seen in this stage.
    pub min_abs: f64,
    pub max_abs: f64,
    /// Samples recorded.
    pub count: u64,
    /// Octave histogram of the stage.
    pub histogram: Log2Histogram,
}

/// Streams values into `num_stages` equal chunks by sample index.
#[derive(Debug)]
pub struct StageTracker {
    per_stage: u64,
    seen: u64,
    current: Log2Histogram,
    done: Vec<StageStats>,
    num_stages: usize,
}

impl StageTracker {
    /// `expected_total` is the number of *records* the run will produce.
    /// Stage boundaries are `floor(expected_total / num_stages)` records
    /// apart, so streaming exactly `expected_total` records yields exactly
    /// `num_stages` stages (the final stage absorbs the division remainder;
    /// see the exact contract on [`StageTracker::finish`]).
    pub fn new(num_stages: usize, expected_total: u64) -> StageTracker {
        assert!(num_stages >= 1);
        StageTracker {
            per_stage: (expected_total / num_stages as u64).max(1),
            seen: 0,
            current: Log2Histogram::new(),
            done: Vec::new(),
            num_stages,
        }
    }

    pub fn record(&mut self, v: f64) {
        self.current.record(v);
        self.seen += 1;
        if self.seen % self.per_stage == 0 && self.done.len() + 1 < self.num_stages {
            self.roll();
        }
    }

    fn roll(&mut self) {
        let h = std::mem::replace(&mut self.current, Log2Histogram::new());
        self.done.push(summarize(self.done.len(), h));
    }

    /// Close the final stage and return all stage summaries.
    ///
    /// Exact contract for a stream of exactly `expected_total` records
    /// (property-tested in `rust/tests/property_suite.rs`):
    ///
    /// * `expected_total ≥ num_stages`: exactly `num_stages` stages; the
    ///   first `num_stages − 1` hold `floor(expected_total / num_stages)`
    ///   records each and the final stage holds the rest (equal to the
    ///   others when the division is exact — the final roll then happens
    ///   here, not in [`StageTracker::record`]).
    /// * `1 ≤ expected_total < num_stages`: one stage per record.
    /// * empty stream: a single empty stage.
    pub fn finish(mut self) -> Vec<StageStats> {
        if self.current.total > 0 || self.done.is_empty() {
            self.roll();
        }
        self.done
    }
}

fn summarize(index: usize, h: Log2Histogram) -> StageStats {
    let (min_abs, max_abs) = h.nonzero_range().unwrap_or((0.0, 0.0));
    StageStats { index, min_abs, max_abs, count: h.total, histogram: h }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_into_equal_stages() {
        let mut t = StageTracker::new(4, 1200);
        for i in 0..1200u64 {
            t.record(i as f64 + 1.0);
        }
        let stages = t.finish();
        assert_eq!(stages.len(), 4);
        assert!(stages.iter().all(|s| s.count == 300));
    }

    #[test]
    fn stage_ranges_reflect_data() {
        let mut t = StageTracker::new(2, 12);
        for v in [100.0, 200.0, 150.0, 180.0, 120.0, 110.0] {
            t.record(v);
        }
        for v in [1.0, 2.0, 1.5, 1.8, 1.2, 1.1] {
            t.record(v);
        }
        let stages = t.finish();
        assert_eq!(stages.len(), 2);
        assert!(stages[0].max_abs >= 100.0);
        assert!(stages[1].max_abs <= 2.0);
    }

    #[test]
    fn non_divisible_total_keeps_stage_count() {
        // 10 records into 4 stages: 2, 2, 2 and a final stage of 4.
        let mut t = StageTracker::new(4, 10);
        for i in 0..10u64 {
            t.record(i as f64 + 1.0);
        }
        let stages = t.finish();
        assert_eq!(stages.len(), 4);
        let counts: Vec<u64> = stages.iter().map(|s| s.count).collect();
        assert_eq!(counts, vec![2, 2, 2, 4]);
    }

    #[test]
    fn short_stream_still_produces_a_stage() {
        let mut t = StageTracker::new(4, 1000);
        t.record(5.0);
        let stages = t.finish();
        assert_eq!(stages.len(), 1);
        assert_eq!(stages[0].count, 1);
    }
}
