//! Power-of-two (octave) histograms of value magnitudes — the natural
//! bucketing for floating-point range studies, since each octave maps to
//! one exponent code.

use std::collections::BTreeMap;

/// Histogram over `floor(log2(|v|))`, with dedicated zero / sign /
/// non-finite counters.
///
/// Inf and NaN are tallied in [`Log2Histogram::nonfinite`], *not* in
/// `zeros` — the adaptive precision scheduler (`pde::adaptive`) keys its
/// widen trigger off this distinction: a flushed-to-zero value is bounded
/// error, a non-finite one means the carrier arithmetic itself blew up.
#[derive(Debug, Clone)]
pub struct Log2Histogram {
    buckets: BTreeMap<i32, u64>,
    pub zeros: u64,
    pub negatives: u64,
    /// Inf/NaN inputs (they carry no magnitude and are not zeros).
    pub nonfinite: u64,
    pub total: u64,
    min_abs: f64,
    max_abs: f64,
}

/// Same sentinel state as [`Log2Histogram::new`] (`min_abs = +inf`), so a
/// default-constructed histogram tracks `nonzero_range` correctly.
impl Default for Log2Histogram {
    fn default() -> Log2Histogram {
        Log2Histogram::new()
    }
}

impl Log2Histogram {
    pub fn new() -> Log2Histogram {
        Log2Histogram {
            buckets: BTreeMap::new(),
            zeros: 0,
            negatives: 0,
            nonfinite: 0,
            total: 0,
            min_abs: f64::INFINITY,
            max_abs: 0.0,
        }
    }

    pub fn record(&mut self, v: f64) {
        self.total += 1;
        if !v.is_finite() {
            self.nonfinite += 1;
            return;
        }
        if v < 0.0 {
            self.negatives += 1;
        }
        let a = v.abs();
        if a == 0.0 {
            self.zeros += 1;
            return;
        }
        self.min_abs = self.min_abs.min(a);
        self.max_abs = self.max_abs.max(a);
        let oct = a.log2().floor() as i32;
        *self.buckets.entry(oct).or_insert(0) += 1;
    }

    /// Fold another histogram into this one, as if every sample recorded
    /// into `other` had been recorded here. Used by scenario harnesses
    /// that build per-worker histograms under `coordinator::parallel_map`
    /// and combine them afterwards — merged totals are order- and
    /// sharding-independent.
    ///
    /// Every counter is combined, **including the `nonfinite` counter**
    /// (added in PR 3 — any merge written against the pre-PR-3 field set
    /// would silently drop Inf/NaN tallies), and the `min_abs` sentinel is
    /// taken with `min` (both sides start at `+inf`, so an empty side
    /// never corrupts the other's range).
    pub fn merge(&mut self, other: &Log2Histogram) {
        for (oct, count) in other.buckets.iter() {
            *self.buckets.entry(*oct).or_insert(0) += count;
        }
        self.zeros += other.zeros;
        self.negatives += other.negatives;
        self.nonfinite += other.nonfinite;
        self.total += other.total;
        self.min_abs = self.min_abs.min(other.min_abs);
        self.max_abs = self.max_abs.max(other.max_abs);
    }

    /// Smallest and largest non-zero magnitude recorded.
    pub fn nonzero_range(&self) -> Option<(f64, f64)> {
        if self.max_abs == 0.0 {
            None
        } else {
            Some((self.min_abs, self.max_abs))
        }
    }

    /// Number of distinct octaves with at least one sample.
    pub fn occupied_octaves(&self) -> usize {
        self.buckets.len()
    }

    /// Smallest number of *contiguous* octaves containing `frac` of the
    /// non-zero samples — the "local cluster width" metric behind Fig. 2a.
    pub fn bulk_octaves(&self, frac: f64) -> usize {
        let nonzero: u64 = self.buckets.values().sum();
        if nonzero == 0 {
            return 0;
        }
        let need = (nonzero as f64 * frac).ceil() as u64;
        let octs: Vec<(i32, u64)> = self.buckets.iter().map(|(k, v)| (*k, *v)).collect();
        let mut best = usize::MAX;
        // Two-pointer over the sorted octave list (windows must be
        // contiguous in octave space, counting empty octaves in the width).
        for start in 0..octs.len() {
            let mut acc = 0u64;
            for end in start..octs.len() {
                acc += octs[end].1;
                if acc >= need {
                    best = best.min((octs[end].0 - octs[start].0 + 1) as usize);
                    break;
                }
            }
        }
        if best == usize::MAX {
            (octs.last().unwrap().0 - octs[0].0 + 1) as usize
        } else {
            best
        }
    }

    /// Iterate `(octave, count)` in ascending octave order.
    pub fn iter(&self) -> impl Iterator<Item = (i32, u64)> + '_ {
        self.buckets.iter().map(|(k, v)| (*k, *v))
    }

    /// Bars for [`crate::report::histogram`]: `[2^k, 2^{k+1})` labels.
    pub fn bars(&self) -> Vec<(String, u64)> {
        self.iter().map(|(o, c)| (format!("2^{o:<4}"), c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_by_octave() {
        let mut h = Log2Histogram::new();
        for v in [1.0, 1.5, 1.99, 2.0, 3.9, 0.5, -0.6] {
            h.record(v);
        }
        let m: Vec<(i32, u64)> = h.iter().collect();
        assert_eq!(m, vec![(-1, 2), (0, 3), (1, 2)]);
        assert_eq!(h.negatives, 1);
        assert_eq!(h.total, 7);
    }

    #[test]
    fn zeros_separate() {
        let mut h = Log2Histogram::new();
        h.record(0.0);
        h.record(-0.0);
        h.record(1.0);
        assert_eq!(h.zeros, 2);
        assert_eq!(h.occupied_octaves(), 1);
    }

    #[test]
    fn range_tracking() {
        let mut h = Log2Histogram::new();
        for v in [0.001, 10.0, -500.0] {
            h.record(v);
        }
        assert_eq!(h.nonzero_range(), Some((0.001, 500.0)));
    }

    #[test]
    fn bulk_octaves_finds_cluster() {
        let mut h = Log2Histogram::new();
        // 90 samples near 1.0, 10 scattered far away.
        for _ in 0..90 {
            h.record(1.2);
        }
        for i in 0..10 {
            h.record(1000.0 * (1 << i) as f64);
        }
        assert_eq!(h.bulk_octaves(0.9), 1);
        assert!(h.occupied_octaves() > 5);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Log2Histogram::new();
        assert_eq!(h.nonzero_range(), None);
        assert_eq!(h.bulk_octaves(0.9), 0);
    }

    #[test]
    fn nonfinite_counted_separately_from_zeros_and_negatives() {
        let mut h = Log2Histogram::new();
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(f64::NAN);
        h.record(0.0);
        h.record(-2.0);
        assert_eq!(h.nonfinite, 3);
        assert_eq!(h.zeros, 1);
        assert_eq!(h.negatives, 1); // −inf is non-finite, not a negative sample
        assert_eq!(h.total, 5);
        assert_eq!(h.occupied_octaves(), 1);
        assert_eq!(h.nonzero_range(), Some((2.0, 2.0)));
    }

    #[test]
    fn merge_equals_sequential_recording() {
        // Shard a mixed stream (zeros, signs, non-finites, wide range) and
        // merge the per-shard histograms: every counter must equal the
        // single-histogram recording, in any merge order.
        let stream: Vec<f64> = vec![
            0.0,
            -0.0,
            1.5,
            -2.5,
            1e-7,
            -1e7,
            f64::INFINITY,
            f64::NAN,
            -3.0,
            0.25,
            f64::NEG_INFINITY,
            42.0,
        ];
        let mut want = Log2Histogram::new();
        for &v in &stream {
            want.record(v);
        }
        for chunk in [1usize, 3, 5] {
            let parts: Vec<Log2Histogram> = stream
                .chunks(chunk)
                .map(|c| {
                    let mut h = Log2Histogram::new();
                    for &v in c {
                        h.record(v);
                    }
                    h
                })
                .collect();
            let mut fwd = Log2Histogram::new();
            for p in parts.iter() {
                fwd.merge(p);
            }
            let mut rev = Log2Histogram::new();
            for p in parts.iter().rev() {
                rev.merge(p);
            }
            for got in [&fwd, &rev] {
                assert_eq!(got.total, want.total);
                assert_eq!(got.zeros, want.zeros);
                assert_eq!(got.negatives, want.negatives);
                assert_eq!(got.nonfinite, want.nonfinite, "nonfinite must merge");
                assert_eq!(got.nonzero_range(), want.nonzero_range());
                let a: Vec<(i32, u64)> = got.iter().collect();
                let b: Vec<(i32, u64)> = want.iter().collect();
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn merge_with_empty_keeps_sentinels() {
        // The regression the audit was for: an empty histogram's
        // `min_abs = +inf` sentinel must not corrupt the other side (a
        // naive `min` over a zero-initialized sentinel would pin the
        // merged min_abs to 0).
        let mut h = Log2Histogram::new();
        h.record(5.0);
        h.merge(&Log2Histogram::new());
        assert_eq!(h.nonzero_range(), Some((5.0, 5.0)));
        let mut empty = Log2Histogram::new();
        empty.merge(&h);
        assert_eq!(empty.nonzero_range(), Some((5.0, 5.0)));
        assert_eq!(empty.total, 1);
        let mut both = Log2Histogram::new();
        both.merge(&Log2Histogram::new());
        assert_eq!(both.nonzero_range(), None);
    }

    #[test]
    fn default_matches_new_sentinels() {
        // The derived Default used to leave `min_abs = 0.0`, corrupting
        // `nonzero_range` of any default-constructed histogram.
        let mut h = Log2Histogram::default();
        h.record(5.0);
        assert_eq!(h.nonzero_range(), Some((5.0, 5.0)));
        let empty = Log2Histogram::default();
        assert_eq!(empty.nonzero_range(), None);
    }
}
