//! Power-of-two (octave) histograms of value magnitudes — the natural
//! bucketing for floating-point range studies, since each octave maps to
//! one exponent code.

use std::collections::BTreeMap;

/// Histogram over `floor(log2(|v|))`, with dedicated zero / sign /
/// non-finite counters.
///
/// Inf and NaN are tallied in [`Log2Histogram::nonfinite`], *not* in
/// `zeros` — the adaptive precision scheduler (`pde::adaptive`) keys its
/// widen trigger off this distinction: a flushed-to-zero value is bounded
/// error, a non-finite one means the carrier arithmetic itself blew up.
#[derive(Debug, Clone)]
pub struct Log2Histogram {
    buckets: BTreeMap<i32, u64>,
    pub zeros: u64,
    pub negatives: u64,
    /// Inf/NaN inputs (they carry no magnitude and are not zeros).
    pub nonfinite: u64,
    pub total: u64,
    min_abs: f64,
    max_abs: f64,
}

/// Same sentinel state as [`Log2Histogram::new`] (`min_abs = +inf`), so a
/// default-constructed histogram tracks `nonzero_range` correctly.
impl Default for Log2Histogram {
    fn default() -> Log2Histogram {
        Log2Histogram::new()
    }
}

impl Log2Histogram {
    pub fn new() -> Log2Histogram {
        Log2Histogram {
            buckets: BTreeMap::new(),
            zeros: 0,
            negatives: 0,
            nonfinite: 0,
            total: 0,
            min_abs: f64::INFINITY,
            max_abs: 0.0,
        }
    }

    pub fn record(&mut self, v: f64) {
        self.total += 1;
        if !v.is_finite() {
            self.nonfinite += 1;
            return;
        }
        if v < 0.0 {
            self.negatives += 1;
        }
        let a = v.abs();
        if a == 0.0 {
            self.zeros += 1;
            return;
        }
        self.min_abs = self.min_abs.min(a);
        self.max_abs = self.max_abs.max(a);
        let oct = a.log2().floor() as i32;
        *self.buckets.entry(oct).or_insert(0) += 1;
    }

    /// Smallest and largest non-zero magnitude recorded.
    pub fn nonzero_range(&self) -> Option<(f64, f64)> {
        if self.max_abs == 0.0 {
            None
        } else {
            Some((self.min_abs, self.max_abs))
        }
    }

    /// Number of distinct octaves with at least one sample.
    pub fn occupied_octaves(&self) -> usize {
        self.buckets.len()
    }

    /// Smallest number of *contiguous* octaves containing `frac` of the
    /// non-zero samples — the "local cluster width" metric behind Fig. 2a.
    pub fn bulk_octaves(&self, frac: f64) -> usize {
        let nonzero: u64 = self.buckets.values().sum();
        if nonzero == 0 {
            return 0;
        }
        let need = (nonzero as f64 * frac).ceil() as u64;
        let octs: Vec<(i32, u64)> = self.buckets.iter().map(|(k, v)| (*k, *v)).collect();
        let mut best = usize::MAX;
        // Two-pointer over the sorted octave list (windows must be
        // contiguous in octave space, counting empty octaves in the width).
        for start in 0..octs.len() {
            let mut acc = 0u64;
            for end in start..octs.len() {
                acc += octs[end].1;
                if acc >= need {
                    best = best.min((octs[end].0 - octs[start].0 + 1) as usize);
                    break;
                }
            }
        }
        if best == usize::MAX {
            (octs.last().unwrap().0 - octs[0].0 + 1) as usize
        } else {
            best
        }
    }

    /// Iterate `(octave, count)` in ascending octave order.
    pub fn iter(&self) -> impl Iterator<Item = (i32, u64)> + '_ {
        self.buckets.iter().map(|(k, v)| (*k, *v))
    }

    /// Bars for [`crate::report::histogram`]: `[2^k, 2^{k+1})` labels.
    pub fn bars(&self) -> Vec<(String, u64)> {
        self.iter().map(|(o, c)| (format!("2^{o:<4}"), c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_by_octave() {
        let mut h = Log2Histogram::new();
        for v in [1.0, 1.5, 1.99, 2.0, 3.9, 0.5, -0.6] {
            h.record(v);
        }
        let m: Vec<(i32, u64)> = h.iter().collect();
        assert_eq!(m, vec![(-1, 2), (0, 3), (1, 2)]);
        assert_eq!(h.negatives, 1);
        assert_eq!(h.total, 7);
    }

    #[test]
    fn zeros_separate() {
        let mut h = Log2Histogram::new();
        h.record(0.0);
        h.record(-0.0);
        h.record(1.0);
        assert_eq!(h.zeros, 2);
        assert_eq!(h.occupied_octaves(), 1);
    }

    #[test]
    fn range_tracking() {
        let mut h = Log2Histogram::new();
        for v in [0.001, 10.0, -500.0] {
            h.record(v);
        }
        assert_eq!(h.nonzero_range(), Some((0.001, 500.0)));
    }

    #[test]
    fn bulk_octaves_finds_cluster() {
        let mut h = Log2Histogram::new();
        // 90 samples near 1.0, 10 scattered far away.
        for _ in 0..90 {
            h.record(1.2);
        }
        for i in 0..10 {
            h.record(1000.0 * (1 << i) as f64);
        }
        assert_eq!(h.bulk_octaves(0.9), 1);
        assert!(h.occupied_octaves() > 5);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Log2Histogram::new();
        assert_eq!(h.nonzero_range(), None);
        assert_eq!(h.bulk_octaves(0.9), 0);
    }

    #[test]
    fn nonfinite_counted_separately_from_zeros_and_negatives() {
        let mut h = Log2Histogram::new();
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(f64::NAN);
        h.record(0.0);
        h.record(-2.0);
        assert_eq!(h.nonfinite, 3);
        assert_eq!(h.zeros, 1);
        assert_eq!(h.negatives, 1); // −inf is non-finite, not a negative sample
        assert_eq!(h.total, 5);
        assert_eq!(h.occupied_octaves(), 1);
        assert_eq!(h.nonzero_range(), Some((2.0, 2.0)));
    }

    #[test]
    fn default_matches_new_sentinels() {
        // The derived Default used to leave `min_abs = 0.0`, corrupting
        // `nonzero_range` of any default-constructed histogram.
        let mut h = Log2Histogram::default();
        h.record(5.0);
        assert_eq!(h.nonzero_range(), Some((5.0, 5.0)));
        let empty = Log2Histogram::default();
        assert_eq!(empty.nonzero_range(), None);
    }
}
