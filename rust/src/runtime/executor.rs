//! Simulation step-loop executors: rust owns the time loop, the compiled
//! step is the body. State literals feed back between steps — the request
//! path is pure rust → PJRT.
//!
//! Compiled only with the `pjrt` feature; see `runtime::stub` otherwise.

use super::client::{Executable, Runtime};
use super::error::{wrap, Result, RuntimeError};
use super::{HeatRunOutput, SweRunOutput};
use crate::metrics::Registry;
use std::sync::Arc;
use std::time::Instant;

/// Heat-equation runner over a `heat_step_*` artifact.
pub struct HeatRunner {
    exe: Arc<Executable>,
    pub n: usize,
    /// Whether the artifact threads R2F2 unit state (5 outputs) or is a
    /// plain field→field step (1 output).
    adaptive: bool,
    metrics: Registry,
}

impl HeatRunner {
    /// `variant` is a manifest name: `heat_step_r2f2`, `heat_step_e5m10`,
    /// `heat_step_f32`.
    pub fn new(rt: &mut Runtime, variant: &str, metrics: Registry) -> Result<HeatRunner> {
        let info = rt
            .manifest
            .find(variant)
            .ok_or_else(|| RuntimeError(format!("unknown heat variant {variant}")))?;
        let n = info.inputs[0].0[0];
        let adaptive = info.outputs == 5;
        let exe = rt.load(variant)?;
        Ok(HeatRunner { exe, n, adaptive, metrics })
    }

    /// Run `steps` steps from the initial field `u0` with diffusion number
    /// `r`. Initial unit split `k0` applies to adaptive variants.
    pub fn run(&self, u0: &[f32], r: f32, steps: usize, k0: i32) -> Result<HeatRunOutput> {
        assert_eq!(u0.len(), self.n, "field length must match the artifact");
        let r_lit = Runtime::lit_f32(&[r]);
        let mut u = Runtime::lit_f32(u0);
        let mut k = Runtime::lit_i32(&vec![k0; self.n]);
        let mut s = Runtime::lit_i32(&vec![0i32; self.n]);
        let mut widen = 0i64;
        let mut narrow = 0i64;

        let t0 = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — PJRT steps/s telemetry; the field result is clock-independent
        for _ in 0..steps {
            if self.adaptive {
                let mut outs = self.exe.run(&[u, r_lit.clone_literal(), k, s])?;
                // Outputs: u', k', streak', widen, narrow.
                let nr: Vec<i32> = outs[4].to_vec().map_err(wrap)?;
                let wd: Vec<i32> = outs[3].to_vec().map_err(wrap)?;
                widen += wd.iter().map(|&x| x as i64).sum::<i64>();
                narrow += nr.iter().map(|&x| x as i64).sum::<i64>();
                s = outs.remove(2);
                k = outs.remove(1);
                u = outs.remove(0);
            } else {
                let mut outs = self.exe.run(&[u, r_lit.clone_literal()])?;
                u = outs.remove(0);
            }
        }
        let elapsed = t0.elapsed();
        self.metrics.inc("heat.steps", steps as u64);
        self.metrics.observe_ns(
            &format!("heat.run.{}", self.exe.name),
            elapsed.as_nanos() as u64,
        );
        Ok(HeatRunOutput { u: u.to_vec::<f32>().map_err(wrap)?, widen, narrow, elapsed, steps })
    }
}

/// Shallow-water runner over a `swe_step_*` artifact.
pub struct SweRunner {
    exe: Arc<Executable>,
    pub n: usize,
    adaptive: bool,
    metrics: Registry,
}

impl SweRunner {
    pub fn new(rt: &mut Runtime, variant: &str, metrics: Registry) -> Result<SweRunner> {
        let info = rt
            .manifest
            .find(variant)
            .ok_or_else(|| RuntimeError(format!("unknown swe variant {variant}")))?;
        let n = info.inputs[0].0[0] - 2;
        let adaptive = info.outputs == 7;
        let exe = rt.load(variant)?;
        Ok(SweRunner { exe, n, adaptive, metrics })
    }

    /// Run from padded initial fields (length (n+2)²).
    pub fn run(&self, h0: &[f32], steps: usize, k0: i32) -> Result<SweRunOutput> {
        let side = self.n + 2;
        assert_eq!(h0.len(), side * side);
        let lanes = (self.n + 1) * self.n;
        let mut h = Runtime::lit_f32_2d(h0, side, side)?;
        let zeros = vec![0f32; side * side];
        let mut u = Runtime::lit_f32_2d(&zeros, side, side)?;
        let mut v = Runtime::lit_f32_2d(&zeros, side, side)?;
        let mut k = Runtime::lit_i32(&vec![k0; lanes]);
        let mut s = Runtime::lit_i32(&vec![0i32; lanes]);
        let mut widen = 0i64;
        let mut narrow = 0i64;

        let t0 = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — PJRT steps/s telemetry; the field result is clock-independent
        for _ in 0..steps {
            if self.adaptive {
                let mut outs = self.exe.run(&[h, u, v, k, s])?;
                widen += outs[5].get_first_element::<i32>().map_err(wrap)? as i64;
                narrow += outs[6].get_first_element::<i32>().map_err(wrap)? as i64;
                s = outs.remove(4);
                k = outs.remove(3);
                v = outs.remove(2);
                u = outs.remove(1);
                h = outs.remove(0);
            } else {
                let mut outs = self.exe.run(&[h, u, v])?;
                v = outs.remove(2);
                u = outs.remove(1);
                h = outs.remove(0);
            }
        }
        let elapsed = t0.elapsed();
        self.metrics.inc("swe.steps", steps as u64);
        Ok(SweRunOutput { h: h.to_vec::<f32>().map_err(wrap)?, widen, narrow, elapsed, steps })
    }
}

/// `xla::Literal` lacks `Clone`; shallow re-materialize via raw copy.
trait CloneLiteral {
    fn clone_literal(&self) -> xla::Literal;
}

impl CloneLiteral for xla::Literal {
    fn clone_literal(&self) -> xla::Literal {
        let v: Vec<f32> = self.to_vec().expect("clone_literal: f32 vec");
        xla::Literal::vec1(&v)
    }
}
