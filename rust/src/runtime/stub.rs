//! Stub PJRT runtime for builds without the `pjrt` feature.
//!
//! The real runtime (`client`/`executor`) needs the `xla` PJRT bindings,
//! which are not vendored in this offline environment (DESIGN.md §7). This
//! stub keeps the exact same API surface so every caller — the CLI
//! `pipeline` command, `benches/hotpath.rs`, the cross-layer integration
//! tests, `examples/e2e_pipeline.rs` — compiles unchanged and *skips
//! politely*: [`Runtime::new`] always fails with a descriptive error, which
//! is the same signal those callers already handle for missing artifacts.

use super::error::{Result, RuntimeError};
use super::manifest::Manifest;
use super::{HeatRunOutput, SweRunOutput};
use crate::metrics::Registry;
use std::path::Path;
use std::sync::Arc;

const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the `pjrt` feature \
(the `xla` bindings are not vendored in this environment); the native emulation \
paths cover every experiment — run `cargo bench` or the CLI without `pipeline`";

fn unavailable() -> RuntimeError {
    RuntimeError::from(UNAVAILABLE)
}

/// Opaque stand-in for a PJRT device literal.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable())
    }
}

/// Stand-in for a compiled artifact; never constructed.
pub struct Executable {
    pub name: String,
    pub outputs: usize,
}

impl Executable {
    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn run_f32(&self, _inputs: &[Literal], _idx: usize) -> Result<Vec<f32>> {
        Err(unavailable())
    }
}

/// Stand-in for the PJRT CPU client; [`Runtime::new`] always fails, so no
/// instance ever exists and the remaining methods are unreachable.
pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(_artifacts_dir: &Path) -> Result<Runtime> {
        Err(unavailable())
    }

    pub fn from_default_dir() -> Result<Runtime> {
        Self::new(&super::manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn load(&mut self, _name: &str) -> Result<Arc<Executable>> {
        Err(unavailable())
    }

    pub fn lit_f32(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn lit_i32(_data: &[i32]) -> Literal {
        Literal
    }

    pub fn lit_f32_2d(_data: &[f32], _rows: usize, _cols: usize) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Heat-equation runner stub.
pub struct HeatRunner {
    pub n: usize,
}

impl HeatRunner {
    pub fn new(_rt: &mut Runtime, _variant: &str, _metrics: Registry) -> Result<HeatRunner> {
        Err(unavailable())
    }

    pub fn run(&self, _u0: &[f32], _r: f32, _steps: usize, _k0: i32) -> Result<HeatRunOutput> {
        Err(unavailable())
    }
}

/// Shallow-water runner stub.
pub struct SweRunner {
    pub n: usize,
}

impl SweRunner {
    pub fn new(_rt: &mut Runtime, _variant: &str, _metrics: Registry) -> Result<SweRunner> {
        Err(unavailable())
    }

    pub fn run(&self, _h0: &[f32], _steps: usize, _k0: i32) -> Result<SweRunOutput> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loud_and_descriptive() {
        let err = Runtime::from_default_dir().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
        let err = Runtime::new(Path::new("/nonexistent")).err().unwrap();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }

    #[test]
    fn literals_construct_but_never_read() {
        let l = Runtime::lit_f32(&[1.0, 2.0]);
        assert!(l.to_vec::<f32>().is_err());
        assert!(Literal.get_first_element::<i32>().is_err());
        assert!(Runtime::lit_f32_2d(&[0.0; 4], 2, 2).is_err());
    }
}
