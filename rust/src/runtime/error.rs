//! Runtime-layer error type.
//!
//! The offline build environment carries no external error crates, so the
//! runtime defines its own minimal error: a message string that implements
//! [`std::error::Error`]. The PJRT-backed implementation (feature `pjrt`)
//! and the stub share it, so callers are identical under both builds.

use std::fmt;

/// An error from the PJRT runtime layer (or its stub).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<String> for RuntimeError {
    fn from(s: String) -> RuntimeError {
        RuntimeError(s)
    }
}

impl From<&str> for RuntimeError {
    fn from(s: &str) -> RuntimeError {
        RuntimeError(s.to_string())
    }
}

/// Runtime-layer result alias.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Wrap any displayable error (the PJRT bindings' error types included).
pub fn wrap<E: fmt::Display>(e: E) -> RuntimeError {
    RuntimeError(e.to_string())
}

/// Wrap with a context prefix, anyhow-style.
pub fn ctx<E: fmt::Display>(context: &str, e: E) -> RuntimeError {
    RuntimeError(format!("{context}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context() {
        let e = ctx("parsing manifest", RuntimeError::from("bad json"));
        assert_eq!(e.to_string(), "parsing manifest: bad json");
        assert_eq!(wrap("plain").to_string(), "plain");
    }
}
