//! PJRT client wrapper: HLO text → compiled executable → literal execution.
//!
//! The interchange is HLO **text** (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md §7).
//!
//! Compiled only with the `pjrt` feature (requires the vendored `xla`
//! bindings); see `runtime::stub` for the featureless build.

use super::error::{ctx, wrap, Result, RuntimeError};
use super::manifest::{ArtifactInfo, Manifest};
use std::collections::HashMap;
use std::path::Path;

/// A compiled artifact ready to run.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Output-tuple arity per the manifest.
    pub outputs: usize,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs).map_err(wrap)?;
        let lit = result[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True, so outputs always arrive as
        // one tuple literal.
        let parts = lit.to_tuple().map_err(wrap)?;
        if parts.len() != self.outputs {
            return Err(RuntimeError(format!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.outputs,
                parts.len()
            )));
        }
        Ok(parts)
    }

    /// Convenience: run and read output `idx` as a f32 vector.
    pub fn run_f32(&self, inputs: &[xla::Literal], idx: usize) -> Result<Vec<f32>> {
        let outs = self.run(inputs)?;
        outs[idx].to_vec::<f32>().map_err(wrap)
    }
}

/// The PJRT CPU client plus an executable cache (compile once per artifact,
/// reuse across jobs — "one compiled executable per model variant").
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, std::sync::Arc<Executable>>,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir).map_err(RuntimeError::from)?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    /// Create from the default artifacts directory (`$R2F2_ARTIFACTS` or
    /// `./artifacts`).
    pub fn from_default_dir() -> Result<Runtime> {
        Self::new(&super::manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let info: ArtifactInfo = self
            .manifest
            .find(name)
            .ok_or_else(|| RuntimeError(format!("artifact `{name}` not in manifest")))?
            .clone();
        let path = self.manifest.path_of(&info);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| ctx(&format!("parsing {}", path.display()), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| ctx(&format!("compiling {name}"), e))?;
        let e = std::sync::Arc::new(Executable { name: name.to_string(), exe, outputs: info.outputs });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Literal helpers for the common dtypes.
    pub fn lit_f32(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    pub fn lit_i32(data: &[i32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// 2-D f32 literal (row-major).
    pub fn lit_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64]).map_err(wrap)
    }
}
