//! `artifacts/manifest.json` loading — the contract between the AOT
//! pipeline and the rust runtime (names, files, input shapes, output
//! arity).

use crate::config::json_mini::{parse_json, Json};
use std::path::{Path, PathBuf};

/// One AOT artifact as described by the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
    /// Input specs as (shape, dtype) in call order.
    pub inputs: Vec<(Vec<usize>, String)>,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
    pub note: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub heat_n: usize,
    pub swe_n: usize,
    pub elemwise_n: usize,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts` first)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (split out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = parse_json(text)?;
        let get_n = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("manifest missing `{k}`"))
        };
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or("manifest missing `artifacts`")?;
        let mut artifacts = Vec::new();
        for a in arts {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or("artifact missing inputs")?
                .iter()
                .map(|i| {
                    let shape = i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|s| s.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default();
                    let dtype =
                        i.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string();
                    (shape, dtype)
                })
                .collect();
            artifacts.push(ArtifactInfo {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or("artifact missing name")?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or("artifact missing file")?
                    .to_string(),
                inputs,
                outputs: a.get("outputs").and_then(Json::as_usize).unwrap_or(1),
                note: a.get("note").and_then(Json::as_str).unwrap_or("").to_string(),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            heat_n: get_n("heat_n")?,
            swe_n: get_n("swe_n")?,
            elemwise_n: get_n("elemwise_n")?,
            artifacts,
        })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn path_of(&self, a: &ArtifactInfo) -> PathBuf {
        self.dir.join(&a.file)
    }
}

/// Default artifacts directory: `$R2F2_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("R2F2_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "heat_n": 512, "swe_n": 16, "elemwise_n": 1024,
        "artifacts": [
            {"name": "heat_step_f32", "file": "heat_step_f32.hlo.txt",
             "inputs": [{"shape": [512], "dtype": "float32"},
                        {"shape": [1], "dtype": "float32"}],
             "outputs": 1, "note": "plain"}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.heat_n, 512);
        let a = m.find("heat_step_f32").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].0, vec![512]);
        assert_eq!(a.outputs, 1);
        assert_eq!(m.path_of(a), PathBuf::from("/tmp/arts/heat_step_f32.hlo.txt"));
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(Path::new("."), "{}").is_err());
        assert!(Manifest::parse(Path::new("."), "{\"heat_n\": 1}").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Soft test: only checks when `make artifacts` has run.
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find("heat_step_r2f2").is_some());
            assert!(m.find("r2f2_mul_k2").is_some());
        }
    }
}
