//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from rust. This is the only
//! bridge between Layer 3 and the compiled Layer-1/Layer-2 computations —
//! python never runs on this path.
//!
//! The PJRT client itself depends on the `xla` bindings, which are not
//! vendored in this offline environment; they are gated behind the `pjrt`
//! cargo feature (DESIGN.md §7). Without the feature, a stub with the same
//! API is compiled whose `Runtime::new` always fails, so benches, examples
//! and the cross-layer tests skip politely — exactly as they already do
//! when `make artifacts` has not been run.

pub mod error;
pub mod manifest;

pub use error::{Result, RuntimeError};
pub use manifest::{ArtifactInfo, Manifest};

/// Result of a heat run through PJRT (shared by the real executor and the
/// stub so the public API cannot drift between feature builds).
#[derive(Debug, Clone)]
pub struct HeatRunOutput {
    pub u: Vec<f32>,
    /// Total widen / narrow adjustment events (adaptive variants only).
    pub widen: i64,
    pub narrow: i64,
    /// Wall time of the stepped region.
    pub elapsed: std::time::Duration,
    pub steps: usize,
}

/// Result of an SWE run through PJRT (shared by the real executor and the
/// stub).
#[derive(Debug, Clone)]
pub struct SweRunOutput {
    /// Final padded (n+2)² height field, row-major.
    pub h: Vec<f32>,
    pub widen: i64,
    pub narrow: i64,
    pub elapsed: std::time::Duration,
    pub steps: usize,
}

// The real client needs the `xla` PJRT bindings, which this offline
// manifest cannot declare (they are not on crates.io and the build
// environment has no network). Turn the otherwise-opaque unresolved-crate
// error into instructions.
#[cfg(all(feature = "pjrt", not(feature = "pjrt_vendored")))]
compile_error!(
    "the `pjrt` feature needs the `xla` bindings: add them as a path dependency in \
rust/Cargo.toml (see DESIGN.md §7) and enable the `pjrt_vendored` feature as well"
);

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(feature = "pjrt")]
pub use client::{Executable, Runtime};
#[cfg(feature = "pjrt")]
pub use executor::{HeatRunner, SweRunner};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, HeatRunner, Literal, Runtime, SweRunner};
