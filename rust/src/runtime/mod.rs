//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from rust. This is the only
//! bridge between Layer 3 and the compiled Layer-1/Layer-2 computations —
//! python never runs on this path.

pub mod client;
pub mod executor;
pub mod manifest;

pub use client::{Executable, Runtime};
pub use executor::{HeatRunner, SweRunner};
pub use manifest::{ArtifactInfo, Manifest};
