//! Deterministic pseudo-random number generation.
//!
//! The environment has no `rand` crate, so experiments use this small,
//! well-known generator. Determinism matters: every figure/table harness
//! seeds its own generator so reruns are reproducible bit-for-bit.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a 64-bit stream.
///
/// Reference: Steele, Lea, Flood, "Fast Splittable Pseudorandom Number
/// Generators", OOPSLA 2014 (the `java.util.SplittableRandom` mixer).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (n > 0). Uses rejection-free multiply-shift;
    /// bias is < 2^-64, irrelevant for experiment sampling.
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Log-uniform in `[lo, hi)` (both > 0) — used for operand-range sweeps
    /// where the paper samples across many decades.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi > lo);
        (self.range_f64(lo.ln(), hi.ln())).exp()
    }

    /// Fork a statistically independent child stream (for worker threads).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_vector() {
        // First outputs for seed 0 from the reference implementation.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = SplitMix64::new(3);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn log_uniform_in_range() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.log_uniform(1e-4, 1e4);
            assert!(v >= 1e-4 && v < 1e4);
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = SplitMix64::new(1);
        let mut c = a.fork();
        let x: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(x, y);
    }
}
