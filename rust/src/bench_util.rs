//! Benchmark harness (the environment has no `criterion`).
//!
//! All `rust/benches/*` binaries (`harness = false`) use this: warmup,
//! automatic iteration-count calibration to a target measurement time,
//! and robust statistics (median / p95 over per-batch means). Output is a
//! plain aligned table so `cargo bench | tee bench_output.txt` captures the
//! paper-table reproductions as text.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Iterations per sample batch.
    pub batch: u64,
    /// Number of sample batches.
    pub samples: usize,
    /// Per-iteration statistics, in nanoseconds.
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// Iterations per second implied by the median.
    pub fn throughput(&self) -> f64 {
        1e9 / self.median_ns
    }
}

/// Measure `f`, auto-calibrating the batch size so each sample batch takes
/// ≳ 2 ms, then collecting `samples` batches.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, 30, Duration::from_millis(2), &mut f)
}

/// Fully parameterized variant: `samples` batches of auto-calibrated size
/// with at least `min_batch_time` per batch.
pub fn bench_with<F: FnMut()>(
    name: &str,
    samples: usize,
    min_batch_time: Duration,
    f: &mut F,
) -> BenchResult {
    // Warmup + calibration: double the batch until it takes long enough.
    let mut batch: u64 = 1;
    loop {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let el = t.elapsed();
        if el >= min_batch_time || batch >= 1 << 30 {
            break;
        }
        // Aim directly at the target once we have a signal.
        if el.as_nanos() > 1000 {
            let scale = (min_batch_time.as_nanos() as f64 / el.as_nanos() as f64).ceil();
            batch = (batch as f64 * scale.max(2.0)) as u64;
        } else {
            batch *= 16;
        }
    }

    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        per_iter.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mean = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    BenchResult {
        name: name.to_string(),
        batch,
        samples,
        mean_ns: mean,
        median_ns: percentile(&per_iter, 50.0),
        p95_ns: percentile(&per_iter, 95.0),
        min_ns: per_iter[0],
    }
}

/// Percentile of an ascending-sorted slice (nearest-rank).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Pretty-print nanoseconds with a sensible unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Render a result table (name, median, mean, p95, throughput).
pub fn print_results(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>14}",
        "case", "median", "mean", "p95", "ops/s"
    );
    for r in results {
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>14.0}",
            r.name,
            fmt_ns(r.median_ns),
            fmt_ns(r.mean_ns),
            fmt_ns(r.p95_ns),
            r.throughput()
        );
    }
}

/// Guard against the optimizer deleting a computation under test.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Parsed command line of a `harness = false` bench binary.
#[derive(Debug, Clone, Default)]
pub struct BenchArgs {
    /// `--smoke` (or `R2F2_BENCH_SMOKE` in the environment): cut workload
    /// sizes to CI scale.
    pub smoke: bool,
    /// `--out <path>` (canonical; `--json` is an accepted alias): override
    /// the bench's default artifact path. `None` keeps the default.
    pub out: Option<String>,
}

/// Strict argv parsing shared by the figure/ablation/hotpath benches.
///
/// Grammar: `--smoke`, `--out <path>` (alias `--json`), and cargo's own
/// `--bench` passthrough. Anything else exits 2 loudly — a typo must not
/// silently bench the wrong configuration (same convention as the
/// `r2f2` CLI's unknown-option handling).
pub fn parse_bench_args() -> BenchArgs {
    parse_bench_tokens(std::env::args().skip(1))
}

fn parse_bench_tokens<I: Iterator<Item = String>>(mut args: I) -> BenchArgs {
    let mut out = BenchArgs::default();
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => out.smoke = true,
            "--out" | "--json" => {
                out.out = Some(args.next().unwrap_or_else(|| {
                    eprintln!("{a} needs a path");
                    std::process::exit(2);
                }))
            }
            "--bench" => {} // cargo bench passes this through
            other => {
                eprintln!("unknown arg {other:?} (expected --smoke, --out <path>)");
                std::process::exit(2);
            }
        }
    }
    if std::env::var("R2F2_BENCH_SMOKE").is_ok() {
        out.smoke = true;
    }
    out
}

/// Variant for benches that print tables only and write no artifact:
/// `--out` is a usage error there, not a silently dropped flag.
pub fn parse_bench_args_no_artifact() -> BenchArgs {
    let args = parse_bench_args();
    if let Some(path) = &args.out {
        eprintln!("this bench emits no artifact; --out {path} is not supported");
        std::process::exit(2);
    }
    args
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let r = bench_with("noop-ish", 5, Duration::from_micros(200), &mut || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.batch >= 1);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.p95_ns);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.1e9), "3.10 s");
    }

    #[test]
    fn bench_args_happy_path() {
        let toks = ["--smoke", "--out", "x.csv", "--bench"];
        let a = parse_bench_tokens(toks.iter().map(|s| s.to_string()));
        assert!(a.smoke);
        assert_eq!(a.out.as_deref(), Some("x.csv"));

        let toks = ["--json", "y.json"];
        let a = parse_bench_tokens(toks.iter().map(|s| s.to_string()));
        assert_eq!(a.out.as_deref(), Some("y.json"), "--json stays an alias for --out");
    }

    #[test]
    fn slow_batches_do_not_explode() {
        // A deliberately slow body must settle on a small batch.
        let r = bench_with("slow", 3, Duration::from_micros(100), &mut || {
            std::thread::sleep(Duration::from_micros(60));
        });
        assert!(r.batch <= 4);
    }
}
