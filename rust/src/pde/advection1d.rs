//! 1D advective transport on a periodic domain, first-order upwind — the
//! scenario the heat stencil never reaches: transport instead of diffusion.
//!
//! Linear form (`∂u/∂t + a ∂u/∂x = 0`, `a > 0`):
//!
//! `u'ᵢ = uᵢ − (c·uᵢ − c·uᵢ₋₁)`, `c = a·Δt/Δx` (stable for `c ≤ 1`),
//! with both `c·u` products routed through the [`Arith`] backend — **one
//! backend multiplication per node per step** (the `c·uⱼ` product is shared
//! between its two uses, like the heat stencil's `r·uⱼ`). The canonical
//! sequence computes the whole product row first (`pⱼ = c ⊗ uⱼ` in index
//! order — one [`Arith::mul_batch`] on the batched path), then the
//! mode-gated combine.
//!
//! Optional **Burgers nonlinearity** (`∂u/∂t + ∂(u²/2)/∂x = 0`, `u > 0`):
//! the flux products multiply the state *by itself* —
//! `qⱼ = uⱼ ⊗ uⱼ` ([`Arith::mul_pairs`]), then `pⱼ = k ⊗ qⱼ` with
//! `k = Δt/(2Δx)` — two backend multiplications per node per step, and an
//! operand distribution that slides with the forming shock. This is the
//! regime that stresses R2F2's sliding-window exponent adjustment: the
//! multiplier sees `u²`, not `coefficient × u`.
//!
//! Why precision-interesting: upwind transport *decays* (numerical
//! diffusion damps every non-constant mode), so one run walks the operand
//! range from hundreds down through the flush threshold — by the tail,
//! every `c·u` product underflows the narrow formats and the transport
//! freezes, which is exactly the stall the adaptive scheduler narrows on.

use super::init::HeatInit;
use super::scenario::{self, RunStats, Sim};
use super::{Arith, Ctx, QuantMode, RangeEvents};
use crate::r2f2core::Stats;

/// Advection run parameters.
#[derive(Debug, Clone)]
pub struct AdvectionParams {
    /// Number of cells (periodic — no duplicated endpoint).
    pub n: usize,
    /// Advection velocity `a > 0` (ignored by the Burgers flux, where the
    /// state itself is the velocity).
    pub velocity: f64,
    /// Domain length L (Δx = L / n).
    pub length: f64,
    /// Time step.
    pub dt: f64,
    /// Number of time steps.
    pub steps: usize,
    /// Evolve Burgers' equation (`f = u²/2`) instead of linear transport.
    pub burgers: bool,
    /// Initial condition, sampled periodically (use whole `cycles`).
    pub init: HeatInit,
    /// Constant added to the initial profile (Burgers runs keep `u > 0`).
    pub offset: f64,
    /// Keep a state snapshot every `snapshot_every` steps (0 = none).
    pub snapshot_every: usize,
}

impl Default for AdvectionParams {
    fn default() -> AdvectionParams {
        // c = a·Δt/Δx = 0.4; amplitude 400 spans the same octaves as the
        // heat study's sine and saturates E4M3 (max finite 240) on encode.
        AdvectionParams {
            n: 256,
            velocity: 1.0,
            length: 1.0,
            dt: 0.4 / 256.0,
            steps: 1000,
            burgers: false,
            init: HeatInit::Sin { amplitude: 400.0, cycles: 2.0 },
            offset: 0.0,
            snapshot_every: 0,
        }
    }
}

impl AdvectionParams {
    /// A positive Burgers setup: `u ∈ [20, 100]`, steepening into a shock.
    pub fn burgers_default() -> AdvectionParams {
        AdvectionParams {
            burgers: true,
            init: HeatInit::Sin { amplitude: 40.0, cycles: 2.0 },
            offset: 60.0,
            // CFL on max |u| = 100: 100·dt/dx = 0.8.
            dt: 0.8 / (100.0 * 256.0),
            ..AdvectionParams::default()
        }
    }

    /// The CFL number of the *linear* scheme, `c = a·Δt/Δx`.
    pub fn cfl(&self) -> f64 {
        self.velocity * self.dt * self.n as f64 / self.length
    }

    /// Backend multiplications per run: 1 per cell per step (linear) or 2
    /// (Burgers).
    pub fn expected_muls(&self) -> u64 {
        let per = if self.burgers { 2 } else { 1 };
        per * self.n as u64 * self.steps as u64
    }
}

/// Result of an advection run.
#[derive(Debug, Clone)]
pub struct AdvectionResult {
    /// Final field.
    pub u: Vec<f64>,
    /// `(step, field)` snapshots if requested.
    pub snapshots: Vec<(usize, Vec<f64>)>,
    /// Multiplications issued.
    pub muls: u64,
    /// Backend name.
    pub backend: String,
    /// R2F2 adjustment statistics, when applicable.
    pub r2f2_stats: Option<Stats>,
    /// Fixed-format range events, when applicable.
    pub range_events: Option<RangeEvents>,
}

/// The advection scenario state.
#[derive(Debug)]
pub struct AdvectionSim {
    pub(super) n: usize,
    /// `c` (linear) or `Δt/(2Δx)` (Burgers) — the constant operand.
    pub(super) coeff: f64,
    pub(super) burgers: bool,
    pub(super) u: Vec<f64>,
    pub(super) next: Vec<f64>,
    /// Product row `pⱼ` scratch.
    pub(super) prod: Vec<f64>,
    /// Burgers `(uⱼ, uⱼ)` pair scratch.
    pub(super) pairs: Vec<(f64, f64)>,
    /// Burgers `uⱼ²` scratch.
    pub(super) sq: Vec<f64>,
}

impl AdvectionSim {
    pub fn new(params: &AdvectionParams) -> AdvectionSim {
        let n = params.n;
        assert!(n >= 3, "need at least three cells");
        // Periodic sampling: x = i/n · L (no duplicated endpoint).
        let u: Vec<f64> = (0..n)
            .map(|i| {
                params.offset + params.init.at(i as f64 / n as f64 * params.length, params.length)
            })
            .collect();
        let dx = params.length / n as f64;
        let coeff = if params.burgers {
            let umax = u.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()));
            let cfl = umax * params.dt / dx;
            assert!(cfl <= 1.0 + 1e-12, "upwind scheme unstable: c = {cfl}");
            assert!(u.iter().all(|&v| v > 0.0), "Burgers upwind needs u > 0");
            0.5 * params.dt / dx
        } else {
            let c = params.cfl();
            assert!(c > 0.0 && c <= 1.0 + 1e-12, "upwind scheme unstable: c = {c}");
            c
        };
        let next = u.clone();
        AdvectionSim {
            n,
            coeff,
            burgers: params.burgers,
            u,
            next,
            prod: vec![0.0; n],
            pairs: Vec::new(),
            sq: vec![0.0; n],
        }
    }

    /// Consume the simulation into its final field.
    pub fn into_field(self) -> Vec<f64> {
        self.u
    }

    /// One upwind step: fill the product row `pⱼ` (through the backend),
    /// then the mode-gated combine `u'ᵢ = uᵢ − (pᵢ − pᵢ₋₁)` with periodic
    /// wrap. The batched path issues the identical multiplication stream
    /// through `mul_pairs`/`mul_batch` (index order — the §8 contract).
    fn step(&mut self, ctx: &mut Ctx<'_>, batched: bool) {
        let n = self.n;
        if self.burgers {
            // qⱼ = uⱼ ⊗ uⱼ, then pⱼ = k ⊗ qⱼ — both rows in index order.
            if batched {
                self.pairs.clear();
                self.pairs.extend(self.u.iter().map(|&v| (v, v)));
                ctx.mul_pairs(&mut self.sq, &self.pairs);
                ctx.mul_batch(&mut self.prod, self.coeff, &self.sq);
            } else {
                for j in 0..n {
                    self.sq[j] = ctx.mul(self.u[j], self.u[j]);
                }
                for j in 0..n {
                    self.prod[j] = ctx.mul(self.coeff, self.sq[j]);
                }
            }
        } else if batched {
            ctx.mul_batch(&mut self.prod, self.coeff, &self.u);
        } else {
            for j in 0..n {
                self.prod[j] = ctx.mul(self.coeff, self.u[j]);
            }
        }
        for i in 0..n {
            let im1 = if i == 0 { n - 1 } else { i - 1 };
            let d = ctx.sub(self.prod[i], self.prod[im1]);
            let unew = ctx.sub(self.u[i], d);
            self.next[i] = ctx.quant(unew);
        }
        std::mem::swap(&mut self.u, &mut self.next);
    }
}

impl Sim for AdvectionSim {
    fn scenario(&self) -> &'static str {
        "advection1d"
    }

    fn quant_state(&mut self, ctx: &mut Ctx<'_>) {
        for v in self.u.iter_mut() {
            *v = ctx.quant(*v);
        }
    }

    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    ) {
        for s in 0..steps {
            self.step(ctx, batched);
            let global = step_base + s + 1;
            if snapshot_every != 0 && global % snapshot_every == 0 {
                snaps.push((global, self.u.clone()));
            }
        }
    }

    fn save(&self) -> Vec<Vec<f64>> {
        vec![self.u.clone()]
    }

    fn restore(&mut self, saved: &[Vec<f64>]) {
        self.u.copy_from_slice(&saved[0]);
    }

    fn telemetry(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&self.u);
    }

    fn telemetry_len(&self) -> usize {
        self.n
    }

    fn primary_field(&self) -> Vec<f64> {
        self.u.clone()
    }
}

pub(super) fn finish(sim: AdvectionSim, stats: RunStats) -> AdvectionResult {
    AdvectionResult {
        u: sim.into_field(),
        snapshots: stats.snapshots,
        muls: stats.muls,
        backend: stats.backend,
        r2f2_stats: stats.r2f2_stats,
        range_events: stats.range_events,
    }
}

/// Run under the backend's batched engine; bit-identical to [`run_scalar`].
pub fn run(params: &AdvectionParams, be: &mut dyn Arith, mode: QuantMode) -> AdvectionResult {
    let mut sim = AdvectionSim::new(params);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, true);
    finish(sim, stats)
}

/// The per-multiplication scalar reference of [`run`].
pub fn run_scalar(
    params: &AdvectionParams,
    be: &mut dyn Arith,
    mode: QuantMode,
) -> AdvectionResult {
    let mut sim = AdvectionSim::new(params);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, false);
    finish(sim, stats)
}

/// Adaptive-precision run through the generic epoch driver.
pub fn run_adaptive(
    params: &AdvectionParams,
    sched: &mut super::AdaptiveArith,
    mode: QuantMode,
) -> AdvectionResult {
    let mut sim = AdvectionSim::new(params);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        true,
    );
    finish(sim, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{rel_l2, F64Arith, FixedArith, R2f2Arith};
    use crate::r2f2core::R2f2Config;
    use crate::softfloat::FpFormat;

    fn small() -> AdvectionParams {
        // dt rescaled so the 64-cell grid keeps the default CFL c = 0.4.
        AdvectionParams { n: 64, dt: 0.4 / 64.0, steps: 200, ..AdvectionParams::default() }
    }

    #[test]
    fn mass_is_conserved_in_f64() {
        // Conservative upwind on a periodic domain preserves the mean.
        let p = small();
        let sum0: f64 = AdvectionSim::new(&p).primary_field().iter().sum();
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let sum1: f64 = res.u.iter().sum();
        assert!((sum1 - sum0).abs() < 1e-7, "mass drift {}", sum1 - sum0);
    }

    #[test]
    fn max_principle_holds_in_f64() {
        // Upwind with 0 ≤ c ≤ 1 is monotone: no new extrema.
        let p = small();
        let u0 = AdvectionSim::new(&p).primary_field();
        let (lo, hi) = u0.iter().fold((f64::MAX, f64::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        assert!(res.u.iter().all(|&v| v >= lo - 1e-9 && v <= hi + 1e-9));
    }

    #[test]
    fn transport_moves_the_profile_and_diffusion_damps_it() {
        let p = small();
        let u0 = AdvectionSim::new(&p).primary_field();
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        // The profile changed (it moved)...
        assert!(rel_l2(&res.u, &u0) > 0.1);
        // ...and first-order upwind damped the mode (|g| < 1).
        let amp0 = u0.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()));
        let amp1 = res.u.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()));
        assert!(amp1 < amp0, "no decay: {amp1} vs {amp0}");
    }

    #[test]
    fn mul_count_matches_expectation() {
        let p = small();
        assert_eq!(run(&p, &mut F64Arith, QuantMode::MulOnly).muls, p.expected_muls());
        let b = AdvectionParams { n: 64, steps: 50, ..AdvectionParams::burgers_default() };
        assert_eq!(run(&b, &mut F64Arith, QuantMode::MulOnly).muls, b.expected_muls());
    }

    #[test]
    fn batched_matches_scalar_bitwise() {
        // §8 contract for both flux forms, both modes, fixed + R2F2.
        let burgers = AdvectionParams { n: 64, steps: 60, ..AdvectionParams::burgers_default() };
        for p in [small(), burgers] {
            for mode in [QuantMode::MulOnly, QuantMode::Full] {
                let mut a = FixedArith::new(FpFormat::E5M10);
                let mut b = FixedArith::new(FpFormat::E5M10);
                let s = run_scalar(&p, &mut a, mode);
                let g = run(&p, &mut b, mode);
                assert_eq!(s.muls, g.muls, "{mode:?}");
                assert_eq!(s.range_events, g.range_events, "{mode:?}");
                for i in 0..p.n {
                    assert_eq!(s.u[i].to_bits(), g.u[i].to_bits(), "{mode:?} node {i}");
                }
                let mut a = R2f2Arith::new(R2f2Config::C16_393);
                let mut b = R2f2Arith::new(R2f2Config::C16_393);
                let s = run_scalar(&p, &mut a, mode);
                let g = run(&p, &mut b, mode);
                assert_eq!(s.r2f2_stats, g.r2f2_stats, "{mode:?}");
                for i in 0..p.n {
                    assert_eq!(s.u[i].to_bits(), g.u[i].to_bits(), "r2f2 {mode:?} node {i}");
                }
            }
        }
    }

    #[test]
    fn e5m10_mulonly_tracks_f64() {
        let p = small();
        let reference = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let mut half = FixedArith::new(FpFormat::E5M10);
        let res = run(&p, &mut half, QuantMode::MulOnly);
        assert!(rel_l2(&res.u, &reference.u) < 1e-1);
    }

    #[test]
    fn e4m3_saturates_on_the_amplitude() {
        // Amplitude 400 > E4M3's max finite: the narrow format must report
        // overflow pressure — the adaptive ladder's widen trigger.
        let p = AdvectionParams { n: 64, steps: 4, ..AdvectionParams::default() };
        let mut narrow = FixedArith::new(FpFormat::E4M3);
        let res = run(&p, &mut narrow, QuantMode::MulOnly);
        assert!(res.range_events.unwrap().overflows > 0);
    }

    #[test]
    fn burgers_steepens_gradients() {
        // Nonlinear transport sharpens the leading edge: the maximum
        // cell-to-cell jump grows before shock dissipation takes over.
        let p = AdvectionParams { n: 128, steps: 120, ..AdvectionParams::burgers_default() };
        let u0 = AdvectionSim::new(&p).primary_field();
        let jump = |u: &[f64]| {
            (0..u.len())
                .map(|i| (u[(i + 1) % u.len()] - u[i]).abs())
                .fold(0.0f64, f64::max)
        };
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        assert!(jump(&res.u) > 1.5 * jump(&u0), "no steepening: {} vs {}", jump(&res.u), jump(&u0));
    }

    #[test]
    fn snapshots_collected() {
        let mut p = small();
        p.snapshot_every = 50;
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        assert_eq!(res.snapshots.len(), 4);
        assert_eq!(res.snapshots[0].0, 50);
    }

    #[test]
    #[should_panic(expected = "unstable")]
    fn instability_rejected() {
        let mut p = small();
        p.dt *= 4.0; // c = 1.6
        run(&p, &mut F64Arith, QuantMode::MulOnly);
    }
}
