//! 2D wave equation `∂²u/∂t² + γ ∂u/∂t = c²∇²u` on the unit square,
//! leapfrog (three-level) time stepping, Dirichlet zero walls — the
//! *oscillating* scenario: where heat decays monotonically and advection
//! translates, the wave field swings through zero every half period.
//!
//! Discretization with `C = c·Δt/Δx` (stable for `C ≤ 1/√2` in 2D) and the
//! per-step damping `k = γ·Δt/2`:
//!
//! ```text
//! u'ᵢⱼ = d₁·uᵢⱼ − d₀·u⁻ᵢⱼ + c₂·lapᵢⱼ
//! d₁ = 2/(1+k),  d₀ = (1−k)/(1+k),  c₂ = C²/(1+k)
//! lapᵢⱼ = uᵢ₋₁ⱼ + uᵢ₊₁ⱼ + uᵢⱼ₋₁ + uᵢⱼ₊₁ − 4uᵢⱼ
//! ```
//!
//! The **three coefficient products** (`d₁·u`, `d₀·u⁻`, `c₂·lap`) route
//! through the [`Arith`] backend — 3 multiplications per interior node per
//! step; the Laplacian gather itself is index arithmetic on the host, like
//! the shallow-water scheme's non-substituted terms. The canonical
//! sequence evaluates each product row in index order (three
//! [`Arith::mul_batch`] rows per grid row on the batched path), then the
//! mode-gated combine `(d₁u − d₀u⁻) + c₂·lap` and storage quantization.
//!
//! Why precision-interesting: the state is **signed and oscillating**, so
//! the range histogram's `negatives` population is half the samples and
//! the combine is a genuine cancellation (`d₁u ≈ d₀u⁻` near the turning
//! points) — the paths a decaying positive field never exercises. The
//! default amplitude 300 saturates `E4M3` (max finite 240) on encode, and
//! with damping the oscillation collapses through the flush threshold to
//! exact zeros — the stall the adaptive ladder narrows on.

use super::scenario::{self, RunStats, Sim};
use super::{Arith, Ctx, QuantMode, RangeEvents};
use crate::r2f2core::Stats;

/// Wave-equation run parameters.
#[derive(Debug, Clone)]
pub struct WaveParams {
    /// Grid side (n × n nodes including the Dirichlet boundary ring).
    pub n: usize,
    /// Wave speed c.
    pub c: f64,
    /// Domain side L (Δx = L / (n−1)).
    pub length: f64,
    /// Time step.
    pub dt: f64,
    /// Per-step damping `k = γ·Δt/2` (0 = undamped).
    pub damping: f64,
    /// Number of time steps.
    pub steps: usize,
    /// Standing-mode initial amplitude `u₀ = A·sin(πx/L)·sin(πy/L)`.
    pub amplitude: f64,
    /// Keep a state snapshot every `snapshot_every` steps (0 = none).
    pub snapshot_every: usize,
}

impl Default for WaveParams {
    fn default() -> WaveParams {
        // C = c·Δt/Δx = 0.5 (C² = 0.25 ≤ 1/2); amplitude 300 saturates
        // E4M3 while E5M10 holds the whole oscillation.
        WaveParams {
            n: 33,
            c: 1.0,
            length: 1.0,
            dt: 0.5 / 32.0,
            damping: 0.0,
            steps: 200,
            amplitude: 300.0,
            snapshot_every: 0,
        }
    }
}

impl WaveParams {
    /// The Courant number `C = c·Δt/Δx`.
    pub fn courant(&self) -> f64 {
        let dx = self.length / (self.n - 1) as f64;
        self.c * self.dt / dx
    }

    /// Backend multiplications per run (3 per interior node per step).
    pub fn expected_muls(&self) -> u64 {
        3 * ((self.n - 2) * (self.n - 2)) as u64 * self.steps as u64
    }
}

/// Result of a wave run.
#[derive(Debug, Clone)]
pub struct WaveResult {
    /// Final displacement field (n × n, row-major, boundary included).
    pub u: Vec<f64>,
    /// `(step, field)` snapshots if requested.
    pub snapshots: Vec<(usize, Vec<f64>)>,
    /// Multiplications issued.
    pub muls: u64,
    /// Backend name.
    pub backend: String,
    /// R2F2 adjustment statistics, when applicable.
    pub r2f2_stats: Option<Stats>,
    /// Fixed-format range events, when applicable.
    pub range_events: Option<RangeEvents>,
}

/// The wave scenario state: current and previous displacement fields plus
/// per-row product scratch.
#[derive(Debug)]
pub struct WaveSim {
    pub(super) n: usize,
    pub(super) d1: f64,
    pub(super) d0: f64,
    pub(super) c2: f64,
    pub(super) u: Vec<f64>,
    pub(super) uold: Vec<f64>,
    pub(super) next: Vec<f64>,
    /// Per-row scratch: current-state row, previous-state row, Laplacian
    /// row, and the three product rows.
    pub(super) row_u: Vec<f64>,
    pub(super) row_old: Vec<f64>,
    pub(super) row_lap: Vec<f64>,
    pub(super) p1: Vec<f64>,
    pub(super) p0: Vec<f64>,
    pub(super) p2: Vec<f64>,
}

impl WaveSim {
    pub fn new(params: &WaveParams) -> WaveSim {
        let n = params.n;
        assert!(n >= 3, "need at least one interior node");
        let cn = params.courant();
        assert!(
            cn * cn <= 0.5 + 1e-12,
            "leapfrog scheme unstable: C = {cn} (need C^2 <= 1/2 in 2D)"
        );
        let k = params.damping;
        assert!((0.0..1.0).contains(&k), "damping k must be in [0, 1)");
        let u: Vec<f64> = (0..n * n)
            .map(|id| {
                let (i, j) = (id / n, id % n);
                if i == 0 || i == n - 1 || j == 0 || j == n - 1 {
                    // Exact Dirichlet zeros (f64's sin(π) is only ~1e-16).
                    return 0.0;
                }
                let sx = (std::f64::consts::PI * i as f64 / (n - 1) as f64).sin();
                let sy = (std::f64::consts::PI * j as f64 / (n - 1) as f64).sin();
                params.amplitude * sx * sy
            })
            .collect();
        // Zero initial velocity: the first leapfrog step uses u⁻ = u⁰.
        let uold = u.clone();
        let next = u.clone();
        let interior = n - 2;
        WaveSim {
            n,
            d1: 2.0 / (1.0 + k),
            d0: (1.0 - k) / (1.0 + k),
            c2: cn * cn / (1.0 + k),
            u,
            uold,
            next,
            row_u: vec![0.0; interior],
            row_old: vec![0.0; interior],
            row_lap: vec![0.0; interior],
            p1: vec![0.0; interior],
            p0: vec![0.0; interior],
            p2: vec![0.0; interior],
        }
    }

    /// Consume the simulation into its final field.
    pub fn into_field(self) -> Vec<f64> {
        self.u
    }

    /// One leapfrog step. Per grid row the three coefficient-product rows
    /// are evaluated in index order — `d₁·u`, then `d₀·u⁻`, then `c₂·lap` —
    /// through three [`Ctx::mul_batch`] calls (batched) or the equivalent
    /// scalar `mul` loops; the combine and storage quantization follow
    /// per node. Boundary nodes stay at their Dirichlet zeros.
    fn step(&mut self, ctx: &mut Ctx<'_>, batched: bool) {
        let n = self.n;
        for i in 1..n - 1 {
            let base = i * n;
            for j in 1..n - 1 {
                let id = base + j;
                self.row_u[j - 1] = self.u[id];
                self.row_old[j - 1] = self.uold[id];
                self.row_lap[j - 1] = self.u[id - n] + self.u[id + n] + self.u[id - 1]
                    + self.u[id + 1]
                    - 4.0 * self.u[id];
            }
            if batched {
                ctx.mul_batch(&mut self.p1, self.d1, &self.row_u);
                ctx.mul_batch(&mut self.p0, self.d0, &self.row_old);
                ctx.mul_batch(&mut self.p2, self.c2, &self.row_lap);
            } else {
                for j in 0..n - 2 {
                    self.p1[j] = ctx.mul(self.d1, self.row_u[j]);
                }
                for j in 0..n - 2 {
                    self.p0[j] = ctx.mul(self.d0, self.row_old[j]);
                }
                for j in 0..n - 2 {
                    self.p2[j] = ctx.mul(self.c2, self.row_lap[j]);
                }
            }
            for j in 1..n - 1 {
                let id = base + j;
                let s = ctx.sub(self.p1[j - 1], self.p0[j - 1]);
                let unew = ctx.add(s, self.p2[j - 1]);
                self.next[id] = ctx.quant(unew);
            }
        }
        // Dirichlet walls stay put.
        for j in 0..n {
            self.next[j] = self.u[j];
            self.next[(n - 1) * n + j] = self.u[(n - 1) * n + j];
        }
        for i in 1..n - 1 {
            self.next[i * n] = self.u[i * n];
            self.next[i * n + n - 1] = self.u[i * n + n - 1];
        }
        std::mem::swap(&mut self.uold, &mut self.u);
        std::mem::swap(&mut self.u, &mut self.next);
    }
}

impl Sim for WaveSim {
    fn scenario(&self) -> &'static str {
        "wave2d"
    }

    fn quant_state(&mut self, ctx: &mut Ctx<'_>) {
        for v in self.u.iter_mut() {
            *v = ctx.quant(*v);
        }
        for v in self.uold.iter_mut() {
            *v = ctx.quant(*v);
        }
    }

    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    ) {
        for s in 0..steps {
            self.step(ctx, batched);
            let global = step_base + s + 1;
            if snapshot_every != 0 && global % snapshot_every == 0 {
                snaps.push((global, self.u.clone()));
            }
        }
    }

    fn save(&self) -> Vec<Vec<f64>> {
        vec![self.u.clone(), self.uold.clone()]
    }

    fn restore(&mut self, saved: &[Vec<f64>]) {
        self.u.copy_from_slice(&saved[0]);
        self.uold.copy_from_slice(&saved[1]);
    }

    /// Both leapfrog levels are streamed: a stall verdict then requires the
    /// full three-level state to be bit-frozen, so an oscillation aliasing
    /// with the epoch length cannot masquerade as one.
    fn telemetry(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&self.u);
        out.extend_from_slice(&self.uold);
    }

    fn telemetry_len(&self) -> usize {
        2 * self.n * self.n
    }

    fn primary_field(&self) -> Vec<f64> {
        self.u.clone()
    }
}

pub(super) fn finish(sim: WaveSim, stats: RunStats) -> WaveResult {
    WaveResult {
        u: sim.into_field(),
        snapshots: stats.snapshots,
        muls: stats.muls,
        backend: stats.backend,
        r2f2_stats: stats.r2f2_stats,
        range_events: stats.range_events,
    }
}

/// Run under the backend's batched engine; bit-identical to [`run_scalar`].
pub fn run(params: &WaveParams, be: &mut dyn Arith, mode: QuantMode) -> WaveResult {
    let mut sim = WaveSim::new(params);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, true);
    finish(sim, stats)
}

/// The per-multiplication scalar reference of [`run`].
pub fn run_scalar(params: &WaveParams, be: &mut dyn Arith, mode: QuantMode) -> WaveResult {
    let mut sim = WaveSim::new(params);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, false);
    finish(sim, stats)
}

/// Adaptive-precision run through the generic epoch driver.
pub fn run_adaptive(
    params: &WaveParams,
    sched: &mut super::AdaptiveArith,
    mode: QuantMode,
) -> WaveResult {
    let mut sim = WaveSim::new(params);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        true,
    );
    finish(sim, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{rel_l2, F64Arith, FixedArith, R2f2Arith};
    use crate::r2f2core::R2f2Config;
    use crate::softfloat::FpFormat;

    fn small() -> WaveParams {
        WaveParams { n: 17, dt: 0.5 / 16.0, steps: 120, ..WaveParams::default() }
    }

    fn amplitude(u: &[f64]) -> f64 {
        u.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()))
    }

    #[test]
    fn undamped_oscillation_conserves_amplitude_and_signs() {
        // The standing mode swings; without damping the envelope holds to
        // discretization accuracy and the field goes genuinely negative.
        let mut p = small();
        p.steps = 400;
        p.snapshot_every = 10;
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let peak = res.snapshots.iter().map(|(_, u)| amplitude(u)).fold(0.0f64, f64::max);
        assert!(peak > 0.9 * p.amplitude && peak < 1.05 * p.amplitude, "peak {peak}");
        let min = res
            .snapshots
            .iter()
            .flat_map(|(_, u)| u.iter())
            .cloned()
            .fold(f64::MAX, f64::min);
        assert!(min < -0.5 * p.amplitude, "no negative swing: {min}");
    }

    #[test]
    fn boundaries_stay_clamped() {
        let p = small();
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let n = p.n;
        for j in 0..n {
            assert_eq!(res.u[j], 0.0);
            assert_eq!(res.u[(n - 1) * n + j], 0.0);
            assert_eq!(res.u[j * n], 0.0);
            assert_eq!(res.u[j * n + n - 1], 0.0);
        }
    }

    #[test]
    fn damping_decays_the_envelope() {
        let p = WaveParams { damping: 0.04, steps: 300, ..small() };
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        assert!(
            amplitude(&res.u) < 0.01 * p.amplitude,
            "damped amplitude {}",
            amplitude(&res.u)
        );
    }

    #[test]
    fn mul_count_matches_expectation() {
        let p = small();
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        assert_eq!(res.muls, p.expected_muls());
    }

    #[test]
    fn batched_matches_scalar_bitwise() {
        // §8 contract: values, counters and R2F2 stats per engine path.
        let p = WaveParams { steps: 60, ..small() };
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            let mut a = FixedArith::new(FpFormat::E5M10);
            let mut b = FixedArith::new(FpFormat::E5M10);
            let s = run_scalar(&p, &mut a, mode);
            let g = run(&p, &mut b, mode);
            assert_eq!(s.muls, g.muls, "{mode:?}");
            assert_eq!(s.range_events, g.range_events, "{mode:?}");
            for i in 0..s.u.len() {
                assert_eq!(s.u[i].to_bits(), g.u[i].to_bits(), "{mode:?} node {i}");
            }
            let mut a = R2f2Arith::new(R2f2Config::C16_393);
            let mut b = R2f2Arith::new(R2f2Config::C16_393);
            let s = run_scalar(&p, &mut a, mode);
            let g = run(&p, &mut b, mode);
            assert_eq!(s.r2f2_stats, g.r2f2_stats, "{mode:?}");
            for i in 0..s.u.len() {
                assert_eq!(s.u[i].to_bits(), g.u[i].to_bits(), "r2f2 {mode:?} node {i}");
            }
        }
    }

    #[test]
    fn e5m10_mulonly_tracks_f64() {
        let p = small();
        let reference = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let mut half = FixedArith::new(FpFormat::E5M10);
        let res = run(&p, &mut half, QuantMode::MulOnly);
        assert!(rel_l2(&res.u, &reference.u) < 3e-1, "{}", rel_l2(&res.u, &reference.u));
    }

    #[test]
    fn e4m3_saturates_on_the_amplitude() {
        // Amplitude 300 > E4M3's max finite: overflow pressure — the
        // adaptive ladder's widen trigger.
        let p = WaveParams { steps: 4, ..small() };
        let mut narrow = FixedArith::new(FpFormat::E4M3);
        let res = run(&p, &mut narrow, QuantMode::MulOnly);
        assert!(res.range_events.unwrap().overflows > 0);
    }

    #[test]
    fn signed_state_populates_negative_telemetry() {
        // The histogram path the decaying-positive scenarios never hit:
        // roughly half the sampled magnitudes carry a negative sign.
        // ~2/3 of a half period: the standing mode has swung negative.
        let p = WaveParams { steps: 30, ..small() };
        let mut sim = WaveSim::new(&p);
        let _ = scenario::run_sim(&mut sim, &mut F64Arith, QuantMode::MulOnly, p.steps, 0, true);
        let mut tele = Vec::new();
        sim.telemetry(&mut tele);
        let mut h = crate::analysis::Log2Histogram::new();
        for v in &tele {
            h.record(*v);
        }
        assert!(h.negatives > h.total / 8, "negatives {} of {}", h.negatives, h.total);
        assert_eq!(h.nonfinite, 0);
    }

    #[test]
    fn snapshots_collected() {
        let mut p = small();
        p.snapshot_every = 40;
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        assert_eq!(res.snapshots.len(), 3);
    }

    #[test]
    #[should_panic(expected = "unstable")]
    fn instability_rejected() {
        let mut p = small();
        p.dt *= 2.0; // C = 1.0, C² = 1 > 1/2
        run(&p, &mut F64Arith, QuantMode::MulOnly);
    }
}
