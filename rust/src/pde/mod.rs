//! PDE case studies (§2, §5.3): the 1D heat equation and the 2D shallow
//! water equations — plus the scenario-registry additions, 1D upwind
//! advection/Burgers and the 2D damped wave equation — each runnable under
//! interchangeable arithmetic backends so a single solver implementation
//! serves every precision experiment. The solvers implement the
//! [`scenario::Sim`] trait and share the generic run/adaptive drivers and
//! the [`scenario::SCENARIOS`] registry (DESIGN.md §11).
//!
//! The paper's methodology replaces *multiplications* with the unit under
//! test (f64 / f32 / fixed `ExMy` / R2F2), converting operands in and the
//! result back out (§5.2). [`Arith`] is that pluggable multiplier;
//! [`QuantMode`] selects whether only multiplications are quantized
//! (`MulOnly`, the paper's R2F2 case studies) or the whole state and the
//! additions too (`Full`, the paper's "simulation using half precision"
//! baseline of Fig. 1).

pub mod adaptive;
pub mod advection1d;
pub mod decomp;
pub mod heat1d;
pub mod init;
pub mod scenario;
pub mod swe2d;
pub mod wave2d;

pub use adaptive::{AdaptiveArith, AdaptivePolicy, AdaptiveReport, Decision, SwitchEvent};
pub use scenario::{ScenarioRun, ScenarioSize, ScenarioSpec, Sim, SCENARIOS};

use crate::r2f2core::{EncSlot, R2f2Config, R2f2Multiplier, Stats};
use crate::softfloat::batch::{mul_batch_packed, mul_pairs_packed};
use crate::softfloat::packed as pk;
use crate::softfloat::swar as sw;
use crate::softfloat::{
    add_f, decode, encode, mul as sf_mul, mul_f, quantize, quantize_flagged, Flags, Fp, FpFormat,
    Rounder, SwarFormat,
};

/// How much of the solver arithmetic routes through the backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    /// Only multiplications are quantized; additions and the stored state
    /// stay in the f64 carrier (the paper's R2F2 deployment, §5.3).
    MulOnly,
    /// Multiplications, additions and state storage all go through the
    /// format (a true low-precision simulation — Fig. 1's baseline).
    Full,
}

/// Which batched-engine implementation a backend runs (DESIGN.md §9, §14).
///
/// Every engine is **bit-identical** to the scalar specification — the
/// selector exists so the perf trajectory keeps comparing them
/// (`benches/hotpath.rs`) and so `rust/tests/packed_vs_carrier.rs` and
/// `rust/tests/swar_vs_packed.rs` can hold them against each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchEngine {
    /// The PR-1 engine: hoisted encodes and dispatch, but every product
    /// still round-trips through the `f64` carrier (`Fp` structs, `u128`
    /// datapath). Frozen as the perf baseline.
    Carrier,
    /// The packed-domain engine: state and products stay in `u32` words
    /// (`softfloat::packed`), 64-bit datapaths, direct-bits transcoding,
    /// and `QuantMode::Full` state persists packed across timesteps.
    #[default]
    Packed,
    /// The SWAR tier of the packed engine (DESIGN.md §14): formats of
    /// ≤ 16 total bits process two elements per `u64` through the
    /// lane-paired kernels (`softfloat::swar`), with a scalar-word tail
    /// for odd counts. Formats wider than a lane fall back to the packed
    /// path; backends without lane kernels (R2F2's truncated datapath)
    /// treat `Swar` as `Packed`.
    Swar,
}

/// Range-event counters accumulated by the fixed-format backend (the
/// evidence for *why* a fixed type fails).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RangeEvents {
    pub overflows: u64,
    pub underflows: u64,
}

/// A pluggable arithmetic unit. One instance is owned by one solver run, so
/// stateful backends (R2F2's split register) behave like one hardware
/// multiplier seeing the solver's multiplication stream in order.
///
/// Besides the scalar operations, the trait carries the **batched engine**
/// (DESIGN.md §8): slice-level operations with default implementations that
/// replay the scalar path, and per-backend fast paths that hoist
/// loop-invariant work (dynamic dispatch, constant-operand encodes, format
/// decomposition) out of the inner loop. The contract is strict: a batched
/// call must produce **bit-identical results and identical counters** to
/// the equivalent scalar sequence — `rust/tests/batched_vs_scalar.rs`
/// enforces it per backend.
///
/// ```
/// use r2f2::pde::{Arith, F64Arith};
///
/// let mut unit = F64Arith;
/// assert_eq!(unit.mul(3.0, 4.0), 12.0);
///
/// let mut out = [0.0; 3];
/// unit.mul_batch(&mut out, 2.0, &[1.0, 2.0, 3.0]);
/// assert_eq!(out, [2.0, 4.0, 6.0]);
/// ```
pub trait Arith {
    /// Human-readable backend name for reports (e.g. `E5M10`, `<3,9,3>`).
    fn name(&self) -> String;
    /// One multiplication through the unit (operands converted in, result
    /// converted back).
    fn mul(&mut self, a: f64, b: f64) -> f64;
    /// One addition. Defaults to the f64 carrier; `Full` mode overrides.
    fn add(&mut self, a: f64, b: f64) -> f64 {
        a + b
    }
    /// Quantize a state value for storage (`Full` mode only).
    fn quant(&mut self, x: f64) -> f64 {
        x
    }
    /// Batched constant × slice multiply: `out[i] = a ⊗ xs[i]`, issued in
    /// index order. Bit-identical to the scalar loop, including counters.
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = self.mul(a, x);
        }
    }
    /// Batched pairwise multiply: `out[i] = pairs[i].0 ⊗ pairs[i].1`, in
    /// index order. Bit-identical to the scalar loop, including counters.
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        assert_eq!(out.len(), pairs.len());
        for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
            *o = self.mul(a, b);
        }
    }
    /// Fused heat stencil sweep: for every interior node
    /// `next[i] = u[i] + (r·u[i−1] − 2r·u[i] + r·u[i+1])` with the three
    /// multiplications routed through the unit in the canonical per-node
    /// order (left, mid, right), and boundary nodes copied. `mode` selects
    /// whether the additions and storage quantization also go through the
    /// backend, exactly as the scalar solver does.
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        scalar_stencil_step(self, next, u, r, mode);
    }
    /// Fused **multi-step** heat sweep (DESIGN.md §9): equivalent to
    /// `steps` iterations of [`Arith::stencil_step`] each followed by
    /// `mem::swap(u, next)`, recording `(step + 1, u.clone())` snapshots
    /// every `snapshot_every` steps (0 = none). On return `u` holds the
    /// final state, bit-identical to the iterated-step reference; `next` is
    /// scratch and its contents are unspecified.
    ///
    /// This is the hook that lets packed backends keep `QuantMode::Full`
    /// state in the packed domain **across** timesteps instead of bouncing
    /// through the `f64` carrier at every node.
    fn stencil_multi(
        &mut self,
        u: &mut Vec<f64>,
        next: &mut Vec<f64>,
        r: f64,
        mode: QuantMode,
        steps: usize,
        snapshot_every: usize,
        snapshots: &mut Vec<(usize, Vec<f64>)>,
    ) {
        stencil_multi_via_steps(self, u, next, r, mode, steps, snapshot_every, snapshots);
    }
    /// Fused shallow-water x-momentum flux batch: for each `(q1, q3)` pair
    /// compute `q1²/q3 + g2·q3²` with its three multiplications (`q1·q1`,
    /// `q3·q3`, `g2·q3²`) through the unit, in index order. Under
    /// [`QuantMode::Full`] the final combine also routes through
    /// [`Arith::add`] (the division stays in the `f64` carrier — the
    /// backends model multipliers and adders, not dividers).
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)], mode: QuantMode) {
        scalar_flux_batch(self, out, g2, q, mode);
    }
    /// R2F2 adjustment statistics, if the backend has them.
    fn r2f2_stats(&self) -> Option<Stats> {
        None
    }
    /// Overflow/underflow events, if the backend tracks them.
    fn range_events(&self) -> Option<RangeEvents> {
        None
    }
    /// The emulated format currently active in this unit, if it has one —
    /// `FixedArith`'s fixed format, R2F2's effective format at the current
    /// split, the adaptive scheduler's current rung. Hardware backends
    /// (`f64`/`f32`) return `None`. Reports and benches use this to label
    /// rows without downcasting.
    fn active_format(&self) -> Option<FpFormat> {
        None
    }
    /// Spawn an independent worker unit for one decomposed subdomain
    /// (`pde::decomp`, DESIGN.md §13): same format and engine, fresh
    /// telemetry counters. Only **history-independent** backends — units
    /// whose per-operation results depend on the operands alone, never on
    /// the multiplication history — may fork, because forked workers see
    /// only their shard's slice of the global operation stream. Stateful
    /// units (R2F2's split register, the stochastic rounder) return `None`
    /// and the decomposed drivers fall back to issuing the shards'
    /// operations sequentially, in global order, through the original unit.
    fn fork(&self) -> Option<Box<dyn Arith + Send>> {
        None
    }
    /// Fold a forked worker's telemetry (range-event counters) back into
    /// this unit after a decomposed advance. The default is a no-op for
    /// backends that track nothing.
    fn absorb(&mut self, _child: &dyn Arith) {}
    /// Clone this unit's **semantic** state into an independent boxed
    /// backend — the checkpoint hook behind the resumable job API
    /// (`server::jobs`, DESIGN.md §16). Unlike [`Arith::fork`] (which
    /// requires history-independence and hands out *fresh* counters), a
    /// snapshot carries everything forward — range-event counters, the
    /// R2F2 split register and its redundancy streak, the stochastic
    /// rounder's stream position — so advancing the snapshot is
    /// bit-identical to advancing the original from the same state.
    /// Backends without a snapshot (`None`, the default) force a
    /// restart-from-step-0 resume, which is still deterministic, just not
    /// incremental.
    fn snapshot(&self) -> Option<Box<dyn Arith + Send>> {
        None
    }
}

/// The canonical scalar heat-stencil sequence — the reference semantics the
/// batched fast paths must reproduce bit-for-bit. Shared by the default
/// [`Arith::stencil_step`] and by backends that fall back for modes they do
/// not accelerate.
pub fn scalar_stencil_step<A: Arith + ?Sized>(
    be: &mut A,
    next: &mut [f64],
    u: &[f64],
    r: f64,
    mode: QuantMode,
) {
    let n = u.len();
    assert_eq!(next.len(), n);
    assert!(n >= 3);
    let two_r = 2.0 * r;
    for i in 1..n - 1 {
        let left = be.mul(r, u[i - 1]);
        let mid = be.mul(two_r, u[i]);
        let right = be.mul(r, u[i + 1]);
        match mode {
            QuantMode::MulOnly => {
                next[i] = u[i] + ((left - mid) + right);
            }
            QuantMode::Full => {
                let s = be.add(left, -mid);
                let du = be.add(s, right);
                let unew = be.add(u[i], du);
                next[i] = be.quant(unew);
            }
        }
    }
    next[0] = u[0];
    next[n - 1] = u[n - 1];
}

/// The canonical multi-step sequence: iterate [`Arith::stencil_step`] with
/// swaps and snapshots. Shared by the default [`Arith::stencil_multi`] and
/// by backends falling back for modes they do not accelerate.
#[allow(clippy::too_many_arguments)]
pub fn stencil_multi_via_steps<A: Arith + ?Sized>(
    be: &mut A,
    u: &mut Vec<f64>,
    next: &mut Vec<f64>,
    r: f64,
    mode: QuantMode,
    steps: usize,
    snapshot_every: usize,
    snapshots: &mut Vec<(usize, Vec<f64>)>,
) {
    for step in 0..steps {
        be.stencil_step(next, u, r, mode);
        std::mem::swap(u, next);
        if snapshot_every != 0 && (step + 1) % snapshot_every == 0 {
            snapshots.push((step + 1, u.clone()));
        }
    }
}

/// The canonical scalar flux sequence — the reference semantics the batched
/// fast paths must reproduce bit-for-bit (per pair: `q1·q1`, `q3·q3`,
/// `g2·q3²` through the unit, then the mode-gated combine).
pub fn scalar_flux_batch<A: Arith + ?Sized>(
    be: &mut A,
    out: &mut [f64],
    g2: f64,
    q: &[(f64, f64)],
    mode: QuantMode,
) {
    assert_eq!(out.len(), q.len());
    for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
        let q1sq = be.mul(q1, q1);
        let q3sq = be.mul(q3, q3);
        let gq = be.mul(g2, q3sq);
        *o = match mode {
            QuantMode::MulOnly => q1sq / q3 + gq,
            QuantMode::Full => be.add(q1sq / q3, gq),
        };
    }
}

/// IEEE double — the ground-truth backend.
#[derive(Debug, Default)]
pub struct F64Arith;

impl Arith for F64Arith {
    fn name(&self) -> String {
        "f64".into()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        a * b
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = a * x;
        }
    }
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        assert_eq!(out.len(), pairs.len());
        for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
            *o = a * b;
        }
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, _mode: QuantMode) {
        // add/quant are identity for f64, so Full and MulOnly coincide and
        // the whole sweep vectorizes as one tight loop.
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let two_r = 2.0 * r;
        for i in 1..n - 1 {
            next[i] = u[i] + ((r * u[i - 1] - two_r * u[i]) + r * u[i + 1]);
        }
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)], _mode: QuantMode) {
        // add is identity for f64, so Full and MulOnly coincide.
        assert_eq!(out.len(), q.len());
        for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
            *o = q1 * q1 / q3 + g2 * (q3 * q3);
        }
    }
    fn fork(&self) -> Option<Box<dyn Arith + Send>> {
        Some(Box::new(F64Arith))
    }
    fn snapshot(&self) -> Option<Box<dyn Arith + Send>> {
        Some(Box::new(F64Arith))
    }
}

/// Hardware single precision (the paper's "32-bit" reference).
#[derive(Debug, Default)]
pub struct F32Arith;

impl Arith for F32Arith {
    fn name(&self) -> String {
        "f32".into()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        (a as f32 * b as f32) as f64
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        (a as f32 + b as f32) as f64
    }
    fn quant(&mut self, x: f64) -> f64 {
        x as f32 as f64
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        let af = a as f32;
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = (af * x as f32) as f64;
        }
    }
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        assert_eq!(out.len(), pairs.len());
        for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
            *o = (a as f32 * b as f32) as f64;
        }
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        if mode == QuantMode::Full {
            // Additions and storage also run in f32; keep the canonical
            // sequence (still monomorphized — no per-mul dynamic dispatch).
            scalar_stencil_step(self, next, u, r, mode);
            return;
        }
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let rf = r as f32;
        let two_rf = (2.0 * r) as f32;
        for i in 1..n - 1 {
            let left = (rf * u[i - 1] as f32) as f64;
            let mid = (two_rf * u[i] as f32) as f64;
            let right = (rf * u[i + 1] as f32) as f64;
            next[i] = u[i] + ((left - mid) + right);
        }
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }
    fn fork(&self) -> Option<Box<dyn Arith + Send>> {
        Some(Box::new(F32Arith))
    }
    fn snapshot(&self) -> Option<Box<dyn Arith + Send>> {
        Some(Box::new(F32Arith))
    }
}

/// Reusable scratch buffers for the packed per-sweep paths, so the
/// per-timestep hot path performs no heap allocation after the first
/// sweep. Not semantic state — contents are transient within one call.
#[derive(Debug, Default)]
struct PackedScratch {
    wu: Vec<u32>,
    enc_fl: Vec<Flags>,
    pr_w: Vec<u32>,
    pr_fl: Vec<Flags>,
    pr_val: Vec<f64>,
    wnext: Vec<u32>,
}

/// A fixed `ExMy` software format (E5M10 = the paper's standard half
/// baseline). Counts range events so reports can show where it breaks.
///
/// Runs the packed-domain engine by default (DESIGN.md §9);
/// [`FixedArith::with_engine`] selects the frozen PR-1 carrier engine for
/// perf-baseline runs. Formats wider than one packed word (`E11M52`) fall
/// back to the carrier path automatically.
#[derive(Debug)]
pub struct FixedArith {
    pub fmt: FpFormat,
    engine: BatchEngine,
    events: RangeEvents,
    scratch: PackedScratch,
    /// Tile-geometry override `(workers, tile_width)` for the multi-step
    /// `Full` driver. `None` derives both from `R2F2_WORKERS` / grid size.
    tiling: Option<(usize, usize)>,
}

impl FixedArith {
    pub fn new(fmt: FpFormat) -> FixedArith {
        FixedArith {
            fmt,
            engine: BatchEngine::default(),
            events: RangeEvents::default(),
            scratch: PackedScratch::default(),
            tiling: None,
        }
    }

    /// Select the batched-engine implementation (all are bit-identical).
    pub fn with_engine(mut self, engine: BatchEngine) -> FixedArith {
        self.engine = engine;
        self
    }

    /// Pin the tile geometry of the multi-step `Full` driver to exactly
    /// `workers` pool workers and `tile_width` interior nodes per tile
    /// (the last tile may be short). The tiled sweep is bit-identical for
    /// every geometry — this hook exists so tests and benches can force
    /// worker counts and non-divisible splits (`rust/tests/swar_vs_packed.rs`)
    /// instead of inheriting `R2F2_WORKERS`.
    pub fn with_tiling(mut self, workers: usize, tile_width: usize) -> FixedArith {
        self.tiling = Some((workers.max(1), tile_width.max(1)));
        self
    }

    fn track(&mut self, flags: crate::softfloat::Flags) {
        if flags.overflow() {
            self.events.overflows += 1;
        }
        if flags.underflow() {
            self.events.underflows += 1;
        }
    }

    /// Does this instance run the packed-domain kernels? `Swar` is a tier
    /// of the packed engine, so it keeps every packed routing decision and
    /// only swaps the innermost kernel calls.
    fn packed_on(&self) -> bool {
        matches!(self.engine, BatchEngine::Packed | BatchEngine::Swar) && self.fmt.fits_word()
    }

    /// Does this instance run the lane-paired SWAR kernels on top of the
    /// packed paths? Requires a format narrow enough for a 16-bit lane;
    /// wider formats silently stay on the scalar-word packed kernels.
    fn swar_on(&self) -> bool {
        self.engine == BatchEngine::Swar && self.fmt.fits_lane()
    }

    /// The lane format when the SWAR tier is active.
    fn swar_fmt(&self) -> Option<SwarFormat> {
        if self.swar_on() {
            Some(self.fmt.swar())
        } else {
            None
        }
    }

    /// Tile geometry `(workers, tile_width)` for the multi-step `Full`
    /// driver: the explicit [`FixedArith::with_tiling`] override, or
    /// `R2F2_WORKERS`-many workers over cache-sized row blocks. The
    /// default width divides the interior evenly across the pool but never
    /// exceeds [`TILE_WIDTH`] words (so a tile's read set stays
    /// cache-resident) and never drops below [`MIN_TILE`] (so small grids
    /// — e.g. decomp shard slabs, §13 — collapse to one inline tile
    /// instead of spawning threads: the two layers compose, they don't
    /// nest pools).
    fn tile_geometry(&self, n: usize) -> (usize, usize) {
        if let Some(geom) = self.tiling {
            return geom;
        }
        let workers = crate::coordinator::default_workers();
        let interior = n.saturating_sub(2).max(1);
        let per_worker = interior.div_ceil(workers);
        (workers, per_worker.clamp(MIN_TILE, TILE_WIDTH))
    }

    /// One packed `MulOnly` stencil sweep: encode the state vector once,
    /// multiply in the word domain (with the `r·u[j]` product dedup and the
    /// scalar event multiplicity), decode each product once for the
    /// f64-carrier adds.
    fn stencil_sweep_packed_mul_only(&mut self, next: &mut [f64], u: &[f64], r: f64) {
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let pf = self.fmt.packed();
        let mut rnd = Rounder::nearest_even();
        let (wr, flr) = pk::encode_bits(r.to_bits(), &pf, &mut rnd);
        let (w2r, fl2r) = pk::encode_bits((2.0 * r).to_bits(), &pf, &mut rnd);

        // Scratch reuse: after the first sweep the per-timestep hot path
        // performs no heap allocation.
        let PackedScratch { wu, enc_fl, pr_val, pr_fl, .. } = &mut self.scratch;
        pk::encode_slice_bits(u, &pf, &mut rnd, wu, enc_fl);

        // r ⊗ u[j], shared between the `right` of node j−1 and the `left`
        // of node j+1 (identical operands ⇒ identical product and flags);
        // events counted once per use, the scalar multiplicity.
        pr_val.clear();
        pr_val.resize(n, 0.0);
        pr_fl.clear();
        pr_fl.resize(n, Flags::NONE);
        let sfmt = if self.engine == BatchEngine::Swar && self.fmt.fits_lane() {
            Some(self.fmt.swar())
        } else {
            None
        };
        let mut j = 0;
        if let Some(sf) = sfmt.as_ref() {
            // SWAR tier: two products per u64; lane k of pair (j, j+1) is
            // flat element j+k, so values and flags match the scalar loop
            // lane-for-lane (DESIGN.md §14).
            let vr = sw::pack2(wr, wr);
            while j + 1 < n {
                let (vp, fl) = sw::mul_packed_lanes(vr, sw::pack2(wu[j], wu[j + 1]), sf, &mut rnd);
                let (p0, p1) = sw::unpack2(vp);
                pr_val[j] = pk::decode_word(p0, &pf);
                pr_val[j + 1] = pk::decode_word(p1, &pf);
                pr_fl[j] = flr | enc_fl[j] | fl[0];
                pr_fl[j + 1] = flr | enc_fl[j + 1] | fl[1];
                j += 2;
            }
        }
        while j < n {
            let (w, fl) = pk::mul_packed(wr, wu[j], &pf, &mut rnd);
            pr_val[j] = pk::decode_word(w, &pf);
            pr_fl[j] = flr | enc_fl[j] | fl;
            j += 1;
        }
        let mut of = 0u64;
        let mut uf = 0u64;
        count_shared_product_events(pr_fl, &mut of, &mut uf);

        let mut i = 1;
        if let Some(sf) = sfmt.as_ref() {
            let v2r = sw::pack2(w2r, w2r);
            while i + 1 < n - 1 {
                let (vm, flm) = sw::mul_packed_lanes(v2r, sw::pack2(wu[i], wu[i + 1]), sf, &mut rnd);
                let (m0, m1) = sw::unpack2(vm);
                for (k, (wm, flk)) in [(m0, flm[0]), (m1, flm[1])].into_iter().enumerate() {
                    let mid = pk::decode_word(wm, &pf);
                    let flm = fl2r | enc_fl[i + k] | flk;
                    of += u64::from(flm.overflow());
                    uf += u64::from(flm.underflow());
                    next[i + k] = u[i + k] + ((pr_val[i + k - 1] - mid) + pr_val[i + k + 1]);
                }
                i += 2;
            }
        }
        while i < n - 1 {
            let (wm, flm) = pk::mul_packed(w2r, wu[i], &pf, &mut rnd);
            let mid = pk::decode_word(wm, &pf);
            let flm = fl2r | enc_fl[i] | flm;
            of += u64::from(flm.overflow());
            uf += u64::from(flm.underflow());
            next[i] = u[i] + ((pr_val[i - 1] - mid) + pr_val[i + 1]);
            i += 1;
        }
        self.events.overflows += of;
        self.events.underflows += uf;
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }

    /// One packed `Full` stencil sweep with fresh encode/decode envelopes
    /// (the multi-step driver below keeps the state packed instead).
    fn stencil_sweep_packed_full(&mut self, next: &mut [f64], u: &[f64], r: f64) {
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let pf = self.fmt.packed();
        let mut rnd = Rounder::nearest_even();
        let (wr, flr) = pk::encode_bits(r.to_bits(), &pf, &mut rnd);
        let (w2r, fl2r) = pk::encode_bits((2.0 * r).to_bits(), &pf, &mut rnd);
        let sfmt = if self.engine == BatchEngine::Swar && self.fmt.fits_lane() {
            Some(self.fmt.swar())
        } else {
            None
        };
        let PackedScratch { wu, enc_fl, pr_w, pr_fl, wnext, .. } = &mut self.scratch;
        pk::encode_slice_bits(u, &pf, &mut rnd, wu, enc_fl);
        wnext.clear();
        wnext.resize(n, 0);
        // A single sweep is one full-width tile: the tiled and untiled
        // paths are the same code (DESIGN.md §14).
        let (of, uf) = tile_full_sweep(
            &pf,
            sfmt.as_ref(),
            &mut rnd,
            wr,
            flr,
            w2r,
            fl2r,
            wu,
            enc_fl,
            1,
            n - 1,
            &mut wnext[1..n - 1],
            pr_w,
            pr_fl,
        );
        wnext[0] = wu[0];
        wnext[n - 1] = wu[n - 1];
        self.events.overflows += of;
        self.events.underflows += uf;
        for (o, &w) in next.iter_mut().zip(self.scratch.wnext.iter()) {
            *o = pk::decode_word(w, &pf);
        }
        // The scalar path copies the raw f64 boundary values (they may not
        // be representable in the format).
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }

    /// The packed-domain `Full`-mode driver: encode the state **once**,
    /// step `steps` times entirely in the packed domain, decode once at the
    /// end (and per snapshot) — no f64 carrier round-trip per node per
    /// step. Bit-identical to iterating the scalar sweep: after the first
    /// sweep every interior value is format-representable, so its re-encode
    /// in the scalar path is exact and flag-free; raw Dirichlet boundary
    /// values are kept aside verbatim (their encode flags persist per
    /// sweep, exactly as the scalar path re-incurs them).
    ///
    /// Each sweep is dispatched as cache-tiled row blocks over
    /// [`crate::coordinator::parallel_map`] (DESIGN.md §14): tiles read
    /// the shared state with a ±1 halo and write disjoint `wnext`
    /// segments, scattered back in deterministic tile order, so the tiled
    /// sweep is bit-identical to the single-tile one for every geometry.
    /// `parallel_map` is the per-step barrier; the swap and snapshot
    /// decodes stay on the calling thread.
    fn stencil_multi_packed_full(
        &mut self,
        u: &mut [f64],
        next: &mut [f64],
        r: f64,
        steps: usize,
        snapshot_every: usize,
        snapshots: &mut Vec<(usize, Vec<f64>)>,
    ) {
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        debug_assert!(steps > 0);
        let pf = self.fmt.packed();
        let sfmt = self.swar_fmt();
        let mut rnd = Rounder::nearest_even();
        let (wr, flr) = pk::encode_bits(r.to_bits(), &pf, &mut rnd);
        let (w2r, fl2r) = pk::encode_bits((2.0 * r).to_bits(), &pf, &mut rnd);

        let (b0, b1) = (u[0], u[n - 1]);
        let mut wu: Vec<u32> = Vec::new();
        let mut enc_fl: Vec<Flags> = Vec::new();
        pk::encode_slice_bits(u, &pf, &mut rnd, &mut wu, &mut enc_fl);
        let mut wnext = wu.clone();
        let mut pr: Vec<u32> = Vec::new();
        let mut pr_fl: Vec<Flags> = Vec::new();

        let (workers, tile_w) = self.tile_geometry(n);
        let tiles = tile_ranges(n, tile_w);

        let mut of = 0u64;
        let mut uf = 0u64;
        for step in 0..steps {
            if tiles.len() == 1 {
                // One tile: run inline on the calling thread with reusable
                // scratch — identical code path to the parallel tiles.
                let (ts, te) = tiles[0];
                let (o, f) = tile_full_sweep(
                    &pf,
                    sfmt.as_ref(),
                    &mut rnd,
                    wr,
                    flr,
                    w2r,
                    fl2r,
                    &wu,
                    &enc_fl,
                    ts,
                    te,
                    &mut wnext[ts..te],
                    &mut pr,
                    &mut pr_fl,
                );
                of += o;
                uf += f;
            } else {
                let results =
                    crate::coordinator::parallel_map(tiles.clone(), workers, |(ts, te)| {
                        let mut rnd = Rounder::nearest_even();
                        let mut seg = vec![0u32; te - ts];
                        let mut pr: Vec<u32> = Vec::new();
                        let mut pr_fl: Vec<Flags> = Vec::new();
                        let (o, f) = tile_full_sweep(
                            &pf,
                            sfmt.as_ref(),
                            &mut rnd,
                            wr,
                            flr,
                            w2r,
                            fl2r,
                            &wu,
                            &enc_fl,
                            ts,
                            te,
                            &mut seg,
                            &mut pr,
                            &mut pr_fl,
                        );
                        (seg, o, f)
                    });
                // Scatter in tile order (segments are disjoint; the order
                // fixes the counter accumulation sequence).
                for (&(ts, te), (seg, o, f)) in tiles.iter().zip(results) {
                    wnext[ts..te].copy_from_slice(&seg);
                    of += o;
                    uf += f;
                }
            }
            wnext[0] = wu[0];
            wnext[n - 1] = wu[n - 1];
            std::mem::swap(&mut wu, &mut wnext);
            if step == 0 {
                // Interior values are representable from now on: the scalar
                // path's re-encodes become exact and flag-free. Boundaries
                // stay raw and keep their flags.
                for fl in enc_fl[1..n - 1].iter_mut() {
                    *fl = Flags::NONE;
                }
            }
            if snapshot_every != 0 && (step + 1) % snapshot_every == 0 {
                let mut snap = vec![0.0; n];
                for (s, &w) in snap.iter_mut().zip(wu.iter()) {
                    *s = pk::decode_word(w, &pf);
                }
                snap[0] = b0;
                snap[n - 1] = b1;
                snapshots.push((step + 1, snap));
            }
        }
        self.events.overflows += of;
        self.events.underflows += uf;
        for (o, &w) in u.iter_mut().zip(wu.iter()) {
            *o = pk::decode_word(w, &pf);
        }
        u[0] = b0;
        u[n - 1] = b1;
        for (o, &w) in next.iter_mut().zip(wnext.iter()) {
            *o = pk::decode_word(w, &pf);
        }
        next[0] = b0;
        next[n - 1] = b1;
    }
}

/// Count range events of the deduplicated `r·u[j]` products at the scalar
/// multiplicity: each product is charged once per use — as a `left` when
/// `j ≤ n−3` and as a `right` when `j ≥ 2` (DESIGN.md §8). This invariant
/// is load-bearing for the bit-identity contract, so it is single-sourced
/// across the carrier and packed sweeps.
fn count_shared_product_events(pr_fl: &[Flags], of: &mut u64, uf: &mut u64) {
    let n = pr_fl.len();
    for (j, fl) in pr_fl.iter().enumerate() {
        let mult = u64::from(j + 3 <= n) + u64::from(j >= 2);
        if fl.overflow() {
            *of += mult;
        }
        if fl.underflow() {
            *uf += mult;
        }
    }
}

/// Upper bound on interior nodes per tile in the multi-step `Full` driver.
/// A tile's working set (`u32` state + products + segment) stays a few
/// tens of KiB — resident in L1/L2 while the sweep walks it.
const TILE_WIDTH: usize = 4096;

/// Lower bound on the *default* tile width: grids whose interior fits one
/// such tile (decomp shard slabs, small scenarios) run inline instead of
/// paying per-step thread dispatch for a handful of nodes. Tests pin
/// smaller widths explicitly via [`FixedArith::with_tiling`].
const MIN_TILE: usize = 1024;

/// Split the interior `[1, n−1)` into contiguous tiles of `tile_w` nodes
/// (the last tile may be short). Tile order is ascending and deterministic
/// — the scatter in [`FixedArith::stencil_multi_packed_full`] relies on it.
fn tile_ranges(n: usize, tile_w: usize) -> Vec<(usize, usize)> {
    let tile_w = tile_w.max(1);
    let mut tiles = Vec::new();
    let mut ts = 1;
    while ts < n - 1 {
        let te = (ts + tile_w).min(n - 1);
        tiles.push((ts, te));
        ts = te;
    }
    tiles
}

/// One `Full`-mode sweep of the node range `[ts, te)` — a cache tile, or
/// the whole interior — entirely in the packed domain (muls, adds and
/// storage quantization; the quantize of an already-packed result is the
/// identity). Reads the shared state `wu` with a ±1 halo and writes only
/// `seg = wnext[ts..te]`, so disjoint tiles can run concurrently.
///
/// The shared products `r ⊗ u[j]` are (re)computed for `j ∈ [ts−1, te+1)`;
/// a product on a tile seam is recomputed by both neighbours from the same
/// words — RNE is a pure function of the operands, so the bits agree. Each
/// product's range events are charged to the tile of its *consuming* node
/// (`left` use at node `j+1`, `right` use at node `j−1`), so the per-tile
/// counts partition the scalar multiplicity of
/// [`count_shared_product_events`] exactly (DESIGN.md §14).
///
/// With `sf` set, lane-paired SWAR kernels process two elements per call
/// with a scalar-word tail; lane `k` of pair `(j, j+1)` is flat element
/// `j+k`, so values and flags match the scalar loop lane-for-lane. The
/// pairing is legal because this path is RNE-only (gated like
/// [`Arith::fork`]): rounding draws no RNG state, so reassociating the
/// *op order* (pair-major instead of node-major) changes no bits and the
/// counters are order-insensitive sums.
///
/// `enc_fl` carries the per-element encode flags of the current state,
/// charged at the scalar multiplicity: each state value feeds up to three
/// multiplications and one addition. Returns `(overflows, underflows)`.
#[allow(clippy::too_many_arguments)]
fn tile_full_sweep(
    pf: &crate::softfloat::PackedFormat,
    sf: Option<&SwarFormat>,
    rnd: &mut Rounder,
    wr: u32,
    flr: Flags,
    w2r: u32,
    fl2r: Flags,
    wu: &[u32],
    enc_fl: &[Flags],
    ts: usize,
    te: usize,
    seg: &mut [u32],
    pr: &mut Vec<u32>,
    pr_fl: &mut Vec<Flags>,
) -> (u64, u64) {
    let n = wu.len();
    debug_assert!(1 <= ts && ts < te && te <= n - 1);
    debug_assert_eq!(seg.len(), te - ts);
    let lo = ts - 1;
    let hi = te + 1; // product index range [lo, hi)
    pr.clear();
    pr.resize(hi - lo, 0);
    pr_fl.clear();
    pr_fl.resize(hi - lo, Flags::NONE);

    let mut of = 0u64;
    let mut uf = 0u64;

    // r ⊗ u[j] for every product this tile consumes.
    let mut j = lo;
    if let Some(sf) = sf {
        let vr = sw::pack2(wr, wr);
        while j + 1 < hi {
            let (vp, fl) = sw::mul_packed_lanes(vr, sw::pack2(wu[j], wu[j + 1]), sf, rnd);
            let (p0, p1) = sw::unpack2(vp);
            pr[j - lo] = p0;
            pr[j + 1 - lo] = p1;
            pr_fl[j - lo] = flr | enc_fl[j] | fl[0];
            pr_fl[j + 1 - lo] = flr | enc_fl[j + 1] | fl[1];
            j += 2;
        }
    }
    while j < hi {
        let (w, fl) = pk::mul_packed(wr, wu[j], pf, rnd);
        pr[j - lo] = w;
        pr_fl[j - lo] = flr | enc_fl[j] | fl;
        j += 1;
    }
    // Charge each product once per use *inside this tile*: its `left` use
    // sits at node j+1, its `right` use at node j−1. Summed over tiles
    // this reproduces the scalar multiplicity (j ≤ n−3) + (j ≥ 2).
    for j in lo..hi {
        let mult = u64::from(j + 1 < te) + u64::from(j >= ts + 1);
        let fl = pr_fl[j - lo];
        if fl.overflow() {
            of += mult;
        }
        if fl.underflow() {
            uf += mult;
        }
    }

    let mut i = ts;
    if let Some(sf) = sf {
        let v2r = sw::pack2(w2r, w2r);
        while i + 1 < te {
            // mid = 2r ⊗ u, then s = left + (−mid); du = s + right;
            // unew = u + du — the scalar Full sequence, two nodes per call.
            let (vm, flm) = sw::mul_packed_lanes(v2r, sw::pack2(wu[i], wu[i + 1]), sf, rnd);
            let (wm0, wm1) = sw::unpack2(vm);
            let flm0 = fl2r | enc_fl[i] | flm[0];
            let flm1 = fl2r | enc_fl[i + 1] | flm[1];
            of += u64::from(flm0.overflow()) + u64::from(flm1.overflow());
            uf += u64::from(flm0.underflow()) + u64::from(flm1.underflow());
            let (vs, fls) = sw::add_packed_lanes(
                sw::pack2(pr[i - 1 - lo], pr[i - lo]),
                sw::pack2(pf.neg_word(wm0), pf.neg_word(wm1)),
                sf,
                rnd,
            );
            of += u64::from(fls[0].overflow()) + u64::from(fls[1].overflow());
            uf += u64::from(fls[0].underflow()) + u64::from(fls[1].underflow());
            let (vdu, fldu) =
                sw::add_packed_lanes(vs, sw::pack2(pr[i + 1 - lo], pr[i + 2 - lo]), sf, rnd);
            of += u64::from(fldu[0].overflow()) + u64::from(fldu[1].overflow());
            uf += u64::from(fldu[0].underflow()) + u64::from(fldu[1].underflow());
            let (vnew, flnew) = sw::add_packed_lanes(sw::pack2(wu[i], wu[i + 1]), vdu, sf, rnd);
            // The scalar path re-encodes the raw u[i] inside this add.
            let flnew0 = flnew[0] | enc_fl[i];
            let flnew1 = flnew[1] | enc_fl[i + 1];
            of += u64::from(flnew0.overflow()) + u64::from(flnew1.overflow());
            uf += u64::from(flnew0.underflow()) + u64::from(flnew1.underflow());
            let (n0, n1) = sw::unpack2(vnew);
            seg[i - ts] = n0;
            seg[i + 1 - ts] = n1;
            i += 2;
        }
    }
    while i < te {
        let (wm, flm) = pk::mul_packed(w2r, wu[i], pf, rnd);
        let flm = fl2r | enc_fl[i] | flm;
        of += u64::from(flm.overflow());
        uf += u64::from(flm.underflow());
        // s = left + (−mid); du = s + right; unew = u[i] + du — the scalar
        // Full sequence, with every operand already packed.
        let (ws, fls) = pk::add_packed(pr[i - 1 - lo], pf.neg_word(wm), pf, rnd);
        of += u64::from(fls.overflow());
        uf += u64::from(fls.underflow());
        let (wdu, fldu) = pk::add_packed(ws, pr[i + 1 - lo], pf, rnd);
        of += u64::from(fldu.overflow());
        uf += u64::from(fldu.underflow());
        let (wnew, flnew) = pk::add_packed(wu[i], wdu, pf, rnd);
        // The scalar path re-encodes the raw u[i] inside this add.
        let flnew = flnew | enc_fl[i];
        of += u64::from(flnew.overflow());
        uf += u64::from(flnew.underflow());
        // quant(unew): encode∘decode is the identity on packed values and
        // raises no flags — storage quantization is free in this domain.
        seg[i - ts] = wnew;
        i += 1;
    }
    (of, uf)
}

impl Arith for FixedArith {
    fn name(&self) -> String {
        self.fmt.to_string()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let (v, fl) = mul_f(a, b, self.fmt);
        self.track(fl);
        v
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        let (v, fl) = add_f(a, b, self.fmt);
        self.track(fl);
        v
    }
    fn quant(&mut self, x: f64) -> f64 {
        let (v, fl) = quantize_flagged(x, self.fmt);
        self.track(fl);
        v
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        let fmt = self.fmt;
        let mut rnd = Rounder::nearest_even();
        if let Some(sf) = self.swar_fmt() {
            // SWAR tier: the constant rides both lanes, operand pairs are
            // encoded, multiplied and decoded two-per-u64, with the scalar
            // packed kernels finishing an odd tail. Lane k of pair
            // (2i, 2i+1) is flat element 2i+k, so per-element flag unions
            // and counters match `mul_batch_packed` exactly; the op
            // reordering (both encodes before both muls) is bit-free
            // because this path is RNE-only (DESIGN.md §14).
            let pf = fmt.packed();
            let (wa, fla) = pk::encode_bits(a.to_bits(), &pf, &mut rnd);
            let va = sw::pack2(wa, wa);
            let mut of = 0u64;
            let mut uf = 0u64;
            let mut count = |fl: Flags| {
                of += u64::from(fl.overflow());
                uf += u64::from(fl.underflow());
            };
            let mut chunks = out.chunks_exact_mut(2);
            let mut xpairs = xs.chunks_exact(2);
            for (o, x) in chunks.by_ref().zip(xpairs.by_ref()) {
                let (vb, flb) = sw::encode_lanes(x[0], x[1], &sf, &mut rnd);
                let (vp, flp) = sw::mul_packed_lanes(va, vb, &sf, &mut rnd);
                let (d0, d1) = sw::decode_lanes(vp, &sf);
                o[0] = d0;
                o[1] = d1;
                count(fla | flb[0] | flp[0]);
                count(fla | flb[1] | flp[1]);
            }
            for (o, &x) in chunks.into_remainder().iter_mut().zip(xpairs.remainder()) {
                let (wb, flb) = pk::encode_bits(x.to_bits(), &pf, &mut rnd);
                let (wp, flp) = pk::mul_packed(wa, wb, &pf, &mut rnd);
                *o = pk::decode_word(wp, &pf);
                count(fla | flb | flp);
            }
            drop(count);
            self.events.overflows += of;
            self.events.underflows += uf;
            return;
        }
        if self.packed_on() {
            // Packed engine: constant encoded once, word kernels, counters
            // accumulated without a per-batch flags allocation. One shared
            // kernel with `softfloat::batch` (DESIGN.md §9).
            let pf = fmt.packed();
            let mut of = 0u64;
            let mut uf = 0u64;
            mul_batch_packed(a, xs, &pf, &mut rnd, out, |_, fl| {
                of += u64::from(fl.overflow());
                uf += u64::from(fl.underflow());
            });
            self.events.overflows += of;
            self.events.underflows += uf;
            return;
        }
        // Carrier engine (the frozen PR-1 fast path): hoisted constant
        // encode on the Fp structs.
        let (fa, fla) = encode(a, fmt, &mut rnd);
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            let (fb, flb) = encode(x, fmt, &mut rnd);
            let (fc, flc) = sf_mul(fa, fb, fmt, &mut rnd);
            *o = decode(fc, fmt);
            self.track(fla | flb | flc);
        }
    }
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        assert_eq!(out.len(), pairs.len());
        let fmt = self.fmt;
        let mut rnd = Rounder::nearest_even();
        if let Some(sf) = self.swar_fmt() {
            // SWAR tier of `mul_pairs_packed`: lane k of chunk (2i, 2i+1)
            // is flat element 2i+k; the encode reordering is bit-free
            // under RNE (this path never runs stochastic).
            let pf = fmt.packed();
            let mut of = 0u64;
            let mut uf = 0u64;
            let mut count = |fl: Flags| {
                of += u64::from(fl.overflow());
                uf += u64::from(fl.underflow());
            };
            let mut chunks = out.chunks_exact_mut(2);
            let mut ppairs = pairs.chunks_exact(2);
            for (o, p) in chunks.by_ref().zip(ppairs.by_ref()) {
                let (va, fla) = sw::encode_lanes(p[0].0, p[1].0, &sf, &mut rnd);
                let (vb, flb) = sw::encode_lanes(p[0].1, p[1].1, &sf, &mut rnd);
                let (vp, flp) = sw::mul_packed_lanes(va, vb, &sf, &mut rnd);
                let (d0, d1) = sw::decode_lanes(vp, &sf);
                o[0] = d0;
                o[1] = d1;
                count(fla[0] | flb[0] | flp[0]);
                count(fla[1] | flb[1] | flp[1]);
            }
            for (o, &(a, b)) in chunks.into_remainder().iter_mut().zip(ppairs.remainder()) {
                let (wa, fla) = pk::encode_bits(a.to_bits(), &pf, &mut rnd);
                let (wb, flb) = pk::encode_bits(b.to_bits(), &pf, &mut rnd);
                let (wp, flp) = pk::mul_packed(wa, wb, &pf, &mut rnd);
                *o = pk::decode_word(wp, &pf);
                count(fla | flb | flp);
            }
            drop(count);
            self.events.overflows += of;
            self.events.underflows += uf;
            return;
        }
        if self.packed_on() {
            let pf = fmt.packed();
            let mut of = 0u64;
            let mut uf = 0u64;
            mul_pairs_packed(pairs, &pf, &mut rnd, out, |_, fl| {
                of += u64::from(fl.overflow());
                uf += u64::from(fl.underflow());
            });
            self.events.overflows += of;
            self.events.underflows += uf;
            return;
        }
        for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
            let (fa, fla) = encode(a, fmt, &mut rnd);
            let (fb, flb) = encode(b, fmt, &mut rnd);
            let (fc, flc) = sf_mul(fa, fb, fmt, &mut rnd);
            *o = decode(fc, fmt);
            self.track(fla | flb | flc);
        }
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        if self.packed_on() {
            match mode {
                QuantMode::MulOnly => self.stencil_sweep_packed_mul_only(next, u, r),
                QuantMode::Full => self.stencil_sweep_packed_full(next, u, r),
            }
            return;
        }
        if mode == QuantMode::Full {
            // Carrier engine, Full mode: quantized adds and storage — no
            // products can be shared, keep the canonical sequence (PR-1).
            scalar_stencil_step(self, next, u, r, mode);
            return;
        }
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let fmt = self.fmt;
        let mut rnd = Rounder::nearest_even();
        let (fr, flr) = encode(r, fmt, &mut rnd);
        let (f2r, fl2r) = encode(2.0 * r, fmt, &mut rnd);

        // Encode the state once. The scalar path re-encodes `u[j]` for each
        // of its up-to-three uses; encode is deterministic under RNE, so
        // reuse is bit-identical.
        let eb: Vec<(Fp, Flags)> = {
            let mut v = Vec::with_capacity(n);
            for &x in u.iter() {
                v.push(encode(x, fmt, &mut rnd));
            }
            v
        };

        // r ⊗ u[j], shared between the `right` of node j−1 and the `left`
        // of node j+1 (identical operands ⇒ identical product and flags).
        let mut pr_val = vec![0.0f64; n];
        let mut pr_fl = vec![Flags::NONE; n];
        for j in 0..n {
            let (fc, flc) = sf_mul(fr, eb[j].0, fmt, &mut rnd);
            pr_val[j] = decode(fc, fmt);
            pr_fl[j] = flr | eb[j].1 | flc;
        }

        let mut of = 0u64;
        let mut uf = 0u64;
        count_shared_product_events(&pr_fl, &mut of, &mut uf);

        for i in 1..n - 1 {
            let (fc, flc) = sf_mul(f2r, eb[i].0, fmt, &mut rnd);
            let mid = decode(fc, fmt);
            let flm = fl2r | eb[i].1 | flc;
            if flm.overflow() {
                of += 1;
            }
            if flm.underflow() {
                uf += 1;
            }
            next[i] = u[i] + ((pr_val[i - 1] - mid) + pr_val[i + 1]);
        }
        self.events.overflows += of;
        self.events.underflows += uf;
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }
    fn stencil_multi(
        &mut self,
        u: &mut Vec<f64>,
        next: &mut Vec<f64>,
        r: f64,
        mode: QuantMode,
        steps: usize,
        snapshot_every: usize,
        snapshots: &mut Vec<(usize, Vec<f64>)>,
    ) {
        if self.packed_on() && mode == QuantMode::Full && steps > 0 {
            // The tentpole: Full-mode state stays packed across timesteps.
            self.stencil_multi_packed_full(u, next, r, steps, snapshot_every, snapshots);
            return;
        }
        // MulOnly state lives in the f64 carrier between sweeps (the adds
        // are f64 by definition), so iterating the per-sweep engine is
        // already optimal.
        stencil_multi_via_steps(self, u, next, r, mode, steps, snapshot_every, snapshots);
    }
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)], mode: QuantMode) {
        assert_eq!(out.len(), q.len());
        let fmt = self.fmt;
        let mut rnd = Rounder::nearest_even();
        if self.packed_on() {
            let pf = fmt.packed();
            let (wg, flg) = pk::encode_bits(g2.to_bits(), &pf, &mut rnd);
            let mut of = 0u64;
            let mut uf = 0u64;
            let count = |fl: Flags, of: &mut u64, uf: &mut u64| {
                *of += u64::from(fl.overflow());
                *uf += u64::from(fl.underflow());
            };
            for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
                let (w1, fl1) = pk::encode_bits(q1.to_bits(), &pf, &mut rnd);
                let (p1, flp1) = pk::mul_packed(w1, w1, &pf, &mut rnd);
                let q1sq = pk::decode_word(p1, &pf);
                let (w3, fl3) = pk::encode_bits(q3.to_bits(), &pf, &mut rnd);
                let (p3, flp3) = pk::mul_packed(w3, w3, &pf, &mut rnd);
                // g2 · q3²: the scalar path re-encodes the decoded product;
                // encode∘decode is the identity (and flag-free) on packed
                // values, so the product feeds the next multiplication
                // without ever leaving the packed domain.
                let (pg, flpg) = pk::mul_packed(wg, p3, &pf, &mut rnd);
                let gq = pk::decode_word(pg, &pf);
                let t = q1sq / q3;
                count(fl1 | flp1, &mut of, &mut uf);
                count(fl3 | flp3, &mut of, &mut uf);
                count(flg | flpg, &mut of, &mut uf);
                match mode {
                    QuantMode::MulOnly => *o = t + gq,
                    QuantMode::Full => {
                        // add(t, gq): the dividend re-enters the format; the
                        // addend is still packed.
                        let (wt, flt) = pk::encode_bits(t.to_bits(), &pf, &mut rnd);
                        let (wsum, flsum) = pk::add_packed(wt, pg, &pf, &mut rnd);
                        *o = pk::decode_word(wsum, &pf);
                        count(flt | flsum, &mut of, &mut uf);
                    }
                }
            }
            self.events.overflows += of;
            self.events.underflows += uf;
            return;
        }
        if mode == QuantMode::Full {
            // Carrier engine has no fused Full flux: canonical sequence.
            scalar_flux_batch(self, out, g2, q, mode);
            return;
        }
        let (fg, flg) = encode(g2, fmt, &mut rnd);
        let mut of = 0u64;
        let mut uf = 0u64;
        for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
            // q1² and q3²: encode each operand once (the scalar path encodes
            // it twice; the encodings are identical).
            let (fq1, fl1) = encode(q1, fmt, &mut rnd);
            let (p1, flp1) = sf_mul(fq1, fq1, fmt, &mut rnd);
            let q1sq = decode(p1, fmt);
            let (fq3, fl3) = encode(q3, fmt, &mut rnd);
            let (p3, flp3) = sf_mul(fq3, fq3, fmt, &mut rnd);
            let q3sq = decode(p3, fmt);
            // g2 · q3²: the scalar path re-encodes the decoded product.
            let (fq3sq, fl3sq) = encode(q3sq, fmt, &mut rnd);
            let (pg, flpg) = sf_mul(fg, fq3sq, fmt, &mut rnd);
            let gq = decode(pg, fmt);
            *o = q1sq / q3 + gq;
            for fl in [fl1 | flp1, fl3 | flp3, flg | fl3sq | flpg] {
                if fl.overflow() {
                    of += 1;
                }
                if fl.underflow() {
                    uf += 1;
                }
            }
        }
        self.events.overflows += of;
        self.events.underflows += uf;
    }
    fn range_events(&self) -> Option<RangeEvents> {
        Some(self.events)
    }
    fn active_format(&self) -> Option<FpFormat> {
        Some(self.fmt)
    }
    fn fork(&self) -> Option<Box<dyn Arith + Send>> {
        // Per-op results depend only on (fmt, operands) — RNE rounding holds
        // no state — so a worker with fresh counters and the same engine
        // reproduces this unit's arithmetic bit-for-bit on its shard.
        let mut child = FixedArith::new(self.fmt).with_engine(self.engine);
        child.tiling = self.tiling;
        Some(Box::new(child))
    }
    fn absorb(&mut self, child: &dyn Arith) {
        if let Some(ev) = child.range_events() {
            self.events.overflows += ev.overflows;
            self.events.underflows += ev.underflows;
        }
    }
    fn snapshot(&self) -> Option<Box<dyn Arith + Send>> {
        // RNE rounding holds no cross-operation state; the semantic state
        // is (fmt, engine, tiling, counters). Scratch buffers are transient
        // within one call and rebuild on demand.
        let mut copy = FixedArith::new(self.fmt).with_engine(self.engine);
        copy.events = self.events;
        copy.tiling = self.tiling;
        Some(Box::new(copy))
    }
}

/// The runtime-reconfigurable multiplier under test.
///
/// Runs the packed adjustment unit by default
/// ([`R2f2Multiplier::mul_packed`], DESIGN.md §9);
/// [`R2f2Arith::with_engine`] selects the frozen PR-1 cached-carrier engine
/// for perf-baseline runs. Both are bit-identical to the scalar unit.
/// R2F2's truncated datapath has no lane kernels, so [`BatchEngine::Swar`]
/// runs the packed engine here (the variant stays valid so adaptive and
/// comparison harnesses can pass one engine to every backend).
#[derive(Debug)]
pub struct R2f2Arith {
    pub unit: R2f2Multiplier,
    engine: BatchEngine,
}

impl R2f2Arith {
    pub fn new(cfg: R2f2Config) -> R2f2Arith {
        R2f2Arith { unit: R2f2Multiplier::new(cfg), engine: BatchEngine::default() }
    }

    /// Select the batched-engine implementation (all are bit-identical;
    /// `Swar` degrades to `Packed` — see the type docs).
    pub fn with_engine(mut self, engine: BatchEngine) -> R2f2Arith {
        self.engine = engine;
        self
    }
}

impl Arith for R2f2Arith {
    fn name(&self) -> String {
        self.unit.config().to_string()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.unit.mul(a, b)
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        // R2F2 is a multiplier; in Full mode additions run in the *current*
        // effective format (same storage width).
        let fmt = self.unit.config().format(self.unit.split());
        add_f(a, b, fmt).0
    }
    fn quant(&mut self, x: f64) -> f64 {
        let fmt = self.unit.config().format(self.unit.split());
        quantize(x, fmt)
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        // §2's observation: operand ranges are stable within a simulation
        // stage, so the constant operand's encoding (and its redundancy
        // verdict) is derived once per split and reused across the block
        // instead of per multiplication. State transitions stay exact.
        let c = self.unit.prepare_const(a);
        match self.engine {
            BatchEngine::Packed | BatchEngine::Swar => {
                let mut slot = EncSlot::empty();
                for (o, &x) in out.iter_mut().zip(xs.iter()) {
                    *o = self.unit.mul_packed(&c, x, &mut slot);
                }
            }
            BatchEngine::Carrier => {
                for (o, &x) in out.iter_mut().zip(xs.iter()) {
                    *o = self.unit.mul_const(&c, x);
                }
            }
        }
    }
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        assert_eq!(out.len(), pairs.len());
        match self.engine {
            BatchEngine::Packed | BatchEngine::Swar => {
                for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
                    *o = self.unit.mul_packed_pair(a, b);
                }
            }
            BatchEngine::Carrier => {
                for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
                    *o = self.unit.mul(a, b);
                }
            }
        }
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        if mode == QuantMode::Full {
            scalar_stencil_step(self, next, u, r, mode);
            return;
        }
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let cr = self.unit.prepare_const(r);
        let c2r = self.unit.prepare_const(2.0 * r);
        // Sliding-window encode cache: u[j] feeds the `right` of node j−1,
        // the `mid` of node j and the `left` of node j+1; while the split
        // is unchanged those three encodes collapse into one. The packed
        // engine additionally runs the truncated datapath on 64-bit words
        // with direct-bits decode (the §9 packed adjustment unit); repack
        // happens only when `k` actually moves.
        let mut sl = EncSlot::empty();
        let mut sm = EncSlot::empty();
        let mut sr = EncSlot::empty();
        match self.engine {
            BatchEngine::Packed | BatchEngine::Swar => {
                for i in 1..n - 1 {
                    let left = self.unit.mul_packed(&cr, u[i - 1], &mut sl);
                    let mid = self.unit.mul_packed(&c2r, u[i], &mut sm);
                    let right = self.unit.mul_packed(&cr, u[i + 1], &mut sr);
                    next[i] = u[i] + ((left - mid) + right);
                    sl = sm;
                    sm = sr;
                    sr = EncSlot::empty();
                }
            }
            BatchEngine::Carrier => {
                for i in 1..n - 1 {
                    let left = self.unit.mul_const_cached(&cr, u[i - 1], &mut sl);
                    let mid = self.unit.mul_const_cached(&c2r, u[i], &mut sm);
                    let right = self.unit.mul_const_cached(&cr, u[i + 1], &mut sr);
                    next[i] = u[i] + ((left - mid) + right);
                    sl = sm;
                    sm = sr;
                    sr = EncSlot::empty();
                }
            }
        }
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)], mode: QuantMode) {
        if mode == QuantMode::Full {
            // R2F2 is a multiplier: Full-mode adds run through `add` in the
            // current split's format — no fused fast path, keep the
            // canonical sequence.
            scalar_flux_batch(self, out, g2, q, mode);
            return;
        }
        assert_eq!(out.len(), q.len());
        let cg = self.unit.prepare_const(g2);
        match self.engine {
            BatchEngine::Packed | BatchEngine::Swar => {
                let mut slot = EncSlot::empty();
                for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
                    let q1sq = self.unit.mul_packed_pair(q1, q1);
                    let q3sq = self.unit.mul_packed_pair(q3, q3);
                    *o = q1sq / q3 + self.unit.mul_packed(&cg, q3sq, &mut slot);
                }
            }
            BatchEngine::Carrier => {
                for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
                    let q1sq = self.unit.mul(q1, q1);
                    let q3sq = self.unit.mul(q3, q3);
                    *o = q1sq / q3 + self.unit.mul_const(&cg, q3sq);
                }
            }
        }
    }
    fn r2f2_stats(&self) -> Option<Stats> {
        Some(self.unit.stats())
    }
    fn active_format(&self) -> Option<FpFormat> {
        Some(self.unit.config().format(self.unit.split()))
    }
    fn snapshot(&self) -> Option<Box<dyn Arith + Send>> {
        // R2F2 is history-dependent (split register, redundancy streak,
        // adjustment counters) — exactly why it cannot `fork`. The derived
        // `Clone` on [`R2f2Multiplier`] carries all of it, so a snapshot
        // resumes the adjustment trajectory mid-stream bit-exactly.
        Some(Box::new(R2f2Arith { unit: self.unit.clone(), engine: self.engine }))
    }
}

/// Fixed format with **stochastic rounding** — the extension the paper
/// cites from Paxton et al. ("with stochastic rounding, 16-bit half
/// precision may be useful in future climate modeling"). Rounds up with
/// probability `discarded / ulp`, so systematically-swallowed small updates
/// survive in expectation; see the `stochastic_rounding_*` tests and the
/// ablations bench.
#[derive(Debug)]
pub struct StochasticArith {
    pub fmt: FpFormat,
    rounder: crate::softfloat::Rounder,
    events: RangeEvents,
}

impl StochasticArith {
    pub fn new(fmt: FpFormat, seed: u64) -> StochasticArith {
        StochasticArith {
            fmt,
            rounder: crate::softfloat::Rounder::stochastic(seed),
            events: RangeEvents::default(),
        }
    }

    fn track(&mut self, flags: crate::softfloat::Flags) {
        if flags.overflow() {
            self.events.overflows += 1;
        }
        if flags.underflow() {
            self.events.underflows += 1;
        }
    }
}

impl Arith for StochasticArith {
    fn name(&self) -> String {
        format!("{}-sr", self.fmt)
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let (fa, f1) = crate::softfloat::encode(a, self.fmt, &mut self.rounder);
        let (fb, f2) = crate::softfloat::encode(b, self.fmt, &mut self.rounder);
        let (fc, f3) = crate::softfloat::mul(fa, fb, self.fmt, &mut self.rounder);
        self.track(f1 | f2 | f3);
        crate::softfloat::decode(fc, self.fmt)
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        let (fa, f1) = crate::softfloat::encode(a, self.fmt, &mut self.rounder);
        let (fb, f2) = crate::softfloat::encode(b, self.fmt, &mut self.rounder);
        let (fc, f3) = crate::softfloat::add(fa, fb, self.fmt, &mut self.rounder);
        self.track(f1 | f2 | f3);
        crate::softfloat::decode(fc, self.fmt)
    }
    fn quant(&mut self, x: f64) -> f64 {
        let (fp, fl) = crate::softfloat::encode(x, self.fmt, &mut self.rounder);
        self.track(fl);
        crate::softfloat::decode(fp, self.fmt)
    }
    fn range_events(&self) -> Option<RangeEvents> {
        Some(self.events)
    }
    fn active_format(&self) -> Option<FpFormat> {
        Some(self.fmt)
    }
    fn snapshot(&self) -> Option<Box<dyn Arith + Send>> {
        // The rounder's SplitMix64 stream position is part of the semantic
        // state (the §14 draw-order contract): cloning it means the
        // snapshot consumes the *same* draw sequence the original would.
        Some(Box::new(StochasticArith {
            fmt: self.fmt,
            rounder: self.rounder.clone(),
            events: self.events,
        }))
    }
}

/// Decorator that streams every multiplication's operands and result into a
/// callback — the instrumentation behind the Fig. 2 data-distribution study.
pub struct RecordingArith<'a, A: Arith> {
    pub inner: A,
    pub tap: &'a mut dyn FnMut(f64, f64, f64),
}

impl<'a, A: Arith> Arith for RecordingArith<'a, A> {
    fn name(&self) -> String {
        format!("recorded({})", self.inner.name())
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let r = self.inner.mul(a, b);
        (self.tap)(a, b, r);
        r
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        self.inner.add(a, b)
    }
    fn quant(&mut self, x: f64) -> f64 {
        self.inner.quant(x)
    }
    fn r2f2_stats(&self) -> Option<Stats> {
        self.inner.r2f2_stats()
    }
    fn range_events(&self) -> Option<RangeEvents> {
        self.inner.range_events()
    }
    fn active_format(&self) -> Option<FpFormat> {
        self.inner.active_format()
    }
}

/// Solver-facing arithmetic context: applies [`QuantMode`] uniformly so the
/// solvers contain a single code path.
pub struct Ctx<'a> {
    pub be: &'a mut dyn Arith,
    pub mode: QuantMode,
    /// Multiplications issued through this context.
    pub muls: u64,
}

impl<'a> Ctx<'a> {
    pub fn new(be: &'a mut dyn Arith, mode: QuantMode) -> Ctx<'a> {
        Ctx { be, mode, muls: 0 }
    }

    #[inline]
    pub fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.muls += 1;
        self.be.mul(a, b)
    }

    #[inline]
    pub fn add(&mut self, a: f64, b: f64) -> f64 {
        match self.mode {
            QuantMode::MulOnly => a + b,
            QuantMode::Full => self.be.add(a, b),
        }
    }

    #[inline]
    pub fn sub(&mut self, a: f64, b: f64) -> f64 {
        self.add(a, -b)
    }

    #[inline]
    pub fn quant(&mut self, x: f64) -> f64 {
        match self.mode {
            QuantMode::MulOnly => x,
            QuantMode::Full => self.be.quant(x),
        }
    }

    /// Batched constant × slice multiply through the backend.
    pub fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        self.muls += xs.len() as u64;
        self.be.mul_batch(out, a, xs);
    }

    /// Batched pairwise multiply through the backend.
    pub fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        self.muls += pairs.len() as u64;
        self.be.mul_pairs(out, pairs);
    }

    /// One fused heat-stencil sweep (3 multiplications per interior node).
    pub fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64) {
        self.muls += 3 * (u.len() as u64 - 2);
        self.be.stencil_step(next, u, r, self.mode);
    }

    /// Fused multi-step heat run (`3·(n−2)·steps` multiplications); on
    /// return `u` holds the final state and `next` is scratch.
    pub fn stencil_multi(
        &mut self,
        u: &mut Vec<f64>,
        next: &mut Vec<f64>,
        r: f64,
        steps: usize,
        snapshot_every: usize,
        snapshots: &mut Vec<(usize, Vec<f64>)>,
    ) {
        self.muls += 3 * (u.len() as u64 - 2) * steps as u64;
        self.be.stencil_multi(u, next, r, self.mode, steps, snapshot_every, snapshots);
    }

    /// Batched x-momentum flux evaluations (3 multiplications per pair).
    pub fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)]) {
        self.muls += 3 * q.len() as u64;
        self.be.flux_batch(out, g2, q, self.mode);
    }
}

/// Root-mean-square error between two equal-length fields — the scalar
/// "same simulation result?" metric used throughout EXPERIMENTS.md.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

/// Relative L2 error `‖a − b‖ / ‖b‖` (b = reference).
pub fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f64 = b.iter().map(|y| y * y).sum();
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_backend_is_exact() {
        let mut be = F64Arith;
        assert_eq!(be.mul(3.0, 4.0), 12.0);
    }

    #[test]
    fn fixed_backend_counts_events() {
        let mut be = FixedArith::new(FpFormat::E5M10);
        let _ = be.mul(1000.0, 1000.0); // overflow
        let _ = be.mul(1e-3, 1e-3); // underflow
        let ev = be.range_events().unwrap();
        assert_eq!(ev.overflows, 1);
        assert_eq!(ev.underflows, 1);
    }

    #[test]
    fn r2f2_backend_tracks_stats() {
        let mut be = R2f2Arith::new(R2f2Config::C16_393);
        let v = be.mul(300.0, 300.0);
        assert!((v - 9e4).abs() / 9e4 < 1e-2);
        assert!(be.r2f2_stats().unwrap().overflow_adjustments >= 1);
    }

    #[test]
    fn ctx_mode_gates_add_and_quant() {
        let mut be = FixedArith::new(FpFormat::E5M10);
        let mut ctx = Ctx::new(&mut be, QuantMode::MulOnly);
        // In MulOnly mode adds stay exact even for values half can't hold.
        assert_eq!(ctx.add(1e6, 1.0), 1_000_001.0);
        assert_eq!(ctx.quant(1e6), 1e6);
        let mut be = FixedArith::new(FpFormat::E5M10);
        let mut ctx = Ctx::new(&mut be, QuantMode::Full);
        assert_eq!(ctx.quant(1e6), 65504.0); // saturates
    }

    #[test]
    fn ctx_counts_muls() {
        let mut be = F64Arith;
        let mut ctx = Ctx::new(&mut be, QuantMode::MulOnly);
        for _ in 0..5 {
            ctx.mul(1.0, 1.0);
        }
        assert_eq!(ctx.muls, 5);
    }

    #[test]
    fn recording_taps_every_mul() {
        let mut count = 0u32;
        {
            let mut tap = |_a: f64, _b: f64, _r: f64| count += 1;
            let mut be = RecordingArith { inner: F64Arith, tap: &mut tap };
            be.mul(1.0, 2.0);
            be.mul(3.0, 4.0);
        }
        assert_eq!(count, 2);
    }

    #[test]
    fn error_metrics() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 2.0, 4.0];
        assert!((rmse(&a, &b) - (1.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!(rel_l2(&a, &a) == 0.0);
    }

    #[test]
    fn tile_ranges_cover_interior_exactly() {
        for n in [3usize, 4, 65, 100, 4099] {
            for w in [1usize, 7, 32, 4096] {
                let tiles = tile_ranges(n, w);
                assert_eq!(tiles.first().unwrap().0, 1, "n={n} w={w}");
                assert_eq!(tiles.last().unwrap().1, n - 1, "n={n} w={w}");
                for pair in tiles.windows(2) {
                    assert_eq!(pair[0].1, pair[1].0, "n={n} w={w}: contiguous");
                }
                assert!(tiles.iter().all(|&(a, b)| a < b && b - a <= w), "n={n} w={w}");
            }
        }
    }

    /// Operand set spanning in-range, overflowing and underflowing values.
    fn nasty_xs(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::rng::SplitMix64::new(seed);
        let mut xs: Vec<f64> = (0..n)
            .map(|_| {
                let s = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                s * rng.log_uniform(1e-7, 1e7)
            })
            .collect();
        xs.extend_from_slice(&[0.0, -0.0, 65504.0, 1e-8, 3e8]);
        xs
    }

    fn check_mul_batch_equivalence(mk: &dyn Fn() -> Box<dyn Arith>, what: &str) {
        let xs = nasty_xs(400, 0x90);
        for &a in &[0.25, 0.5, 4.9, 2000.0, 1e-4] {
            let mut scalar_be = mk();
            let mut batch_be = mk();
            let want: Vec<f64> = xs.iter().map(|&x| scalar_be.mul(a, x)).collect();
            let mut got = vec![0.0; xs.len()];
            batch_be.mul_batch(&mut got, a, &xs);
            for i in 0..xs.len() {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "{what}: {a} × {} (lane {i})",
                    xs[i]
                );
            }
            assert_eq!(scalar_be.range_events(), batch_be.range_events(), "{what}: events");
            assert_eq!(scalar_be.r2f2_stats(), batch_be.r2f2_stats(), "{what}: stats");
        }
    }

    #[test]
    fn mul_batch_bit_identical_across_backends() {
        check_mul_batch_equivalence(&|| Box::new(F64Arith) as Box<dyn Arith>, "f64");
        check_mul_batch_equivalence(&|| Box::new(F32Arith) as Box<dyn Arith>, "f32");
        check_mul_batch_equivalence(
            &|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>,
            "E5M10",
        );
        check_mul_batch_equivalence(
            &|| {
                Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier))
                    as Box<dyn Arith>
            },
            "E5M10-carrier",
        );
        check_mul_batch_equivalence(
            &|| {
                Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Swar))
                    as Box<dyn Arith>
            },
            "E5M10-swar",
        );
        check_mul_batch_equivalence(
            &|| Box::new(FixedArith::new(FpFormat::new(6, 9))) as Box<dyn Arith>,
            "E6M9",
        );
        check_mul_batch_equivalence(
            &|| Box::new(FixedArith::new(FpFormat::E11M52)) as Box<dyn Arith>,
            "E11M52 (no word fit, carrier fallback)",
        );
        check_mul_batch_equivalence(
            &|| {
                Box::new(FixedArith::new(FpFormat::E8M23).with_engine(BatchEngine::Swar))
                    as Box<dyn Arith>
            },
            "E8M23-swar (no lane fit, packed fallback)",
        );
        check_mul_batch_equivalence(
            &|| Box::new(R2f2Arith::new(R2f2Config::C16_393)) as Box<dyn Arith>,
            "r2f2",
        );
        check_mul_batch_equivalence(
            &|| {
                Box::new(R2f2Arith::new(R2f2Config::C16_393).with_engine(BatchEngine::Carrier))
                    as Box<dyn Arith>
            },
            "r2f2-carrier",
        );
        check_mul_batch_equivalence(
            &|| Box::new(StochasticArith::new(FpFormat::E5M10, 42)) as Box<dyn Arith>,
            "E5M10-sr",
        );
    }

    #[test]
    fn mul_pairs_bit_identical_across_backends() {
        let xs = nasty_xs(300, 0x91);
        let ys = nasty_xs(300, 0x92);
        let pairs: Vec<(f64, f64)> = xs.into_iter().zip(ys).collect();
        #[allow(clippy::type_complexity)]
        let mks: Vec<(Box<dyn Fn() -> Box<dyn Arith>>, &str)> = vec![
            (Box::new(|| Box::new(F64Arith) as Box<dyn Arith>), "f64"),
            (Box::new(|| Box::new(F32Arith) as Box<dyn Arith>), "f32"),
            (Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>), "E5M10"),
            (
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier))
                        as Box<dyn Arith>
                }),
                "E5M10-carrier",
            ),
            (
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Swar))
                        as Box<dyn Arith>
                }),
                "E5M10-swar",
            ),
            (Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_384)) as Box<dyn Arith>), "r2f2"),
            (
                Box::new(|| {
                    Box::new(R2f2Arith::new(R2f2Config::C16_384).with_engine(BatchEngine::Carrier))
                        as Box<dyn Arith>
                }),
                "r2f2-carrier",
            ),
        ];
        for (mk, what) in &mks {
            let mut scalar_be = mk();
            let mut batch_be = mk();
            let want: Vec<f64> = pairs.iter().map(|&(a, b)| scalar_be.mul(a, b)).collect();
            let mut got = vec![0.0; pairs.len()];
            batch_be.mul_pairs(&mut got, &pairs);
            for i in 0..pairs.len() {
                assert_eq!(got[i].to_bits(), want[i].to_bits(), "{what}: lane {i}");
            }
            assert_eq!(scalar_be.range_events(), batch_be.range_events(), "{what}: events");
            assert_eq!(scalar_be.r2f2_stats(), batch_be.r2f2_stats(), "{what}: stats");
        }
    }

    #[test]
    fn stencil_step_bit_identical_across_backends_and_modes() {
        // One stencil sweep over a field that spans the full §3.1 range
        // story: large values near the crest, sub-ulp values in the tails.
        let mut rng = crate::rng::SplitMix64::new(0x93);
        let n = 257;
        let u: Vec<f64> = (0..n)
            .map(|i| {
                let x = i as f64 / (n - 1) as f64;
                500.0 * (std::f64::consts::PI * x).sin() * rng.range_f64(0.99, 1.01)
            })
            .collect();
        let r = 0.25;
        #[allow(clippy::type_complexity)]
        let mks: Vec<(Box<dyn Fn() -> Box<dyn Arith>>, &str)> = vec![
            (Box::new(|| Box::new(F64Arith) as Box<dyn Arith>), "f64"),
            (Box::new(|| Box::new(F32Arith) as Box<dyn Arith>), "f32"),
            (Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>), "E5M10"),
            (
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier))
                        as Box<dyn Arith>
                }),
                "E5M10-carrier",
            ),
            (
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Swar))
                        as Box<dyn Arith>
                }),
                "E5M10-swar",
            ),
            (Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_393)) as Box<dyn Arith>), "r2f2"),
            (
                Box::new(|| {
                    Box::new(R2f2Arith::new(R2f2Config::C16_393).with_engine(BatchEngine::Carrier))
                        as Box<dyn Arith>
                }),
                "r2f2-carrier",
            ),
            (
                Box::new(|| Box::new(StochasticArith::new(FpFormat::E5M10, 7)) as Box<dyn Arith>),
                "E5M10-sr",
            ),
        ];
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            for (mk, what) in &mks {
                let mut scalar_be = mk();
                let mut batch_be = mk();
                let mut want = u.clone();
                let mut got = u.clone();
                scalar_stencil_step(scalar_be.as_mut(), &mut want, &u, r, mode);
                batch_be.stencil_step(&mut got, &u, r, mode);
                for i in 0..n {
                    assert_eq!(
                        got[i].to_bits(),
                        want[i].to_bits(),
                        "{what}/{mode:?}: node {i}: {} vs {}",
                        got[i],
                        want[i]
                    );
                }
                assert_eq!(
                    scalar_be.range_events(),
                    batch_be.range_events(),
                    "{what}/{mode:?}: events"
                );
                assert_eq!(
                    scalar_be.r2f2_stats(),
                    batch_be.r2f2_stats(),
                    "{what}/{mode:?}: stats"
                );
            }
        }
    }

    #[test]
    fn stencil_step_fixed_counts_range_events_like_scalar() {
        // A tiny field drives every r·u product below E5M10's min normal:
        // the deduplicated fast path must still report the scalar path's
        // event multiplicity (each product is counted once per use).
        let n = 33;
        let u: Vec<f64> = (0..n).map(|i| 1e-4 * (i as f64 + 1.0)).collect();
        let r = 0.25;
        let mut scalar_be = FixedArith::new(FpFormat::E5M10);
        let mut batch_be = FixedArith::new(FpFormat::E5M10);
        let mut want = u.clone();
        let mut got = u.clone();
        scalar_stencil_step(&mut scalar_be, &mut want, &u, r, QuantMode::MulOnly);
        batch_be.stencil_step(&mut got, &u, r, QuantMode::MulOnly);
        let se = scalar_be.range_events().unwrap();
        let be = batch_be.range_events().unwrap();
        assert!(se.underflows > 0, "test field must actually underflow");
        assert_eq!(se, be);
        for i in 0..n {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "node {i}");
        }
    }

    #[test]
    fn flux_batch_bit_identical_across_backends_and_modes() {
        let mut rng = crate::rng::SplitMix64::new(0x94);
        // Shelf-scale operands (the Fig. 8 regime): h ≈ 150, u ≈ ±40.
        let q: Vec<(f64, f64)> = (0..500)
            .map(|_| (rng.range_f64(-40.0, 40.0), rng.range_f64(140.0, 160.0)))
            .collect();
        let g2 = 4.9;
        #[allow(clippy::type_complexity)]
        let mks: Vec<(Box<dyn Fn() -> Box<dyn Arith>>, &str)> = vec![
            (Box::new(|| Box::new(F64Arith) as Box<dyn Arith>), "f64"),
            (Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>), "E5M10"),
            (
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier))
                        as Box<dyn Arith>
                }),
                "E5M10-carrier",
            ),
            (
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Swar))
                        as Box<dyn Arith>
                }),
                "E5M10-swar (flux stays on the packed path)",
            ),
            (Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_384)) as Box<dyn Arith>), "r2f2"),
            (
                Box::new(|| {
                    Box::new(R2f2Arith::new(R2f2Config::C16_384).with_engine(BatchEngine::Carrier))
                        as Box<dyn Arith>
                }),
                "r2f2-carrier",
            ),
        ];
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            for (mk, what) in &mks {
                let mut scalar_be = mk();
                let mut batch_be = mk();
                let mut want = vec![0.0; q.len()];
                scalar_flux_batch(scalar_be.as_mut(), &mut want, g2, &q, mode);
                let mut got = vec![0.0; q.len()];
                batch_be.flux_batch(&mut got, g2, &q, mode);
                for i in 0..q.len() {
                    assert_eq!(got[i].to_bits(), want[i].to_bits(), "{what}/{mode:?}: lane {i}");
                }
                assert_eq!(
                    scalar_be.range_events(),
                    batch_be.range_events(),
                    "{what}/{mode:?}: events"
                );
                assert_eq!(
                    scalar_be.r2f2_stats(),
                    batch_be.r2f2_stats(),
                    "{what}/{mode:?}: stats"
                );
            }
        }
    }

    #[test]
    fn stencil_multi_matches_iterated_steps() {
        // The multi-step driver vs the iterated single-sweep reference —
        // values, snapshots and counters — for the backends with packed
        // cross-step state as well as the defaults.
        let mut rng = crate::rng::SplitMix64::new(0x95);
        let n = 65;
        let u0: Vec<f64> = (0..n)
            .map(|i| {
                let x = i as f64 / (n - 1) as f64;
                400.0 * (std::f64::consts::PI * x).sin() * rng.range_f64(0.99, 1.01)
            })
            .collect();
        let r = 0.25;
        #[allow(clippy::type_complexity)]
        let mks: Vec<(Box<dyn Fn() -> Box<dyn Arith>>, &str)> = vec![
            (Box::new(|| Box::new(F64Arith) as Box<dyn Arith>), "f64"),
            (Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>), "E5M10"),
            (
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier))
                        as Box<dyn Arith>
                }),
                "E5M10-carrier",
            ),
            (
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Swar))
                        as Box<dyn Arith>
                }),
                "E5M10-swar",
            ),
            (
                // Non-divisible tiles (interior 63 = 9×7) across a pool —
                // tiled multi-step must match the iterated single sweep.
                Box::new(|| {
                    Box::new(FixedArith::new(FpFormat::E5M10).with_tiling(4, 7)) as Box<dyn Arith>
                }),
                "E5M10-tiled(4w,7)",
            ),
            (
                Box::new(|| {
                    Box::new(
                        FixedArith::new(FpFormat::E5M10)
                            .with_engine(BatchEngine::Swar)
                            .with_tiling(3, 10),
                    ) as Box<dyn Arith>
                }),
                "E5M10-swar-tiled(3w,10)",
            ),
            (Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_393)) as Box<dyn Arith>), "r2f2"),
            (
                Box::new(|| Box::new(StochasticArith::new(FpFormat::E5M10, 3)) as Box<dyn Arith>),
                "E5M10-sr",
            ),
        ];
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            for steps in [0usize, 1, 7, 40] {
                for (mk, what) in &mks {
                    let mut ref_be = mk();
                    let mut multi_be = mk();
                    let what = format!("{what}/{mode:?}/steps={steps}");

                    let mut u_ref = u0.clone();
                    let mut next_ref = u0.clone();
                    let mut snaps_ref = Vec::new();
                    stencil_multi_via_steps(
                        ref_be.as_mut(),
                        &mut u_ref,
                        &mut next_ref,
                        r,
                        mode,
                        steps,
                        10,
                        &mut snaps_ref,
                    );

                    let mut u_got = u0.clone();
                    let mut next_got = u0.clone();
                    let mut snaps_got = Vec::new();
                    multi_be.stencil_multi(
                        &mut u_got,
                        &mut next_got,
                        r,
                        mode,
                        steps,
                        10,
                        &mut snaps_got,
                    );

                    for i in 0..n {
                        assert_eq!(u_got[i].to_bits(), u_ref[i].to_bits(), "{what}: node {i}");
                    }
                    assert_eq!(ref_be.range_events(), multi_be.range_events(), "{what}: events");
                    assert_eq!(ref_be.r2f2_stats(), multi_be.r2f2_stats(), "{what}: stats");
                    assert_eq!(snaps_got.len(), snaps_ref.len(), "{what}: snapshot count");
                    for (s, (g, w)) in snaps_got.iter().zip(snaps_ref.iter()).enumerate() {
                        assert_eq!(g.0, w.0, "{what}: snapshot step {s}");
                        for i in 0..n {
                            assert_eq!(
                                g.1[i].to_bits(),
                                w.1[i].to_bits(),
                                "{what}: snapshot {s} node {i}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn ctx_batched_ops_count_muls() {
        let mut be = F64Arith;
        let mut ctx = Ctx::new(&mut be, QuantMode::MulOnly);
        let mut out = [0.0; 4];
        ctx.mul_batch(&mut out, 2.0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ctx.muls, 4);
        ctx.mul_pairs(&mut out, &[(1.0, 2.0); 4]);
        assert_eq!(ctx.muls, 8);
        let u = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut next = [0.0; 5];
        ctx.stencil_step(&mut next, &u, 0.25);
        assert_eq!(ctx.muls, 8 + 9); // 3 interior nodes × 3 muls
        ctx.flux_batch(&mut out, 4.9, &[(1.0, 2.0); 4]);
        assert_eq!(ctx.muls, 17 + 12);
    }

    /// Drive `be` through a mixed operation stream and return the outputs.
    fn snapshot_probe(be: &mut dyn Arith, rounds: usize) -> Vec<u64> {
        let mut bits = Vec::new();
        for r in 0..rounds {
            let a = 1.25 + r as f64 * 0.375;
            let xs = [0.5, -3.0, 700.0, 1e-6, 42.0, -0.125];
            let mut out = [0.0; 6];
            be.mul_batch(&mut out, a, &xs);
            bits.extend(out.iter().map(|v| v.to_bits()));
            let pairs = [(a, 2.5), (-a, 1e3), (a * 0.01, a)];
            let mut po = [0.0; 3];
            be.mul_pairs(&mut po, &pairs);
            bits.extend(po.iter().map(|v| v.to_bits()));
        }
        bits
    }

    #[test]
    fn snapshot_resumes_bit_identically_for_every_backend() {
        // The jobs-layer checkpoint contract: run a prefix, snapshot, then
        // the snapshot's continuation must bit-equal the original's — for
        // history-free (fixed) AND history-dependent (R2F2, stochastic)
        // units, counters included.
        let mk: Vec<(&str, Box<dyn Fn() -> Box<dyn Arith + Send>>)> = vec![
            ("f64", Box::new(|| Box::new(F64Arith))),
            ("f32", Box::new(|| Box::new(F32Arith))),
            ("fixed", Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)))),
            (
                "r2f2",
                Box::new(|| Box::new(R2f2Arith::new(crate::r2f2core::R2f2Config::C16_393))),
            ),
            ("stochastic", Box::new(|| Box::new(StochasticArith::new(FpFormat::E5M10, 7)))),
        ];
        for (name, make) in &mk {
            let mut whole = make();
            let whole_bits = snapshot_probe(whole.as_mut(), 8);

            let mut prefix = make();
            let prefix_bits = snapshot_probe(prefix.as_mut(), 5);
            let mut resumed = prefix.snapshot().unwrap_or_else(|| panic!("{name}: snapshot"));
            // Continue on the snapshot: rounds 5..8 of the same stream.
            let mut tail_bits = Vec::new();
            for r in 5..8 {
                let a = 1.25 + r as f64 * 0.375;
                let xs = [0.5, -3.0, 700.0, 1e-6, 42.0, -0.125];
                let mut out = [0.0; 6];
                resumed.mul_batch(&mut out, a, &xs);
                tail_bits.extend(out.iter().map(|v| v.to_bits()));
                let pairs = [(a, 2.5), (-a, 1e3), (a * 0.01, a)];
                let mut po = [0.0; 3];
                resumed.mul_pairs(&mut po, &pairs);
                tail_bits.extend(po.iter().map(|v| v.to_bits()));
            }
            let mut stitched = prefix_bits;
            stitched.extend(tail_bits);
            assert_eq!(stitched, whole_bits, "{name}: snapshot continuation diverged");
            assert_eq!(
                resumed.range_events(),
                whole.range_events(),
                "{name}: range-event counters must carry across the snapshot"
            );
            assert_eq!(
                resumed.r2f2_stats(),
                whole.r2f2_stats(),
                "{name}: adjustment counters must carry across the snapshot"
            );
        }
    }

    #[test]
    fn snapshot_is_independent_of_the_original() {
        // Advancing the original after the snapshot must not disturb the
        // snapshot (checkpoints outlive the epoch that made them).
        let mut be = R2f2Arith::new(crate::r2f2core::R2f2Config::C16_393);
        snapshot_probe(&mut be, 3);
        let snap = be.snapshot().unwrap();
        let stats_at_snapshot = snap.r2f2_stats();
        snapshot_probe(&mut be, 4); // keep mutating the original
        assert_eq!(snap.r2f2_stats(), stats_at_snapshot, "snapshot state leaked");
    }
}
