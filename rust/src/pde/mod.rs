//! PDE case studies (§2, §5.3): the 1D heat equation and the 2D shallow
//! water equations, each runnable under interchangeable arithmetic backends
//! so a single solver implementation serves every precision experiment.
//!
//! The paper's methodology replaces *multiplications* with the unit under
//! test (f64 / f32 / fixed `ExMy` / R2F2), converting operands in and the
//! result back out (§5.2). [`Arith`] is that pluggable multiplier;
//! [`QuantMode`] selects whether only multiplications are quantized
//! (`MulOnly`, the paper's R2F2 case studies) or the whole state and the
//! additions too (`Full`, the paper's "simulation using half precision"
//! baseline of Fig. 1).

pub mod heat1d;
pub mod init;
pub mod swe2d;

use crate::r2f2core::{R2f2Config, R2f2Multiplier, Stats};
use crate::softfloat::{add_f, mul_f, quantize, quantize_flagged, FpFormat};

/// How much of the solver arithmetic routes through the backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    /// Only multiplications are quantized; additions and the stored state
    /// stay in the f64 carrier (the paper's R2F2 deployment, §5.3).
    MulOnly,
    /// Multiplications, additions and state storage all go through the
    /// format (a true low-precision simulation — Fig. 1's baseline).
    Full,
}

/// Range-event counters accumulated by the fixed-format backend (the
/// evidence for *why* a fixed type fails).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RangeEvents {
    pub overflows: u64,
    pub underflows: u64,
}

/// A pluggable arithmetic unit. One instance is owned by one solver run, so
/// stateful backends (R2F2's split register) behave like one hardware
/// multiplier seeing the solver's multiplication stream in order.
pub trait Arith {
    /// Human-readable backend name for reports (e.g. `E5M10`, `<3,9,3>`).
    fn name(&self) -> String;
    /// One multiplication through the unit (operands converted in, result
    /// converted back).
    fn mul(&mut self, a: f64, b: f64) -> f64;
    /// One addition. Defaults to the f64 carrier; `Full` mode overrides.
    fn add(&mut self, a: f64, b: f64) -> f64 {
        a + b
    }
    /// Quantize a state value for storage (`Full` mode only).
    fn quant(&mut self, x: f64) -> f64 {
        x
    }
    /// R2F2 adjustment statistics, if the backend has them.
    fn r2f2_stats(&self) -> Option<Stats> {
        None
    }
    /// Overflow/underflow events, if the backend tracks them.
    fn range_events(&self) -> Option<RangeEvents> {
        None
    }
}

/// IEEE double — the ground-truth backend.
#[derive(Debug, Default)]
pub struct F64Arith;

impl Arith for F64Arith {
    fn name(&self) -> String {
        "f64".into()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        a * b
    }
}

/// Hardware single precision (the paper's "32-bit" reference).
#[derive(Debug, Default)]
pub struct F32Arith;

impl Arith for F32Arith {
    fn name(&self) -> String {
        "f32".into()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        (a as f32 * b as f32) as f64
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        (a as f32 + b as f32) as f64
    }
    fn quant(&mut self, x: f64) -> f64 {
        x as f32 as f64
    }
}

/// A fixed `ExMy` software format (E5M10 = the paper's standard half
/// baseline). Counts range events so reports can show where it breaks.
#[derive(Debug)]
pub struct FixedArith {
    pub fmt: FpFormat,
    events: RangeEvents,
}

impl FixedArith {
    pub fn new(fmt: FpFormat) -> FixedArith {
        FixedArith { fmt, events: RangeEvents::default() }
    }

    fn track(&mut self, flags: crate::softfloat::Flags) {
        if flags.overflow() {
            self.events.overflows += 1;
        }
        if flags.underflow() {
            self.events.underflows += 1;
        }
    }
}

impl Arith for FixedArith {
    fn name(&self) -> String {
        self.fmt.to_string()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let (v, fl) = mul_f(a, b, self.fmt);
        self.track(fl);
        v
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        let (v, fl) = add_f(a, b, self.fmt);
        self.track(fl);
        v
    }
    fn quant(&mut self, x: f64) -> f64 {
        let (v, fl) = quantize_flagged(x, self.fmt);
        self.track(fl);
        v
    }
    fn range_events(&self) -> Option<RangeEvents> {
        Some(self.events)
    }
}

/// The runtime-reconfigurable multiplier under test.
#[derive(Debug)]
pub struct R2f2Arith {
    pub unit: R2f2Multiplier,
}

impl R2f2Arith {
    pub fn new(cfg: R2f2Config) -> R2f2Arith {
        R2f2Arith { unit: R2f2Multiplier::new(cfg) }
    }
}

impl Arith for R2f2Arith {
    fn name(&self) -> String {
        self.unit.config().to_string()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.unit.mul(a, b)
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        // R2F2 is a multiplier; in Full mode additions run in the *current*
        // effective format (same storage width).
        let fmt = self.unit.config().format(self.unit.split());
        add_f(a, b, fmt).0
    }
    fn quant(&mut self, x: f64) -> f64 {
        let fmt = self.unit.config().format(self.unit.split());
        quantize(x, fmt)
    }
    fn r2f2_stats(&self) -> Option<Stats> {
        Some(self.unit.stats())
    }
}

/// Fixed format with **stochastic rounding** — the extension the paper
/// cites from Paxton et al. ("with stochastic rounding, 16-bit half
/// precision may be useful in future climate modeling"). Rounds up with
/// probability `discarded / ulp`, so systematically-swallowed small updates
/// survive in expectation; see the `stochastic_rounding_*` tests and the
/// ablations bench.
#[derive(Debug)]
pub struct StochasticArith {
    pub fmt: FpFormat,
    rounder: crate::softfloat::Rounder,
    events: RangeEvents,
}

impl StochasticArith {
    pub fn new(fmt: FpFormat, seed: u64) -> StochasticArith {
        StochasticArith {
            fmt,
            rounder: crate::softfloat::Rounder::stochastic(seed),
            events: RangeEvents::default(),
        }
    }

    fn track(&mut self, flags: crate::softfloat::Flags) {
        if flags.overflow() {
            self.events.overflows += 1;
        }
        if flags.underflow() {
            self.events.underflows += 1;
        }
    }
}

impl Arith for StochasticArith {
    fn name(&self) -> String {
        format!("{}-sr", self.fmt)
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let (fa, f1) = crate::softfloat::encode(a, self.fmt, &mut self.rounder);
        let (fb, f2) = crate::softfloat::encode(b, self.fmt, &mut self.rounder);
        let (fc, f3) = crate::softfloat::mul(fa, fb, self.fmt, &mut self.rounder);
        self.track(f1 | f2 | f3);
        crate::softfloat::decode(fc, self.fmt)
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        let (fa, f1) = crate::softfloat::encode(a, self.fmt, &mut self.rounder);
        let (fb, f2) = crate::softfloat::encode(b, self.fmt, &mut self.rounder);
        let (fc, f3) = crate::softfloat::add(fa, fb, self.fmt, &mut self.rounder);
        self.track(f1 | f2 | f3);
        crate::softfloat::decode(fc, self.fmt)
    }
    fn quant(&mut self, x: f64) -> f64 {
        let (fp, fl) = crate::softfloat::encode(x, self.fmt, &mut self.rounder);
        self.track(fl);
        crate::softfloat::decode(fp, self.fmt)
    }
    fn range_events(&self) -> Option<RangeEvents> {
        Some(self.events)
    }
}

/// Decorator that streams every multiplication's operands and result into a
/// callback — the instrumentation behind the Fig. 2 data-distribution study.
pub struct RecordingArith<'a, A: Arith> {
    pub inner: A,
    pub tap: &'a mut dyn FnMut(f64, f64, f64),
}

impl<'a, A: Arith> Arith for RecordingArith<'a, A> {
    fn name(&self) -> String {
        format!("recorded({})", self.inner.name())
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let r = self.inner.mul(a, b);
        (self.tap)(a, b, r);
        r
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        self.inner.add(a, b)
    }
    fn quant(&mut self, x: f64) -> f64 {
        self.inner.quant(x)
    }
    fn r2f2_stats(&self) -> Option<Stats> {
        self.inner.r2f2_stats()
    }
    fn range_events(&self) -> Option<RangeEvents> {
        self.inner.range_events()
    }
}

/// Solver-facing arithmetic context: applies [`QuantMode`] uniformly so the
/// solvers contain a single code path.
pub struct Ctx<'a> {
    pub be: &'a mut dyn Arith,
    pub mode: QuantMode,
    /// Multiplications issued through this context.
    pub muls: u64,
}

impl<'a> Ctx<'a> {
    pub fn new(be: &'a mut dyn Arith, mode: QuantMode) -> Ctx<'a> {
        Ctx { be, mode, muls: 0 }
    }

    #[inline]
    pub fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.muls += 1;
        self.be.mul(a, b)
    }

    #[inline]
    pub fn add(&mut self, a: f64, b: f64) -> f64 {
        match self.mode {
            QuantMode::MulOnly => a + b,
            QuantMode::Full => self.be.add(a, b),
        }
    }

    #[inline]
    pub fn sub(&mut self, a: f64, b: f64) -> f64 {
        self.add(a, -b)
    }

    #[inline]
    pub fn quant(&mut self, x: f64) -> f64 {
        match self.mode {
            QuantMode::MulOnly => x,
            QuantMode::Full => self.be.quant(x),
        }
    }
}

/// Root-mean-square error between two equal-length fields — the scalar
/// "same simulation result?" metric used throughout EXPERIMENTS.md.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

/// Relative L2 error `‖a − b‖ / ‖b‖` (b = reference).
pub fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f64 = b.iter().map(|y| y * y).sum();
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_backend_is_exact() {
        let mut be = F64Arith;
        assert_eq!(be.mul(3.0, 4.0), 12.0);
    }

    #[test]
    fn fixed_backend_counts_events() {
        let mut be = FixedArith::new(FpFormat::E5M10);
        let _ = be.mul(1000.0, 1000.0); // overflow
        let _ = be.mul(1e-3, 1e-3); // underflow
        let ev = be.range_events().unwrap();
        assert_eq!(ev.overflows, 1);
        assert_eq!(ev.underflows, 1);
    }

    #[test]
    fn r2f2_backend_tracks_stats() {
        let mut be = R2f2Arith::new(R2f2Config::C16_393);
        let v = be.mul(300.0, 300.0);
        assert!((v - 9e4).abs() / 9e4 < 1e-2);
        assert!(be.r2f2_stats().unwrap().overflow_adjustments >= 1);
    }

    #[test]
    fn ctx_mode_gates_add_and_quant() {
        let mut be = FixedArith::new(FpFormat::E5M10);
        let mut ctx = Ctx::new(&mut be, QuantMode::MulOnly);
        // In MulOnly mode adds stay exact even for values half can't hold.
        assert_eq!(ctx.add(1e6, 1.0), 1_000_001.0);
        assert_eq!(ctx.quant(1e6), 1e6);
        let mut be = FixedArith::new(FpFormat::E5M10);
        let mut ctx = Ctx::new(&mut be, QuantMode::Full);
        assert_eq!(ctx.quant(1e6), 65504.0); // saturates
    }

    #[test]
    fn ctx_counts_muls() {
        let mut be = F64Arith;
        let mut ctx = Ctx::new(&mut be, QuantMode::MulOnly);
        for _ in 0..5 {
            ctx.mul(1.0, 1.0);
        }
        assert_eq!(ctx.muls, 5);
    }

    #[test]
    fn recording_taps_every_mul() {
        let mut count = 0u32;
        {
            let mut tap = |_a: f64, _b: f64, _r: f64| count += 1;
            let mut be = RecordingArith { inner: F64Arith, tap: &mut tap };
            be.mul(1.0, 2.0);
            be.mul(3.0, 4.0);
        }
        assert_eq!(count, 2);
    }

    #[test]
    fn error_metrics() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 2.0, 4.0];
        assert!((rmse(&a, &b) - (1.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!(rel_l2(&a, &a) == 0.0);
    }
}
