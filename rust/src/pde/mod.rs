//! PDE case studies (§2, §5.3): the 1D heat equation and the 2D shallow
//! water equations, each runnable under interchangeable arithmetic backends
//! so a single solver implementation serves every precision experiment.
//!
//! The paper's methodology replaces *multiplications* with the unit under
//! test (f64 / f32 / fixed `ExMy` / R2F2), converting operands in and the
//! result back out (§5.2). [`Arith`] is that pluggable multiplier;
//! [`QuantMode`] selects whether only multiplications are quantized
//! (`MulOnly`, the paper's R2F2 case studies) or the whole state and the
//! additions too (`Full`, the paper's "simulation using half precision"
//! baseline of Fig. 1).

pub mod heat1d;
pub mod init;
pub mod swe2d;

use crate::r2f2core::{EncSlot, R2f2Config, R2f2Multiplier, Stats};
use crate::softfloat::{
    add_f, decode, encode, mul as sf_mul, mul_batch_f, mul_f, mul_pairs_f, quantize,
    quantize_flagged, Flags, Fp, FpFormat, Rounder,
};

/// How much of the solver arithmetic routes through the backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    /// Only multiplications are quantized; additions and the stored state
    /// stay in the f64 carrier (the paper's R2F2 deployment, §5.3).
    MulOnly,
    /// Multiplications, additions and state storage all go through the
    /// format (a true low-precision simulation — Fig. 1's baseline).
    Full,
}

/// Range-event counters accumulated by the fixed-format backend (the
/// evidence for *why* a fixed type fails).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RangeEvents {
    pub overflows: u64,
    pub underflows: u64,
}

/// A pluggable arithmetic unit. One instance is owned by one solver run, so
/// stateful backends (R2F2's split register) behave like one hardware
/// multiplier seeing the solver's multiplication stream in order.
///
/// Besides the scalar operations, the trait carries the **batched engine**
/// (DESIGN.md §8): slice-level operations with default implementations that
/// replay the scalar path, and per-backend fast paths that hoist
/// loop-invariant work (dynamic dispatch, constant-operand encodes, format
/// decomposition) out of the inner loop. The contract is strict: a batched
/// call must produce **bit-identical results and identical counters** to
/// the equivalent scalar sequence — `rust/tests/batched_vs_scalar.rs`
/// enforces it per backend.
///
/// ```
/// use r2f2::pde::{Arith, F64Arith};
///
/// let mut unit = F64Arith;
/// assert_eq!(unit.mul(3.0, 4.0), 12.0);
///
/// let mut out = [0.0; 3];
/// unit.mul_batch(&mut out, 2.0, &[1.0, 2.0, 3.0]);
/// assert_eq!(out, [2.0, 4.0, 6.0]);
/// ```
pub trait Arith {
    /// Human-readable backend name for reports (e.g. `E5M10`, `<3,9,3>`).
    fn name(&self) -> String;
    /// One multiplication through the unit (operands converted in, result
    /// converted back).
    fn mul(&mut self, a: f64, b: f64) -> f64;
    /// One addition. Defaults to the f64 carrier; `Full` mode overrides.
    fn add(&mut self, a: f64, b: f64) -> f64 {
        a + b
    }
    /// Quantize a state value for storage (`Full` mode only).
    fn quant(&mut self, x: f64) -> f64 {
        x
    }
    /// Batched constant × slice multiply: `out[i] = a ⊗ xs[i]`, issued in
    /// index order. Bit-identical to the scalar loop, including counters.
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = self.mul(a, x);
        }
    }
    /// Batched pairwise multiply: `out[i] = pairs[i].0 ⊗ pairs[i].1`, in
    /// index order. Bit-identical to the scalar loop, including counters.
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        assert_eq!(out.len(), pairs.len());
        for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
            *o = self.mul(a, b);
        }
    }
    /// Fused heat stencil sweep: for every interior node
    /// `next[i] = u[i] + (r·u[i−1] − 2r·u[i] + r·u[i+1])` with the three
    /// multiplications routed through the unit in the canonical per-node
    /// order (left, mid, right), and boundary nodes copied. `mode` selects
    /// whether the additions and storage quantization also go through the
    /// backend, exactly as the scalar solver does.
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        scalar_stencil_step(self, next, u, r, mode);
    }
    /// Fused shallow-water x-momentum flux batch: for each `(q1, q3)` pair
    /// compute `q1²/q3 + g2·q3²` with its three multiplications (`q1·q1`,
    /// `q3·q3`, `g2·q3²`) through the unit, in index order.
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)]) {
        assert_eq!(out.len(), q.len());
        for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
            let q1sq = self.mul(q1, q1);
            let q3sq = self.mul(q3, q3);
            *o = q1sq / q3 + self.mul(g2, q3sq);
        }
    }
    /// R2F2 adjustment statistics, if the backend has them.
    fn r2f2_stats(&self) -> Option<Stats> {
        None
    }
    /// Overflow/underflow events, if the backend tracks them.
    fn range_events(&self) -> Option<RangeEvents> {
        None
    }
}

/// The canonical scalar heat-stencil sequence — the reference semantics the
/// batched fast paths must reproduce bit-for-bit. Shared by the default
/// [`Arith::stencil_step`] and by backends that fall back for modes they do
/// not accelerate.
pub fn scalar_stencil_step<A: Arith + ?Sized>(
    be: &mut A,
    next: &mut [f64],
    u: &[f64],
    r: f64,
    mode: QuantMode,
) {
    let n = u.len();
    assert_eq!(next.len(), n);
    assert!(n >= 3);
    let two_r = 2.0 * r;
    for i in 1..n - 1 {
        let left = be.mul(r, u[i - 1]);
        let mid = be.mul(two_r, u[i]);
        let right = be.mul(r, u[i + 1]);
        match mode {
            QuantMode::MulOnly => {
                next[i] = u[i] + ((left - mid) + right);
            }
            QuantMode::Full => {
                let s = be.add(left, -mid);
                let du = be.add(s, right);
                let unew = be.add(u[i], du);
                next[i] = be.quant(unew);
            }
        }
    }
    next[0] = u[0];
    next[n - 1] = u[n - 1];
}

/// IEEE double — the ground-truth backend.
#[derive(Debug, Default)]
pub struct F64Arith;

impl Arith for F64Arith {
    fn name(&self) -> String {
        "f64".into()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        a * b
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = a * x;
        }
    }
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        assert_eq!(out.len(), pairs.len());
        for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
            *o = a * b;
        }
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, _mode: QuantMode) {
        // add/quant are identity for f64, so Full and MulOnly coincide and
        // the whole sweep vectorizes as one tight loop.
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let two_r = 2.0 * r;
        for i in 1..n - 1 {
            next[i] = u[i] + ((r * u[i - 1] - two_r * u[i]) + r * u[i + 1]);
        }
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)]) {
        assert_eq!(out.len(), q.len());
        for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
            *o = q1 * q1 / q3 + g2 * (q3 * q3);
        }
    }
}

/// Hardware single precision (the paper's "32-bit" reference).
#[derive(Debug, Default)]
pub struct F32Arith;

impl Arith for F32Arith {
    fn name(&self) -> String {
        "f32".into()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        (a as f32 * b as f32) as f64
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        (a as f32 + b as f32) as f64
    }
    fn quant(&mut self, x: f64) -> f64 {
        x as f32 as f64
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        let af = a as f32;
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = (af * x as f32) as f64;
        }
    }
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        assert_eq!(out.len(), pairs.len());
        for (o, &(a, b)) in out.iter_mut().zip(pairs.iter()) {
            *o = (a as f32 * b as f32) as f64;
        }
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        if mode == QuantMode::Full {
            // Additions and storage also run in f32; keep the canonical
            // sequence (still monomorphized — no per-mul dynamic dispatch).
            scalar_stencil_step(self, next, u, r, mode);
            return;
        }
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let rf = r as f32;
        let two_rf = (2.0 * r) as f32;
        for i in 1..n - 1 {
            let left = (rf * u[i - 1] as f32) as f64;
            let mid = (two_rf * u[i] as f32) as f64;
            let right = (rf * u[i + 1] as f32) as f64;
            next[i] = u[i] + ((left - mid) + right);
        }
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }
}

/// A fixed `ExMy` software format (E5M10 = the paper's standard half
/// baseline). Counts range events so reports can show where it breaks.
#[derive(Debug)]
pub struct FixedArith {
    pub fmt: FpFormat,
    events: RangeEvents,
}

impl FixedArith {
    pub fn new(fmt: FpFormat) -> FixedArith {
        FixedArith { fmt, events: RangeEvents::default() }
    }

    fn track(&mut self, flags: crate::softfloat::Flags) {
        if flags.overflow() {
            self.events.overflows += 1;
        }
        if flags.underflow() {
            self.events.underflows += 1;
        }
    }
}

impl Arith for FixedArith {
    fn name(&self) -> String {
        self.fmt.to_string()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let (v, fl) = mul_f(a, b, self.fmt);
        self.track(fl);
        v
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        let (v, fl) = add_f(a, b, self.fmt);
        self.track(fl);
        v
    }
    fn quant(&mut self, x: f64) -> f64 {
        let (v, fl) = quantize_flagged(x, self.fmt);
        self.track(fl);
        v
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        let mut flags = vec![Flags::NONE; xs.len()];
        mul_batch_f(a, xs, self.fmt, out, &mut flags);
        for fl in &flags {
            self.track(*fl);
        }
    }
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        let mut flags = vec![Flags::NONE; pairs.len()];
        mul_pairs_f(pairs, self.fmt, out, &mut flags);
        for fl in &flags {
            self.track(*fl);
        }
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        if mode == QuantMode::Full {
            // Full mode also quantizes the adds and the stored state; no
            // products can be shared there, so keep the canonical sequence.
            scalar_stencil_step(self, next, u, r, mode);
            return;
        }
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let fmt = self.fmt;
        let mut rnd = Rounder::nearest_even();
        let (fr, flr) = encode(r, fmt, &mut rnd);
        let (f2r, fl2r) = encode(2.0 * r, fmt, &mut rnd);

        // Encode the state once. The scalar path re-encodes `u[j]` for each
        // of its up-to-three uses; encode is deterministic under RNE, so
        // reuse is bit-identical.
        let eb: Vec<(Fp, Flags)> = {
            let mut v = Vec::with_capacity(n);
            for &x in u.iter() {
                v.push(encode(x, fmt, &mut rnd));
            }
            v
        };

        // r ⊗ u[j], shared between the `right` of node j−1 and the `left`
        // of node j+1 (identical operands ⇒ identical product and flags).
        let mut pr_val = vec![0.0f64; n];
        let mut pr_fl = vec![Flags::NONE; n];
        for j in 0..n {
            let (fc, flc) = sf_mul(fr, eb[j].0, fmt, &mut rnd);
            pr_val[j] = decode(fc, fmt);
            pr_fl[j] = flr | eb[j].1 | flc;
        }

        // Range events with the scalar path's multiplicity: the product
        // r·u[j] is tracked once per use — as `left` when j ≤ n−3 and as
        // `right` when j ≥ 2.
        let mut of = 0u64;
        let mut uf = 0u64;
        for j in 0..n {
            let mult = u64::from(j + 3 <= n) + u64::from(j >= 2);
            if pr_fl[j].overflow() {
                of += mult;
            }
            if pr_fl[j].underflow() {
                uf += mult;
            }
        }

        for i in 1..n - 1 {
            let (fc, flc) = sf_mul(f2r, eb[i].0, fmt, &mut rnd);
            let mid = decode(fc, fmt);
            let flm = fl2r | eb[i].1 | flc;
            if flm.overflow() {
                of += 1;
            }
            if flm.underflow() {
                uf += 1;
            }
            next[i] = u[i] + ((pr_val[i - 1] - mid) + pr_val[i + 1]);
        }
        self.events.overflows += of;
        self.events.underflows += uf;
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)]) {
        assert_eq!(out.len(), q.len());
        let fmt = self.fmt;
        let mut rnd = Rounder::nearest_even();
        let (fg, flg) = encode(g2, fmt, &mut rnd);
        let mut of = 0u64;
        let mut uf = 0u64;
        for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
            // q1² and q3²: encode each operand once (the scalar path encodes
            // it twice; the encodings are identical).
            let (fq1, fl1) = encode(q1, fmt, &mut rnd);
            let (p1, flp1) = sf_mul(fq1, fq1, fmt, &mut rnd);
            let q1sq = decode(p1, fmt);
            let (fq3, fl3) = encode(q3, fmt, &mut rnd);
            let (p3, flp3) = sf_mul(fq3, fq3, fmt, &mut rnd);
            let q3sq = decode(p3, fmt);
            // g2 · q3²: the scalar path re-encodes the decoded product.
            let (fq3sq, fl3sq) = encode(q3sq, fmt, &mut rnd);
            let (pg, flpg) = sf_mul(fg, fq3sq, fmt, &mut rnd);
            let gq = decode(pg, fmt);
            *o = q1sq / q3 + gq;
            for fl in [fl1 | flp1, fl3 | flp3, flg | fl3sq | flpg] {
                if fl.overflow() {
                    of += 1;
                }
                if fl.underflow() {
                    uf += 1;
                }
            }
        }
        self.events.overflows += of;
        self.events.underflows += uf;
    }
    fn range_events(&self) -> Option<RangeEvents> {
        Some(self.events)
    }
}

/// The runtime-reconfigurable multiplier under test.
#[derive(Debug)]
pub struct R2f2Arith {
    pub unit: R2f2Multiplier,
}

impl R2f2Arith {
    pub fn new(cfg: R2f2Config) -> R2f2Arith {
        R2f2Arith { unit: R2f2Multiplier::new(cfg) }
    }
}

impl Arith for R2f2Arith {
    fn name(&self) -> String {
        self.unit.config().to_string()
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.unit.mul(a, b)
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        // R2F2 is a multiplier; in Full mode additions run in the *current*
        // effective format (same storage width).
        let fmt = self.unit.config().format(self.unit.split());
        add_f(a, b, fmt).0
    }
    fn quant(&mut self, x: f64) -> f64 {
        let fmt = self.unit.config().format(self.unit.split());
        quantize(x, fmt)
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        assert_eq!(out.len(), xs.len());
        // §2's observation: operand ranges are stable within a simulation
        // stage, so the constant operand's encoding (and its redundancy
        // verdict) is derived once per split and reused across the block
        // instead of per multiplication. State transitions stay exact.
        let c = self.unit.prepare_const(a);
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = self.unit.mul_const(&c, x);
        }
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        if mode == QuantMode::Full {
            scalar_stencil_step(self, next, u, r, mode);
            return;
        }
        let n = u.len();
        assert_eq!(next.len(), n);
        assert!(n >= 3);
        let cr = self.unit.prepare_const(r);
        let c2r = self.unit.prepare_const(2.0 * r);
        // Sliding-window encode cache: u[j] feeds the `right` of node j−1,
        // the `mid` of node j and the `left` of node j+1; while the split
        // is unchanged those three encodes collapse into one.
        let mut sl = EncSlot::empty();
        let mut sm = EncSlot::empty();
        let mut sr = EncSlot::empty();
        for i in 1..n - 1 {
            let left = self.unit.mul_const_cached(&cr, u[i - 1], &mut sl);
            let mid = self.unit.mul_const_cached(&c2r, u[i], &mut sm);
            let right = self.unit.mul_const_cached(&cr, u[i + 1], &mut sr);
            next[i] = u[i] + ((left - mid) + right);
            sl = sm;
            sm = sr;
            sr = EncSlot::empty();
        }
        next[0] = u[0];
        next[n - 1] = u[n - 1];
    }
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)]) {
        assert_eq!(out.len(), q.len());
        let cg = self.unit.prepare_const(g2);
        for (o, &(q1, q3)) in out.iter_mut().zip(q.iter()) {
            let q1sq = self.unit.mul(q1, q1);
            let q3sq = self.unit.mul(q3, q3);
            *o = q1sq / q3 + self.unit.mul_const(&cg, q3sq);
        }
    }
    fn r2f2_stats(&self) -> Option<Stats> {
        Some(self.unit.stats())
    }
}

/// Fixed format with **stochastic rounding** — the extension the paper
/// cites from Paxton et al. ("with stochastic rounding, 16-bit half
/// precision may be useful in future climate modeling"). Rounds up with
/// probability `discarded / ulp`, so systematically-swallowed small updates
/// survive in expectation; see the `stochastic_rounding_*` tests and the
/// ablations bench.
#[derive(Debug)]
pub struct StochasticArith {
    pub fmt: FpFormat,
    rounder: crate::softfloat::Rounder,
    events: RangeEvents,
}

impl StochasticArith {
    pub fn new(fmt: FpFormat, seed: u64) -> StochasticArith {
        StochasticArith {
            fmt,
            rounder: crate::softfloat::Rounder::stochastic(seed),
            events: RangeEvents::default(),
        }
    }

    fn track(&mut self, flags: crate::softfloat::Flags) {
        if flags.overflow() {
            self.events.overflows += 1;
        }
        if flags.underflow() {
            self.events.underflows += 1;
        }
    }
}

impl Arith for StochasticArith {
    fn name(&self) -> String {
        format!("{}-sr", self.fmt)
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let (fa, f1) = crate::softfloat::encode(a, self.fmt, &mut self.rounder);
        let (fb, f2) = crate::softfloat::encode(b, self.fmt, &mut self.rounder);
        let (fc, f3) = crate::softfloat::mul(fa, fb, self.fmt, &mut self.rounder);
        self.track(f1 | f2 | f3);
        crate::softfloat::decode(fc, self.fmt)
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        let (fa, f1) = crate::softfloat::encode(a, self.fmt, &mut self.rounder);
        let (fb, f2) = crate::softfloat::encode(b, self.fmt, &mut self.rounder);
        let (fc, f3) = crate::softfloat::add(fa, fb, self.fmt, &mut self.rounder);
        self.track(f1 | f2 | f3);
        crate::softfloat::decode(fc, self.fmt)
    }
    fn quant(&mut self, x: f64) -> f64 {
        let (fp, fl) = crate::softfloat::encode(x, self.fmt, &mut self.rounder);
        self.track(fl);
        crate::softfloat::decode(fp, self.fmt)
    }
    fn range_events(&self) -> Option<RangeEvents> {
        Some(self.events)
    }
}

/// Decorator that streams every multiplication's operands and result into a
/// callback — the instrumentation behind the Fig. 2 data-distribution study.
pub struct RecordingArith<'a, A: Arith> {
    pub inner: A,
    pub tap: &'a mut dyn FnMut(f64, f64, f64),
}

impl<'a, A: Arith> Arith for RecordingArith<'a, A> {
    fn name(&self) -> String {
        format!("recorded({})", self.inner.name())
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        let r = self.inner.mul(a, b);
        (self.tap)(a, b, r);
        r
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        self.inner.add(a, b)
    }
    fn quant(&mut self, x: f64) -> f64 {
        self.inner.quant(x)
    }
    fn r2f2_stats(&self) -> Option<Stats> {
        self.inner.r2f2_stats()
    }
    fn range_events(&self) -> Option<RangeEvents> {
        self.inner.range_events()
    }
}

/// Solver-facing arithmetic context: applies [`QuantMode`] uniformly so the
/// solvers contain a single code path.
pub struct Ctx<'a> {
    pub be: &'a mut dyn Arith,
    pub mode: QuantMode,
    /// Multiplications issued through this context.
    pub muls: u64,
}

impl<'a> Ctx<'a> {
    pub fn new(be: &'a mut dyn Arith, mode: QuantMode) -> Ctx<'a> {
        Ctx { be, mode, muls: 0 }
    }

    #[inline]
    pub fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.muls += 1;
        self.be.mul(a, b)
    }

    #[inline]
    pub fn add(&mut self, a: f64, b: f64) -> f64 {
        match self.mode {
            QuantMode::MulOnly => a + b,
            QuantMode::Full => self.be.add(a, b),
        }
    }

    #[inline]
    pub fn sub(&mut self, a: f64, b: f64) -> f64 {
        self.add(a, -b)
    }

    #[inline]
    pub fn quant(&mut self, x: f64) -> f64 {
        match self.mode {
            QuantMode::MulOnly => x,
            QuantMode::Full => self.be.quant(x),
        }
    }

    /// Batched constant × slice multiply through the backend.
    pub fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        self.muls += xs.len() as u64;
        self.be.mul_batch(out, a, xs);
    }

    /// Batched pairwise multiply through the backend.
    pub fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        self.muls += pairs.len() as u64;
        self.be.mul_pairs(out, pairs);
    }

    /// One fused heat-stencil sweep (3 multiplications per interior node).
    pub fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64) {
        self.muls += 3 * (u.len() as u64 - 2);
        self.be.stencil_step(next, u, r, self.mode);
    }

    /// Batched x-momentum flux evaluations (3 multiplications per pair).
    pub fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)]) {
        self.muls += 3 * q.len() as u64;
        self.be.flux_batch(out, g2, q);
    }
}

/// Root-mean-square error between two equal-length fields — the scalar
/// "same simulation result?" metric used throughout EXPERIMENTS.md.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

/// Relative L2 error `‖a − b‖ / ‖b‖` (b = reference).
pub fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f64 = b.iter().map(|y| y * y).sum();
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_backend_is_exact() {
        let mut be = F64Arith;
        assert_eq!(be.mul(3.0, 4.0), 12.0);
    }

    #[test]
    fn fixed_backend_counts_events() {
        let mut be = FixedArith::new(FpFormat::E5M10);
        let _ = be.mul(1000.0, 1000.0); // overflow
        let _ = be.mul(1e-3, 1e-3); // underflow
        let ev = be.range_events().unwrap();
        assert_eq!(ev.overflows, 1);
        assert_eq!(ev.underflows, 1);
    }

    #[test]
    fn r2f2_backend_tracks_stats() {
        let mut be = R2f2Arith::new(R2f2Config::C16_393);
        let v = be.mul(300.0, 300.0);
        assert!((v - 9e4).abs() / 9e4 < 1e-2);
        assert!(be.r2f2_stats().unwrap().overflow_adjustments >= 1);
    }

    #[test]
    fn ctx_mode_gates_add_and_quant() {
        let mut be = FixedArith::new(FpFormat::E5M10);
        let mut ctx = Ctx::new(&mut be, QuantMode::MulOnly);
        // In MulOnly mode adds stay exact even for values half can't hold.
        assert_eq!(ctx.add(1e6, 1.0), 1_000_001.0);
        assert_eq!(ctx.quant(1e6), 1e6);
        let mut be = FixedArith::new(FpFormat::E5M10);
        let mut ctx = Ctx::new(&mut be, QuantMode::Full);
        assert_eq!(ctx.quant(1e6), 65504.0); // saturates
    }

    #[test]
    fn ctx_counts_muls() {
        let mut be = F64Arith;
        let mut ctx = Ctx::new(&mut be, QuantMode::MulOnly);
        for _ in 0..5 {
            ctx.mul(1.0, 1.0);
        }
        assert_eq!(ctx.muls, 5);
    }

    #[test]
    fn recording_taps_every_mul() {
        let mut count = 0u32;
        {
            let mut tap = |_a: f64, _b: f64, _r: f64| count += 1;
            let mut be = RecordingArith { inner: F64Arith, tap: &mut tap };
            be.mul(1.0, 2.0);
            be.mul(3.0, 4.0);
        }
        assert_eq!(count, 2);
    }

    #[test]
    fn error_metrics() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 2.0, 4.0];
        assert!((rmse(&a, &b) - (1.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!(rel_l2(&a, &a) == 0.0);
    }

    /// Operand set spanning in-range, overflowing and underflowing values.
    fn nasty_xs(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::rng::SplitMix64::new(seed);
        let mut xs: Vec<f64> = (0..n)
            .map(|_| {
                let s = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                s * rng.log_uniform(1e-7, 1e7)
            })
            .collect();
        xs.extend_from_slice(&[0.0, -0.0, 65504.0, 1e-8, 3e8]);
        xs
    }

    fn check_mul_batch_equivalence(mk: &dyn Fn() -> Box<dyn Arith>, what: &str) {
        let xs = nasty_xs(400, 0x90);
        for &a in &[0.25, 0.5, 4.9, 2000.0, 1e-4] {
            let mut scalar_be = mk();
            let mut batch_be = mk();
            let want: Vec<f64> = xs.iter().map(|&x| scalar_be.mul(a, x)).collect();
            let mut got = vec![0.0; xs.len()];
            batch_be.mul_batch(&mut got, a, &xs);
            for i in 0..xs.len() {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "{what}: {a} × {} (lane {i})",
                    xs[i]
                );
            }
            assert_eq!(scalar_be.range_events(), batch_be.range_events(), "{what}: events");
            assert_eq!(scalar_be.r2f2_stats(), batch_be.r2f2_stats(), "{what}: stats");
        }
    }

    #[test]
    fn mul_batch_bit_identical_across_backends() {
        check_mul_batch_equivalence(&|| Box::new(F64Arith) as Box<dyn Arith>, "f64");
        check_mul_batch_equivalence(&|| Box::new(F32Arith) as Box<dyn Arith>, "f32");
        check_mul_batch_equivalence(&|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>, "E5M10");
        check_mul_batch_equivalence(
            &|| Box::new(FixedArith::new(FpFormat::new(6, 9))) as Box<dyn Arith>,
            "E6M9",
        );
        check_mul_batch_equivalence(&|| Box::new(R2f2Arith::new(R2f2Config::C16_393)) as Box<dyn Arith>, "r2f2");
        check_mul_batch_equivalence(
            &|| Box::new(StochasticArith::new(FpFormat::E5M10, 42)) as Box<dyn Arith>,
            "E5M10-sr",
        );
    }

    #[test]
    fn mul_pairs_bit_identical_across_backends() {
        let xs = nasty_xs(300, 0x91);
        let ys = nasty_xs(300, 0x92);
        let pairs: Vec<(f64, f64)> = xs.into_iter().zip(ys).collect();
        let mks: Vec<(Box<dyn Fn() -> Box<dyn Arith>>, &str)> = vec![
            (Box::new(|| Box::new(F64Arith) as Box<dyn Arith>), "f64"),
            (Box::new(|| Box::new(F32Arith) as Box<dyn Arith>), "f32"),
            (Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>), "E5M10"),
            (Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_384)) as Box<dyn Arith>), "r2f2"),
        ];
        for (mk, what) in &mks {
            let mut scalar_be = mk();
            let mut batch_be = mk();
            let want: Vec<f64> = pairs.iter().map(|&(a, b)| scalar_be.mul(a, b)).collect();
            let mut got = vec![0.0; pairs.len()];
            batch_be.mul_pairs(&mut got, &pairs);
            for i in 0..pairs.len() {
                assert_eq!(got[i].to_bits(), want[i].to_bits(), "{what}: lane {i}");
            }
            assert_eq!(scalar_be.range_events(), batch_be.range_events(), "{what}: events");
            assert_eq!(scalar_be.r2f2_stats(), batch_be.r2f2_stats(), "{what}: stats");
        }
    }

    #[test]
    fn stencil_step_bit_identical_across_backends_and_modes() {
        // One stencil sweep over a field that spans the full §3.1 range
        // story: large values near the crest, sub-ulp values in the tails.
        let mut rng = crate::rng::SplitMix64::new(0x93);
        let n = 257;
        let u: Vec<f64> = (0..n)
            .map(|i| {
                let x = i as f64 / (n - 1) as f64;
                500.0 * (std::f64::consts::PI * x).sin() * rng.range_f64(0.99, 1.01)
            })
            .collect();
        let r = 0.25;
        let mks: Vec<(Box<dyn Fn() -> Box<dyn Arith>>, &str)> = vec![
            (Box::new(|| Box::new(F64Arith) as Box<dyn Arith>), "f64"),
            (Box::new(|| Box::new(F32Arith) as Box<dyn Arith>), "f32"),
            (Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>), "E5M10"),
            (Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_393)) as Box<dyn Arith>), "r2f2"),
            (Box::new(|| Box::new(StochasticArith::new(FpFormat::E5M10, 7)) as Box<dyn Arith>), "E5M10-sr"),
        ];
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            for (mk, what) in &mks {
                let mut scalar_be = mk();
                let mut batch_be = mk();
                let mut want = u.clone();
                let mut got = u.clone();
                scalar_stencil_step(scalar_be.as_mut(), &mut want, &u, r, mode);
                batch_be.stencil_step(&mut got, &u, r, mode);
                for i in 0..n {
                    assert_eq!(
                        got[i].to_bits(),
                        want[i].to_bits(),
                        "{what}/{mode:?}: node {i}: {} vs {}",
                        got[i],
                        want[i]
                    );
                }
                assert_eq!(
                    scalar_be.range_events(),
                    batch_be.range_events(),
                    "{what}/{mode:?}: events"
                );
                assert_eq!(
                    scalar_be.r2f2_stats(),
                    batch_be.r2f2_stats(),
                    "{what}/{mode:?}: stats"
                );
            }
        }
    }

    #[test]
    fn stencil_step_fixed_counts_range_events_like_scalar() {
        // A tiny field drives every r·u product below E5M10's min normal:
        // the deduplicated fast path must still report the scalar path's
        // event multiplicity (each product is counted once per use).
        let n = 33;
        let u: Vec<f64> = (0..n).map(|i| 1e-4 * (i as f64 + 1.0)).collect();
        let r = 0.25;
        let mut scalar_be = FixedArith::new(FpFormat::E5M10);
        let mut batch_be = FixedArith::new(FpFormat::E5M10);
        let mut want = u.clone();
        let mut got = u.clone();
        scalar_stencil_step(&mut scalar_be, &mut want, &u, r, QuantMode::MulOnly);
        batch_be.stencil_step(&mut got, &u, r, QuantMode::MulOnly);
        let se = scalar_be.range_events().unwrap();
        let be = batch_be.range_events().unwrap();
        assert!(se.underflows > 0, "test field must actually underflow");
        assert_eq!(se, be);
        for i in 0..n {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "node {i}");
        }
    }

    #[test]
    fn flux_batch_bit_identical_across_backends() {
        let mut rng = crate::rng::SplitMix64::new(0x94);
        // Shelf-scale operands (the Fig. 8 regime): h ≈ 150, u ≈ ±40.
        let q: Vec<(f64, f64)> = (0..500)
            .map(|_| (rng.range_f64(-40.0, 40.0), rng.range_f64(140.0, 160.0)))
            .collect();
        let g2 = 4.9;
        let mks: Vec<(Box<dyn Fn() -> Box<dyn Arith>>, &str)> = vec![
            (Box::new(|| Box::new(F64Arith) as Box<dyn Arith>), "f64"),
            (Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>), "E5M10"),
            (Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_384)) as Box<dyn Arith>), "r2f2"),
        ];
        for (mk, what) in &mks {
            let mut scalar_be = mk();
            let mut batch_be = mk();
            let want: Vec<f64> = q
                .iter()
                .map(|&(q1, q3)| {
                    let q1sq = scalar_be.mul(q1, q1);
                    let q3sq = scalar_be.mul(q3, q3);
                    q1sq / q3 + scalar_be.mul(g2, q3sq)
                })
                .collect();
            let mut got = vec![0.0; q.len()];
            batch_be.flux_batch(&mut got, g2, &q);
            for i in 0..q.len() {
                assert_eq!(got[i].to_bits(), want[i].to_bits(), "{what}: lane {i}");
            }
            assert_eq!(scalar_be.range_events(), batch_be.range_events(), "{what}: events");
            assert_eq!(scalar_be.r2f2_stats(), batch_be.r2f2_stats(), "{what}: stats");
        }
    }

    #[test]
    fn ctx_batched_ops_count_muls() {
        let mut be = F64Arith;
        let mut ctx = Ctx::new(&mut be, QuantMode::MulOnly);
        let mut out = [0.0; 4];
        ctx.mul_batch(&mut out, 2.0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ctx.muls, 4);
        ctx.mul_pairs(&mut out, &[(1.0, 2.0); 4]);
        assert_eq!(ctx.muls, 8);
        let u = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut next = [0.0; 5];
        ctx.stencil_step(&mut next, &u, 0.25);
        assert_eq!(ctx.muls, 8 + 9); // 3 interior nodes × 3 muls
        ctx.flux_batch(&mut out, 4.9, &[(1.0, 2.0); 4]);
        assert_eq!(ctx.muls, 17 + 12);
    }
}
