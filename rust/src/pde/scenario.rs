//! The generic scenario layer (DESIGN.md §11): one [`Sim`] trait every PDE
//! case study implements, blanket drivers that run *any* scenario under any
//! arithmetic backend, and the [`SCENARIOS`] registry that tests, benches,
//! the CLI and CI all iterate.
//!
//! Before this layer, `heat1d` and `swe2d` each hand-rolled their own
//! `run`/`run_mode`/`run_adaptive`/`run_adaptive_scalar` plumbing, so every
//! engine improvement (batched dispatch, packed state, the adaptive
//! scheduler) had to be wired once per solver. Now a scenario provides only
//! its physics:
//!
//! * [`Sim::advance`] — step the state through a [`Ctx`] (the canonical
//!   scalar sequence when `batched` is false, the backend's batched engine
//!   otherwise — the §8/§9 contract makes the two bit-identical);
//! * [`Sim::save`] / [`Sim::restore`] — the persistent state a widen-retried
//!   epoch must roll back (the `AdaptiveArith` retry semantics, written
//!   once in [`run_sim_adaptive`] instead of once per solver);
//! * [`Sim::telemetry`] — the per-epoch state sample the adaptive
//!   scheduler's range histogram inspects;
//! * [`Sim::quant_state`] — storage quantization of the persistent state
//!   ([`Ctx::quant`] gates it on [`QuantMode`], so scenarios whose state
//!   lives in the f64 carrier under every mode — shallow water — implement
//!   it as a no-op).
//!
//! Dispatch cost: the drivers are generic over the scenario and issue
//! arithmetic through the batched [`Arith`] entry points, so the hot path
//! performs O(1) virtual calls per row/epoch — never per multiplication.
//!
//! **Bit-exactness.** The drivers preserve the exact operation streams of
//! the per-solver plumbing they replaced: `rust/tests/batched_vs_scalar.rs`,
//! `packed_vs_carrier.rs` and `adaptive_schedule.rs` all pass unmodified,
//! and `rust/tests/scenario_matrix.rs` extends the same contracts to every
//! registry scenario.

use super::adaptive::{fixed_cost_lut, AdaptiveArith, AdaptivePolicy, Decision};
use super::advection1d::{AdvectionParams, AdvectionSim};
use super::decomp::{DecompAdvection, DecompHeat, DecompSwe, DecompWave};
use super::heat1d::{HeatParams, HeatSim};
use super::swe2d::{QuantScope, SweParams, SweSim};
use super::wave2d::{WaveParams, WaveSim};
use super::{Arith, Ctx, QuantMode, RangeEvents};
use crate::r2f2core::Stats;
use crate::softfloat::FpFormat;

/// One PDE case study, steppable under any [`Arith`] backend.
///
/// The contract mirrors DESIGN.md §8: for every backend,
/// `advance(batched = true)` must be bit-identical — values, counters,
/// multiplication count — to `advance(batched = false)`, whose body is the
/// scenario's canonical scalar sequence.
pub trait Sim {
    /// Registry name of the scenario (`heat1d`, `swe2d`, ...).
    fn scenario(&self) -> &'static str;

    /// Quantize the persistent state into the backend's storage format.
    /// Route it through [`Ctx::quant`] so `MulOnly` mode is the identity;
    /// scenarios whose state stays in the f64 carrier under every mode
    /// implement this as a no-op.
    fn quant_state(&mut self, ctx: &mut Ctx<'_>);

    /// Advance `steps` timesteps. Global step numbers continue from
    /// `step_base`; every `snapshot_every` global steps a
    /// `(global_step, primary field)` snapshot is pushed onto `snaps`
    /// (0 = none). `batched` selects the backend's batched engine over the
    /// canonical per-multiplication scalar sequence.
    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    );

    /// The persistent state a widen-retried epoch must restore.
    fn save(&self) -> Vec<Vec<f64>>;

    /// Restore a [`Sim::save`] image.
    fn restore(&mut self, saved: &[Vec<f64>]);

    /// Stream the adaptive scheduler's per-epoch range-telemetry sample.
    fn telemetry(&self, out: &mut Vec<f64>);

    /// Telemetry samples per epoch (sizes the scheduler's stage tracker).
    fn telemetry_len(&self) -> usize;

    /// The field reports and the RMSE-vs-reference metric use.
    fn primary_field(&self) -> Vec<f64>;
}

/// Backend-side statistics of one generic run; scenario wrappers combine
/// it with their final fields into their result records.
#[derive(Debug, Clone)]
pub struct RunStats {
    /// Multiplications issued through the backend.
    pub muls: u64,
    /// Backend name.
    pub backend: String,
    /// R2F2 adjustment statistics, when applicable.
    pub r2f2_stats: Option<Stats>,
    /// Fixed-format range events, when applicable.
    pub range_events: Option<RangeEvents>,
    /// `(step, primary field)` snapshots if requested.
    pub snapshots: Vec<(usize, Vec<f64>)>,
}

/// Run any scenario under any backend — the one driver behind every
/// `run`/`run_scalar`/`run_mode` entry point.
pub fn run_sim<S: Sim>(
    sim: &mut S,
    be: &mut dyn Arith,
    mode: QuantMode,
    steps: usize,
    snapshot_every: usize,
    batched: bool,
) -> RunStats {
    let backend = be.name();
    let mut snapshots = Vec::new();
    let muls = {
        let mut ctx = Ctx::new(be, mode);
        sim.quant_state(&mut ctx);
        sim.advance(&mut ctx, steps, 0, snapshot_every, &mut snapshots, batched);
        ctx.muls
    };
    RunStats {
        muls,
        backend,
        r2f2_stats: be.r2f2_stats(),
        range_events: be.range_events(),
        snapshots,
    }
}

/// Adaptive-precision run of any scenario: the epoch protocol —
/// save → attempt → telemetry → decide, with widen-and-**retry** rollback
/// and narrow re-quantization — written once for every scenario
/// (DESIGN.md §10/§11).
pub fn run_sim_adaptive<S: Sim>(
    sim: &mut S,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    steps: usize,
    snapshot_every: usize,
    batched: bool,
) -> RunStats {
    let backend = sched.name();
    let epoch_len = sched.policy().epoch_len;
    let est_epochs = steps.div_ceil(epoch_len).max(1);
    sched.prepare(est_epochs as u64 * sim.telemetry_len() as u64);

    let mut snapshots = Vec::new();
    let mut tele: Vec<f64> = Vec::new();
    let mut muls = 0u64;
    let mut done = 0usize;
    // Initial storage quantization is deferred into the first epoch attempt
    // so its flags land in epoch 0's event delta; a widen retry sets the
    // flag again so the restored state re-enters the *widened* format
    // (identity in MulOnly — `Ctx::quant` gates on the mode).
    let mut pending_quant = true;

    if steps == 0 {
        let mut ctx = Ctx::new(&mut sched.inner, mode);
        sim.quant_state(&mut ctx);
        return RunStats {
            muls: 0,
            backend,
            r2f2_stats: None,
            range_events: Some(sched.events()),
            snapshots,
        };
    }

    while done < steps {
        let e_len = epoch_len.min(steps - done);
        // Epoch-start save. For the very first epoch this is the *raw*
        // state (quantization happens inside the attempt), so a widen
        // retry re-quantizes the original data in the wider format —
        // nothing of the narrow attempt survives.
        let save = sim.save();
        loop {
            sched.begin_epoch();
            let mut esnaps: Vec<(usize, Vec<f64>)> = Vec::new();
            let delta = {
                let mut ctx = Ctx::new(&mut sched.inner, mode);
                if pending_quant {
                    sim.quant_state(&mut ctx);
                    pending_quant = false;
                }
                sim.advance(&mut ctx, e_len, done, snapshot_every, &mut esnaps, batched);
                ctx.muls
            };
            muls += delta;
            sched.charge(delta);
            sim.telemetry(&mut tele);
            match sched.end_epoch(&tele, done + e_len) {
                Decision::Widen => {
                    sim.restore(&save);
                    pending_quant = true;
                }
                Decision::Narrow => {
                    // Re-quantize the committed state into the narrower
                    // format (may flush/saturate; the flags are tracked
                    // exactly like any storage quantization).
                    let mut ctx = Ctx::new(&mut sched.inner, mode);
                    sim.quant_state(&mut ctx);
                    snapshots.extend(esnaps);
                    break;
                }
                Decision::Stay => {
                    snapshots.extend(esnaps);
                    break;
                }
            }
        }
        done += e_len;
    }

    RunStats { muls, backend, r2f2_stats: None, range_events: Some(sched.events()), snapshots }
}

// ---------------------------------------------------------------------------
// The scenario registry
// ---------------------------------------------------------------------------

/// Preset run scale, so every consumer of the registry sizes a scenario the
/// same way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioSize {
    /// Smallest runnable setup — bit-identity matrices, bench smoke rows,
    /// example walkthroughs.
    Quick,
    /// Moderate run where the solution is still live everywhere — the
    /// RMSE-envelope scale.
    Accuracy,
    /// Sized for the adaptive ladder: immediate widen pressure at the
    /// narrow rung and (where [`ScenarioSpec::expect_narrow`]) a decayed
    /// tail that stalls and narrows back.
    Adaptive,
}

/// Outcome of one registry-driven run.
#[derive(Debug, Clone)]
pub struct ScenarioRun {
    /// Final primary field.
    pub field: Vec<f64>,
    /// Multiplications issued through the backend.
    pub muls: u64,
    /// Backend name.
    pub backend: String,
    /// Fixed-format range events, when applicable.
    pub range_events: Option<RangeEvents>,
    /// R2F2 adjustment statistics, when applicable.
    pub r2f2_stats: Option<Stats>,
}

/// One registry entry: name, one-line physics, why it stresses precision,
/// and the uniform run hooks every consumer calls.
#[derive(Clone, Copy)]
pub struct ScenarioSpec {
    pub name: &'static str,
    /// One-line physics description (the README scenario table).
    pub physics: &'static str,
    /// Why this scenario stresses reduced-precision arithmetic.
    pub stress: &'static str,
    /// Run under an arbitrary backend (`batched` selects the engine path).
    pub run: fn(ScenarioSize, &mut dyn Arith, QuantMode, bool) -> ScenarioRun,
    /// Run under the adaptive scheduler (build it from
    /// [`ScenarioSpec::adaptive_policy`]).
    pub run_adaptive: fn(ScenarioSize, &mut AdaptiveArith, QuantMode, bool) -> ScenarioRun,
    /// [`ScenarioSpec::run`] decomposed over the worker pool (`pde::decomp`,
    /// DESIGN.md §13); the last argument is the shard count. Bit-identical
    /// to `run` for every shard count — `rust/tests/decomp_identity.rs`
    /// holds the contract.
    pub run_sharded: fn(ScenarioSize, &mut dyn Arith, QuantMode, bool, usize) -> ScenarioRun,
    /// [`ScenarioSpec::run_adaptive`] decomposed over the worker pool.
    pub run_adaptive_sharded:
        fn(ScenarioSize, &mut AdaptiveArith, QuantMode, bool, usize) -> ScenarioRun,
    /// The scenario's default adaptive ladder + epoch length.
    pub adaptive_policy: fn() -> AdaptivePolicy,
    /// The rung the default [`ScenarioSize::Adaptive`] run widens onto in
    /// its first epoch — the format whose fixed run the committed adaptive
    /// trajectory bit-equals.
    pub wide_format: FpFormat,
    /// Does the default adaptive setup stall and narrow (⇒ strictly lower
    /// modeled cost than the all-wide run)?
    pub expect_narrow: bool,
    /// `(format, max rel-L2 vs the f64 reference)` MulOnly accuracy
    /// envelopes at [`ScenarioSize::Accuracy`].
    pub envelopes: &'static [(FpFormat, f64)],
}

impl std::fmt::Debug for ScenarioSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScenarioSpec").field("name", &self.name).finish()
    }
}

/// Every scenario, in registry order. Tests
/// (`rust/tests/scenario_matrix.rs`), `benches/hotpath.rs`, the CLI
/// `scenarios` command and the CI scenario-matrix job all iterate this
/// list — adding a scenario here enrolls it everywhere.
pub static SCENARIOS: &[ScenarioSpec] = &[
    ScenarioSpec {
        name: "heat1d",
        physics: "1D heat diffusion, explicit finite differences (paper §2)",
        stress: "decaying sine crosses many octaves: wide range early, sub-ulp updates late",
        run: run_heat_scn,
        run_adaptive: run_heat_adaptive_scn,
        run_sharded: run_heat_scn_sharded,
        run_adaptive_sharded: run_heat_adaptive_scn_sharded,
        adaptive_policy: heat_scn_policy,
        wide_format: FpFormat::E5M10,
        expect_narrow: true,
        envelopes: &[(FpFormat::E5M10, 1e-2)],
    },
    ScenarioSpec {
        name: "swe2d",
        physics: "2D shallow water, two-step Lax-Wendroff (paper §2, Fig. 8)",
        stress: "flux term 0.5*g*h^2 ~ 1e5 overflows E5M10 while gradients need mantissa",
        run: run_swe_scn,
        run_adaptive: run_swe_adaptive_scn,
        run_sharded: run_swe_scn_sharded,
        run_adaptive_sharded: run_swe_adaptive_scn_sharded,
        adaptive_policy: AdaptivePolicy::swe_default,
        wide_format: FpFormat::new(6, 9),
        expect_narrow: false,
        envelopes: &[(FpFormat::new(6, 9), 2e-2)],
    },
    ScenarioSpec {
        name: "advection1d",
        physics: "1D upwind advection (optional Burgers nonlinearity), periodic",
        stress: "CFL-constant and state-by-state products walk the exponent range as transport decays",
        run: run_advection_scn,
        run_adaptive: run_advection_adaptive_scn,
        run_sharded: run_advection_scn_sharded,
        run_adaptive_sharded: run_advection_adaptive_scn_sharded,
        adaptive_policy: AdaptivePolicy::advection_default,
        wide_format: FpFormat::E5M10,
        expect_narrow: true,
        envelopes: &[(FpFormat::E5M10, 1e-1)],
    },
    ScenarioSpec {
        name: "wave2d",
        physics: "2D wave equation, damped leapfrog, Dirichlet walls",
        stress: "signed oscillation exercises negatives/cancellation; amplitude 300 saturates E4M3",
        run: run_wave_scn,
        run_adaptive: run_wave_adaptive_scn,
        run_sharded: run_wave_scn_sharded,
        run_adaptive_sharded: run_wave_adaptive_scn_sharded,
        adaptive_policy: AdaptivePolicy::wave_default,
        wide_format: FpFormat::E5M10,
        expect_narrow: true,
        envelopes: &[(FpFormat::E5M10, 3e-1)],
    },
];

/// Look a scenario up by registry name.
pub fn find(name: &str) -> Option<&'static ScenarioSpec> {
    SCENARIOS.iter().find(|s| s.name == name)
}

fn finish_scn<S: Sim>(sim: S, stats: RunStats) -> ScenarioRun {
    ScenarioRun {
        field: sim.primary_field(),
        muls: stats.muls,
        backend: stats.backend,
        range_events: stats.range_events,
        r2f2_stats: stats.r2f2_stats,
    }
}

// -- heat ------------------------------------------------------------------

fn heat_scn_params(size: ScenarioSize) -> HeatParams {
    match size {
        ScenarioSize::Quick => HeatParams {
            n: 33,
            dt: 0.25 / (32.0f64 * 32.0),
            steps: 40,
            ..HeatParams::default()
        },
        ScenarioSize::Accuracy => HeatParams {
            n: 101,
            dt: 0.25 / (100.0f64 * 100.0),
            steps: 1500,
            ..HeatParams::default()
        },
        // The adaptive_schedule.rs MulOnly setup: widens out of E4M3 in
        // epoch 0 (amplitude 500), stalls and narrows back by step ~1600.
        ScenarioSize::Adaptive => HeatParams {
            n: 33,
            dt: 0.25 / (32.0f64 * 32.0),
            steps: 3000,
            ..HeatParams::default()
        },
    }
}

fn heat_scn_policy() -> AdaptivePolicy {
    let mut p = AdaptivePolicy::heat_default();
    p.epoch_len = 50;
    p
}

fn run_heat_scn(
    size: ScenarioSize,
    be: &mut dyn Arith,
    mode: QuantMode,
    batched: bool,
) -> ScenarioRun {
    let p = heat_scn_params(size);
    let mut sim = HeatSim::new(&p);
    let stats = run_sim(&mut sim, be, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_heat_adaptive_scn(
    size: ScenarioSize,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    batched: bool,
) -> ScenarioRun {
    let p = heat_scn_params(size);
    let mut sim = HeatSim::new(&p);
    let stats = run_sim_adaptive(&mut sim, sched, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_heat_scn_sharded(
    size: ScenarioSize,
    be: &mut dyn Arith,
    mode: QuantMode,
    batched: bool,
    shards: usize,
) -> ScenarioRun {
    let p = heat_scn_params(size);
    let mut sim = DecompHeat::new(&p, shards);
    let stats = run_sim(&mut sim, be, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_heat_adaptive_scn_sharded(
    size: ScenarioSize,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    batched: bool,
    shards: usize,
) -> ScenarioRun {
    let p = heat_scn_params(size);
    let mut sim = DecompHeat::new(&p, shards);
    let stats = run_sim_adaptive(&mut sim, sched, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

// -- shallow water ---------------------------------------------------------

fn swe_scn_params(size: ScenarioSize) -> SweParams {
    match size {
        ScenarioSize::Quick => SweParams { steps: 10, ..SweParams::default() },
        ScenarioSize::Accuracy => SweParams { steps: 40, ..SweParams::default() },
        ScenarioSize::Adaptive => SweParams { steps: 24, ..SweParams::default() },
    }
}

fn run_swe_scn(
    size: ScenarioSize,
    be: &mut dyn Arith,
    mode: QuantMode,
    batched: bool,
) -> ScenarioRun {
    let p = swe_scn_params(size);
    let mut sim = SweSim::new(&p, QuantScope::UxFluxOnly);
    let stats = run_sim(&mut sim, be, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_swe_adaptive_scn(
    size: ScenarioSize,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    batched: bool,
) -> ScenarioRun {
    let p = swe_scn_params(size);
    let mut sim = SweSim::new(&p, QuantScope::UxFluxOnly);
    let stats = run_sim_adaptive(&mut sim, sched, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_swe_scn_sharded(
    size: ScenarioSize,
    be: &mut dyn Arith,
    mode: QuantMode,
    batched: bool,
    shards: usize,
) -> ScenarioRun {
    let p = swe_scn_params(size);
    let mut sim = DecompSwe::new(&p, QuantScope::UxFluxOnly, shards);
    let stats = run_sim(&mut sim, be, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_swe_adaptive_scn_sharded(
    size: ScenarioSize,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    batched: bool,
    shards: usize,
) -> ScenarioRun {
    let p = swe_scn_params(size);
    let mut sim = DecompSwe::new(&p, QuantScope::UxFluxOnly, shards);
    let stats = run_sim_adaptive(&mut sim, sched, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

// -- advection -------------------------------------------------------------

fn advection_scn_params(size: ScenarioSize) -> AdvectionParams {
    // dt rescales with n so every size keeps the default CFL c = 0.4.
    match size {
        ScenarioSize::Quick => {
            AdvectionParams { n: 64, dt: 0.4 / 64.0, steps: 50, ..AdvectionParams::default() }
        }
        ScenarioSize::Accuracy => {
            AdvectionParams { n: 256, steps: 800, ..AdvectionParams::default() }
        }
        // Sized for the envelope: amplitude 400 > E4M3's max finite, so
        // epoch 0 widens; upwind diffusion then decays the sine below the
        // flush threshold (~step 3200 at n = 64, c = 0.4), the transport
        // freezes, and the ladder narrows back for the tail.
        ScenarioSize::Adaptive => {
            AdvectionParams { n: 64, dt: 0.4 / 64.0, steps: 4000, ..AdvectionParams::default() }
        }
    }
}

fn run_advection_scn(
    size: ScenarioSize,
    be: &mut dyn Arith,
    mode: QuantMode,
    batched: bool,
) -> ScenarioRun {
    let p = advection_scn_params(size);
    let mut sim = AdvectionSim::new(&p);
    let stats = run_sim(&mut sim, be, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_advection_adaptive_scn(
    size: ScenarioSize,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    batched: bool,
) -> ScenarioRun {
    let p = advection_scn_params(size);
    let mut sim = AdvectionSim::new(&p);
    let stats = run_sim_adaptive(&mut sim, sched, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_advection_scn_sharded(
    size: ScenarioSize,
    be: &mut dyn Arith,
    mode: QuantMode,
    batched: bool,
    shards: usize,
) -> ScenarioRun {
    let p = advection_scn_params(size);
    let mut sim = DecompAdvection::new(&p, shards);
    let stats = run_sim(&mut sim, be, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_advection_adaptive_scn_sharded(
    size: ScenarioSize,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    batched: bool,
    shards: usize,
) -> ScenarioRun {
    let p = advection_scn_params(size);
    let mut sim = DecompAdvection::new(&p, shards);
    let stats = run_sim_adaptive(&mut sim, sched, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

// -- wave ------------------------------------------------------------------

fn wave_scn_params(size: ScenarioSize) -> WaveParams {
    match size {
        ScenarioSize::Quick => WaveParams { n: 17, steps: 40, ..WaveParams::default() },
        ScenarioSize::Accuracy => WaveParams { n: 33, steps: 200, ..WaveParams::default() },
        // Damped hard enough that the oscillation collapses to exact zeros
        // well before the end: widen in epoch 0 (amplitude 300 > E4M3's
        // ceiling), stall at zero, narrow for the tail.
        ScenarioSize::Adaptive => {
            WaveParams { n: 17, steps: 768, damping: 0.04, ..WaveParams::default() }
        }
    }
}

fn run_wave_scn(
    size: ScenarioSize,
    be: &mut dyn Arith,
    mode: QuantMode,
    batched: bool,
) -> ScenarioRun {
    let p = wave_scn_params(size);
    let mut sim = WaveSim::new(&p);
    let stats = run_sim(&mut sim, be, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_wave_adaptive_scn(
    size: ScenarioSize,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    batched: bool,
) -> ScenarioRun {
    let p = wave_scn_params(size);
    let mut sim = WaveSim::new(&p);
    let stats = run_sim_adaptive(&mut sim, sched, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_wave_scn_sharded(
    size: ScenarioSize,
    be: &mut dyn Arith,
    mode: QuantMode,
    batched: bool,
    shards: usize,
) -> ScenarioRun {
    let p = wave_scn_params(size);
    let mut sim = DecompWave::new(&p, shards);
    let stats = run_sim(&mut sim, be, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

fn run_wave_adaptive_scn_sharded(
    size: ScenarioSize,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    batched: bool,
    shards: usize,
) -> ScenarioRun {
    let p = wave_scn_params(size);
    let mut sim = DecompWave::new(&p, shards);
    let stats = run_sim_adaptive(&mut sim, sched, mode, p.steps, 0, batched);
    finish_scn(sim, stats)
}

/// Modeled all-fixed datapath cost of a registry run — convenience wrapper
/// over [`fixed_cost_lut`] for matrix tests and reports.
pub fn fixed_run_cost(fmt: FpFormat, run: &ScenarioRun) -> f64 {
    fixed_cost_lut(fmt, run.muls)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{rel_l2, F64Arith, FixedArith};

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        for (i, s) in SCENARIOS.iter().enumerate() {
            assert!(find(s.name).is_some(), "{} not findable", s.name);
            for t in &SCENARIOS[i + 1..] {
                assert_ne!(s.name, t.name, "duplicate scenario name");
            }
        }
        assert_eq!(SCENARIOS.len(), 4);
        assert!(find("no-such-scenario").is_none());
    }

    #[test]
    fn every_scenario_runs_under_every_mode_and_engine_path() {
        for spec in SCENARIOS {
            for mode in [QuantMode::MulOnly, QuantMode::Full] {
                for batched in [false, true] {
                    let mut be = FixedArith::new(FpFormat::E5M10);
                    let r = (spec.run)(ScenarioSize::Quick, &mut be, mode, batched);
                    assert!(r.muls > 0, "{}: no muls issued", spec.name);
                    assert!(!r.field.is_empty(), "{}: empty field", spec.name);
                    assert!(
                        r.field.iter().all(|v| v.is_finite()),
                        "{}/{mode:?}: non-finite field",
                        spec.name
                    );
                }
            }
        }
    }

    #[test]
    fn scalar_and_batched_registry_runs_are_bit_identical() {
        // The §8 contract through the generic drivers, per scenario (the
        // full engine matrix lives in rust/tests/scenario_matrix.rs).
        for spec in SCENARIOS {
            let mut a = FixedArith::new(FpFormat::E5M10);
            let mut b = FixedArith::new(FpFormat::E5M10);
            let s = (spec.run)(ScenarioSize::Quick, &mut a, QuantMode::Full, false);
            let g = (spec.run)(ScenarioSize::Quick, &mut b, QuantMode::Full, true);
            assert_eq!(s.muls, g.muls, "{}", spec.name);
            assert_eq!(s.range_events, g.range_events, "{}", spec.name);
            for i in 0..s.field.len() {
                assert_eq!(
                    s.field[i].to_bits(),
                    g.field[i].to_bits(),
                    "{}: node {i}",
                    spec.name
                );
            }
        }
    }

    #[test]
    fn quick_runs_track_f64_loosely() {
        // Sanity, not the envelope (that is Accuracy-sized in the matrix
        // test): short quick runs under E5M10 MulOnly stay near f64.
        for spec in SCENARIOS {
            let reference =
                (spec.run)(ScenarioSize::Quick, &mut F64Arith, QuantMode::MulOnly, true);
            let fmt = spec.wide_format;
            let mut be = FixedArith::new(fmt);
            let r = (spec.run)(ScenarioSize::Quick, &mut be, QuantMode::MulOnly, true);
            let err = rel_l2(&r.field, &reference.field);
            assert!(err < 0.2, "{}: quick rel err {err}", spec.name);
        }
    }

    #[test]
    fn adaptive_driver_reports_schedule_for_every_scenario() {
        // Full adaptive expectations (widen/narrow/cost/bit-equality) are
        // in rust/tests/scenario_matrix.rs; here: the generic driver runs
        // and charges ops for every scenario at Quick size.
        for spec in SCENARIOS {
            let mut sched = AdaptiveArith::new((spec.adaptive_policy)());
            let r = (spec.run_adaptive)(ScenarioSize::Quick, &mut sched, QuantMode::MulOnly, true);
            let rep = sched.report();
            let charged: u64 = rep.ops_per_rung.iter().map(|&(_, n)| n).sum();
            assert_eq!(charged, r.muls, "{}: charge accounting", spec.name);
        }
    }
}
