//! Initial conditions for the case studies.
//!
//! Fig. 1 of the paper uses `sin` and `exp` heat initializations; Fig. 2's
//! distribution study ("smallest value can be −500 ... in the last 25% all
//! values fall in (−0.25, 0.25)") implies a sine amplitude of several
//! hundred that decays through the run — our defaults reproduce that range
//! trajectory.

/// Heat-equation initial condition selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HeatInit {
    /// `u₀(x) = A · sin(c·π·x/L)` — Fig. 1(a)-(b); A defaults to 500.
    Sin { amplitude: f64, cycles: f64 },
    /// `u₀(x) = exp(r·x/L) − 1` — Fig. 1(c)-(d); r defaults to 10 so values
    /// span (0, e¹⁰ ≈ 2.2e4), exercising the wide-range story.
    Exp { rate: f64 },
    /// Centered Gaussian pulse `A·exp(−((x−L/2)/w)²)`.
    Gaussian { amplitude: f64, width: f64 },
    /// Step: A on the middle third, 0 elsewhere (sharp-gradient stressor).
    Step { amplitude: f64 },
}

impl HeatInit {
    /// The paper's sine case with the Fig. 2 amplitude.
    pub fn sin_default() -> HeatInit {
        HeatInit::Sin { amplitude: 500.0, cycles: 2.0 }
    }

    /// The paper's exponential case.
    pub fn exp_default() -> HeatInit {
        HeatInit::Exp { rate: 10.0 }
    }

    /// Sample the initial field on `n` nodes over `[0, L]`.
    pub fn sample(&self, n: usize, length: f64) -> Vec<f64> {
        (0..n)
            .map(|i| {
                let x = i as f64 / (n - 1) as f64 * length;
                self.at(x, length)
            })
            .collect()
    }

    /// Evaluate at position `x ∈ [0, L]`.
    pub fn at(&self, x: f64, length: f64) -> f64 {
        let s = x / length;
        match *self {
            HeatInit::Sin { amplitude, cycles } => {
                amplitude * (cycles * std::f64::consts::PI * s).sin()
            }
            HeatInit::Exp { rate } => (rate * s).exp() - 1.0,
            HeatInit::Gaussian { amplitude, width } => {
                let d = (x - 0.5 * length) / width;
                amplitude * (-d * d).exp()
            }
            HeatInit::Step { amplitude } => {
                if (1.0 / 3.0..=2.0 / 3.0).contains(&s) {
                    amplitude
                } else {
                    0.0
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            HeatInit::Sin { .. } => "sin",
            HeatInit::Exp { .. } => "exp",
            HeatInit::Gaussian { .. } => "gaussian",
            HeatInit::Step { .. } => "step",
        }
    }
}

/// Shallow-water initial condition: a Gaussian water-column perturbation
/// ("drop") on a flat basin — the classic dam-break/drop benchmark the
/// paper's Fig. 8 wave fronts correspond to.
///
/// The defaults are **continental-shelf scale** (like the paper's earth
/// simulation's shallow regions): with `h ≈ 150 m` the substituted flux
/// term `0.5·g·h² ≈ 1.1·10⁵` **overflows standard half** (max 65504) —
/// precisely the failure Fig. 8(c) shows — while one step of R2F2 exponent
/// widening (E6M9) both covers the range and still resolves the
/// cell-to-cell flux differences (~2·10³ vs an ulp of ~128). Much deeper
/// basins push the flux so high that *no* 16-bit mantissa resolves the
/// gradients; this scale is the regime where runtime reconfiguration wins,
/// which is the paper's operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweInit {
    /// Undisturbed depth in metres.
    pub base_depth: f64,
    /// Drop amplitude added on top of the base depth.
    pub amplitude: f64,
    /// Drop width as a fraction of the domain side.
    pub width_frac: f64,
    /// Drop center as fractions of the domain side.
    pub center: (f64, f64),
}

impl Default for SweInit {
    fn default() -> SweInit {
        SweInit { base_depth: 150.0, amplitude: 6.0, width_frac: 0.15, center: (0.5, 0.5) }
    }
}

impl SweInit {
    /// Sample the initial height field on an `n × n` interior grid.
    pub fn sample(&self, n: usize, side: f64) -> Vec<f64> {
        let w = self.width_frac * side;
        let (cx, cy) = (self.center.0 * side, self.center.1 * side);
        let mut h = vec![0.0; n * n];
        for j in 0..n {
            for i in 0..n {
                let x = (i as f64 + 0.5) / n as f64 * side;
                let y = (j as f64 + 0.5) / n as f64 * side;
                let d2 = ((x - cx) * (x - cx) + (y - cy) * (y - cy)) / (w * w);
                h[j * n + i] = self.base_depth + self.amplitude * (-d2).exp();
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sin_spans_paper_range() {
        let u = HeatInit::sin_default().sample(257, 1.0);
        let max = u.iter().cloned().fold(f64::MIN, f64::max);
        let min = u.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max > 499.0 && min < -499.0, "range [{min},{max}]");
    }

    #[test]
    fn sin_boundaries_are_zero() {
        let u = HeatInit::sin_default().sample(101, 1.0);
        assert!(u[0].abs() < 1e-9);
        assert!(u[100].abs() < 1e-10 * 500.0);
    }

    #[test]
    fn exp_is_monotone_and_wide() {
        let u = HeatInit::exp_default().sample(100, 1.0);
        assert!(u.windows(2).all(|w| w[1] > w[0]));
        assert_eq!(u[0], 0.0);
        assert!(u[99] > 2.0e4);
    }

    #[test]
    fn gaussian_peak_centered() {
        let u = HeatInit::Gaussian { amplitude: 3.0, width: 0.1 }.sample(101, 1.0);
        let (imax, _) =
            u.iter().enumerate().fold((0, f64::MIN), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
        assert_eq!(imax, 50);
    }

    #[test]
    fn swe_drop_above_base() {
        let init = SweInit::default();
        let h = init.sample(32, 32_000.0);
        assert!(h.iter().all(|&v| v >= init.base_depth - 1e-9));
        let peak = h.iter().cloned().fold(f64::MIN, f64::max);
        assert!(peak > init.base_depth + 0.8 * init.amplitude);
    }
}
