//! Domain decomposition over the worker pool (DESIGN.md §13).
//!
//! One grid, many shards: a 1D field splits into contiguous intervals and a
//! 2D field into row strips, each shard carrying the halo cells its stencil
//! reads across the cut. Per timestep every subdomain advances through
//! [`crate::coordinator::parallel_map`] — deterministic per-shard work,
//! like the Fig. 6 sweep — and the halo exchange happens at the step
//! boundary when the shards' results are scattered back into the one
//! global field the next step's gathers read.
//!
//! ```text
//!        shard 0            shard 1            shard 2
//!   ┌───────────────┬──────────────────┬───────────────┐
//!   │ own: [0, a)   │   own: [a, b)    │  own: [b, n)  │
//!   └───────────▲───┴──▲────────────▲──┴───▲───────────┘
//!          gather a-1  │   gather   │  gather b
//!               (halo) a..b-1 + a-1,b  (halo)
//!   step k:  every shard reads its slab from the global field,
//!            multiplies through its forked unit, writes its own cells;
//!   barrier: scatter owned cells → global field (the halo exchange);
//!   step k+1 gathers fresh halos — no shard ever reads a stale cell.
//! ```
//!
//! **Why bit-identity holds.** The adapters below change *where* each
//! multiplication executes, never *which* multiplications execute or on
//! what operands:
//!
//! * Ownership is a partition: every global operation (each `r·uⱼ`
//!   product, each flux evaluation, each combine) belongs to exactly one
//!   shard, so values, `muls` counts and range-event counters sum to the
//!   unsharded totals exactly.
//! * Halo values travel in the f64 carrier and are re-encoded by the
//!   consuming shard; encode under round-to-nearest-even is a pure
//!   function of (value, format), and `decode∘encode` is the identity on
//!   format-representable values (`tests/property_suite.rs`), so a halo
//!   re-encode can never perturb a product.
//! * Only **history-independent** backends fork ([`Arith::fork`]): their
//!   per-op results depend on the operands alone, so a shard seeing only
//!   its slice of the operation stream computes the same bits the global
//!   stream would. History-dependent units (R2F2's split register, the
//!   stochastic rounder) refuse to fork and the adapters fall back to the
//!   unsharded single-stream path — sharding degrades to a no-op, never
//!   to different arithmetic.
//! * The shared-product dedup of the heat sweep charges each `r·uⱼ`
//!   product once per *use* at the scalar multiplicity; each use lives in
//!   exactly one shard's slab, so per-shard event counts sum to the
//!   unsharded count even though a cut-adjacent product is *computed* by
//!   both neighbours.
//!
//! The adapters implement [`Sim`], so the generic drivers — including the
//! adaptive scheduler's save → attempt → decide epoch protocol — run
//! sharded unchanged: [`Sim::save`]/[`Sim::restore`] act on the assembled
//! global state, which makes a widen-retry atomic across *all* shards by
//! construction. The conformance suite is `rust/tests/decomp_identity.rs`.

use super::advection1d::{self, AdvectionParams, AdvectionResult, AdvectionSim};
use super::heat1d::{self, HeatParams, HeatResult, HeatSim};
use super::scenario::{self, Sim};
use super::swe2d::{self, f2_plain, flux_row, reflect, QuantScope, SweParams, SweResult, SweSim};
use super::wave2d::{self, WaveParams, WaveResult, WaveSim};
use super::{AdaptiveArith, Arith, Ctx, QuantMode};
use crate::coordinator::{default_workers, parallel_map};

/// One shard's owned index range `[lo, hi)` of a 1D grid (or of a row set,
/// for the 2D strips).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Part {
    pub lo: usize,
    pub hi: usize,
}

impl Part {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }
    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }
}

/// Split `[0, n)` into `shards` contiguous parts covering it exactly once,
/// sizes differing by at most one (the first `n mod k` parts take the
/// extra element). `shards` is clamped to `[1, n]` so every returned part
/// is non-empty — asking for more shards than elements yields `n` parts.
pub fn partition(n: usize, shards: usize) -> Vec<Part> {
    let k = shards.max(1).min(n.max(1));
    let base = n / k;
    let rem = n % k;
    let mut parts = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        parts.push(Part { lo, hi: lo + len });
        lo += len;
    }
    debug_assert_eq!(lo, n);
    parts
}

/// The halo-extended slab a 1D-stencil shard must gather: its owned
/// interior nodes plus one neighbour on each side. Returns `None` for a
/// part that owns no interior node (a boundary-only sliver — nothing to
/// compute). The slab bounds are global indices `[lo, hi)`.
pub fn stencil_slab(part: Part, n: usize) -> Option<(usize, usize)> {
    let i0 = part.lo.max(1);
    let i1 = part.hi.min(n - 1);
    if i0 >= i1 {
        return None;
    }
    Some((i0 - 1, i1 + 1))
}

/// Fork one worker unit per shard, or `None` if the backend is
/// history-dependent (the adapters then run the unsharded single stream).
fn fork_units(be: &dyn Arith, count: usize) -> Option<Vec<Box<dyn Arith + Send>>> {
    let mut units = Vec::with_capacity(count);
    for _ in 0..count {
        units.push(be.fork()?);
    }
    Some(units)
}

// ---------------------------------------------------------------------------
// heat1d
// ---------------------------------------------------------------------------

struct HeatTask {
    part: Part,
    be: Box<dyn Arith + Send>,
    muls: u64,
    slab: Vec<f64>,
    out: Vec<f64>,
}

/// [`HeatSim`] sharded into 1D intervals with one-node halos.
pub struct DecompHeat {
    inner: HeatSim,
    shards: usize,
}

impl DecompHeat {
    pub fn new(params: &HeatParams, shards: usize) -> DecompHeat {
        DecompHeat { inner: HeatSim::new(params), shards }
    }

    pub fn into_inner(self) -> HeatSim {
        self.inner
    }
}

impl Sim for DecompHeat {
    fn scenario(&self) -> &'static str {
        self.inner.scenario()
    }
    fn quant_state(&mut self, ctx: &mut Ctx<'_>) {
        self.inner.quant_state(ctx);
    }
    fn save(&self) -> Vec<Vec<f64>> {
        self.inner.save()
    }
    fn restore(&mut self, saved: &[Vec<f64>]) {
        self.inner.restore(saved);
    }
    fn telemetry(&self, out: &mut Vec<f64>) {
        self.inner.telemetry(out);
    }
    fn telemetry_len(&self) -> usize {
        self.inner.telemetry_len()
    }
    fn primary_field(&self) -> Vec<f64> {
        self.inner.primary_field()
    }

    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    ) {
        let n = self.inner.n;
        let parts = partition(n, self.shards);
        let units = if parts.len() > 1 { fork_units(&*ctx.be, parts.len()) } else { None };
        let Some(units) = units else {
            // One shard, or a history-dependent backend: the unsharded
            // single stream *is* the decomposed semantics.
            self.inner.advance(ctx, steps, step_base, snapshot_every, snaps, batched);
            return;
        };

        let mode = ctx.mode;
        let workers = default_workers();
        let r = self.inner.r;
        let two_r = 2.0 * r;
        let mut tasks: Vec<HeatTask> = parts
            .into_iter()
            .zip(units)
            .map(|(part, be)| HeatTask { part, be, muls: 0, slab: Vec::new(), out: Vec::new() })
            .collect();

        for s in 0..steps {
            let u = &self.inner.u;
            tasks = parallel_map(tasks, workers, |mut t| {
                let Some((s0, s1)) = stencil_slab(t.part, n) else {
                    return t;
                };
                t.slab.clear();
                t.slab.extend_from_slice(&u[s0..s1]);
                let m = t.slab.len();
                t.out.clear();
                t.out.resize(m, 0.0);
                let muls = {
                    let mut c = Ctx::new(t.be.as_mut(), mode);
                    if batched {
                        c.stencil_step(&mut t.out, &t.slab, r);
                    } else {
                        // The canonical per-multiplication sequence on the
                        // slab — identical per-node ops to the unsharded
                        // scalar path.
                        for i in 1..m - 1 {
                            let left = c.mul(r, t.slab[i - 1]);
                            let mid = c.mul(two_r, t.slab[i]);
                            let right = c.mul(r, t.slab[i + 1]);
                            let du = {
                                let tmp = c.sub(left, mid);
                                c.add(tmp, right)
                            };
                            let unew = c.add(t.slab[i], du);
                            t.out[i] = c.quant(unew);
                        }
                    }
                    c.muls
                };
                t.muls += muls;
                t
            });

            // Halo exchange: scatter every shard's owned interior back into
            // the global field; the next step's gathers see fresh values.
            for t in &tasks {
                if let Some((s0, _)) = stencil_slab(t.part, n) {
                    let i0 = t.part.lo.max(1);
                    let i1 = t.part.hi.min(n - 1);
                    for g in i0..i1 {
                        self.inner.next[g] = t.out[g - s0];
                    }
                }
            }
            self.inner.next[0] = self.inner.u[0];
            self.inner.next[n - 1] = self.inner.u[n - 1];
            std::mem::swap(&mut self.inner.u, &mut self.inner.next);
            let global = step_base + s + 1;
            if snapshot_every != 0 && global % snapshot_every == 0 {
                snaps.push((global, self.inner.u.clone()));
            }
        }

        for t in tasks {
            ctx.muls += t.muls;
            ctx.be.absorb(t.be.as_ref());
        }
    }
}

// ---------------------------------------------------------------------------
// advection1d
// ---------------------------------------------------------------------------

struct AdvTask {
    part: Part,
    be: Box<dyn Arith + Send>,
    muls: u64,
    pairs: Vec<(f64, f64)>,
    sq: Vec<f64>,
    prod: Vec<f64>,
    out: Vec<f64>,
}

/// [`AdvectionSim`] sharded into 1D intervals. The product row is the halo:
/// phase A fills each shard's owned products, the scatter publishes them,
/// and phase B's periodic-wrap reads (`pᵢ₋₁` across a cut, including the
/// `0 ↔ n−1` wrap) see the neighbour's fresh values.
pub struct DecompAdvection {
    inner: AdvectionSim,
    shards: usize,
}

impl DecompAdvection {
    pub fn new(params: &AdvectionParams, shards: usize) -> DecompAdvection {
        DecompAdvection { inner: AdvectionSim::new(params), shards }
    }

    pub fn into_inner(self) -> AdvectionSim {
        self.inner
    }
}

impl Sim for DecompAdvection {
    fn scenario(&self) -> &'static str {
        self.inner.scenario()
    }
    fn quant_state(&mut self, ctx: &mut Ctx<'_>) {
        self.inner.quant_state(ctx);
    }
    fn save(&self) -> Vec<Vec<f64>> {
        self.inner.save()
    }
    fn restore(&mut self, saved: &[Vec<f64>]) {
        self.inner.restore(saved);
    }
    fn telemetry(&self, out: &mut Vec<f64>) {
        self.inner.telemetry(out);
    }
    fn telemetry_len(&self) -> usize {
        self.inner.telemetry_len()
    }
    fn primary_field(&self) -> Vec<f64> {
        self.inner.primary_field()
    }

    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    ) {
        let n = self.inner.n;
        let parts = partition(n, self.shards);
        let units = if parts.len() > 1 { fork_units(&*ctx.be, parts.len()) } else { None };
        let Some(units) = units else {
            self.inner.advance(ctx, steps, step_base, snapshot_every, snaps, batched);
            return;
        };

        let mode = ctx.mode;
        let workers = default_workers();
        let coeff = self.inner.coeff;
        let burgers = self.inner.burgers;
        let mut tasks: Vec<AdvTask> = parts
            .into_iter()
            .zip(units)
            .map(|(part, be)| AdvTask {
                part,
                be,
                muls: 0,
                pairs: Vec::new(),
                sq: Vec::new(),
                prod: Vec::new(),
                out: Vec::new(),
            })
            .collect();

        for s in 0..steps {
            // Phase A: every shard's product row chunk, through its unit.
            {
                let u = &self.inner.u;
                tasks = parallel_map(tasks, workers, |mut t| {
                    let (lo, hi) = (t.part.lo, t.part.hi);
                    let len = hi - lo;
                    t.prod.clear();
                    t.prod.resize(len, 0.0);
                    let muls = {
                        let mut c = Ctx::new(t.be.as_mut(), mode);
                        if burgers {
                            t.sq.clear();
                            t.sq.resize(len, 0.0);
                            if batched {
                                t.pairs.clear();
                                t.pairs.extend(u[lo..hi].iter().map(|&v| (v, v)));
                                c.mul_pairs(&mut t.sq, &t.pairs);
                                c.mul_batch(&mut t.prod, coeff, &t.sq);
                            } else {
                                for j in 0..len {
                                    t.sq[j] = c.mul(u[lo + j], u[lo + j]);
                                }
                                for j in 0..len {
                                    t.prod[j] = c.mul(coeff, t.sq[j]);
                                }
                            }
                        } else if batched {
                            c.mul_batch(&mut t.prod, coeff, &u[lo..hi]);
                        } else {
                            for j in 0..len {
                                t.prod[j] = c.mul(coeff, u[lo + j]);
                            }
                        }
                        c.muls
                    };
                    t.muls += muls;
                    t
                });
            }
            // Product halo exchange.
            for t in &tasks {
                self.inner.prod[t.part.lo..t.part.hi].copy_from_slice(&t.prod);
            }
            // Phase B: the combine, reading the assembled product row
            // (periodic wrap crosses the cuts through the global arrays).
            {
                let u = &self.inner.u;
                let prod = &self.inner.prod;
                tasks = parallel_map(tasks, workers, |mut t| {
                    let (lo, hi) = (t.part.lo, t.part.hi);
                    t.out.clear();
                    t.out.resize(hi - lo, 0.0);
                    let mut c = Ctx::new(t.be.as_mut(), mode);
                    for i in lo..hi {
                        let im1 = if i == 0 { n - 1 } else { i - 1 };
                        let d = c.sub(prod[i], prod[im1]);
                        let unew = c.sub(u[i], d);
                        t.out[i - lo] = c.quant(unew);
                    }
                    t
                });
            }
            for t in &tasks {
                self.inner.next[t.part.lo..t.part.hi].copy_from_slice(&t.out);
            }
            std::mem::swap(&mut self.inner.u, &mut self.inner.next);
            let global = step_base + s + 1;
            if snapshot_every != 0 && global % snapshot_every == 0 {
                snaps.push((global, self.inner.u.clone()));
            }
        }

        for t in tasks {
            ctx.muls += t.muls;
            ctx.be.absorb(t.be.as_ref());
        }
    }
}

// ---------------------------------------------------------------------------
// wave2d
// ---------------------------------------------------------------------------

struct WaveTask {
    /// Owned interior-row range (0-based over the `n−2` interior rows).
    part: Part,
    be: Box<dyn Arith + Send>,
    muls: u64,
    row_u: Vec<f64>,
    row_old: Vec<f64>,
    row_lap: Vec<f64>,
    p1: Vec<f64>,
    p0: Vec<f64>,
    p2: Vec<f64>,
    out: Vec<f64>,
}

/// [`WaveSim`] sharded into row strips. Each strip's Laplacian gather
/// reads rows `i−1` and `i+1` of the global field — the one-row halo —
/// while it owns the writes to its own rows only.
pub struct DecompWave {
    inner: WaveSim,
    shards: usize,
}

impl DecompWave {
    pub fn new(params: &WaveParams, shards: usize) -> DecompWave {
        DecompWave { inner: WaveSim::new(params), shards }
    }

    pub fn into_inner(self) -> WaveSim {
        self.inner
    }
}

impl Sim for DecompWave {
    fn scenario(&self) -> &'static str {
        self.inner.scenario()
    }
    fn quant_state(&mut self, ctx: &mut Ctx<'_>) {
        self.inner.quant_state(ctx);
    }
    fn save(&self) -> Vec<Vec<f64>> {
        self.inner.save()
    }
    fn restore(&mut self, saved: &[Vec<f64>]) {
        self.inner.restore(saved);
    }
    fn telemetry(&self, out: &mut Vec<f64>) {
        self.inner.telemetry(out);
    }
    fn telemetry_len(&self) -> usize {
        self.inner.telemetry_len()
    }
    fn primary_field(&self) -> Vec<f64> {
        self.inner.primary_field()
    }

    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    ) {
        let n = self.inner.n;
        let w = n - 2; // interior row width
        let parts = partition(n - 2, self.shards);
        let units = if parts.len() > 1 { fork_units(&*ctx.be, parts.len()) } else { None };
        let Some(units) = units else {
            self.inner.advance(ctx, steps, step_base, snapshot_every, snaps, batched);
            return;
        };

        let mode = ctx.mode;
        let workers = default_workers();
        let (d1, d0, c2) = (self.inner.d1, self.inner.d0, self.inner.c2);
        let mut tasks: Vec<WaveTask> = parts
            .into_iter()
            .zip(units)
            .map(|(part, be)| WaveTask {
                part,
                be,
                muls: 0,
                row_u: vec![0.0; w],
                row_old: vec![0.0; w],
                row_lap: vec![0.0; w],
                p1: vec![0.0; w],
                p0: vec![0.0; w],
                p2: vec![0.0; w],
                out: Vec::new(),
            })
            .collect();

        for s in 0..steps {
            {
                let u = &self.inner.u;
                let uold = &self.inner.uold;
                tasks = parallel_map(tasks, workers, |mut t| {
                    let (lo, hi) = (t.part.lo, t.part.hi);
                    t.out.clear();
                    t.out.resize((hi - lo) * w, 0.0);
                    let muls = {
                        let mut c = Ctx::new(t.be.as_mut(), mode);
                        for (ri, row) in (lo..hi).enumerate() {
                            let i = row + 1; // global row index
                            let base = i * n;
                            for j in 1..n - 1 {
                                let id = base + j;
                                t.row_u[j - 1] = u[id];
                                t.row_old[j - 1] = uold[id];
                                t.row_lap[j - 1] = u[id - n] + u[id + n] + u[id - 1]
                                    + u[id + 1]
                                    - 4.0 * u[id];
                            }
                            if batched {
                                c.mul_batch(&mut t.p1, d1, &t.row_u);
                                c.mul_batch(&mut t.p0, d0, &t.row_old);
                                c.mul_batch(&mut t.p2, c2, &t.row_lap);
                            } else {
                                for j in 0..w {
                                    t.p1[j] = c.mul(d1, t.row_u[j]);
                                }
                                for j in 0..w {
                                    t.p0[j] = c.mul(d0, t.row_old[j]);
                                }
                                for j in 0..w {
                                    t.p2[j] = c.mul(c2, t.row_lap[j]);
                                }
                            }
                            for j in 0..w {
                                let sv = c.sub(t.p1[j], t.p0[j]);
                                let unew = c.add(sv, t.p2[j]);
                                t.out[ri * w + j] = c.quant(unew);
                            }
                        }
                        c.muls
                    };
                    t.muls += muls;
                    t
                });
            }

            // Halo exchange: owned interior rows back into the global next.
            for t in &tasks {
                for (ri, row) in (t.part.lo..t.part.hi).enumerate() {
                    let i = row + 1;
                    self.inner.next[i * n + 1..i * n + n - 1]
                        .copy_from_slice(&t.out[ri * w..(ri + 1) * w]);
                }
            }
            // Dirichlet walls stay put (coordinator-side, as in the solver).
            for j in 0..n {
                self.inner.next[j] = self.inner.u[j];
                self.inner.next[(n - 1) * n + j] = self.inner.u[(n - 1) * n + j];
            }
            for i in 1..n - 1 {
                self.inner.next[i * n] = self.inner.u[i * n];
                self.inner.next[i * n + n - 1] = self.inner.u[i * n + n - 1];
            }
            std::mem::swap(&mut self.inner.uold, &mut self.inner.u);
            std::mem::swap(&mut self.inner.u, &mut self.inner.next);
            let global = step_base + s + 1;
            if snapshot_every != 0 && global % snapshot_every == 0 {
                snaps.push((global, self.inner.u.clone()));
            }
        }

        for t in tasks {
            ctx.muls += t.muls;
            ctx.be.absorb(t.be.as_ref());
        }
    }
}

// ---------------------------------------------------------------------------
// swe2d
// ---------------------------------------------------------------------------

struct SweTask {
    /// Owned x-half-step rows (of `0..=n`).
    px: Part,
    /// Owned y-half-step rows (of `0..n`).
    py: Part,
    /// Owned full-step rows, 0-based (global row = index + 1).
    pf: Part,
    be: Box<dyn Arith + Send>,
    muls: u64,
    fin: Vec<(f64, f64)>,
    frow: Vec<f64>,
    hx: Vec<f64>,
    ux: Vec<f64>,
    vx: Vec<f64>,
    hy: Vec<f64>,
    uy: Vec<f64>,
    vy: Vec<f64>,
    oh: Vec<f64>,
    ou: Vec<f64>,
    ov: Vec<f64>,
}

/// [`SweSim`] sharded into row strips, one partition per phase of the
/// two-step Lax–Wendroff scheme. The half-step arrays are the halos: each
/// phase's scatter publishes a shard's rows before the next phase's
/// cross-row reads.
pub struct DecompSwe {
    inner: SweSim,
    shards: usize,
}

impl DecompSwe {
    pub fn new(params: &SweParams, scope: QuantScope, shards: usize) -> DecompSwe {
        DecompSwe { inner: SweSim::new(params, scope), shards }
    }

    pub fn into_inner(self) -> SweSim {
        self.inner
    }
}

impl Sim for DecompSwe {
    fn scenario(&self) -> &'static str {
        self.inner.scenario()
    }
    fn quant_state(&mut self, ctx: &mut Ctx<'_>) {
        self.inner.quant_state(ctx);
    }
    fn save(&self) -> Vec<Vec<f64>> {
        self.inner.save()
    }
    fn restore(&mut self, saved: &[Vec<f64>]) {
        self.inner.restore(saved);
    }
    fn telemetry(&self, out: &mut Vec<f64>) {
        self.inner.telemetry(out);
    }
    fn telemetry_len(&self) -> usize {
        self.inner.telemetry_len()
    }
    fn primary_field(&self) -> Vec<f64> {
        self.inner.primary_field()
    }

    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    ) {
        let n = self.inner.n;
        let m = self.inner.m;
        // One shard count for all three phases (n ≥ 4 rows in each), so a
        // task owns an aligned strip of every phase.
        let k = self.shards.max(1).min(n);
        let units = if k > 1 { fork_units(&*ctx.be, k) } else { None };
        let Some(units) = units else {
            self.inner.advance(ctx, steps, step_base, snapshot_every, snaps, batched);
            return;
        };

        let mode = ctx.mode;
        let workers = default_workers();
        let scope = self.inner.scope;
        let g2 = self.inner.g2;
        let (ddx, ddy) = (self.inner.ddx, self.inner.ddy);
        let all = scope == QuantScope::AllFluxMuls;
        let parts_x = partition(n + 1, k);
        let parts_y = partition(n, k);
        let parts_f = partition(n, k);
        let mut tasks: Vec<SweTask> = (0..k)
            .zip(units)
            .map(|(i, be)| SweTask {
                px: parts_x[i],
                py: parts_y[i],
                pf: parts_f[i],
                be,
                muls: 0,
                fin: Vec::new(),
                frow: Vec::new(),
                hx: Vec::new(),
                ux: Vec::new(),
                vx: Vec::new(),
                hy: Vec::new(),
                uy: Vec::new(),
                vy: Vec::new(),
                oh: Vec::new(),
                ou: Vec::new(),
                ov: Vec::new(),
            })
            .collect();

        for s in 0..steps {
            reflect(&mut self.inner.grid);

            // First half step — x direction, rows of 0..=n by strip.
            {
                let grid = &self.inner.grid;
                tasks = parallel_map(tasks, workers, |mut t| {
                    let (a, b) = (t.px.lo, t.px.hi);
                    let len = (b - a) * m;
                    t.hx.clear();
                    t.hx.resize(len, 0.0);
                    t.ux.clear();
                    t.ux.resize(len, 0.0);
                    t.vx.clear();
                    t.vx.resize(len, 0.0);
                    let muls = {
                        let mut c = Ctx::new(t.be.as_mut(), mode);
                        for i in a..b {
                            if all {
                                t.fin.clear();
                                for j in 0..n {
                                    let ga = grid.idx(i + 1, j + 1);
                                    let gb = grid.idx(i, j + 1);
                                    t.fin.push((grid.u[ga], grid.h[ga]));
                                    t.fin.push((grid.u[gb], grid.h[gb]));
                                }
                                flux_row(&mut c, g2, &t.fin, &mut t.frow, batched);
                            }
                            for j in 0..n {
                                let ga = grid.idx(i + 1, j + 1);
                                let gb = grid.idx(i, j + 1);
                                let kk = (i - a) * m + j;
                                t.hx[kk] = 0.5 * (grid.h[ga] + grid.h[gb])
                                    - 0.5 * ddx * (grid.u[ga] - grid.u[gb]);
                                let (fa, fb) = if all {
                                    (t.frow[2 * j], t.frow[2 * j + 1])
                                } else {
                                    (
                                        f2_plain(g2, grid.u[ga], grid.h[ga]),
                                        f2_plain(g2, grid.u[gb], grid.h[gb]),
                                    )
                                };
                                t.ux[kk] =
                                    0.5 * (grid.u[ga] + grid.u[gb]) - 0.5 * ddx * (fa - fb);
                                t.vx[kk] = 0.5 * (grid.v[ga] + grid.v[gb])
                                    - 0.5
                                        * ddx
                                        * (grid.u[ga] * grid.v[ga] / grid.h[ga]
                                            - grid.u[gb] * grid.v[gb] / grid.h[gb]);
                            }
                        }
                        c.muls
                    };
                    t.muls += muls;
                    t
                });
            }
            for t in &tasks {
                let (a, b) = (t.px.lo, t.px.hi);
                self.inner.hx[a * m..b * m].copy_from_slice(&t.hx);
                self.inner.ux[a * m..b * m].copy_from_slice(&t.ux);
                self.inner.vx[a * m..b * m].copy_from_slice(&t.vx);
            }

            // First half step — y direction, rows of 0..n by strip.
            {
                let grid = &self.inner.grid;
                tasks = parallel_map(tasks, workers, |mut t| {
                    let (a, b) = (t.py.lo, t.py.hi);
                    let len = (b - a) * m;
                    t.hy.clear();
                    t.hy.resize(len, 0.0);
                    t.uy.clear();
                    t.uy.resize(len, 0.0);
                    t.vy.clear();
                    t.vy.resize(len, 0.0);
                    let muls = {
                        let mut c = Ctx::new(t.be.as_mut(), mode);
                        for i in a..b {
                            if all {
                                t.fin.clear();
                                for j in 0..=n {
                                    let ga = grid.idx(i + 1, j + 1);
                                    let gb = grid.idx(i + 1, j);
                                    t.fin.push((grid.v[ga], grid.h[ga]));
                                    t.fin.push((grid.v[gb], grid.h[gb]));
                                }
                                flux_row(&mut c, g2, &t.fin, &mut t.frow, batched);
                            }
                            for j in 0..=n {
                                let ga = grid.idx(i + 1, j + 1);
                                let gb = grid.idx(i + 1, j);
                                let kk = (i - a) * m + j;
                                t.hy[kk] = 0.5 * (grid.h[ga] + grid.h[gb])
                                    - 0.5 * ddy * (grid.v[ga] - grid.v[gb]);
                                t.uy[kk] = 0.5 * (grid.u[ga] + grid.u[gb])
                                    - 0.5
                                        * ddy
                                        * (grid.v[ga] * grid.u[ga] / grid.h[ga]
                                            - grid.v[gb] * grid.u[gb] / grid.h[gb]);
                                let (ga2, gb2) = if all {
                                    (t.frow[2 * j], t.frow[2 * j + 1])
                                } else {
                                    (
                                        f2_plain(g2, grid.v[ga], grid.h[ga]),
                                        f2_plain(g2, grid.v[gb], grid.h[gb]),
                                    )
                                };
                                t.vy[kk] =
                                    0.5 * (grid.v[ga] + grid.v[gb]) - 0.5 * ddy * (ga2 - gb2);
                            }
                        }
                        c.muls
                    };
                    t.muls += muls;
                    t
                });
            }
            for t in &tasks {
                let (a, b) = (t.py.lo, t.py.hi);
                self.inner.hy[a * m..b * m].copy_from_slice(&t.hy);
                self.inner.uy[a * m..b * m].copy_from_slice(&t.uy);
                self.inner.vy[a * m..b * m].copy_from_slice(&t.vy);
            }

            // Second (full) step — interior rows 1..=n by strip; reads the
            // assembled half-step arrays (the halos), writes its own rows.
            {
                let grid = &self.inner.grid;
                let (hx, ux, vx) = (&self.inner.hx, &self.inner.ux, &self.inner.vx);
                let (hy, uy, vy) = (&self.inner.hy, &self.inner.uy, &self.inner.vy);
                tasks = parallel_map(tasks, workers, |mut t| {
                    let (a, b) = (t.pf.lo + 1, t.pf.hi + 1);
                    let len = (b - a) * n;
                    t.oh.clear();
                    t.oh.resize(len, 0.0);
                    t.ou.clear();
                    t.ou.resize(len, 0.0);
                    t.ov.clear();
                    t.ov.resize(len, 0.0);
                    let stride = if all { 4 } else { 2 };
                    let muls = {
                        let mut c = Ctx::new(t.be.as_mut(), mode);
                        for i in a..b {
                            t.fin.clear();
                            for j in 1..=n {
                                let kxa = i * m + (j - 1);
                                let kxb = (i - 1) * m + (j - 1);
                                t.fin.push((ux[kxa], hx[kxa]));
                                t.fin.push((ux[kxb], hx[kxb]));
                                if all {
                                    let kya = (i - 1) * m + j;
                                    let kyb = (i - 1) * m + (j - 1);
                                    t.fin.push((vy[kya], hy[kya]));
                                    t.fin.push((vy[kyb], hy[kyb]));
                                }
                            }
                            flux_row(&mut c, g2, &t.fin, &mut t.frow, batched);
                            for j in 1..=n {
                                let cc = grid.idx(i, j);
                                let kxa = i * m + (j - 1);
                                let kxb = (i - 1) * m + (j - 1);
                                let kya = (i - 1) * m + j;
                                let kyb = (i - 1) * m + (j - 1);
                                let o = (i - a) * n + (j - 1);

                                t.oh[o] = grid.h[cc]
                                    - (ddx * (ux[kxa] - ux[kxb]) + ddy * (vy[kya] - vy[kyb]));

                                let base = (j - 1) * stride;
                                let (fa, fb) = (t.frow[base], t.frow[base + 1]);
                                t.ou[o] = grid.u[cc]
                                    - (ddx * (fa - fb)
                                        + ddy
                                            * (vy[kya] * uy[kya] / hy[kya]
                                                - vy[kyb] * uy[kyb] / hy[kyb]));

                                let (ga, gb) = if all {
                                    (t.frow[base + 2], t.frow[base + 3])
                                } else {
                                    (
                                        f2_plain(g2, vy[kya], hy[kya]),
                                        f2_plain(g2, vy[kyb], hy[kyb]),
                                    )
                                };
                                t.ov[o] = grid.v[cc]
                                    - (ddx
                                        * (ux[kxa] * vx[kxa] / hx[kxa]
                                            - ux[kxb] * vx[kxb] / hx[kxb])
                                        + ddy * (ga - gb));
                            }
                        }
                        c.muls
                    };
                    t.muls += muls;
                    t
                });
            }
            for t in &tasks {
                for (ri, i) in ((t.pf.lo + 1)..(t.pf.hi + 1)).enumerate() {
                    for j in 1..=n {
                        let cc = self.inner.grid.idx(i, j);
                        self.inner.grid.h[cc] = t.oh[ri * n + (j - 1)];
                        self.inner.grid.u[cc] = t.ou[ri * n + (j - 1)];
                        self.inner.grid.v[cc] = t.ov[ri * n + (j - 1)];
                    }
                }
            }

            let global = step_base + s + 1;
            if snapshot_every != 0 && global % snapshot_every == 0 {
                snaps.push((global, self.inner.interior_h()));
            }
        }

        for t in tasks {
            ctx.muls += t.muls;
            ctx.be.absorb(t.be.as_ref());
        }
    }
}

// ---------------------------------------------------------------------------
// Run wrappers (the `shards` knob the config/serving layers call)
// ---------------------------------------------------------------------------

/// Sharded [`heat1d::run`]: `shards = 1` (or a non-forkable backend) is the
/// unsharded run, and every other shard count is bit-identical to it.
pub fn run_heat(
    params: &HeatParams,
    be: &mut dyn Arith,
    mode: QuantMode,
    shards: usize,
) -> HeatResult {
    let mut sim = DecompHeat::new(params, shards);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, true);
    heat1d::finish(sim.into_inner(), stats)
}

/// Sharded [`heat1d::run_adaptive`] — the widen-retry restores the whole
/// assembled grid, so a format switch is atomic across all shards.
pub fn run_heat_adaptive(
    params: &HeatParams,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    shards: usize,
) -> HeatResult {
    let mut sim = DecompHeat::new(params, shards);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        true,
    );
    heat1d::finish(sim.into_inner(), stats)
}

/// Sharded [`advection1d::run`].
pub fn run_advection(
    params: &AdvectionParams,
    be: &mut dyn Arith,
    mode: QuantMode,
    shards: usize,
) -> AdvectionResult {
    let mut sim = DecompAdvection::new(params, shards);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, true);
    advection1d::finish(sim.into_inner(), stats)
}

/// Sharded [`advection1d::run_adaptive`].
pub fn run_advection_adaptive(
    params: &AdvectionParams,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    shards: usize,
) -> AdvectionResult {
    let mut sim = DecompAdvection::new(params, shards);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        true,
    );
    advection1d::finish(sim.into_inner(), stats)
}

/// Sharded [`wave2d::run`].
pub fn run_wave(
    params: &WaveParams,
    be: &mut dyn Arith,
    mode: QuantMode,
    shards: usize,
) -> WaveResult {
    let mut sim = DecompWave::new(params, shards);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, true);
    wave2d::finish(sim.into_inner(), stats)
}

/// Sharded [`wave2d::run_adaptive`].
pub fn run_wave_adaptive(
    params: &WaveParams,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
    shards: usize,
) -> WaveResult {
    let mut sim = DecompWave::new(params, shards);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        true,
    );
    wave2d::finish(sim.into_inner(), stats)
}

/// Sharded [`swe2d::run_mode`].
pub fn run_swe(
    params: &SweParams,
    be: &mut dyn Arith,
    scope: QuantScope,
    mode: QuantMode,
    shards: usize,
) -> SweResult {
    let mut sim = DecompSwe::new(params, scope, shards);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, true);
    swe2d::finish_result(sim.into_inner(), stats)
}

/// Sharded [`swe2d::run_adaptive`].
pub fn run_swe_adaptive(
    params: &SweParams,
    sched: &mut AdaptiveArith,
    scope: QuantScope,
    mode: QuantMode,
    shards: usize,
) -> SweResult {
    let mut sim = DecompSwe::new(params, scope, shards);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        true,
    );
    swe2d::finish_result(sim.into_inner(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{BatchEngine, F64Arith, FixedArith, R2f2Arith};
    use crate::r2f2core::R2f2Config;
    use crate::softfloat::FpFormat;

    #[test]
    fn partition_covers_exactly_once_with_balanced_sizes() {
        for n in [1usize, 2, 3, 7, 64, 1000] {
            for shards in [1usize, 2, 3, 7, 64, 2000] {
                let parts = partition(n, shards);
                assert_eq!(parts.len(), shards.min(n));
                assert_eq!(parts[0].lo, 0);
                assert_eq!(parts.last().unwrap().hi, n);
                for w in parts.windows(2) {
                    assert_eq!(w[0].hi, w[1].lo, "gap/overlap at {w:?}");
                }
                let min = parts.iter().map(Part::len).min().unwrap();
                let max = parts.iter().map(Part::len).max().unwrap();
                assert!(max - min <= 1, "unbalanced: {min}..{max}");
                assert!(parts.iter().all(|p| !p.is_empty()));
            }
        }
    }

    #[test]
    fn stencil_slab_overlaps_are_exactly_one_node() {
        let n = 11;
        let parts = partition(n, 3);
        let slabs: Vec<_> = parts.iter().filter_map(|&p| stencil_slab(p, n)).collect();
        // Each slab = owned interior ± 1; neighbours overlap by 2 nodes
        // (each other's halo + boundary-shared node).
        for (&(s0, s1), &p) in slabs.iter().zip(parts.iter()) {
            assert_eq!(s0, p.lo.max(1) - 1);
            assert_eq!(s1, p.hi.min(n - 1) + 1);
        }
        // Boundary-only parts have no slab.
        assert!(stencil_slab(Part { lo: 0, hi: 1 }, 3).is_none());
        assert!(stencil_slab(Part { lo: 2, hi: 3 }, 3).is_none());
        assert!(stencil_slab(Part { lo: 1, hi: 2 }, 3).is_some());
    }

    fn heat_params() -> HeatParams {
        HeatParams { n: 33, dt: 0.25 / (32.0f64 * 32.0), steps: 25, ..HeatParams::default() }
    }

    #[test]
    fn sharded_heat_is_bit_identical_for_forkable_backends() {
        let p = heat_params();
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            let mut be = FixedArith::new(FpFormat::E5M10);
            let base = heat1d::run(&p, &mut be, mode);
            for shards in [1usize, 2, 3, 7, 32] {
                let mut be = FixedArith::new(FpFormat::E5M10);
                let run = run_heat(&p, &mut be, mode, shards);
                assert_eq!(run.muls, base.muls, "{mode:?} shards={shards}");
                assert_eq!(run.range_events, base.range_events, "{mode:?} shards={shards}");
                for i in 0..p.n {
                    assert_eq!(
                        run.u[i].to_bits(),
                        base.u[i].to_bits(),
                        "{mode:?} shards={shards} node {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_heat_carrier_engine_and_f64_also_match() {
        let p = heat_params();
        let mut be = FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier);
        let base = heat1d::run(&p, &mut be, QuantMode::Full);
        let mut be = FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier);
        let run = run_heat(&p, &mut be, QuantMode::Full, 3);
        assert_eq!(run.range_events, base.range_events);
        for i in 0..p.n {
            assert_eq!(run.u[i].to_bits(), base.u[i].to_bits(), "node {i}");
        }

        let base = heat1d::run(&p, &mut F64Arith, QuantMode::MulOnly);
        let run = run_heat(&p, &mut F64Arith, QuantMode::MulOnly, 5);
        for i in 0..p.n {
            assert_eq!(run.u[i].to_bits(), base.u[i].to_bits(), "f64 node {i}");
        }
    }

    #[test]
    fn non_forkable_backend_falls_back_to_the_unsharded_stream() {
        let p = heat_params();
        let mut a = R2f2Arith::new(R2f2Config::C16_393);
        let base = heat1d::run(&p, &mut a, QuantMode::MulOnly);
        let mut b = R2f2Arith::new(R2f2Config::C16_393);
        let run = run_heat(&p, &mut b, QuantMode::MulOnly, 4);
        assert_eq!(run.r2f2_stats, base.r2f2_stats);
        for i in 0..p.n {
            assert_eq!(run.u[i].to_bits(), base.u[i].to_bits(), "node {i}");
        }
    }

    #[test]
    fn n3_grid_shards_to_single_interior_node() {
        // The degenerate split: two boundary-only shards, one worker shard.
        let p = HeatParams { n: 3, dt: 0.25 / 4.0, steps: 8, ..HeatParams::default() };
        let mut be = FixedArith::new(FpFormat::E5M10);
        let base = heat1d::run(&p, &mut be, QuantMode::Full);
        let mut be = FixedArith::new(FpFormat::E5M10);
        let run = run_heat(&p, &mut be, QuantMode::Full, 3);
        assert_eq!(run.muls, base.muls);
        assert_eq!(run.range_events, base.range_events);
        for i in 0..3 {
            assert_eq!(run.u[i].to_bits(), base.u[i].to_bits());
        }
    }

    #[test]
    fn sharded_burgers_advection_is_bit_identical() {
        let p = AdvectionParams {
            n: 64,
            steps: 40,
            ..AdvectionParams::burgers_default()
        };
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            let mut be = FixedArith::new(FpFormat::E5M10);
            let base = advection1d::run(&p, &mut be, mode);
            for shards in [2usize, 3, 7, 63] {
                let mut be = FixedArith::new(FpFormat::E5M10);
                let run = run_advection(&p, &mut be, mode, shards);
                assert_eq!(run.muls, base.muls, "{mode:?} shards={shards}");
                assert_eq!(run.range_events, base.range_events, "{mode:?} shards={shards}");
                for i in 0..p.n {
                    assert_eq!(
                        run.u[i].to_bits(),
                        base.u[i].to_bits(),
                        "{mode:?} shards={shards} cell {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_wave_is_bit_identical() {
        let p = WaveParams { n: 17, dt: 0.5 / 16.0, steps: 30, ..WaveParams::default() };
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            let mut be = FixedArith::new(FpFormat::E5M10);
            let base = wave2d::run(&p, &mut be, mode);
            for shards in [2usize, 3, 7, 15] {
                let mut be = FixedArith::new(FpFormat::E5M10);
                let run = run_wave(&p, &mut be, mode, shards);
                assert_eq!(run.muls, base.muls, "{mode:?} shards={shards}");
                assert_eq!(run.range_events, base.range_events, "{mode:?} shards={shards}");
                for i in 0..run.u.len() {
                    assert_eq!(
                        run.u[i].to_bits(),
                        base.u[i].to_bits(),
                        "{mode:?} shards={shards} node {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_swe_is_bit_identical_in_both_scopes() {
        let p = SweParams { steps: 12, ..SweParams::default() };
        for scope in [QuantScope::UxFluxOnly, QuantScope::AllFluxMuls] {
            let mut be = FixedArith::new(FpFormat::new(6, 9));
            let base = swe2d::run_mode(&p, &mut be, scope, QuantMode::MulOnly);
            for shards in [2usize, 3, 7] {
                let mut be = FixedArith::new(FpFormat::new(6, 9));
                let run = run_swe(&p, &mut be, scope, QuantMode::MulOnly, shards);
                assert_eq!(run.muls, base.muls, "{scope:?} shards={shards}");
                assert_eq!(run.range_events, base.range_events, "{scope:?} shards={shards}");
                assert_eq!(run.mass_drift.to_bits(), base.mass_drift.to_bits());
                for (name, a, b) in
                    [("h", &run.h, &base.h), ("u", &run.u, &base.u), ("v", &run.v, &base.v)]
                {
                    for i in 0..a.len() {
                        assert_eq!(
                            a[i].to_bits(),
                            b[i].to_bits(),
                            "{scope:?} shards={shards} {name}[{i}]"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_snapshots_match_unsharded() {
        let p = HeatParams {
            n: 33,
            dt: 0.25 / (32.0f64 * 32.0),
            steps: 40,
            snapshot_every: 10,
            ..HeatParams::default()
        };
        let mut be = FixedArith::new(FpFormat::E5M10);
        let base = heat1d::run(&p, &mut be, QuantMode::Full);
        let mut be = FixedArith::new(FpFormat::E5M10);
        let run = run_heat(&p, &mut be, QuantMode::Full, 4);
        assert_eq!(run.snapshots.len(), base.snapshots.len());
        for (a, b) in run.snapshots.iter().zip(base.snapshots.iter()) {
            assert_eq!(a.0, b.0);
            for i in 0..a.1.len() {
                assert_eq!(a.1[i].to_bits(), b.1[i].to_bits(), "snapshot step {} node {i}", a.0);
            }
        }
    }

    #[test]
    fn sharded_adaptive_heat_matches_unsharded_schedule_and_field() {
        use crate::pde::adaptive::AdaptivePolicy;
        let p = HeatParams {
            n: 33,
            dt: 0.25 / (32.0f64 * 32.0),
            steps: 600,
            ..HeatParams::default()
        };
        let mut pol = AdaptivePolicy::heat_default();
        pol.epoch_len = 50;
        let mut s_base = AdaptiveArith::new(pol.clone());
        let base = heat1d::run_adaptive(&p, &mut s_base, QuantMode::MulOnly);
        for shards in [2usize, 5] {
            let mut s_run = AdaptiveArith::new(pol.clone());
            let run = run_heat_adaptive(&p, &mut s_run, QuantMode::MulOnly, shards);
            assert_eq!(s_run.decisions(), s_base.decisions(), "shards={shards}");
            assert_eq!(s_run.trace(), s_base.trace(), "shards={shards}");
            assert_eq!(run.muls, base.muls, "shards={shards}");
            assert_eq!(run.range_events, base.range_events, "shards={shards}");
            for i in 0..p.n {
                assert_eq!(run.u[i].to_bits(), base.u[i].to_bits(), "shards={shards} node {i}");
            }
        }
        // The schedule must actually have widened (real adaptive pressure).
        assert!(s_base.report().widen_events >= 1);
    }
}
