//! 2D shallow-water equations, two-step (Richtmyer) Lax–Wendroff (§2, Fig. 8).
//!
//! State per cell: `h` (depth), `u = h·vx`, `v = h·vy` on an `n × n`
//! interior grid with one ring of ghost cells and reflective walls.
//! Fluxes:
//!
//! ```text
//! x: F = (u,            u²/h + g/2·h²,  u·v/h)
//! y: G = (v,            u·v/h,          v²/h + g/2·h²)
//! ```
//!
//! The paper substitutes R2F2 into exactly **one sub-equation** of the 24
//! (§5.3): `Ux_mx[i][j] = q1_mx·q1_mx/q3_mx + 0.5g·q3_mx·q3_mx` — the
//! x-momentum flux evaluated from the half-step (midpoint) values. With
//! [`QuantScope::UxFluxOnly`] precisely those multiplications route through
//! the backend (3 per evaluation: `q1²`, `q3²`, `0.5g·q3²`); everything
//! else stays f64, as in the paper. [`QuantScope::AllFluxMuls`] is the
//! ablation that quantizes every flux multiplication.

use super::init::SweInit;
use super::scenario::{self, RunStats, Sim};
use super::{Arith, Ctx, QuantMode, RangeEvents};
use crate::r2f2core::Stats;

/// Which multiplications go through the arithmetic backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantScope {
    /// Only the full-step x-momentum flux from midpoint values — the
    /// paper's substituted sub-equation.
    UxFluxOnly,
    /// Every multiplication in every flux evaluation (ablation).
    AllFluxMuls,
}

/// Shallow-water run parameters.
#[derive(Debug, Clone)]
pub struct SweParams {
    /// Interior grid side (n × n cells).
    pub n: usize,
    /// Gravity.
    pub g: f64,
    /// Cell size (Δx = Δy).
    pub dx: f64,
    /// Time step (CFL: `dt·(√(g·h_max)+|u|) < dx/2` is comfortable).
    pub dt: f64,
    /// Number of time steps.
    pub steps: usize,
    /// Initial water-drop condition.
    pub init: SweInit,
    /// Keep an `h`-field snapshot every `snapshot_every` steps (0 = none).
    pub snapshot_every: usize,
}

impl Default for SweParams {
    fn default() -> SweParams {
        // Shelf scale: 16×16 cells of 2 km over a 150 m deep basin
        // (c = √(g·h) ≈ 39 m/s; CFL = c·dt/dx ≈ 0.4). 20 steps ⇒
        // 6·n²·steps = 30 720 quantized muls, matching the paper's
        // "within the 30K multiplications" (§5.3).
        SweParams {
            n: 16,
            g: 9.8,
            dx: 2000.0,
            dt: 20.0,
            steps: 20,
            init: SweInit::default(),
            snapshot_every: 0,
        }
    }
}

impl SweParams {
    /// Quantized multiplications the run will issue under
    /// [`QuantScope::UxFluxOnly`] (2 F2 evaluations × 3 muls per interior
    /// cell per step).
    pub fn expected_muls(&self) -> u64 {
        6 * (self.n * self.n) as u64 * self.steps as u64
    }
}

/// Result of a shallow-water run.
#[derive(Debug, Clone)]
pub struct SweResult {
    /// Final interior depth field (n×n, row-major).
    pub h: Vec<f64>,
    /// Final interior x-momentum.
    pub u: Vec<f64>,
    /// Final interior y-momentum.
    pub v: Vec<f64>,
    /// `(step, h-field)` snapshots if requested.
    pub snapshots: Vec<(usize, Vec<f64>)>,
    /// Multiplications issued through the backend.
    pub muls: u64,
    /// Backend name.
    pub backend: String,
    /// R2F2 adjustment statistics, when applicable.
    pub r2f2_stats: Option<Stats>,
    /// Fixed-format range events, when applicable.
    pub range_events: Option<RangeEvents>,
    /// Relative total-mass drift over the run (conservation check).
    pub mass_drift: f64,
}

pub(super) struct Grid {
    pub(super) n: usize,
    pub(super) h: Vec<f64>,
    pub(super) u: Vec<f64>,
    pub(super) v: Vec<f64>,
}

impl Grid {
    pub(super) fn idx(&self, i: usize, j: usize) -> usize {
        i * (self.n + 2) + j
    }
}

/// The quantized sub-equation: `F2(q1, q3) = q1²/q3 + 0.5g·q3²` with its
/// three multiplications routed through the backend. Under
/// [`QuantMode::Full`] the final combine also routes through the backend's
/// adder (`Ctx::add` gates this on the mode); the division stays in the
/// f64 carrier — the backends model multipliers and adders, not dividers.
#[inline]
pub(super) fn f2_quant(ctx: &mut Ctx, g2: f64, q1: f64, q3: f64) -> f64 {
    let q1sq = ctx.mul(q1, q1);
    let q3sq = ctx.mul(q3, q3);
    let gq = ctx.mul(g2, q3sq);
    ctx.add(q1sq / q3, gq)
}

/// The same flux in plain f64 (all the paper's other 23 sub-equations).
#[inline]
pub(super) fn f2_plain(g2: f64, q1: f64, q3: f64) -> f64 {
    q1 * q1 / q3 + g2 * (q3 * q3)
}

pub(super) fn finish_result(sim: SweSim, stats: RunStats) -> SweResult {
    sim.finish(stats.muls, stats.backend, stats.r2f2_stats, stats.range_events, stats.snapshots)
}

/// Run the simulation. `be` receives only the multiplications selected by
/// `scope` (the paper's methodology); the rest of the scheme is f64.
///
/// Flux evaluations are issued row-at-a-time through the backend's batched
/// [`Arith::flux_batch`] engine (DESIGN.md §8), preserving the exact
/// multiplication stream of the per-call reference [`run_scalar`] — the two
/// produce bit-identical fields and counters. The run loop itself is the
/// generic scenario driver (`pde::scenario`, DESIGN.md §11).
pub fn run(params: &SweParams, be: &mut dyn Arith, scope: QuantScope) -> SweResult {
    run_mode(params, be, scope, QuantMode::MulOnly)
}

/// Per-multiplication reference path (one dynamically-dispatched `mul` per
/// stencil multiplication); the baseline for `benches/hotpath.rs` and the
/// semantic reference for the batched engine.
pub fn run_scalar(params: &SweParams, be: &mut dyn Arith, scope: QuantScope) -> SweResult {
    run_scalar_mode(params, be, scope, QuantMode::MulOnly)
}

/// [`run`] with an explicit [`QuantMode`]: under [`QuantMode::Full`] the
/// quantized flux's final add also routes through the backend (see
/// `f2_quant`), modeling a datapath whose adder sits in the reduced format
/// as well. The paper's deployment is `MulOnly`; `Full` is the ablation.
pub fn run_mode(
    params: &SweParams,
    be: &mut dyn Arith,
    scope: QuantScope,
    mode: QuantMode,
) -> SweResult {
    let mut sim = SweSim::new(params, scope);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, true);
    finish_result(sim, stats)
}

/// The scalar-dispatch reference for [`run_mode`].
pub fn run_scalar_mode(
    params: &SweParams,
    be: &mut dyn Arith,
    scope: QuantScope,
    mode: QuantMode,
) -> SweResult {
    let mut sim = SweSim::new(params, scope);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, false);
    finish_result(sim, stats)
}

/// Adaptive-precision run: the [`super::AdaptiveArith`] scheduler samples
/// range telemetry between timesteps and walks its format ladder under the
/// widen/narrow hysteresis policy (`pde::adaptive`), with the epoch
/// save/restore retry semantics provided by the generic
/// [`scenario::run_sim_adaptive`] driver. The schedule trace is available
/// from the scheduler afterwards.
pub fn run_adaptive(
    params: &SweParams,
    sched: &mut super::AdaptiveArith,
    scope: QuantScope,
    mode: QuantMode,
) -> SweResult {
    let mut sim = SweSim::new(params, scope);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        true,
    );
    finish_result(sim, stats)
}

/// The per-multiplication scalar reference of [`run_adaptive`] —
/// bit-identical to it, including the switch schedule.
pub fn run_adaptive_scalar(
    params: &SweParams,
    sched: &mut super::AdaptiveArith,
    scope: QuantScope,
    mode: QuantMode,
) -> SweResult {
    let mut sim = SweSim::new(params, scope);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        false,
    );
    finish_result(sim, stats)
}

/// Evaluate one row's worth of quantized fluxes into a reused output
/// buffer, either fused through the batched engine or via per-call
/// [`f2_quant`] — the streams are identical.
pub(super) fn flux_row(ctx: &mut Ctx, g2: f64, fin: &[(f64, f64)], out: &mut Vec<f64>, batched: bool) {
    out.clear();
    if batched {
        out.resize(fin.len(), 0.0);
        ctx.flux_batch(out, g2, fin);
    } else {
        out.extend(fin.iter().map(|&(q1, q3)| f2_quant(ctx, g2, q1, q3)));
    }
}

/// The simulation state + scratch of one shallow-water run — the scenario
/// the generic drivers (`pde::scenario`) step, save/restore and sample.
/// Only the grid (`h`, `u`, `v` with ghost cells) carries across steps; the
/// half-step arrays and flux row buffers are per-step scratch.
pub struct SweSim {
    pub(super) n: usize,
    pub(super) m: usize,
    pub(super) g2: f64,
    pub(super) ddx: f64,
    pub(super) ddy: f64,
    pub(super) scope: QuantScope,
    pub(super) grid: Grid,
    pub(super) hx: Vec<f64>,
    pub(super) ux: Vec<f64>,
    pub(super) vx: Vec<f64>,
    pub(super) hy: Vec<f64>,
    pub(super) uy: Vec<f64>,
    pub(super) vy: Vec<f64>,
    pub(super) fin: Vec<(f64, f64)>,
    pub(super) frow: Vec<f64>,
    pub(super) mass0: f64,
}

impl SweSim {
    pub fn new(params: &SweParams, scope: QuantScope) -> SweSim {
        let n = params.n;
        assert!(n >= 4, "grid too small");
        let (dt, dx, g) = (params.dt, params.dx, params.g);
        let side = n as f64 * dx;
        let h0 = params.init.sample(n, side);
        let mut grid = Grid {
            n,
            h: vec![params.init.base_depth; (n + 2) * (n + 2)],
            u: vec![0.0; (n + 2) * (n + 2)],
            v: vec![0.0; (n + 2) * (n + 2)],
        };
        for j in 0..n {
            for i in 0..n {
                let id = grid.idx(i + 1, j + 1);
                grid.h[id] = h0[j * n + i];
            }
        }
        let mass0: f64 = interior(&grid.h, n).iter().sum();
        SweSim {
            n,
            m: n + 1,
            g2: 0.5 * g,
            ddx: dt / dx,
            ddy: dt / dx,
            scope,
            grid,
            // Half-step arrays (Moler's waterwave layout).
            hx: vec![0.0; (n + 1) * (n + 1)],
            ux: vec![0.0; (n + 1) * (n + 1)],
            vx: vec![0.0; (n + 1) * (n + 1)],
            hy: vec![0.0; (n + 1) * (n + 1)],
            uy: vec![0.0; (n + 1) * (n + 1)],
            vy: vec![0.0; (n + 1) * (n + 1)],
            // Reused flux input/output row buffers (no per-row allocation
            // in the hot loop).
            fin: Vec::new(),
            frow: Vec::new(),
            mass0,
        }
    }

    pub fn interior_h(&self) -> Vec<f64> {
        interior(&self.grid.h, self.n)
    }

    /// Build the result record (consumes the simulation).
    pub(super) fn finish(
        self,
        muls: u64,
        backend: String,
        r2f2_stats: Option<Stats>,
        range_events: Option<RangeEvents>,
        snapshots: Vec<(usize, Vec<f64>)>,
    ) -> SweResult {
        let n = self.n;
        let h = interior(&self.grid.h, n);
        let mass1: f64 = h.iter().sum();
        SweResult {
            h,
            u: interior(&self.grid.u, n),
            v: interior(&self.grid.v, n),
            snapshots,
            muls,
            backend,
            r2f2_stats,
            range_events,
            mass_drift: ((mass1 - self.mass0) / self.mass0).abs(),
        }
    }
}

impl Sim for SweSim {
    fn scenario(&self) -> &'static str {
        "swe2d"
    }

    /// Shallow-water state lives in the f64 carrier under every mode
    /// ([`QuantMode::Full`] only moves the flux adder into the format), so
    /// storage quantization is a no-op — and a format switch moves only the
    /// flux datapath's format, never repacks state.
    fn quant_state(&mut self, _ctx: &mut Ctx<'_>) {}

    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    ) {
        for s in 0..steps {
            self.step(ctx, batched);
            let global = step_base + s + 1;
            if snapshot_every != 0 && global % snapshot_every == 0 {
                snaps.push((global, self.interior_h()));
            }
        }
    }

    /// The persistent state (`h`, `u`, `v` including ghosts) — everything
    /// a retried epoch needs restored.
    fn save(&self) -> Vec<Vec<f64>> {
        vec![self.grid.h.clone(), self.grid.u.clone(), self.grid.v.clone()]
    }

    fn restore(&mut self, s: &[Vec<f64>]) {
        self.grid.h.copy_from_slice(&s[0]);
        self.grid.u.copy_from_slice(&s[1]);
        self.grid.v.copy_from_slice(&s[2]);
    }

    /// Stream the interior depth + x-momentum fields into `out` — the
    /// adaptive scheduler's per-epoch range-telemetry sample.
    fn telemetry(&self, out: &mut Vec<f64>) {
        out.clear();
        let n = self.n;
        for i in 1..=n {
            for j in 1..=n {
                out.push(self.grid.h[i * (n + 2) + j]);
                out.push(self.grid.u[i * (n + 2) + j]);
            }
        }
    }

    fn telemetry_len(&self) -> usize {
        2 * self.n * self.n
    }

    fn primary_field(&self) -> Vec<f64> {
        self.interior_h()
    }
}

impl SweSim {
    /// One Lax–Wendroff step (two half steps + the full step), with the
    /// flux multiplications selected by the sim's [`QuantScope`] routed
    /// through `ctx` — the body of the original monolithic loop, verbatim.
    pub(super) fn step(&mut self, ctx: &mut Ctx, batched: bool) {
        let scope = self.scope;
        let n = self.n;
        let m = self.m;
        let g2 = self.g2;
        let (ddx, ddy) = (self.ddx, self.ddy);
        let grid = &mut self.grid;
        let (hx, ux, vx) = (&mut self.hx, &mut self.ux, &mut self.vx);
        let (hy, uy, vy) = (&mut self.hy, &mut self.uy, &mut self.vy);
        let fin = &mut self.fin;
        let frow = &mut self.frow;

        reflect(grid);

        // First half step — x direction (i = 0..n, j = 0..n−1 in the
        // (n+1)-wide half-step arrays). Under the ablation scope the flux
        // pairs of a whole row go through the backend in one batch; the
        // input order (fa then fb per column) matches the per-call path.
        for i in 0..=n {
            if scope == QuantScope::AllFluxMuls {
                fin.clear();
                for j in 0..n {
                    let a = grid.idx(i + 1, j + 1);
                    let b = grid.idx(i, j + 1);
                    fin.push((grid.u[a], grid.h[a]));
                    fin.push((grid.u[b], grid.h[b]));
                }
                flux_row(ctx, g2, fin, frow, batched);
            }
            for j in 0..n {
                let a = grid.idx(i + 1, j + 1); // (i+1, j+1)
                let b = grid.idx(i, j + 1); // (i, j+1)
                let k = i * m + j;
                hx[k] = 0.5 * (grid.h[a] + grid.h[b]) - 0.5 * ddx * (grid.u[a] - grid.u[b]);
                let (fa, fb) = match scope {
                    QuantScope::AllFluxMuls => (frow[2 * j], frow[2 * j + 1]),
                    QuantScope::UxFluxOnly => (
                        f2_plain(g2, grid.u[a], grid.h[a]),
                        f2_plain(g2, grid.u[b], grid.h[b]),
                    ),
                };
                ux[k] = 0.5 * (grid.u[a] + grid.u[b]) - 0.5 * ddx * (fa - fb);
                vx[k] = 0.5 * (grid.v[a] + grid.v[b])
                    - 0.5
                        * ddx
                        * (grid.u[a] * grid.v[a] / grid.h[a] - grid.u[b] * grid.v[b] / grid.h[b]);
            }
        }

        // First half step — y direction (i = 0..n−1, j = 0..n).
        for i in 0..n {
            if scope == QuantScope::AllFluxMuls {
                fin.clear();
                for j in 0..=n {
                    let a = grid.idx(i + 1, j + 1);
                    let b = grid.idx(i + 1, j);
                    fin.push((grid.v[a], grid.h[a]));
                    fin.push((grid.v[b], grid.h[b]));
                }
                flux_row(ctx, g2, fin, frow, batched);
            }
            for j in 0..=n {
                let a = grid.idx(i + 1, j + 1); // (i+1, j+1)
                let b = grid.idx(i + 1, j); // (i+1, j)
                let k = i * m + j;
                hy[k] = 0.5 * (grid.h[a] + grid.h[b]) - 0.5 * ddy * (grid.v[a] - grid.v[b]);
                uy[k] = 0.5 * (grid.u[a] + grid.u[b])
                    - 0.5
                        * ddy
                        * (grid.v[a] * grid.u[a] / grid.h[a] - grid.v[b] * grid.u[b] / grid.h[b]);
                let (ga, gb) = match scope {
                    QuantScope::AllFluxMuls => (frow[2 * j], frow[2 * j + 1]),
                    QuantScope::UxFluxOnly => (
                        f2_plain(g2, grid.v[a], grid.h[a]),
                        f2_plain(g2, grid.v[b], grid.h[b]),
                    ),
                };
                vy[k] = 0.5 * (grid.v[a] + grid.v[b]) - 0.5 * ddy * (ga - gb);
            }
        }

        // Second (full) step on the interior — this is where the paper's
        // substituted equation `Ux_mx = q1_mx²/q3_mx + 0.5g·q3_mx²` lives:
        // the x-momentum flux evaluated from the midpoint (…_mx) values.
        // The flux inputs all come from the (read-only) half-step arrays, so
        // a whole row is evaluated through the batched engine up front; the
        // stream order (fa, fb[, ga, gb] per cell) matches the per-call
        // reference exactly.
        let all = scope == QuantScope::AllFluxMuls;
        let stride = if all { 4 } else { 2 };
        for i in 1..=n {
            fin.clear();
            for j in 1..=n {
                let kxa = i * m + (j - 1);
                let kxb = (i - 1) * m + (j - 1);
                fin.push((ux[kxa], hx[kxa]));
                fin.push((ux[kxb], hx[kxb]));
                if all {
                    let kya = (i - 1) * m + j;
                    let kyb = (i - 1) * m + (j - 1);
                    fin.push((vy[kya], hy[kya]));
                    fin.push((vy[kyb], hy[kyb]));
                }
            }
            flux_row(ctx, g2, fin, frow, batched);
            for j in 1..=n {
                let c = grid.idx(i, j);
                let kxa = i * m + (j - 1); // Ux(i, j−1)
                let kxb = (i - 1) * m + (j - 1); // Ux(i−1, j−1)
                let kya = (i - 1) * m + j; // Vy(i−1, j)
                let kyb = (i - 1) * m + (j - 1); // Vy(i−1, j−1)

                grid.h[c] -= ddx * (ux[kxa] - ux[kxb]) + ddy * (vy[kya] - vy[kyb]);

                // Quantized sub-equation (two evaluations per cell).
                let base = (j - 1) * stride;
                let (fa, fb) = (frow[base], frow[base + 1]);
                grid.u[c] -= ddx * (fa - fb)
                    + ddy
                        * (vy[kya] * uy[kya] / hy[kya] - vy[kyb] * uy[kyb] / hy[kyb]);

                let (ga, gb) = if all {
                    (frow[base + 2], frow[base + 3])
                } else {
                    (f2_plain(g2, vy[kya], hy[kya]), f2_plain(g2, vy[kyb], hy[kyb]))
                };
                grid.v[c] -= ddx * (ux[kxa] * vx[kxa] / hx[kxa] - ux[kxb] * vx[kxb] / hx[kxb])
                    + ddy * (ga - gb);
            }
        }
    }
}

/// Copy the interior n×n block out of an (n+2)²-padded field.
pub(super) fn interior(a: &[f64], n: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(n * n);
    for i in 1..=n {
        for j in 1..=n {
            out.push(a[i * (n + 2) + j]);
        }
    }
    out
}

/// Reflective walls: depth mirrors, wall-normal momentum negates.
pub(super) fn reflect(grid: &mut Grid) {
    let n = grid.n;
    for j in 0..n + 2 {
        let (w0, w1) = (grid.idx(0, j), grid.idx(1, j));
        let (e0, e1) = (grid.idx(n + 1, j), grid.idx(n, j));
        grid.h[w0] = grid.h[w1];
        grid.u[w0] = -grid.u[w1];
        grid.v[w0] = grid.v[w1];
        grid.h[e0] = grid.h[e1];
        grid.u[e0] = -grid.u[e1];
        grid.v[e0] = grid.v[e1];
    }
    for i in 0..n + 2 {
        let (s0, s1) = (grid.idx(i, 0), grid.idx(i, 1));
        let (n0, n1) = (grid.idx(i, n + 1), grid.idx(i, n));
        grid.h[s0] = grid.h[s1];
        grid.u[s0] = grid.u[s1];
        grid.v[s0] = -grid.v[s1];
        grid.h[n0] = grid.h[n1];
        grid.u[n0] = grid.u[n1];
        grid.v[n0] = -grid.v[n1];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{rel_l2, F64Arith, FixedArith, R2f2Arith};
    use crate::r2f2core::R2f2Config;
    use crate::softfloat::FpFormat;

    #[test]
    fn mass_is_conserved_in_f64() {
        let p = SweParams { steps: 50, ..SweParams::default() };
        let res = run(&p, &mut F64Arith, QuantScope::UxFluxOnly);
        assert!(res.mass_drift < 1e-10, "mass drift {}", res.mass_drift);
    }

    #[test]
    fn depth_stays_positive_and_bounded() {
        let p = SweParams { steps: 100, ..SweParams::default() };
        let res = run(&p, &mut F64Arith, QuantScope::UxFluxOnly);
        let base = p.init.base_depth;
        assert!(res.h.iter().all(|&h| h > 0.5 * base && h < base + 2.0 * p.init.amplitude));
    }

    #[test]
    fn waves_propagate() {
        // After a few steps the drop must have excited momentum.
        let p = SweParams { steps: 10, ..SweParams::default() };
        let res = run(&p, &mut F64Arith, QuantScope::UxFluxOnly);
        let umax = res.u.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()));
        assert!(umax > 1e-3, "umax={umax}");
    }

    #[test]
    fn mul_count_matches_expectation() {
        let p = SweParams::default();
        let res = run(&p, &mut F64Arith, QuantScope::UxFluxOnly);
        assert_eq!(res.muls, p.expected_muls());
        // ≈ the paper's 30K multiplications in the substituted equation.
        assert_eq!(res.muls, 30_720);
    }

    #[test]
    fn r2f2_matches_f64_where_half_fails() {
        // Fig. 8: R2F2-16 in the substituted equation tracks double, while
        // E5M10 saturates on 0.5·g·h² ≈ 5e6 >> 65504 and corrupts the flow.
        let p = SweParams { steps: 40, ..SweParams::default() };
        let reference = run(&p, &mut F64Arith, QuantScope::UxFluxOnly);

        let mut r2f2 = R2f2Arith::new(R2f2Config::C16_384);
        let ours = run(&p, &mut r2f2, QuantScope::UxFluxOnly);
        let err_r2f2 = rel_l2(&ours.h, &reference.h);

        let mut half = FixedArith::new(FpFormat::E5M10);
        let theirs = run(&p, &mut half, QuantScope::UxFluxOnly);
        let err_half = rel_l2(&theirs.h, &reference.h);

        assert!(err_r2f2 < 1e-3, "R2F2 error {err_r2f2}");
        assert!(err_half > 10.0 * err_r2f2, "half {err_half} vs r2f2 {err_r2f2}");
        assert!(theirs.range_events.unwrap().overflows > 0);
    }

    #[test]
    fn r2f2_adjustment_counts_are_small() {
        // §5.3: "R2F2 adjusted precision 7 and 15 times, because of overflow
        // and redundancy" within 30K muls — same order of magnitude here.
        let p = SweParams::default();
        let mut r2f2 = R2f2Arith::new(R2f2Config::C16_384);
        let res = run(&p, &mut r2f2, QuantScope::UxFluxOnly);
        let st = res.r2f2_stats.unwrap();
        let adj = st.overflow_adjustments + st.redundancy_adjustments;
        assert!(adj >= 1, "the ocean scale must force at least one widen");
        assert!(adj < 100, "adjustments should be rare: {adj} in {} muls", st.muls);
    }

    #[test]
    fn symmetric_drop_keeps_symmetry() {
        // A centered drop on a square basin must stay x/y symmetric in f64.
        let p = SweParams { steps: 25, ..SweParams::default() };
        let res = run(&p, &mut F64Arith, QuantScope::UxFluxOnly);
        let n = p.n;
        for i in 0..n {
            for j in 0..n {
                let a = res.h[i * n + j];
                let b = res.h[j * n + i]; // transpose symmetry
                assert!((a - b).abs() < 1e-9, "asymmetry at ({i},{j})");
            }
        }
    }

    #[test]
    fn snapshots_collected() {
        let p = SweParams { steps: 20, snapshot_every: 10, ..SweParams::default() };
        let res = run(&p, &mut F64Arith, QuantScope::UxFluxOnly);
        assert_eq!(res.snapshots.len(), 2);
    }

    #[test]
    fn all_flux_scope_issues_more_muls() {
        let p = SweParams::default();
        let only = run(&p, &mut F64Arith, QuantScope::UxFluxOnly).muls;
        let all = run(&p, &mut F64Arith, QuantScope::AllFluxMuls).muls;
        assert!(all > 3 * only);
    }

    #[test]
    fn batched_run_matches_scalar_reference() {
        // Row-batched flux evaluation must reproduce the per-call stream
        // exactly (DESIGN.md §8) — fields, counters and mass drift.
        let p = SweParams { steps: 30, ..SweParams::default() };
        for scope in [QuantScope::UxFluxOnly, QuantScope::AllFluxMuls] {
            let mut a = R2f2Arith::new(R2f2Config::C16_384);
            let mut b = R2f2Arith::new(R2f2Config::C16_384);
            let scalar = run_scalar(&p, &mut a, scope);
            let batched = run(&p, &mut b, scope);
            assert_eq!(scalar.muls, batched.muls, "{scope:?}");
            assert_eq!(scalar.r2f2_stats, batched.r2f2_stats, "{scope:?}");
            assert_eq!(scalar.mass_drift.to_bits(), batched.mass_drift.to_bits(), "{scope:?}");
            for (field, s, t) in [
                ("h", &scalar.h, &batched.h),
                ("u", &scalar.u, &batched.u),
                ("v", &scalar.v, &batched.v),
            ] {
                for i in 0..s.len() {
                    assert_eq!(s[i].to_bits(), t[i].to_bits(), "{scope:?} {field}[{i}]");
                }
            }
        }
    }
}
