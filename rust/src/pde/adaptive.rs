//! Solver-level adaptive precision scheduling — the telemetry layer made
//! load-bearing.
//!
//! The paper's premise (§2, Fig. 2) is that *observed runtime ranges*
//! should drive precision choices; its R2F2 unit applies that per
//! multiplier. This module lifts the same widen/narrow/streak state
//! machine to **solver granularity** (cf. RAPTOR's lightweight numerical
//! profiling woven into the application loop, arXiv 2507.04647, and the
//! per-phase mixed-precision switching of Siklósi et al., arXiv
//! 2505.20911): the solver runs in a *ladder* of fixed `ExMy` formats, and
//! between timesteps an [`AdaptiveArith`] scheduler inspects cheap range
//! telemetry — the fixed [`Log2Histogram`]/[`StageTracker`] over the state
//! vector plus the backend's [`RangeEvents`] overflow/underflow deltas —
//! and moves along the ladder:
//!
//! * **Widen + retry**: overflow (or non-finite) pressure inside an epoch
//!   widens to the next rung and **re-runs the epoch from its saved start
//!   state** — the solver-level analogue of R2F2's "retry the
//!   multiplication using updated precision". The polluted attempt never
//!   lands in the committed trajectory (its cost is still charged).
//! * **Narrow after a clean streak**: after a configurable number of
//!   consecutive epochs with no widen pressure, with the observed peak
//!   magnitude clearing the narrower rung's ceiling by a headroom margin
//!   and (by default) with the dynamics *stalled* — the state sample
//!   bit-unchanged across an epoch. For a flush-induced stall (every
//!   update product below the wide format's min normal — the generic fate
//!   of a decaying PDE) the narrower rung's products flush too, so
//!   narrowing cannot diverge from the wide-format trajectory; a stall
//!   from exact cancellation of live products carries no such guarantee
//!   and is what the streak + headroom hysteresis is for. One rung is
//!   given back — hysteresis exactly like the R2F2 unit's redundancy
//!   streak.
//!
//! **Bit-exactness contract.** The decision function is a deterministic
//! function of the state vector and the event deltas, both of which are
//! bit-identical between the scalar reference path and the batched/packed
//! engines (the PR-2 contract). Therefore a scalar adaptive run and a
//! packed adaptive run produce the *same switch schedule* and bit-identical
//! fields — `rust/tests/adaptive_schedule.rs` enforces it, including runs
//! with widen retries and narrow events. A recorded decision log can also
//! be replayed verbatim ([`AdaptiveArith::from_trace`]) to pin one path to
//! another's schedule.
//!
//! **Packed state within an epoch.** In `QuantMode::Full` the packed
//! engine runs each epoch as one fused `Arith::stencil_multi`-style call,
//! so the state stays in packed words across every timestep of the epoch
//! and round-trips through the f64 carrier only at epoch boundaries —
//! where the scheduler needs the sample anyway. A format switch is then an
//! ordinary storage re-quantization of the carrier image (the standalone
//! word-domain repack hook, `softfloat::packed::repack_word` /
//! `crate::softfloat::PackedVec::repack`, remains available and
//! bit-equivalent for callers that keep state packed across epochs).
//!
//! **Modeled datapath cost.** Each multiplication is charged the
//! calibrated LUT area of a fixed multiplier of the *active* format
//! (`r2f2core::resource::fixed_multiplier`, anchored on the paper's
//! Table 1 rows) — an area×op proxy for datapath energy. The scheduler's
//! win condition, enforced by `tests/adaptive_schedule.rs`, is matching
//! the wide format's accuracy at strictly lower modeled cost.

use super::heat1d::{self, HeatParams, HeatResult};
use super::swe2d::{self, QuantScope, SweParams, SweResult};
use super::{Arith, BatchEngine, FixedArith, QuantMode, RangeEvents};
use crate::analysis::{Log2Histogram, StageStats, StageTracker};
use crate::r2f2core::resource::fixed_multiplier;
use crate::softfloat::FpFormat;

/// What the scheduler decided at one epoch boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Keep the current rung; the epoch is committed.
    Stay,
    /// Move one rung wider and **retry the epoch** from its saved state.
    Widen,
    /// Move one rung narrower for subsequent epochs; the epoch is
    /// committed (narrowing never retries — mirroring the R2F2 unit, where
    /// narrowing applies to *subsequent* multiplications).
    Narrow,
}

/// One applied format switch, for the schedule trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SwitchEvent {
    /// Epoch index (committed epochs; retried attempts share the index).
    pub epoch: usize,
    /// Global timestep at the epoch boundary where the switch fired.
    pub step: usize,
    pub from: FpFormat,
    pub to: FpFormat,
    /// `true` = widen (the epoch is re-run), `false` = narrow.
    pub widened: bool,
}

/// The per-epoch telemetry the policy sees: range-event deltas from the
/// arithmetic backend plus a magnitude summary of the state vector.
#[derive(Debug, Clone, Copy)]
pub struct EpochTelemetry {
    /// Overflow/underflow events raised during this epoch attempt.
    pub events: RangeEvents,
    /// Non-finite state values (distinct from flush-to-zero — the
    /// [`Log2Histogram::nonfinite`] counter this PR's bugfix added).
    pub nonfinite: u64,
    /// Largest non-zero state magnitude (0.0 when the state is all-zero).
    pub max_abs: f64,
    /// Smallest non-zero state magnitude (0.0 when the state is all-zero).
    pub min_abs: f64,
    /// State samples inspected.
    pub samples: u64,
}

/// One epoch-boundary observation, streamed to a registered
/// [`AdaptiveArith::set_epoch_hook`] observer as the schedule evolves —
/// the live feed behind the job API's `/v1/jobs/:id/events` stream
/// (DESIGN.md §16). Purely an observer: the hook sees every decision
/// *after* it is made and can neither veto nor reorder it, so a hooked run
/// is bit-identical to an unhooked one.
#[derive(Debug, Clone, Copy)]
pub struct EpochEvent {
    /// Committed-epoch index (retried attempts share the index).
    pub epoch: usize,
    /// Global timestep at the epoch boundary.
    pub step: usize,
    pub decision: Decision,
    /// The rung in force *after* the decision was applied.
    pub format: FpFormat,
    pub telemetry: EpochTelemetry,
}

/// Boxed epoch observer. A newtype (rather than a bare boxed closure
/// field) so [`AdaptiveArith`] can keep `#[derive(Debug)]`.
pub struct EpochHook(Box<dyn FnMut(&EpochEvent) + Send>);

impl std::fmt::Debug for EpochHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("EpochHook(..)")
    }
}

/// Hysteresis policy for the solver-level widen/narrow state machine.
#[derive(Debug, Clone)]
pub struct AdaptivePolicy {
    /// Formats ordered narrow → wide. The scheduler moves along this
    /// ladder one rung at a time.
    pub ladder: Vec<FpFormat>,
    /// Starting rung index into `ladder`. Cold starts default to 0
    /// (narrowest, probing upward); `trace::profile::ProfilePlan::
    /// seeded_policy` re-seeds this from a pilot run instead
    /// (profile-guided adaptation, ROADMAP item 4) — the committed
    /// trajectory is unchanged either way, only the probing cost moves.
    pub start_rung: usize,
    /// Timesteps per epoch (the telemetry/decision granularity).
    pub epoch_len: usize,
    /// Widen when an epoch's overflow-event delta reaches this count.
    pub widen_overflow_threshold: u64,
    /// Widen when any non-finite value appears in the state.
    pub widen_on_nonfinite: bool,
    /// Consecutive clean epochs required before narrowing (the streak
    /// hysteresis; cf. the R2F2 unit's redundancy streak).
    pub narrow_clean_epochs: u32,
    /// Octaves of headroom the observed peak magnitude must clear below
    /// the narrower rung's max finite value before narrowing.
    pub narrow_headroom_octaves: u32,
    /// If set, an epoch with more underflow events than this is not
    /// "clean" (off by default: flush-to-zero is bounded error, exactly
    /// like the R2F2 unit's silent operand flush).
    pub narrow_underflow_guard: Option<u64>,
    /// Narrow only once the dynamics have **stalled** in the current
    /// format: the state sample is bit-identical to the previous epoch's
    /// (every update flushed or cancelled). When the stall is
    /// flush-induced — every update product already below the wide
    /// format's min normal, the generic fate of a decaying PDE — the
    /// narrower rung's products flush too, so narrowing cannot diverge
    /// from the wide-format trajectory; that is what lets the adaptive
    /// schedule match the wide format's accuracy exactly while paying
    /// narrow-format cost for the tail (a cancellation-induced stall of
    /// live products carries no such guarantee). On by default; turn off
    /// for aggressive narrowing that trades accuracy for cost.
    pub narrow_requires_stall: bool,
}

impl AdaptivePolicy {
    /// A policy over `ladder` with the default hysteresis constants.
    pub fn new(ladder: Vec<FpFormat>) -> AdaptivePolicy {
        AdaptivePolicy {
            ladder,
            start_rung: 0,
            epoch_len: 32,
            widen_overflow_threshold: 1,
            widen_on_nonfinite: true,
            narrow_clean_epochs: 3,
            narrow_headroom_octaves: 12,
            narrow_underflow_guard: None,
            narrow_requires_stall: true,
        }
    }

    /// The heat-equation default: start at FP8 (`E4M3`), widen to the
    /// paper's half baseline (`E5M10`) under range pressure, narrow back
    /// once the decaying solution leaves generous headroom.
    pub fn heat_default() -> AdaptivePolicy {
        AdaptivePolicy::new(vec![FpFormat::E4M3, FpFormat::E5M10])
    }

    /// The shallow-water default: start at `E5M10` (which the shelf-scale
    /// flux overflows, §5.3) with `E6M9` as the wide rung — the same
    /// trade the R2F2 `<3,9,3>` unit makes per multiplication.
    pub fn swe_default() -> AdaptivePolicy {
        let mut p = AdaptivePolicy::new(vec![FpFormat::E5M10, FpFormat::new(6, 9)]);
        p.epoch_len = 4;
        p
    }

    /// The advection default (`pde::advection1d`): the same FP8 → half
    /// ladder as [`AdaptivePolicy::heat_default`] (delegated, so the rungs
    /// can never drift apart) — amplitude 400 saturates `E4M3` on encode
    /// in epoch 0, and upwind diffusion later decays the transport into a
    /// flush stall that narrows back.
    pub fn advection_default() -> AdaptivePolicy {
        AdaptivePolicy::heat_default()
    }

    /// The wave default (`pde::wave2d`): the same FP8 → half ladder as
    /// [`AdaptivePolicy::heat_default`] (delegated) — the signed
    /// oscillation's amplitude 300 saturates `E4M3` immediately, and a
    /// damped run collapses to exact zeros, the stall that narrows back.
    pub fn wave_default() -> AdaptivePolicy {
        AdaptivePolicy::heat_default()
    }

    /// May the scheduler narrow onto `narrower` given the observed peak?
    fn headroom_ok(&self, max_abs: f64, narrower: FpFormat) -> bool {
        max_abs <= narrower.max_value() * (2.0f64).powi(-(self.narrow_headroom_octaves as i32))
    }
}

/// Report of one adaptive run (schedule trace + telemetry + modeled cost).
#[derive(Debug, Clone)]
pub struct AdaptiveReport {
    pub trace: Vec<SwitchEvent>,
    /// Every epoch-boundary decision in order (including retried attempts)
    /// — replayable via [`AdaptiveArith::from_trace`].
    pub decisions: Vec<Decision>,
    /// Committed epochs.
    pub epochs: usize,
    pub widen_events: u64,
    pub narrow_events: u64,
    /// Epochs that wanted to widen while already at the widest rung (the
    /// solver-level analogue of R2F2's unresolved range events).
    pub pressure_at_widest: u64,
    /// Multiplications charged per ladder rung (retried attempts included).
    pub ops_per_rung: Vec<(FpFormat, u64)>,
    /// Σ ops × calibrated per-multiplication LUT area of the rung.
    pub modeled_cost_lut: f64,
    pub final_format: FpFormat,
    pub events: RangeEvents,
    /// Whole-run magnitude histogram of the sampled state telemetry.
    pub overall: Log2Histogram,
    /// Per-quarter stage summaries of the sampled state telemetry.
    pub stages: Vec<StageStats>,
}

/// The solver-level adaptive scheduler. Implements [`Arith`] by delegating
/// to the wrapped [`FixedArith`] engine at the current rung, so it plugs
/// into every harness a fixed or R2F2 backend does; the adaptive run
/// variants ([`run_heat`], [`run_swe`], `heat1d::run_adaptive`,
/// `swe2d::run_adaptive`) additionally drive its epoch protocol
/// ([`AdaptiveArith::begin_epoch`] / [`AdaptiveArith::end_epoch`]).
#[derive(Debug)]
pub struct AdaptiveArith {
    pub(super) policy: AdaptivePolicy,
    pub(super) inner: FixedArith,
    rung: usize,
    clean: u32,
    mark: RangeEvents,
    epoch: usize,
    trace: Vec<SwitchEvent>,
    decisions: Vec<Decision>,
    replay: Option<Vec<Decision>>,
    replay_cursor: usize,
    overall: Log2Histogram,
    stages: Option<StageTracker>,
    ops: Vec<u64>,
    pressure_at_widest: u64,
    /// Previous epoch's state sample (raw bits), for the stall detector.
    last_state_bits: Vec<u64>,
    /// Optional epoch-boundary observer (see [`EpochEvent`]).
    hook: Option<EpochHook>,
}

impl AdaptiveArith {
    /// New scheduler at the policy's starting rung, on the default
    /// (packed) batched engine.
    pub fn new(policy: AdaptivePolicy) -> AdaptiveArith {
        assert!(!policy.ladder.is_empty(), "ladder must have at least one rung");
        assert!(policy.start_rung < policy.ladder.len(), "start_rung out of range");
        assert!(policy.epoch_len >= 1, "epoch_len must be at least 1");
        let rung = policy.start_rung;
        let ops = vec![0u64; policy.ladder.len()];
        let inner = FixedArith::new(policy.ladder[rung]);
        AdaptiveArith {
            policy,
            inner,
            rung,
            clean: 0,
            mark: RangeEvents::default(),
            epoch: 0,
            trace: Vec::new(),
            decisions: Vec::new(),
            replay: None,
            replay_cursor: 0,
            overall: Log2Histogram::new(),
            stages: None,
            ops,
            pressure_at_widest: 0,
            last_state_bits: Vec::new(),
            hook: None,
        }
    }

    /// Register an observer invoked at every epoch boundary (including
    /// retried attempts) with the decision just made and the telemetry
    /// that drove it. Observation only — the schedule and the committed
    /// trajectory are bit-identical with or without a hook.
    pub fn set_epoch_hook(&mut self, hook: impl FnMut(&EpochEvent) + Send + 'static) {
        self.hook = Some(EpochHook(Box::new(hook)));
    }

    /// Select the batched-engine implementation of the wrapped unit (call
    /// before running; both engines are bit-identical).
    pub fn with_engine(mut self, engine: BatchEngine) -> AdaptiveArith {
        self.inner = FixedArith::new(self.policy.ladder[self.rung]).with_engine(engine);
        self
    }

    /// Replay mode: ignore live telemetry decisions and apply `decisions`
    /// (a recorded [`AdaptiveReport::decisions`] log) verbatim, one per
    /// epoch boundary — this pins a run to another run's switch schedule.
    pub fn from_trace(policy: AdaptivePolicy, decisions: Vec<Decision>) -> AdaptiveArith {
        let mut s = AdaptiveArith::new(policy);
        s.replay = Some(decisions);
        s
    }

    /// The format of the current rung.
    pub fn format(&self) -> FpFormat {
        self.policy.ladder[self.rung]
    }

    /// Current rung index.
    pub fn rung(&self) -> usize {
        self.rung
    }

    pub fn policy(&self) -> &AdaptivePolicy {
        &self.policy
    }

    /// Applied switches so far.
    pub fn trace(&self) -> &[SwitchEvent] {
        &self.trace
    }

    /// Every epoch-boundary decision so far.
    pub fn decisions(&self) -> &[Decision] {
        &self.decisions
    }

    /// Cumulative range events of the wrapped unit.
    pub fn events(&self) -> RangeEvents {
        self.inner.events
    }

    /// Do all rungs fit a packed `u32` word (⇒ the packed engine's fused
    /// Full-mode epoch driver is applicable on every rung)?
    pub fn ladder_fits_word(&self) -> bool {
        self.policy.ladder.iter().all(|f| f.fits_word())
    }

    /// Size the run-level [`StageTracker`] telemetry: `expected_records`
    /// state samples from **committed** epochs will stream through
    /// `end_epoch` over the whole run (widen-retried attempts feed the
    /// decision but not the stage quarters, so the count is exact and the
    /// quarters align with simulation quarters).
    pub fn prepare(&mut self, expected_records: u64) {
        self.stages = Some(StageTracker::new(4, expected_records));
    }

    /// Mark the start of an epoch attempt: subsequent [`RangeEvents`] are
    /// attributed to it. Call again before re-running a retried epoch.
    pub fn begin_epoch(&mut self) {
        self.mark = self.inner.events;
    }

    /// Charge `muls` multiplications to the current rung's cost account.
    pub fn charge(&mut self, muls: u64) {
        self.ops[self.rung] += muls;
    }

    /// Modeled datapath cost so far: Σ per-rung multiplications × the
    /// calibrated LUT area of a fixed multiplier of that format.
    pub fn modeled_cost_lut(&self) -> f64 {
        self.policy
            .ladder
            .iter()
            .zip(self.ops.iter())
            .map(|(fmt, &n)| n as f64 * fixed_multiplier(*fmt).lut)
            .sum()
    }

    /// End an epoch attempt: stream the state sample into the telemetry
    /// (histogram + stage tracker), compute the event delta since
    /// [`AdaptiveArith::begin_epoch`], decide, and apply any rung change.
    /// On [`Decision::Widen`] the caller must restore the epoch's saved
    /// start state, re-quantize it into the new format, call `begin_epoch`
    /// and re-run the epoch.
    pub fn end_epoch(&mut self, state: &[f64], step: usize) -> Decision {
        let mut hist = Log2Histogram::new();
        for &v in state {
            hist.record(v);
        }
        let delta = RangeEvents {
            overflows: self.inner.events.overflows - self.mark.overflows,
            underflows: self.inner.events.underflows - self.mark.underflows,
        };
        let (min_abs, max_abs) = hist.nonzero_range().unwrap_or((0.0, 0.0));
        let tele = EpochTelemetry {
            events: delta,
            nonfinite: hist.nonfinite,
            max_abs,
            min_abs,
            samples: hist.total,
        };
        // Stall detector: bit-exact comparison against the previous epoch's
        // sample (identical across the scalar and packed paths, since both
        // produce bit-identical states).
        let stalled = self.last_state_bits.len() == state.len()
            && self.last_state_bits.iter().zip(state.iter()).all(|(&b, v)| b == v.to_bits());
        self.last_state_bits.clear();
        self.last_state_bits.extend(state.iter().map(|v| v.to_bits()));

        let decision = if self.replay.is_some() {
            let d = self
                .replay
                .as_ref()
                .and_then(|log| log.get(self.replay_cursor).copied())
                .unwrap_or(Decision::Stay);
            self.replay_cursor += 1;
            // A faithful log never walks off the ladder, but a hand-built
            // or policy-mismatched one could: degrade to Stay instead of
            // under/overflowing the rung index.
            match d {
                Decision::Widen if self.rung + 1 >= self.policy.ladder.len() => Decision::Stay,
                Decision::Narrow if self.rung == 0 => Decision::Stay,
                d => d,
            }
        } else {
            self.decide(&tele, stalled)
        };
        self.decisions.push(decision);

        // Run-level stage telemetry covers the *committed* trajectory:
        // widen-retried attempts never reach it, so the quarters line up
        // with simulation quarters and the record count matches
        // [`AdaptiveArith::prepare`] exactly.
        if decision != Decision::Widen {
            for &v in state {
                self.overall.record(v);
                if let Some(t) = self.stages.as_mut() {
                    t.record(v);
                }
            }
        }

        // Capture before the match: Stay/Narrow advance the epoch counter.
        let epoch_index = self.epoch;
        match decision {
            Decision::Widen => {
                let from = self.format();
                self.rung += 1;
                self.clean = 0;
                self.inner.fmt = self.format();
                self.trace.push(SwitchEvent {
                    epoch: self.epoch,
                    step,
                    from,
                    to: self.format(),
                    widened: true,
                });
                // Epoch index unchanged: the caller retries this epoch.
            }
            Decision::Narrow => {
                let from = self.format();
                self.rung -= 1;
                self.clean = 0;
                self.inner.fmt = self.format();
                self.trace.push(SwitchEvent {
                    epoch: self.epoch,
                    step,
                    from,
                    to: self.format(),
                    widened: false,
                });
                self.epoch += 1;
            }
            Decision::Stay => {
                self.epoch += 1;
            }
        }
        if let Some(h) = self.hook.as_mut() {
            (h.0)(&EpochEvent {
                epoch: epoch_index,
                step,
                decision,
                format: self.policy.ladder[self.rung],
                telemetry: tele,
            });
        }
        decision
    }

    /// The live widen/narrow/streak state machine (bypassed in replay).
    fn decide(&mut self, t: &EpochTelemetry, stalled: bool) -> Decision {
        let p = &self.policy;
        let pressure = t.events.overflows >= p.widen_overflow_threshold
            || (p.widen_on_nonfinite && t.nonfinite > 0);
        if pressure {
            self.clean = 0;
            if self.rung + 1 < p.ladder.len() {
                return Decision::Widen;
            }
            // Already at the widest rung: accept, like R2F2's unresolved
            // saturation at k = FX.
            self.pressure_at_widest += 1;
            return Decision::Stay;
        }
        let clean = p.narrow_underflow_guard.is_none_or(|g| t.events.underflows <= g);
        if clean {
            self.clean += 1;
        } else {
            self.clean = 0;
        }
        if self.rung > 0
            && self.clean >= p.narrow_clean_epochs
            && (!p.narrow_requires_stall || stalled)
            && p.headroom_ok(t.max_abs, p.ladder[self.rung - 1])
        {
            return Decision::Narrow;
        }
        Decision::Stay
    }

    /// Consume the run's telemetry into a report (the stage tracker is
    /// finished; further epochs would re-start its staging).
    pub fn report(&mut self) -> AdaptiveReport {
        let stages = self.stages.take().map(StageTracker::finish).unwrap_or_default();
        AdaptiveReport {
            trace: self.trace.clone(),
            decisions: self.decisions.clone(),
            epochs: self.epoch,
            widen_events: self.trace.iter().filter(|e| e.widened).count() as u64,
            narrow_events: self.trace.iter().filter(|e| !e.widened).count() as u64,
            pressure_at_widest: self.pressure_at_widest,
            ops_per_rung: self
                .policy
                .ladder
                .iter()
                .copied()
                .zip(self.ops.iter().copied())
                .collect(),
            modeled_cost_lut: self.modeled_cost_lut(),
            final_format: self.format(),
            events: self.inner.events,
            overall: self.overall.clone(),
            stages,
        }
    }
}

/// Modeled datapath cost of an all-fixed run: `muls` multiplications at
/// `fmt`'s calibrated per-multiplication LUT area. The comparison target
/// for [`AdaptiveArith::modeled_cost_lut`].
pub fn fixed_cost_lut(fmt: FpFormat, muls: u64) -> f64 {
    muls as f64 * fixed_multiplier(fmt).lut
}

impl Arith for AdaptiveArith {
    fn name(&self) -> String {
        let mut s = String::from("adaptive(");
        for (i, f) in self.policy.ladder.iter().enumerate() {
            if i > 0 {
                s.push('→');
            }
            s.push_str(&f.to_string());
        }
        s.push(')');
        s
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.inner.mul(a, b)
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        self.inner.add(a, b)
    }
    fn quant(&mut self, x: f64) -> f64 {
        self.inner.quant(x)
    }
    fn mul_batch(&mut self, out: &mut [f64], a: f64, xs: &[f64]) {
        self.inner.mul_batch(out, a, xs);
    }
    fn mul_pairs(&mut self, out: &mut [f64], pairs: &[(f64, f64)]) {
        self.inner.mul_pairs(out, pairs);
    }
    fn stencil_step(&mut self, next: &mut [f64], u: &[f64], r: f64, mode: QuantMode) {
        self.inner.stencil_step(next, u, r, mode);
    }
    fn stencil_multi(
        &mut self,
        u: &mut Vec<f64>,
        next: &mut Vec<f64>,
        r: f64,
        mode: QuantMode,
        steps: usize,
        snapshot_every: usize,
        snapshots: &mut Vec<(usize, Vec<f64>)>,
    ) {
        self.inner.stencil_multi(u, next, r, mode, steps, snapshot_every, snapshots);
    }
    fn flux_batch(&mut self, out: &mut [f64], g2: f64, q: &[(f64, f64)], mode: QuantMode) {
        self.inner.flux_batch(out, g2, q, mode);
    }
    fn range_events(&self) -> Option<RangeEvents> {
        Some(self.inner.events)
    }
    fn active_format(&self) -> Option<FpFormat> {
        Some(self.format())
    }
}

// ---------------------------------------------------------------------------
// Per-scenario adaptive entry points (thin wrappers)
// ---------------------------------------------------------------------------
//
// The epoch protocol — save → attempt → telemetry → decide, widen-retry
// rollback, narrow re-quantization — lives once in
// `pde::scenario::run_sim_adaptive`; these wrappers only pick the scenario.

/// Adaptive heat run on the batched engines. In `QuantMode::Full` the
/// packed engine steps each epoch as one fused multi-step call, so state
/// stays packed across the epoch. Bit-identical to [`run_heat_scalar`]
/// under the same schedule — and the schedules themselves coincide, since
/// the decision inputs are bit-identical.
pub fn run_heat(params: &HeatParams, sched: &mut AdaptiveArith, mode: QuantMode) -> HeatResult {
    heat1d::run_adaptive(params, sched, mode)
}

/// The per-multiplication scalar reference of [`run_heat`].
pub fn run_heat_scalar(
    params: &HeatParams,
    sched: &mut AdaptiveArith,
    mode: QuantMode,
) -> HeatResult {
    heat1d::run_adaptive_scalar(params, sched, mode)
}

/// Adaptive shallow-water run on the batched flux engine. The telemetry
/// sample is the interior depth + x-momentum fields; SWE state lives in
/// the f64 carrier under every mode, so a switch only moves the flux
/// datapath's format (no state repack is needed).
pub fn run_swe(
    params: &SweParams,
    sched: &mut AdaptiveArith,
    scope: QuantScope,
    mode: QuantMode,
) -> SweResult {
    swe2d::run_adaptive(params, sched, scope, mode)
}

/// The per-multiplication scalar reference of [`run_swe`].
pub fn run_swe_scalar(
    params: &SweParams,
    sched: &mut AdaptiveArith,
    scope: QuantScope,
    mode: QuantMode,
) -> SweResult {
    swe2d::run_adaptive_scalar(params, sched, scope, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::heat1d;
    use crate::pde::rel_l2;

    fn tiny_heat() -> HeatParams {
        HeatParams {
            n: 17,
            dt: 0.25 / (16.0f64 * 16.0),
            steps: 96,
            ..HeatParams::default()
        }
    }

    #[test]
    fn widen_fires_on_overflow_pressure_and_retries_cleanly() {
        // Amplitude 500 > E4M3's max finite 480: epoch 0 must widen, and
        // because the epoch is retried from the raw field, the committed
        // MulOnly trajectory is exactly the all-E5M10 one.
        let p = tiny_heat();
        let mut sched = AdaptiveArith::new(AdaptivePolicy::heat_default());
        let res = run_heat(&p, &mut sched, QuantMode::MulOnly);
        let rep = sched.report();
        assert!(rep.widen_events >= 1, "trace: {:?}", rep.trace);
        assert_eq!(rep.final_format, FpFormat::E5M10);

        let mut fixed = FixedArith::new(FpFormat::E5M10);
        let want = heat1d::run(&p, &mut fixed, QuantMode::MulOnly);
        for i in 0..p.n {
            assert_eq!(res.u[i].to_bits(), want.u[i].to_bits(), "node {i}");
        }
        // The aborted E4M3 attempt is still charged: one 32-step epoch of
        // 3·(n−2) multiplications per step on top of the committed run.
        assert!(rep.ops_per_rung[0].1 > 0);
        assert_eq!(res.muls, p.expected_muls() + 32 * 3 * (p.n as u64 - 2));
    }

    #[test]
    fn narrow_fires_after_decay_with_headroom() {
        // Longer decay at hysteresis headroom: the solution shrinks far
        // below E4M3's ceiling, stalls (every E5M10 product flushes), and
        // the ladder narrows back.
        let mut p = tiny_heat();
        p.steps = 900;
        let mut policy = AdaptivePolicy::heat_default();
        policy.epoch_len = 16;
        let mut sched = AdaptiveArith::new(policy);
        let _ = run_heat(&p, &mut sched, QuantMode::Full);
        let rep = sched.report();
        assert!(rep.widen_events >= 1, "trace: {:?}", rep.trace);
        assert!(rep.narrow_events >= 1, "trace: {:?}", rep.trace);
        assert_eq!(rep.final_format, FpFormat::E4M3);
        // Telemetry staging reused the fixed StageTracker: stage maxima
        // shrink as the sine decays (the Fig. 2 story, now load-bearing).
        assert_eq!(rep.stages.len(), 4);
        assert!(rep.stages[rep.stages.len() - 1].max_abs < rep.stages[0].max_abs);
    }

    #[test]
    fn replayed_schedule_matches_live_schedule() {
        let mut p = tiny_heat();
        p.steps = 700;
        let mut policy = AdaptivePolicy::heat_default();
        policy.epoch_len = 16;
        let mut live = AdaptiveArith::new(policy.clone());
        let res_live = run_heat(&p, &mut live, QuantMode::Full);
        let rep = live.report();

        let mut replay = AdaptiveArith::from_trace(policy, rep.decisions.clone());
        let res_replay = run_heat(&p, &mut replay, QuantMode::Full);
        let rep2 = replay.report();
        assert_eq!(rep.trace, rep2.trace);
        for i in 0..p.n {
            assert_eq!(res_live.u[i].to_bits(), res_replay.u[i].to_bits(), "node {i}");
        }
        assert_eq!(res_live.range_events, res_replay.range_events);
    }

    #[test]
    fn pressure_at_widest_is_accounted() {
        // A one-rung ladder can never widen: pressure is recorded instead.
        let p = tiny_heat();
        let mut policy = AdaptivePolicy::new(vec![FpFormat::E4M3]);
        policy.epoch_len = 16;
        let mut sched = AdaptiveArith::new(policy);
        let _ = run_heat(&p, &mut sched, QuantMode::MulOnly);
        let rep = sched.report();
        assert_eq!(rep.widen_events, 0);
        assert!(rep.pressure_at_widest >= 1);
    }

    #[test]
    fn modeled_cost_accounts_per_rung() {
        let mut sched = AdaptiveArith::new(AdaptivePolicy::heat_default());
        sched.charge(100); // E4M3
        let before = sched.modeled_cost_lut();
        assert!((before - fixed_cost_lut(FpFormat::E4M3, 100)).abs() < 1e-9);
        assert!(fixed_cost_lut(FpFormat::E4M3, 100) < fixed_cost_lut(FpFormat::E5M10, 100));
    }

    #[test]
    fn epoch_hook_observes_every_decision_without_perturbing_the_run() {
        use std::sync::{Arc, Mutex};
        let p = tiny_heat();
        let mut plain = AdaptiveArith::new(AdaptivePolicy::heat_default());
        let res_plain = run_heat(&p, &mut plain, QuantMode::MulOnly);
        let rep_plain = plain.report();

        let events: Arc<Mutex<Vec<EpochEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&events);
        let mut hooked = AdaptiveArith::new(AdaptivePolicy::heat_default());
        hooked.set_epoch_hook(move |e| sink.lock().unwrap().push(*e));
        let res_hooked = run_heat(&p, &mut hooked, QuantMode::MulOnly);
        let rep_hooked = hooked.report();

        // Observation only: identical schedule, bit-identical field.
        assert_eq!(rep_plain.decisions, rep_hooked.decisions);
        for i in 0..p.n {
            assert_eq!(res_plain.u[i].to_bits(), res_hooked.u[i].to_bits(), "node {i}");
        }
        // One event per epoch-boundary decision, retried attempts included,
        // carrying the decision and the post-decision rung.
        let seen = events.lock().unwrap();
        assert_eq!(seen.len(), rep_hooked.decisions.len());
        for (e, d) in seen.iter().zip(rep_hooked.decisions.iter()) {
            assert_eq!(e.decision, *d);
        }
        let widen = seen.iter().find(|e| e.decision == Decision::Widen).expect("a widen event");
        assert_eq!(widen.format, FpFormat::E5M10, "format is the post-decision rung");
        assert!(widen.telemetry.events.overflows >= 1 || widen.telemetry.nonfinite > 0);
    }

    #[test]
    fn adaptive_arith_delegates_as_plain_backend() {
        // Plugged into the ordinary (non-adaptive) harness, the scheduler
        // behaves exactly like its current rung's fixed engine.
        let p = tiny_heat();
        let mut sched = AdaptiveArith::new(AdaptivePolicy::new(vec![FpFormat::E5M10]));
        let a = heat1d::run(&p, &mut sched, QuantMode::MulOnly);
        let mut fixed = FixedArith::new(FpFormat::E5M10);
        let b = heat1d::run(&p, &mut fixed, QuantMode::MulOnly);
        assert_eq!(rel_l2(&a.u, &b.u), 0.0);
        assert_eq!(a.range_events, b.range_events);
        assert_eq!(sched.active_format(), Some(FpFormat::E5M10));
    }
}
