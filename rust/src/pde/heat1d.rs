//! 1D heat equation `∂u/∂t = α ∂²u/∂x²`, explicit finite differences (§2).
//!
//! Update rule per interior node, written the way stencil codes multiply
//! coefficients into the field (so the multiplication *operands* are the
//! coefficient and the state values — the quantities whose distribution §3.1
//! studies):
//!
//! `u'ᵢ = uᵢ + (r·uᵢ₋₁ − (2r)·uᵢ + r·uᵢ₊₁)`, `r = α·Δt/Δx²` (stable for
//! `r ≤ 1/2`). Each node costs **three multiplications** per step, all
//! routed through the [`Arith`] backend — the paper's heat run totals
//! ~1.5 M multiplications, matched here by the default `n = 501,
//! steps = 1000` (3 × 499 × 1000 ≈ 1.5 M).
//!
//! Boundary conditions are Dirichlet: the end nodes hold their initial
//! values (0 for the sine case).
//!
//! The run plumbing lives in the generic scenario layer
//! (`pde::scenario`, DESIGN.md §11): this module provides only the physics
//! ([`HeatSim`]) and thin result-shaping wrappers around
//! [`scenario::run_sim`] / [`scenario::run_sim_adaptive`].

use super::init::HeatInit;
use super::scenario::{self, RunStats, Sim};
use super::{Arith, Ctx, QuantMode, RangeEvents};
use crate::r2f2core::Stats;

/// Heat-equation run parameters.
#[derive(Debug, Clone)]
pub struct HeatParams {
    /// Number of spatial nodes (including the two boundary nodes).
    pub n: usize,
    /// Diffusivity α.
    pub alpha: f64,
    /// Domain length L (Δx = L / (n−1)).
    pub length: f64,
    /// Time step.
    pub dt: f64,
    /// Number of time steps.
    pub steps: usize,
    /// Initial condition.
    pub init: HeatInit,
    /// Keep a state snapshot every `snapshot_every` steps (0 = none).
    pub snapshot_every: usize,
}

impl Default for HeatParams {
    fn default() -> HeatParams {
        // r = α·Δt/Δx² = 0.25 with these values; 499 interior nodes ×
        // 1000 steps × 3 muls ≈ 1.5 M multiplications (§5.3).
        HeatParams {
            n: 501,
            alpha: 1.0,
            length: 1.0,
            dt: 0.25 / (500.0f64 * 500.0),
            steps: 1000,
            init: HeatInit::sin_default(),
            snapshot_every: 0,
        }
    }
}

impl HeatParams {
    /// The dimensionless diffusion number `r = α·Δt/Δx²`.
    pub fn r(&self) -> f64 {
        let dx = self.length / (self.n - 1) as f64;
        self.alpha * self.dt / (dx * dx)
    }

    /// Multiplications the run will issue (3 per interior node per step).
    pub fn expected_muls(&self) -> u64 {
        3 * (self.n as u64 - 2) * self.steps as u64
    }
}

/// Result of a heat-equation run.
#[derive(Debug, Clone)]
pub struct HeatResult {
    /// Final temperature field.
    pub u: Vec<f64>,
    /// `(step, field)` snapshots if requested.
    pub snapshots: Vec<(usize, Vec<f64>)>,
    /// Multiplications issued.
    pub muls: u64,
    /// Backend name.
    pub backend: String,
    /// R2F2 adjustment statistics, when applicable.
    pub r2f2_stats: Option<Stats>,
    /// Fixed-format range events, when applicable.
    pub range_events: Option<RangeEvents>,
}

/// The heat-equation scenario state: the temperature field plus the sweep
/// scratch buffer. Everything else — run loops, epoch protocol, widen-retry
/// rollback — is the generic drivers' job.
#[derive(Debug)]
pub struct HeatSim {
    pub(super) n: usize,
    pub(super) r: f64,
    pub(super) u: Vec<f64>,
    pub(super) next: Vec<f64>,
}

impl HeatSim {
    pub fn new(params: &HeatParams) -> HeatSim {
        assert!(params.n >= 3, "need at least one interior node");
        assert!(params.r() <= 0.5 + 1e-12, "explicit scheme unstable: r = {}", params.r());
        let u = params.init.sample(params.n, params.length);
        let next = u.clone();
        HeatSim { n: params.n, r: params.r(), u, next }
    }

    /// Consume the simulation into its final field.
    pub fn into_field(self) -> Vec<f64> {
        self.u
    }
}

impl Sim for HeatSim {
    fn scenario(&self) -> &'static str {
        "heat1d"
    }

    fn quant_state(&mut self, ctx: &mut Ctx<'_>) {
        for v in self.u.iter_mut() {
            *v = ctx.quant(*v);
        }
    }

    fn advance(
        &mut self,
        ctx: &mut Ctx<'_>,
        steps: usize,
        step_base: usize,
        snapshot_every: usize,
        snaps: &mut Vec<(usize, Vec<f64>)>,
        batched: bool,
    ) {
        if batched {
            // When the snapshot phase aligns with this call's step window
            // (always true for whole-run calls; epoch calls align unless a
            // snapshot boundary cuts an epoch), the whole window is one
            // fused multi-step call (DESIGN.md §9): packed backends keep
            // Full-mode state in the packed domain across the window.
            let aligned = snapshot_every == 0 || step_base % snapshot_every == 0;
            if aligned {
                let mut local = Vec::new();
                ctx.stencil_multi(
                    &mut self.u,
                    &mut self.next,
                    self.r,
                    steps,
                    snapshot_every,
                    &mut local,
                );
                snaps.extend(local.into_iter().map(|(s, f)| (step_base + s, f)));
            } else {
                for s in 0..steps {
                    ctx.stencil_step(&mut self.next, &self.u, self.r);
                    std::mem::swap(&mut self.u, &mut self.next);
                    let global = step_base + s + 1;
                    if global % snapshot_every == 0 {
                        snaps.push((global, self.u.clone()));
                    }
                }
            }
            return;
        }
        // The per-multiplication reference path: every stencil
        // multiplication goes through one dynamically-dispatched mul call,
        // exactly as the paper's emulation is specified (and bit-identical
        // to `scalar_stencil_step` — the shared canonical sequence).
        let two_r = 2.0 * self.r;
        for s in 0..steps {
            for i in 1..self.n - 1 {
                // du = r·u[i−1] − (2r)·u[i] + r·u[i+1]
                let left = ctx.mul(self.r, self.u[i - 1]);
                let mid = ctx.mul(two_r, self.u[i]);
                let right = ctx.mul(self.r, self.u[i + 1]);
                let du = {
                    let t = ctx.sub(left, mid);
                    ctx.add(t, right)
                };
                let unew = ctx.add(self.u[i], du);
                self.next[i] = ctx.quant(unew);
            }
            // Dirichlet boundaries keep their (possibly quantized) values.
            self.next[0] = self.u[0];
            self.next[self.n - 1] = self.u[self.n - 1];
            std::mem::swap(&mut self.u, &mut self.next);
            let global = step_base + s + 1;
            if snapshot_every != 0 && global % snapshot_every == 0 {
                snaps.push((global, self.u.clone()));
            }
        }
    }

    fn save(&self) -> Vec<Vec<f64>> {
        vec![self.u.clone()]
    }

    fn restore(&mut self, saved: &[Vec<f64>]) {
        self.u.copy_from_slice(&saved[0]);
    }

    fn telemetry(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&self.u);
    }

    fn telemetry_len(&self) -> usize {
        self.n
    }

    fn primary_field(&self) -> Vec<f64> {
        self.u.clone()
    }
}

pub(super) fn finish(sim: HeatSim, stats: RunStats) -> HeatResult {
    HeatResult {
        u: sim.into_field(),
        snapshots: stats.snapshots,
        muls: stats.muls,
        backend: stats.backend,
        r2f2_stats: stats.r2f2_stats,
        range_events: stats.range_events,
    }
}

/// Run the simulation with the given arithmetic backend and quantization
/// mode, using the backend's batched stencil engine (DESIGN.md §8). Results
/// are bit-identical to [`run_scalar`]; `rust/tests/batched_vs_scalar.rs`
/// holds the contract.
pub fn run(params: &HeatParams, be: &mut dyn Arith, mode: QuantMode) -> HeatResult {
    let mut sim = HeatSim::new(params);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, true);
    finish(sim, stats)
}

/// The per-multiplication reference path: every stencil multiplication goes
/// through one dynamically-dispatched [`Arith::mul`] call, exactly as the
/// paper's emulation is specified. Kept as the semantic reference for the
/// batched engine and as the baseline for `benches/hotpath.rs`.
pub fn run_scalar(params: &HeatParams, be: &mut dyn Arith, mode: QuantMode) -> HeatResult {
    let mut sim = HeatSim::new(params);
    let stats = scenario::run_sim(&mut sim, be, mode, params.steps, params.snapshot_every, false);
    finish(sim, stats)
}

/// Adaptive-precision run: the [`super::AdaptiveArith`] scheduler samples
/// range telemetry between timesteps and walks its format ladder under the
/// widen/narrow hysteresis policy (`pde::adaptive`), with the epoch
/// save/restore retry semantics provided by the generic
/// [`scenario::run_sim_adaptive`] driver. The schedule trace is available
/// from the scheduler afterwards.
pub fn run_adaptive(
    params: &HeatParams,
    sched: &mut super::AdaptiveArith,
    mode: QuantMode,
) -> HeatResult {
    let mut sim = HeatSim::new(params);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        true,
    );
    finish(sim, stats)
}

/// The per-multiplication scalar reference of [`run_adaptive`] —
/// bit-identical to it, including the switch schedule.
pub fn run_adaptive_scalar(
    params: &HeatParams,
    sched: &mut super::AdaptiveArith,
    mode: QuantMode,
) -> HeatResult {
    let mut sim = HeatSim::new(params);
    let stats = scenario::run_sim_adaptive(
        &mut sim,
        sched,
        mode,
        params.steps,
        params.snapshot_every,
        false,
    );
    finish(sim, stats)
}

/// Analytic solution for the single-mode sine case
/// `u₀ = A·sin(cπx/L)`: `u(x,t) = A·exp(−α(cπ/L)²t)·sin(cπx/L)` — used to
/// validate the solver itself (not just backend-vs-backend).
pub fn sine_analytic(params: &HeatParams, t: f64) -> Option<Vec<f64>> {
    if let HeatInit::Sin { amplitude, cycles } = params.init {
        let k = cycles * std::f64::consts::PI / params.length;
        let decay = (-params.alpha * k * k * t).exp();
        Some(
            (0..params.n)
                .map(|i| {
                    let x = i as f64 / (params.n - 1) as f64 * params.length;
                    amplitude * decay * (k * x).sin()
                })
                .collect(),
        )
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{rel_l2, F32Arith, F64Arith, FixedArith, R2f2Arith};
    use crate::r2f2core::R2f2Config;
    use crate::softfloat::FpFormat;

    fn small() -> HeatParams {
        HeatParams {
            n: 101,
            dt: 0.25 / (100.0f64 * 100.0),
            steps: 1500,
            ..HeatParams::default()
        }
    }

    #[test]
    fn f64_matches_analytic_solution() {
        let p = small();
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let t = p.dt * p.steps as f64;
        let exact = sine_analytic(&p, t).unwrap();
        let err = rel_l2(&res.u, &exact);
        assert!(err < 5e-3, "solver discretization error too large: {err}");
    }

    #[test]
    fn max_principle_holds_in_f64() {
        // Explicit heat with r ≤ 1/2 is monotone: no new extrema.
        let p = small();
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let max0 = 500.0;
        assert!(res.u.iter().all(|&v| v.abs() <= max0 + 1e-9));
    }

    #[test]
    fn mul_count_matches_expectation() {
        let p = small();
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        assert_eq!(res.muls, p.expected_muls());
    }

    #[test]
    fn default_run_is_about_1_5m_muls() {
        // §5.3: "the entire computation ... involves 1.5M multiplications".
        let p = HeatParams::default();
        assert_eq!(p.expected_muls(), 1_497_000); // 3 × 499 × 1000
    }

    #[test]
    fn f32_close_to_f64() {
        let p = small();
        let a = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let b = run(&p, &mut F32Arith, QuantMode::MulOnly);
        assert!(rel_l2(&b.u, &a.u) < 1e-5);
    }

    #[test]
    fn r2f2_16bit_matches_f32_where_full_half_fails() {
        // The headline case study (Fig. 1b vs Fig. 7a): a genuinely
        // half-precision simulation (state + arithmetic in E5M10) is wrong,
        // while 16-bit R2F2 multiplications (state in the f32 carrier, the
        // paper's deployment, §5.2) track single precision.
        let p = small();
        let reference = run(&p, &mut F32Arith, QuantMode::MulOnly);

        let mut r2f2 = R2f2Arith::new(R2f2Config::C16_393);
        let ours = run(&p, &mut r2f2, QuantMode::MulOnly);
        let err_r2f2 = rel_l2(&ours.u, &reference.u);

        let mut half = FixedArith::new(FpFormat::E5M10);
        let theirs = run(&p, &mut half, QuantMode::Full);
        let err_half = rel_l2(&theirs.u, &reference.u);

        assert!(err_r2f2 < 1e-2, "R2F2 error {err_r2f2}");
        assert!(err_half > 5.0 * err_r2f2, "half {err_half} vs r2f2 {err_r2f2}");
    }

    #[test]
    fn r2f2_beats_fixed_half_at_equal_scope() {
        // Same quantization scope (MulOnly) for both units: the adaptive
        // format must not be worse than the fixed one.
        let p = small();
        let reference = run(&p, &mut F32Arith, QuantMode::MulOnly);
        let mut r2f2 = R2f2Arith::new(R2f2Config::C16_393);
        let err_r2f2 = rel_l2(&run(&p, &mut r2f2, QuantMode::MulOnly).u, &reference.u);
        let mut half = FixedArith::new(FpFormat::E5M10);
        let err_half = rel_l2(&run(&p, &mut half, QuantMode::MulOnly).u, &reference.u);
        assert!(err_r2f2 <= err_half * 1.05, "r2f2 {err_r2f2} vs half {err_half}");
    }

    #[test]
    fn r2f2_adjustments_are_rare() {
        // §5.3: adjustments happen a handful of times in 1.5M muls.
        let p = small();
        let mut r2f2 = R2f2Arith::new(R2f2Config::C16_393);
        let res = run(&p, &mut r2f2, QuantMode::MulOnly);
        let st = res.r2f2_stats.unwrap();
        assert!(st.muls > 0);
        let adj = st.overflow_adjustments + st.redundancy_adjustments;
        assert!(adj < st.muls / 100, "adjustments should be rare: {adj} of {}", st.muls);
    }

    #[test]
    fn full_mode_half_fails_visibly() {
        // Fig. 1(b): a *fully* half-precision simulation is wrong (the
        // coarse ulp at |u| ≈ 500 swallows the per-step updates).
        let p = small();
        let reference = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let mut half = FixedArith::new(FpFormat::E5M10);
        let wrong = run(&p, &mut half, QuantMode::Full);
        assert!(rel_l2(&wrong.u, &reference.u) > 0.05);
    }

    #[test]
    fn stochastic_rounding_rescues_full_half() {
        // Paxton et al. (cited in §2): deterministic RNE in a fully
        // half-precision simulation systematically swallows sub-ulp updates,
        // while stochastic rounding preserves them in expectation. The
        // stochastic full-half run must beat the RNE full-half run.
        let p = small();
        let reference = run(&p, &mut F64Arith, QuantMode::MulOnly);
        let mut rne = FixedArith::new(FpFormat::E5M10);
        let err_rne = rel_l2(&run(&p, &mut rne, QuantMode::Full).u, &reference.u);
        let mut sr = crate::pde::StochasticArith::new(FpFormat::E5M10, 7);
        let err_sr = rel_l2(&run(&p, &mut sr, QuantMode::Full).u, &reference.u);
        assert!(
            err_sr < 0.5 * err_rne,
            "stochastic {err_sr} should beat deterministic {err_rne}"
        );
    }

    #[test]
    fn batched_run_matches_scalar_reference() {
        // The DESIGN.md §8 contract in miniature; the full per-backend
        // matrix lives in tests/batched_vs_scalar.rs.
        let p = small();
        let mut a = R2f2Arith::new(R2f2Config::C16_393);
        let mut b = R2f2Arith::new(R2f2Config::C16_393);
        let scalar = super::run_scalar(&p, &mut a, QuantMode::MulOnly);
        let batched = run(&p, &mut b, QuantMode::MulOnly);
        assert_eq!(scalar.muls, batched.muls);
        assert_eq!(scalar.r2f2_stats, batched.r2f2_stats);
        for i in 0..p.n {
            assert_eq!(scalar.u[i].to_bits(), batched.u[i].to_bits(), "node {i}");
        }
    }

    #[test]
    fn snapshots_collected() {
        let mut p = small();
        p.steps = 400;
        p.snapshot_every = 100;
        let res = run(&p, &mut F64Arith, QuantMode::MulOnly);
        assert_eq!(res.snapshots.len(), 4);
        assert_eq!(res.snapshots[0].0, 100);
    }

    #[test]
    #[should_panic(expected = "unstable")]
    fn instability_rejected() {
        let mut p = small();
        p.dt *= 3.0; // r = 0.75
        run(&p, &mut F64Arith, QuantMode::MulOnly);
    }
}
