//! Command-line argument parsing (the environment has no `clap`).
//!
//! Grammar: `r2f2 <subcommand> [--key value]... [--switch]... [positional]...`
//! `--key=value` is accepted as a synonym for `--key value`. Boolean
//! switches must be *declared* at parse time (like clap) so that
//! `--verbose out.csv` doesn't swallow the positional as a value. Unknown
//! keys are an error at [`Args::finish`] time so typos fail loudly.

use std::collections::{BTreeMap, BTreeSet};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first non-flag token), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    opts: BTreeMap<String, String>,
    /// Bare `--switch` flags.
    switches: BTreeSet<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// Keys the program actually consumed (for unknown-option detection).
    consumed: BTreeSet<String>,
}

/// Errors produced while reading options.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    MissingValue(String),
    BadValue { key: String, value: String, expected: &'static str },
    Unknown(Vec<String>),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(k) => write!(f, "option --{k} requires a value"),
            CliError::BadValue { key, value, expected } => {
                write!(f, "option --{key}={value} is not a valid {expected}")
            }
            CliError::Unknown(keys) => write!(f, "unknown options: {}", keys.join(", ")),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse an iterator of tokens (excluding argv[0]). `known_switches`
    /// lists the boolean flags; every other `--key` expects a value.
    pub fn parse<I: IntoIterator<Item = String>>(
        tokens: I,
        known_switches: &[&str],
    ) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&body) {
                    out.switches.insert(body.to_string());
                } else {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.opts.insert(body.to_string(), v);
                        }
                        _ => return Err(CliError::MissingValue(body.to_string())),
                    }
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process's own command line.
    pub fn from_env(known_switches: &[&str]) -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1), known_switches)
    }

    /// Raw string option.
    pub fn get(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.opts.get(key).cloned()
    }

    /// String option with default.
    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v,
                expected: std::any::type_name::<T>(),
            }),
        }
    }

    /// Comma-separated typed list with default (`--rates 50,100,200`).
    /// Empty segments are rejected rather than skipped — `50,,200` is a
    /// typo, not a two-element list.
    pub fn get_list<T: std::str::FromStr>(
        &mut self,
        key: &str,
        default: &[T],
    ) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|part| part.trim().parse::<T>())
                .collect::<Result<Vec<T>, _>>()
                .map_err(|_| CliError::BadValue {
                    key: key.to_string(),
                    value: v,
                    expected: "comma-separated list",
                }),
        }
    }

    /// Bare switch (`--verbose`).
    pub fn switch(&mut self, key: &str) -> bool {
        self.consumed.insert(key.to_string());
        self.switches.contains(key)
    }

    /// Fail if the user passed options the program never consumed.
    pub fn finish(&self) -> Result<(), CliError> {
        let unknown: Vec<String> = self
            .opts
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !self.consumed.contains(*k))
            .map(|k| format!("--{k}"))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(unknown))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    const SW: &[&str] = &["verbose", "dry-run", "quick"];

    #[test]
    fn command_options_switches_positionals() {
        let mut a =
            Args::parse(toks("run --app heat --steps=100 --verbose out.csv"), SW).unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("app").as_deref(), Some("heat"));
        assert_eq!(a.get_parse("steps", 0u32).unwrap(), 100);
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(toks("run"), SW).unwrap();
        assert_eq!(a.get_or("app", "heat"), "heat");
        assert_eq!(a.get_parse("n", 64usize).unwrap(), 64);
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn bad_value_reported() {
        let mut a = Args::parse(toks("run --steps abc"), SW).unwrap();
        let err = a.get_parse("steps", 0u32).unwrap_err();
        assert!(matches!(err, CliError::BadValue { .. }));
    }

    #[test]
    fn missing_value_reported() {
        let err = Args::parse(toks("run --steps"), SW).unwrap_err();
        assert_eq!(err, CliError::MissingValue("steps".into()));
    }

    #[test]
    fn unknown_options_detected() {
        let mut a = Args::parse(toks("run --app heat --tpyo 3"), SW).unwrap();
        let _ = a.get("app");
        let err = a.finish().unwrap_err();
        assert_eq!(err, CliError::Unknown(vec!["--tpyo".into()]));
    }

    #[test]
    fn declared_switch_does_not_eat_positional() {
        let mut a = Args::parse(toks("bench --quick table1"), SW).unwrap();
        assert!(a.switch("quick"));
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["table1"]);
    }

    #[test]
    fn equals_form_allows_flag_like_values() {
        let mut a = Args::parse(toks("run --backend=r2f2:<3,9,3> --dry-run"), SW).unwrap();
        assert_eq!(a.get("backend").as_deref(), Some("r2f2:<3,9,3>"));
        assert!(a.switch("dry-run"));
    }

    #[test]
    fn comma_separated_lists_parse() {
        let mut a = Args::parse(toks("bench-serve --rates 50,100,200"), SW).unwrap();
        assert_eq!(a.get_list("rates", &[40u64]).unwrap(), vec![50, 100, 200]);
        assert_eq!(a.get_list("missing", &[40u64]).unwrap(), vec![40], "default applies");

        let mut b = Args::parse(toks("bench-serve --rates=25"), SW).unwrap();
        assert_eq!(b.get_list("rates", &[0u64]).unwrap(), vec![25], "equals form, single item");

        // Whitespace around segments is trimmed (one quoted shell token).
        let mut c = Args::parse(vec!["bench-serve".into(), "--rates".into(), "10 , 30".into()], SW)
            .unwrap();
        assert_eq!(c.get_list("rates", &[0u64]).unwrap(), vec![10, 30]);

        // Trailing commas and junk are typos, not silently-shorter lists.
        for bad in ["50,100,", ",50", "50,x,70"] {
            let mut d =
                Args::parse(vec!["bench-serve".into(), format!("--rates={bad}")], SW).unwrap();
            assert!(
                matches!(d.get_list("rates", &[0u64]), Err(CliError::BadValue { .. })),
                "{bad}"
            );
        }
    }

    #[test]
    fn serve_style_command_lines_parse() {
        // The `serve` / `bench-serve` surfaces: numeric options (including
        // port 0 for an ephemeral bind), a declared switch, and a path.
        let sw = &["smoke"];
        let line = toks("serve --port 0 --workers 2 --queue-cap 1 --cache-cap 64");
        let mut a = Args::parse(line, sw).unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_parse("port", 7272u16).unwrap(), 0);
        assert_eq!(a.get_parse("workers", 1usize).unwrap(), 2);
        assert_eq!(a.get_parse("queue-cap", 64usize).unwrap(), 1);
        assert_eq!(a.get_parse("cache-cap", 256usize).unwrap(), 64);
        a.finish().unwrap();

        let mut b = Args::parse(toks("bench-serve --smoke --out BENCH_serve.json"), sw).unwrap();
        assert!(b.switch("smoke"));
        assert_eq!(b.get_or("out", "BENCH_serve.json"), "BENCH_serve.json");
        assert_eq!(b.get_parse("clients", 8usize).unwrap(), 8, "defaults apply");
        b.finish().unwrap();
    }
}
