//! Minimal property-based testing support (the environment has no
//! `proptest`/`quickcheck`).
//!
//! Properties are closures over a [`Gen`]; [`check`] runs them for a fixed
//! number of cases with a deterministic seed (override with the
//! `R2F2_PROPTEST_SEED` environment variable to explore) and reports the
//! failing case index + seed so any failure is replayable.

use crate::rng::SplitMix64;

/// Random input generator handed to properties.
pub struct Gen {
    rng: SplitMix64,
    /// Case index (0-based) — useful in failure messages.
    pub case: usize,
}

impl Gen {
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    /// Uniform in `[lo, hi]` (inclusive) for small integer ranges.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Log-uniform float in `[lo, hi)`, `lo > 0` — the natural distribution
    /// for floating-point magnitudes.
    pub fn f64_log(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.log_uniform(lo, hi)
    }

    /// Log-uniform magnitude with random sign.
    pub fn f64_signed_log(&mut self, lo: f64, hi: f64) -> f64 {
        let m = self.rng.log_uniform(lo, hi);
        if self.rng.next_u64() & 1 == 0 {
            m
        } else {
            -m
        }
    }

    /// A "nasty" f64: boundary values mixed with random bit patterns and
    /// log-uniform magnitudes — the adversarial diet for encode/mul/add.
    pub fn f64_nasty(&mut self) -> f64 {
        const SPECIALS: [f64; 12] = [
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.5,
            2.0,
            65504.0,
            6.103515625e-5,
            1e-30,
            1e30,
            f64::MAX,
            f64::MIN_POSITIVE,
        ];
        match self.below(4) {
            0 => SPECIALS[self.below(SPECIALS.len() as u64) as usize],
            1 => f64::from_bits(self.u64()),
            _ => self.f64_signed_log(1e-20, 1e20),
        }
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 0
    }
}

fn seed() -> u64 {
    std::env::var("R2F2_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE_F00D_D00D)
}

/// Run `prop` for `cases` generated inputs; panic with a replayable message
/// on the first failure (a property fails by returning `Err(description)`
/// or panicking itself).
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let seed = seed();
    let mut root = SplitMix64::new(seed);
    for case in 0..cases {
        // Fork per case so failures are replayable independently of how
        // many draws earlier cases consumed.
        let mut g = Gen { rng: root.fork(), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n\
                 replay with R2F2_PROPTEST_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 100, |g| {
            n += 1;
            let x = g.f64_in(0.0, 1.0);
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
        assert_eq!(n, 100);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_case_info() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_are_deterministic() {
        let mut a = Vec::new();
        check("collect-a", 5, |g| {
            a.push(g.u64());
            Ok(())
        });
        let mut b = Vec::new();
        check("collect-b", 5, |g| {
            b.push(g.u64());
            Ok(())
        });
        assert_eq!(a, b);
    }

    #[test]
    fn int_in_is_inclusive() {
        let mut seen_lo = false;
        let mut seen_hi = false;
        check("int-range", 1000, |g| {
            let v = g.int_in(-2, 2);
            if v == -2 {
                seen_lo = true;
            }
            if v == 2 {
                seen_hi = true;
            }
            if (-2..=2).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v}"))
            }
        });
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn nasty_floats_include_specials_and_randoms() {
        let mut zeros = 0;
        let mut finites = 0;
        check("nasty", 2000, |g| {
            let x = g.f64_nasty();
            if x == 0.0 {
                zeros += 1;
            }
            if x.is_finite() {
                finites += 1;
            }
            Ok(())
        });
        assert!(zeros > 0);
        assert!(finites > 1000);
    }
}
