//! ASCII renderings of the paper's figures, so `cargo bench` output shows
//! the *shape* of each result (wave profiles, error curves, histograms)
//! directly in the terminal / bench_output.txt.

/// Render one or more named series as an ASCII line plot.
///
/// All series share the x-index (0..len) and the y-scale. Each series draws
/// with its own glyph; later series overdraw earlier ones.
pub fn line_plot(title: &str, series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    assert!(!series.is_empty() && height >= 2 && width >= 2);
    let glyphs = ['*', 'o', '+', 'x', '#', '@'];
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    let mut maxlen = 0usize;
    for (_, ys) in series {
        maxlen = maxlen.max(ys.len());
        for &y in ys.iter().filter(|y| y.is_finite()) {
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !ymin.is_finite() || ymax == ymin {
        ymax = ymin + 1.0;
    }
    let mut canvas = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let g = glyphs[si % glyphs.len()];
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let x = if maxlen <= 1 { 0 } else { i * (width - 1) / (maxlen - 1) };
            let fy = (y - ymin) / (ymax - ymin);
            let r = height - 1 - ((fy * (height - 1) as f64).round() as usize).min(height - 1);
            canvas[r][x] = g;
        }
    }
    let mut out = format!("{title}\n");
    out.push_str(&format!("  ymax = {ymax:.4e}\n"));
    for row in canvas {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("  ymin = {ymin:.4e}   legend: "));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", glyphs[si % glyphs.len()], name));
    }
    out.push('\n');
    out
}

/// Render a pre-bucketed histogram (`(label, count)` bars).
pub fn histogram(title: &str, buckets: &[(String, u64)], width: usize) -> String {
    let maxc = buckets.iter().map(|(_, c)| *c).max().unwrap_or(1).max(1);
    let lw = buckets.iter().map(|(l, _)| l.len()).max().unwrap_or(4);
    let mut out = format!("{title}\n");
    for (label, count) in buckets {
        let bar = (*count as usize * width) / maxc as usize;
        out.push_str(&format!("  {label:<lw$} |{} {count}\n", "#".repeat(bar)));
    }
    out
}

/// Render a small 2D field (e.g. the SWE height map) with intensity glyphs.
pub fn surface(title: &str, field: &[f64], n: usize) -> String {
    assert_eq!(field.len(), n * n);
    const RAMP: &[u8] = b" .:-=+*#%@";
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in field.iter().filter(|v| v.is_finite()) {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || hi == lo {
        hi = lo + 1.0;
    }
    let mut out = format!("{title}  [{lo:.4e} … {hi:.4e}]\n");
    for j in 0..n {
        out.push_str("  ");
        for i in 0..n {
            let v = field[j * n + i];
            let t = if v.is_finite() { (v - lo) / (hi - lo) } else { 0.0 };
            let idx = ((t * (RAMP.len() - 1) as f64).round() as usize).min(RAMP.len() - 1);
            out.push(RAMP[idx] as char);
            out.push(RAMP[idx] as char); // double width ≈ square pixels
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_plot_contains_series_and_bounds() {
        let ys: Vec<f64> = (0..50).map(|i| (i as f64 / 8.0).sin()).collect();
        let p = line_plot("sine", &[("u", &ys)], 60, 12);
        assert!(p.contains("sine"));
        assert!(p.contains("ymax"));
        assert!(p.contains('*'));
        assert_eq!(p.lines().count(), 12 + 4);
    }

    #[test]
    fn two_series_two_glyphs() {
        let a = [0.0, 1.0, 0.0];
        let b = [1.0, 0.0, 1.0];
        let p = line_plot("two", &[("a", &a), ("b", &b)], 20, 8);
        assert!(p.contains('*') && p.contains('o'));
        assert!(p.contains("legend"));
    }

    #[test]
    fn histogram_bars_scale() {
        let b = vec![("[0,1)".to_string(), 10u64), ("[1,2)".to_string(), 5)];
        let h = histogram("h", &b, 20);
        let lines: Vec<&str> = h.lines().collect();
        let bars: Vec<usize> =
            lines[1..].iter().map(|l| l.matches('#').count()).collect();
        assert_eq!(bars[0], 20);
        assert_eq!(bars[1], 10);
    }

    #[test]
    fn surface_renders_square() {
        let f: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let s = surface("field", &f, 4);
        assert_eq!(s.lines().count(), 5);
        assert!(s.contains('@'));
    }

    #[test]
    fn constant_series_does_not_divide_by_zero() {
        let ys = [2.0; 10];
        let p = line_plot("const", &[("c", &ys)], 20, 5);
        assert!(p.contains('*'));
    }

    #[test]
    fn non_finite_values_skipped() {
        let ys = [1.0, f64::NAN, 2.0, f64::INFINITY, 0.5];
        let p = line_plot("nan", &[("v", &ys)], 20, 5);
        assert!(p.contains('*'));
        let f = [1.0, f64::NAN, 2.0, 0.0];
        let s = surface("nan", &f, 2);
        assert!(!s.is_empty());
    }
}
