//! Minimal CSV writing (quoting only when needed).

use std::fs;
use std::io::Write;
use std::path::Path;

/// Accumulates rows, then writes to a string or file.
#[derive(Debug, Default, Clone)]
pub struct CsvWriter {
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new() -> CsvWriter {
        CsvWriter::default()
    }

    /// Add a row of raw cells (quoted on write if they contain `,"\n`).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    /// Add a row of floats.
    pub fn row_f64(&mut self, cells: &[f64]) -> &mut Self {
        self.rows.push(cells.iter().map(|v| format!("{v}")).collect());
        self
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        for row in &self.rows {
            let encoded: Vec<String> = row.iter().map(|c| quote(c)).collect();
            out.push_str(&encoded.join(","));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

fn quote(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_rows() {
        let mut w = CsvWriter::new();
        w.row(vec!["a", "b"]).row_f64(&[1.5, 2.0]);
        assert_eq!(w.to_string(), "a,b\n1.5,2\n");
    }

    #[test]
    fn quoting() {
        let mut w = CsvWriter::new();
        w.row(vec!["x,y", "he said \"hi\""]);
        assert_eq!(w.to_string(), "\"x,y\",\"he said \"\"hi\"\"\"\n");
    }

    #[test]
    fn file_roundtrip() {
        let mut w = CsvWriter::new();
        w.row(vec!["k", "v"]).row(vec!["n", "3"]);
        let p = std::env::temp_dir().join("r2f2_csv_test/out.csv");
        w.write(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "k,v\nn,3\n");
        let _ = std::fs::remove_file(&p);
    }
}
