//! Report emitters: aligned tables, CSV files, and ASCII plots.
//!
//! The bench binaries print the paper's tables/figures through this module
//! so `cargo bench | tee bench_output.txt` records everything as text, and
//! also drop machine-readable CSVs under `target/reports/`.

pub mod ascii_plot;
pub mod csv;

pub use ascii_plot::{histogram, line_plot, surface};
pub use csv::CsvWriter;

/// An aligned text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with columns padded to their widest cell. First column is
    /// left-aligned, the rest right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for row in std::iter::once(&self.header).chain(self.rows.iter()) {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |row: &[String], out: &mut String| {
            for (i, c) in row.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                if i == 0 {
                    out.push_str(&format!("{:<w$}", c, w = width[i]));
                } else {
                    out.push_str(&format!("{:>w$}", c, w = width[i]));
                }
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            fmt_row(r, &mut out);
        }
        out
    }
}

/// Format a float with `digits` significant decimals, trimming noise.
pub fn sig(v: f64, digits: usize) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if !(1e-4..1e7).contains(&a) {
        return format!("{v:.*e}", digits.saturating_sub(1));
    }
    let decimals = (digits as i32 - 1 - a.log10().floor() as i32).max(0) as usize;
    format!("{v:.decimals$}")
}

/// Percentage rendering (`0.702` → `70.2%`).
pub fn pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["short", "1"]).row(vec!["a-much-longer-name", "12345"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[1].starts_with('-'));
        // Right-aligned second column: both rows end aligned.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn sig_formatting() {
        assert_eq!(sig(0.0, 3), "0");
        assert_eq!(sig(1234.5678, 4), "1235");
        assert_eq!(sig(0.00123, 3), "0.00123");
        assert!(sig(1.23e-9, 3).contains('e'));
    }

    #[test]
    fn pct_formatting() {
        assert_eq!(pct(0.702), "70.2%");
        assert_eq!(pct(0.0), "0.0%");
    }
}
