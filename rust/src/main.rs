//! `r2f2` — the Layer-3 command-line driver.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!   run       one simulation experiment (TOML config or flags); --trace
//!             exports the run's span records as r2f2-trace/1 ndjson
//!   compare   f64 / f32 / half / R2F2 side by side (Figs 1, 7, 8)
//!   analyze   data-distribution study (Fig 2)
//!   profile   precision-configuration profiling + Eq.(1) check (Fig 3);
//!             with --scenario, the RAPTOR-style pilot: per-rung range
//!             telemetry → recommended starting format with predicted
//!             RMSE and modeled datapath cost (ROADMAP item 4)
//!   sweep     multiplication-accuracy sweep (Fig 6)
//!   table1    resource + latency model (Table 1)
//!   pipeline  three-layer run: AOT artifacts via PJRT (the e2e path)
//!   serve     long-lived simulation service (worker pool + result cache)
//!   bench-serve  loopback load generator for the service (BENCH_serve.json)
//!   audit     static conformance pass over the tree (DESIGN.md §15)

use r2f2::analysis;
use r2f2::audit;
use r2f2::cli::Args;
use r2f2::config::{parse_backend, ExperimentConfig, APPS};
use r2f2::coordinator::{self, Coordinator};
use r2f2::metrics::Registry;
use r2f2::pde::init::HeatInit;
use r2f2::pde::scenario::SCENARIOS;
use r2f2::pde::QuantMode;
use r2f2::r2f2core::{datapath, resource, R2f2Config};
use r2f2::report::{self, ascii_plot, Table};
use r2f2::runtime::{HeatRunner, Runtime};
use r2f2::softfloat::FpFormat;
use r2f2::sweep::{config_profile, error_sweep};

const SWITCHES: &[&str] = &["verbose", "json", "help", "full", "profile", "smoke"];

fn main() {
    let mut args = match Args::from_env(SWITCHES) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "run" => cmd_run(&mut args),
        "compare" => cmd_compare(&mut args),
        "serve" => cmd_serve(&mut args),
        "bench-serve" => cmd_bench_serve(&mut args),
        "scenarios" => cmd_scenarios(&mut args),
        "analyze" => cmd_analyze(&mut args),
        "profile" => cmd_profile(&mut args),
        "sweep" => cmd_sweep(&mut args),
        "table1" => cmd_table1(&mut args),
        "pipeline" => cmd_pipeline(&mut args),
        "audit" => cmd_audit(&mut args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
    // Unknown / unconsumed flags are usage errors, not runtime failures:
    // exit 2 loudly (same convention as the bench harnesses).
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}

fn print_help() {
    println!(
        "r2f2 — runtime reconfigurable floating-point precision (paper reproduction)

USAGE: r2f2 <command> [options]

COMMANDS
  run       --config FILE | --app heat|swe|advection|wave --backend SPEC
            [--mode mul-only|full] [--n N --steps S] [--trace FILE] — run
            one experiment vs the f64 reference; --trace writes the run's
            deterministic span records (r2f2-trace/1 ndjson)
  compare   --app heat|swe|advection|wave — f64/f32/half/R2F2 comparison
            table (Figs 1/7/8)
  scenarios [--scenario NAME] [--profile] — list the scenario registry;
            with --profile, per-scenario fixed-format precision profiles
  analyze   [--n N --steps S] — Fig 2 data-distribution study
  profile   [--pairs P] — Fig 3 precision profiling + Eq.(1) check
            --scenario NAME|all [--out FILE] — RAPTOR-style pilot over the
            scenario registry: per-rung range telemetry, recommended
            starting format + predicted rel-err + modeled LUT cost
            (r2f2-profile-plan/1); the adaptive scheduler can seed its
            ladder from the plan
  sweep     [--intervals I --pairs P] — Fig 6 accuracy sweep
  table1    — Table 1 resource & latency model vs paper
  pipeline  [--artifacts DIR --steps S --backend r2f2|e5m10|f32] — run the
            heat simulation through the AOT artifacts on PJRT (three-layer)
  serve     [--port P] [--workers W] [--queue-cap Q] [--cache-cap C]
            [--keepalive-ms MS] [--jobs-cap J] — the simulation service:
            POST /v1/run, async POST /v1/jobs (+ status/result/events/
            pause/resume), POST /v1/profile, GET /v1/scenarios, /v1/trace,
            /healthz, /metrics (JSON, or Prometheus text under
            Accept: text/plain) (DESIGN.md §12/§16/§17); R2F2_WORKERS
            overrides the pool size
  bench-serve [--clients N] [--requests M] [--workers W] [--cache-cap C]
            [--rates R1,R2,...] [--smoke] [--out FILE] — start an
            in-process server and drive it from N loopback clients
            (M requests each), then replay an open-loop arrival sweep at
            each rate (req/s); emits BENCH_serve.json
            (schema r2f2-bench-serve/2)
  audit     [--json [FILE]] [--snapshot FILE] [--rule ID] [--root DIR] —
            static conformance pass (DESIGN.md §15): lexes the tree and
            enforces the determinism/bit-identity rules; exits non-zero
            on any unsuppressed finding. --json alone prints the
            r2f2-audit/1 report to stdout; --snapshot writes the
            counts-only form diffed against rust/AUDIT_smoke.json

BACKEND SPECS: f64 | f32 | fixed:E5M10 (any ExMy) | r2f2:<3,9,3> (any <EB,MB,FX>)"
    );
}

fn experiment_from_args(args: &mut Args) -> Result<ExperimentConfig, String> {
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
        return ExperimentConfig::from_toml(&text);
    }
    let mut cfg = ExperimentConfig::default();
    cfg.app = args.get_or("app", "heat");
    if !APPS.contains(&cfg.app.as_str()) {
        return Err(format!("app must be {}, got `{}`", APPS.join("|"), cfg.app));
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = parse_backend(&b)?;
    }
    match args.get_or("mode", "mul-only").as_str() {
        "mul-only" => cfg.mode = QuantMode::MulOnly,
        "full" => cfg.mode = QuantMode::Full,
        other => return Err(format!("bad mode {other}")),
    }
    if let Some(n) = args.get("n") {
        let n: usize = n.parse().map_err(|_| "bad --n")?;
        cfg.heat.n = n;
        cfg.heat.dt = 0.25 / ((n - 1) as f64 * (n - 1) as f64);
        cfg.swe.n = n;
        // Keep the scenario defaults' stability numbers at the new size.
        cfg.advection.dt = cfg.advection.dt * cfg.advection.n as f64 / n as f64;
        cfg.advection.n = n;
        cfg.wave.dt = cfg.wave.dt * (cfg.wave.n - 1) as f64 / (n - 1) as f64;
        cfg.wave.n = n;
    }
    if let Some(s) = args.get("steps") {
        let s: usize = s.parse().map_err(|_| "bad --steps")?;
        cfg.heat.steps = s;
        cfg.swe.steps = s;
        cfg.advection.steps = s;
        cfg.wave.steps = s;
    }
    if let Some(init) = args.get("init") {
        cfg.heat.init = match init.as_str() {
            "sin" => HeatInit::sin_default(),
            "exp" => HeatInit::exp_default(),
            other => return Err(format!("bad init {other}")),
        };
    }
    Ok(cfg)
}

fn cmd_run(args: &mut Args) -> Result<(), String> {
    let trace_path = args.get("trace");
    let cfg = experiment_from_args(args)?;
    let metrics = Registry::new();
    let collector = trace_path.as_ref().map(|_| r2f2::trace::Collector::new());
    let outcome = coordinator::run_experiment_traced(&cfg, &metrics, collector.as_ref());
    println!("{}", Coordinator::outcome_table(std::slice::from_ref(&outcome)));
    if let (Some(path), Some(c)) = (&trace_path, &collector) {
        std::fs::write(path, c.to_ndjson()).map_err(|e| format!("write {path}: {e}"))?;
        println!("wrote {path} ({} events, schema r2f2-trace/1)", c.len());
    }
    if args.switch("verbose") {
        let ds: Vec<f64> = outcome.field.iter().step_by(outcome.field.len().div_ceil(64)).copied().collect();
        println!("{}", ascii_plot::line_plot("final field", &[("u", &ds)], 64, 12));
        println!("{}", metrics.render());
    }
    if args.switch("json") {
        println!("{}", metrics.to_json());
    }
    Ok(())
}

fn cmd_compare(args: &mut Args) -> Result<(), String> {
    let app = args.get_or("app", "heat");
    if !APPS.contains(&app.as_str()) {
        return Err(format!("app must be {}, got `{app}`", APPS.join("|")));
    }
    let coord = Coordinator::default();
    let outcomes = coord.run_batch(coordinator::comparison_set(&app));
    println!("{}", Coordinator::outcome_table(&outcomes));
    // Overlay the final fields (the Figs 1/7/8 visual).
    let series: Vec<(&str, Vec<f64>)> = outcomes
        .iter()
        .map(|o| {
            let stride = o.field.len().div_ceil(64);
            (o.backend.as_str(), o.field.iter().step_by(stride).copied().collect::<Vec<f64>>())
        })
        .collect();
    let refs: Vec<(&str, &[f64])> = series.iter().map(|(n, v)| (*n, v.as_slice())).collect();
    println!("{}", ascii_plot::line_plot(&format!("{app}: final fields"), &refs, 64, 14));
    Ok(())
}

fn cmd_scenarios(args: &mut Args) -> Result<(), String> {
    let wanted = args.get("scenario");
    let profile = args.switch("profile");
    let specs: Vec<_> = SCENARIOS
        .iter()
        .filter(|s| wanted.as_deref().is_none_or(|w| w == s.name))
        .collect();
    if specs.is_empty() {
        let names: Vec<&str> = SCENARIOS.iter().map(|s| s.name).collect();
        return Err(format!("unknown scenario (have: {})", names.join(", ")));
    }
    let mut t = Table::new(vec!["scenario", "physics", "why it stresses precision"]);
    for s in &specs {
        t.row(vec![s.name.to_string(), s.physics.to_string(), s.stress.to_string()]);
    }
    println!("scenario registry ({} entries)\n{}", SCENARIOS.len(), t.render());

    if profile {
        let formats = error_sweep::profile_formats();
        let workers = coordinator::default_workers();
        for s in &specs {
            let prof = error_sweep::scenario_precision_profile(s.name, &formats, workers)?;
            let mut t = Table::new(vec!["format", "rel-err vs f64", "oflow", "uflow", "muls"]);
            for r in &prof.rows {
                t.row(vec![
                    r.fmt.to_string(),
                    format!("{:.3e}", r.rel_err),
                    r.overflows.to_string(),
                    r.underflows.to_string(),
                    r.muls.to_string(),
                ]);
            }
            // The profile already ran the f64 reference — histogram its
            // field instead of re-simulating.
            let hist = analysis::field_histogram(&prof.reference, workers);
            println!("{}: fixed-format precision profile (MulOnly)\n{}", s.name, t.render());
            println!(
                "{}: f64 field occupies {} octaves (90% bulk: {})\n",
                s.name,
                hist.occupied_octaves(),
                hist.bulk_octaves(0.9)
            );
        }
    }
    Ok(())
}

fn cmd_analyze(args: &mut Args) -> Result<(), String> {
    let n: usize = args.get_parse("n", 257usize).map_err(|e| e.to_string())?;
    let steps: usize = args.get_parse("steps", 2048usize).map_err(|e| e.to_string())?;
    let mut p = r2f2::pde::heat1d::HeatParams::default();
    p.n = n;
    p.dt = 0.25 / ((n - 1) as f64 * (n - 1) as f64);
    p.steps = steps;
    let rep = analysis::heat_distribution(&p, 4);
    println!("Fig 2(a): octave histogram of all multiplication data ({} samples)", rep.samples);
    println!("{}", ascii_plot::histogram("", &rep.overall.bars(), 48));
    let mut t = Table::new(vec!["stage", "min |v|", "max |v|", "bulk-90% octaves"]);
    for s in &rep.stages {
        t.row(vec![
            format!("{}/4", s.index + 1),
            report::sig(s.min_abs, 3),
            report::sig(s.max_abs, 3),
            s.histogram.bulk_octaves(0.9).to_string(),
        ]);
    }
    println!("Fig 2(b/c): per-stage range shift\n{}", t.render());
    Ok(())
}

fn cmd_profile(args: &mut Args) -> Result<(), String> {
    // `--scenario` selects the RAPTOR-style pilot (ROADMAP item 4); the
    // original Fig 3 study stays the default path.
    if let Some(which) = args.get("scenario") {
        return cmd_profile_pilot(&which, args);
    }
    let pairs: usize = args.get_parse("pairs", 1000usize).map_err(|e| e.to_string())?;
    let configs = config_profile::sixteen_bit_family();
    let mut t = Table::new(vec!["range", "best (profiled)", "avg err", "Eq.(1) says", "agree?"]);
    for (lo, hi) in config_profile::PAPER_RANGES {
        let pts = config_profile::profile_range(lo, hi, &configs, pairs, 42);
        let best = config_profile::best_of(&pts);
        let eq1 = config_profile::eq1_exponent_bits(hi);
        t.row(vec![
            format!("({lo}, {hi})"),
            best.fmt.to_string(),
            format!("{:.3e}", best.avg_err),
            format!("E{eq1}"),
            if best.fmt.e_w == eq1 { "yes".into() } else { "NO (paper's point)".to_string() },
        ]);
    }
    println!("Fig 3 / §3.2: profiled optimum vs the intuition formula\n{}", t.render());
    Ok(())
}

/// `r2f2 profile --scenario NAME|all [--out FILE]`: the precision
/// profiler + recommendation engine. Runs the short pilot
/// (`trace::profile`), prints each plan as a table plus greppable
/// `PROFILE |` summary rows, and optionally writes the
/// `r2f2-profile-plan/1` JSON artifact.
fn cmd_profile_pilot(which: &str, args: &mut Args) -> Result<(), String> {
    use r2f2::pde::scenario;
    use r2f2::trace::profile;
    let out = args.get("out");
    let plans = if which == "all" {
        profile::run_all_pilots(None)
    } else {
        match scenario::find(which) {
            Some(spec) => vec![profile::run_pilot(spec, None)],
            None => {
                let names: Vec<&str> = SCENARIOS.iter().map(|s| s.name).collect();
                return Err(format!(
                    "unknown scenario `{which}` (have: {}, or `all`)",
                    names.join(", ")
                ));
            }
        }
    };
    for plan in &plans {
        let mut t = Table::new(vec![
            "rung",
            "format",
            "rel-err vs f64",
            "oflow",
            "uflow",
            "modeled LUT cost",
            "clean",
        ]);
        for r in &plan.rungs {
            t.row(vec![
                r.rung.to_string(),
                r.format.to_string(),
                format!("{:.3e}", r.rel_err),
                r.overflows.to_string(),
                r.underflows.to_string(),
                format!("{:.3e}", r.modeled_cost_lut),
                if r.clean { "yes".to_string() } else { "no".to_string() },
            ]);
        }
        let rec = plan.recommended();
        println!("{}: pilot precision plan (Quick, mul-only)\n{}", plan.scenario, t.render());
        println!(
            "{}: seed the adaptive ladder at rung {} ({}) — predicted rel-err {:.3e}, \
             modeled cost {:.3e}; f64 field occupies {} octaves (90% bulk: {})\n",
            plan.scenario,
            plan.seed_rung,
            rec.format,
            rec.rel_err,
            rec.modeled_cost_lut,
            plan.occupied_octaves,
            plan.bulk90_octaves
        );
        // Machine-greppable summary row (the CI trace-smoke job tables these).
        println!(
            "PROFILE | {} | seed rung {} ({}) | rel-err {:.3e} | cost {:.3e} | \
             {} octaves (bulk90 {}) |",
            plan.scenario,
            plan.seed_rung,
            rec.format,
            rec.rel_err,
            rec.modeled_cost_lut,
            plan.occupied_octaves,
            plan.bulk90_octaves
        );
    }
    if let Some(path) = out {
        let doc = if plans.len() == 1 {
            plans[0].to_json()
        } else {
            profile::plans_json(&plans)
        };
        std::fs::write(&path, doc).map_err(|e| format!("write {path}: {e}"))?;
        println!("wrote {path} (schema r2f2-profile-plan/1)");
    }
    Ok(())
}

fn cmd_sweep(args: &mut Args) -> Result<(), String> {
    let intervals: usize = args.get_parse("intervals", 2000usize).map_err(|e| e.to_string())?;
    let pairs: usize = args.get_parse("pairs", 200usize).map_err(|e| e.to_string())?;
    let params = error_sweep::SweepParams { intervals, pairs, ..Default::default() };
    let mut t = Table::new(vec![
        "pairing",
        "avg reduction (per-interval)",
        "pooled reduction",
        "max",
        "min",
    ]);
    for (cfg, fixed) in error_sweep::paper_pairings() {
        let r = error_sweep::error_sweep(cfg, fixed, &params);
        t.row(vec![
            format!("{cfg} vs {fixed}"),
            report::pct(r.avg_reduction),
            report::pct(r.global_reduction),
            report::pct(r.max_reduction),
            report::pct(r.min_reduction),
        ]);
    }
    println!("Fig 6(g): error reduction (paper: 70.2% / 70.6% / 70.7%)\n{}", t.render());
    Ok(())
}

fn cmd_table1(_args: &mut Args) -> Result<(), String> {
    let mut t = Table::new(vec!["unit", "FF model", "FF paper", "LUT model", "LUT paper", "Lat", "II"]);
    for (fmt, row) in [
        (FpFormat::E11M52, &resource::PAPER_ROWS[0]),
        (FpFormat::E8M23, &resource::PAPER_ROWS[1]),
        (FpFormat::E5M10, &resource::PAPER_ROWS[2]),
    ] {
        let r = resource::fixed_multiplier(fmt);
        let s = datapath::fixed_schedule(fmt.total_bits());
        t.row(vec![
            row.name.to_string(),
            format!("{:.0}", r.ff),
            row.ff.to_string(),
            format!("{:.0}", r.lut),
            row.lut.to_string(),
            s.latency.to_string(),
            s.ii.to_string(),
        ]);
    }
    for (i, cfg) in R2f2Config::TABLE1.iter().enumerate() {
        let r = resource::r2f2_multiplier(*cfg);
        let s = datapath::r2f2_schedule(*cfg);
        let row = &resource::PAPER_ROWS[3 + i];
        t.row(vec![
            row.name.to_string(),
            format!("{:.0}", r.ff),
            row.ff.to_string(),
            format!("{:.0}", r.lut),
            row.lut.to_string(),
            s.latency.to_string(),
            s.ii.to_string(),
        ]);
    }
    println!("Table 1: resource cost model + datapath schedule vs paper\n{}", t.render());
    Ok(())
}

fn cmd_pipeline(args: &mut Args) -> Result<(), String> {
    let dir = args.get_or("artifacts", "artifacts");
    let steps: usize = args.get_parse("steps", 500usize).map_err(|e| e.to_string())?;
    let variant = match args.get_or("backend", "r2f2").as_str() {
        "r2f2" => "heat_step_r2f2",
        "e5m10" => "heat_step_e5m10",
        "f32" => "heat_step_f32",
        other => return Err(format!("bad pipeline backend {other}")),
    };
    let metrics = Registry::new();
    let mut rt = Runtime::new(std::path::Path::new(&dir)).map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", rt.platform());
    let runner = HeatRunner::new(&mut rt, variant, metrics.clone()).map_err(|e| e.to_string())?;
    let n = runner.n;
    let u0: Vec<f32> = (0..n)
        .map(|i| 500.0 * (2.0 * std::f32::consts::PI * i as f32 / (n - 1) as f32).sin())
        .collect();
    let out = runner.run(&u0, 0.25, steps, 2).map_err(|e| e.to_string())?;
    println!(
        "{variant}: {} steps in {:?} ({:.1} steps/s), widen={}, narrow={}",
        out.steps,
        out.elapsed,
        out.steps as f64 / out.elapsed.as_secs_f64(),
        out.widen,
        out.narrow
    );
    let ds: Vec<f64> = out.u.iter().step_by(n.div_ceil(64)).map(|&x| x as f64).collect();
    println!("{}", ascii_plot::line_plot("final field (PJRT)", &[("u", &ds)], 64, 12));
    println!("{}", metrics.render());
    Ok(())
}

fn cmd_serve(args: &mut Args) -> Result<(), String> {
    use r2f2::server::{ServeOptions, Server};
    let port: u16 = args.get_parse("port", 7272u16).map_err(|e| e.to_string())?;
    let workers: usize = args
        .get_parse("workers", coordinator::default_workers())
        .map_err(|e| e.to_string())?
        .max(1);
    let queue_cap: usize = args.get_parse("queue-cap", 64usize).map_err(|e| e.to_string())?;
    let cache_cap: usize = args.get_parse("cache-cap", 256usize).map_err(|e| e.to_string())?;
    let keepalive_ms: u64 = args.get_parse("keepalive-ms", 5000u64).map_err(|e| e.to_string())?;
    let jobs_cap: usize =
        args.get_parse("jobs-cap", 64usize).map_err(|e| e.to_string())?.max(1);
    // `wait` below never returns; surface unknown-flag typos first (usage
    // errors exit 2, matching the top-level convention).
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let server = Server::start(ServeOptions {
        port,
        workers,
        queue_cap,
        cache_cap,
        keepalive_ms,
        jobs_cap,
    })?;
    println!("r2f2 serve: listening on http://{}", server.addr());
    println!("  endpoints  POST /v1/run · POST /v1/jobs · GET /v1/jobs/:id[/result|/events]");
    println!("             POST /v1/profile · GET /v1/scenarios · GET /v1/trace · GET /healthz");
    println!("             GET /metrics (JSON; Prometheus text under Accept: text/plain)");
    println!(
        "  pool       workers={workers} queue-cap={queue_cap} cache-cap={cache_cap} \
         keepalive-ms={keepalive_ms} jobs-cap={jobs_cap}"
    );
    println!("  (foreground; stop with Ctrl-C)");
    server.wait();
    Ok(())
}

/// The mixed-scenario request set the load generator cycles through:
/// every registry scenario, two backends, both quantization modes — small
/// enough that a single request is milliseconds, repeated often enough
/// that the cache must carry most of the traffic.
fn bench_serve_bodies(smoke: bool) -> Vec<String> {
    let (heat_steps, adv_steps, wave_steps, swe_steps) =
        if smoke { (40, 50, 40, 5) } else { (200, 200, 120, 10) };
    vec![
        format!(
            "{{\"app\": \"heat\", \"backend\": \"fixed:E5M10\", \
             \"heat\": {{\"n\": 33, \"dt\": 0.000244140625, \"steps\": {heat_steps}}}}}"
        ),
        format!(
            "{{\"app\": \"heat\", \"backend\": \"r2f2:<3,9,3>\", \
             \"heat\": {{\"n\": 33, \"dt\": 0.000244140625, \"steps\": {heat_steps}}}}}"
        ),
        format!(
            "{{\"app\": \"heat\", \"backend\": \"fixed:E5M10\", \"mode\": \"full\", \
             \"heat\": {{\"n\": 33, \"dt\": 0.000244140625, \"steps\": {heat_steps}}}}}"
        ),
        format!(
            "{{\"app\": \"advection\", \"backend\": \"fixed:E5M10\", \
             \"advection\": {{\"n\": 64, \"steps\": {adv_steps}}}}}"
        ),
        format!(
            "{{\"app\": \"wave\", \"backend\": \"fixed:E5M10\", \
             \"wave\": {{\"n\": 17, \"steps\": {wave_steps}}}}}"
        ),
        format!(
            "{{\"app\": \"swe\", \"backend\": \"r2f2:<3,8,4>\", \
             \"swe\": {{\"steps\": {swe_steps}}}}}"
        ),
    ]
}

fn cmd_bench_serve(args: &mut Args) -> Result<(), String> {
    use r2f2::bench_util::{fmt_ns, percentile};
    use r2f2::server::{http, ServeOptions, Server};
    use std::time::Instant;

    let smoke = args.switch("smoke");
    let clients: usize = args
        .get_parse("clients", if smoke { 4usize } else { 8 })
        .map_err(|e| e.to_string())?
        .max(1);
    let per_client: usize = args
        .get_parse("requests", if smoke { 24usize } else { 120 })
        .map_err(|e| e.to_string())?
        .max(1);
    let workers: usize = args
        .get_parse("workers", coordinator::default_workers())
        .map_err(|e| e.to_string())?
        .max(1);
    let cache_cap: usize = args.get_parse("cache-cap", 256usize).map_err(|e| e.to_string())?;
    let default_rates: &[u64] = if smoke { &[40, 80] } else { &[50, 100, 200, 400] };
    let rates: Vec<u64> = args
        .get_list("rates", default_rates)
        .map_err(|e| e.to_string())?
        .into_iter()
        .filter(|&r| r > 0)
        .collect();
    let out_path = args.get_or("out", "BENCH_serve.json");

    let server = Server::start(ServeOptions {
        port: 0,
        workers,
        queue_cap: 2 * clients + 8,
        cache_cap,
        keepalive_ms: 5000,
        jobs_cap: 64,
    })?;
    let addr = server.addr();
    let bodies = bench_serve_bodies(smoke);
    let total_requests = clients * per_client;
    println!(
        "bench-serve: {clients} clients × {per_client} requests over {} distinct configs \
         against {addr} ({workers} workers)",
        bodies.len()
    );

    // r2f2-audit: allow(wall-clock-quarantine) — load-generator wall timing; feeds BENCH_serve.json, never a result body
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let bodies = bodies.clone();
            std::thread::spawn(move || {
                let mut latencies: Vec<f64> = Vec::with_capacity(per_client);
                let (mut hits, mut errors) = (0u64, 0u64);
                for i in 0..per_client {
                    let body = &bodies[(c + i) % bodies.len()];
                    let t = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — per-request latency sample for the bench table
                    match http::request(addr, "POST", "/v1/run", body.as_bytes()) {
                        Ok(resp) if resp.status == 200 => {
                            latencies.push(t.elapsed().as_nanos() as f64);
                            if resp.header("x-r2f2-cache") == Some("hit") {
                                hits += 1;
                            }
                        }
                        _ => errors += 1,
                    }
                }
                (latencies, hits, errors)
            })
        })
        .collect();

    let mut latencies: Vec<f64> = Vec::with_capacity(total_requests);
    let (mut hits, mut errors) = (0u64, 0u64);
    for h in handles {
        let (l, hh, e) = h.join().map_err(|_| "client thread panicked".to_string())?;
        latencies.extend(l);
        hits += hh;
        errors += e;
    }
    let wall = t0.elapsed();

    if latencies.is_empty() {
        server.shutdown();
        return Err(format!("no successful responses ({errors} errors)"));
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ok = latencies.len();

    // Workers bump `serve.served` after writing the response, so a client
    // can join before the last increment lands — drain briefly so the
    // artifact's `served` matches what was actually answered.
    let deadline = Instant::now() + std::time::Duration::from_secs(2); // r2f2-audit: allow(wall-clock-quarantine) — bounded drain timeout, not a result
    while server.metrics_snapshot().counter("serve.served") < ok as u64
        && Instant::now() < deadline // r2f2-audit: allow(wall-clock-quarantine) — drain-loop clock check against the timeout above
    {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let snapshot = server.metrics_snapshot();
    let served = snapshot.counter("serve.served");
    let rejected = snapshot.counter("serve.rejected");
    let cache = server.cache_stats();

    // ---- open-loop arrival sweep (latency under load) ----------------
    // The closed loop above measures capacity: clients wait for each
    // response, so a slow server throttles its own load generator. The
    // open loop dispatches on a fixed timer regardless of completions —
    // queueing delay shows up in the tail (and the 503 count) instead of
    // silently slowing the offered rate.
    struct OpenLoopRow {
        rate_rps: u64,
        sent: usize,
        ok: usize,
        rejected: u64,
        p50_ns: f64,
        p99_ns: f64,
        achieved_rps: f64,
    }
    let mut open_rows: Vec<OpenLoopRow> = Vec::with_capacity(rates.len());
    let window_s = if smoke { 0.5 } else { 1.0 };
    for &rate in &rates {
        let interval = std::time::Duration::from_nanos(1_000_000_000 / rate);
        let sent = ((rate as f64 * window_s).round() as usize).max(4);
        let t_rate = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — open-loop dispatch schedule; feeds the bench artifact only
        let mut open_handles = Vec::with_capacity(sent);
        for i in 0..sent {
            let target = t_rate + interval * i as u32;
            let now = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — pacing check against the dispatch schedule
            if target > now {
                std::thread::sleep(target - now);
            }
            let body = bodies[i % bodies.len()].clone();
            open_handles.push(std::thread::spawn(move || {
                let t = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — per-request latency sample for the open-loop table
                match http::request(addr, "POST", "/v1/run", body.as_bytes()) {
                    Ok(resp) if resp.status == 200 => {
                        (Some(t.elapsed().as_nanos() as f64), false)
                    }
                    Ok(resp) if resp.status == 503 => (None, true),
                    _ => (None, false),
                }
            }));
        }
        let mut lat: Vec<f64> = Vec::with_capacity(sent);
        let mut rej = 0u64;
        for h in open_handles {
            match h.join().map_err(|_| "open-loop thread panicked".to_string())? {
                (Some(ns), _) => lat.push(ns),
                (None, true) => rej += 1,
                (None, false) => {}
            }
        }
        let wall_rate = t_rate.elapsed().as_secs_f64();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        open_rows.push(OpenLoopRow {
            rate_rps: rate,
            sent,
            ok: lat.len(),
            rejected: rej,
            p50_ns: if lat.is_empty() { 0.0 } else { percentile(&lat, 50.0) },
            p99_ns: if lat.is_empty() { 0.0 } else { percentile(&lat, 99.0) },
            achieved_rps: lat.len() as f64 / wall_rate.max(1e-9),
        });
    }
    server.shutdown();
    let throughput = ok as f64 / wall.as_secs_f64();
    let p50 = percentile(&latencies, 50.0);
    let p99 = percentile(&latencies, 99.0);
    let hit_rate = hits as f64 / ok as f64;

    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests ok / sent".to_string(), format!("{ok} / {total_requests}")]);
    t.row(vec!["wall".to_string(), format!("{:.3} s", wall.as_secs_f64())]);
    t.row(vec!["throughput".to_string(), format!("{throughput:.1} req/s")]);
    t.row(vec!["latency p50".to_string(), fmt_ns(p50)]);
    t.row(vec!["latency p99".to_string(), fmt_ns(p99)]);
    t.row(vec!["cache hit rate".to_string(), report::pct(hit_rate)]);
    let hme = format!("{}/{}/{}", cache.hits, cache.misses, cache.evictions);
    t.row(vec!["cache h/m/evict".to_string(), hme]);
    t.row(vec!["guard checks".to_string(), cache.guard_checks.to_string()]);
    t.row(vec!["served (workers)".to_string(), served.to_string()]);
    t.row(vec!["rejected (503)".to_string(), rejected.to_string()]);
    t.row(vec!["client errors".to_string(), errors.to_string()]);
    println!("{}", t.render());

    let mut ot = Table::new(vec!["rate req/s", "sent", "ok", "503", "p50", "p99", "achieved"]);
    for r in &open_rows {
        ot.row(vec![
            r.rate_rps.to_string(),
            r.sent.to_string(),
            r.ok.to_string(),
            r.rejected.to_string(),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p99_ns),
            format!("{:.1} req/s", r.achieved_rps),
        ]);
    }
    println!("open-loop latency under load ({window_s} s per rate)\n{}", ot.render());

    // Machine-greppable summary rows (the CI serve-smoke job tables these).
    println!(
        "SERVE | {clients}×{per_client} req, {workers} workers | {throughput:.1} req/s | \
         p50 {} p99 {} | {} hits, {rejected} rejected |",
        fmt_ns(p50),
        fmt_ns(p99),
        report::pct(hit_rate)
    );
    for r in &open_rows {
        println!(
            "SERVE | open-loop {} rps | {} ok / {} sent | p50 {} p99 {} | {} rejected | \
             achieved {:.1} rps |",
            r.rate_rps,
            r.ok,
            r.sent,
            fmt_ns(r.p50_ns),
            fmt_ns(r.p99_ns),
            r.rejected,
            r.achieved_rps
        );
    }

    let open_json: Vec<String> = open_rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"rate_rps\": {}, \"sent\": {}, \"ok\": {}, \"rejected\": {}, \
                 \"p50_ns\": {:.3}, \"p99_ns\": {:.3}, \"achieved_rps\": {:.3}}}",
                r.rate_rps, r.sent, r.ok, r.rejected, r.p50_ns, r.p99_ns, r.achieved_rps
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"schema\": \"r2f2-bench-serve/2\",\n  \"smoke\": {smoke},\n  \
         \"clients\": {clients},\n  \"requests_per_client\": {per_client},\n  \
         \"requests\": {total_requests},\n  \"distinct_configs\": {},\n  \
         \"workers\": {workers},\n  \"wall_s\": {:.6},\n  \
         \"throughput_rps\": {:.3},\n  \"p50_ns\": {:.3},\n  \"p99_ns\": {:.3},\n  \
         \"cache_hit_rate\": {:.6},\n  \"cache_hits\": {},\n  \"cache_misses\": {},\n  \
         \"cache_evictions\": {},\n  \"guard_checks\": {},\n  \"served\": {served},\n  \
         \"rejected\": {rejected},\n  \"errors\": {errors},\n  \
         \"open_loop\": [\n{}\n  ]\n}}\n",
        bodies.len(),
        wall.as_secs_f64(),
        throughput,
        p50,
        p99,
        hit_rate,
        cache.hits,
        cache.misses,
        cache.evictions,
        cache.guard_checks,
        open_json.join(",\n"),
    );
    std::fs::write(&out_path, json).map_err(|e| format!("write {out_path}: {e}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_audit(args: &mut Args) -> Result<(), String> {
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => audit::find_root()?,
    };
    let rule = args.get("rule");
    // `--json` is a declared switch, so `audit --json out.json` parses as
    // the switch plus a positional; `--json=out.json` lands in the option
    // map. Accept both, plus canonical `--out`; a bare `--json` streams
    // the report to stdout.
    let json_opt = args.get("json").or_else(|| args.get("out"));
    let json_switch = args.switch("json");
    let json_positional = if json_switch { args.positional.first().cloned() } else { None };
    let json_path = json_opt.or(json_positional);
    let snapshot = args.get("snapshot");

    let generator = match &rule {
        Some(id) => format!("r2f2 audit --rule {id}"),
        None => "r2f2 audit".to_string(),
    };
    let report = audit::run(&audit::Options { root, rule })?;

    let json_to_stdout = json_switch && json_path.is_none();
    if json_to_stdout {
        print!("{}", report.to_json(&generator));
    } else {
        print!("{}", report.render());
    }
    if let Some(path) = &json_path {
        std::fs::write(path, report.to_json(&generator))
            .map_err(|e| format!("write {path}: {e}"))?;
        if !json_to_stdout {
            println!("wrote {path}");
        }
    }
    if let Some(path) = &snapshot {
        // The snapshot generator is fixed so the emitted bytes do not
        // depend on where CI writes the file (it is diffed against the
        // committed rust/AUDIT_smoke.json).
        std::fs::write(path, report.snapshot_json(&generator))
            .map_err(|e| format!("write {path}: {e}"))?;
        if !json_to_stdout {
            println!("wrote {path}");
        }
    }
    if report.findings.is_empty() {
        Ok(())
    } else {
        Err(format!("{} unsuppressed audit finding(s)", report.findings.len()))
    }
}
