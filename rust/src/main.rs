//! `r2f2` — the Layer-3 command-line driver.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!   run       one simulation experiment (TOML config or flags)
//!   compare   f64 / f32 / half / R2F2 side by side (Figs 1, 7, 8)
//!   analyze   data-distribution study (Fig 2)
//!   profile   precision-configuration profiling + Eq.(1) check (Fig 3)
//!   sweep     multiplication-accuracy sweep (Fig 6)
//!   table1    resource + latency model (Table 1)
//!   pipeline  three-layer run: AOT artifacts via PJRT (the e2e path)

use r2f2::analysis;
use r2f2::cli::Args;
use r2f2::config::{parse_backend, ExperimentConfig, APPS};
use r2f2::coordinator::{self, Coordinator};
use r2f2::metrics::Registry;
use r2f2::pde::init::HeatInit;
use r2f2::pde::scenario::SCENARIOS;
use r2f2::pde::QuantMode;
use r2f2::r2f2core::{datapath, resource, R2f2Config};
use r2f2::report::{self, ascii_plot, Table};
use r2f2::runtime::{HeatRunner, Runtime};
use r2f2::softfloat::FpFormat;
use r2f2::sweep::{config_profile, error_sweep};

const SWITCHES: &[&str] = &["verbose", "json", "help", "full", "profile"];

fn main() {
    let mut args = match Args::from_env(SWITCHES) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "run" => cmd_run(&mut args),
        "compare" => cmd_compare(&mut args),
        "scenarios" => cmd_scenarios(&mut args),
        "analyze" => cmd_analyze(&mut args),
        "profile" => cmd_profile(&mut args),
        "sweep" => cmd_sweep(&mut args),
        "table1" => cmd_table1(&mut args),
        "pipeline" => cmd_pipeline(&mut args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result.and_then(|()| args.finish().map_err(|e| e.to_string())) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "r2f2 — runtime reconfigurable floating-point precision (paper reproduction)

USAGE: r2f2 <command> [options]

COMMANDS
  run       --config FILE | --app heat|swe|advection|wave --backend SPEC
            [--mode mul-only|full] [--n N --steps S] — run one experiment
            vs the f64 reference
  compare   --app heat|swe|advection|wave — f64/f32/half/R2F2 comparison
            table (Figs 1/7/8)
  scenarios [--scenario NAME] [--profile] — list the scenario registry;
            with --profile, per-scenario fixed-format precision profiles
  analyze   [--n N --steps S] — Fig 2 data-distribution study
  profile   [--pairs P] — Fig 3 precision profiling + Eq.(1) check
  sweep     [--intervals I --pairs P] — Fig 6 accuracy sweep
  table1    — Table 1 resource & latency model vs paper
  pipeline  [--artifacts DIR --steps S --backend r2f2|e5m10|f32] — run the
            heat simulation through the AOT artifacts on PJRT (three-layer)

BACKEND SPECS: f64 | f32 | fixed:E5M10 (any ExMy) | r2f2:<3,9,3> (any <EB,MB,FX>)"
    );
}

fn experiment_from_args(args: &mut Args) -> Result<ExperimentConfig, String> {
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
        return ExperimentConfig::from_toml(&text);
    }
    let mut cfg = ExperimentConfig::default();
    cfg.app = args.get_or("app", "heat");
    if !APPS.contains(&cfg.app.as_str()) {
        return Err(format!("app must be {}, got `{}`", APPS.join("|"), cfg.app));
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = parse_backend(&b)?;
    }
    match args.get_or("mode", "mul-only").as_str() {
        "mul-only" => cfg.mode = QuantMode::MulOnly,
        "full" => cfg.mode = QuantMode::Full,
        other => return Err(format!("bad mode {other}")),
    }
    if let Some(n) = args.get("n") {
        let n: usize = n.parse().map_err(|_| "bad --n")?;
        cfg.heat.n = n;
        cfg.heat.dt = 0.25 / ((n - 1) as f64 * (n - 1) as f64);
        cfg.swe.n = n;
        // Keep the scenario defaults' stability numbers at the new size.
        cfg.advection.dt = cfg.advection.dt * cfg.advection.n as f64 / n as f64;
        cfg.advection.n = n;
        cfg.wave.dt = cfg.wave.dt * (cfg.wave.n - 1) as f64 / (n - 1) as f64;
        cfg.wave.n = n;
    }
    if let Some(s) = args.get("steps") {
        let s: usize = s.parse().map_err(|_| "bad --steps")?;
        cfg.heat.steps = s;
        cfg.swe.steps = s;
        cfg.advection.steps = s;
        cfg.wave.steps = s;
    }
    if let Some(init) = args.get("init") {
        cfg.heat.init = match init.as_str() {
            "sin" => HeatInit::sin_default(),
            "exp" => HeatInit::exp_default(),
            other => return Err(format!("bad init {other}")),
        };
    }
    Ok(cfg)
}

fn cmd_run(args: &mut Args) -> Result<(), String> {
    let cfg = experiment_from_args(args)?;
    let metrics = Registry::new();
    let outcome = coordinator::run_experiment(&cfg, &metrics);
    println!("{}", Coordinator::outcome_table(std::slice::from_ref(&outcome)));
    if args.switch("verbose") {
        let ds: Vec<f64> = outcome.field.iter().step_by(outcome.field.len().div_ceil(64)).copied().collect();
        println!("{}", ascii_plot::line_plot("final field", &[("u", &ds)], 64, 12));
        println!("{}", metrics.render());
    }
    if args.switch("json") {
        println!("{}", metrics.to_json());
    }
    Ok(())
}

fn cmd_compare(args: &mut Args) -> Result<(), String> {
    let app = args.get_or("app", "heat");
    if !APPS.contains(&app.as_str()) {
        return Err(format!("app must be {}, got `{app}`", APPS.join("|")));
    }
    let coord = Coordinator::default();
    let outcomes = coord.run_batch(coordinator::comparison_set(&app));
    println!("{}", Coordinator::outcome_table(&outcomes));
    // Overlay the final fields (the Figs 1/7/8 visual).
    let series: Vec<(&str, Vec<f64>)> = outcomes
        .iter()
        .map(|o| {
            let stride = o.field.len().div_ceil(64);
            (o.backend.as_str(), o.field.iter().step_by(stride).copied().collect::<Vec<f64>>())
        })
        .collect();
    let refs: Vec<(&str, &[f64])> = series.iter().map(|(n, v)| (*n, v.as_slice())).collect();
    println!("{}", ascii_plot::line_plot(&format!("{app}: final fields"), &refs, 64, 14));
    Ok(())
}

fn cmd_scenarios(args: &mut Args) -> Result<(), String> {
    let wanted = args.get("scenario");
    let profile = args.switch("profile");
    let specs: Vec<_> = SCENARIOS
        .iter()
        .filter(|s| wanted.as_deref().is_none_or(|w| w == s.name))
        .collect();
    if specs.is_empty() {
        let names: Vec<&str> = SCENARIOS.iter().map(|s| s.name).collect();
        return Err(format!("unknown scenario (have: {})", names.join(", ")));
    }
    let mut t = Table::new(vec!["scenario", "physics", "why it stresses precision"]);
    for s in &specs {
        t.row(vec![s.name.to_string(), s.physics.to_string(), s.stress.to_string()]);
    }
    println!("scenario registry ({} entries)\n{}", SCENARIOS.len(), t.render());

    if profile {
        let formats = error_sweep::profile_formats();
        let workers = coordinator::default_workers();
        for s in &specs {
            let prof = error_sweep::scenario_precision_profile(s.name, &formats, workers)?;
            let mut t = Table::new(vec!["format", "rel-err vs f64", "oflow", "uflow", "muls"]);
            for r in &prof.rows {
                t.row(vec![
                    r.fmt.to_string(),
                    format!("{:.3e}", r.rel_err),
                    r.overflows.to_string(),
                    r.underflows.to_string(),
                    r.muls.to_string(),
                ]);
            }
            // The profile already ran the f64 reference — histogram its
            // field instead of re-simulating.
            let hist = analysis::field_histogram(&prof.reference, workers);
            println!("{}: fixed-format precision profile (MulOnly)\n{}", s.name, t.render());
            println!(
                "{}: f64 field occupies {} octaves (90% bulk: {})\n",
                s.name,
                hist.occupied_octaves(),
                hist.bulk_octaves(0.9)
            );
        }
    }
    Ok(())
}

fn cmd_analyze(args: &mut Args) -> Result<(), String> {
    let n: usize = args.get_parse("n", 257usize).map_err(|e| e.to_string())?;
    let steps: usize = args.get_parse("steps", 2048usize).map_err(|e| e.to_string())?;
    let mut p = r2f2::pde::heat1d::HeatParams::default();
    p.n = n;
    p.dt = 0.25 / ((n - 1) as f64 * (n - 1) as f64);
    p.steps = steps;
    let rep = analysis::heat_distribution(&p, 4);
    println!("Fig 2(a): octave histogram of all multiplication data ({} samples)", rep.samples);
    println!("{}", ascii_plot::histogram("", &rep.overall.bars(), 48));
    let mut t = Table::new(vec!["stage", "min |v|", "max |v|", "bulk-90% octaves"]);
    for s in &rep.stages {
        t.row(vec![
            format!("{}/4", s.index + 1),
            report::sig(s.min_abs, 3),
            report::sig(s.max_abs, 3),
            s.histogram.bulk_octaves(0.9).to_string(),
        ]);
    }
    println!("Fig 2(b/c): per-stage range shift\n{}", t.render());
    Ok(())
}

fn cmd_profile(args: &mut Args) -> Result<(), String> {
    let pairs: usize = args.get_parse("pairs", 1000usize).map_err(|e| e.to_string())?;
    let configs = config_profile::sixteen_bit_family();
    let mut t = Table::new(vec!["range", "best (profiled)", "avg err", "Eq.(1) says", "agree?"]);
    for (lo, hi) in config_profile::PAPER_RANGES {
        let pts = config_profile::profile_range(lo, hi, &configs, pairs, 42);
        let best = config_profile::best_of(&pts);
        let eq1 = config_profile::eq1_exponent_bits(hi);
        t.row(vec![
            format!("({lo}, {hi})"),
            best.fmt.to_string(),
            format!("{:.3e}", best.avg_err),
            format!("E{eq1}"),
            if best.fmt.e_w == eq1 { "yes".into() } else { "NO (paper's point)".to_string() },
        ]);
    }
    println!("Fig 3 / §3.2: profiled optimum vs the intuition formula\n{}", t.render());
    Ok(())
}

fn cmd_sweep(args: &mut Args) -> Result<(), String> {
    let intervals: usize = args.get_parse("intervals", 2000usize).map_err(|e| e.to_string())?;
    let pairs: usize = args.get_parse("pairs", 200usize).map_err(|e| e.to_string())?;
    let params = error_sweep::SweepParams { intervals, pairs, ..Default::default() };
    let mut t = Table::new(vec![
        "pairing",
        "avg reduction (per-interval)",
        "pooled reduction",
        "max",
        "min",
    ]);
    for (cfg, fixed) in error_sweep::paper_pairings() {
        let r = error_sweep::error_sweep(cfg, fixed, &params);
        t.row(vec![
            format!("{cfg} vs {fixed}"),
            report::pct(r.avg_reduction),
            report::pct(r.global_reduction),
            report::pct(r.max_reduction),
            report::pct(r.min_reduction),
        ]);
    }
    println!("Fig 6(g): error reduction (paper: 70.2% / 70.6% / 70.7%)\n{}", t.render());
    Ok(())
}

fn cmd_table1(_args: &mut Args) -> Result<(), String> {
    let mut t = Table::new(vec!["unit", "FF model", "FF paper", "LUT model", "LUT paper", "Lat", "II"]);
    for (fmt, row) in [
        (FpFormat::E11M52, &resource::PAPER_ROWS[0]),
        (FpFormat::E8M23, &resource::PAPER_ROWS[1]),
        (FpFormat::E5M10, &resource::PAPER_ROWS[2]),
    ] {
        let r = resource::fixed_multiplier(fmt);
        let s = datapath::fixed_schedule(fmt.total_bits());
        t.row(vec![
            row.name.to_string(),
            format!("{:.0}", r.ff),
            row.ff.to_string(),
            format!("{:.0}", r.lut),
            row.lut.to_string(),
            s.latency.to_string(),
            s.ii.to_string(),
        ]);
    }
    for (i, cfg) in R2f2Config::TABLE1.iter().enumerate() {
        let r = resource::r2f2_multiplier(*cfg);
        let s = datapath::r2f2_schedule(*cfg);
        let row = &resource::PAPER_ROWS[3 + i];
        t.row(vec![
            row.name.to_string(),
            format!("{:.0}", r.ff),
            row.ff.to_string(),
            format!("{:.0}", r.lut),
            row.lut.to_string(),
            s.latency.to_string(),
            s.ii.to_string(),
        ]);
    }
    println!("Table 1: resource cost model + datapath schedule vs paper\n{}", t.render());
    Ok(())
}

fn cmd_pipeline(args: &mut Args) -> Result<(), String> {
    let dir = args.get_or("artifacts", "artifacts");
    let steps: usize = args.get_parse("steps", 500usize).map_err(|e| e.to_string())?;
    let variant = match args.get_or("backend", "r2f2").as_str() {
        "r2f2" => "heat_step_r2f2",
        "e5m10" => "heat_step_e5m10",
        "f32" => "heat_step_f32",
        other => return Err(format!("bad pipeline backend {other}")),
    };
    let metrics = Registry::new();
    let mut rt = Runtime::new(std::path::Path::new(&dir)).map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", rt.platform());
    let runner = HeatRunner::new(&mut rt, variant, metrics.clone()).map_err(|e| e.to_string())?;
    let n = runner.n;
    let u0: Vec<f32> = (0..n)
        .map(|i| 500.0 * (2.0 * std::f32::consts::PI * i as f32 / (n - 1) as f32).sin())
        .collect();
    let out = runner.run(&u0, 0.25, steps, 2).map_err(|e| e.to_string())?;
    println!(
        "{variant}: {} steps in {:?} ({:.1} steps/s), widen={}, narrow={}",
        out.steps,
        out.elapsed,
        out.steps as f64 / out.elapsed.as_secs_f64(),
        out.widen,
        out.narrow
    );
    let ds: Vec<f64> = out.u.iter().step_by(n.div_ceil(64)).map(|&x| x as f64).collect();
    println!("{}", ascii_plot::line_plot("final field (PJRT)", &[("u", &ds)], 64, 12));
    println!("{}", metrics.render());
    Ok(())
}
