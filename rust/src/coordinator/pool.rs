//! Work distribution across OS threads (no tokio in this environment; the
//! workloads are CPU-bound simulations, so a scoped thread pool with an
//! atomic work index is the right shape anyway).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Parallel map preserving input order: runs `f` over `items` on up to
/// `workers` threads. `f` must be `Sync` (shared immutably across workers).
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("item taken twice");
                let r = f(item);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });

    out.into_iter().map(|m| m.into_inner().unwrap().expect("missing result")).collect()
}

/// Default worker count: the `R2F2_WORKERS` environment override when set
/// (clamped to ≥ 1; non-numeric values are ignored), else available
/// parallelism capped at 8 (experiment fan-out is memory-light but the
/// softfloat sweeps saturate quickly). The override is what CI and the
/// scenario-matrix suite pin worker counts with, and what sizes the
/// `r2f2 serve` pool on shared hosts — every sharded computation in the
/// crate is worker-count-invariant by contract, so the override can only
/// change speed, never results.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("R2F2_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(items, 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_sequential() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn actually_parallel() {
        // With 4 workers, 4 sleeps of 50ms should take well under 200ms.
        let t = std::time::Instant::now();
        let _ = parallel_map(vec![(); 4], 4, |_| {
            std::thread::sleep(std::time::Duration::from_millis(50));
        });
        assert!(t.elapsed() < std::time::Duration::from_millis(160), "{:?}", t.elapsed());
    }

    #[test]
    fn more_items_than_workers() {
        let out = parallel_map((0..1000).collect::<Vec<_>>(), 3, |x| x % 7);
        assert_eq!(out.len(), 1000);
        assert_eq!(out[999], 999 % 7);
    }

    #[test]
    fn workers_env_override_clamped_and_validated() {
        // The env is process-global: serialize against other readers and
        // put the caller's original value back before releasing the guard.
        // (Concurrent lib tests can still observe the transient values;
        // that only moves worker counts, and every sharded computation is
        // worker-count-invariant by contract.)
        static ENV_GUARD: Mutex<()> = Mutex::new(());
        let _g = ENV_GUARD.lock().unwrap();
        let original = std::env::var("R2F2_WORKERS").ok();
        std::env::remove_var("R2F2_WORKERS");
        let base = default_workers();
        assert!(base >= 1);

        std::env::set_var("R2F2_WORKERS", "3");
        assert_eq!(default_workers(), 3);
        std::env::set_var("R2F2_WORKERS", " 12 ");
        assert_eq!(default_workers(), 12, "whitespace-tolerant");
        std::env::set_var("R2F2_WORKERS", "0");
        assert_eq!(default_workers(), 1, "clamped to >= 1");
        std::env::set_var("R2F2_WORKERS", "not-a-number");
        assert_eq!(default_workers(), base, "garbage is ignored");

        match original {
            Some(v) => std::env::set_var("R2F2_WORKERS", v),
            None => std::env::remove_var("R2F2_WORKERS"),
        }
    }
}
