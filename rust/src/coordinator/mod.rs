//! Experiment coordinator: schedules simulation/sweep jobs across a worker
//! pool, aggregates outcomes and metrics. The paper's contribution lives at
//! L1/L2 (the multiplier), so this layer is deliberately thin — a job
//! system, not a serving stack — but it is what every example, bench and
//! the CLI drive.

pub mod job;
pub mod pool;

pub use job::{comparison_set, run_experiment, run_experiment_traced, Outcome};
pub use pool::{default_workers, parallel_map};

use crate::config::ExperimentConfig;
use crate::metrics::Registry;

/// The coordinator: a worker pool plus a shared metrics registry.
pub struct Coordinator {
    pub workers: usize,
    pub metrics: Registry,
}

impl Default for Coordinator {
    fn default() -> Self {
        Coordinator { workers: default_workers(), metrics: Registry::new() }
    }
}

impl Coordinator {
    pub fn new(workers: usize) -> Coordinator {
        Coordinator { workers: workers.max(1), metrics: Registry::new() }
    }

    /// Run a batch of experiments in parallel; outcomes keep input order.
    pub fn run_batch(&self, configs: Vec<ExperimentConfig>) -> Vec<Outcome> {
        let metrics = &self.metrics;
        parallel_map(configs, self.workers, |cfg| run_experiment(&cfg, metrics))
    }

    /// Render a comparison table of outcomes.
    pub fn outcome_table(outcomes: &[Outcome]) -> String {
        let mut t = crate::report::Table::new(vec![
            "experiment",
            "backend",
            "rel-err vs f64",
            "muls",
            "widen/narrow",
            "oflow/uflow",
            "wall",
        ]);
        for o in outcomes {
            t.row(vec![
                o.title.clone(),
                o.backend.clone(),
                format!("{:.3e}", o.rel_err_vs_f64),
                o.muls.to_string(),
                o.adjustments.map(|(w, n)| format!("{w}/{n}")).unwrap_or_else(|| "-".into()),
                o.range_events.map(|(a, b)| format!("{a}/{b}")).unwrap_or_else(|| "-".into()),
                format!("{:.1?}", o.wall),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_backend;
    use crate::pde::init::HeatInit;

    fn quick(backend: &str) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = "heat".into();
        c.backend = parse_backend(backend).unwrap();
        c.title = backend.to_string();
        c.heat.n = 65;
        c.heat.dt = 0.25 / (64.0 * 64.0);
        c.heat.steps = 100;
        c.heat.init = HeatInit::sin_default();
        c
    }

    #[test]
    fn batch_runs_in_parallel_and_keeps_order() {
        let c = Coordinator::new(4);
        let outcomes =
            c.run_batch(vec![quick("f64"), quick("f32"), quick("fixed:E5M10"), quick("r2f2:<3,9,3>")]);
        assert_eq!(outcomes.len(), 4);
        assert_eq!(outcomes[0].backend, "f64");
        assert_eq!(outcomes[3].backend, "r2f2:<3,9,3>");
        assert_eq!(c.metrics.counter("jobs.completed"), 4);
    }

    #[test]
    fn table_renders_all_rows() {
        let c = Coordinator::new(2);
        let outcomes = c.run_batch(vec![quick("f64"), quick("r2f2:<3,9,3>")]);
        let table = Coordinator::outcome_table(&outcomes);
        assert!(table.contains("f64"));
        assert!(table.contains("r2f2:<3,9,3>"));
        assert!(table.lines().count() >= 4);
    }
}
