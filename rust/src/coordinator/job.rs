//! Experiment jobs: a typed unit of work the coordinator schedules, and the
//! outcome record the report layer consumes.

use crate::config::{BackendSpec, ExperimentConfig};
use crate::metrics::Registry;
use crate::pde::{self, decomp, swe2d, QuantMode};
use crate::trace::{Clock, Collector, Value};
use std::time::Instant;

/// Outcome of one simulation experiment.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub title: String,
    pub app: String,
    pub backend: String,
    pub mode: QuantMode,
    /// Relative L2 error of the final field vs the f64 ground truth run
    /// with identical parameters.
    pub rel_err_vs_f64: f64,
    /// Multiplications issued through the backend.
    pub muls: u64,
    /// R2F2 adjustment events, if applicable: (widen, narrow).
    pub adjustments: Option<(u64, u64)>,
    /// Fixed-format range events, if applicable: (overflow, underflow).
    pub range_events: Option<(u64, u64)>,
    pub wall: std::time::Duration,
    /// Final field for figure rendering.
    pub field: Vec<f64>,
}

/// Run one experiment (plus its f64 reference) natively.
///
/// `cfg.shards > 1` routes the run through the domain-decomposition
/// adapters (`pde::decomp`, DESIGN.md §13) — bit-identical results, with
/// each step spread across the worker pool. The f64 reference runs sharded
/// too (also bit-identical either way, but the wall-clock win is the point
/// of admitting shard-scaled grids).
pub fn run_experiment(cfg: &ExperimentConfig, metrics: &Registry) -> Outcome {
    let t0 = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — Outcome.wall is display-only; outcome_json (the cache body) excludes it
    let shards = cfg.shards.max(1);
    let (field, reference, muls, adjustments, range_events) = match cfg.app.as_str() {
        "heat" => {
            let mut be = cfg.backend.build();
            let res = decomp::run_heat(&cfg.heat, be.as_mut(), cfg.mode, shards);
            let reference =
                decomp::run_heat(&cfg.heat, &mut pde::F64Arith, QuantMode::MulOnly, shards);
            (
                res.u,
                reference.u,
                res.muls,
                res.r2f2_stats.map(|s| (s.overflow_adjustments, s.redundancy_adjustments)),
                res.range_events.map(|e| (e.overflows, e.underflows)),
            )
        }
        "swe" => {
            let mut be = cfg.backend.build();
            let res = decomp::run_swe(
                &cfg.swe,
                be.as_mut(),
                swe2d::QuantScope::UxFluxOnly,
                QuantMode::MulOnly,
                shards,
            );
            let reference = decomp::run_swe(
                &cfg.swe,
                &mut pde::F64Arith,
                swe2d::QuantScope::UxFluxOnly,
                QuantMode::MulOnly,
                shards,
            );
            (
                res.h,
                reference.h,
                res.muls,
                res.r2f2_stats.map(|s| (s.overflow_adjustments, s.redundancy_adjustments)),
                res.range_events.map(|e| (e.overflows, e.underflows)),
            )
        }
        "advection" => {
            let mut be = cfg.backend.build();
            let res = decomp::run_advection(&cfg.advection, be.as_mut(), cfg.mode, shards);
            let reference = decomp::run_advection(
                &cfg.advection,
                &mut pde::F64Arith,
                QuantMode::MulOnly,
                shards,
            );
            (
                res.u,
                reference.u,
                res.muls,
                res.r2f2_stats.map(|s| (s.overflow_adjustments, s.redundancy_adjustments)),
                res.range_events.map(|e| (e.overflows, e.underflows)),
            )
        }
        "wave" => {
            let mut be = cfg.backend.build();
            let res = decomp::run_wave(&cfg.wave, be.as_mut(), cfg.mode, shards);
            let reference =
                decomp::run_wave(&cfg.wave, &mut pde::F64Arith, QuantMode::MulOnly, shards);
            (
                res.u,
                reference.u,
                res.muls,
                res.r2f2_stats.map(|s| (s.overflow_adjustments, s.redundancy_adjustments)),
                res.range_events.map(|e| (e.overflows, e.underflows)),
            )
        }
        other => panic!("unknown app {other}"),
    };
    let rel = pde::rel_l2(&field, &reference);
    metrics.inc("jobs.completed", 1);
    metrics.inc("jobs.muls", muls);
    Outcome {
        title: cfg.title.clone(),
        app: cfg.app.clone(),
        backend: cfg.backend.name(),
        mode: cfg.mode,
        rel_err_vs_f64: rel,
        muls,
        adjustments,
        range_events,
        wall: t0.elapsed(),
        field,
    }
}

/// [`run_experiment`] with a `run.start`/`run.done` span pair on lane
/// `run/<app>` when a trace collector is given. Tracing cannot perturb
/// the run: the events are recorded strictly before and after the solver
/// executes, their content is built from the deterministic outcome
/// (logical clock: final step count and mul counter), and the wall
/// duration attached to `run.done` reuses `Outcome.wall` — the already
/// sanctioned display-only measurement above, excluded from trace
/// content like it is from the cache body.
pub fn run_experiment_traced(
    cfg: &ExperimentConfig,
    metrics: &Registry,
    trace: Option<&Collector>,
) -> Outcome {
    let lane = format!("run/{}", cfg.app);
    if let Some(c) = trace {
        c.record(
            &lane,
            "run.start",
            Clock::zero(),
            vec![
                ("app".into(), Value::Str(cfg.app.clone())),
                ("backend".into(), Value::Str(cfg.backend.name())),
                ("shards".into(), Value::U64(cfg.shards.max(1) as u64)),
            ],
        );
    }
    let outcome = run_experiment(cfg, metrics);
    if let Some(c) = trace {
        let steps = match cfg.app.as_str() {
            "heat" => cfg.heat.steps,
            "swe" => cfg.swe.steps,
            "advection" => cfg.advection.steps,
            "wave" => cfg.wave.steps,
            _ => 0,
        };
        let (widen, narrow) = outcome.adjustments.unwrap_or((0, 0));
        let (overflows, underflows) = outcome.range_events.unwrap_or((0, 0));
        c.record_wall(
            &lane,
            "run.done",
            Clock { step: steps as u64, epoch: 0, muls: outcome.muls },
            vec![
                ("backend".into(), Value::Str(outcome.backend.clone())),
                ("rel_err_vs_f64".into(), Value::F64(outcome.rel_err_vs_f64)),
                ("widen".into(), Value::U64(widen)),
                ("narrow".into(), Value::U64(narrow)),
                ("overflows".into(), Value::U64(overflows)),
                ("underflows".into(), Value::U64(underflows)),
                ("n".into(), Value::U64(outcome.field.len() as u64)),
            ],
            outcome.wall.as_nanos() as u64,
        );
    }
    outcome
}

/// Standard comparison set for an app: f64, f32, fixed half, R2F2-16.
pub fn comparison_set(app: &str) -> Vec<ExperimentConfig> {
    use crate::r2f2core::R2f2Config;
    use crate::softfloat::FpFormat;
    let mk = |backend: BackendSpec, title: &str| {
        let mut c = ExperimentConfig::default();
        c.app = app.to_string();
        c.backend = backend;
        c.title = title.to_string();
        c
    };
    let r2f2 = if app == "swe" { R2f2Config::C16_384 } else { R2f2Config::C16_393 };
    vec![
        mk(BackendSpec::F64, &format!("{app}/f64")),
        mk(BackendSpec::F32, &format!("{app}/f32")),
        mk(BackendSpec::Fixed(FpFormat::E5M10), &format!("{app}/half")),
        mk(BackendSpec::R2f2(r2f2), &format!("{app}/r2f2")),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_backend;
    use crate::pde::init::HeatInit;

    fn quick_heat(backend: &str) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = "heat".into();
        c.backend = parse_backend(backend).unwrap();
        c.heat.n = 65;
        c.heat.dt = 0.25 / (64.0 * 64.0);
        c.heat.steps = 200;
        c.heat.init = HeatInit::sin_default();
        c
    }

    #[test]
    fn heat_outcome_sane() {
        let m = Registry::new();
        let o = run_experiment(&quick_heat("r2f2:<3,9,3>"), &m);
        assert_eq!(o.app, "heat");
        assert_eq!(o.muls, 3 * 63 * 200);
        assert!(o.rel_err_vs_f64 < 0.01, "{}", o.rel_err_vs_f64);
        assert!(o.adjustments.is_some());
        assert_eq!(m.counter("jobs.completed"), 1);
    }

    #[test]
    fn f64_experiment_has_zero_error() {
        let m = Registry::new();
        let o = run_experiment(&quick_heat("f64"), &m);
        assert_eq!(o.rel_err_vs_f64, 0.0);
    }

    #[test]
    fn comparison_set_covers_backends() {
        let set = comparison_set("heat");
        let names: Vec<String> = set.iter().map(|c| c.backend.name()).collect();
        assert_eq!(names, vec!["f64", "f32", "fixed:E5M10", "r2f2:<3,9,3>"]);
    }

    #[test]
    fn advection_and_wave_quick_outcomes() {
        let m = Registry::new();
        let mut c = ExperimentConfig::default();
        c.app = "advection".into();
        c.backend = parse_backend("fixed:E5M10").unwrap();
        c.advection.n = 64;
        c.advection.steps = 50;
        let o = run_experiment(&c, &m);
        assert_eq!(o.muls, 64 * 50);
        assert!(o.rel_err_vs_f64 < 0.05, "{}", o.rel_err_vs_f64);

        let mut c = ExperimentConfig::default();
        c.app = "wave".into();
        c.backend = parse_backend("fixed:E5M10").unwrap();
        c.wave.n = 17;
        c.wave.dt = 0.5 / 16.0;
        c.wave.steps = 40;
        let o = run_experiment(&c, &m);
        assert_eq!(o.muls, 3 * 15 * 15 * 40);
        assert!(o.rel_err_vs_f64 < 0.2, "{}", o.rel_err_vs_f64);
        assert_eq!(m.counter("jobs.completed"), 2);
    }

    #[test]
    fn sharded_experiment_is_bit_identical_to_unsharded() {
        let m = Registry::new();
        let mut base = quick_heat("fixed:E5M10");
        base.heat.steps = 60;
        let o1 = run_experiment(&base, &m);
        for shards in [3usize, 7] {
            let mut c = base.clone();
            c.shards = shards;
            let o = run_experiment(&c, &m);
            assert_eq!(o.muls, o1.muls, "shards={shards}");
            assert_eq!(o.range_events, o1.range_events, "shards={shards}");
            let bits = |f: &[f64]| f.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&o.field), bits(&o1.field), "shards={shards}");
            assert_eq!(o.rel_err_vs_f64.to_bits(), o1.rel_err_vs_f64.to_bits());
        }
    }

    #[test]
    fn traced_run_records_spans_without_perturbing_the_outcome() {
        let m = Registry::new();
        let cfg = quick_heat("fixed:E5M10");
        let plain = run_experiment(&cfg, &m);
        let c = Collector::new();
        let traced = run_experiment_traced(&cfg, &m, Some(&c));
        let bits = |f: &[f64]| f.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&traced.field), bits(&plain.field), "tracing must not touch results");
        assert_eq!(traced.muls, plain.muls);
        let events = c.snapshot();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].name, "run.start");
        assert_eq!(events[1].name, "run.done");
        assert!(events[0].lane.starts_with("run/heat"));
        assert_eq!(events[1].clock.muls, traced.muls);
        assert!(events[1].wall_ns.is_some(), "run.done carries the sanctioned wall attachment");
        assert!(
            run_experiment_traced(&cfg, &m, None).muls == plain.muls,
            "None collector is the untraced path"
        );
    }

    #[test]
    fn swe_quick_outcome() {
        let m = Registry::new();
        let mut c = ExperimentConfig::default();
        c.app = "swe".into();
        c.backend = parse_backend("r2f2:<3,8,4>").unwrap();
        c.swe.steps = 5;
        let o = run_experiment(&c, &m);
        assert_eq!(o.muls, 6 * 16 * 16 * 5);
        assert!(o.rel_err_vs_f64 < 1e-3);
    }
}
