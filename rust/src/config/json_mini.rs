//! Minimal JSON parser (no `serde` in this environment) — enough for
//! `artifacts/manifest.json` and similar tool-generated documents.
//!
//! Supports objects, arrays, strings (with the common escapes), numbers,
//! booleans and null. Numbers parse as f64 (manifest values are small
//! integers, exactly representable).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
}

/// Escape a string's content for embedding inside a JSON string literal
/// (the emission-side dual of [`parse_json`]'s string parser: everything
/// this produces, that parser reads back verbatim). Every JSON emitter in
/// the crate — `metrics::Registry::to_json`, the server's response
/// bodies, the bench artifacts — must route names/strings through this,
/// so a hostile key (quotes, backslashes, control characters) can never
/// yield a malformed document.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse a JSON document.
pub fn parse_json(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Consume one UTF-8 scalar (multibyte-safe).
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let s = std::str::from_utf8(&self.b[self.i..self.i + len])
                        .map_err(|_| "bad utf8")?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_shaped_document() {
        let j = parse_json(
            r#"{"heat_n": 512, "artifacts": [{"name": "heat_step_r2f2",
                "inputs": [{"shape": [512], "dtype": "float32"}], "outputs": 5}]}"#,
        )
        .unwrap();
        assert_eq!(j.get("heat_n").unwrap().as_usize(), Some(512));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("heat_step_r2f2"));
        let inp = arts[0].get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inp[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(512));
    }

    #[test]
    fn scalars_and_specials() {
        assert_eq!(parse_json("true").unwrap(), Json::Bool(true));
        assert_eq!(parse_json(" null ").unwrap(), Json::Null);
        assert_eq!(parse_json("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse_json(r#""a\nb\"c""#).unwrap(), Json::Str("a\nb\"c".into()));
    }

    #[test]
    fn unicode_escape_and_utf8() {
        assert_eq!(parse_json(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse_json("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn errors() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("12 34").is_err());
        assert!(parse_json("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse_json("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse_json("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn escape_roundtrips_through_own_parser() {
        for hostile in [
            "plain",
            "quo\"te",
            "back\\slash",
            "new\nline",
            "tab\tret\r",
            "ctl\u{1}\u{1f}",
            "uni é ☃",
            "\\\"both\\\"",
            "",
        ] {
            let doc = format!("\"{}\"", escape(hostile));
            assert_eq!(
                parse_json(&doc).unwrap(),
                Json::Str(hostile.to_string()),
                "roundtrip {hostile:?} via {doc:?}"
            );
        }
    }

    #[test]
    fn escaped_keys_keep_objects_wellformed() {
        let doc = format!("{{\"{}\": 1}}", escape("a\"b\\c\nd"));
        let j = parse_json(&doc).unwrap();
        assert_eq!(j.get("a\"b\\c\nd").unwrap().as_f64(), Some(1.0));
    }
}
