//! Experiment configuration: a TOML-subset parser (no `serde` in this
//! environment) plus the typed experiment config the CLI and coordinator
//! consume.

pub mod experiment;
pub mod json_mini;
pub mod toml_mini;

pub use experiment::{parse_backend, BackendSpec, ExperimentConfig, APPS};
pub use json_mini::{escape as json_escape, parse_json, Json};
pub use toml_mini::{parse as parse_toml, Document, Value};
