//! Typed experiment configuration consumed by the CLI, the coordinator and
//! the examples.

use super::toml_mini::{Document, Value};
use crate::pde::advection1d::AdvectionParams;
use crate::pde::heat1d::HeatParams;
use crate::pde::init::{HeatInit, SweInit};
use crate::pde::swe2d::SweParams;
use crate::pde::wave2d::WaveParams;
use crate::pde::QuantMode;
use crate::r2f2core::R2f2Config;
use crate::softfloat::FpFormat;

/// Which arithmetic unit a run uses — the parsed form of CLI/TOML strings
/// like `f64`, `f32`, `fixed:E5M10`, `r2f2:<3,9,3>`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BackendSpec {
    F64,
    F32,
    Fixed(FpFormat),
    R2f2(R2f2Config),
}

impl BackendSpec {
    /// Instantiate the arithmetic backend.
    pub fn build(&self) -> Box<dyn crate::pde::Arith> {
        match *self {
            BackendSpec::F64 => Box::new(crate::pde::F64Arith),
            BackendSpec::F32 => Box::new(crate::pde::F32Arith),
            BackendSpec::Fixed(fmt) => Box::new(crate::pde::FixedArith::new(fmt)),
            BackendSpec::R2f2(cfg) => Box::new(crate::pde::R2f2Arith::new(cfg)),
        }
    }

    /// [`BackendSpec::build`] with a `Send` bound: the job executor parks a
    /// run's backend between epochs and hands it across worker threads
    /// (`server::jobs`, DESIGN.md §16). Every concrete backend is plain
    /// data, so this is the same construction under a tighter type.
    pub fn build_send(&self) -> Box<dyn crate::pde::Arith + Send> {
        match *self {
            BackendSpec::F64 => Box::new(crate::pde::F64Arith),
            BackendSpec::F32 => Box::new(crate::pde::F32Arith),
            BackendSpec::Fixed(fmt) => Box::new(crate::pde::FixedArith::new(fmt)),
            BackendSpec::R2f2(cfg) => Box::new(crate::pde::R2f2Arith::new(cfg)),
        }
    }

    pub fn name(&self) -> String {
        match *self {
            BackendSpec::F64 => "f64".into(),
            BackendSpec::F32 => "f32".into(),
            BackendSpec::Fixed(fmt) => format!("fixed:{fmt}"),
            BackendSpec::R2f2(cfg) => format!("r2f2:{cfg}"),
        }
    }
}

/// Parse a backend spec string.
///
/// Accepted: `f64` · `f32` · `fixed:E5M10` (any `E<x>M<y>`) ·
/// `r2f2:<3,9,3>` (any `<EB,MB,FX>`).
pub fn parse_backend(s: &str) -> Result<BackendSpec, String> {
    match s {
        "f64" => return Ok(BackendSpec::F64),
        "f32" => return Ok(BackendSpec::F32),
        _ => {}
    }
    if let Some(fmt) = s.strip_prefix("fixed:") {
        return parse_exmy(fmt).map(BackendSpec::Fixed);
    }
    if let Some(cfg) = s.strip_prefix("r2f2:") {
        return parse_r2f2(cfg).map(BackendSpec::R2f2);
    }
    Err(format!("unknown backend `{s}` (expected f64|f32|fixed:ExMy|r2f2:<EB,MB,FX>)"))
}

/// Parse `E<x>M<y>`.
pub fn parse_exmy(s: &str) -> Result<FpFormat, String> {
    let body = s.strip_prefix('E').ok_or_else(|| format!("`{s}`: expected ExMy"))?;
    let (e, m) = body.split_once('M').ok_or_else(|| format!("`{s}`: expected ExMy"))?;
    let e_w: u32 = e.parse().map_err(|_| format!("`{s}`: bad exponent width"))?;
    let m_w: u32 = m.parse().map_err(|_| format!("`{s}`: bad mantissa width"))?;
    if !(2..=11).contains(&e_w) || !(1..=52).contains(&m_w) {
        return Err(format!("`{s}`: widths out of range"));
    }
    Ok(FpFormat::new(e_w, m_w))
}

/// Parse `<EB,MB,FX>`.
pub fn parse_r2f2(s: &str) -> Result<R2f2Config, String> {
    let body = s
        .strip_prefix('<')
        .and_then(|t| t.strip_suffix('>'))
        .ok_or_else(|| format!("`{s}`: expected <EB,MB,FX>"))?;
    let parts: Vec<&str> = body.split(',').map(str::trim).collect();
    if parts.len() != 3 {
        return Err(format!("`{s}`: expected three comma-separated fields"));
    }
    let nums: Result<Vec<u32>, _> = parts.iter().map(|p| p.parse::<u32>()).collect();
    let nums = nums.map_err(|_| format!("`{s}`: non-numeric field"))?;
    if !(2..=8).contains(&nums[0]) || !(1..=24).contains(&nums[1]) || !(1..=8).contains(&nums[2]) {
        return Err(format!("`{s}`: field out of range"));
    }
    Ok(R2f2Config::new(nums[0], nums[1], nums[2]))
}

/// The scenario apps a config may select (the registry names minus the
/// `1d`/`2d` suffixes the CLI has always used for heat/swe).
pub const APPS: &[&str] = &["heat", "swe", "advection", "wave"];

/// One simulation experiment, loadable from a TOML document.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub title: String,
    /// One of [`APPS`]: `heat`, `swe`, `advection` or `wave`.
    pub app: String,
    pub backend: BackendSpec,
    pub mode: QuantMode,
    /// Domain-decomposition shard count (`pde::decomp`, DESIGN.md §13).
    /// 1 = unsharded; any other value produces bit-identical results while
    /// spreading each step across the worker pool.
    pub shards: usize,
    pub heat: HeatParams,
    pub swe: SweParams,
    pub advection: AdvectionParams,
    pub wave: WaveParams,
}

impl Default for ExperimentConfig {
    fn default() -> ExperimentConfig {
        ExperimentConfig {
            title: "experiment".into(),
            app: "heat".into(),
            backend: BackendSpec::R2f2(R2f2Config::C16_393),
            mode: QuantMode::MulOnly,
            shards: 1,
            heat: HeatParams::default(),
            swe: SweParams::default(),
            advection: AdvectionParams::default(),
            wave: WaveParams::default(),
        }
    }
}

fn get<'a>(doc: &'a Document, section: &str, key: &str) -> Option<&'a Value> {
    doc.get(section).and_then(|s| s.get(key))
}

impl ExperimentConfig {
    /// Build from a parsed TOML document; unspecified fields keep defaults.
    pub fn from_document(doc: &Document) -> Result<ExperimentConfig, String> {
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = get(doc, "", "title").and_then(Value::as_str) {
            cfg.title = v.to_string();
        }
        if let Some(v) = get(doc, "", "app").and_then(Value::as_str) {
            if !APPS.contains(&v) {
                return Err(format!("app must be {}, got `{v}`", APPS.join("|")));
            }
            cfg.app = v.to_string();
        }
        if let Some(v) = get(doc, "", "backend").and_then(Value::as_str) {
            cfg.backend = parse_backend(v)?;
        }
        if let Some(v) = get(doc, "", "mode").and_then(Value::as_str) {
            cfg.mode = match v {
                "mul-only" => QuantMode::MulOnly,
                "full" => QuantMode::Full,
                other => return Err(format!("mode must be mul-only|full, got `{other}`")),
            };
        }
        if let Some(v) = get(doc, "", "shards").and_then(Value::as_int) {
            if !(1..=64).contains(&v) {
                return Err(format!("shards must be in 1..=64, got {v}"));
            }
            cfg.shards = v as usize;
        }

        if let Some(v) = get(doc, "heat", "n").and_then(Value::as_int) {
            if v < 3 {
                return Err(format!("heat.n must be at least 3, got {v}"));
            }
            cfg.heat.n = v as usize;
        }
        if let Some(v) = get(doc, "heat", "steps").and_then(Value::as_int) {
            cfg.heat.steps = v as usize;
        }
        if let Some(v) = get(doc, "heat", "dt").and_then(Value::as_float) {
            cfg.heat.dt = v;
        }
        if let Some(v) = get(doc, "heat", "alpha").and_then(Value::as_float) {
            cfg.heat.alpha = v;
        }
        if let Some(v) = get(doc, "heat", "init").and_then(Value::as_str) {
            cfg.heat.init = match v {
                "sin" => HeatInit::sin_default(),
                "exp" => HeatInit::exp_default(),
                other => return Err(format!("heat.init must be sin|exp, got `{other}`")),
            };
        }
        if let Some(v) = get(doc, "heat", "snapshot_every").and_then(Value::as_int) {
            cfg.heat.snapshot_every = v as usize;
        }

        if let Some(v) = get(doc, "swe", "n").and_then(Value::as_int) {
            if v < 3 {
                return Err(format!("swe.n must be at least 3, got {v}"));
            }
            cfg.swe.n = v as usize;
        }
        if let Some(v) = get(doc, "swe", "steps").and_then(Value::as_int) {
            cfg.swe.steps = v as usize;
        }
        if let Some(v) = get(doc, "swe", "dt").and_then(Value::as_float) {
            cfg.swe.dt = v;
        }
        if let Some(v) = get(doc, "swe", "dx").and_then(Value::as_float) {
            cfg.swe.dx = v;
        }
        if let Some(v) = get(doc, "swe", "base_depth").and_then(Value::as_float) {
            cfg.swe.init = SweInit { base_depth: v, ..cfg.swe.init };
        }
        if let Some(v) = get(doc, "swe", "amplitude").and_then(Value::as_float) {
            cfg.swe.init = SweInit { amplitude: v, ..cfg.swe.init };
        }

        if let Some(v) = get(doc, "advection", "n").and_then(Value::as_int) {
            if v < 3 {
                return Err(format!("advection.n must be at least 3, got {v}"));
            }
            let n = v as usize;
            // Keep the default CFL (0.4) at the new resolution.
            cfg.advection.dt = cfg.advection.dt * cfg.advection.n as f64 / n as f64;
            cfg.advection.n = n;
        }
        if let Some(v) = get(doc, "advection", "steps").and_then(Value::as_int) {
            cfg.advection.steps = v as usize;
        }
        if let Some(v) = get(doc, "advection", "burgers").and_then(Value::as_bool) {
            if v {
                let steps = cfg.advection.steps;
                let n = cfg.advection.n;
                cfg.advection =
                    AdvectionParams { steps, ..AdvectionParams::burgers_default() };
                cfg.advection.dt = cfg.advection.dt * cfg.advection.n as f64 / n as f64;
                cfg.advection.n = n;
            }
        }

        if let Some(v) = get(doc, "wave", "n").and_then(Value::as_int) {
            if v < 3 {
                return Err(format!("wave.n must be at least 3, got {v}"));
            }
            let n = v as usize;
            // Keep the default Courant number (0.5) at the new resolution.
            cfg.wave.dt = cfg.wave.dt * (cfg.wave.n - 1) as f64 / (n - 1) as f64;
            cfg.wave.n = n;
        }
        if let Some(v) = get(doc, "wave", "steps").and_then(Value::as_int) {
            cfg.wave.steps = v as usize;
        }
        if let Some(v) = get(doc, "wave", "damping").and_then(Value::as_float) {
            if !(0.0..1.0).contains(&v) {
                return Err(format!("wave.damping must be in [0, 1), got {v}"));
            }
            cfg.wave.damping = v;
        }
        Ok(cfg)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig, String> {
        let doc = super::toml_mini::parse(text).map_err(|e| e.to_string())?;
        Self::from_document(&doc)
    }

    /// Build from a parsed JSON document (the `POST /v1/run` body). The
    /// shape mirrors the TOML config exactly: scalar fields at the top
    /// level, one nested object per `[section]` —
    /// `{"app": "heat", "backend": "fixed:E5M10", "heat": {"n": 65}}`.
    ///
    /// The JSON is lowered onto the same [`Document`] the TOML path
    /// produces and validated by the same [`ExperimentConfig::from_document`],
    /// so the two config surfaces can never drift (including the TOML
    /// path's leniency: unknown keys are ignored, wrong-typed values fall
    /// back to defaults). Integral numbers lower to `Int` so they satisfy
    /// both integer and float fields, like TOML's `as_float` does.
    ///
    /// On top of `from_document`, **serving limits** apply
    /// ([`ExperimentConfig::check_serving_limits`]): this is the remote
    /// surface, and a giant grid must be a `400`, not a multi-GB
    /// allocation (allocation failure aborts the process — a worker's
    /// panic guard cannot catch it) or a worker pinned for days.
    pub fn from_json(json: &super::Json) -> Result<ExperimentConfig, String> {
        use super::Json;
        fn lower(v: &Json) -> Result<Value, String> {
            match v {
                Json::Str(s) => Ok(Value::Str(s.clone())),
                Json::Bool(b) => Ok(Value::Bool(*b)),
                Json::Num(n) if n.fract() == 0.0 && n.abs() <= 9.0e15 => Ok(Value::Int(*n as i64)),
                Json::Num(n) => Ok(Value::Float(*n)),
                other => Err(format!("config values must be scalars, got {other:?}")),
            }
        }
        let obj = match json {
            Json::Obj(m) => m,
            _ => return Err("config must be a JSON object".to_string()),
        };
        let mut doc = Document::new();
        doc.insert(String::new(), Default::default());
        for (key, value) in obj {
            match value {
                Json::Obj(section) => {
                    let table = doc.entry(key.clone()).or_default();
                    for (k, v) in section {
                        table.insert(k.clone(), lower(v)?);
                    }
                }
                scalar => {
                    doc.get_mut("").unwrap().insert(key.clone(), lower(scalar)?);
                }
            }
        }
        let cfg = Self::from_document(&doc)?;
        cfg.check_serving_limits()?;
        Ok(cfg)
    }

    /// Reject configs too large to serve: 1D grids above 10⁶ nodes, 2D
    /// grids above 2048², more than 10⁷ timesteps, or — the binding
    /// constraint — more than 10⁹ node·steps of total work per section
    /// (bounding n and steps independently would still admit jobs that pin
    /// a worker for days; jobs have no timeout). Local (TOML/CLI) runs are
    /// deliberately not limited — on your own machine, your call — but the
    /// server must bound memory (an allocation failure aborts the process)
    /// and job length.
    pub fn check_serving_limits(&self) -> Result<(), String> {
        const MAX_NODES_1D: usize = 1_000_000;
        const MAX_SIDE_2D: usize = 2048;
        const MAX_STEPS: usize = 10_000_000;
        // Grid nodes × timesteps: ≈ minutes of worker time at worst, not
        // days (every default/preset is well below 1e7).
        const MAX_WORK: usize = 1_000_000_000;
        // A sharded run (`shards > 1`, pde::decomp) spreads each timestep
        // across that many pool workers, so the per-worker wall clock — the
        // quantity these limits actually bound — stays put when the
        // admitted grid and total work scale with the shard count. The 2D
        // side cap stays fixed: it bounds the *assembled* global field's
        // memory, which sharding does not reduce.
        let scale = self.shards.max(1);
        let max_nodes_1d = MAX_NODES_1D.saturating_mul(scale);
        let max_work = MAX_WORK.saturating_mul(scale);
        let checks: [(&str, usize, usize); 8] = [
            ("heat.n", self.heat.n, max_nodes_1d),
            ("advection.n", self.advection.n, max_nodes_1d),
            ("swe.n", self.swe.n, MAX_SIDE_2D),
            ("wave.n", self.wave.n, MAX_SIDE_2D),
            ("heat.steps", self.heat.steps, MAX_STEPS),
            ("advection.steps", self.advection.steps, MAX_STEPS),
            ("swe.steps", self.swe.steps, MAX_STEPS),
            ("wave.steps", self.wave.steps, MAX_STEPS),
        ];
        for (name, value, cap) in checks {
            if value > cap {
                return Err(format!("{name} = {value} exceeds the serving limit of {cap}"));
            }
        }
        let work: [(&str, usize); 4] = [
            ("heat", self.heat.n.saturating_mul(self.heat.steps)),
            ("advection", self.advection.n.saturating_mul(self.advection.steps)),
            ("swe", self.swe.n.saturating_mul(self.swe.n).saturating_mul(self.swe.steps)),
            ("wave", self.wave.n.saturating_mul(self.wave.n).saturating_mul(self.wave.steps)),
        ];
        for (name, nodesteps) in work {
            if nodesteps > max_work {
                return Err(format!(
                    "{name}: n × steps = {nodesteps} node·steps exceeds the serving limit \
                     of {max_work}"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_specs_roundtrip() {
        for s in ["f64", "f32", "fixed:E5M10", "fixed:E6M9", "r2f2:<3,9,3>", "r2f2:<3,8,4>"] {
            let b = parse_backend(s).unwrap();
            assert_eq!(b.name(), s, "roundtrip {s}");
        }
    }

    #[test]
    fn backend_build_produces_working_arith() {
        let mut be = parse_backend("r2f2:<3,9,3>").unwrap().build();
        let v = be.mul(3.0, 4.0);
        assert!((v - 12.0).abs() < 0.05);
        let mut be = parse_backend("fixed:E5M10").unwrap().build();
        assert_eq!(be.mul(1000.0, 1000.0), 65504.0);
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(parse_backend("f16").is_err());
        assert!(parse_backend("fixed:X5M10").is_err());
        assert!(parse_backend("r2f2:<3,9>").is_err());
        assert!(parse_backend("r2f2:<99,9,3>").is_err());
    }

    #[test]
    fn config_from_toml() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            title = "fig7a"
            app = "heat"
            backend = "r2f2:<3,9,3>"
            mode = "mul-only"
            [heat]
            n = 101
            steps = 200
            dt = 2.5e-5
            init = "sin"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.title, "fig7a");
        assert_eq!(cfg.heat.n, 101);
        assert_eq!(cfg.heat.steps, 200);
        assert_eq!(cfg.backend.name(), "r2f2:<3,9,3>");
        assert_eq!(cfg.mode, QuantMode::MulOnly);
    }

    #[test]
    fn scenario_apps_accepted_with_sections() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            app = "wave"
            [wave]
            n = 17
            steps = 64
            damping = 0.02
            "#,
        )
        .unwrap();
        assert_eq!(cfg.app, "wave");
        assert_eq!(cfg.wave.n, 17);
        assert_eq!(cfg.wave.steps, 64);
        assert_eq!(cfg.wave.damping, 0.02);
        // Resizing preserves the default Courant number.
        assert!((cfg.wave.courant() - 0.5).abs() < 1e-12);

        let cfg = ExperimentConfig::from_toml(
            r#"
            app = "advection"
            [advection]
            n = 64
            steps = 100
            "#,
        )
        .unwrap();
        assert_eq!(cfg.app, "advection");
        assert_eq!(cfg.advection.n, 64);
        assert_eq!(cfg.advection.steps, 100);
        assert!((cfg.advection.cfl() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn json_and_toml_configs_agree() {
        let toml = ExperimentConfig::from_toml(
            r#"
            title = "served"
            app = "heat"
            backend = "fixed:E5M10"
            mode = "full"
            [heat]
            n = 65
            steps = 120
            dt = 2.5e-5
            init = "exp"
            "#,
        )
        .unwrap();
        let json = ExperimentConfig::from_json(
            &crate::config::parse_json(
                r#"{"title": "served", "app": "heat", "backend": "fixed:E5M10",
                    "mode": "full",
                    "heat": {"n": 65, "steps": 120, "dt": 2.5e-5, "init": "exp"}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(json.title, toml.title);
        assert_eq!(json.app, toml.app);
        assert_eq!(json.backend, toml.backend);
        assert_eq!(json.mode, toml.mode);
        assert_eq!(json.heat.n, toml.heat.n);
        assert_eq!(json.heat.steps, toml.heat.steps);
        assert_eq!(json.heat.dt.to_bits(), toml.heat.dt.to_bits());
        assert_eq!(json.heat.init, toml.heat.init);
    }

    #[test]
    fn json_integral_numbers_satisfy_float_fields() {
        // `"dt": 1` is an integral JSON number landing on a float field —
        // must behave like TOML's Int-accepting `as_float`.
        let cfg = ExperimentConfig::from_json(
            &crate::config::parse_json(r#"{"swe": {"dt": 1, "steps": 3}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.swe.dt, 1.0);
        assert_eq!(cfg.swe.steps, 3);
    }

    #[test]
    fn bad_json_configs_rejected() {
        for doc in [
            "[1, 2]",                          // not an object
            "{\"app\": \"chess\"}",            // unknown app
            "{\"mode\": \"sideways\"}",        // unknown mode
            "{\"backend\": \"r2f2:bogus\"}",   // bad backend spec
            "{\"heat\": {\"n\": [1, 2]}}",     // non-scalar section value
            "{\"wave\": {\"n\": 1}}",          // degenerate grid
            "{\"wave\": {\"damping\": 1.5}}",  // out-of-range damping
            "{\"heat\": {\"n\": 2000000000}}", // above the serving limit
            "{\"swe\": {\"n\": 100000}}",      // 2D side above the limit
            "{\"heat\": {\"steps\": 100000000}}", // job effectively forever
            // n and steps each in-limits, but the n × steps work product
            // would pin a worker for days.
            "{\"heat\": {\"n\": 1000000, \"steps\": 10000000}}",
            "{\"wave\": {\"n\": 2048, \"steps\": 1000000}}",
        ] {
            let j = crate::config::parse_json(doc).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "{doc}");
        }
    }

    #[test]
    fn shards_knob_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml("shards = 8").unwrap();
        assert_eq!(cfg.shards, 8);
        assert_eq!(ExperimentConfig::from_toml("").unwrap().shards, 1);
        let cfg = ExperimentConfig::from_json(
            &crate::config::parse_json(r#"{"app": "heat", "shards": 4}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.shards, 4);
        for bad in ["shards = 0", "shards = 65", "shards = -2"] {
            assert!(ExperimentConfig::from_toml(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn serving_limits_scale_with_shards() {
        // A 4M-node grid is over the unsharded cap but fits when the run is
        // decomposed over at least 4 shards; the work product scales the
        // same way. The 2D memory cap never scales.
        let mut c = ExperimentConfig::default();
        c.heat.n = 4_000_000;
        c.heat.steps = 1;
        assert!(c.check_serving_limits().is_err());
        c.shards = 4;
        c.check_serving_limits().unwrap();

        let mut c = ExperimentConfig::default();
        c.heat.n = 1_000_000;
        c.heat.steps = 4_000;
        assert!(c.check_serving_limits().is_err());
        c.shards = 8;
        c.check_serving_limits().unwrap();

        let mut c = ExperimentConfig::default();
        c.swe.n = 4096;
        c.shards = 64;
        assert!(c.check_serving_limits().is_err(), "2D side cap must not scale");
    }

    #[test]
    fn serving_limits_allow_all_defaults() {
        // Every local default and preset must stay servable.
        ExperimentConfig::default().check_serving_limits().unwrap();
        for app in APPS {
            let mut c = ExperimentConfig::default();
            c.app = app.to_string();
            c.check_serving_limits().unwrap();
        }
    }

    #[test]
    fn defaults_survive_empty_toml() {
        let cfg = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(cfg.app, "heat");
        assert_eq!(cfg.heat.n, 501);
    }

    #[test]
    fn invalid_fields_error() {
        assert!(ExperimentConfig::from_toml("app = \"chess\"").is_err());
        assert!(ExperimentConfig::from_toml("mode = \"sideways\"").is_err());
        assert!(ExperimentConfig::from_toml("backend = \"r2f2:bogus\"").is_err());
        // Degenerate grids are a config error, not a div-by-zero downstream
        // (load-bearing for the server: a panicking worker is a DoS).
        assert!(ExperimentConfig::from_toml("[wave]\nn = 1").is_err());
        assert!(ExperimentConfig::from_toml("[advection]\nn = 0").is_err());
        assert!(ExperimentConfig::from_toml("[heat]\nn = 1").is_err());
        assert!(ExperimentConfig::from_toml("[swe]\nn = 0").is_err());
        assert!(ExperimentConfig::from_toml("[wave]\ndamping = 1.5").is_err());
    }
}
