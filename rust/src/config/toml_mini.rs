//! A TOML-subset parser sufficient for experiment configs.
//!
//! Supported: `[section]` headers, `key = value` pairs with string
//! (`"..."`), integer, float, and boolean values, `#` comments, and blank
//! lines. Dotted keys, arrays, tables-in-tables and multi-line strings are
//! deliberately out of scope — experiment configs are flat.

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`steps = 100` readable as f64).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Section name → (key → value). Keys before any `[section]` land in the
/// `""` root section.
pub type Document = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse error with a 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a config document.
pub fn parse(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::new();
    let mut section = String::new();
    doc.insert(section.clone(), BTreeMap::new());

    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, "expected `key = value`"))?;
        let key = k.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(v.trim()).map_err(|m| err(lineno, &m))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn err(line: usize, msg: &str) -> ParseError {
    ParseError { line, msg: msg.to_string() }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(body.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
            # experiment
            title = "heat sweep"   # inline comment
            [app]
            kind = "heat"
            n = 501
            dt = 1e-6
            quantize_state = false
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["title"], Value::Str("heat sweep".into()));
        assert_eq!(doc["app"]["kind"].as_str(), Some("heat"));
        assert_eq!(doc["app"]["n"].as_int(), Some(501));
        assert!((doc["app"]["dt"].as_float().unwrap() - 1e-6).abs() < 1e-18);
        assert_eq!(doc["app"]["quantize_state"].as_bool(), Some(false));
    }

    #[test]
    fn int_readable_as_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc[""]["x"].as_float(), Some(3.0));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(doc[""]["name"].as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[oops").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("x = @@").unwrap_err();
        assert!(e.msg.contains("@@"));
    }

    #[test]
    fn later_sections_merge() {
        let doc = parse("[a]\nx = 1\n[b]\ny = 2\n[a]\nz = 3").unwrap();
        assert_eq!(doc["a"]["x"].as_int(), Some(1));
        assert_eq!(doc["a"]["z"].as_int(), Some(3));
    }
}
