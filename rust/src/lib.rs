//! # R2F2 — Runtime Reconfigurable Floating-Point Precision
//!
//! A production-quality reproduction of *"Exploring and Exploiting Runtime
//! Reconfigurable Floating Point Precision in Scientific Computing: a Case
//! Study for Solving PDEs"* (Cong Hao, CS.AR 2024).
//!
//! The crate is organized as the Layer-3 (rust) side of a three-layer
//! rust + JAX + Pallas stack:
//!
//! * [`softfloat`] — arbitrary-precision floating-point library (the paper's
//!   exploration substrate, §3): encode/decode/multiply/add for any
//!   `ExMy` format with selectable rounding.
//! * [`r2f2core`] — the paper's contribution (§4): the flexible
//!   `<EB, MB, FX>` representation, the runtime-reconfigurable multiplier
//!   with the truncated flexible-partial-product approximation, the dynamic
//!   precision-adjustment unit, a cycle-accurate datapath model and an FPGA
//!   resource (FF/LUT) cost model for Table 1.
//! * [`pde`] — the PDE scenarios: the paper's two case studies (1D heat,
//!   2D shallow water) plus 1D upwind advection/Burgers and the 2D damped
//!   wave equation, runnable under f64 / f32 / fixed `ExMy` / R2F2
//!   multiplication backends. The [`pde::Arith`] trait carries the
//!   **batched arithmetic engine** (DESIGN.md §8) and, by default, routes
//!   it through the **packed-domain engine** (DESIGN.md §9): solver state
//!   held as `u32` `[sign|exp|frac]` words, 64-bit integer datapaths, no
//!   f64 carrier round-trip on the hot path — bit-identical to the scalar
//!   path, with the PR-1 carrier engine kept selectable as the perf
//!   baseline. The [`pde::adaptive`] scheduler (DESIGN.md §10) makes the
//!   range-telemetry layer load-bearing: solvers walk a ladder of fixed
//!   formats between timesteps (widen + retry on overflow pressure,
//!   narrow after a clean streak once the dynamics stall). The
//!   [`pde::scenario`] layer (DESIGN.md §11) is what every solver plugs
//!   into: one [`pde::scenario::Sim`] trait, generic run/adaptive
//!   drivers, and the [`pde::scenario::SCENARIOS`] registry that tests,
//!   benches, the CLI and CI all iterate.
//! * [`analysis`] / [`sweep`] — the exploration harnesses behind Figs 2, 3
//!   and 6.
//! * [`runtime`] — PJRT client wrapper: loads `artifacts/*.hlo.txt`
//!   (AOT-lowered JAX/Pallas computations) and drives the simulation step
//!   loop from rust. Python never runs on this path.
//! * [`coordinator`] — experiment job system: a thread-pool scheduler that
//!   fans sweeps and simulations out across workers.
//! * [`server`] — the serving layer (DESIGN.md §12): `r2f2 serve` exposes
//!   the whole stack over a std-only HTTP/1.1 surface — a persistent
//!   worker pool with a bounded job queue, and a content-addressed result
//!   cache that is *sound* because runs are bit-reproducible by the
//!   §8/§9/§11 contracts (a debug determinism guard re-verifies sampled
//!   hits). `r2f2 bench-serve` is the in-process loopback load generator.
//! * [`config`] / [`metrics`] / [`report`] / [`cli`] — the supporting
//!   substrates (TOML-subset config, counters, CSV/ASCII-plot emitters,
//!   argument parsing) built from scratch for this offline environment.
//!
//! * [`trace`] — deterministic structured tracing + the precision
//!   profiler (DESIGN.md §17): span/event records stamped with logical
//!   clocks (step/epoch/mul counters, never wall time on content paths),
//!   per-worker bounded ring collectors that merge order-invariantly,
//!   ndjson export under `r2f2-trace/1`, and `r2f2 profile` — a
//!   RAPTOR-style pilot that recommends a per-scenario starting format
//!   (predicted RMSE + modeled datapath cost) the adaptive scheduler can
//!   seed its ladder from.
//! * [`audit`] — the static conformance pass (DESIGN.md §15): `r2f2 audit`
//!   lexes the tree (comments/strings stripped) and enforces the
//!   determinism and bit-identity disciplines as source-level rules —
//!   native-float quarantine in the integer kernels, wall-clock and hash
//!   iteration quarantines on result paths, RNG discipline, `unsafe`-free,
//!   zero-dep manifests — with reasoned inline allow markers as the only
//!   suppression channel.
//!
//! See `DESIGN.md` for the bit-exact emulation spec shared with the Pallas
//! kernels and `EXPERIMENTS.md` for paper-vs-measured results.

// The whole crate is safe Rust; the audit subsystem's `unsafe-free` rule
// extends the same ban to benches/tests/examples, which this attribute
// cannot reach.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod audit;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod pde;
pub mod proptest_mini;
pub mod r2f2core;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod softfloat;
pub mod sweep;
pub mod trace;
