//! Audit findings, the machine-readable report (`r2f2-audit/1`), and the
//! counts-only snapshot committed as `rust/AUDIT_smoke.json`.
//!
//! Emission rules: findings/allows are sorted (file, line, rule) so the
//! report is byte-stable for a given tree; the snapshot contains *counts
//! only* (no file:line), so it changes exactly when the shipped rule set
//! or the allowlist population changes — that is the reviewed trajectory
//! CI diffs, not file churn.

use super::rules::{self, RULES};
use crate::config::json_mini::escape;

/// One unsuppressed rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Repo-root-relative path.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: String,
    /// The offending source line, trimmed.
    pub snippet: String,
    /// Extra context (marker diagnostics); empty for pattern findings.
    pub note: String,
}

/// One suppressed violation: a finding covered by a reasoned allow marker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// A syntactically valid marker that suppressed nothing. Surfaced (table +
/// JSON) but non-gating: stale markers are cleanup, not contract breaks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnusedMarker {
    pub file: String,
    pub line: usize,
    /// Comma-joined rule ids the marker named.
    pub rules: String,
}

/// Everything one audit run produced.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub findings: Vec<Finding>,
    pub allows: Vec<Allow>,
    pub unused: Vec<UnusedMarker>,
    pub files_scanned: usize,
}

impl AuditReport {
    /// Sort all sections (file, line, rule) for stable emission.
    pub fn sort(&mut self) {
        self.findings.sort_by(|a, b| {
            (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
        });
        self.allows.sort_by(|a, b| {
            (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
        });
        self.unused.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    }

    /// Per-rule (id, findings, allows) in inventory order.
    pub fn counts(&self) -> Vec<(&'static str, usize, usize)> {
        RULES
            .iter()
            .map(|r| {
                let f = self.findings.iter().filter(|x| x.rule == r.id).count();
                let a = self.allows.iter().filter(|x| x.rule == r.id).count();
                (r.id, f, a)
            })
            .collect()
    }

    /// The full machine-readable report (schema `r2f2-audit/1`,
    /// EXPERIMENTS.md). `generator` records the exact invocation.
    pub fn to_json(&self, generator: &str) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"r2f2-audit/1\",\n");
        s.push_str(&format!("  \"generator\": \"{}\",\n", escape(generator)));
        s.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        s.push_str("  \"rules\": [\n");
        let counts = self.counts();
        for (i, rule) in RULES.iter().enumerate() {
            let (_, nf, na) = counts[i];
            s.push_str(&format!(
                "    {{ \"id\": \"{}\", \"summary\": \"{}\", \"contract\": \"{}\", \"findings\": {}, \"allows\": {} }}{}\n",
                escape(rule.id),
                escape(rule.summary),
                escape(rule.contract),
                nf,
                na,
                if i + 1 < RULES.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"findings\": [\n");
        for (i, f) in self.findings.iter().enumerate() {
            s.push_str(&format!(
                "    {{ \"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"snippet\": \"{}\", \"note\": \"{}\" }}{}\n",
                escape(&f.file),
                f.line,
                escape(&f.rule),
                escape(&f.snippet),
                escape(&f.note),
                if i + 1 < self.findings.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"allows\": [\n");
        for (i, a) in self.allows.iter().enumerate() {
            s.push_str(&format!(
                "    {{ \"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"reason\": \"{}\" }}{}\n",
                escape(&a.file),
                a.line,
                escape(&a.rule),
                escape(&a.reason),
                if i + 1 < self.allows.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"unused_markers\": [\n");
        for (i, u) in self.unused.iter().enumerate() {
            s.push_str(&format!(
                "    {{ \"file\": \"{}\", \"line\": {}, \"rules\": \"{}\" }}{}\n",
                escape(&u.file),
                u.line,
                escape(&u.rules),
                if i + 1 < self.unused.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!("  \"total_findings\": {},\n", self.findings.len()));
        s.push_str(&format!("  \"total_allows\": {}\n", self.allows.len()));
        s.push_str("}\n");
        s
    }

    /// The counts-only snapshot (committed as `rust/AUDIT_smoke.json` and
    /// diffed byte-for-byte by CI). Deliberately excludes file:line so it
    /// only moves when the rule set or the allowlist population moves.
    pub fn snapshot_json(&self, generator: &str) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"r2f2-audit/1\",\n");
        s.push_str(&format!("  \"generator\": \"{}\",\n", escape(generator)));
        s.push_str("  \"rules\": [\n");
        let counts = self.counts();
        for (i, (id, nf, na)) in counts.iter().enumerate() {
            s.push_str(&format!(
                "    {{ \"id\": \"{}\", \"findings\": {}, \"allows\": {} }}{}\n",
                escape(id),
                nf,
                na,
                if i + 1 < counts.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!("  \"total_findings\": {},\n", self.findings.len()));
        s.push_str(&format!("  \"total_allows\": {}\n", self.allows.len()));
        s.push_str("}\n");
        s
    }

    /// Human-readable report. Each rule gets an `AUDIT |` row (the CI job
    /// summary greps these, like the conformance suites' `MATRIX |` rows),
    /// findings are listed file:line with the rule id and quoted snippet.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "audit: {} files scanned, {} finding(s), {} allow(s), {} unused marker(s)\n\n",
            self.files_scanned,
            self.findings.len(),
            self.allows.len(),
            self.unused.len()
        ));
        for (id, nf, na) in self.counts() {
            s.push_str(&format!("AUDIT | {id} | findings {nf} | allows {na}\n"));
        }
        if !self.findings.is_empty() {
            s.push_str("\nfindings:\n");
            for f in &self.findings {
                let note = if f.note.is_empty() {
                    String::new()
                } else {
                    format!(" ({})", f.note)
                };
                s.push_str(&format!(
                    "  {}:{} [{}]{} `{}`\n",
                    f.file, f.line, f.rule, note, f.snippet
                ));
                if let Some(rule) = rules::rule(&f.rule) {
                    s.push_str(&format!("      contract: {}\n", rule.contract));
                }
            }
        }
        if !self.unused.is_empty() {
            s.push_str("\nunused allow markers (stale — remove them):\n");
            for u in &self.unused {
                s.push_str(&format!("  {}:{} allow({})\n", u.file, u.line, u.rules));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AuditReport {
        let mut rep = AuditReport {
            findings: vec![Finding {
                file: "rust/src/x.rs".into(),
                line: 9,
                rule: "unsafe-free".into(),
                snippet: "unsafe { hole() }".into(),
                note: String::new(),
            }],
            allows: vec![Allow {
                file: "rust/src/y.rs".into(),
                line: 3,
                rule: "wall-clock-quarantine".into(),
                reason: "bench harness".into(),
            }],
            unused: Vec::new(),
            files_scanned: 2,
        };
        rep.sort();
        rep
    }

    #[test]
    fn json_is_parseable_and_carries_schema() {
        let rep = sample();
        let doc = crate::config::json_mini::parse_json(&rep.to_json("r2f2 audit")).unwrap();
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("r2f2-audit/1"));
        assert_eq!(doc.get("total_findings").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(doc.get("total_allows").and_then(|v| v.as_usize()), Some(1));
        let rules_arr = doc.get("rules").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rules_arr.len(), RULES.len());
    }

    #[test]
    fn snapshot_is_parseable_counts_only() {
        let rep = sample();
        let snap = rep.snapshot_json("r2f2 audit --snapshot rust/AUDIT_smoke.json");
        let doc = crate::config::json_mini::parse_json(&snap).unwrap();
        assert!(doc.get("findings").is_none(), "snapshot must not carry file:line detail");
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("r2f2-audit/1"));
        assert!(!snap.contains("x.rs"), "snapshot leaks a path");
    }

    #[test]
    fn render_has_audit_rows_for_every_rule() {
        let rep = sample();
        let text = rep.render();
        for rule in RULES {
            assert!(
                text.contains(&format!("AUDIT | {} |", rule.id)),
                "missing AUDIT row for {}",
                rule.id
            );
        }
        assert!(text.contains("rust/src/x.rs:9"));
    }

    #[test]
    fn counts_align_with_inventory_order() {
        let rep = sample();
        let counts = rep.counts();
        assert_eq!(counts.len(), RULES.len());
        for (i, rule) in RULES.iter().enumerate() {
            assert_eq!(counts[i].0, rule.id);
        }
        let unsafe_row = counts.iter().find(|c| c.0 == "unsafe-free").unwrap();
        assert_eq!((unsafe_row.1, unsafe_row.2), (1, 0));
    }
}
