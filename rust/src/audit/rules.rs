//! The rule inventory and the per-module policy map (DESIGN.md §15).
//!
//! Each rule protects a contract the repo already enforces dynamically
//! somewhere (the §9/§13/§14 bit-identity suites, the §12 cache-soundness
//! argument) — the auditor makes the *source-level discipline* behind the
//! contract checkable on every PR without running anything.
//!
//! A rule is (patterns × path policy × test-exemption). Paths are
//! repo-root-relative with `/` separators; a rule applies to a file when
//! the path starts with one of `include` and none of `exclude`. The rule
//! list is the inventory CI diffs against the DESIGN.md §15 catalog, so
//! adding a rule here without documenting it (or vice versa) fails the
//! `static-analysis` job.

/// A textual pattern matched against lexed code (never comments/strings).
#[derive(Debug, Clone, Copy)]
pub enum Pattern {
    /// Literal matched with identifier boundaries: the preceding char must
    /// not be `[A-Za-z_]` (digits ARE allowed before, so the literal
    /// suffix in `2.0f64` still matches) and the following char must not
    /// be `[A-Za-z0-9_]` (so the identifier `e_f64` never matches).
    Token(&'static str),
    /// Magic numeric constant, matched as a substring of the lowercased,
    /// underscore-stripped line — catches `0x9E37_79B9_7F4A_7C15` however
    /// it is grouped. Spell the needle lowercase without underscores.
    Const(&'static str),
}

/// One audit rule: identity, what it protects, where it applies.
#[derive(Debug)]
pub struct RuleSpec {
    pub id: &'static str,
    /// One-line human summary (rendered in the report table).
    pub summary: &'static str,
    /// The DESIGN.md invariant this rule protects, cited by section.
    pub contract: &'static str,
    pub patterns: &'static [Pattern],
    /// Path prefixes the rule applies to.
    pub include: &'static [&'static str],
    /// Path prefixes carved back out (the policy allowlist).
    pub exclude: &'static [&'static str],
    /// Whether `#[cfg(test)]` regions are exempt.
    pub exempt_tests: bool,
}

/// Rule id of the marker-hygiene rule (reason-less / malformed / unknown
/// allow markers). Not suppressible — an allow marker cannot allow itself.
pub const ALLOW_MARKER: &str = "allow-marker";

/// Rule id of the manifest rule (checked against `Cargo.toml`, not `.rs`).
pub const ZERO_DEP: &str = "zero-dep";

/// The integer-datapath kernel modules: everything on the packed hot path
/// must stay in `u32`/`u64` bit domains (DESIGN.md §9/§14). The
/// encode/decode boundary functions that legitimately touch the `f64`
/// carrier inside these files carry inline allow markers; the carrier-side
/// modules (`encode.rs`, `format.rs`, `batch.rs`, `mod.rs`) are outside
/// the quarantine by policy.
const KERNEL_MODULES: &[&str] = &[
    "rust/src/softfloat/mul.rs",
    "rust/src/softfloat/add.rs",
    "rust/src/softfloat/round.rs",
    "rust/src/softfloat/packed.rs",
    "rust/src/softfloat/swar.rs",
];

/// The full inventory, in report order.
pub const RULES: &[RuleSpec] = &[
    RuleSpec {
        id: "native-float-quarantine",
        summary: "no f32/f64 in the integer-datapath kernel modules",
        contract: "DESIGN.md \u{a7}9/\u{a7}14 \u{2014} packed and SWAR kernels are bit-identical to the scalar reference because every intermediate is an integer; one stray native-float op voids packed_vs_carrier/swar_vs_packed",
        patterns: &[Pattern::Token("f64"), Pattern::Token("f32")],
        include: KERNEL_MODULES,
        exclude: &[],
        exempt_tests: true,
    },
    RuleSpec {
        id: "wall-clock-quarantine",
        summary: "Instant::now/SystemTime only in metrics and bench harnesses",
        contract: "DESIGN.md \u{a7}12 \u{2014} result bodies and cache keys exclude wall-clock, which is what makes the content-addressed cache sound; a clock read on a result path breaks bit-reproducibility",
        patterns: &[Pattern::Token("Instant::now"), Pattern::Token("SystemTime")],
        include: &["rust/src/"],
        exclude: &["rust/src/metrics/", "rust/src/bench_util.rs"],
        exempt_tests: true,
    },
    RuleSpec {
        id: "ordered-iteration",
        summary: "no HashMap/HashSet in result-affecting modules",
        contract: "DESIGN.md \u{a7}11/\u{a7}13 \u{2014} scenario results, sweeps and solver state must be iteration-order deterministic; hash iteration order is seeded per process, so use BTreeMap/BTreeSet or an explicit sort",
        patterns: &[Pattern::Token("HashMap"), Pattern::Token("HashSet")],
        include: &["rust/src/config/", "rust/src/sweep/", "rust/src/pde/", "rust/src/softfloat/"],
        exclude: &[],
        exempt_tests: true,
    },
    RuleSpec {
        id: "rng-discipline",
        summary: "all stochastic draws flow through rng.rs / Rounder",
        contract: "DESIGN.md \u{a7}9/\u{a7}14 \u{2014} the stochastic draw-order contract: one SplitMix64 stream, one draw sequence, identical across scalar/packed/SWAR engines; an inline generator or RandomState entropy forks the sequence",
        patterns: &[
            Pattern::Token("RandomState"),
            Pattern::Token("DefaultHasher"),
            Pattern::Token("thread_rng"),
            Pattern::Token("from_entropy"),
            // SplitMix64 / PCG / java.util.Random / xorshift* multipliers:
            // an inline reimplementation of a mixer is an unsanctioned
            // stream even when it is seeded deterministically.
            Pattern::Const("0x9e3779b97f4a7c15"),
            Pattern::Const("0xbf58476d1ce4e5b9"),
            Pattern::Const("0x94d049bb133111eb"),
            Pattern::Const("6364136223846793005"),
            Pattern::Const("0x5deece66d"),
            Pattern::Const("1103515245"),
            Pattern::Const("0x2545f4914f6cdd1d"),
        ],
        include: &["rust/src/"],
        exclude: &["rust/src/rng.rs"],
        exempt_tests: true,
    },
    RuleSpec {
        id: "unsafe-free",
        summary: "the `unsafe` token is banned tree-wide",
        contract: "lib.rs `#![forbid(unsafe_code)]` \u{2014} the auditor extends the compiler gate to benches, tests and examples, and (unlike the attribute) cannot be out-scoped by a nested allow",
        patterns: &[Pattern::Token("unsafe")],
        include: &["rust/src/", "rust/benches/", "rust/tests/", "examples/"],
        exclude: &[],
        exempt_tests: false,
    },
    RuleSpec {
        id: ZERO_DEP,
        summary: "Cargo.toml dependency sections stay empty",
        contract: "DESIGN.md \u{a7}1 \u{2014} the tree is std-only by construction (offline environment); every capability is in-tree, and the pjrt runtime is a feature-gated stub, not a dependency",
        patterns: &[], // manifest rule: audited by `audit_cargo_toml`, not line patterns
        include: &["Cargo.toml", "rust/Cargo.toml"],
        exclude: &[],
        exempt_tests: false,
    },
    RuleSpec {
        id: ALLOW_MARKER,
        summary: "allow markers must name a known rule and carry a reason",
        contract: "DESIGN.md \u{a7}15 \u{2014} suppressions are part of the reviewed surface: a reason-less or malformed marker is itself a finding, so the allowlist population stays a deliberate trajectory",
        patterns: &[], // engine-internal: emitted while resolving markers
        include: &["rust/src/", "rust/benches/", "rust/tests/", "examples/", "Cargo.toml"],
        exclude: &[],
        exempt_tests: false,
    },
];

/// Look up a rule by id.
pub fn rule(id: &str) -> Option<&'static RuleSpec> {
    RULES.iter().find(|r| r.id == id)
}

/// Does `rule` apply to the file at root-relative `path`?
pub fn applies(rule: &RuleSpec, path: &str) -> bool {
    rule.include.iter().any(|p| path.starts_with(p))
        && !rule.exclude.iter().any(|p| path.starts_with(p))
}

/// Match one pattern against one lexed code line.
pub fn pattern_matches(pat: &Pattern, code: &str) -> bool {
    match pat {
        Pattern::Token(tok) => token_match(code, tok),
        Pattern::Const(needle) => {
            let norm: String =
                code.chars().filter(|&c| c != '_').map(|c| c.to_ascii_lowercase()).collect();
            norm.contains(needle)
        }
    }
}

/// Identifier-boundary literal search (see [`Pattern::Token`]).
fn token_match(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(tok) {
        let i = start + pos;
        let prev_ok = i == 0 || {
            let p = bytes[i - 1];
            !(p.is_ascii_alphabetic() || p == b'_')
        };
        let end = i + tok.len();
        let next_ok = end >= bytes.len() || {
            let n = bytes[end];
            !(n.is_ascii_alphanumeric() || n == b'_')
        };
        if prev_ok && next_ok {
            return true;
        }
        start = i + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_boundaries() {
        let t = Pattern::Token("f64");
        assert!(pattern_matches(&t, "fn f(x: f64) {}"));
        assert!(pattern_matches(&t, "let y = 2.0f64;"), "literal suffix counts");
        assert!(pattern_matches(&t, "f64::from_bits(b)"));
        assert!(pattern_matches(&t, "x as f64"));
        assert!(!pattern_matches(&t, "let e_f64 = 3;"), "identifier tail is not a use");
        assert!(!pattern_matches(&t, "let f64x = 3;"), "identifier head is not a use");
        assert!(!pattern_matches(&t, "F64_EXP_MASK"), "case-sensitive");
    }

    #[test]
    fn multi_segment_token() {
        let t = Pattern::Token("Instant::now");
        assert!(pattern_matches(&t, "let t0 = Instant::now();"));
        assert!(pattern_matches(&t, "std::time::Instant::now()"));
        assert!(!pattern_matches(&t, "use std::time::Instant;"));
    }

    #[test]
    fn const_pattern_ignores_grouping_and_case() {
        let c = Pattern::Const("0x9e3779b97f4a7c15");
        assert!(pattern_matches(&c, "wrapping_add(0x9E37_79B9_7F4A_7C15)"));
        assert!(pattern_matches(&c, "wrapping_add(0x9e3779b97f4a7c15)"));
        assert!(!pattern_matches(&c, "wrapping_add(0x9e3779b9)"));
    }

    #[test]
    fn policy_map_includes_and_excludes() {
        let wall = rule("wall-clock-quarantine").unwrap();
        assert!(applies(wall, "rust/src/coordinator/job.rs"));
        assert!(!applies(wall, "rust/src/metrics/mod.rs"));
        assert!(!applies(wall, "rust/src/bench_util.rs"));
        assert!(!applies(wall, "rust/benches/hotpath.rs"), "benches measure time by design");

        let nf = rule("native-float-quarantine").unwrap();
        assert!(applies(nf, "rust/src/softfloat/packed.rs"));
        assert!(!applies(nf, "rust/src/softfloat/encode.rs"), "carrier boundary is policy");
        assert!(!applies(nf, "rust/src/pde/heat1d.rs"));
    }

    #[test]
    fn inventory_ids_unique_and_nonempty() {
        let mut ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate rule id");
        assert!(n >= 6, "the catalog ships at least six rules");
    }
}
