//! `r2f2 audit` — the zero-dep static conformance pass (DESIGN.md §15).
//!
//! Every guarantee the reproduction rests on — packed/SWAR kernels
//! bit-identical to the scalar reference (§9/§14), the stochastic
//! draw-order contract (§14), cache soundness from bit-reproducible runs
//! (§12) — is a *source-level discipline*: one stray `f64` multiply in a
//! kernel module, one `HashMap` iteration on a result path, one ad-hoc RNG
//! silently voids contracts the dynamic suites can only probe pointwise.
//! This module makes the discipline statically checkable on every PR:
//!
//! * [`lexer`] — line-level lexing that strips comments and blanks
//!   string/char-literal contents, so rules never false-positive on them;
//! * [`rules`] — the rule inventory with its per-module policy map;
//! * [`report`] — findings with `file:line + rule id + quoted snippet`,
//!   the `r2f2-audit/1` JSON report, and the counts-only snapshot.
//!
//! Violations are suppressible only by an inline allow marker (grammar in
//! DESIGN.md §15): a comment carrying the marker trigger, `allow(<rule>)`
//! and a **non-empty reason**. A trailing marker covers its own line; a
//! marker on a comment-only line covers the next code line. Reason-less,
//! malformed or unknown-rule markers are findings themselves
//! (`allow-marker`), and stale markers that suppress nothing are surfaced
//! as `unused_markers` (non-gating).
//!
//! The CLI surface is `r2f2 audit [--json <out>] [--snapshot <out>]
//! [--rule <id>] [--root <dir>]`; the process exits non-zero on any
//! unsuppressed finding, which is what the CI `static-analysis` job gates
//! on.

pub mod lexer;
pub mod report;
pub mod rules;

pub use report::{Allow, AuditReport, Finding, UnusedMarker};
pub use rules::{RuleSpec, ALLOW_MARKER, RULES, ZERO_DEP};

use std::path::{Path, PathBuf};

/// Audit configuration.
#[derive(Debug, Clone)]
pub struct Options {
    /// Repository root (the directory holding `rust/src/lib.rs`).
    pub root: PathBuf,
    /// Restrict the report to one rule id.
    pub rule: Option<String>,
}

/// A marker resolved to the line it covers.
struct BoundMarker {
    /// 0-based index of the marker's own line.
    at: usize,
    /// 0-based index of the line it suppresses (None: dangled at EOF).
    target: Option<usize>,
    marker: lexer::Marker,
    used: bool,
}

fn truncate_snippet(raw: &str) -> String {
    let t = raw.trim();
    if t.chars().count() <= 120 {
        t.to_string()
    } else {
        let mut s: String = t.chars().take(117).collect();
        s.push_str("...");
        s
    }
}

/// Resolve every marker in `lines` to its covered line and emit the
/// `allow-marker` hygiene findings (malformed / unknown rule / missing
/// reason / self-allow) into `rep`.
fn bind_markers(path: &str, lines: &[lexer::LexedLine], rep: &mut AuditReport) -> Vec<BoundMarker> {
    let mut markers: Vec<BoundMarker> = Vec::new();
    let mut pending: Vec<usize> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let has_code = !line.code.trim().is_empty();
        if has_code {
            for mi in pending.drain(..) {
                markers[mi].target = Some(idx);
            }
        }
        if let Some(marker) = lexer::parse_marker(&line.comment) {
            let mi = markers.len();
            markers.push(BoundMarker {
                at: idx,
                target: if has_code { Some(idx) } else { None },
                marker,
                used: false,
            });
            if !has_code {
                pending.push(mi);
            }
        }
    }

    for bm in &markers {
        let snippet = truncate_snippet(&lines[bm.at].raw);
        let mut notes: Vec<String> = Vec::new();
        if let Some(why) = bm.marker.malformed {
            notes.push(why.to_string());
        }
        for id in &bm.marker.rules {
            if id == ALLOW_MARKER {
                notes.push("allow-marker is not suppressible".to_string());
            } else if rules::rule(id).is_none() {
                notes.push(format!("unknown rule `{id}`"));
            }
        }
        if bm.marker.malformed.is_none() && bm.marker.reason.is_empty() {
            notes.push("missing reason (`allow(<rule>)` needs a justification)".to_string());
        }
        for note in notes {
            rep.findings.push(Finding {
                file: path.to_string(),
                line: bm.at + 1,
                rule: ALLOW_MARKER.to_string(),
                snippet: snippet.clone(),
                note,
            });
        }
    }
    markers
}

/// Audit one Rust source file (the whole line-rule set + marker hygiene).
/// `path` is the repo-root-relative label the policy map keys on — tests
/// pass fixture labels like `rust/src/softfloat/mul.rs`.
pub fn audit_source(path: &str, src: &str) -> AuditReport {
    let mut rep = AuditReport { files_scanned: 1, ..AuditReport::default() };
    let lines = lexer::lex(src);
    let mut markers = bind_markers(path, &lines, &mut rep);

    for rule in RULES {
        if rule.patterns.is_empty() || !rules::applies(rule, path) {
            continue;
        }
        for (idx, line) in lines.iter().enumerate() {
            if rule.exempt_tests && line.in_test {
                continue;
            }
            if line.code.trim().is_empty() {
                continue;
            }
            if !rule.patterns.iter().any(|p| rules::pattern_matches(p, &line.code)) {
                continue;
            }
            // One finding per (line, rule) however many patterns hit.
            let covering = markers.iter_mut().find(|m| {
                m.target == Some(idx)
                    && m.marker.malformed.is_none()
                    && m.marker.rules.iter().any(|id| id == rule.id)
            });
            match covering {
                Some(m) => {
                    m.used = true;
                    rep.allows.push(Allow {
                        file: path.to_string(),
                        line: idx + 1,
                        rule: rule.id.to_string(),
                        reason: m.marker.reason.clone(),
                    });
                }
                None => rep.findings.push(Finding {
                    file: path.to_string(),
                    line: idx + 1,
                    rule: rule.id.to_string(),
                    snippet: truncate_snippet(&lines[idx].raw),
                    note: String::new(),
                }),
            }
        }
    }

    for m in &markers {
        if !m.used && m.marker.malformed.is_none() && !m.marker.rules.is_empty() {
            rep.unused.push(UnusedMarker {
                file: path.to_string(),
                line: m.at + 1,
                rules: m.marker.rules.join(", "),
            });
        }
    }
    rep
}

/// Audit one `Cargo.toml` for the `zero-dep` rule: every dependency
/// section (`[dependencies]`, `[dev-dependencies]`, `[build-dependencies]`,
/// `[target.….dependencies]`, `[workspace.dependencies]`) must be empty.
/// Suppression works like in Rust sources, with `#` comments.
pub fn audit_cargo_toml(path: &str, src: &str) -> AuditReport {
    let mut rep = AuditReport { files_scanned: 1, ..AuditReport::default() };
    // Reuse the marker binder by mapping TOML lines onto lexed lines:
    // `#` starts a comment (our manifests use no `#` inside strings).
    let lines: Vec<lexer::LexedLine> = src
        .lines()
        .map(|l| {
            let (code, comment) = match l.find('#') {
                Some(p) => (l[..p].to_string(), l[p + 1..].to_string()),
                None => (l.to_string(), String::new()),
            };
            lexer::LexedLine { code, comment, raw: l.to_string(), in_test: false }
        })
        .collect();
    let mut markers = bind_markers(path, &lines, &mut rep);

    let mut section = String::new();
    for (idx, line) in lines.iter().enumerate() {
        let t = line.code.trim();
        if t.starts_with('[') && t.ends_with(']') {
            section = t.trim_matches(['[', ']']).trim().to_string();
            continue;
        }
        let dep_section = section == "dependencies"
            || section == "dev-dependencies"
            || section == "build-dependencies"
            || section.ends_with(".dependencies");
        if !dep_section || t.is_empty() || !t.contains('=') {
            continue;
        }
        let covering = markers.iter_mut().find(|m| {
            m.target == Some(idx)
                && m.marker.malformed.is_none()
                && m.marker.rules.iter().any(|id| id == ZERO_DEP)
        });
        match covering {
            Some(m) => {
                m.used = true;
                rep.allows.push(Allow {
                    file: path.to_string(),
                    line: idx + 1,
                    rule: ZERO_DEP.to_string(),
                    reason: m.marker.reason.clone(),
                });
            }
            None => rep.findings.push(Finding {
                file: path.to_string(),
                line: idx + 1,
                rule: ZERO_DEP.to_string(),
                snippet: truncate_snippet(&line.raw),
                note: format!("dependency declared in [{section}]"),
            }),
        }
    }

    for m in &markers {
        if !m.used && m.marker.malformed.is_none() && !m.marker.rules.is_empty() {
            rep.unused.push(UnusedMarker {
                file: path.to_string(),
                line: m.at + 1,
                rules: m.marker.rules.join(", "),
            });
        }
    }
    rep
}

fn merge(into: &mut AuditReport, from: AuditReport) {
    into.findings.extend(from.findings);
    into.allows.extend(from.allows);
    into.unused.extend(from.unused);
    into.files_scanned += from.files_scanned;
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// The directories the auditor sweeps, relative to the repo root. The
/// per-rule policy map narrows further (e.g. only `unsafe-free` and
/// marker hygiene apply outside `rust/src/`).
pub const SCAN_DIRS: &[&str] = &["rust/src", "rust/benches", "rust/tests", "examples"];

/// The manifests the `zero-dep` rule parses.
pub const SCAN_MANIFESTS: &[&str] = &["Cargo.toml", "rust/Cargo.toml"];

/// Locate the repo root from the current directory (CLI runs from the
/// repo root; `cargo test` runs from `rust/`).
pub fn find_root() -> Result<PathBuf, String> {
    for cand in [".", "..", "../.."] {
        let p = PathBuf::from(cand);
        if p.join("rust/src/lib.rs").is_file() {
            return Ok(p);
        }
    }
    Err("cannot locate the repo root (no rust/src/lib.rs in ., .. or ../..)".to_string())
}

/// Run the audit over the real tree.
pub fn run(opts: &Options) -> Result<AuditReport, String> {
    let mut rep = AuditReport::default();
    let mut files: Vec<PathBuf> = Vec::new();
    for dir in SCAN_DIRS {
        let d = opts.root.join(dir);
        if d.is_dir() {
            walk_rs(&d, &mut files)?;
        }
    }
    if files.is_empty() {
        return Err(format!("no .rs files under {} — wrong --root?", opts.root.display()));
    }
    for f in &files {
        let rel = rel_label(&opts.root, f);
        let src =
            std::fs::read_to_string(f).map_err(|e| format!("read {}: {e}", f.display()))?;
        merge(&mut rep, audit_source(&rel, &src));
    }
    for m in SCAN_MANIFESTS {
        let p = opts.root.join(m);
        if p.is_file() {
            let src =
                std::fs::read_to_string(&p).map_err(|e| format!("read {}: {e}", p.display()))?;
            merge(&mut rep, audit_cargo_toml(m, &src));
        }
    }
    if let Some(only) = &opts.rule {
        if rules::rule(only).is_none() {
            let known: Vec<&str> = RULES.iter().map(|r| r.id).collect();
            return Err(format!("unknown rule `{only}` (known: {})", known.join(", ")));
        }
        rep.findings.retain(|f| &f.rule == only);
        rep.allows.retain(|a| &a.rule == only);
        // Unused markers are only meaningful for a whole-inventory run.
        rep.unused.clear();
    }
    rep.sort();
    Ok(rep)
}

fn rel_label(root: &Path, file: &Path) -> String {
    let rel = file.strip_prefix(root).unwrap_or(file);
    rel.to_string_lossy().replace('\\', "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn marker(rule: &str, reason: &str) -> String {
        format!("// {} allow({rule}) \u{2014} {reason}", lexer::marker_trigger())
    }

    #[test]
    fn finding_then_trailing_marker_suppresses() {
        let label = "rust/src/softfloat/mul.rs";
        let bad = "fn leak(x: f64) -> f64 { x }\n";
        let rep = audit_source(label, bad);
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, "native-float-quarantine");
        assert_eq!(rep.findings[0].line, 1);

        let ok = format!("fn leak(x: f64) -> f64 {{ x }} {}\n", marker("native-float-quarantine", "test shim"));
        let rep = audit_source(label, &ok);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
        assert_eq!(rep.allows.len(), 1);
        assert_eq!(rep.allows[0].reason, "test shim");
    }

    #[test]
    fn standalone_marker_covers_next_code_line() {
        let label = "rust/src/softfloat/packed.rs";
        let src = format!("{}\nfn b(x: f64) {{}}\n", marker("native-float-quarantine", "boundary"));
        let rep = audit_source(label, &src);
        assert!(rep.findings.is_empty());
        assert_eq!(rep.allows.len(), 1);
        assert_eq!(rep.allows[0].line, 2, "allow is recorded at the covered line");
    }

    #[test]
    fn one_finding_per_line_rule_pair() {
        let rep = audit_source("rust/src/softfloat/swar.rs", "fn f(a: f64, b: f64) -> (f64, f32) { (a, b as f32) }\n");
        assert_eq!(rep.findings.len(), 1, "many tokens on one line dedupe");
    }

    #[test]
    fn reasonless_marker_is_a_finding_but_still_suppresses() {
        let label = "rust/src/softfloat/mul.rs";
        let src = format!("fn leak(x: f64) {{}} // {} allow(native-float-quarantine)\n", lexer::marker_trigger());
        let rep = audit_source(label, &src);
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, ALLOW_MARKER);
        assert!(rep.findings[0].note.contains("missing reason"));
        assert_eq!(rep.allows.len(), 1, "the target violation is still visibly suppressed");
    }

    #[test]
    fn unknown_rule_marker_is_a_finding() {
        let src = format!("fn ok() {{}} {}\n", marker("no-such-rule", "whatever"));
        let rep = audit_source("rust/src/pde/mod.rs", &src);
        assert_eq!(rep.findings.len(), 1);
        assert!(rep.findings[0].note.contains("unknown rule"));
    }

    #[test]
    fn unused_marker_surfaced_not_gating() {
        let src = format!("fn ok() {{}} {}\n", marker("unsafe-free", "leftover"));
        let rep = audit_source("rust/src/pde/mod.rs", &src);
        assert!(rep.findings.is_empty());
        assert_eq!(rep.unused.len(), 1);
    }

    #[test]
    fn test_region_exemption_per_rule() {
        let src = "#[cfg(test)]\nmod tests {\n    fn helper(x: f64) {}\n    fn hole() { let p = 0; }\n}\n";
        let rep = audit_source("rust/src/softfloat/mul.rs", src);
        assert!(rep.findings.is_empty(), "native-float is test-exempt: {:?}", rep.findings);

        let src_unsafe = "#[cfg(test)]\nmod tests {\n    unsafe fn hole() {}\n}\n";
        let rep = audit_source("rust/src/softfloat/mul.rs", src_unsafe);
        assert_eq!(rep.findings.len(), 1, "unsafe-free is NOT test-exempt");
        assert_eq!(rep.findings[0].rule, "unsafe-free");
    }

    #[test]
    fn cargo_toml_dep_sections() {
        let clean = "[package]\nname = \"x\"\n\n[features]\npjrt = []\n";
        assert!(audit_cargo_toml("rust/Cargo.toml", clean).findings.is_empty());

        let dirty = "[dependencies]\nserde = \"1\"\n";
        let rep = audit_cargo_toml("rust/Cargo.toml", dirty);
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, ZERO_DEP);
        assert_eq!(rep.findings[0].line, 2);

        let allowed = format!(
            "[dependencies]\n# {} allow(zero-dep) \u{2014} vendored path dep for pjrt\nxla = {{ path = \"../xla\" }}\n",
            lexer::marker_trigger()
        );
        let rep = audit_cargo_toml("rust/Cargo.toml", &allowed);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
        assert_eq!(rep.allows.len(), 1);

        let dev = "[dev-dependencies]\nproptest = \"1\"\n";
        assert_eq!(audit_cargo_toml("Cargo.toml", dev).findings.len(), 1);
    }

    #[test]
    fn rule_filter_validated_and_applied() {
        let root = find_root().expect("repo root");
        let err = run(&Options { root: root.clone(), rule: Some("nope".into()) }).unwrap_err();
        assert!(err.contains("unknown rule"));
    }
}
