//! Line-level lexing for the static conformance pass (DESIGN.md §15).
//!
//! The rule engine must never false-positive on text that is not code: a
//! `f64` inside a doc comment, a `HashMap` inside a string literal, a
//! quote character inside a char literal. This lexer walks a source file
//! once and produces, per line,
//!
//! * `code` — the line with comments removed and the *contents* of string
//!   and char literals blanked (delimiters kept, so `"as f64"` lexes to
//!   `""` and can never match a pattern);
//! * `comment` — the concatenated comment text of the line, which is where
//!   audit allow markers live (and the only place they are recognized);
//! * `in_test` — whether the line sits in the file's test region.
//!
//! It is deliberately *not* a Rust parser: it understands exactly the
//! token forms that could hide a pattern or a marker — line comments,
//! nested block comments, string literals with escapes, raw strings
//! (`r"…"`, `r#"…"#`, any hash depth, multi-line), byte strings and
//! byte/char literals, and the char-literal-vs-lifetime ambiguity — and
//! nothing else. Everything it does is per-character and std-only.
//!
//! **Test region heuristic.** Module convention in this tree (enforced by
//! review, relied on here): the `#[cfg(test)] mod tests` block is the last
//! item of a file. The lexer marks every line from the first `#[cfg(test)]`
//! attribute to end-of-file as test code; rules that exempt tests skip
//! those lines. A `#[cfg(test)]` on an early item would over-exempt the
//! rest of the file — the conformance suite pins the heuristic instead
//! with fixtures.

/// One lexed source line.
#[derive(Debug, Clone)]
pub struct LexedLine {
    /// Code with comments removed and literal contents blanked.
    pub code: String,
    /// Concatenated comment text (line + block comments) on this line.
    pub comment: String,
    /// Raw line, untouched — findings quote this.
    pub raw: String,
    /// True from the first `#[cfg(test)]` attribute to end of file.
    pub in_test: bool,
}

/// Lexer state that survives a newline.
enum State {
    Code,
    /// Inside a block comment at the given nesting depth.
    Block(u32),
    /// Inside a normal string literal.
    Str,
    /// Inside a raw string literal closed by `"` + this many `#`.
    RawStr(u32),
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Lex a whole source file into per-line code/comment channels.
pub fn lex(src: &str) -> Vec<LexedLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut out: Vec<LexedLine> = Vec::new();
    let mut state = State::Code;
    let mut code = String::new();
    let mut comment = String::new();
    let mut raw = String::new();
    let mut in_test = false;
    let mut i = 0usize;

    macro_rules! flush_line {
        () => {{
            if !in_test && code.contains("#[cfg(test)]") {
                in_test = true;
            }
            out.push(LexedLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                raw: std::mem::take(&mut raw),
                in_test,
            });
        }};
    }

    while i < chars.len() {
        let c = chars[i];
        if c != '\n' {
            raw.push(c);
        }
        match state {
            State::Code => match c {
                '\n' => flush_line!(),
                '/' if chars.get(i + 1) == Some(&'/') => {
                    // Line comment: everything to end-of-line is comment
                    // text (doc comments included — they are comments).
                    let mut j = i + 2;
                    while j < chars.len() && chars[j] != '\n' {
                        comment.push(chars[j]);
                        raw.push(chars[j]);
                        j += 1;
                    }
                    i = j;
                    continue; // let the '\n' (or EOF) be handled above
                }
                '/' if chars.get(i + 1) == Some(&'*') => {
                    state = State::Block(1);
                    raw.push('*');
                    i += 2;
                    continue;
                }
                '"' => {
                    code.push('"');
                    state = State::Str;
                }
                'r' => {
                    // Possible raw string start: r"…", r#"…"#, br"…".
                    // The `r` must not continue an identifier (`writer"`
                    // is not a raw string) — a single `b` prefix is the
                    // byte-string exception.
                    let prev = code.chars().last();
                    let ident_prev = match prev {
                        Some('b') => {
                            let before = code.chars().rev().nth(1);
                            before.is_some_and(is_ident)
                        }
                        Some(p) => is_ident(p),
                        None => false,
                    };
                    let mut hashes = 0u32;
                    let mut j = i + 1;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if !ident_prev && chars.get(j) == Some(&'"') {
                        code.push_str("r\"");
                        for k in i + 1..=j {
                            if chars[k] != '\n' {
                                raw.push(chars[k]);
                            }
                        }
                        state = State::RawStr(hashes);
                        i = j + 1;
                        continue;
                    }
                    code.push('r');
                }
                '\'' => {
                    // Char literal vs lifetime. `'\…'` and `'x'` are
                    // literals (contents blanked); anything else is a
                    // lifetime tick, which stays in the code channel.
                    if chars.get(i + 1) == Some(&'\\') {
                        let mut j = i + 1;
                        while j < chars.len() {
                            match chars[j] {
                                '\\' => j += 2,
                                '\'' => break,
                                _ => j += 1,
                            }
                        }
                        code.push_str("''");
                        for k in i + 1..=j.min(chars.len() - 1) {
                            if chars[k] != '\n' {
                                raw.push(chars[k]);
                            }
                        }
                        i = j + 1;
                        continue;
                    }
                    if chars.get(i + 2) == Some(&'\'') {
                        code.push_str("''");
                        if chars[i + 1] != '\n' {
                            raw.push(chars[i + 1]);
                        }
                        raw.push('\'');
                        i += 3;
                        continue;
                    }
                    code.push('\'');
                }
                _ => code.push(c),
            },
            State::Block(depth) => match c {
                '\n' => flush_line!(),
                '*' if chars.get(i + 1) == Some(&'/') => {
                    raw.push('/');
                    i += 2;
                    if depth == 1 {
                        state = State::Code;
                        comment.push(' ');
                    } else {
                        state = State::Block(depth - 1);
                    }
                    continue;
                }
                '/' if chars.get(i + 1) == Some(&'*') => {
                    // Rust block comments nest.
                    raw.push('*');
                    i += 2;
                    state = State::Block(depth + 1);
                    continue;
                }
                _ => comment.push(c),
            },
            State::Str => match c {
                '\n' => flush_line!(), // strings may span lines
                '\\' => {
                    if let Some(&n) = chars.get(i + 1) {
                        if n != '\n' {
                            raw.push(n);
                        }
                        i += 2;
                        if n == '\n' {
                            flush_line!();
                        }
                        continue;
                    }
                }
                '"' => {
                    code.push('"');
                    state = State::Code;
                }
                _ => {} // blank string contents
            },
            State::RawStr(hashes) => match c {
                '\n' => flush_line!(),
                '"' => {
                    let mut ok = true;
                    for k in 0..hashes as usize {
                        if chars.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        code.push('"');
                        for k in 0..hashes as usize {
                            raw.push(chars[i + 1 + k]);
                        }
                        i += 1 + hashes as usize;
                        state = State::Code;
                        continue;
                    }
                }
                _ => {} // blank raw-string contents
            },
        }
        i += 1;
    }
    if !code.is_empty() || !comment.is_empty() || !raw.is_empty() {
        flush_line!();
    }
    out
}

/// A parsed audit allow marker (see DESIGN.md §15 for the grammar).
///
/// Recognition triggers on the marker literal — the tool name, `-audit`,
/// and a trailing colon (see [`marker_trigger`]) — inside a comment; prose
/// that mentions the marker *name* without the colon (like this sentence)
/// is never parsed. After the trigger the grammar is
/// `allow(<rule>[, <rule>…])` followed by a separator (`—`, `-` or `:`)
/// and a non-empty reason.
#[derive(Debug, Clone)]
pub struct Marker {
    /// Rule ids named inside `allow(…)` (empty when malformed).
    pub rules: Vec<String>,
    /// Free-text justification after the separator.
    pub reason: String,
    /// Set when the text after the trigger does not parse as `allow(…)`.
    pub malformed: Option<&'static str>,
}

/// The literal that makes a comment a marker. Built from pieces so the
/// auditor's own sources never contain the trigger in comment position.
pub fn marker_trigger() -> String {
    format!("{}-{}:", "r2f2", "audit")
}

/// Parse an audit marker out of a line's comment text, if present.
pub fn parse_marker(comment: &str) -> Option<Marker> {
    let trigger = marker_trigger();
    let at = comment.find(&trigger)?;
    let rest = comment[at + trigger.len()..].trim_start();
    let Some(inner_start) = rest.strip_prefix("allow(") else {
        return Some(Marker {
            rules: Vec::new(),
            reason: String::new(),
            malformed: Some("expected `allow(<rule>)` after the marker trigger"),
        });
    };
    let Some(close) = inner_start.find(')') else {
        return Some(Marker {
            rules: Vec::new(),
            reason: String::new(),
            malformed: Some("unclosed `allow(`"),
        });
    };
    let ids: Vec<String> = inner_start[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if ids.is_empty() {
        return Some(Marker {
            rules: Vec::new(),
            reason: String::new(),
            malformed: Some("empty rule list in `allow()`"),
        });
    }
    let reason = inner_start[close + 1..]
        .trim_start_matches([' ', '\t', '—', '–', '-', ':'])
        .trim()
        .to_string();
    Some(Marker { rules: ids, reason, malformed: None })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_stripped() {
        let c = code_of("let x = 1; // uses f64 internally\n");
        assert_eq!(c, vec!["let x = 1; "]);
    }

    #[test]
    fn doc_comments_stripped() {
        let c = code_of("/// encode an f64 slice\npub fn f() {}\n");
        assert_eq!(c, vec!["", "pub fn f() {}"]);
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let c = code_of("a /* x /* y */ f64 */ b\nc /* open\nstill f64\nclose */ d\n");
        assert_eq!(c, vec!["a  b", "c ", "", " d"]);
    }

    #[test]
    fn string_contents_blanked() {
        let c = code_of("let s = \"as f64\"; let t = 2;\n");
        assert_eq!(c, vec!["let s = \"\"; let t = 2;"]);
    }

    #[test]
    fn string_escapes_do_not_end_the_string() {
        let c = code_of("let s = \"a\\\"f64\\\"b\"; g();\n");
        assert_eq!(c, vec!["let s = \"\"; g();"]);
    }

    #[test]
    fn raw_strings_span_lines() {
        let src = "let s = r#\"line one f64\nline two HashMap\"#; tail();\n";
        let c = code_of(src);
        assert_eq!(c, vec!["let s = r\"", "\"; tail();"]);
    }

    #[test]
    fn raw_string_hash_depth_respected() {
        let c = code_of("let s = r##\"inner \"# still f64\"##; x();\n");
        assert_eq!(c, vec!["let s = r\"\"; x();"]);
    }

    #[test]
    fn identifier_ending_in_r_is_not_raw_string() {
        let c = code_of("writer\"f64\" + 1\n");
        // `writer` keeps its r; the quoted part is a normal string.
        assert_eq!(c, vec!["writer\"\" + 1"]);
    }

    #[test]
    fn char_literals_blanked_lifetimes_kept() {
        let c = code_of("let q = '\"'; let e = '\\''; fn f<'a>(x: &'a str) {}\n");
        assert_eq!(c, vec!["let q = ''; let e = ''; fn f<'a>(x: &'a str) {}"]);
    }

    #[test]
    fn test_region_marked_from_cfg_test() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n fn b() {}\n}\n";
        let l = lex(src);
        assert!(!l[0].in_test);
        assert!(l[1].in_test && l[2].in_test && l[3].in_test);
    }

    #[test]
    fn cfg_test_inside_string_does_not_start_region() {
        let src = "let s = \"#[cfg(test)]\";\nfn real() {}\n";
        let l = lex(src);
        assert!(!l[0].in_test && !l[1].in_test);
    }

    #[test]
    fn comment_channel_collects_text() {
        let l = lex("code(); // trailing words\n");
        assert_eq!(l[0].comment.trim(), "trailing words");
        assert_eq!(l[0].raw, "code(); // trailing words");
    }

    #[test]
    fn marker_parses_with_reason() {
        let m = parse_marker(&format!(" {} allow(unsafe-free) — ffi shim", marker_trigger()))
            .unwrap();
        assert_eq!(m.rules, vec!["unsafe-free"]);
        assert_eq!(m.reason, "ffi shim");
        assert!(m.malformed.is_none());
    }

    #[test]
    fn marker_multi_rule_and_ascii_separator() {
        let m = parse_marker(&format!("{} allow(a, b) - why not", marker_trigger())).unwrap();
        assert_eq!(m.rules, vec!["a", "b"]);
        assert_eq!(m.reason, "why not");
    }

    #[test]
    fn marker_without_reason_parses_empty() {
        let m = parse_marker(&format!("{} allow(unsafe-free)", marker_trigger())).unwrap();
        assert!(m.malformed.is_none());
        assert!(m.reason.is_empty());
    }

    #[test]
    fn marker_malformed_without_allow() {
        let m = parse_marker(&format!("{} allov(unsafe-free)", marker_trigger())).unwrap();
        assert!(m.malformed.is_some());
    }

    #[test]
    fn prose_without_colon_is_not_a_marker() {
        assert!(parse_marker("the r2f2-audit pass checks this").is_none());
    }
}
