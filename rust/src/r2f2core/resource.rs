//! FPGA resource (FF / LUT) cost model — the area columns of Table 1.
//!
//! The paper synthesizes the multipliers with Vitis HLS 2023 for a Pynq-Z2
//! board (DSPs disabled so LUT/FF is a clean area proxy). This environment
//! has no FPGA toolchain, so per DESIGN.md §6 we substitute a **structural
//! cost model**:
//!
//! * A fixed-format multiplier of width `ExMy` decomposes into an
//!   `(m+1)²` partial-product array (quadratic term), width-proportional
//!   datapath (converters, exponent adder, normalizer — linear term) and
//!   constant control logic. The three coefficients of
//!   `LUT = a·(m+1)² + b·(1+e+m) + c` (and likewise FF) are solved
//!   **exactly** from the paper's own three published baseline rows
//!   (Impl. 16/32/64-bit FP), so the model is anchored to the paper's
//!   toolchain, not invented.
//! * An R2F2 `<EB,MB,FX>` multiplier replaces the full array with a fixed
//!   `(MB+1)²` array plus the serial flexible unit, the masked exponent
//!   adder and the adjustment unit. Those extras are linear in `FX`,
//!   `MB+FX` and `EB+FX`; their four weights are least-squares calibrated
//!   on the paper's seven published R2F2 rows (fit residual < ±2% on every
//!   row — see the `model_matches_paper_*` tests). Negative weights on the
//!   `MB+FX` terms reflect the paper's design point that the mask-based
//!   flexible regions *avoid* large multiplexers (§4.1).
//!
//! The Table 1 bench prints paper vs model side by side; the claim being
//! reproduced is *relative* overhead (R2F2 within −5%..+7% of the 16-bit
//! baseline, ~37.9%/33.2% below single precision), which a structural model
//! with calibrated coefficients preserves.

use super::repr::R2f2Config;
use crate::softfloat::FpFormat;

/// Resource estimate for one multiplier instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resources {
    pub ff: f64,
    pub lut: f64,
}

impl Resources {
    /// Overhead of `self` relative to `base` (1.0 = equal).
    pub fn overhead(&self, base: &Resources) -> (f64, f64) {
        (self.ff / base.ff, self.lut / base.lut)
    }
}

/// Coefficients of the fixed-format model `a·(m+1)² + b·(1+e+m) + c`,
/// solved exactly from Table 1's Impl. 16/32/64-bit rows:
///
/// ```text
/// LUT:  121a + 16b + c = 4888   (E5M10)
///       576a + 32b + c = 8093   (E8M23)
///      2809a + 64b + c = 15650  (E11M52)
/// ```
const LUT_FIXED: [f64; 3] = [0.866_969_010, 175.658_069, 1_972.567_65];
const FF_FIXED: [f64; 3] = [0.300_075_586, 10.529_100_5, 515.225_246];

/// Calibrated weights of the R2F2 extras `w0 + w1·FX + w2·(MB+FX) +
/// w3·(EB+FX)` (least squares over the paper's seven R2F2 rows).
const LUT_FLEX: [f64; 4] = [417.853_625, -600.354_975, -192.118_429, 653.205_899];
const FF_FLEX: [f64; 4] = [-3.753_260_5, 14.505_303_8, -5.384_666_9, 3.245_522_3];

/// Paper-published Vitis HLS *library* rows (row 1–3 of Table 1). These are
/// opaque vendor IP with unknown optimizations; we report them alongside the
/// model output for completeness but cannot regenerate them structurally.
pub const LIB_ROWS: [(&str, u32, u32, u32, u32); 3] = [
    ("Lib. 64-bit FP (HLS)", 2180, 3264, 30, 11),
    ("Lib. 32-bit FP (HLS)", 492, 1438, 24, 5),
    ("Lib. 16-bit FP (HLS)", 318, 740, 26, 5),
];

fn fixed_model(coef: &[f64; 3], e: u32, m: u32) -> f64 {
    let m1 = (m + 1) as f64;
    coef[0] * m1 * m1 + coef[1] * (1 + e + m) as f64 + coef[2]
}

fn flex_model(coef: &[f64; 4], cfg: R2f2Config) -> f64 {
    coef[0]
        + coef[1] * cfg.fx as f64
        + coef[2] * (cfg.mb + cfg.fx) as f64
        + coef[3] * (cfg.eb + cfg.fx) as f64
}

/// Estimate a fixed-format multiplier (the "Impl. N-bit FP" rows).
pub fn fixed_multiplier(fmt: FpFormat) -> Resources {
    Resources {
        ff: fixed_model(&FF_FIXED, fmt.e_w, fmt.m_w),
        lut: fixed_model(&LUT_FIXED, fmt.e_w, fmt.m_w),
    }
}

/// Estimate an R2F2 multiplier: fixed-array base at the nominal widths plus
/// the flexible-unit / masked-adder / adjustment-unit extras.
pub fn r2f2_multiplier(cfg: R2f2Config) -> Resources {
    // Base: the datapath must carry the full flexible width (linear term
    // over all 1+EB+MB+FX storage bits) but only multiplies the fixed
    // (MB+1)² array in parallel.
    let base_lut = LUT_FIXED[0] * ((cfg.mb + 1) * (cfg.mb + 1)) as f64
        + LUT_FIXED[1] * cfg.total_bits() as f64
        + LUT_FIXED[2];
    let base_ff = FF_FIXED[0] * ((cfg.mb + 1) * (cfg.mb + 1)) as f64
        + FF_FIXED[1] * cfg.total_bits() as f64
        + FF_FIXED[2];
    Resources {
        ff: base_ff + flex_model(&FF_FLEX, cfg),
        lut: base_lut + flex_model(&LUT_FLEX, cfg),
    }
}

/// A Table 1 row as published in the paper, for side-by-side reporting.
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    pub name: &'static str,
    pub ff: u32,
    pub lut: u32,
    pub lat: u32,
    pub ii: u32,
}

/// The paper's Impl. + R2F2 rows of Table 1 (everything the model targets).
pub const PAPER_ROWS: [PaperRow; 10] = [
    PaperRow { name: "Impl. 64-bit FP", ff: 2032, lut: 15650, lat: 13, ii: 4 },
    PaperRow { name: "Impl. 32-bit FP", ff: 1025, lut: 8093, lat: 13, ii: 4 },
    PaperRow { name: "Impl. 16-bit FP", ff: 720, lut: 4888, lat: 12, ii: 4 },
    PaperRow { name: "R2F2 16-bit <3,9,3>", ff: 710, lut: 5161, lat: 12, ii: 4 },
    PaperRow { name: "R2F2 16-bit <3,8,4>", ff: 720, lut: 5132, lat: 12, ii: 4 },
    PaperRow { name: "R2F2 16-bit <3,7,5>", ff: 731, lut: 5152, lat: 12, ii: 4 },
    PaperRow { name: "R2F2 15-bit <3,8,3>", ff: 696, lut: 5091, lat: 12, ii: 4 },
    PaperRow { name: "R2F2 15-bit <3,7,4>", ff: 713, lut: 5082, lat: 12, ii: 4 },
    PaperRow { name: "R2F2 14-bit <3,7,3>", ff: 685, lut: 5028, lat: 12, ii: 4 },
    PaperRow { name: "R2F2 14-bit <3,6,4>", ff: 702, lut: 5249, lat: 12, ii: 4 },
];

#[cfg(test)]
mod tests {
    use super::*;

    fn rel_dev(model: f64, paper: u32) -> f64 {
        (model - paper as f64).abs() / paper as f64
    }

    #[test]
    fn fixed_model_reproduces_baselines_exactly() {
        // The 3×3 system was solved exactly; allow float round-off only.
        for (fmt, ff, lut) in [
            (FpFormat::E5M10, 720, 4888),
            (FpFormat::E8M23, 1025, 8093),
            (FpFormat::E11M52, 2032, 15650),
        ] {
            let r = fixed_multiplier(fmt);
            assert!(rel_dev(r.ff, ff) < 1e-4, "{fmt} ff={}", r.ff);
            assert!(rel_dev(r.lut, lut) < 1e-4, "{fmt} lut={}", r.lut);
        }
    }

    #[test]
    fn model_matches_paper_r2f2_rows_within_3pct() {
        let paper: [(R2f2Config, u32, u32); 7] = [
            (R2f2Config::C16_393, 710, 5161),
            (R2f2Config::C16_384, 720, 5132),
            (R2f2Config::C16_375, 731, 5152),
            (R2f2Config::C15_383, 696, 5091),
            (R2f2Config::C15_374, 713, 5082),
            (R2f2Config::C14_373, 685, 5028),
            (R2f2Config::C14_364, 702, 5249),
        ];
        for (cfg, ff, lut) in paper {
            let r = r2f2_multiplier(cfg);
            assert!(rel_dev(r.ff, ff) < 0.03, "{cfg} ff model={} paper={ff}", r.ff);
            assert!(rel_dev(r.lut, lut) < 0.03, "{cfg} lut model={} paper={lut}", r.lut);
        }
    }

    #[test]
    fn paper_headline_overheads_hold_in_model() {
        // §1: vs half, LUT overhead 3%..7% more, FF −5%..+2%;
        // vs single, −37.9% LUT and −33.2% FF (±few %).
        let half = fixed_multiplier(FpFormat::E5M10);
        let single = fixed_multiplier(FpFormat::E8M23);
        for cfg in R2f2Config::TABLE1 {
            let r = r2f2_multiplier(cfg);
            let (ff_oh, lut_oh) = r.overhead(&half);
            assert!(
                (0.93..=1.09).contains(&lut_oh),
                "{cfg} LUT overhead vs half = {lut_oh:.3}"
            );
            assert!(
                (0.93..=1.04).contains(&ff_oh),
                "{cfg} FF overhead vs half = {ff_oh:.3}"
            );
            let (ff_vs_single, lut_vs_single) = r.overhead(&single);
            assert!(lut_vs_single < 0.68, "{cfg} vs single LUT {lut_vs_single:.3}");
            assert!(ff_vs_single < 0.75, "{cfg} vs single FF {ff_vs_single:.3}");
        }
    }

    #[test]
    fn area_scales_with_mantissa_width() {
        // Sanity: the quadratic array term dominates growth.
        let small = fixed_multiplier(FpFormat::new(5, 8));
        let big = fixed_multiplier(FpFormat::new(5, 16));
        assert!(big.lut > small.lut);
        assert!(big.ff > small.ff);
    }
}
