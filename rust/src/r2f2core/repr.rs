//! The flexible `<EB, MB, FX>` representation (§4.1, Fig. 4a).

use crate::softfloat::FpFormat;
use std::fmt;

/// An R2F2 multiplier configuration: `EB` fixed exponent bits, `MB` fixed
/// mantissa bits and `FX` flexible bits. Total storage is `1 + EB + MB + FX`
/// bits. The paper writes this `<EB, MB, FX>`.
///
/// ```
/// use r2f2::r2f2core::{R2f2Config, R2f2Multiplier};
///
/// let cfg = R2f2Config::C16_393;               // the paper's 16-bit <3,9,3>
/// assert_eq!(cfg.total_bits(), 16);
/// assert_eq!(cfg.format(2).to_string(), "E5M10"); // split k=2 ≡ half's shape
/// assert_eq!(cfg.initial_k(), 2);              // starts at half's range
///
/// // 300 × 300 overflows E5M10; the unit widens its exponent and retries.
/// let mut unit = R2f2Multiplier::new(cfg);
/// let v = unit.mul(300.0, 300.0);
/// assert!((v - 90_000.0).abs() / 90_000.0 < 2e-3);
/// assert_eq!(unit.split(), 3);                 // now at E6M9
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct R2f2Config {
    /// Fixed exponent bits.
    pub eb: u32,
    /// Fixed mantissa bits.
    pub mb: u32,
    /// Flexible bits, assignable to either field at runtime.
    pub fx: u32,
}

impl R2f2Config {
    /// 16-bit `<3,9,3>` — the configuration of Figs. 6(a-d) and 7(a).
    pub const C16_393: R2f2Config = R2f2Config { eb: 3, mb: 9, fx: 3 };
    /// 16-bit `<3,8,4>`.
    pub const C16_384: R2f2Config = R2f2Config { eb: 3, mb: 8, fx: 4 };
    /// 16-bit `<3,7,5>`.
    pub const C16_375: R2f2Config = R2f2Config { eb: 3, mb: 7, fx: 5 };
    /// 15-bit `<3,8,3>` — Figs. 6(e) and 7(b).
    pub const C15_383: R2f2Config = R2f2Config { eb: 3, mb: 8, fx: 3 };
    /// 15-bit `<3,7,4>`.
    pub const C15_374: R2f2Config = R2f2Config { eb: 3, mb: 7, fx: 4 };
    /// 14-bit `<3,7,3>` — Fig. 6(f).
    pub const C14_373: R2f2Config = R2f2Config { eb: 3, mb: 7, fx: 3 };
    /// 14-bit `<3,6,4>`.
    pub const C14_364: R2f2Config = R2f2Config { eb: 3, mb: 6, fx: 4 };

    /// All configurations evaluated in Table 1, in the paper's row order.
    pub const TABLE1: [R2f2Config; 7] = [
        Self::C16_393,
        Self::C16_384,
        Self::C16_375,
        Self::C15_383,
        Self::C15_374,
        Self::C14_373,
        Self::C14_364,
    ];

    /// Construct and validate a configuration.
    pub const fn new(eb: u32, mb: u32, fx: u32) -> R2f2Config {
        assert!(eb >= 2 && eb <= 8, "EB must be in 2..=8");
        assert!(mb >= 1 && mb <= 24, "MB must be in 1..=24");
        assert!(fx >= 1 && fx <= 8, "FX must be in 1..=8");
        assert!(eb + fx <= 11, "EB+FX must fit the f64 carrier (≤ 11)");
        R2f2Config { eb, mb, fx }
    }

    /// Total storage bits, sign included.
    pub const fn total_bits(&self) -> u32 {
        1 + self.eb + self.mb + self.fx
    }

    /// The effective fixed format when `k` flexible bits serve the exponent.
    pub fn format(&self, k: u32) -> FpFormat {
        assert!(k <= self.fx, "split k={k} exceeds FX={}", self.fx);
        FpFormat::new(self.eb + k, self.mb + (self.fx - k))
    }

    /// Mask bits for split `k`: `1` = flexible bit serves the exponent
    /// (§4.1: "a bit 1'b1 means that the corresponding flexible bit is used
    /// by exponent"). The k exponent bits occupy the top of the flexible
    /// region.
    pub const fn mask(&self, k: u32) -> u32 {
        assert!(k <= self.fx);
        if k == 0 {
            0
        } else {
            (((1u32 << k) - 1) << (self.fx - k)) & ((1u32 << self.fx) - 1)
        }
    }

    /// Recover the split from a mask (number of leading ones).
    pub const fn split_of_mask(&self, mask: u32) -> u32 {
        // Masks are contiguous-from-the-top by construction.
        (mask << (32 - self.fx)).leading_ones()
    }

    /// Default initial split: start the exponent at 5 bits (standard half's
    /// range) when possible, so the multiplier behaves like the fixed
    /// baseline until the data says otherwise.
    pub fn initial_k(&self) -> u32 {
        (5u32.saturating_sub(self.eb)).min(self.fx)
    }

    /// Truncation width of the flexible partial products at split `k`
    /// (DESIGN.md §3): the hardware keeps only `FX` extra result bits beyond
    /// the fixed `2·MB`, dropping the lowest `t = max(0, 2·(FX−k) − FX)`
    /// product bits.
    pub const fn trunc_bits(&self, k: u32) -> u32 {
        let f = self.fx - k; // flexible bits currently on the mantissa
        if 2 * f > self.fx {
            2 * f - self.fx
        } else {
            0
        }
    }

    /// Widest exponent this configuration can reach (`k = FX`).
    pub fn max_exponent_format(&self) -> FpFormat {
        self.format(self.fx)
    }
}

impl fmt::Display for R2f2Config {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "<{},{},{}>", self.eb, self.mb, self.fx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_bits_matches_paper_configs() {
        assert_eq!(R2f2Config::C16_393.total_bits(), 16);
        assert_eq!(R2f2Config::C16_384.total_bits(), 16);
        assert_eq!(R2f2Config::C16_375.total_bits(), 16);
        assert_eq!(R2f2Config::C15_383.total_bits(), 15);
        assert_eq!(R2f2Config::C14_373.total_bits(), 14);
    }

    #[test]
    fn format_split_arithmetic() {
        let c = R2f2Config::C16_393;
        assert_eq!(c.format(0), FpFormat::new(3, 12));
        assert_eq!(c.format(2), FpFormat::new(5, 10)); // = E5M10 shape
        assert_eq!(c.format(3), FpFormat::new(6, 9));
    }

    #[test]
    fn paper_widest_range_for_384() {
        // §4.1: <3,8,4> at k=FX reaches E7M8, max ≈ 1.8410715e19.
        let f = R2f2Config::C16_384.max_exponent_format();
        assert_eq!(f, FpFormat::new(7, 8));
        assert!((f.max_value() - 1.8410715e19).abs() / 1.8410715e19 < 1e-7);
    }

    #[test]
    fn masks_are_contiguous_and_invertible() {
        let c = R2f2Config::new(3, 8, 4);
        assert_eq!(c.mask(0), 0b0000);
        assert_eq!(c.mask(1), 0b1000);
        assert_eq!(c.mask(2), 0b1100);
        assert_eq!(c.mask(4), 0b1111);
        for k in 0..=c.fx {
            assert_eq!(c.split_of_mask(c.mask(k)), k);
        }
    }

    #[test]
    fn initial_split_mimics_half_range() {
        assert_eq!(R2f2Config::C16_393.initial_k(), 2); // E5M10
        assert_eq!(R2f2Config::C15_383.initial_k(), 2); // E5M9
        assert_eq!(R2f2Config::C14_373.initial_k(), 2); // E5M8
        assert_eq!(R2f2Config::new(6, 8, 1).initial_k(), 0);
    }

    #[test]
    fn truncation_widths() {
        let c = R2f2Config::C16_393; // FX=3
        assert_eq!(c.trunc_bits(3), 0); // all flex on exponent: exact
        assert_eq!(c.trunc_bits(2), 0); // f=1, 2f=2 ≤ 3
        assert_eq!(c.trunc_bits(1), 1); // f=2, 2f=4 > 3
        assert_eq!(c.trunc_bits(0), 3); // f=3, 2f=6 > 3
    }

    #[test]
    fn display() {
        assert_eq!(R2f2Config::C16_393.to_string(), "<3,9,3>");
    }

    #[test]
    #[should_panic]
    fn oversized_split_panics() {
        let _ = R2f2Config::C16_393.format(4);
    }
}
