//! R2F2 — the paper's contribution (§4): a **R**untime **R**econ**F**igurable
//! **F**loating-point multiplier.
//!
//! A value is represented with four regions (§4.1, Fig. 4a): one sign bit, a
//! fixed exponent region of `EB` bits, a fixed mantissa region of `MB` bits,
//! and a flexible region of `FX` bits that can serve either field, selected
//! at runtime by mask bits. The effective format at split `k` (k = flexible
//! bits granted to the exponent) is `E(EB+k) M(MB+FX−k)`.
//!
//! Submodules:
//! * [`repr`] — the `<EB, MB, FX>` configuration, masks, and packing.
//! * [`mul`] — the multiplier with the paper's truncated flexible
//!   partial-product approximation.
//! * [`adjust`] — the dynamic precision-adjustment unit (§4.2): widen the
//!   exponent and retry on overflow/underflow; narrow it when the operands
//!   and result all show exponent redundancy.
//! * [`datapath`] — cycle-accurate model of the pipelined datapath
//!   (Table 1's latency / initiation-interval columns).
//! * [`resource`] — FPGA FF/LUT cost model (Table 1's area columns),
//!   calibrated on the paper's published synthesis results.

pub mod adjust;
pub mod datapath;
pub mod mul;
pub mod repr;
pub mod resource;

pub use adjust::{AdjustEvent, ConstOperand, EncSlot, R2f2Multiplier, Stats};
pub use mul::mul_packed;
pub use repr::R2f2Config;
