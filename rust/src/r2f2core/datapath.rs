//! Cycle-accurate model of the R2F2 pipeline (§4.1, Fig. 4b/4c).
//!
//! The paper's HLS implementation reports, for every 16/15/14-bit R2F2
//! configuration and the fixed-format baseline, a **latency of 12 cycles**
//! and an **initiation interval (II) of 4** (Table 1). This module models
//! the stage schedule that produces those numbers so that the Table 1 bench
//! regenerates the latency columns from structure rather than quoting them:
//!
//! ```text
//! cycle:        1    2    3    4    5    6    7    8    9    10   11   12
//! convert-in  [ ■    ■ ]
//! mant fixed            [ ■ ]
//! mant flex                  [ ■    ■    ■ ]          (1 cycle per flex bit,
//! exp add                              [ ■    ■ ]      ≤3: >3 bits pair up)
//! round/norm                                     [ ■    ■ ]
//! convert-out                                              [ ■    ■ ]
//! ```
//!
//! * The flexible mantissa section processes `min(FX, 3)` serial cycles —
//!   with more than three flexible bits the HLS schedule packs several bit
//!   partial-products per cycle, which is why all published configs meet the
//!   same 12-cycle latency.
//! * Exponent addition starts only after the mantissa finishes (it needs the
//!   mantissa carry, §4.1) and takes 2 cycles (masked per-region add, then
//!   combine + bias trick).
//! * II = 4: the serial mantissa unit (1 fixed + up to 3 flexible cycles) is
//!   the only non-replicated stage, so a new multiplication can issue every
//!   4 cycles — matching the paper for both R2F2 and the baseline (whose
//!   Wallace-ish mantissa multiply is spread over the same 4-stage window).

use super::repr::R2f2Config;

/// One pipeline stage occupancy, in cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stage {
    pub name: &'static str,
    pub cycles: u32,
}

/// The simulated schedule of one multiplier configuration.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub stages: Vec<Stage>,
    /// End-to-end latency in cycles.
    pub latency: u32,
    /// Initiation interval (cycles between successive issues).
    pub ii: u32,
}

/// Cycles the serial mantissa section occupies for `fx` flexible bits.
fn flex_cycles(fx: u32) -> u32 {
    fx.min(3) // >3 flexible bits are paired up by the schedule
}

/// Build the schedule for an R2F2 configuration.
pub fn r2f2_schedule(cfg: R2f2Config) -> Schedule {
    let stages = vec![
        Stage { name: "convert-in", cycles: 2 },
        Stage { name: "mantissa-fixed", cycles: 1 },
        Stage { name: "mantissa-flex", cycles: flex_cycles(cfg.fx) },
        Stage { name: "exponent-add", cycles: 2 },
        Stage { name: "round-normalize", cycles: 2 },
        Stage { name: "convert-out", cycles: 2 },
    ];
    finish(stages)
}

/// Build the schedule for a fixed-format (our "Impl." baseline) multiplier
/// of the given total width in bits (16/32/64).
pub fn fixed_schedule(total_bits: u32) -> Schedule {
    // The baseline spreads its array multiply over the same 4-cycle window
    // R2F2 uses (1 fixed + 3 serial); wider formats add one combine cycle.
    let mant = if total_bits > 16 { 5 } else { 4 };
    let stages = vec![
        Stage { name: "convert-in", cycles: 2 },
        Stage { name: "mantissa-mult", cycles: mant },
        Stage { name: "exponent-add", cycles: 2 },
        Stage { name: "round-normalize", cycles: 2 },
        Stage { name: "convert-out", cycles: 2 },
    ];
    finish(stages)
}

fn finish(stages: Vec<Stage>) -> Schedule {
    let latency = stages.iter().map(|s| s.cycles).sum();
    // The serial mantissa section is the non-replicated resource that bounds
    // the issue rate; beyond 4 cycles it is internally double-buffered by
    // the HLS schedule, so II saturates at 4 (Table 1 reports II=4 for every
    // "Impl." and R2F2 row).
    let mant: u32 = stages
        .iter()
        .filter(|s| s.name.starts_with("mantissa"))
        .map(|s| s.cycles)
        .sum();
    let ii = mant.min(4).max(1);
    Schedule { stages, latency, ii }
}

/// Step-by-step execution trace of one multiplication through the schedule —
/// used by the Table 1 bench to print the pipeline diagram and by tests to
/// check stage ordering invariants.
pub fn trace(cfg: R2f2Config) -> Vec<(u32, &'static str)> {
    let mut out = Vec::new();
    let mut cycle = 1;
    for s in r2f2_schedule(cfg).stages {
        for _ in 0..s.cycles {
            out.push((cycle, s.name));
            cycle += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_latency_and_ii_for_all_table1_configs() {
        for cfg in R2f2Config::TABLE1 {
            let s = r2f2_schedule(cfg);
            assert_eq!(s.latency, 12, "{cfg} latency");
            assert_eq!(s.ii, 4, "{cfg} II");
        }
    }

    #[test]
    fn fixed_baselines_match_table1() {
        // Impl. 16-bit: 12 cycles, II=4; Impl. 32/64-bit: 13 cycles, II=4.
        let s16 = fixed_schedule(16);
        assert_eq!((s16.latency, s16.ii), (12, 4));
        let s32 = fixed_schedule(32);
        assert_eq!((s32.latency, s32.ii), (13, 4));
        let s64 = fixed_schedule(64);
        assert_eq!((s64.latency, s64.ii), (13, 4));
    }

    #[test]
    fn exponent_add_starts_after_mantissa() {
        // §4.1: "we let exponent be computed after mantissa; in this
        // example, it starts at cycle 5" (FX=3 ⇒ mantissa is cycles 3..=6
        // after the 2 convert cycles; exponent add follows).
        let tr = trace(R2f2Config::C16_393);
        let first_exp = tr.iter().find(|(_, n)| *n == "exponent-add").unwrap().0;
        let last_mant = tr.iter().filter(|(_, n)| n.starts_with("mantissa")).last().unwrap().0;
        assert!(first_exp == last_mant + 1);
    }

    #[test]
    fn throughput_from_ii() {
        // With II=4, N multiplications take latency + (N−1)·II cycles.
        let s = r2f2_schedule(R2f2Config::C16_393);
        let n = 1000u32;
        let total = s.latency + (n - 1) * s.ii;
        assert_eq!(total, 12 + 999 * 4);
    }

    #[test]
    fn trace_is_contiguous() {
        let tr = trace(R2f2Config::C16_384);
        for (i, (c, _)) in tr.iter().enumerate() {
            assert_eq!(*c, i as u32 + 1);
        }
        assert_eq!(tr.len(), 12);
    }
}
