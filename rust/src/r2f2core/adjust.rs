//! The dynamic precision-adjustment unit (§4.2, Fig. 5).
//!
//! A stateful R2F2 multiplier instance: it holds the current flexible split
//! `k` (the mask) and adjusts it from the data flowing through:
//!
//! * **Widen** (`k += 1`): if the multiplication's *result* overflows or
//!   underflows, or an *operand* saturates on conversion, the exponent
//!   gains one flexible bit and the multiplication is **retried** with the
//!   updated precision ("it issues a signal to retry the multiplication
//!   using updated precision"). Retries cascade until the result fits or
//!   `k = FX`. Operand *underflow* does **not** widen: the converter
//!   flushes silently, as hardware flush-to-zero converters do — a
//!   saturated operand has unbounded error, a flushed one is bounded by the
//!   min normal. (Ablatable: [`R2f2Multiplier::widen_on_operand_underflow`].)
//! * **Narrow** (`k −= 1`): after a **streak** of multiplications whose
//!   operands *and* result all show exponent redundancy — the two bits
//!   following the exponent MSB differing from it — one flexible bit moves
//!   back to the mantissa for *subsequent* multiplications, improving
//!   resolution. The streak threshold (default 32) is the hysteresis that
//!   keeps one instance from oscillating when small- and large-range
//!   multiplications interleave; the paper's single-digit adjustment counts
//!   over millions of multiplications (§5.3) imply such damping even though
//!   Fig. 5 only draws the detector.
//!
//! The redundancy window is two bits: the paper found one bit "too
//! sensitive" and three bits "too conservative" (§4.2). Window width and
//! streak threshold are both exposed for the ablation bench.

use super::mul::{mul_packed, mul_packed_fast};
use super::repr::R2f2Config;
use crate::softfloat::{decode, encode, Flags, Fp, Rounder};

/// Counters exposed by a multiplier instance — the quantities the paper
/// reports in §5.3 ("precision adjustment because of overflow happened only
/// 5 times ...; because of redundancy ... 23 times").
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Stats {
    /// Total multiplications requested.
    pub muls: u64,
    /// Retries issued (one per `k` increment while a mul is in flight).
    pub overflow_adjustments: u64,
    /// Splits narrowed after redundancy was seen on operands and result.
    pub redundancy_adjustments: u64,
    /// Multiplications that still saturated/flushed at `k = FX`.
    pub unresolved_range_events: u64,
}

/// What the adjustment unit did for one multiplication.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdjustEvent {
    /// No precision change.
    None,
    /// Widened the exponent `retries` times and re-ran the multiplication.
    WidenedAndRetried { retries: u32 },
    /// Narrowed the exponent for subsequent operations.
    Narrowed,
}

/// A stateful runtime-reconfigurable multiplier (one hardware instance).
#[derive(Debug, Clone)]
pub struct R2f2Multiplier {
    cfg: R2f2Config,
    k: u32,
    rounder: Rounder,
    stats: Stats,
    /// Redundancy window width (bits examined after the exponent MSB).
    window: u32,
    /// Consecutive all-redundant multiplications required before narrowing.
    streak_threshold: u32,
    /// Current redundancy streak.
    streak: u32,
    /// Ablation switch: also widen when an operand flushes to zero.
    widen_on_operand_underflow: bool,
}

impl R2f2Multiplier {
    /// New instance at the configuration's default initial split.
    pub fn new(cfg: R2f2Config) -> R2f2Multiplier {
        Self::with_split(cfg, cfg.initial_k())
    }

    /// New instance at an explicit initial split.
    pub fn with_split(cfg: R2f2Config, k: u32) -> R2f2Multiplier {
        assert!(k <= cfg.fx);
        R2f2Multiplier {
            cfg,
            k,
            rounder: Rounder::nearest_even(),
            stats: Stats::default(),
            window: 2,
            streak_threshold: 32,
            streak: 0,
            widen_on_operand_underflow: false,
        }
    }

    /// Override the redundancy window width (ablation: 1 = "too sensitive",
    /// 3 = "too conservative" per §4.2).
    pub fn with_window(mut self, window: u32) -> R2f2Multiplier {
        assert!((1..=3).contains(&window));
        self.window = window;
        self
    }

    /// Override the narrowing hysteresis (1 = narrow on first detection,
    /// the literal reading of Fig. 5 — demonstrably oscillation-prone).
    pub fn with_streak_threshold(mut self, t: u32) -> R2f2Multiplier {
        assert!(t >= 1);
        self.streak_threshold = t;
        self
    }

    /// Ablation: treat operand flush-to-zero as a widen trigger too.
    pub fn widen_on_operand_underflow(mut self, on: bool) -> R2f2Multiplier {
        self.widen_on_operand_underflow = on;
        self
    }

    pub fn config(&self) -> R2f2Config {
        self.cfg
    }

    /// Current flexible split (bits granted to the exponent).
    pub fn split(&self) -> u32 {
        self.k
    }

    /// Current redundancy streak (exposed for cross-layer state checks).
    pub fn streak(&self) -> u32 {
        self.streak
    }

    /// Current mask bits (1 = flexible bit serves the exponent).
    pub fn mask(&self) -> u32 {
        self.cfg.mask(self.k)
    }

    pub fn stats(&self) -> Stats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = Stats::default();
    }

    /// Multiply `a × b`: convert the f64 operands into the current format,
    /// run the truncated multiplier, let the adjustment unit react, convert
    /// the result back (§5.2's conversion envelope).
    pub fn mul(&mut self, a: f64, b: f64) -> f64 {
        self.mul_traced(a, b).0
    }

    /// [`Self::mul`] that also reports what the adjustment unit did.
    pub fn mul_traced(&mut self, a: f64, b: f64) -> (f64, AdjustEvent) {
        self.mul_pair_machine(a, b, mul_packed)
    }

    /// The packed twin of [`Self::mul`] for pairs where **both** operands
    /// vary (the Fig. 6 sweep, the SWE flux squares): the same §4 state
    /// machine instantiated over the §9 u64 truncated datapath.
    /// Bit-identical to `mul` (`packed_vs_carrier.rs` polices it).
    pub fn mul_packed_pair(&mut self, a: f64, b: f64) -> f64 {
        self.mul_pair_machine(a, b, mul_packed_fast).0
    }

    /// The §4 widen/narrow state machine for a two-varying-operand
    /// multiplication, generic over the mantissa datapath — the `u128`
    /// specification ([`mul_packed`]) or the §9 `u64` fast path
    /// (`mul_packed_fast`). One copy of the state machine serves both
    /// engines, so they cannot drift.
    #[inline]
    fn mul_pair_machine<D>(&mut self, a: f64, b: f64, datapath: D) -> (f64, AdjustEvent)
    where
        D: Fn(Fp, Fp, R2f2Config, u32, &mut Rounder) -> (Fp, Flags),
    {
        self.stats.muls += 1;
        let mut retries = 0u32;
        loop {
            let fmt = self.cfg.format(self.k);
            let (fa, fla) = encode(a, fmt, &mut self.rounder);
            let (fb, flb) = encode(b, fmt, &mut self.rounder);
            let (fc, flc) = datapath(fa, fb, self.cfg, self.k, &mut self.rounder);

            // Widen triggers: result out of range, or an operand saturated
            // on conversion (unbounded error). Operand flush-to-zero is
            // silent unless the ablation switch is on.
            let operand_trouble = fla.overflow()
                || flb.overflow()
                || (self.widen_on_operand_underflow && (fla.underflow() || flb.underflow()));
            if operand_trouble || flc.range_event() {
                self.streak = 0;
                if self.k < self.cfg.fx {
                    // Widen the exponent by one flexible bit and retry.
                    self.k += 1;
                    self.stats.overflow_adjustments += 1;
                    retries += 1;
                    continue;
                }
                // Already at the widest exponent: accept the saturated /
                // flushed result (the hardware has no further recourse).
                self.stats.unresolved_range_events += 1;
                return (
                    decode(fc, fmt),
                    if retries > 0 { AdjustEvent::WidenedAndRetried { retries } } else { AdjustEvent::None },
                );
            }

            if retries > 0 {
                return (decode(fc, fmt), AdjustEvent::WidenedAndRetried { retries });
            }

            // Redundancy: narrow for subsequent multiplications once a full
            // streak of operations wasted exponent range.
            if self.k > 0
                && fmt.e_w >= self.window + 2
                && is_redundant(fa, fmt.e_w, self.window)
                && is_redundant(fb, fmt.e_w, self.window)
                && is_redundant(fc, fmt.e_w, self.window)
            {
                self.streak += 1;
                if self.streak >= self.streak_threshold {
                    self.streak = 0;
                    self.k -= 1;
                    self.stats.redundancy_adjustments += 1;
                    return (decode(fc, fmt), AdjustEvent::Narrowed);
                }
            } else {
                self.streak = 0;
            }
            return (decode(fc, fmt), AdjustEvent::None);
        }
    }
}

/// A constant multiplication operand pre-encoded at every split of one
/// configuration — the batched-engine fast path for the PDE stencils, where
/// one operand of every multiplication is a loop-invariant coefficient
/// (`r`, `2r`, `g/2`; see DESIGN.md §8).
///
/// [`encode`] is deterministic under round-to-nearest-even, so reusing the
/// cached encoding is bit-identical to re-encoding per multiplication. The
/// per-split redundancy verdict of the constant is precomputed too, since
/// the detector only looks at the packed exponent.
#[derive(Debug, Clone)]
pub struct ConstOperand {
    value: f64,
    /// Configuration the encodings were prepared for (guards against a
    /// cache prepared on one unit being replayed on another).
    cfg: R2f2Config,
    /// Per split `k`: packed encoding, encode flags, and whether the
    /// redundancy detector fires for it at that split's format.
    enc: Vec<(Fp, Flags, bool)>,
}

impl ConstOperand {
    /// The f64 value this cache was built from.
    pub fn value(&self) -> f64 {
        self.value
    }
}

/// One-slot cache of an encoded *varying* operand, keyed by (f64 bits,
/// split). The heat stencil reads each state value up to three times in a
/// sliding window; when the split has not changed in between, the second
/// and third encodes are free.
#[derive(Debug, Clone, Copy)]
pub struct EncSlot {
    bits: u64,
    k: u32,
    fp: Fp,
    fl: Flags,
    valid: bool,
}

impl EncSlot {
    /// An empty (always-miss) slot.
    pub fn empty() -> EncSlot {
        EncSlot { bits: 0, k: 0, fp: Fp::zero(0), fl: Flags::NONE, valid: false }
    }
}

impl R2f2Multiplier {
    /// Pre-encode a constant operand at every split of this unit's
    /// configuration, for use with [`R2f2Multiplier::mul_const`].
    pub fn prepare_const(&self, a: f64) -> ConstOperand {
        let mut rnd = Rounder::nearest_even();
        let enc = (0..=self.cfg.fx)
            .map(|k| {
                let fmt = self.cfg.format(k);
                let (fa, fla) = encode(a, fmt, &mut rnd);
                let red = fmt.e_w >= self.window + 2 && is_redundant(fa, fmt.e_w, self.window);
                (fa, fla, red)
            })
            .collect();
        ConstOperand { value: a, cfg: self.cfg, enc }
    }

    /// `self.mul(c.value(), b)` computed from the cached constant encoding:
    /// bit-identical result, identical state transitions and [`Stats`].
    pub fn mul_const(&mut self, c: &ConstOperand, b: f64) -> f64 {
        let mut slot = EncSlot::empty();
        self.mul_const_cached(c, b, &mut slot)
    }

    /// [`Self::mul_const`] with a caller-managed cache slot for the varying
    /// operand `b`. The slot is consulted when it holds the encoding of the
    /// same f64 bits at the current split, and refreshed otherwise; callers
    /// that stream overlapping windows (the heat stencil) rotate slots to
    /// skip most encodes.
    pub fn mul_const_cached(&mut self, c: &ConstOperand, b: f64, slot: &mut EncSlot) -> f64 {
        self.mul_const_machine(c, b, slot, mul_packed)
    }

    /// The **packed adjustment unit** (DESIGN.md §9): the cached-constant
    /// state machine instantiated over the §9 `u64` truncated datapath.
    /// The constant operand comes pre-packed at every split from
    /// [`Self::prepare_const`]; the varying operand lives in the caller's
    /// [`EncSlot`] and is **repacked only when `k` actually moves** (or the
    /// value changes). Bit-identical to [`Self::mul_const_cached`] — one
    /// shared state machine, two datapath instantiations. (The result is
    /// still returned through the f64 carrier: in `MulOnly` deployments the
    /// surrounding additions are f64 by definition, and `decode` is a
    /// direct bit construction since this PR.)
    pub fn mul_packed(&mut self, c: &ConstOperand, b: f64, slot: &mut EncSlot) -> f64 {
        self.mul_const_machine(c, b, slot, mul_packed_fast)
    }

    /// The §4 widen/narrow state machine for a cached-constant
    /// multiplication, generic over the mantissa datapath (see
    /// `mul_pair_machine`).
    #[inline]
    fn mul_const_machine<D>(
        &mut self,
        c: &ConstOperand,
        b: f64,
        slot: &mut EncSlot,
        datapath: D,
    ) -> f64
    where
        D: Fn(Fp, Fp, R2f2Config, u32, &mut Rounder) -> (Fp, Flags),
    {
        assert_eq!(c.cfg, self.cfg, "ConstOperand prepared for another configuration");
        self.stats.muls += 1;
        let bbits = b.to_bits();
        let mut retried = false;
        loop {
            let k = self.k;
            let fmt = self.cfg.format(k);
            let (fa, fla, a_red) = c.enc[k as usize];
            let (fb, flb) = if slot.valid && slot.bits == bbits && slot.k == k {
                (slot.fp, slot.fl)
            } else {
                let (fb, flb) = encode(b, fmt, &mut self.rounder);
                *slot = EncSlot { bits: bbits, k, fp: fb, fl: flb, valid: true };
                (fb, flb)
            };
            let (fc, flc) = datapath(fa, fb, self.cfg, k, &mut self.rounder);

            // Mirror of `mul_traced`, with the constant's encode flags and
            // redundancy verdict read from the cache.
            let operand_trouble = fla.overflow()
                || flb.overflow()
                || (self.widen_on_operand_underflow && (fla.underflow() || flb.underflow()));
            if operand_trouble || flc.range_event() {
                self.streak = 0;
                if self.k < self.cfg.fx {
                    self.k += 1;
                    self.stats.overflow_adjustments += 1;
                    retried = true;
                    continue;
                }
                self.stats.unresolved_range_events += 1;
                return decode(fc, fmt);
            }

            if retried {
                return decode(fc, fmt);
            }

            if self.k > 0
                && a_red
                && is_redundant(fb, fmt.e_w, self.window)
                && is_redundant(fc, fmt.e_w, self.window)
            {
                self.streak += 1;
                if self.streak >= self.streak_threshold {
                    self.streak = 0;
                    self.k -= 1;
                    self.stats.redundancy_adjustments += 1;
                }
            } else {
                self.streak = 0;
            }
            return decode(fc, fmt);
        }
    }
}

/// Exponent-redundancy detector (§4.2): the `window` bits following the
/// exponent MSB all differ from it. Zero values carry no exponent
/// information and are never considered redundant.
#[inline]
pub fn is_redundant(v: Fp, e_w: u32, window: u32) -> bool {
    if v.is_zero() {
        return false;
    }
    debug_assert!(e_w >= window + 2);
    let msb = (v.exp >> (e_w - 1)) & 1;
    for i in 1..=window {
        let bit = (v.exp >> (e_w - 1 - i)) & 1;
        if bit == msb {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;
    use crate::softfloat::FpFormat;

    #[test]
    fn redundancy_detector_matches_paper_example() {
        // §4.2: 8-bit exponent 10000111 (= 2^(135−127)) is redundant.
        let v = Fp { sign: 0, exp: 0b1000_0111, frac: 0 };
        assert!(is_redundant(v, 8, 2));
        // 1.0 in E8: exp = 127 = 01111111 → bits after MSB are 1s → redundant.
        let one = Fp { sign: 0, exp: 127, frac: 0 };
        assert!(is_redundant(one, 8, 2));
        // A large exponent (2^65: exp=192=11000000) is not redundant — the
        // bit right after the MSB repeats it.
        let big = Fp { sign: 0, exp: 192, frac: 0 };
        assert!(!is_redundant(big, 8, 2));
        // A very small exponent (2^-100: exp=27=00011011) is not redundant.
        let small = Fp { sign: 0, exp: 27, frac: 0 };
        assert!(!is_redundant(small, 8, 2));
        // Zero is never redundant.
        assert!(!is_redundant(Fp::zero(0), 8, 2));
    }

    #[test]
    fn redundancy_implies_narrowable() {
        // Whenever the detector fires, the value must be representable with
        // one fewer exponent bit — otherwise narrowing would corrupt data.
        for e_w in 4..=8u32 {
            let wide = FpFormat::new(e_w, 8);
            let narrow = FpFormat::new(e_w - 1, 9);
            for exp in 1..=(wide.max_biased_exp() as u32) {
                let v = Fp { sign: 0, exp, frac: 0 };
                if is_redundant(v, e_w, 2) {
                    let unbiased = exp as i64 - wide.bias();
                    let re = unbiased + narrow.bias();
                    assert!(
                        re >= 1 && re <= narrow.max_biased_exp(),
                        "e_w={e_w} exp={exp} unbiased={unbiased}"
                    );
                }
            }
        }
    }

    #[test]
    fn overflow_widens_and_retries() {
        // <3,9,3> starts at k=2 (E5M10, max 65504). 300×300=9e4 overflows
        // E5M10 but fits E6M9 (max ≈ 4.3e9 at k=3).
        let mut m = R2f2Multiplier::new(R2f2Config::C16_393);
        assert_eq!(m.split(), 2);
        let (v, ev) = m.mul_traced(300.0, 300.0);
        assert_eq!(ev, AdjustEvent::WidenedAndRetried { retries: 1 });
        assert_eq!(m.split(), 3);
        assert!((v - 90000.0).abs() / 90000.0 < 2e-3, "v={v}");
        assert_eq!(m.stats().overflow_adjustments, 1);
    }

    #[test]
    fn underflow_widens_and_retries() {
        // 1e-3 × 1e-3 = 1e-6 underflows E5M10 (min normal 6.1e-5) but fits
        // E6M9 (min normal ≈ 4.3e-10).
        let mut m = R2f2Multiplier::new(R2f2Config::C16_393);
        let (v, ev) = m.mul_traced(1e-3, 1e-3);
        assert!(matches!(ev, AdjustEvent::WidenedAndRetried { .. }));
        assert!(v != 0.0 && (v - 1e-6).abs() / 1e-6 < 2e-3, "v={v}");
    }

    #[test]
    fn redundancy_narrows_after_streak() {
        // Multiplying values near 1.0 wastes exponent range at k=2; after a
        // full streak the unit must shift bits back to the mantissa.
        let mut m = R2f2Multiplier::new(R2f2Config::C16_393);
        let k0 = m.split();
        let mut narrow_at = None;
        for i in 0..100 {
            let (_, ev) = m.mul_traced(1.1, 0.9);
            if ev == AdjustEvent::Narrowed {
                narrow_at = Some(i);
                break;
            }
        }
        // Fires exactly at the streak threshold (32 consecutive redundant
        // multiplications), not before.
        assert_eq!(narrow_at, Some(31));
        assert!(m.split() < k0);
        assert_eq!(m.stats().redundancy_adjustments, 1);
    }

    #[test]
    fn streak_threshold_one_narrows_immediately() {
        let mut m = R2f2Multiplier::new(R2f2Config::C16_393).with_streak_threshold(1);
        let (_, ev) = m.mul_traced(1.1, 0.9);
        assert_eq!(ev, AdjustEvent::Narrowed);
    }

    #[test]
    fn non_redundant_mul_resets_streak() {
        let mut m = R2f2Multiplier::new(R2f2Config::C16_393).with_streak_threshold(4);
        for _ in 0..3 {
            let _ = m.mul_traced(1.1, 0.9); // redundant
        }
        let _ = m.mul_traced(400.0, 1.5); // large exponent: breaks the streak
        for i in 0..4 {
            let (_, ev) = m.mul_traced(1.1, 0.9);
            if i < 3 {
                assert_eq!(ev, AdjustEvent::None);
            } else {
                assert_eq!(ev, AdjustEvent::Narrowed);
            }
        }
    }

    #[test]
    fn operand_flush_is_silent_by_default_but_ablatable() {
        // 1e-9 flushes at every split of <3,9,3> (even E6M9's min normal is
        // ≈4.3e-10 > 1e-9? no: 4.3e-10 < 1e-9, so it fits at k=3 — use 1e-10
        // which is below every split's min normal).
        let mut m = R2f2Multiplier::new(R2f2Config::C16_393);
        let (v, ev) = m.mul_traced(1e-10, 5.0);
        assert_eq!(v, 0.0); // operand flushed silently, product is zero
        assert_eq!(ev, AdjustEvent::None);
        assert_eq!(m.stats().overflow_adjustments, 0);

        let mut m = R2f2Multiplier::new(R2f2Config::C16_393).widen_on_operand_underflow(true);
        let (_, ev) = m.mul_traced(1e-10, 5.0);
        // With the ablation on, the unit widens (and still cannot represent
        // the operand, counting an unresolved event at k = FX).
        assert!(matches!(ev, AdjustEvent::WidenedAndRetried { .. }) || m.stats().unresolved_range_events > 0);
    }

    #[test]
    fn split_stays_in_bounds_under_random_traffic() {
        let cfg = R2f2Config::C16_384;
        let mut m = R2f2Multiplier::new(cfg);
        let mut rng = SplitMix64::new(3);
        for _ in 0..50_000 {
            let a = rng.log_uniform(1e-8, 1e8)
                * if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
            let b = rng.log_uniform(1e-8, 1e8);
            let v = m.mul(a, b);
            assert!(m.split() <= cfg.fx);
            assert!(v.is_finite());
        }
        assert_eq!(m.stats().muls, 50_000);
    }

    #[test]
    fn accuracy_beats_fixed_half_on_wide_range() {
        // The Fig. 6(a) story in miniature: on operands beyond E5M10's range
        // R2F2 keeps relative error small where the fixed type saturates.
        let mut m = R2f2Multiplier::new(R2f2Config::C16_393);
        let a = 5000.0;
        let b = 400.0; // product 2e6 >> 65504
        let v = m.mul(a, b);
        assert!((v - 2e6).abs() / 2e6 < 5e-3, "v={v}");
        let (fixed, fl) = crate::softfloat::mul_f(a, b, FpFormat::E5M10);
        assert!(fl.overflow());
        assert_eq!(fixed, 65504.0); // fixed half is hopeless here
    }

    #[test]
    fn result_exact_zero_times_anything() {
        let mut m = R2f2Multiplier::new(R2f2Config::C16_393);
        assert_eq!(m.mul(0.0, 123.0), 0.0);
        assert_eq!(m.mul(-7.0, 0.0), -0.0);
    }

    #[test]
    fn cascaded_widening_counts_each_step() {
        // Start from k=0 and feed a product needing k=3: three retries.
        let cfg = R2f2Config::C16_393;
        let mut m = R2f2Multiplier::with_split(cfg, 0);
        let (v, ev) = m.mul_traced(1000.0, 1000.0); // 1e6 needs E6
        assert_eq!(ev, AdjustEvent::WidenedAndRetried { retries: 3 });
        assert_eq!(m.stats().overflow_adjustments, 3);
        assert!((v - 1e6).abs() / 1e6 < 2e-3);
    }

    /// Two units stepped in lockstep must agree on everything observable.
    fn assert_units_equal(a: &R2f2Multiplier, b: &R2f2Multiplier, ctx: &str) {
        assert_eq!(a.split(), b.split(), "{ctx}: split");
        assert_eq!(a.streak(), b.streak(), "{ctx}: streak");
        assert_eq!(a.stats(), b.stats(), "{ctx}: stats");
    }

    #[test]
    fn mul_const_is_bit_identical_to_mul() {
        // The batched-engine contract (DESIGN.md §8): cached-constant
        // multiplication replays the exact scalar state machine, through
        // widen retries, narrowing streaks and unresolved saturations.
        for cfg in [R2f2Config::C16_393, R2f2Config::C16_384, R2f2Config::C14_373] {
            let mut scalar = R2f2Multiplier::new(cfg);
            let mut batched = R2f2Multiplier::new(cfg);
            let mut rng = SplitMix64::new(0x77);
            for &a in &[0.25, 0.5, 1.1, 4.9, 900.0, 1e-3] {
                let c = batched.prepare_const(a);
                assert_eq!(c.value(), a);
                for _ in 0..2000 {
                    let s = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                    let b = s * rng.log_uniform(1e-7, 1e7);
                    let want = scalar.mul(a, b);
                    let got = batched.mul_const(&c, b);
                    assert_eq!(got.to_bits(), want.to_bits(), "{cfg}: {a} × {b}");
                    assert_units_equal(&scalar, &batched, "after mul");
                }
            }
        }
    }

    #[test]
    fn mul_const_cached_slot_reuse_is_bit_identical() {
        // Repeating the same varying operand through a live slot (the heat
        // stencil's sliding window) must not change anything, even when the
        // split moves between repeats.
        let cfg = R2f2Config::C16_393;
        let mut scalar = R2f2Multiplier::new(cfg);
        let mut batched = R2f2Multiplier::new(cfg);
        let c = batched.prepare_const(0.25);
        let mut rng = SplitMix64::new(0x78);
        let mut slot = EncSlot::empty();
        for i in 0..3000 {
            // Mostly mid-range values with occasional range-busting spikes
            // so the split keeps moving while slots are warm.
            let b = if i % 97 == 0 { 3.0e5 } else { rng.log_uniform(1e-2, 1e2) };
            for _ in 0..3 {
                let want = scalar.mul(0.25, b);
                let got = batched.mul_const_cached(&c, b, &mut slot);
                assert_eq!(got.to_bits(), want.to_bits(), "iter {i}: 0.25 × {b}");
                assert_units_equal(&scalar, &batched, "after cached mul");
            }
        }
    }

    #[test]
    fn mul_packed_is_bit_identical_to_mul_const_cached() {
        // The packed adjustment unit replays the cached-carrier state
        // machine exactly — values, split, streak, stats — through widen
        // retries, narrowing streaks and warm-slot reuse.
        for cfg in [R2f2Config::C16_393, R2f2Config::C16_384, R2f2Config::C14_373] {
            let mut carrier = R2f2Multiplier::new(cfg);
            let mut packed = R2f2Multiplier::new(cfg);
            let mut rng = SplitMix64::new(0x79);
            for &a in &[0.25, 1.1, 4.9, 900.0, 1e-3] {
                let cc = carrier.prepare_const(a);
                let cp = packed.prepare_const(a);
                let mut sc = EncSlot::empty();
                let mut sp = EncSlot::empty();
                for i in 0..3000 {
                    let b = if i % 97 == 0 {
                        3.0e5
                    } else {
                        let s = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                        s * rng.log_uniform(1e-7, 1e7)
                    };
                    let reps = 1 + (i % 3);
                    for _ in 0..reps {
                        let want = carrier.mul_const_cached(&cc, b, &mut sc);
                        let got = packed.mul_packed(&cp, b, &mut sp);
                        assert_eq!(got.to_bits(), want.to_bits(), "{cfg}: {a} × {b}");
                        assert_units_equal(&carrier, &packed, "after packed mul");
                    }
                }
            }
        }
    }

    #[test]
    fn mul_packed_pair_is_bit_identical_to_mul() {
        for cfg in [R2f2Config::C16_393, R2f2Config::C16_384] {
            let mut scalar = R2f2Multiplier::new(cfg);
            let mut packed = R2f2Multiplier::new(cfg);
            let mut rng = SplitMix64::new(0x7A);
            for _ in 0..20_000 {
                let s = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                let a = s * rng.log_uniform(1e-8, 1e8);
                let b = rng.log_uniform(1e-8, 1e8);
                let want = scalar.mul(a, b);
                let got = packed.mul_packed_pair(a, b);
                assert_eq!(got.to_bits(), want.to_bits(), "{cfg}: {a} × {b}");
                assert_units_equal(&scalar, &packed, "after packed pair mul");
            }
        }
    }

    #[test]
    fn unresolved_at_max_split_saturates() {
        let cfg = R2f2Config::C16_393; // k=FX gives E6M9, max ≈ 4.6e9? (2^31·~2)
        let mut m = R2f2Multiplier::with_split(cfg, cfg.fx);
        let v = m.mul(1e9, 1e9); // 1e18 overflows E6M9
        let maxv = cfg.format(cfg.fx).max_value();
        assert_eq!(v, maxv);
        assert_eq!(m.stats().unresolved_range_events, 1);
    }
}
