//! The R2F2 multiplier datapath arithmetic (§4.1).
//!
//! Identical to the exact multiplier in [`crate::softfloat::mul`] except for
//! the paper's approximation: the hardware computes the flexible mantissa
//! bits serially and "only keep[s] FX extra bits", eliminating the lowest
//! partial-product bits. At split `k` (flexible mantissa width
//! `f = FX − k`), the full product would need `2·f` extra bits beyond the
//! fixed `2·MB`; keeping `FX` of them drops the lowest
//! `t = max(0, 2·f − FX)` bits (see `R2f2Config::trunc_bits` and
//! DESIGN.md §3). The same truncation is implemented bit-for-bit by the
//! Pallas kernel `python/compile/kernels/r2f2.py`.

use super::repr::R2f2Config;
use crate::softfloat::{
    mul::{normalize_round_pack, normalize_round_pack64},
    Flags, Fp, Rounder,
};

/// Multiply two values packed in `cfg.format(k)`, applying the flexible
/// partial-product truncation for split `k`.
///
/// Returns the packed product and flags (overflow ⇒ saturated, underflow ⇒
/// flushed — the signals the adjustment unit reacts to).
#[inline]
pub fn mul_packed(a: Fp, b: Fp, cfg: R2f2Config, k: u32, r: &mut Rounder) -> (Fp, Flags) {
    let fmt = cfg.format(k);
    let sign = a.sign ^ b.sign;
    if a.is_zero() || b.is_zero() {
        return (Fp::zero(sign), Flags::NONE);
    }

    let m_w = fmt.m_w;
    let ia = (1u64 << m_w) | a.frac;
    let ib = (1u64 << m_w) | b.frac;
    let mut p = ia as u128 * ib as u128;

    // The paper's approximation: drop the lowest t partial-product bits
    // (they would only feed rounding; §4.1 measures the effect at "<0.1%
    // error in <0.04% of cases").
    let t = cfg.trunc_bits(k);
    if t > 0 {
        p &= !((1u128 << t) - 1);
    }

    normalize_round_pack(p, sign, a.exp as i64 + b.exp as i64, fmt, r)
}

/// [`mul_packed`] with 64-bit intermediates — the packed-domain engine's
/// datapath (DESIGN.md §9). For `m_w ≤ 30` (every valid `<EB,MB,FX>` at
/// every split of the paper's configurations) the raw mantissa product fits
/// `u64`, so the `u128` multiply and shifts of the specification path are
/// avoided; wider splits fall back to [`mul_packed`]. Bit-identical either
/// way, including the truncation mask and the rounding draw sequence.
#[inline]
pub(crate) fn mul_packed_fast(
    a: Fp,
    b: Fp,
    cfg: R2f2Config,
    k: u32,
    r: &mut Rounder,
) -> (Fp, Flags) {
    let fmt = cfg.format(k);
    if fmt.m_w > 30 {
        return mul_packed(a, b, cfg, k, r);
    }
    let sign = a.sign ^ b.sign;
    if a.is_zero() || b.is_zero() {
        return (Fp::zero(sign), Flags::NONE);
    }

    let m_w = fmt.m_w;
    let ia = (1u64 << m_w) | a.frac;
    let ib = (1u64 << m_w) | b.frac;
    let mut p = ia * ib; // 2·m_w + 2 ≤ 62 bits: fits u64

    let t = cfg.trunc_bits(k);
    if t > 0 {
        p &= !((1u64 << t) - 1);
    }

    normalize_round_pack64(p, sign, a.exp as i64 + b.exp as i64, fmt, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;
    use crate::softfloat::{decode, encode, mul as exact_mul, FpFormat};

    fn enc(x: f64, fmt: FpFormat) -> Fp {
        encode(x, fmt, &mut Rounder::nearest_even()).0
    }

    #[test]
    fn k_max_split_is_exact() {
        // k = FX ⇒ no flexible mantissa bits ⇒ truncation width 0 ⇒ must be
        // bit-identical to the exact softfloat multiplier.
        let cfg = R2f2Config::C16_393;
        let k = cfg.fx;
        let fmt = cfg.format(k);
        let mut rng = SplitMix64::new(17);
        let mut r1 = Rounder::nearest_even();
        let mut r2 = Rounder::nearest_even();
        for _ in 0..20_000 {
            let a = enc(rng.log_uniform(1e-6, 1e6), fmt);
            let b = enc(rng.log_uniform(1e-6, 1e6), fmt);
            assert_eq!(
                mul_packed(a, b, cfg, k, &mut r1),
                exact_mul(a, b, fmt, &mut r2)
            );
        }
    }

    #[test]
    fn truncation_error_is_rare_and_tiny() {
        // §4.1: the approximation "only introduces errors smaller than 0.1%
        // in less than 0.04% of the time". Validate at the worst split
        // (k=0, maximum truncation) of <3,9,3>.
        let cfg = R2f2Config::C16_393;
        let k = 0;
        let fmt = cfg.format(k);
        let mut rng = SplitMix64::new(23);
        let mut n_diff = 0u32;
        let n = 200_000;
        for _ in 0..n {
            let a = enc(rng.log_uniform(0.5, 2.0), fmt);
            let b = enc(rng.log_uniform(0.5, 2.0), fmt);
            let (p_apx, _) = mul_packed(a, b, cfg, k, &mut Rounder::nearest_even());
            let (p_ex, _) = exact_mul(a, b, fmt, &mut Rounder::nearest_even());
            if p_apx != p_ex {
                n_diff += 1;
                let va = decode(p_apx, fmt);
                let ve = decode(p_ex, fmt);
                let rel = ((va - ve) / ve).abs();
                assert!(rel < 1e-3, "truncation error too large: {rel}");
            }
        }
        let frac = n_diff as f64 / n as f64;
        // The paper claims <0.04%; allow a conservative bound of 0.1%.
        assert!(frac < 1e-3, "approximation fired too often: {frac}");
    }

    #[test]
    fn truncated_result_never_above_exact() {
        // Truncation clears low product bits, so before rounding the
        // approximate significand is ≤ exact; after RNE they may still tie,
        // but |approx| ≤ |exact| must hold.
        let cfg = R2f2Config::C16_384; // FX=4
        let k = 1; // f = 3, t = 2
        let fmt = cfg.format(k);
        let mut rng = SplitMix64::new(41);
        for _ in 0..20_000 {
            let a = enc(rng.log_uniform(1e-2, 1e2), fmt);
            let b = enc(rng.log_uniform(1e-2, 1e2), fmt);
            let (p_apx, _) = mul_packed(a, b, cfg, k, &mut Rounder::nearest_even());
            let (p_ex, _) = exact_mul(a, b, fmt, &mut Rounder::nearest_even());
            assert!(
                decode(p_apx, fmt).abs() <= decode(p_ex, fmt).abs(),
                "a={:?} b={:?}",
                a,
                b
            );
        }
    }

    #[test]
    fn wide_range_covered_at_high_k() {
        // At k=FX, <3,8,4> must represent products near 1e19 (§4.1).
        let cfg = R2f2Config::C16_384;
        let k = cfg.fx;
        let fmt = cfg.format(k);
        let a = enc(3.0e9, fmt);
        let b = enc(4.0e9, fmt);
        let (p, fl) = mul_packed(a, b, cfg, k, &mut Rounder::nearest_even());
        assert!(!fl.overflow());
        let v = decode(p, fmt);
        assert!((v - 1.2e19).abs() / 1.2e19 < 0.01, "v={v}");
    }

    #[test]
    fn fast_datapath_matches_specification_all_splits() {
        // The u64 packed-domain datapath must agree with the u128
        // specification on every split, including truncating ones, zeros
        // and range-event operands.
        let mut rng = SplitMix64::new(0x2F);
        for cfg in [R2f2Config::C16_393, R2f2Config::C16_384, R2f2Config::C14_373] {
            for k in 0..=cfg.fx {
                let fmt = cfg.format(k);
                let mut r1 = Rounder::nearest_even();
                let mut r2 = Rounder::nearest_even();
                for i in 0..10_000 {
                    let a = if i % 50 == 0 {
                        Fp::zero((i % 100 == 0) as u8)
                    } else {
                        enc(rng.log_uniform(1e-8, 1e8), fmt)
                    };
                    let b = enc(rng.log_uniform(1e-8, 1e8), fmt);
                    assert_eq!(
                        mul_packed_fast(a, b, cfg, k, &mut r1),
                        mul_packed(a, b, cfg, k, &mut r2),
                        "{cfg} k={k} a={a:?} b={b:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn overflow_flag_raised_at_narrow_split() {
        let cfg = R2f2Config::C16_393;
        let k = 0; // E3M12: max value ≈ 16
        let fmt = cfg.format(k);
        let a = enc(8.0, fmt);
        let (_, fl) = mul_packed(a, a, cfg, k, &mut Rounder::nearest_even());
        assert!(fl.overflow());
    }
}
