//! Fig. 3: "Average computation error using different configurations for
//! floating point precision" — profile arbitrary `ExMy` configurations over
//! operand ranges, and check the paper's Eq. (1) intuition against the
//! profiled optimum (§3.2).

use crate::rng::SplitMix64;
use crate::softfloat::{mul_f, FpFormat};

/// The operand ranges discussed in §3.2 / Fig. 3.
pub const PAPER_RANGES: [(f64, f64); 4] =
    [(0.05, 0.07), (4.0, 5.0), (100.0, 110.0), (1000.0, 1100.0)];

/// Average multiplication error of one configuration over one range.
#[derive(Debug, Clone, Copy)]
pub struct ProfilePoint {
    pub fmt: FpFormat,
    /// Mean relative error vs the 32-bit result, overflow/underflow cast to
    /// 100% (the paper's convention).
    pub avg_err: f64,
}

/// Profile `configs` over uniform operand pairs from `[lo, hi)`.
///
/// Error definition (§5.1): relative to the single-precision product;
/// range events count as 100% error.
pub fn profile_range(
    lo: f64,
    hi: f64,
    configs: &[FpFormat],
    pairs: usize,
    seed: u64,
) -> Vec<ProfilePoint> {
    let mut rng = SplitMix64::new(seed);
    // Pre-draw the operand set so every configuration sees identical data.
    let ops: Vec<(f64, f64)> =
        (0..pairs).map(|_| (rng.range_f64(lo, hi), rng.range_f64(lo, hi))).collect();

    configs
        .iter()
        .map(|&fmt| {
            let mut sum = 0.0;
            for &(a, b) in &ops {
                let want = (a as f32 * b as f32) as f64;
                let (got, flags) = mul_f(a, b, fmt);
                let err = if flags.range_event() || want == 0.0 {
                    1.0
                } else {
                    ((got - want) / want).abs().min(1.0)
                };
                sum += err;
            }
            ProfilePoint { fmt, avg_err: sum / pairs as f64 }
        })
        .collect()
}

/// 16-bit configuration family `E{e}M{15−e}` for the Fig. 3 x-axis.
pub fn sixteen_bit_family() -> Vec<FpFormat> {
    (2..=8).map(|e| FpFormat::new(e, 15 - e)).collect()
}

/// The paper's Eq. (1) intuition for exponent bits given `v_max`
/// (empirically the paper evaluates the log base-10 — its worked examples
/// `(0.05,0.07) → 4`, `(100,110) → 6`, `(1000,1100) → 8` only hold for
/// log₁₀; see §3.2 where the profiled optimum *disagrees* with this
/// formula, which is the figure's point).
pub fn eq1_exponent_bits(v_max: f64) -> u32 {
    let x = if v_max >= 1.0 { v_max * v_max } else { (1.0 / v_max) * (1.0 / v_max) };
    x.log10().ceil() as u32 + 1
}

/// The profiled optimum: configuration with minimal average error.
pub fn best_of(points: &[ProfilePoint]) -> ProfilePoint {
    *points
        .iter()
        .min_by(|a, b| a.avg_err.partial_cmp(&b.avg_err).unwrap())
        .expect("non-empty profile")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_matches_paper_worked_examples() {
        assert_eq!(eq1_exponent_bits(0.07), 4); // §3.2: "suggests 4 bits"
        assert_eq!(eq1_exponent_bits(110.0), 6); // "suggests 6"
        assert_eq!(eq1_exponent_bits(1100.0), 8); // "suggests 8"
    }

    #[test]
    fn small_range_profile_prefers_5bit_exponent() {
        // §3.2: "multiplications within range (0.05, 0.07) favor 5-bit
        // exponent and 10/11-bit mantissa" — products ≈ 2.5e-3..4.9e-3
        // underflow E4 (min normal 2^-6) but fit E5.
        let pts = profile_range(0.05, 0.07, &sixteen_bit_family(), 400, 1);
        let best = best_of(&pts);
        assert_eq!(best.fmt.e_w, 5, "profiled best {}", best.fmt);
    }

    #[test]
    fn eq1_disagrees_with_profile_on_small_range() {
        // The paper's core §3.2 observation: the intuition formula and the
        // profiled optimum differ — here Eq.(1) says 4, profiling says 5.
        let pts = profile_range(0.05, 0.07, &sixteen_bit_family(), 400, 1);
        assert_ne!(best_of(&pts).fmt.e_w, eq1_exponent_bits(0.07));
    }

    #[test]
    fn mid_range_profile_prefers_small_exponent() {
        // (4,5): products 16..25 — covered from E4 up (E3's reserved-top
        // max is ~16; see EXPERIMENTS.md note about the paper's E3 claim).
        let pts = profile_range(4.0, 5.0, &sixteen_bit_family(), 400, 2);
        let best = best_of(&pts);
        assert_eq!(best.fmt.e_w, 4, "profiled best {}", best.fmt);
    }

    #[test]
    fn larger_ranges_need_more_exponent() {
        // (1000,1100): products ≈ 1e6..1.2e6 need e_w ≥ 6 (E5 max 65504).
        let pts = profile_range(1000.0, 1100.0, &sixteen_bit_family(), 400, 3);
        let best = best_of(&pts);
        assert_eq!(best.fmt.e_w, 6, "profiled best {}", best.fmt);
        // And the trend across ranges is monotone non-decreasing.
        let small = best_of(&profile_range(4.0, 5.0, &sixteen_bit_family(), 400, 4));
        assert!(best.fmt.e_w > small.fmt.e_w);
    }

    #[test]
    fn identical_operands_across_configs() {
        // Two calls with the same seed must produce identical profiles
        // (paired comparison, not re-sampled noise).
        let a = profile_range(0.05, 0.07, &sixteen_bit_family(), 200, 7);
        let b = profile_range(0.05, 0.07, &sixteen_bit_family(), 200, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.avg_err, y.avg_err);
        }
    }

    #[test]
    fn errors_are_capped_at_one() {
        let pts = profile_range(1000.0, 1100.0, &[FpFormat::new(2, 13)], 100, 5);
        assert!(pts[0].avg_err <= 1.0);
        assert!(pts[0].avg_err > 0.99, "E2 must overflow this range");
    }
}
