//! Fig. 6: the multiplication-accuracy sweep.
//!
//! §5.1: "we sweep the range (0.0001, 10000) for operands, divided into 10K
//! intervals, and each interval has 1000 randomly sampled data pairs."
//! Per interval we measure the mean relative error (vs the single-precision
//! product; overflow/underflow cast to 100%, the paper's convention) of the
//! R2F2 multiplier and of its fixed-type counterpart, then report the
//! per-interval error-reduction distribution of Fig. 6(g).

use crate::coordinator::{default_workers, parallel_map};
use crate::pde::scenario::{self, ScenarioSize};
use crate::pde::{rel_l2, Arith, F64Arith, FixedArith, QuantMode, R2f2Arith};
use crate::r2f2core::R2f2Config;
use crate::rng::SplitMix64;
use crate::softfloat::FpFormat;

/// Sweep configuration.
#[derive(Debug, Clone, Copy)]
pub struct SweepParams {
    pub lo: f64,
    pub hi: f64,
    /// Number of log-spaced operand intervals.
    pub intervals: usize,
    /// Random operand pairs per interval.
    pub pairs: usize,
    pub seed: u64,
    /// Worker threads the intervals are sharded over
    /// (`coordinator::parallel_map`). Results are **bit-identical for any
    /// worker count**: every interval draws from its own seed, derived
    /// sequentially from `seed`.
    pub workers: usize,
}

impl Default for SweepParams {
    fn default() -> SweepParams {
        // The paper's full sweep. Benches use this; unit tests shrink it.
        SweepParams {
            lo: 1e-4,
            hi: 1e4,
            intervals: 10_000,
            pairs: 1000,
            seed: 0x516,
            workers: default_workers(),
        }
    }
}

/// Per-interval outcome.
#[derive(Debug, Clone, Copy)]
pub struct IntervalResult {
    /// Interval bounds (operands are drawn log-uniformly inside).
    pub lo: f64,
    pub hi: f64,
    /// Mean relative error of the fixed format.
    pub err_fixed: f64,
    /// Mean relative error of R2F2.
    pub err_r2f2: f64,
}

impl IntervalResult {
    /// Relative error reduction of R2F2 vs the fixed type (can be negative
    /// where the truncation approximation loses — Fig. 6(d)'s dips).
    pub fn reduction(&self) -> f64 {
        if self.err_fixed == 0.0 {
            0.0
        } else {
            (self.err_fixed - self.err_r2f2) / self.err_fixed
        }
    }
}

/// Whole-sweep outcome.
///
/// Two aggregations of "error reduction" are reported because the paper's
/// exact definition is not fully specified: [`SweepResult::avg_reduction`]
/// (mean over intervals of the per-interval relative reduction — the
/// conservative reading) and [`SweepResult::global_reduction`] (reduction
/// of the error mass pooled over all samples — the generous reading).
/// The paper's 70.2% falls between the two; see EXPERIMENTS.md E5.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub cfg: R2f2Config,
    pub fixed: FpFormat,
    pub intervals: Vec<IntervalResult>,
    /// Mean of per-interval reductions.
    pub avg_reduction: f64,
    /// Maximum per-interval reduction (paper: up to 99.9%).
    pub max_reduction: f64,
    /// Most negative per-interval reduction (paper: R2F2 occasionally worse
    /// due to the mantissa truncation; largest regression 0.09% error).
    pub min_reduction: f64,
    /// Pooled mean error of the fixed type over the whole sweep.
    pub global_err_fixed: f64,
    /// Pooled mean error of R2F2 over the whole sweep.
    pub global_err_r2f2: f64,
    /// `1 − global_err_r2f2 / global_err_fixed`.
    pub global_reduction: f64,
}

/// Run the sweep for one R2F2 configuration against one fixed format.
///
/// The 10K intervals are independent by construction (fresh units, one RNG
/// stream per interval seeded from `p.seed`), so they shard over
/// `p.workers` threads via `coordinator::parallel_map` with bit-identical
/// results for any worker count. Each interval's pair stream runs through
/// the packed-domain `mul_pairs` engine (DESIGN.md §9) — bit-identical to
/// per-call multiplication.
pub fn error_sweep(cfg: R2f2Config, fixed: FpFormat, p: &SweepParams) -> SweepResult {
    let log_lo = p.lo.ln();
    let step = (p.hi.ln() - log_lo) / p.intervals as f64;

    // Deterministic sharding: per-interval seeds are drawn sequentially
    // from the root seed, so the sampled operands do not depend on how the
    // intervals are distributed across workers.
    let mut root = SplitMix64::new(p.seed);
    let jobs: Vec<(usize, u64)> = (0..p.intervals).map(|i| (i, root.next_u64())).collect();
    let pairs_n = p.pairs;

    let intervals = parallel_map(jobs, p.workers.max(1), |(i, seed)| {
        let ilo = (log_lo + step * i as f64).exp();
        let ihi = (log_lo + step * (i + 1) as f64).exp();
        let mut rng = SplitMix64::new(seed);

        // Fresh units per interval: the sweep measures steady-state
        // accuracy on locally-clustered data (the paper's premise), with
        // R2F2's adjustment allowed to settle within the interval stream.
        let mut r2f2 = R2f2Arith::new(cfg);
        let mut fix = FixedArith::new(fixed);

        let mut pairs = Vec::with_capacity(pairs_n);
        let mut wants = Vec::with_capacity(pairs_n);
        for _ in 0..pairs_n {
            let a = rng.range_f64(ilo, ihi);
            let b = rng.range_f64(ilo, ihi);
            pairs.push((a, b));
            wants.push((a as f32 * b as f32) as f64);
        }
        let mut got_f = vec![0.0; pairs_n];
        let mut got_r = vec![0.0; pairs_n];
        fix.mul_pairs(&mut got_f, &pairs);
        r2f2.mul_pairs(&mut got_r, &pairs);

        let mut sum_f = 0.0;
        let mut sum_r = 0.0;
        for idx in 0..pairs_n {
            sum_f += rel_err(got_f[idx], wants[idx]);
            sum_r += rel_err(got_r[idx], wants[idx]);
        }
        IntervalResult {
            lo: ilo,
            hi: ihi,
            err_fixed: sum_f / pairs_n as f64,
            err_r2f2: sum_r / pairs_n as f64,
        }
    });

    let reductions: Vec<f64> = intervals.iter().map(IntervalResult::reduction).collect();
    let avg = reductions.iter().sum::<f64>() / reductions.len() as f64;
    let max = reductions.iter().cloned().fold(f64::MIN, f64::max);
    let min = reductions.iter().cloned().fold(f64::MAX, f64::min);
    let gf = intervals.iter().map(|iv| iv.err_fixed).sum::<f64>() / intervals.len() as f64;
    let gr = intervals.iter().map(|iv| iv.err_r2f2).sum::<f64>() / intervals.len() as f64;
    SweepResult {
        cfg,
        fixed,
        intervals,
        avg_reduction: avg,
        max_reduction: max,
        min_reduction: min,
        global_err_fixed: gf,
        global_err_r2f2: gr,
        global_reduction: if gf > 0.0 { 1.0 - gr / gf } else { 0.0 },
    }
}

/// Relative error with the paper's 100%-on-range-failure convention.
fn rel_err(got: f64, want: f64) -> f64 {
    if want == 0.0 {
        return if got == 0.0 { 0.0 } else { 1.0 };
    }
    ((got - want) / want).abs().min(1.0)
}

/// One row of a per-scenario precision profile.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioProfileRow {
    pub fmt: FpFormat,
    /// Relative L2 error of the fixed-format MulOnly run vs the f64
    /// reference at [`ScenarioSize::Accuracy`].
    pub rel_err: f64,
    pub overflows: u64,
    pub underflows: u64,
    /// Multiplications the run issued.
    pub muls: u64,
}

/// A per-scenario precision profile: one row per candidate format, plus
/// the f64 reference field the errors were measured against (so callers
/// never re-run the reference — e.g. to histogram its range).
#[derive(Debug, Clone)]
pub struct ScenarioProfile {
    pub rows: Vec<ScenarioProfileRow>,
    /// Final f64 MulOnly field at [`ScenarioSize::Accuracy`].
    pub reference: Vec<f64>,
}

/// The Fig. 3 profiling idea pointed at whole simulations instead of
/// operand ranges: run a registry scenario (selected by name —
/// `pde::scenario::SCENARIOS`) under every candidate fixed format and
/// report the end-to-end error + range-event profile. Candidate formats
/// shard over `workers` threads via `coordinator::parallel_map` — each run
/// owns a fresh backend, so results are identical for any worker count.
pub fn scenario_precision_profile(
    name: &str,
    formats: &[FpFormat],
    workers: usize,
) -> Result<ScenarioProfile, String> {
    let spec = scenario::find(name).ok_or_else(|| format!("unknown scenario `{name}`"))?;
    let reference = (spec.run)(ScenarioSize::Accuracy, &mut F64Arith, QuantMode::MulOnly, true);
    let rows = parallel_map(formats.to_vec(), workers.max(1), |fmt| {
        let mut be = FixedArith::new(fmt);
        let run = (spec.run)(ScenarioSize::Accuracy, &mut be, QuantMode::MulOnly, true);
        let ev = run.range_events.unwrap_or_default();
        ScenarioProfileRow {
            fmt,
            rel_err: rel_l2(&run.field, &reference.field),
            overflows: ev.overflows,
            underflows: ev.underflows,
            muls: run.muls,
        }
    });
    Ok(ScenarioProfile { rows, reference: reference.field })
}

/// The default candidate ladder for [`scenario_precision_profile`]: the
/// 16-bit family around the paper's E5M10 plus the FP8 floor.
pub fn profile_formats() -> Vec<FpFormat> {
    vec![FpFormat::E4M3, FpFormat::E5M8, FpFormat::E5M10, FpFormat::new(6, 9), FpFormat::E8M7]
}

/// The three fixed-vs-R2F2 pairings evaluated in Fig. 6(g).
pub fn paper_pairings() -> [(R2f2Config, FpFormat); 3] {
    [
        (R2f2Config::C16_393, FpFormat::E5M10),
        (R2f2Config::C15_383, FpFormat::E5M9),
        (R2f2Config::C14_373, FpFormat::E5M8),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> SweepParams {
        SweepParams { intervals: 200, pairs: 60, ..SweepParams::default() }
    }

    #[test]
    fn r2f2_reduces_error_substantially_vs_half() {
        // Fig. 6(g): 70.2% average reduction. Our two aggregations bracket
        // it: per-interval mean ≈ 0.45-0.6, pooled error-mass ≈ 0.99+.
        let r = error_sweep(R2f2Config::C16_393, FpFormat::E5M10, &quick());
        assert!(
            r.avg_reduction > 0.4 && r.avg_reduction < 0.95,
            "avg reduction {}",
            r.avg_reduction
        );
        assert!(r.global_reduction > 0.9, "global {}", r.global_reduction);
        assert!(
            r.avg_reduction < 0.702 && 0.702 < r.global_reduction,
            "paper's 70.2% should fall between the two aggregations: {} vs {}",
            r.avg_reduction,
            r.global_reduction
        );
        assert!(r.max_reduction > 0.99, "max {}", r.max_reduction);
    }

    #[test]
    fn fixed_fails_outside_its_range_r2f2_does_not() {
        let r = error_sweep(R2f2Config::C16_393, FpFormat::E5M10, &quick());
        // Intervals with operands near 1e4 (products ~1e8) overflow E5M10.
        let top = r.intervals.last().unwrap();
        assert!(top.err_fixed > 0.99, "fixed should cap at 100%: {}", top.err_fixed);
        assert!(top.err_r2f2 < 0.01, "r2f2 should follow the range: {}", top.err_r2f2);
        // Intervals near 1e-4 (products ~1e-8) underflow E5M10.
        let bot = r.intervals.first().unwrap();
        assert!(bot.err_fixed > 0.99);
        assert!(bot.err_r2f2 < 0.01);
    }

    #[test]
    fn in_range_intervals_have_small_errors_for_both() {
        let r = error_sweep(R2f2Config::C16_393, FpFormat::E5M10, &quick());
        // Operands around 1..100: well inside E5M10.
        let mid: Vec<&IntervalResult> =
            r.intervals.iter().filter(|iv| iv.lo > 1.0 && iv.hi < 100.0).collect();
        assert!(!mid.is_empty());
        for iv in mid {
            assert!(iv.err_fixed < 2e-3, "fixed err {} at [{},{}]", iv.err_fixed, iv.lo, iv.hi);
            assert!(iv.err_r2f2 < 2e-3, "r2f2 err {} at [{},{}]", iv.err_r2f2, iv.lo, iv.hi);
        }
    }

    #[test]
    fn reductions_can_be_negative_but_small() {
        // The truncation approximation may cost accuracy in spots
        // (Fig. 6(d)'s negative dips) but never much.
        let r = error_sweep(R2f2Config::C16_393, FpFormat::E5M10, &quick());
        assert!(r.min_reduction > -0.6, "min reduction {}", r.min_reduction);
    }

    #[test]
    fn fewer_bits_keep_the_advantage() {
        // Fig. 6(g): 70.6% and 70.7% for 15/14 bits — the advantage holds
        // as total width shrinks, in both aggregations.
        for (cfg, fixed) in paper_pairings() {
            let r = error_sweep(cfg, fixed, &quick());
            assert!(r.avg_reduction > 0.4, "{cfg}: avg {}", r.avg_reduction);
            assert!(r.global_reduction > 0.9, "{cfg}: global {}", r.global_reduction);
        }
    }

    #[test]
    fn scenario_profile_orders_formats_sanely() {
        // On the shallow-water scenario the shelf-scale flux overflows
        // E5M10 but fits E6M9: the wider-exponent run must be far more
        // accurate and the half run must report overflows.
        let formats = [FpFormat::E5M10, FpFormat::new(6, 9)];
        let profile = scenario_precision_profile("swe2d", &formats, 2).unwrap();
        assert_eq!(profile.rows.len(), 2);
        assert!(!profile.reference.is_empty());
        let half = &profile.rows[0];
        let e6m9 = &profile.rows[1];
        assert!(half.overflows > 0, "E5M10 must overflow the shelf flux");
        assert!(e6m9.rel_err < 0.2 * half.rel_err, "{} vs {}", e6m9.rel_err, half.rel_err);
        assert!(half.muls > 0 && e6m9.muls == half.muls);
        assert!(scenario_precision_profile("nope", &[FpFormat::E5M10], 1).is_err());
    }

    #[test]
    fn scenario_profile_is_worker_count_invariant() {
        let formats = profile_formats();
        let one = scenario_precision_profile("heat1d", &formats, 1).unwrap().rows;
        let many = scenario_precision_profile("heat1d", &formats, 4).unwrap().rows;
        assert_eq!(one.len(), many.len());
        for (a, b) in one.iter().zip(many.iter()) {
            assert_eq!(a.fmt, b.fmt);
            assert_eq!(a.rel_err.to_bits(), b.rel_err.to_bits());
            assert_eq!((a.overflows, a.underflows, a.muls), (b.overflows, b.underflows, b.muls));
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let a = error_sweep(R2f2Config::C16_393, FpFormat::E5M10, &quick());
        let b = error_sweep(R2f2Config::C16_393, FpFormat::E5M10, &quick());
        assert_eq!(a.avg_reduction, b.avg_reduction);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        // Sharding is an implementation detail: the per-interval seed
        // derivation makes every aggregate bit-identical no matter how many
        // workers the intervals land on.
        let results: Vec<_> = [1usize, 2, 5, 8]
            .iter()
            .map(|&w| {
                error_sweep(
                    R2f2Config::C16_393,
                    FpFormat::E5M10,
                    &SweepParams { workers: w, ..quick() },
                )
            })
            .collect();
        for r in &results[1..] {
            assert_eq!(r.avg_reduction.to_bits(), results[0].avg_reduction.to_bits());
            assert_eq!(r.global_reduction.to_bits(), results[0].global_reduction.to_bits());
            for (a, b) in r.intervals.iter().zip(results[0].intervals.iter()) {
                assert_eq!(a.err_fixed.to_bits(), b.err_fixed.to_bits());
                assert_eq!(a.err_r2f2.to_bits(), b.err_r2f2.to_bits());
            }
        }
    }
}
