//! Multiplication-accuracy sweeps: the harnesses behind Fig. 3 (precision-
//! configuration profiling) and Fig. 6 (R2F2 vs fixed-type error sweep).

pub mod config_profile;
pub mod error_sweep;

pub use config_profile::{eq1_exponent_bits, profile_range, ProfilePoint, PAPER_RANGES};
pub use error_sweep::{error_sweep, IntervalResult, SweepParams, SweepResult};
