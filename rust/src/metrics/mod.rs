//! Lightweight metrics registry: named counters, gauges and duration
//! histograms, shareable across coordinator worker threads.
//!
//! The registry is the L3 observability surface: solvers and the runtime
//! report multiplication counts, adjustment events, PJRT execution times
//! etc.; the CLI prints a rendering at the end of a run and the report
//! module can serialize it as JSON.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    /// Duration samples in nanoseconds, keyed by timer name.
    timers: BTreeMap<String, Vec<u64>>,
}

/// A cloneable handle to a shared metrics registry.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<Inner>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Increment a counter.
    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a gauge.
    pub fn set(&self, name: &str, value: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), value);
    }

    /// Record one duration sample (nanoseconds).
    pub fn observe_ns(&self, name: &str, ns: u64) {
        let mut g = self.inner.lock().unwrap();
        g.timers.entry(name.to_string()).or_default().push(ns);
    }

    /// Time a closure into the named timer.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe_ns(name, t.elapsed().as_nanos() as u64);
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// (count, mean_ns, max_ns) summary of a timer.
    pub fn timer_summary(&self, name: &str) -> Option<(usize, f64, u64)> {
        let g = self.inner.lock().unwrap();
        let v = g.timers.get(name)?;
        if v.is_empty() {
            return None;
        }
        let sum: u64 = v.iter().sum();
        Some((v.len(), sum as f64 / v.len() as f64, *v.iter().max().unwrap()))
    }

    /// Human-readable rendering (stable ordering for tests/logs).
    pub fn render(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("gauge   {k} = {v}\n"));
        }
        for (k, v) in &g.timers {
            let sum: u64 = v.iter().sum();
            let mean = sum as f64 / v.len() as f64;
            out.push_str(&format!(
                "timer   {k}: n={} mean={:.0}ns total={:.3}ms\n",
                v.len(),
                mean,
                sum as f64 / 1e6
            ));
        }
        out
    }

    /// JSON rendering (hand-rolled; no serde in this environment).
    pub fn to_json(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut parts = Vec::new();
        let counters: Vec<String> =
            g.counters.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
        parts.push(format!("\"counters\": {{{}}}", counters.join(", ")));
        let gauges: Vec<String> = g
            .gauges
            .iter()
            .map(|(k, v)| format!("\"{k}\": {}", json_f64(*v)))
            .collect();
        parts.push(format!("\"gauges\": {{{}}}", gauges.join(", ")));
        let timers: Vec<String> = g
            .timers
            .iter()
            .map(|(k, v)| {
                let sum: u64 = v.iter().sum();
                format!(
                    "\"{k}\": {{\"count\": {}, \"mean_ns\": {}}}",
                    v.len(),
                    json_f64(sum as f64 / v.len() as f64)
                )
            })
            .collect();
        parts.push(format!("\"timers\": {{{}}}", timers.join(", ")));
        format!("{{{}}}", parts.join(", "))
    }
}

/// JSON-safe float rendering (no NaN/inf literals).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Registry::new();
        m.inc("muls", 10);
        m.inc("muls", 5);
        assert_eq!(m.counter("muls"), 15);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Registry::new();
        m.set("rmse", 0.5);
        m.set("rmse", 0.25);
        assert_eq!(m.gauge("rmse"), Some(0.25));
    }

    #[test]
    fn timers_summarize() {
        let m = Registry::new();
        m.observe_ns("step", 100);
        m.observe_ns("step", 300);
        let (n, mean, max) = m.timer_summary("step").unwrap();
        assert_eq!(n, 2);
        assert_eq!(mean, 200.0);
        assert_eq!(max, 300);
    }

    #[test]
    fn time_closure_records() {
        let m = Registry::new();
        let v = m.time("work", || 42);
        assert_eq!(v, 42);
        assert!(m.timer_summary("work").is_some());
    }

    #[test]
    fn shared_across_threads() {
        let m = Registry::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("n", 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
    }

    #[test]
    fn json_is_wellformed_ish() {
        let m = Registry::new();
        m.inc("a", 1);
        m.set("b", 2.5);
        m.observe_ns("t", 10);
        let j = m.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"a\": 1"));
        assert!(j.contains("\"b\": 2.5"));
        assert!(j.contains("\"t\""));
    }

    #[test]
    fn render_is_stable() {
        let m = Registry::new();
        m.inc("z", 1);
        m.inc("a", 2);
        let r = m.render();
        let za = r.find("counter a").unwrap();
        let zz = r.find("counter z").unwrap();
        assert!(za < zz, "BTreeMap ordering expected");
    }
}
