//! Lightweight metrics registry: named counters, gauges and duration
//! histograms, shareable across coordinator worker threads.
//!
//! The registry is the L3 observability surface: solvers and the runtime
//! report multiplication counts, adjustment events, PJRT execution times
//! etc.; the CLI prints a rendering at the end of a run and the report
//! module can serialize it as JSON.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Retained percentile samples per timer. Count/mean/max stay exact
/// forever; the sample window overwrites ring-style once full, so a
/// long-lived process (the `r2f2 serve` workers are the first) holds a
/// bounded, recent-biased window instead of growing per observation.
const TIMER_SAMPLE_CAP: usize = 4096;

/// One timer: exact aggregates + the capped percentile window.
#[derive(Debug, Clone, Default)]
struct Timer {
    count: u64,
    sum_ns: u64,
    max_ns: u64,
    samples: Vec<u64>,
}

impl Timer {
    fn observe(&mut self, ns: u64) {
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
        if self.samples.len() < TIMER_SAMPLE_CAP {
            self.samples.push(ns);
        } else {
            self.samples[(self.count - 1) as usize % TIMER_SAMPLE_CAP] = ns;
        }
    }

    fn mean_ns(&self) -> f64 {
        self.sum_ns as f64 / self.count as f64
    }

    fn sorted_samples(&self) -> Vec<f64> {
        let mut sorted: Vec<f64> = self.samples.iter().map(|&x| x as f64).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted
    }
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    /// Duration observations in nanoseconds, keyed by timer name.
    timers: BTreeMap<String, Timer>,
}

/// A cloneable handle to a shared metrics registry.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<Inner>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Increment a counter.
    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a gauge.
    pub fn set(&self, name: &str, value: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), value);
    }

    /// Raise a gauge to `value` if it exceeds the current reading — a peak
    /// tracker (high-water mark) under one lock acquisition, so concurrent
    /// observers cannot lose a peak between a read and a write. The serve
    /// acceptor uses this for `serve.connections.peak`.
    pub fn set_max(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.gauges.entry(name.to_string()).or_insert(value);
        if value > *e {
            *e = value;
        }
    }

    /// Record one duration sample (nanoseconds).
    pub fn observe_ns(&self, name: &str, ns: u64) {
        let mut g = self.inner.lock().unwrap();
        g.timers.entry(name.to_string()).or_default().observe(ns);
    }

    /// Time a closure into the named timer.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe_ns(name, t.elapsed().as_nanos() as u64);
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// (count, mean_ns, max_ns) summary of a timer. Exact regardless of
    /// how many observations the percentile window has dropped.
    pub fn timer_summary(&self, name: &str) -> Option<(usize, f64, u64)> {
        let g = self.inner.lock().unwrap();
        let t = g.timers.get(name)?;
        if t.count == 0 {
            return None;
        }
        Some((t.count as usize, t.mean_ns(), t.max_ns))
    }

    /// Percentiles (nearest-rank, in nanoseconds) of a timer's retained
    /// samples at the given fractions — `percentiles("t", &[0.5, 0.99])`
    /// is (p50, p99).
    ///
    /// Window semantics (what a dashboard must know before reading p99):
    ///
    /// * **Empty timer** (never observed, or merged from empty sources) →
    ///   `None`, never a fabricated zero.
    /// * **Single sample** → that sample at *every* fraction, p0 through
    ///   p100 (nearest-rank over one element).
    /// * The window holds at most `TIMER_SAMPLE_CAP` (= 4096) samples
    ///   **per source registry**. Up to the cap it is complete; from
    ///   observation `cap + 1` on, each new sample overwrites ring-style
    ///   (slot `(count - 1) % cap`), so exactly at the boundary the
    ///   oldest sample is the first to go and the window becomes
    ///   **recent-biased** rather than complete. `count`/`mean`/`max`
    ///   from [`Registry::timer_summary`] stay exact forever.
    /// * Sample order never matters, so percentiles over a
    ///   [`Registry::merge`] rollup are invariant to merge order (a
    ///   rollup window is bounded by sources × cap).
    ///
    /// [`Registry::to_prometheus`] surfaces the held window size per timer
    /// (`*_ns_window`) so the bias is visible where the quantiles are read.
    pub fn percentiles(&self, name: &str, fracs: &[f64]) -> Option<Vec<f64>> {
        let g = self.inner.lock().unwrap();
        let t = g.timers.get(name)?;
        if t.samples.is_empty() {
            return None;
        }
        let sorted = t.sorted_samples();
        Some(fracs.iter().map(|&p| crate::bench_util::percentile(&sorted, p * 100.0)).collect())
    }

    /// `true` when the two handles share one underlying registry. The
    /// server uses this to map a worker's registry handle back to its
    /// slot (and so to the worker's trace collector) without comparing
    /// contents.
    pub fn same_instance(&self, other: &Registry) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Fold another registry into this one: counters **sum**, gauges take
    /// the other's value (**last write wins** — merge order is the write
    /// order), timers **concatenate** their samples. This is how
    /// per-worker registries roll up into one `/metrics` snapshot; counter
    /// totals and timer percentiles are invariant to the merge order.
    /// Merging a registry into itself (same shared handle) is a no-op.
    pub fn merge(&self, other: &Registry) {
        if Arc::ptr_eq(&self.inner, &other.inner) {
            return;
        }
        let (counters, gauges, timers) = {
            let o = other.inner.lock().unwrap();
            (o.counters.clone(), o.gauges.clone(), o.timers.clone())
        };
        let mut g = self.inner.lock().unwrap();
        for (k, v) in counters {
            *g.counters.entry(k).or_insert(0) += v;
        }
        for (k, v) in gauges {
            g.gauges.insert(k, v);
        }
        for (k, v) in timers {
            let t = g.timers.entry(k).or_default();
            t.count += v.count;
            t.sum_ns += v.sum_ns;
            t.max_ns = t.max_ns.max(v.max_ns);
            // Concatenate the sample windows (each source is capped, so a
            // snapshot's total is bounded by sources × TIMER_SAMPLE_CAP).
            t.samples.extend(v.samples);
        }
    }

    /// Human-readable rendering (stable ordering for tests/logs). Metric
    /// names are `escape_debug`-ed so a name containing a newline cannot
    /// forge extra lines.
    pub fn render(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("counter {} = {v}\n", k.escape_debug()));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("gauge   {} = {v}\n", k.escape_debug()));
        }
        for (k, t) in &g.timers {
            out.push_str(&format!(
                "timer   {}: n={} mean={:.0}ns total={:.3}ms\n",
                k.escape_debug(),
                t.count,
                t.mean_ns(),
                t.sum_ns as f64 / 1e6
            ));
        }
        out
    }

    /// JSON rendering (hand-rolled; no serde in this environment). Names
    /// go through [`crate::config::json_mini::escape`] — the same routine
    /// the `config` parser is the dual of — so hostile names (quotes,
    /// backslashes, control characters) still yield well-formed JSON.
    /// Timers carry nearest-rank p50/p99 alongside count/mean.
    pub fn to_json(&self) -> String {
        use crate::config::json_mini::escape;
        let g = self.inner.lock().unwrap();
        let mut parts = Vec::new();
        let counters: Vec<String> =
            g.counters.iter().map(|(k, v)| format!("\"{}\": {v}", escape(k))).collect();
        parts.push(format!("\"counters\": {{{}}}", counters.join(", ")));
        let gauges: Vec<String> = g
            .gauges
            .iter()
            .map(|(k, v)| format!("\"{}\": {}", escape(k), json_f64(*v)))
            .collect();
        parts.push(format!("\"gauges\": {{{}}}", gauges.join(", ")));
        let timers: Vec<String> = g
            .timers
            .iter()
            .map(|(k, t)| {
                let sorted = t.sorted_samples();
                let p50 = crate::bench_util::percentile(&sorted, 50.0);
                let p99 = crate::bench_util::percentile(&sorted, 99.0);
                format!(
                    "\"{}\": {{\"count\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \"p99_ns\": {}}}",
                    escape(k),
                    t.count,
                    json_f64(t.mean_ns()),
                    json_f64(p50),
                    json_f64(p99)
                )
            })
            .collect();
        parts.push(format!("\"timers\": {{{}}}", timers.join(", ")));
        format!("{{{}}}", parts.join(", "))
    }

    /// Prometheus text exposition (format 0.0.4) — what `GET /metrics`
    /// serves under `Accept: text/plain` while JSON stays the default.
    ///
    /// Mapping: every metric is prefixed `r2f2_` and its name sanitized to
    /// the Prometheus charset `[a-zA-Z0-9_:]`. When sanitizing mangled the
    /// name, the original rides along as a `raw="..."` label (escaped with
    /// the exposition-format dual of `json_mini::escape`: `\\`, `\"`,
    /// `\n`) — so hostile names stay round-trippable and two names that
    /// sanitize identically stay distinguishable under one `# TYPE` line.
    /// Counters and gauges map directly; each timer becomes a summary
    /// family `<name>_ns` (quantile 0.5/0.99 over the bounded recent-biased
    /// window, exact `_sum`/`_count`) plus a `<name>_ns_window` gauge
    /// surfacing how many samples the quantiles were computed over — a
    /// dashboard reading p99 can see when the window, not the history, is
    /// speaking (see [`Registry::percentiles`]).
    pub fn to_prometheus(&self) -> String {
        // One lock for the whole exposition; quantiles are computed inline
        // (calling self.percentiles here would re-take the lock).
        let g = self.inner.lock().unwrap();
        let mut out = format!(
            "# r2f2 metrics exposition; timer quantiles use a bounded recent-biased \
             window (cap {TIMER_SAMPLE_CAP} samples per source), *_ns_window is the held sample count\n"
        );
        let families = |names: Vec<&String>| {
            let mut fam: BTreeMap<String, Vec<&String>> = BTreeMap::new();
            for k in names {
                fam.entry(prom_sanitize(k)).or_default().push(k);
            }
            fam
        };
        for (family, members) in families(g.counters.keys().collect()) {
            out.push_str(&format!("# TYPE {family} counter\n"));
            for k in members {
                out.push_str(&format!(
                    "{family}{} {}\n",
                    prom_raw_label(k),
                    g.counters[k]
                ));
            }
        }
        for (family, members) in families(g.gauges.keys().collect()) {
            out.push_str(&format!("# TYPE {family} gauge\n"));
            for k in members {
                out.push_str(&format!(
                    "{family}{} {}\n",
                    prom_raw_label(k),
                    prom_f64(g.gauges[k])
                ));
            }
        }
        for (family, members) in families(g.timers.keys().collect()) {
            let ns = format!("{family}_ns");
            out.push_str(&format!("# TYPE {ns} summary\n"));
            out.push_str(&format!("# TYPE {ns}_window gauge\n"));
            for k in members {
                let t = &g.timers[k];
                let sorted = t.sorted_samples();
                let raw = if prom_sanitize(k) == format!("r2f2_{k}") {
                    String::new()
                } else {
                    format!("raw=\"{}\"", prom_label_escape(k))
                };
                let with = |extra: &str| -> String {
                    match (raw.is_empty(), extra.is_empty()) {
                        (true, true) => String::new(),
                        (true, false) => format!("{{{extra}}}"),
                        (false, true) => format!("{{{raw}}}"),
                        (false, false) => format!("{{{raw},{extra}}}"),
                    }
                };
                for (q, pct) in [("0.5", 50.0), ("0.99", 99.0)] {
                    let v = if sorted.is_empty() {
                        f64::NAN
                    } else {
                        crate::bench_util::percentile(&sorted, pct)
                    };
                    out.push_str(&format!(
                        "{ns}{} {}\n",
                        with(&format!("quantile=\"{q}\"")),
                        prom_f64(v)
                    ));
                }
                out.push_str(&format!("{ns}_sum{} {}\n", with(""), t.sum_ns));
                out.push_str(&format!("{ns}_count{} {}\n", with(""), t.count));
                out.push_str(&format!("{ns}_window{} {}\n", with(""), t.samples.len()));
            }
        }
        out
    }
}

/// Sanitize a metric name to the Prometheus charset and namespace it:
/// `r2f2_` prefix, every byte outside `[a-zA-Z0-9_:]` replaced with `_`.
fn prom_sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 5);
    out.push_str("r2f2_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

/// `{raw="<escaped original>"}` when sanitizing changed the name, empty
/// otherwise.
fn prom_raw_label(name: &str) -> String {
    if prom_sanitize(name) == format!("r2f2_{name}") {
        String::new()
    } else {
        format!("{{raw=\"{}\"}}", prom_label_escape(name))
    }
}

/// Prometheus label-value escaping: backslash, double quote, newline.
fn prom_label_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            other => out.push(other),
        }
    }
    out
}

/// Prometheus sample-value rendering (unlike JSON, the text format has
/// literal spellings for non-finite values).
fn prom_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// JSON-safe float rendering (no NaN/inf literals).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Registry::new();
        m.inc("muls", 10);
        m.inc("muls", 5);
        assert_eq!(m.counter("muls"), 15);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Registry::new();
        m.set("rmse", 0.5);
        m.set("rmse", 0.25);
        assert_eq!(m.gauge("rmse"), Some(0.25));
    }

    #[test]
    fn set_max_tracks_the_high_water_mark() {
        let m = Registry::new();
        m.set_max("peak", 3.0);
        m.set_max("peak", 1.0);
        assert_eq!(m.gauge("peak"), Some(3.0), "lower readings never regress the peak");
        m.set_max("peak", 7.0);
        assert_eq!(m.gauge("peak"), Some(7.0));
        // Interacts with plain set() as an ordinary gauge.
        m.set("peak", 0.0);
        m.set_max("peak", 2.0);
        assert_eq!(m.gauge("peak"), Some(2.0));
    }

    #[test]
    fn timers_summarize() {
        let m = Registry::new();
        m.observe_ns("step", 100);
        m.observe_ns("step", 300);
        let (n, mean, max) = m.timer_summary("step").unwrap();
        assert_eq!(n, 2);
        assert_eq!(mean, 200.0);
        assert_eq!(max, 300);
    }

    #[test]
    fn time_closure_records() {
        let m = Registry::new();
        let v = m.time("work", || 42);
        assert_eq!(v, 42);
        assert!(m.timer_summary("work").is_some());
    }

    #[test]
    fn shared_across_threads() {
        let m = Registry::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("n", 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
    }

    #[test]
    fn json_is_wellformed_ish() {
        let m = Registry::new();
        m.inc("a", 1);
        m.set("b", 2.5);
        m.observe_ns("t", 10);
        let j = m.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"a\": 1"));
        assert!(j.contains("\"b\": 2.5"));
        assert!(j.contains("\"t\""));
    }

    #[test]
    fn hostile_names_roundtrip_through_json() {
        // The PR-5 fix: names with quotes/backslashes/control characters
        // used to be interpolated raw and yield malformed JSON. They must
        // now parse back exactly through the crate's own parser.
        let m = Registry::new();
        m.inc("quo\"te", 1);
        m.inc("back\\slash", 2);
        m.set("new\nline", 2.5);
        m.observe_ns("tab\tand\u{1}ctl", 10);
        let parsed = crate::config::parse_json(&m.to_json()).expect("well-formed JSON");
        let counters = parsed.get("counters").unwrap();
        assert_eq!(counters.get("quo\"te").unwrap().as_f64(), Some(1.0));
        assert_eq!(counters.get("back\\slash").unwrap().as_f64(), Some(2.0));
        assert_eq!(parsed.get("gauges").unwrap().get("new\nline").unwrap().as_f64(), Some(2.5));
        let t = parsed.get("timers").unwrap().get("tab\tand\u{1}ctl").unwrap();
        assert_eq!(t.get("count").unwrap().as_usize(), Some(1));
        assert_eq!(t.get("p50_ns").unwrap().as_f64(), Some(10.0));
        assert_eq!(t.get("p99_ns").unwrap().as_f64(), Some(10.0));
        // render can no longer forge lines either.
        assert_eq!(m.render().lines().count(), 4);
    }

    #[test]
    fn percentiles_empty_one_sample_many() {
        let m = Registry::new();
        assert!(m.percentiles("t", &[0.5]).is_none(), "no samples → None");
        m.observe_ns("t", 100);
        assert_eq!(m.percentiles("t", &[0.0, 0.5, 0.99]).unwrap(), vec![100.0, 100.0, 100.0]);
        for v in [300u64, 200, 500, 400] {
            m.observe_ns("t", v);
        }
        // Sorted: [100, 200, 300, 400, 500] — nearest-rank.
        assert_eq!(m.percentiles("t", &[0.5, 0.99]).unwrap(), vec![300.0, 500.0]);
    }

    #[test]
    fn merge_sums_counters_overwrites_gauges_concats_timers() {
        let a = Registry::new();
        a.inc("n", 3);
        a.set("g", 1.0);
        a.observe_ns("t", 100);
        let b = Registry::new();
        b.inc("n", 4);
        b.inc("only_b", 1);
        b.set("g", 2.0);
        b.observe_ns("t", 300);
        a.merge(&b);
        assert_eq!(a.counter("n"), 7);
        assert_eq!(a.counter("only_b"), 1);
        assert_eq!(a.gauge("g"), Some(2.0), "gauges are last-write-wins");
        let (count, mean, max) = a.timer_summary("t").unwrap();
        assert_eq!((count, mean, max), (2, 200.0, 300));
    }

    #[test]
    fn merge_order_invariance_for_counters_and_percentiles() {
        let mk = |vals: &[u64]| {
            let r = Registry::new();
            r.inc("n", vals.len() as u64);
            for &v in vals {
                r.observe_ns("t", v);
            }
            r
        };
        let a = mk(&[500, 100]);
        let b = mk(&[300, 200, 400]);
        let ab = Registry::new();
        ab.merge(&a);
        ab.merge(&b);
        let ba = Registry::new();
        ba.merge(&b);
        ba.merge(&a);
        assert_eq!(ab.counter("n"), ba.counter("n"));
        assert_eq!(
            ab.percentiles("t", &[0.5, 0.9, 0.99]),
            ba.percentiles("t", &[0.5, 0.9, 0.99])
        );
        assert_eq!(ab.timer_summary("t"), ba.timer_summary("t"));
    }

    #[test]
    fn timer_sample_window_is_bounded_but_aggregates_stay_exact() {
        let m = Registry::new();
        let n = (TIMER_SAMPLE_CAP as u64) * 2 + 7;
        for i in 0..n {
            m.observe_ns("t", i);
        }
        let (count, mean, max) = m.timer_summary("t").unwrap();
        assert_eq!(count as u64, n, "count is exact past the window cap");
        assert_eq!(max, n - 1);
        assert!((mean - (n - 1) as f64 / 2.0).abs() < 1e-9, "mean is exact");
        // The percentile window stays capped and recent-biased: after 2n
        // observations of an increasing series, the retained minimum is
        // well above the series start.
        let p = m.percentiles("t", &[0.0]).unwrap();
        assert!(p[0] >= (n - 2 * TIMER_SAMPLE_CAP as u64) as f64);
        let g = m.inner.lock().unwrap();
        assert_eq!(g.timers.get("t").unwrap().samples.len(), TIMER_SAMPLE_CAP);
    }

    #[test]
    fn merge_with_self_is_noop() {
        let m = Registry::new();
        m.inc("n", 5);
        let same_handle = m.clone();
        m.merge(&same_handle);
        assert_eq!(m.counter("n"), 5, "self-merge must not double counters");
    }

    #[test]
    fn render_is_stable() {
        let m = Registry::new();
        m.inc("z", 1);
        m.inc("a", 2);
        let r = m.render();
        let za = r.find("counter a").unwrap();
        let zz = r.find("counter z").unwrap();
        assert!(za < zz, "BTreeMap ordering expected");
    }

    #[test]
    fn same_instance_is_handle_identity_not_content_equality() {
        let a = Registry::new();
        let b = Registry::new();
        assert!(a.same_instance(&a.clone()));
        assert!(!a.same_instance(&b), "distinct registries, even both empty");
    }

    /// Minimal parser for the exposition's sample lines:
    /// `name{label="value"} number` → (name, Option<raw label>, value).
    /// Un-escapes the label the way a Prometheus scraper would, so the
    /// test proves hostile names *round-trip*, not just "don't crash".
    fn parse_exposition(text: &str) -> Vec<(String, Option<String>, f64)> {
        let mut out = Vec::new();
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (head, value) = line.rsplit_once(' ').expect("sample line");
            let (name, raw) = match head.split_once('{') {
                None => (head.to_string(), None),
                Some((name, rest)) => {
                    let labels = rest.strip_suffix('}').expect("closed label set");
                    let raw = labels.split("raw=\"").nth(1).map(|tail| {
                        // The value runs to the closing unescaped quote.
                        let mut s = String::new();
                        let mut chars = tail.chars();
                        while let Some(c) = chars.next() {
                            match c {
                                '"' => break,
                                '\\' => match chars.next() {
                                    Some('n') => s.push('\n'),
                                    Some(other) => s.push(other),
                                    None => {}
                                },
                                other => s.push(other),
                            }
                        }
                        s
                    });
                    (name.to_string(), raw)
                }
            };
            let v = match value {
                "NaN" => f64::NAN,
                "+Inf" => f64::INFINITY,
                "-Inf" => f64::NEG_INFINITY,
                n => n.parse().expect("numeric sample value"),
            };
            out.push((name, raw, v));
        }
        out
    }

    #[test]
    fn prometheus_hostile_names_roundtrip() {
        let m = Registry::new();
        m.inc("quo\"te", 1);
        m.inc("back\\slash", 2);
        m.set("new\nline", 2.5);
        m.set("dotted.ok", f64::INFINITY);
        m.observe_ns("t\tab", 10);
        let text = m.to_prometheus();
        let samples = parse_exposition(&text);
        let find = |raw: &str| {
            samples
                .iter()
                .find(|(_, r, _)| r.as_deref() == Some(raw))
                .unwrap_or_else(|| panic!("no sample with raw label {raw:?}"))
        };
        assert_eq!(find("quo\"te").2, 1.0);
        assert_eq!(find("back\\slash").2, 2.0);
        assert_eq!(find("new\nline").2, 2.5);
        assert_eq!(find("dotted.ok").2, f64::INFINITY);
        // Mangled names still expose under the sanitized family name.
        assert!(find("quo\"te").0.starts_with("r2f2_quo_te"));
        // The timer summary carries its raw label on every series.
        let timer_lines: Vec<_> =
            samples.iter().filter(|(_, r, _)| r.as_deref() == Some("t\tab")).collect();
        assert_eq!(timer_lines.len(), 5, "2 quantiles + sum + count + window");
        // A name containing a newline cannot forge extra sample lines:
        // every non-comment line still parsed as exactly one sample above,
        // and none of them starts with the smuggled text.
        assert!(text.lines().all(|l| l.starts_with('#') || l.starts_with("r2f2_")));
    }

    #[test]
    fn prometheus_groups_colliding_names_under_one_type_line() {
        let m = Registry::new();
        // Both sanitize to r2f2_cache_hits: one family, one TYPE line,
        // two samples kept distinguishable by the raw label.
        m.inc("cache.hits", 1);
        m.inc("cache_hits", 2);
        // A colon is legal in the exposition charset and survives as-is.
        m.inc("cache:hits", 3);
        let text = m.to_prometheus();
        let type_lines: Vec<_> = text.lines().filter(|l| l.starts_with("# TYPE")).collect();
        assert_eq!(type_lines.len(), 2, "one family per distinct sanitized name");
        assert_eq!(
            text.matches("# TYPE r2f2_cache_hits counter").count(),
            1,
            "colliding names must not duplicate the TYPE line"
        );
        assert!(text.contains("# TYPE r2f2_cache:hits counter"));
        assert!(text.contains("r2f2_cache_hits{raw=\"cache.hits\"} 1"));
        assert!(text.contains("r2f2_cache_hits 2\n"));
        assert!(text.contains("r2f2_cache:hits 3\n"));
    }

    #[test]
    fn prometheus_clean_names_have_no_labels_and_json_stays_untouched() {
        let m = Registry::new();
        m.inc("serve_requests", 3);
        m.set("rmse", 0.5);
        m.observe_ns("step", 100);
        m.observe_ns("step", 300);
        let text = m.to_prometheus();
        assert!(text.contains("# TYPE r2f2_serve_requests counter\n"));
        assert!(text.contains("r2f2_serve_requests 3\n"));
        assert!(text.contains("r2f2_rmse 0.5\n"));
        assert!(text.contains("r2f2_step_ns{quantile=\"0.5\"} "));
        assert!(text.contains("r2f2_step_ns{quantile=\"0.99\"} "));
        assert!(text.contains("r2f2_step_ns_sum 400\n"));
        assert!(text.contains("r2f2_step_ns_count 2\n"));
        assert!(text.contains("r2f2_step_ns_window 2\n"), "window size is surfaced");
        // The exposition is a second rendering, not a change to the first:
        // the JSON body existing clients parse keeps its exact shape.
        let parsed = crate::config::parse_json(&m.to_json()).unwrap();
        let t = parsed.get("timers").unwrap().get("step").unwrap();
        assert_eq!(t.get("count").unwrap().as_usize(), Some(2));
        assert!(t.get("window").is_none(), "window stays out of the JSON shape");
    }

    #[test]
    fn percentile_window_exact_cap_boundary() {
        let m = Registry::new();
        // Exactly at the cap the window is still complete: p0 is the very
        // first observation.
        for i in 1..=TIMER_SAMPLE_CAP as u64 {
            m.observe_ns("t", i);
        }
        assert_eq!(
            m.percentiles("t", &[0.0, 1.0]).unwrap(),
            vec![1.0, TIMER_SAMPLE_CAP as f64]
        );
        // One past the cap, the ring overwrites slot (count-1) % cap = 0 —
        // the oldest sample is the first casualty and the window turns
        // recent-biased, while count stays exact.
        m.observe_ns("t", TIMER_SAMPLE_CAP as u64 + 1);
        assert_eq!(
            m.percentiles("t", &[0.0, 1.0]).unwrap(),
            vec![2.0, TIMER_SAMPLE_CAP as f64 + 1.0]
        );
        let (count, _, max) = m.timer_summary("t").unwrap();
        assert_eq!(count, TIMER_SAMPLE_CAP + 1);
        assert_eq!(max, TIMER_SAMPLE_CAP as u64 + 1);
        // The exposition's window gauge reports the cap, telling the
        // reader its quantiles describe the last `cap` samples only.
        let text = m.to_prometheus();
        assert!(text.contains(&format!("r2f2_t_ns_window {TIMER_SAMPLE_CAP}\n")));
    }

    #[test]
    fn prometheus_empty_timer_exposes_nan_quantiles() {
        // A timer family that was merged in with zero samples must not
        // fabricate a 0 latency; the text format can say NaN.
        let m = Registry::new();
        let empty = Registry::new();
        empty.inner.lock().unwrap().timers.insert("t".into(), Timer::default());
        m.merge(&empty);
        let text = m.to_prometheus();
        assert!(text.contains("r2f2_t_ns{quantile=\"0.5\"} NaN\n"));
        assert!(text.contains("r2f2_t_ns_count 0\n"));
        assert!(text.contains("r2f2_t_ns_window 0\n"));
    }
}
