//! The simulation service: `r2f2 serve` (DESIGN.md §12).
//!
//! The fourth architectural layer — **serve**, atop arith (§3), solve
//! (§11) and orchestrate (coordinator). Everything below this layer is a
//! one-shot invocation; this module gives the registry, the engines and
//! the adaptive scheduler a long-lived surface shaped like the workload
//! numerical-precision experimentation actually is: repeated parameterized
//! queries over the same solvers.
//!
//! Std-only: a `TcpListener` acceptor thread, the persistent
//! [`pool::WorkerPool`] (bounded MPMC queue — a full queue rejects with
//! `503`, which is the whole backpressure story), and the
//! [`cache::ResultCache`] (sound because runs are bit-reproducible; see
//! that module's docs for why, and for the debug determinism guard).
//!
//! Endpoints:
//!
//! | route | behavior |
//! | --- | --- |
//! | `POST /v1/run` | JSON body → [`ExperimentConfig`] (same fields as the TOML config) → cached [`run_experiment`] → deterministic outcome JSON. Headers: `x-r2f2-cache: hit\|miss`, `x-r2f2-key: <fnv64>` |
//! | `GET /v1/scenarios` | the [`SCENARIOS`] registry listing |
//! | `GET /healthz` | liveness probe |
//! | `GET /metrics` | merged per-worker [`Registry`] rollup + queue/cache gauges |
//!
//! The response body of `/v1/run` deliberately excludes wall-clock time —
//! it is the *deterministic* payload, byte-identical across hits, misses
//! and re-runs, which is what makes both the cache and the loopback
//! bit-identity suite (`rust/tests/serve_loopback.rs`) possible. Timing
//! lives in `/metrics` (`serve.handle_ns` percentiles) instead.

pub mod cache;
pub mod http;
pub mod pool;

use crate::config::json_mini::escape;
use crate::config::{parse_json, ExperimentConfig};
use crate::coordinator::job::Outcome;
use crate::coordinator::{self, run_experiment};
use crate::metrics::Registry;
use crate::pde::scenario::SCENARIOS;
use crate::pde::QuantMode;
use cache::ResultCache;
use pool::{Bounded, WorkerPool};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// At most this many concurrent detached 503-responder threads; beyond it
/// rejected connections are dropped unanswered (still a rejection, and the
/// acceptor stays alive under any flood).
const MAX_REJECT_RESPONDERS: usize = 64;

/// Server configuration (the `r2f2 serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// TCP port on 127.0.0.1 (0 = ephemeral, reported by [`Server::addr`]).
    pub port: u16,
    /// Worker threads ([`coordinator::default_workers`] by default, so the
    /// `R2F2_WORKERS` env override applies).
    pub workers: usize,
    /// Bounded job-queue capacity; a full queue rejects with `503`.
    pub queue_cap: usize,
    /// Result-cache capacity (entries, LRU-evicted).
    pub cache_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            port: 7272,
            workers: coordinator::default_workers(),
            queue_cap: 64,
            cache_cap: 256,
        }
    }
}

/// State shared by the acceptor, the workers and the metrics rollup.
struct Shared {
    cache: ResultCache,
    queue: Arc<Bounded<TcpStream>>,
    /// Acceptor-side counters (`serve.accepted` / `serve.rejected`).
    acceptor_reg: Registry,
    /// Every worker's private registry (handles — cloneable), so the
    /// `/metrics` route can roll up the whole pool, not just the worker
    /// that happens to serve the request.
    worker_regs: Vec<Registry>,
}

/// The full metrics rollup over shared state: acceptor counters + every
/// worker registry + queue/cache gauges. Both the `/metrics` route and
/// [`Server::metrics_snapshot`] are exactly this.
fn rollup(shared: &Shared) -> Registry {
    let snap = Registry::new();
    snap.merge(&shared.acceptor_reg);
    for reg in &shared.worker_regs {
        snap.merge(reg);
    }
    let st = shared.cache.stats();
    snap.inc("serve.cache.hits", st.hits);
    snap.inc("serve.cache.misses", st.misses);
    snap.inc("serve.cache.evictions", st.evictions);
    snap.inc("serve.cache.guard_checks", st.guard_checks);
    snap.set("serve.queue.depth", shared.queue.len() as f64);
    snap.set("serve.cache.entries", shared.cache.len() as f64);
    snap
}

/// A running simulation service. Dropping (or [`Server::shutdown`]) stops
/// the acceptor, drains admitted connections and joins every thread.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    pool: Option<WorkerPool<TcpStream>>,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind, spawn the worker pool and the acceptor, return immediately.
    pub fn start(opts: ServeOptions) -> Result<Server, String> {
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .map_err(|e| format!("bind 127.0.0.1:{}: {e}", opts.port))?;
        let addr = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;

        let queue = Arc::new(Bounded::new(opts.queue_cap));
        let worker_regs: Vec<Registry> =
            (0..opts.workers.max(1)).map(|_| Registry::new()).collect();
        let shared = Arc::new(Shared {
            cache: ResultCache::new(opts.cache_cap),
            queue: queue.clone(),
            acceptor_reg: Registry::new(),
            worker_regs: worker_regs.clone(),
        });

        let pool = {
            let shared = shared.clone();
            let handler = move |stream: TcpStream, reg: &Registry| {
                handle_connection(stream, &shared, reg);
            };
            WorkerPool::with_registries(queue.clone(), worker_regs, handler)
        };

        let stop = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let stop = stop.clone();
            let shared = shared.clone();
            let responders = Arc::new(AtomicUsize::new(0));
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => {
                            // Persistent accept errors (fd exhaustion)
                            // must back off, not busy-spin a core.
                            std::thread::sleep(Duration::from_millis(10));
                            continue;
                        }
                    };
                    shared.acceptor_reg.inc("serve.accepted", 1);
                    if let Err(stream) = shared.queue.try_push(stream) {
                        // Backpressure: reject with 503. The drain + write
                        // happen on a short-lived detached thread so a slow
                        // rejected client can never stall the accept loop —
                        // stalling it under overload would make the server
                        // reject work the draining queue could admit. The
                        // responders are bounded and spawn failure is
                        // non-fatal (a flood must not kill the acceptor);
                        // past the bound the connection is dropped, which
                        // is itself an unambiguous rejection.
                        shared.acceptor_reg.inc("serve.rejected", 1);
                        if responders.fetch_add(1, Ordering::SeqCst) < MAX_REJECT_RESPONDERS {
                            let done = responders.clone();
                            let spawned = std::thread::Builder::new()
                                .name("r2f2-reject".into())
                                .spawn(move || {
                                    reject_with_503(stream);
                                    done.fetch_sub(1, Ordering::SeqCst);
                                });
                            if spawned.is_err() {
                                responders.fetch_sub(1, Ordering::SeqCst);
                                shared.acceptor_reg.inc("serve.rejected_dropped", 1);
                            }
                        } else {
                            responders.fetch_sub(1, Ordering::SeqCst);
                            shared.acceptor_reg.inc("serve.rejected_dropped", 1);
                        }
                    }
                }
                // Listener drops here: the port is released before
                // shutdown() returns.
            })
        };

        Ok(Server { addr, stop, acceptor: Some(acceptor), pool: Some(pool), shared })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Cache effectiveness counters.
    pub fn cache_stats(&self) -> cache::CacheStats {
        self.shared.cache.stats()
    }

    /// Merged metrics rollup: acceptor counters + every worker registry
    /// (via [`Registry::merge`]) + queue/cache gauges. Identical to what
    /// `GET /metrics` serves.
    pub fn metrics_snapshot(&self) -> Registry {
        rollup(&self.shared)
    }

    /// Block on the acceptor thread — the `r2f2 serve` foreground mode
    /// (runs until the process is killed).
    pub fn wait(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
    }

    /// Graceful shutdown: stop accepting, drain admitted connections, join
    /// the acceptor and every worker. Returning means no server thread is
    /// left and the port is released.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so the acceptor observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

// ---------------------------------------------------------------------------
// Request handling
// ---------------------------------------------------------------------------

fn respond(stream: &mut TcpStream, status: u16, extra: &[(&str, &str)], body: &str) {
    let _ = http::write_response(stream, status, extra, "application/json", body.as_bytes());
}

/// Rejection path: drain the request (bounded by the parser's size limits,
/// short timeouts), then answer 503. Draining first matters — closing a
/// socket that still has unread received bytes sends RST, which would tear
/// the 503 out of the client's receive buffer.
fn reject_with_503(stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut reader = BufReader::new(stream);
    let parsed = http::read_request(&mut reader);
    let mut stream = reader.into_inner();
    if parsed.is_err() {
        // Mid-stream parse failure leaves unread bytes; see drain_best_effort.
        drain_best_effort(&stream);
    }
    let _ = http::write_response(
        &mut stream,
        503,
        &[("retry-after", "1")],
        "application/json",
        b"{\"error\": \"job queue full\"}",
    );
}

fn respond_error(stream: &mut TcpStream, status: u16, msg: &str) {
    respond(stream, status, &[], &format!("{{\"error\": \"{}\"}}", escape(msg)));
}

/// Best-effort drain of unread request bytes before an error response.
/// Only needed when request parsing failed mid-stream: closing a socket
/// with unread received bytes sends RST, which can tear the error response
/// out of the client's receive buffer. Bounded in both bytes and time.
fn drain_best_effort(stream: &TcpStream) {
    use std::io::Read;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut sink = [0u8; 4096];
    let mut total = 0usize;
    let mut s = stream;
    while total < 256 * 1024 {
        match s.read(&mut sink) {
            Ok(0) => break,
            Ok(n) => total += n,
            Err(_) => break,
        }
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared, reg: &Registry) {
    // Connections are admitted before any bytes are read (the acceptor
    // must stay non-blocking), so a client that connects and sends nothing
    // holds a worker for this read window — keep it short. A full fix is
    // a dedicated reader stage; known limitation, documented in
    // DESIGN.md §12.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let mut reader = BufReader::new(stream);
    let req = match http::read_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            reg.inc("serve.http.400", 1);
            let mut stream = reader.into_inner();
            drain_best_effort(&stream);
            respond_error(&mut stream, 400, &e);
            return;
        }
    };
    let mut stream = reader.into_inner();
    reg.inc("serve.requests", 1);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond(
            &mut stream,
            200,
            &[],
            &format!("{{\"status\": \"ok\", \"scenarios\": {}}}", SCENARIOS.len()),
        ),
        ("GET", "/v1/scenarios") => respond(&mut stream, 200, &[], &scenarios_json()),
        ("GET", "/metrics") => respond(&mut stream, 200, &[], &rollup(shared).to_json()),
        ("POST", "/v1/run") => handle_run(&req.body, &mut stream, shared, reg),
        (_, "/healthz" | "/v1/scenarios" | "/metrics") => {
            reg.inc("serve.http.405", 1);
            respond_error(&mut stream, 405, "use GET");
        }
        (_, "/v1/run") => {
            reg.inc("serve.http.405", 1);
            respond_error(&mut stream, 405, "use POST");
        }
        (_, path) => {
            reg.inc("serve.http.404", 1);
            respond_error(&mut stream, 404, &format!("no route {path}"));
        }
    }
}

fn handle_run(body: &[u8], stream: &mut TcpStream, shared: &Shared, reg: &Registry) {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => {
            reg.inc("serve.http.400", 1);
            return respond_error(stream, 400, "body is not UTF-8");
        }
    };
    let json = match parse_json(text) {
        Ok(j) => j,
        Err(e) => {
            reg.inc("serve.http.400", 1);
            return respond_error(stream, 400, &format!("bad JSON: {e}"));
        }
    };
    let cfg = match ExperimentConfig::from_json(&json) {
        Ok(c) => c,
        Err(e) => {
            reg.inc("serve.http.400", 1);
            return respond_error(stream, 400, &format!("bad config: {e}"));
        }
    };
    let (canonical, key) = cache::content_key(&cfg);
    let (value, hit) =
        shared.cache.get_or_insert_with(&canonical, || outcome_json(&run_experiment(&cfg, reg)));
    reg.inc(if hit { "serve.run.hits" } else { "serve.run.misses" }, 1);
    let cache_header = if hit { "hit" } else { "miss" };
    let headers = [("x-r2f2-cache", cache_header), ("x-r2f2-key", key.as_str())];
    respond(stream, 200, &headers, value.as_str());
}

// ---------------------------------------------------------------------------
// JSON shaping
// ---------------------------------------------------------------------------

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// The deterministic response body for one outcome. Wall-clock time is
/// deliberately excluded: everything here is bit-reproducible, which is
/// the property the cache (and its determinism guard) relies on.
pub fn outcome_json(o: &Outcome) -> String {
    let mode = match o.mode {
        QuantMode::MulOnly => "mul-only",
        QuantMode::Full => "full",
    };
    let adjustments = match o.adjustments {
        Some((w, n)) => format!("{{\"widen\": {w}, \"narrow\": {n}}}"),
        None => "null".to_string(),
    };
    let range_events = match o.range_events {
        Some((of, uf)) => format!("{{\"overflows\": {of}, \"underflows\": {uf}}}"),
        None => "null".to_string(),
    };
    let field: Vec<String> = o.field.iter().map(|&v| json_f64(v)).collect();
    format!(
        "{{\"title\": \"{}\", \"app\": \"{}\", \"backend\": \"{}\", \"mode\": \"{mode}\", \
         \"rel_err_vs_f64\": {}, \"muls\": {}, \"adjustments\": {adjustments}, \
         \"range_events\": {range_events}, \"n\": {}, \"field\": [{}]}}",
        escape(&o.title),
        escape(&o.app),
        escape(&o.backend),
        json_f64(o.rel_err_vs_f64),
        o.muls,
        o.field.len(),
        field.join(", ")
    )
}

/// The `/v1/scenarios` body: the registry, one object per entry.
pub fn scenarios_json() -> String {
    let items: Vec<String> = SCENARIOS
        .iter()
        .map(|s| {
            format!(
                "{{\"name\": \"{}\", \"physics\": \"{}\", \"stress\": \"{}\", \
                 \"wide_format\": \"{}\", \"expect_narrow\": {}}}",
                escape(s.name),
                escape(s.physics),
                escape(s.stress),
                s.wide_format,
                s.expect_narrow
            )
        })
        .collect();
    format!("{{\"scenarios\": [{}]}}", items.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_backend;
    use crate::pde::init::HeatInit;

    fn quick_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = "heat".into();
        c.backend = parse_backend("fixed:E5M10").unwrap();
        c.heat.n = 17;
        c.heat.dt = 0.25 / (16.0 * 16.0);
        c.heat.steps = 10;
        c.heat.init = HeatInit::sin_default();
        c
    }

    #[test]
    fn outcome_json_is_deterministic_and_parseable() {
        let cfg = quick_cfg();
        let a = outcome_json(&run_experiment(&cfg, &Registry::new()));
        let b = outcome_json(&run_experiment(&cfg, &Registry::new()));
        assert_eq!(a, b, "two runs of one config must serialize identically");
        let j = parse_json(&a).unwrap();
        assert_eq!(j.get("app").unwrap().as_str(), Some("heat"));
        assert_eq!(j.get("backend").unwrap().as_str(), Some("fixed:E5M10"));
        assert_eq!(j.get("mode").unwrap().as_str(), Some("mul-only"));
        assert_eq!(j.get("n").unwrap().as_usize(), Some(17));
        assert_eq!(j.get("field").unwrap().as_arr().unwrap().len(), 17);
        assert!(j.get("muls").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn scenarios_json_lists_the_registry() {
        let j = parse_json(&scenarios_json()).unwrap();
        let arr = j.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), SCENARIOS.len());
        for (item, spec) in arr.iter().zip(SCENARIOS) {
            assert_eq!(item.get("name").unwrap().as_str(), Some(spec.name));
        }
    }

    #[test]
    fn server_starts_and_answers_healthz() {
        let server = Server::start(ServeOptions {
            port: 0,
            workers: 2,
            queue_cap: 8,
            cache_cap: 8,
        })
        .unwrap();
        let resp = http::request(server.addr(), "GET", "/healthz", b"").unwrap();
        assert_eq!(resp.status, 200);
        let j = parse_json(&resp.text()).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));
        server.shutdown();
    }
}
