//! The simulation service: `r2f2 serve` (DESIGN.md §12, §16).
//!
//! The fourth architectural layer — **serve**, atop arith (§3), solve
//! (§11) and orchestrate (coordinator). Everything below this layer is a
//! one-shot invocation; this module gives the registry, the engines and
//! the adaptive scheduler a long-lived surface shaped like the workload
//! numerical-precision experimentation actually is: repeated parameterized
//! queries over the same solvers.
//!
//! Std-only, three moving parts:
//!
//! - a **nonblocking acceptor** that owns every idle socket: it polls a
//!   1-byte `peek` over the idle table and hands a connection to the pool
//!   only when request bytes have actually arrived. Keep-alive sockets
//!   come *back* to this table between requests, so a silent connection
//!   costs an entry in a `Vec` and a timer — never a worker (the §12
//!   slow-loris limitation, fixed). Idle sockets past the keep-alive
//!   deadline are closed (`serve.idle_expired`).
//! - the persistent [`pool::WorkerPool`] draining a bounded [`Work`]
//!   queue of ready connections and job-epoch continuations (a full queue
//!   rejects new connections with `503`, which is the whole backpressure
//!   story; continuations re-enter past the cap but behind admitted
//!   connections, bounded by the job store's own cap).
//! - the [`cache::ResultCache`] (sound because runs are bit-reproducible;
//!   see that module's docs for why, and for the debug determinism guard).
//!
//! Endpoints:
//!
//! | route | behavior |
//! | --- | --- |
//! | `POST /v1/run` | JSON body → [`ExperimentConfig`] (same fields as the TOML config) → cached [`run_experiment`] → deterministic outcome JSON. Headers: `x-r2f2-cache: hit\|miss`, `x-r2f2-key: <fnv64>` |
//! | `POST /v1/jobs` | same body (+ optional `job.epoch_steps`) → `202` with a job id; the run executes as checkpointed epochs on the pool ([`jobs`]) |
//! | `GET /v1/jobs/:id` | progress/status record |
//! | `GET /v1/jobs/:id/result` | `200` outcome body (byte-identical to `/v1/run` on the same config) · `409` while unfinished · `500` if failed |
//! | `GET /v1/jobs/:id/events` | chunked ndjson stream of per-epoch progress + range telemetry, ending when the job does |
//! | `POST /v1/jobs/:id/pause` · `/resume` | park / continue at epoch boundaries |
//! | `GET /v1/scenarios` | the [`SCENARIOS`] registry listing |
//! | `GET /healthz` | liveness probe |
//! | `GET /metrics` | merged per-worker [`Registry`] rollup + queue/cache/connection/job gauges. JSON by default; Prometheus text exposition when the `Accept` header asks for `text/plain` |
//! | `GET /v1/trace` | merged per-worker [`trace::Collector`] rollup as `r2f2-trace/1` ndjson (request/job lifecycle spans on logical clocks; wall durations attached, excluded from trace *content*) |
//! | `POST /v1/profile` | `{"scenario": "<name>"\|"all"}` → RAPTOR-style pilot ([`trace::profile`]): per-rung range telemetry and a recommended starting format with predicted RMSE + modeled datapath cost |
//!
//! HTTP/1.1 keep-alive with in-order pipelining: a worker keeps answering
//! as long as the client has already-buffered requests, then parks the
//! socket back with the acceptor. Responses differ from the one-shot path
//! only in the `connection:` header, which is what the byte-identity
//! keep-alive tests pin.
//!
//! The response body of `/v1/run` (and of a job's `/result`) deliberately
//! excludes wall-clock time — it is the *deterministic* payload,
//! byte-identical across hits, misses, re-runs and crash-resumed jobs,
//! which is what makes the cache, the loopback bit-identity suite
//! (`rust/tests/serve_loopback.rs`) and the job suite
//! (`rust/tests/serve_jobs.rs`) possible. Timing lives in `/metrics`
//! (`serve.handle_ns` percentiles) instead.

pub mod cache;
pub mod http;
pub mod jobs;
pub mod pool;

use crate::config::json_mini::escape;
use crate::config::{parse_json, ExperimentConfig};
use crate::coordinator::job::Outcome;
use crate::coordinator::{self, run_experiment};
use crate::metrics::Registry;
use crate::pde::scenario::SCENARIOS;
use crate::pde::QuantMode;
use crate::trace::{profile, Clock, Collector, Value};
use cache::ResultCache;
use jobs::{EpochOutcome, JobStore, SubmitError};
use pool::{Bounded, WorkerPool};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// At most this many concurrent detached 503-responder threads; beyond it
/// rejected connections are dropped unanswered (still a rejection, and the
/// acceptor stays alive under any flood).
const MAX_REJECT_RESPONDERS: usize = 64;

/// At most this many concurrent detached event-streamer threads; beyond it
/// `GET /v1/jobs/:id/events` answers `503`. Streams are long-lived by
/// design (they follow a job to its terminal state), so they must not be
/// able to occupy the worker pool — each one owns its socket on a thread
/// of its own, and this cap bounds the thread count.
const MAX_STREAMERS: usize = 32;

/// Acceptor poll tick: the granularity of idle-socket peeks, returned
/// keep-alive pickups and the stop flag.
const ACCEPT_TICK: Duration = Duration::from_millis(1);

/// Server configuration (the `r2f2 serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// TCP port on 127.0.0.1 (0 = ephemeral, reported by [`Server::addr`]).
    pub port: u16,
    /// Worker threads ([`coordinator::default_workers`] by default, so the
    /// `R2F2_WORKERS` env override applies).
    pub workers: usize,
    /// Bounded work-queue capacity; a full queue rejects with `503`.
    pub queue_cap: usize,
    /// Result-cache capacity (entries, LRU-evicted).
    pub cache_cap: usize,
    /// Keep-alive idle deadline in milliseconds: how long a connection may
    /// sit in the acceptor's idle table with no request bytes before it is
    /// closed (`serve.idle_expired`). Also the arrival deadline for a
    /// fresh connection's first byte.
    pub keepalive_ms: u64,
    /// Job-store capacity: at most this many live jobs (`503` beyond) and
    /// this many retained terminal results (oldest-completion evicted).
    pub jobs_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            port: 7272,
            workers: coordinator::default_workers(),
            queue_cap: 64,
            cache_cap: 256,
            keepalive_ms: 5000,
            jobs_cap: 64,
        }
    }
}

/// A tracked connection: the socket plus the shared connection-count
/// gauge, incremented on accept and decremented on drop — however the
/// socket leaves (served and closed, idle-expired, rejected, streamed).
struct Conn {
    /// `None` only transiently, while a worker has moved the socket into
    /// a `BufReader` (the `Conn` survives as the gauge guard).
    stream: Option<TcpStream>,
    gauge: Arc<AtomicI64>,
}

impl Conn {
    fn new(stream: TcpStream, gauge: Arc<AtomicI64>) -> Conn {
        gauge.fetch_add(1, Ordering::SeqCst);
        Conn { stream: Some(stream), gauge }
    }
}

impl Drop for Conn {
    fn drop(&mut self) {
        self.gauge.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One unit of worker-pool work.
enum Work {
    /// A connection with request bytes waiting.
    Conn(Conn),
    /// Run one epoch of this job, then re-enqueue the continuation.
    Job(String),
}

/// State shared by the acceptor, the workers and the metrics rollup.
struct Shared {
    cache: ResultCache,
    queue: Arc<Bounded<Work>>,
    jobs: JobStore,
    /// Workers park finished keep-alive sockets back to the acceptor's
    /// idle table through this channel.
    returns: mpsc::Sender<Conn>,
    /// Live connection count (the `serve.connections` gauge).
    connections: Arc<AtomicI64>,
    /// Live detached event-streamer count (capped at [`MAX_STREAMERS`]).
    streamers: Arc<AtomicUsize>,
    /// Acceptor-side counters (`serve.accepted` / `serve.rejected` / ...).
    acceptor_reg: Registry,
    /// Every worker's private registry (handles — cloneable), so the
    /// `/metrics` route can roll up the whole pool, not just the worker
    /// that happens to serve the request.
    worker_regs: Vec<Registry>,
    /// One trace collector per worker, indexed like `worker_regs` (a
    /// worker finds its collector by registry handle identity,
    /// [`trace_for`]); `GET /v1/trace` merges them order-invariantly.
    traces: Vec<Collector>,
}

/// The trace collector belonging to the worker whose registry is `reg`.
/// Falls back to slot 0 for callers outside the pool (tests driving
/// handlers directly).
fn trace_for<'a>(shared: &'a Shared, reg: &Registry) -> &'a Collector {
    shared
        .worker_regs
        .iter()
        .position(|r| r.same_instance(reg))
        .map_or(&shared.traces[0], |i| &shared.traces[i])
}

/// Merge every per-worker trace collector into one snapshot — the
/// [`Collector::merge`] dual of [`rollup`]. Export order is canonical
/// (lane, seq, content), so the bytes don't depend on worker count or
/// merge order.
fn trace_rollup(shared: &Shared) -> Collector {
    let all = Collector::new();
    for t in &shared.traces {
        all.merge(t);
    }
    all
}

/// The full metrics rollup over shared state: acceptor counters + every
/// worker registry + queue/cache/connection/job gauges. Both the
/// `/metrics` route and [`Server::metrics_snapshot`] are exactly this.
fn rollup(shared: &Shared) -> Registry {
    let snap = Registry::new();
    snap.merge(&shared.acceptor_reg);
    for reg in &shared.worker_regs {
        snap.merge(reg);
    }
    let st = shared.cache.stats();
    snap.inc("serve.cache.hits", st.hits);
    snap.inc("serve.cache.misses", st.misses);
    snap.inc("serve.cache.evictions", st.evictions);
    snap.inc("serve.cache.guard_checks", st.guard_checks);
    snap.set("serve.queue.depth", shared.queue.len() as f64);
    snap.set("serve.cache.entries", shared.cache.len() as f64);
    snap.set("serve.connections", shared.connections.load(Ordering::SeqCst) as f64);
    snap.set("serve.streamers", shared.streamers.load(Ordering::SeqCst) as f64);
    let (live, terminal) = shared.jobs.counts();
    snap.set("serve.jobs.live", live as f64);
    snap.set("serve.jobs.terminal", terminal as f64);
    snap
}

/// A running simulation service. Dropping (or [`Server::shutdown`]) stops
/// the acceptor, drains admitted work and joins every pool thread.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    pool: Option<WorkerPool<Work>>,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind, spawn the worker pool and the acceptor, return immediately.
    pub fn start(opts: ServeOptions) -> Result<Server, String> {
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .map_err(|e| format!("bind 127.0.0.1:{}: {e}", opts.port))?;
        let addr = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        listener.set_nonblocking(true).map_err(|e| format!("nonblocking: {e}"))?;

        let queue = Arc::new(Bounded::new(opts.queue_cap));
        let worker_regs: Vec<Registry> =
            (0..opts.workers.max(1)).map(|_| Registry::new()).collect();
        let (returns, returned) = mpsc::channel::<Conn>();
        let shared = Arc::new(Shared {
            cache: ResultCache::new(opts.cache_cap),
            queue: queue.clone(),
            jobs: JobStore::new(opts.jobs_cap),
            returns,
            connections: Arc::new(AtomicI64::new(0)),
            streamers: Arc::new(AtomicUsize::new(0)),
            acceptor_reg: Registry::new(),
            worker_regs: worker_regs.clone(),
            traces: (0..opts.workers.max(1)).map(|_| Collector::new()).collect(),
        });

        let pool = {
            let shared = shared.clone();
            let handler = move |work: Work, reg: &Registry| match work {
                Work::Conn(conn) => handle_conn(conn, &shared, reg),
                Work::Job(id) => {
                    let outcome = jobs::run_epoch(&shared.jobs, &id, reg);
                    // The epoch span's logical clock is the job's own
                    // checkpoint counters — no wall time on this record.
                    let clock = shared
                        .jobs
                        .get(&id)
                        .map(|j| {
                            let j = j.lock().unwrap();
                            Clock {
                                step: j.steps_done as u64,
                                epoch: j.epochs_done as u64,
                                muls: 0,
                            }
                        })
                        .unwrap_or_default();
                    let outcome_name = match outcome {
                        EpochOutcome::Continue => "continue",
                        EpochOutcome::Terminal => "terminal",
                        EpochOutcome::Idle => "idle",
                    };
                    trace_for(&shared, reg).record(
                        "server/jobs",
                        "job.epoch",
                        clock,
                        vec![
                            ("id".into(), Value::Str(id.clone())),
                            ("outcome".into(), Value::Str(outcome_name.into())),
                        ],
                    );
                    if outcome == EpochOutcome::Continue {
                        // Continuations bypass the cap but queue behind
                        // admitted connections; see `Bounded::push_unbounded`
                        // for why that is both bounded and fair. Failure
                        // means shutdown — the job stays resumable from its
                        // checkpoint, just unscheduled.
                        let _ = shared.queue.push_unbounded(Work::Job(id));
                    }
                }
            };
            WorkerPool::with_registries(queue.clone(), worker_regs, handler)
        };

        let stop = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let stop = stop.clone();
            let shared = shared.clone();
            let keepalive = Duration::from_millis(opts.keepalive_ms.max(1));
            std::thread::spawn(move || {
                accept_loop(&listener, &stop, &shared, returned, keepalive);
                // Listener drops here: the port is released before
                // shutdown() returns.
            })
        };

        Ok(Server { addr, stop, acceptor: Some(acceptor), pool: Some(pool), shared })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Cache effectiveness counters.
    pub fn cache_stats(&self) -> cache::CacheStats {
        self.shared.cache.stats()
    }

    /// Merged metrics rollup: acceptor counters + every worker registry
    /// (via [`Registry::merge`]) + queue/cache/connection/job gauges.
    /// Identical to what `GET /metrics` serves.
    pub fn metrics_snapshot(&self) -> Registry {
        rollup(&self.shared)
    }

    /// Merged trace-collector rollup over every worker — identical to
    /// what `GET /v1/trace` exports.
    pub fn trace_snapshot(&self) -> Collector {
        trace_rollup(&self.shared)
    }

    /// Block on the acceptor thread — the `r2f2 serve` foreground mode
    /// (runs until the process is killed).
    pub fn wait(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
    }

    /// Graceful shutdown: stop accepting, drain admitted work, join the
    /// acceptor and every worker. Returning means no pool or acceptor
    /// thread is left and the port is released. (Detached event streamers
    /// may outlive shutdown briefly; they own their sockets and exit when
    /// their job ends or their client hangs up.)
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

// ---------------------------------------------------------------------------
// The acceptor: nonblocking accept + idle-socket polling
// ---------------------------------------------------------------------------

/// What one idle-table poll says about a socket.
enum Poll {
    /// No bytes yet, deadline not reached.
    Wait,
    /// Request bytes waiting — dispatch to the pool.
    Ready,
    /// Peer closed (half-closed counts: a read-shut client can never send
    /// another request, so the socket is done).
    Closed,
    /// Idle past the keep-alive deadline.
    Expired,
}

/// The acceptor loop: accept new sockets, re-admit keep-alive returns,
/// peek-poll the idle table, dispatch ready connections, expire idle ones.
/// Every socket in here is nonblocking; a connection only costs a worker
/// once its request bytes have arrived.
fn accept_loop(
    listener: &TcpListener,
    stop: &AtomicBool,
    shared: &Shared,
    returned: mpsc::Receiver<Conn>,
    keepalive: Duration,
) {
    let responders = Arc::new(AtomicUsize::new(0));
    let mut idle: Vec<(Conn, Instant)> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        // One clock read per tick: deadlines for this tick's admissions and
        // the expiry sweep all use it (1 ms granularity is plenty).
        let now = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — keep-alive idle deadlines are real time; no result bytes derive from this
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    shared.acceptor_reg.inc("serve.accepted", 1);
                    if stream.set_nonblocking(true).is_err() {
                        continue; // socket dropped; nothing to track
                    }
                    idle.push((Conn::new(stream, shared.connections.clone()), now + keepalive));
                    let open = shared.connections.load(Ordering::SeqCst).max(0) as f64;
                    shared.acceptor_reg.set_max("serve.connections.peak", open);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    // Persistent accept errors (fd exhaustion) must back
                    // off, not busy-spin a core.
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
        while let Ok(conn) = returned.try_recv() {
            shared.acceptor_reg.inc("serve.keepalive.parked", 1);
            idle.push((conn, now + keepalive));
        }
        let mut i = 0;
        while i < idle.len() {
            let verdict = match &idle[i].0.stream {
                None => Poll::Closed,
                Some(s) => match s.peek(&mut [0u8; 1]) {
                    Ok(0) => Poll::Closed,
                    Ok(_) => Poll::Ready,
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut =>
                    {
                        if now >= idle[i].1 {
                            Poll::Expired
                        } else {
                            Poll::Wait
                        }
                    }
                    Err(_) => Poll::Closed,
                },
            };
            match verdict {
                Poll::Wait => i += 1,
                Poll::Closed => {
                    shared.acceptor_reg.inc("serve.closed", 1);
                    idle.swap_remove(i);
                }
                Poll::Expired => {
                    shared.acceptor_reg.inc("serve.idle_expired", 1);
                    idle.swap_remove(i);
                }
                Poll::Ready => {
                    let (conn, _) = idle.swap_remove(i);
                    if let Err(Work::Conn(conn)) = shared.queue.try_push(Work::Conn(conn)) {
                        reject(conn, shared, &responders);
                    }
                }
            }
        }
        std::thread::sleep(ACCEPT_TICK);
    }
    // Remaining idle sockets close here (their gauge guards drop).
}

/// Backpressure: reject with 503. The drain + write happen on a
/// short-lived detached thread so a slow rejected client can never stall
/// the accept loop — stalling it under overload would make the server
/// reject work the draining queue could admit. The responders are bounded
/// and spawn failure is non-fatal (a flood must not kill the acceptor);
/// past the bound the connection is dropped, which is itself an
/// unambiguous rejection.
fn reject(conn: Conn, shared: &Shared, responders: &Arc<AtomicUsize>) {
    shared.acceptor_reg.inc("serve.rejected", 1);
    if responders.fetch_add(1, Ordering::SeqCst) < MAX_REJECT_RESPONDERS {
        let done = responders.clone();
        let spawned = std::thread::Builder::new().name("r2f2-reject".into()).spawn(move || {
            reject_with_503(conn);
            done.fetch_sub(1, Ordering::SeqCst);
        });
        if spawned.is_err() {
            responders.fetch_sub(1, Ordering::SeqCst);
            shared.acceptor_reg.inc("serve.rejected_dropped", 1);
        }
    } else {
        responders.fetch_sub(1, Ordering::SeqCst);
        shared.acceptor_reg.inc("serve.rejected_dropped", 1);
    }
}

// ---------------------------------------------------------------------------
// Request handling
// ---------------------------------------------------------------------------

fn respond(stream: &mut TcpStream, status: u16, extra: &[(&str, &str)], body: &str, close: bool) {
    let _ =
        http::write_response_with(stream, status, extra, "application/json", body.as_bytes(), close);
}

/// [`respond`] with a non-JSON content type (the Prometheus exposition
/// and the trace ndjson export).
fn respond_as(stream: &mut TcpStream, status: u16, content_type: &str, body: &str, close: bool) {
    let _ = http::write_response_with(stream, status, &[], content_type, body.as_bytes(), close);
}

fn respond_error(stream: &mut TcpStream, status: u16, msg: &str, close: bool) {
    respond(stream, status, &[], &format!("{{\"error\": \"{}\"}}", escape(msg)), close);
}

/// Rejection path: drain the request (bounded by the parser's size limits,
/// short timeouts), then answer 503. Draining first matters — closing a
/// socket that still has unread received bytes sends RST, which would tear
/// the 503 out of the client's receive buffer.
fn reject_with_503(mut conn: Conn) {
    let Some(stream) = conn.stream.take() else { return };
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut reader = BufReader::new(stream);
    let parsed = http::read_request(&mut reader);
    let mut stream = reader.into_inner();
    if parsed.is_err() {
        // Mid-stream parse failure leaves unread bytes; see drain_best_effort.
        drain_best_effort(&stream);
    }
    let _ = http::write_response(
        &mut stream,
        503,
        &[("retry-after", "1")],
        "application/json",
        b"{\"error\": \"job queue full\"}",
    );
    // `conn` drops here: the connection gauge sees the rejection out.
}

/// Best-effort drain of unread request bytes before an error response.
/// Only needed when request parsing failed mid-stream: closing a socket
/// with unread received bytes sends RST, which can tear the error response
/// out of the client's receive buffer. Bounded in both bytes and time.
fn drain_best_effort(stream: &TcpStream) {
    use std::io::Read;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut sink = [0u8; 4096];
    let mut total = 0usize;
    let mut s = stream;
    while total < 256 * 1024 {
        match s.read(&mut sink) {
            Ok(0) => break,
            Ok(n) => total += n,
            Err(_) => break,
        }
    }
}

/// Serve one dispatched connection: answer the request whose bytes woke
/// it, keep answering while the client has pipelined more, then either
/// close (client asked, or an error did) or park the socket back with the
/// acceptor for the next keep-alive round.
///
/// The 2-second read deadline bounds what a byte-dribbling client can cost
/// a worker *per request*; a client sending nothing costs only the
/// acceptor's idle table (the §16 division of labor).
fn handle_conn(mut conn: Conn, shared: &Shared, reg: &Registry) {
    let Some(stream) = conn.stream.take() else { return };
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let mut reader = BufReader::new(stream);
    let mut served_here = 0u64;
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(r) => r,
            Err(e) => {
                reg.inc("serve.http.400", 1);
                let mut stream = reader.into_inner();
                drain_best_effort(&stream);
                respond_error(&mut stream, 400, &e, true);
                return;
            }
        };
        reg.inc("serve.requests", 1);
        if served_here > 0 {
            reg.inc("serve.keepalive.reuses", 1);
        }
        served_here += 1;
        let close = req
            .header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false);

        // The events route streams for the job's lifetime: it takes the
        // socket over entirely (chunked, `connection: close`).
        if let Some((id, Some("events"))) = job_path(&req.path) {
            if req.method == "GET" {
                conn.stream = Some(reader.into_inner());
                handle_events(conn, id, shared, reg);
                return;
            }
        }

        let t0 = Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — request-span wall duration is telemetry attached outside the deterministic trace content; no result bytes derive from it
        route(&req, reader.get_mut(), shared, reg, close);
        trace_for(shared, reg).record_wall(
            "server/http",
            "http.request",
            Clock::zero(),
            vec![
                ("method".into(), Value::Str(req.method.clone())),
                ("path".into(), Value::Str(req.path.clone())),
            ],
            t0.elapsed().as_nanos() as u64,
        );
        if close {
            return;
        }
        if !reader.buffer().is_empty() {
            // The client pipelined: answer in order, same worker, no
            // round-trip through the acceptor.
            reg.inc("serve.pipelined", 1);
            continue;
        }
        // Park the socket back with the acceptor until the next request.
        let stream = reader.into_inner();
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        conn.stream = Some(stream);
        let _ = shared.returns.send(conn); // acceptor gone ⇒ drop closes
        return;
    }
}

/// Dispatch one parsed request to its route.
fn route(req: &http::Request, stream: &mut TcpStream, shared: &Shared, reg: &Registry, close: bool) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond(
            stream,
            200,
            &[],
            &format!("{{\"status\": \"ok\", \"scenarios\": {}}}", SCENARIOS.len()),
            close,
        ),
        ("GET", "/v1/scenarios") => respond(stream, 200, &[], &scenarios_json(), close),
        ("GET", "/metrics") => {
            // Content negotiation: the JSON body existing clients parse is
            // the default and stays byte-identical; a scraper asking for
            // text/plain gets the Prometheus exposition instead.
            let wants_text =
                req.header("accept").map(|v| v.contains("text/plain")).unwrap_or(false);
            if wants_text {
                respond_as(
                    stream,
                    200,
                    "text/plain; version=0.0.4",
                    &rollup(shared).to_prometheus(),
                    close,
                );
            } else {
                respond(stream, 200, &[], &rollup(shared).to_json(), close);
            }
        }
        ("GET", "/v1/trace") => respond_as(
            stream,
            200,
            "application/x-ndjson",
            &trace_rollup(shared).to_ndjson(),
            close,
        ),
        ("POST", "/v1/run") => handle_run(&req.body, stream, shared, reg, close),
        ("POST", "/v1/jobs") => handle_job_submit(&req.body, stream, shared, reg, close),
        ("POST", "/v1/profile") => handle_profile(&req.body, stream, shared, reg, close),
        (_, "/healthz" | "/v1/scenarios" | "/metrics" | "/v1/trace") => {
            reg.inc("serve.http.405", 1);
            respond_error(stream, 405, "use GET", close);
        }
        (_, "/v1/run" | "/v1/jobs" | "/v1/profile") => {
            reg.inc("serve.http.405", 1);
            respond_error(stream, 405, "use POST", close);
        }
        (method, path) => match job_path(path) {
            Some((id, sub)) => handle_job_routes(method, id, sub, stream, shared, reg, close),
            None => {
                reg.inc("serve.http.404", 1);
                respond_error(stream, 404, &format!("no route {path}"), close);
            }
        },
    }
}

/// Split `/v1/jobs/<id>[/<sub>]` into `(id, sub)`; `None` for any other
/// path (including `/v1/jobs` itself and empty ids).
fn job_path(path: &str) -> Option<(&str, Option<&str>)> {
    let rest = path.strip_prefix("/v1/jobs/")?;
    match rest.split_once('/') {
        None if rest.is_empty() => None,
        None => Some((rest, None)),
        Some((id, sub)) if !id.is_empty() && !sub.is_empty() => Some((id, Some(sub))),
        Some(_) => None,
    }
}

fn handle_job_submit(
    body: &[u8],
    stream: &mut TcpStream,
    shared: &Shared,
    reg: &Registry,
    close: bool,
) {
    match shared.jobs.submit(body) {
        Ok(id) => {
            reg.inc("serve.jobs.submitted", 1);
            trace_for(shared, reg).record(
                "server/jobs",
                "job.submitted",
                Clock::zero(),
                vec![("id".into(), Value::Str(id.clone()))],
            );
            // First epoch enqueued like a continuation: bypasses the cap
            // (bounded by jobs_cap, which the submit above just enforced)
            // so an accepted job is always scheduled.
            let _ = shared.queue.push_unbounded(Work::Job(id.clone()));
            let body = format!(
                "{{\"id\": \"{id}\", \"status\": \"/v1/jobs/{id}\", \
                 \"result\": \"/v1/jobs/{id}/result\", \"events\": \"/v1/jobs/{id}/events\"}}"
            );
            respond(stream, 202, &[("x-r2f2-job", id.as_str())], &body, close);
        }
        Err(SubmitError::Bad(e)) => {
            reg.inc("serve.http.400", 1);
            respond_error(stream, 400, &e, close);
        }
        Err(SubmitError::Full) => {
            reg.inc("serve.jobs.rejected", 1);
            respond(
                stream,
                503,
                &[("retry-after", "1")],
                "{\"error\": \"job store full\"}",
                close,
            );
        }
    }
}

fn handle_job_routes(
    method: &str,
    id: &str,
    sub: Option<&str>,
    stream: &mut TcpStream,
    shared: &Shared,
    reg: &Registry,
    close: bool,
) {
    let job = shared.jobs.get(id);
    let not_found = |stream: &mut TcpStream, reg: &Registry| {
        reg.inc("serve.http.404", 1);
        respond_error(stream, 404, &format!("no job {id}"), close);
    };
    match (method, sub) {
        ("GET", None) => match job {
            Some(j) => respond(stream, 200, &[], &j.lock().unwrap().status_json(), close),
            None => not_found(stream, reg),
        },
        ("GET", Some("result")) => match job {
            Some(j) => {
                let j = j.lock().unwrap();
                if let Some(body) = &j.body {
                    respond(stream, 200, &[("x-r2f2-job", id)], body, close);
                } else if j.state == jobs::JobState::Failed {
                    reg.inc("serve.http.500", 1);
                    respond_error(stream, 500, j.error.as_deref().unwrap_or("job failed"), close);
                } else {
                    reg.inc("serve.http.409", 1);
                    respond_error(
                        stream,
                        409,
                        &format!("job {id} is {}", j.state.as_str()),
                        close,
                    );
                }
            }
            None => not_found(stream, reg),
        },
        ("POST", Some("pause")) => match job {
            Some(j) => match shared.jobs.pause(id) {
                Ok(()) => {
                    reg.inc("serve.jobs.paused", 1);
                    respond(stream, 200, &[], &j.lock().unwrap().status_json(), close);
                }
                Err(e) => {
                    reg.inc("serve.http.409", 1);
                    respond_error(stream, 409, &e, close);
                }
            },
            None => not_found(stream, reg),
        },
        ("POST", Some("resume")) => match job {
            Some(j) => match shared.jobs.resume(id) {
                Ok(needs_enqueue) => {
                    reg.inc("serve.jobs.resumed", 1);
                    if needs_enqueue {
                        let _ = shared.queue.push_unbounded(Work::Job(id.to_string()));
                    }
                    respond(stream, 200, &[], &j.lock().unwrap().status_json(), close);
                }
                Err(e) => {
                    reg.inc("serve.http.409", 1);
                    respond_error(stream, 409, &e, close);
                }
            },
            None => not_found(stream, reg),
        },
        (_, Some("events")) => {
            // GET /events is consumed before routing; only wrong methods
            // can land here.
            reg.inc("serve.http.405", 1);
            respond_error(stream, 405, "use GET", close);
        }
        (_, None | Some("result")) => {
            reg.inc("serve.http.405", 1);
            respond_error(stream, 405, "use GET", close);
        }
        (_, Some("pause" | "resume")) => {
            reg.inc("serve.http.405", 1);
            respond_error(stream, 405, "use POST", close);
        }
        (_, Some(other)) => {
            reg.inc("serve.http.404", 1);
            respond_error(stream, 404, &format!("no route /v1/jobs/{id}/{other}"), close);
        }
    }
}

/// `GET /v1/jobs/:id/events`: hand the socket to a detached streamer
/// thread that follows the job's ndjson event log to its terminal state.
/// Streamers are bounded by [`MAX_STREAMERS`] (503 beyond) so they can
/// never exhaust threads, and they hold the `Conn` gauge guard for their
/// whole lifetime, so `/metrics` counts streaming connections too.
fn handle_events(mut conn: Conn, id: &str, shared: &Shared, reg: &Registry) {
    let Some(mut stream) = conn.stream.take() else { return };
    let Some(job) = shared.jobs.get(id) else {
        reg.inc("serve.http.404", 1);
        respond_error(&mut stream, 404, &format!("no job {id}"), true);
        return;
    };
    if shared.streamers.fetch_add(1, Ordering::SeqCst) >= MAX_STREAMERS {
        shared.streamers.fetch_sub(1, Ordering::SeqCst);
        reg.inc("serve.streamers.rejected", 1);
        respond(
            &mut stream,
            503,
            &[("retry-after", "1")],
            "{\"error\": \"too many event streams\"}",
            true,
        );
        return;
    }
    reg.inc("serve.streams", 1);
    conn.stream = Some(stream);
    let streamers = shared.streamers.clone();
    let spawned = std::thread::Builder::new().name("r2f2-stream".into()).spawn(move || {
        stream_events(conn, job);
        streamers.fetch_sub(1, Ordering::SeqCst);
    });
    if spawned.is_err() {
        shared.streamers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The streamer body: chunked ndjson, one event per line, following the
/// job's event log cursor until the job is terminal and fully flushed.
/// Exits early if the client hangs up (detected by peek between polls).
fn stream_events(mut conn: Conn, job: Arc<Mutex<jobs::Job>>) {
    let Some(mut stream) = conn.stream.take() else { return };
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    // The read half is only peeked for EOF; a short timeout turns those
    // peeks into cheap liveness checks.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(1)));
    if http::write_chunked_head(&mut stream, 200, &[], "application/x-ndjson").is_err() {
        return;
    }
    let mut cursor = 0usize;
    loop {
        let (batch, done) = {
            let j = job.lock().unwrap();
            (j.events_from(cursor).to_vec(), j.state.is_terminal())
        };
        cursor += batch.len();
        for line in &batch {
            let mut data = Vec::with_capacity(line.len() + 1);
            data.extend_from_slice(line.as_bytes());
            data.push(b'\n');
            if http::write_chunk(&mut stream, &data).is_err() {
                return;
            }
        }
        if done {
            // Terminal events are appended under the same lock that sets
            // the state, so `done` implies the log above was complete.
            break;
        }
        match stream.peek(&mut [0u8; 1]) {
            Ok(0) => return, // client hung up
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => return,
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let _ = http::finish_chunked(&mut stream);
}

/// `POST /v1/profile`: run the RAPTOR-style pilot and return the format
/// plan. Body `{"scenario": "<name>"}` profiles one registry entry (plan
/// object); `{"scenario": "all"}` — or an empty/omitted field — profiles
/// the whole registry (`{"plans": [...]}` wrapper). Pilot `profile.rung`
/// events land in the serving worker's trace collector, so a profile
/// shows up under `GET /v1/trace` like any other span source.
fn handle_profile(
    body: &[u8],
    stream: &mut TcpStream,
    shared: &Shared,
    reg: &Registry,
    close: bool,
) {
    let which = if body.is_empty() {
        "all".to_string()
    } else {
        let text = match std::str::from_utf8(body) {
            Ok(t) => t,
            Err(_) => {
                reg.inc("serve.http.400", 1);
                return respond_error(stream, 400, "body is not UTF-8", close);
            }
        };
        let json = match parse_json(text) {
            Ok(j) => j,
            Err(e) => {
                reg.inc("serve.http.400", 1);
                return respond_error(stream, 400, &format!("bad JSON: {e}"), close);
            }
        };
        json.get("scenario")
            .and_then(|s| s.as_str())
            .unwrap_or("all")
            .to_string()
    };
    let tr = trace_for(shared, reg);
    reg.inc("serve.profiles", 1);
    if which == "all" {
        let plans = reg.time("serve.profile_ns", || profile::run_all_pilots(Some(tr)));
        respond(stream, 200, &[], &profile::plans_json(&plans), close)
    } else {
        match crate::pde::scenario::find(&which) {
            Some(spec) => {
                let plan = reg.time("serve.profile_ns", || profile::run_pilot(spec, Some(tr)));
                respond(stream, 200, &[], &plan.to_json(), close)
            }
            None => {
                reg.inc("serve.http.400", 1);
                respond_error(stream, 400, &format!("no scenario {which}"), close)
            }
        }
    }
}

fn handle_run(body: &[u8], stream: &mut TcpStream, shared: &Shared, reg: &Registry, close: bool) {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => {
            reg.inc("serve.http.400", 1);
            return respond_error(stream, 400, "body is not UTF-8", close);
        }
    };
    let json = match parse_json(text) {
        Ok(j) => j,
        Err(e) => {
            reg.inc("serve.http.400", 1);
            return respond_error(stream, 400, &format!("bad JSON: {e}"), close);
        }
    };
    let cfg = match ExperimentConfig::from_json(&json) {
        Ok(c) => c,
        Err(e) => {
            reg.inc("serve.http.400", 1);
            return respond_error(stream, 400, &format!("bad config: {e}"), close);
        }
    };
    let (canonical, key) = cache::content_key(&cfg);
    let (value, hit) =
        shared.cache.get_or_insert_with(&canonical, || outcome_json(&run_experiment(&cfg, reg)));
    reg.inc(if hit { "serve.run.hits" } else { "serve.run.misses" }, 1);
    let cache_header = if hit { "hit" } else { "miss" };
    let headers = [("x-r2f2-cache", cache_header), ("x-r2f2-key", key.as_str())];
    respond(stream, 200, &headers, value.as_str(), close);
}

// ---------------------------------------------------------------------------
// JSON shaping
// ---------------------------------------------------------------------------

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// The deterministic response body for one outcome. Wall-clock time is
/// deliberately excluded: everything here is bit-reproducible, which is
/// the property the cache (and its determinism guard) relies on.
pub fn outcome_json(o: &Outcome) -> String {
    let mode = match o.mode {
        QuantMode::MulOnly => "mul-only",
        QuantMode::Full => "full",
    };
    let adjustments = match o.adjustments {
        Some((w, n)) => format!("{{\"widen\": {w}, \"narrow\": {n}}}"),
        None => "null".to_string(),
    };
    let range_events = match o.range_events {
        Some((of, uf)) => format!("{{\"overflows\": {of}, \"underflows\": {uf}}}"),
        None => "null".to_string(),
    };
    let field: Vec<String> = o.field.iter().map(|&v| json_f64(v)).collect();
    format!(
        "{{\"title\": \"{}\", \"app\": \"{}\", \"backend\": \"{}\", \"mode\": \"{mode}\", \
         \"rel_err_vs_f64\": {}, \"muls\": {}, \"adjustments\": {adjustments}, \
         \"range_events\": {range_events}, \"n\": {}, \"field\": [{}]}}",
        escape(&o.title),
        escape(&o.app),
        escape(&o.backend),
        json_f64(o.rel_err_vs_f64),
        o.muls,
        o.field.len(),
        field.join(", ")
    )
}

/// The `/v1/scenarios` body: the registry, one object per entry.
pub fn scenarios_json() -> String {
    let items: Vec<String> = SCENARIOS
        .iter()
        .map(|s| {
            format!(
                "{{\"name\": \"{}\", \"physics\": \"{}\", \"stress\": \"{}\", \
                 \"wide_format\": \"{}\", \"expect_narrow\": {}}}",
                escape(s.name),
                escape(s.physics),
                escape(s.stress),
                s.wide_format,
                s.expect_narrow
            )
        })
        .collect();
    format!("{{\"scenarios\": [{}]}}", items.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_backend;
    use crate::pde::init::HeatInit;

    fn quick_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = "heat".into();
        c.backend = parse_backend("fixed:E5M10").unwrap();
        c.heat.n = 17;
        c.heat.dt = 0.25 / (16.0 * 16.0);
        c.heat.steps = 10;
        c.heat.init = HeatInit::sin_default();
        c
    }

    fn test_opts() -> ServeOptions {
        ServeOptions {
            port: 0,
            workers: 2,
            queue_cap: 8,
            cache_cap: 8,
            keepalive_ms: 5000,
            jobs_cap: 8,
        }
    }

    #[test]
    fn outcome_json_is_deterministic_and_parseable() {
        let cfg = quick_cfg();
        let a = outcome_json(&run_experiment(&cfg, &Registry::new()));
        let b = outcome_json(&run_experiment(&cfg, &Registry::new()));
        assert_eq!(a, b, "two runs of one config must serialize identically");
        let j = parse_json(&a).unwrap();
        assert_eq!(j.get("app").unwrap().as_str(), Some("heat"));
        assert_eq!(j.get("backend").unwrap().as_str(), Some("fixed:E5M10"));
        assert_eq!(j.get("mode").unwrap().as_str(), Some("mul-only"));
        assert_eq!(j.get("n").unwrap().as_usize(), Some(17));
        assert_eq!(j.get("field").unwrap().as_arr().unwrap().len(), 17);
        assert!(j.get("muls").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn scenarios_json_lists_the_registry() {
        let j = parse_json(&scenarios_json()).unwrap();
        let arr = j.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), SCENARIOS.len());
        for (item, spec) in arr.iter().zip(SCENARIOS) {
            assert_eq!(item.get("name").unwrap().as_str(), Some(spec.name));
        }
    }

    #[test]
    fn job_path_splits_ids_and_subresources() {
        assert_eq!(job_path("/v1/jobs/job-1"), Some(("job-1", None)));
        assert_eq!(job_path("/v1/jobs/job-1/result"), Some(("job-1", Some("result"))));
        assert_eq!(job_path("/v1/jobs/job-1/events"), Some(("job-1", Some("events"))));
        assert_eq!(job_path("/v1/jobs"), None);
        assert_eq!(job_path("/v1/jobs/"), None);
        assert_eq!(job_path("/v1/jobs/job-1/"), None);
        assert_eq!(job_path("/v1/run"), None);
    }

    #[test]
    fn server_starts_and_answers_healthz() {
        let server = Server::start(test_opts()).unwrap();
        let resp = http::request(server.addr(), "GET", "/healthz", b"").unwrap();
        assert_eq!(resp.status, 200);
        let j = parse_json(&resp.text()).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));
        server.shutdown();
    }

    #[test]
    fn keep_alive_serves_many_requests_on_one_connection() {
        let server = Server::start(test_opts()).unwrap();
        let mut client = http::Client::connect(server.addr()).unwrap();
        for _ in 0..3 {
            let resp = client.send("GET", "/healthz", b"").unwrap();
            assert_eq!(resp.status, 200);
            assert_eq!(resp.header("connection"), Some("keep-alive"));
        }
        let snap = server.metrics_snapshot();
        assert!(
            snap.counter("serve.keepalive.reuses") + snap.counter("serve.keepalive.parked") >= 2,
            "reuse must show up in metrics"
        );
        server.shutdown();
    }

    #[test]
    fn job_submitted_over_http_completes_and_matches_v1_run() {
        let server = Server::start(test_opts()).unwrap();
        let body = b"{\"app\": \"heat\", \"backend\": \"fixed:E5M10\", \
                      \"heat\": {\"n\": 17, \"steps\": 24, \"dt\": 9.7e-4}}";
        let accepted = http::request(server.addr(), "POST", "/v1/jobs", body).unwrap();
        assert_eq!(accepted.status, 202);
        let id = parse_json(&accepted.text())
            .unwrap()
            .get("id")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let result_path = format!("/v1/jobs/{id}/result");
        let deadline = 4000; // polls
        let mut body_out = None;
        for _ in 0..deadline {
            let r = http::request(server.addr(), "GET", &result_path, b"").unwrap();
            if r.status == 200 {
                body_out = Some(r.text());
                break;
            }
            assert_eq!(r.status, 409, "only 'not finished' is acceptable while polling");
            std::thread::sleep(Duration::from_millis(5));
        }
        let direct = http::request(server.addr(), "POST", "/v1/run", body).unwrap();
        assert_eq!(direct.status, 200);
        assert_eq!(
            body_out.expect("job finished"),
            direct.text(),
            "job result must be byte-identical to /v1/run"
        );
        server.shutdown();
    }

    #[test]
    fn metrics_negotiates_prometheus_text_and_keeps_json_default() {
        let server = Server::start(test_opts()).unwrap();
        let _ = http::request(server.addr(), "GET", "/healthz", b"").unwrap();
        let json = http::request(server.addr(), "GET", "/metrics", b"").unwrap();
        assert_eq!(json.status, 200);
        assert_eq!(json.header("content-type"), Some("application/json"));
        let parsed = parse_json(&json.text()).expect("default body is still JSON");
        assert!(parsed.get("counters").is_some());
        let prom = http::request_with_headers(
            server.addr(),
            "GET",
            "/metrics",
            &[("accept", "text/plain")],
            b"",
        )
        .unwrap();
        assert_eq!(prom.status, 200);
        assert_eq!(prom.header("content-type"), Some("text/plain; version=0.0.4"));
        let text = prom.text();
        assert!(text.starts_with("# r2f2 metrics exposition"));
        assert!(text.contains("# TYPE r2f2_serve_accepted counter"));
        assert!(
            text.lines().all(|l| l.starts_with('#') || l.starts_with("r2f2_")),
            "every exposition line is a comment or a namespaced sample"
        );
        server.shutdown();
    }

    #[test]
    fn trace_route_exports_request_and_job_spans() {
        let server = Server::start(test_opts()).unwrap();
        let _ = http::request(server.addr(), "GET", "/healthz", b"").unwrap();
        let body = b"{\"app\": \"heat\", \"backend\": \"fixed:E5M10\", \
                      \"heat\": {\"n\": 17, \"steps\": 24, \"dt\": 9.7e-4}}";
        let accepted = http::request(server.addr(), "POST", "/v1/jobs", body).unwrap();
        assert_eq!(accepted.status, 202);
        // Let the job's first epoch land so a job.epoch span exists.
        for _ in 0..4000 {
            if server.trace_snapshot().snapshot().iter().any(|e| e.name == "job.epoch") {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let resp = http::request(server.addr(), "GET", "/v1/trace", b"").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some("application/x-ndjson"));
        let text = resp.text();
        let header = parse_json(text.lines().next().unwrap()).unwrap();
        assert_eq!(header.get("schema").unwrap().as_str(), Some("r2f2-trace/1"));
        assert!(text.contains("\"name\": \"http.request\""));
        assert!(text.contains("\"name\": \"job.submitted\""));
        assert!(text.contains("\"name\": \"job.epoch\""));
        for line in text.lines() {
            parse_json(line).expect("every trace line is one JSON object");
        }
        // The request spans carry wall durations (sanctioned attachments);
        // the content projection drops them and nothing else.
        let content = server.trace_snapshot().content_ndjson();
        assert!(!content.contains("wall_ns"));
        server.shutdown();
    }

    #[test]
    fn profile_route_returns_a_plan_and_rejects_unknown_scenarios() {
        let server = Server::start(test_opts()).unwrap();
        let one = http::request(
            server.addr(),
            "POST",
            "/v1/profile",
            b"{\"scenario\": \"heat1d\"}",
        )
        .unwrap();
        assert_eq!(one.status, 200);
        let plan = parse_json(&one.text()).unwrap();
        assert_eq!(plan.get("schema").unwrap().as_str(), Some("r2f2-profile-plan/1"));
        assert_eq!(plan.get("scenario").unwrap().as_str(), Some("heat1d"));
        assert!(plan.get("recommendation").unwrap().get("seed_rung").is_some());
        let bad = http::request(
            server.addr(),
            "POST",
            "/v1/profile",
            b"{\"scenario\": \"nope\"}",
        )
        .unwrap();
        assert_eq!(bad.status, 400);
        let all = http::request(server.addr(), "POST", "/v1/profile", b"").unwrap();
        assert_eq!(all.status, 200);
        let plans = parse_json(&all.text()).unwrap();
        assert_eq!(
            plans.get("plans").unwrap().as_arr().unwrap().len(),
            SCENARIOS.len(),
            "empty body profiles the whole registry"
        );
        let wrong_method = http::request(server.addr(), "GET", "/v1/profile", b"").unwrap();
        assert_eq!(wrong_method.status, 405);
        let wrong_method = http::request(server.addr(), "POST", "/v1/trace", b"").unwrap();
        assert_eq!(wrong_method.status, 405);
        server.shutdown();
    }
}
