//! Minimal HTTP/1.1 support for the serving subsystem (no hyper/axum in
//! this environment — DESIGN.md §12).
//!
//! Scope: exactly what `r2f2 serve` and its loopback load generator need.
//! `Content-Length`-framed bodies on requests and plain responses, chunked
//! transfer encoding for the streamed job-event route, HTTP/1.1 keep-alive
//! with in-order pipelining (DESIGN.md §16), header names normalized to
//! lowercase. Both directions live here — [`read_request`] /
//! [`write_response_with`] for the server workers, [`request`] /
//! [`Client`] / [`read_response`] for the in-process clients
//! (`bench-serve`, `tests/serve_loopback.rs`, `tests/serve_keepalive.rs`)
//! — so the parser that the tests drive is the same code the server
//! trusts.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Reject requests whose header block exceeds this many bytes.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Reject requests whose declared body exceeds this many bytes.
pub const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

/// A parsed HTTP request (server side).
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Request target with any `?query` suffix stripped.
    pub path: String,
    /// Header names lowercased, values trimmed, in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// A parsed HTTP response (client side).
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 (lossy — bodies here are always JSON text).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Standard reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        409 => "Conflict",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

fn read_crlf_line<R: BufRead>(r: &mut R, budget: &mut usize) -> Result<String, String> {
    let mut line = String::new();
    let n = r.read_line(&mut line).map_err(|e| format!("read: {e}"))?;
    if n == 0 {
        return Err("connection closed mid-request".into());
    }
    *budget = budget.checked_sub(n).ok_or("header block too large")?;
    Ok(line.trim_end_matches(['\r', '\n']).to_string())
}

/// Parse one request: request line, headers, `Content-Length` body.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Request, String> {
    // Belt and braces against hostile header blocks: the per-line budget
    // gives precise errors, and the `take` wrapper hard-bounds how much a
    // single line with no `\n` in it can ever buffer into memory.
    let mut budget = MAX_HEADER_BYTES;
    let mut head = r.by_ref().take(MAX_HEADER_BYTES as u64 + 2);
    let start = read_crlf_line(&mut head, &mut budget)?;
    let parts: Vec<&str> = start.split_whitespace().collect();
    if parts.len() != 3 || !parts[2].starts_with("HTTP/1.") {
        return Err(format!("malformed request line `{start}`"));
    }
    let method = parts[0].to_string();
    let path = parts[1].split('?').next().unwrap_or("").to_string();

    let mut headers = Vec::new();
    loop {
        let line = read_crlf_line(&mut head, &mut budget)?;
        if line.is_empty() {
            break;
        }
        let (k, v) = line.split_once(':').ok_or_else(|| format!("malformed header `{line}`"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    let len: usize = match headers.iter().find(|(k, _)| k == "content-length") {
        None => 0,
        Some((_, v)) => v.parse().map_err(|_| format!("bad content-length `{v}`"))?,
    };
    if len > MAX_BODY_BYTES {
        return Err(format!("body of {len} bytes exceeds the {MAX_BODY_BYTES} limit"));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| format!("body read: {e}"))?;
    Ok(Request { method, path, headers, body })
}

/// Write a complete response (`Content-Length` framed, `Connection: close`).
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    extra_headers: &[(&str, &str)],
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write_response_with(w, status, extra_headers, content_type, body, true)
}

/// Write a complete `Content-Length`-framed response, advertising
/// `connection: keep-alive` when `close` is false — identical bytes to
/// [`write_response`] apart from that one header, which is what makes
/// keep-alive vs one-shot responses byte-comparable in the tests.
pub fn write_response_with<W: Write>(
    w: &mut W,
    status: u16,
    extra_headers: &[(&str, &str)],
    content_type: &str,
    body: &[u8],
    close: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\n\
         content-length: {}\r\nconnection: {}\r\n",
        reason(status),
        body.len(),
        if close { "close" } else { "keep-alive" }
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Start a chunked streaming response (the `/v1/jobs/:id/events` route).
/// Streams always end with `connection: close` — the stream's length is
/// unknowable up front, so the terminal chunk is the framing boundary and
/// the socket is not reused after it.
pub fn write_chunked_head<W: Write>(
    w: &mut W,
    status: u16,
    extra_headers: &[(&str, &str)],
    content_type: &str,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\n\
         transfer-encoding: chunked\r\nconnection: close\r\n",
        reason(status)
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.flush()
}

/// Write one chunk of a chunked response (empty `data` is skipped — a
/// zero-length chunk would terminate the stream).
pub fn write_chunk<W: Write>(w: &mut W, data: &[u8]) -> std::io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked response (the zero chunk + final CRLF).
pub fn finish_chunked<W: Write>(w: &mut W) -> std::io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

/// Parse one response (client side). With `Connection: close` framing the
/// body is still read by `Content-Length` so short reads fail loudly.
pub fn read_response<R: BufRead>(r: &mut R) -> Result<Response, String> {
    let mut budget = MAX_HEADER_BYTES;
    let mut head = r.by_ref().take(MAX_HEADER_BYTES as u64 + 2);
    let start = read_crlf_line(&mut head, &mut budget)?;
    let mut parts = start.split_whitespace();
    let version = parts.next().unwrap_or("");
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("malformed status line `{start}`"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(format!("malformed status line `{start}`"));
    }

    let mut headers = Vec::new();
    loop {
        let line = read_crlf_line(&mut head, &mut budget)?;
        if line.is_empty() {
            break;
        }
        let (k, v) = line.split_once(':').ok_or_else(|| format!("malformed header `{line}`"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    let chunked = headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && v.to_ascii_lowercase().contains("chunked"));
    let mut body = Vec::new();
    if chunked {
        while let Some(chunk) = read_chunk(r)? {
            body.extend_from_slice(&chunk);
        }
    } else {
        match headers.iter().find(|(k, _)| k == "content-length") {
            Some((_, v)) => {
                let len: usize = v.parse().map_err(|_| format!("bad content-length `{v}`"))?;
                body = vec![0u8; len];
                r.read_exact(&mut body).map_err(|e| format!("body read: {e}"))?;
            }
            None => {
                r.read_to_end(&mut body).map_err(|e| format!("body read: {e}"))?;
            }
        }
    }
    Ok(Response { status, headers, body })
}

/// Read one chunk of a chunked body: `Some(data)` per chunk, `None` at the
/// terminal zero chunk. Exposed so a streaming client can consume events
/// incrementally instead of blocking for the whole stream.
pub fn read_chunk<R: BufRead>(r: &mut R) -> Result<Option<Vec<u8>>, String> {
    let mut budget = MAX_HEADER_BYTES;
    let line = read_crlf_line(r, &mut budget)?;
    let size_part = line.split(';').next().unwrap_or("").trim();
    let n = usize::from_str_radix(size_part, 16).map_err(|_| format!("bad chunk size `{line}`"))?;
    if n == 0 {
        // Consume trailers (none are ever sent here) up to the blank line.
        loop {
            if read_crlf_line(r, &mut budget)?.is_empty() {
                return Ok(None);
            }
        }
    }
    if n > MAX_BODY_BYTES {
        return Err(format!("chunk of {n} bytes exceeds the {MAX_BODY_BYTES} limit"));
    }
    let mut data = vec![0u8; n];
    r.read_exact(&mut data).map_err(|e| format!("chunk read: {e}"))?;
    let mut crlf = [0u8; 2];
    r.read_exact(&mut crlf).map_err(|e| format!("chunk read: {e}"))?;
    if &crlf != b"\r\n" {
        return Err("chunk missing CRLF terminator".into());
    }
    Ok(Some(data))
}

/// One-shot client: connect, send `method path` with `body`, parse the
/// response. Used by `bench-serve` and the loopback tests.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<Response, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .map_err(|e| format!("timeout: {e}"))?;
    let mut w = &stream;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nhost: {addr}\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    );
    w.write_all(head.as_bytes()).map_err(|e| format!("send: {e}"))?;
    w.write_all(body).map_err(|e| format!("send: {e}"))?;
    w.flush().map_err(|e| format!("send: {e}"))?;
    let mut r = BufReader::new(&stream);
    read_response(&mut r)
}

/// [`request`] plus caller-supplied extra request headers — what content
/// negotiation needs (e.g. `Accept: text/plain` against `GET /metrics`).
pub fn request_with_headers(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<Response, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .map_err(|e| format!("timeout: {e}"))?;
    let mut w = &stream;
    let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {addr}\r\n");
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("content-length: {}\r\nconnection: close\r\n\r\n", body.len()));
    w.write_all(head.as_bytes()).map_err(|e| format!("send: {e}"))?;
    w.write_all(body).map_err(|e| format!("send: {e}"))?;
    w.flush().map_err(|e| format!("send: {e}"))?;
    let mut r = BufReader::new(&stream);
    read_response(&mut r)
}

/// A keep-alive client: one TCP connection carrying many requests, with
/// optional pipelining ([`Client::send_only`] several, then [`Client::recv`]
/// in order). The write half and the buffered read half are the same
/// socket via `try_clone`.
pub struct Client {
    addr: SocketAddr,
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client, String> {
        let w = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        w.set_read_timeout(Some(Duration::from_secs(60))).map_err(|e| format!("timeout: {e}"))?;
        let r = BufReader::new(w.try_clone().map_err(|e| format!("clone: {e}"))?);
        Ok(Client { addr, w, r })
    }

    /// Queue a request without reading its response (pipelining). With
    /// `close` the request asks the server to end the connection after
    /// answering.
    pub fn send_only(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
        close: bool,
    ) -> Result<(), String> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: {}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
            self.addr,
            body.len(),
            if close { "close" } else { "keep-alive" }
        );
        self.w.write_all(head.as_bytes()).map_err(|e| format!("send: {e}"))?;
        self.w.write_all(body).map_err(|e| format!("send: {e}"))?;
        self.w.flush().map_err(|e| format!("send: {e}"))
    }

    /// Read the next in-order response off the connection.
    pub fn recv(&mut self) -> Result<Response, String> {
        read_response(&mut self.r)
    }

    /// One request-response exchange, leaving the connection open.
    pub fn send(&mut self, method: &str, path: &str, body: &[u8]) -> Result<Response, String> {
        self.send_only(method, path, body, false)?;
        self.recv()
    }

    /// Read the next chunk of an in-flight chunked response (after a
    /// [`Client::send_only`] to a streaming route and manual header
    /// consumption via [`Client::recv_stream_head`]).
    pub fn recv_chunk(&mut self) -> Result<Option<Vec<u8>>, String> {
        read_chunk(&mut self.r)
    }

    /// Consume a streaming response's status line and headers, leaving the
    /// chunked body for incremental [`Client::recv_chunk`] calls.
    pub fn recv_stream_head(&mut self) -> Result<(u16, Vec<(String, String)>), String> {
        let mut budget = MAX_HEADER_BYTES;
        let start = read_crlf_line(&mut self.r, &mut budget)?;
        let status: u16 = start
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| format!("malformed status line `{start}`"))?;
        let mut headers = Vec::new();
        loop {
            let line = read_crlf_line(&mut self.r, &mut budget)?;
            if line.is_empty() {
                break;
            }
            let (k, v) =
                line.split_once(':').ok_or_else(|| format!("malformed header `{line}`"))?;
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
        Ok((status, headers))
    }

    /// Shut down the write half, signalling a half-closed socket to the
    /// server while the read half stays open (the keep-alive edge-case
    /// tests drive this).
    pub fn close_write(&mut self) -> Result<(), String> {
        self.w.shutdown(std::net::Shutdown::Write).map_err(|e| format!("shutdown: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_with_body_parses() {
        let raw = b"POST /v1/run?x=1 HTTP/1.1\r\nHost: localhost\r\nContent-Length: 4\r\n\r\nabcd";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/run");
        assert_eq!(req.header("HOST"), Some("localhost"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn request_without_body_is_empty() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn malformed_requests_error() {
        for raw in [
            &b"GARBAGE\r\n\r\n"[..],
            &b"GET /x SMTP/1.0\r\n\r\n"[..],
            &b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n"[..],
            &b"POST /x HTTP/1.1\r\ncontent-length: nan\r\n\r\n"[..],
            &b"POST /x HTTP/1.1\r\ncontent-length: 10\r\n\r\nshort"[..],
            &b""[..],
        ] {
            assert!(read_request(&mut Cursor::new(raw)).is_err(), "{raw:?}");
        }
    }

    #[test]
    fn oversized_bodies_rejected() {
        let raw = format!("POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(read_request(&mut Cursor::new(raw.as_bytes())).is_err());
    }

    #[test]
    fn oversized_header_blocks_rejected_even_without_newlines() {
        // A single header "line" with no terminator must hit the size
        // bound, not buffer without limit.
        let mut raw = b"GET /x HTTP/1.1\r\nx: ".to_vec();
        raw.extend(std::iter::repeat(b'a').take(MAX_HEADER_BYTES + 64));
        assert!(read_request(&mut Cursor::new(&raw[..])).is_err());
        // And many well-formed lines overflow the same budget.
        let mut raw = b"GET /x HTTP/1.1\r\n".to_vec();
        for i in 0..2048 {
            raw.extend(format!("h{i}: {}\r\n", "v".repeat(64)).into_bytes());
        }
        raw.extend(b"\r\n");
        assert!(read_request(&mut Cursor::new(&raw[..])).is_err());
    }

    #[test]
    fn response_roundtrips_through_writer_and_parser() {
        let mut buf = Vec::new();
        write_response(&mut buf, 200, &[("x-r2f2-cache", "hit")], "application/json", b"{}")
            .unwrap();
        let resp = read_response(&mut Cursor::new(&buf[..])).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("X-R2F2-Cache"), Some("hit"));
        assert_eq!(resp.body, b"{}");
        assert_eq!(resp.text(), "{}");
    }

    #[test]
    fn error_statuses_carry_reasons() {
        let mut buf = Vec::new();
        write_response(&mut buf, 503, &[], "application/json", b"{\"error\": \"full\"}").unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert_eq!(reason(404), "Not Found");
        assert_eq!(reason(405), "Method Not Allowed");
        assert_eq!(reason(202), "Accepted");
        assert_eq!(reason(409), "Conflict");
    }

    #[test]
    fn keep_alive_responses_differ_only_in_the_connection_header() {
        let mut one = Vec::new();
        let mut ka = Vec::new();
        write_response_with(&mut one, 200, &[], "application/json", b"{\"x\": 1}", true).unwrap();
        write_response_with(&mut ka, 200, &[], "application/json", b"{\"x\": 1}", false).unwrap();
        let one = String::from_utf8(one).unwrap();
        let ka = String::from_utf8(ka).unwrap();
        assert!(one.contains("connection: close\r\n"));
        assert!(ka.contains("connection: keep-alive\r\n"));
        assert_eq!(
            one.replace("connection: close", "connection: keep-alive"),
            ka,
            "identical apart from the connection header"
        );
        // Both parse to the same body.
        let a = read_response(&mut Cursor::new(one.as_bytes())).unwrap();
        let b = read_response(&mut Cursor::new(ka.as_bytes())).unwrap();
        assert_eq!(a.body, b.body);
    }

    #[test]
    fn chunked_stream_roundtrips_through_writer_and_parser() {
        let mut buf = Vec::new();
        write_chunked_head(&mut buf, 200, &[("x-r2f2-job", "job-1")], "application/x-ndjson")
            .unwrap();
        write_chunk(&mut buf, b"{\"event\": \"epoch\"}\n").unwrap();
        write_chunk(&mut buf, b"").unwrap(); // skipped, not a terminator
        write_chunk(&mut buf, b"{\"event\": \"done\"}\n").unwrap();
        finish_chunked(&mut buf).unwrap();
        let resp = read_response(&mut Cursor::new(&buf[..])).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("transfer-encoding"), Some("chunked"));
        assert_eq!(resp.header("x-r2f2-job"), Some("job-1"));
        assert_eq!(resp.text(), "{\"event\": \"epoch\"}\n{\"event\": \"done\"}\n");
    }

    #[test]
    fn chunks_read_incrementally() {
        let mut buf = Vec::new();
        write_chunk(&mut buf, b"alpha").unwrap();
        write_chunk(&mut buf, b"beta").unwrap();
        finish_chunked(&mut buf).unwrap();
        let mut r = Cursor::new(&buf[..]);
        assert_eq!(read_chunk(&mut r).unwrap().as_deref(), Some(&b"alpha"[..]));
        assert_eq!(read_chunk(&mut r).unwrap().as_deref(), Some(&b"beta"[..]));
        assert_eq!(read_chunk(&mut r).unwrap(), None);
    }

    #[test]
    fn malformed_chunks_error() {
        for raw in [&b"zz\r\n"[..], &b"5\r\nabcdeXX"[..], &b"ffffffffff\r\n"[..]] {
            assert!(read_chunk(&mut Cursor::new(raw)).is_err(), "{raw:?}");
        }
    }
}
